"""AOT build entrypoint: datasets -> trained models -> HLO artifacts.

Runs once at `make artifacts`; emits everything the Rust coordinator needs:

  artifacts/data/<ds>.bin      — quantized dataset (binary, see datasets.py)
  artifacts/models/<ds>.json   — integer model (powers/signs/biases/trunc)
  artifacts/hlo/<ds>_b<B>.hlo.txt — lowered hybrid forward, B in {1, 256}
  artifacts/manifest.json      — index of all of the above

HLO **text** is the interchange format: jax >= 0.5 serializes protos with
64-bit instruction ids which xla_extension 0.5.1 (the version the `xla`
crate binds) rejects; the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from . import datasets, model, train

BATCHES = (1, 256)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_dataset(cfg: datasets.DatasetConfig, trunc: int, batch: int) -> str:
    fn = functools.partial(model.mlp_forward, trunc=trunc)
    lowered = jax.jit(fn).lower(*model.example_args(cfg, batch))
    return to_hlo_text(lowered)


def model_to_json(m: train.QuantModel) -> dict:
    c = m.cfg
    return {
        "name": c.name,
        "features": c.features,
        "classes": c.classes,
        "hidden": c.hidden,
        "in_bits": 4,
        "w_bits": c.w_bits,
        "pmax": c.pmax,
        "trunc": m.trunc,
        "seq_clock_ms": c.seq_clock_ms,
        "comb_clock_ms": c.comb_clock_ms,
        "float_acc": m.float_acc,
        "train_acc": m.train_acc,
        "test_acc": m.test_acc,
        "w1_p": m.w1p.tolist(),
        "w1_s": m.w1s.tolist(),
        "b1": m.b1.tolist(),
        "w2_p": m.w2p.tolist(),
        "w2_s": m.w2s.tolist(),
        "b2": m.b2.tolist(),
    }


def build_one(name: str, out: str) -> dict:
    cfg = datasets.CONFIGS[name]
    t0 = time.time()
    ds = datasets.generate(cfg)
    datasets.save_bin(ds, os.path.join(out, "data", f"{name}.bin"))

    params = train.train_float(ds)
    qm = train.quantize_and_qat(ds, params)
    with open(os.path.join(out, "models", f"{name}.json"), "w") as fh:
        json.dump(model_to_json(qm), fh)

    hlo_paths = {}
    for b in BATCHES:
        text = lower_dataset(cfg, qm.trunc, b)
        path = os.path.join("hlo", f"{name}_b{b}.hlo.txt")
        with open(os.path.join(out, path), "w") as fh:
            fh.write(text)
        hlo_paths[str(b)] = path

    entry = {
        "name": name,
        "data": f"data/{name}.bin",
        "model": f"models/{name}.json",
        "hlo": hlo_paths,
        "float_acc": qm.float_acc,
        "quant_test_acc": qm.test_acc,
    }
    print(
        f"[aot] {name:<12} F={cfg.features:<4} H={cfg.hidden:<3} C={cfg.classes:<3} "
        f"trunc={qm.trunc} float={qm.float_acc:.3f} quant={qm.test_acc:.3f} "
        f"({time.time() - t0:.1f}s)"
    )
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--datasets", nargs="*", default=datasets.DATASET_ORDER)
    args = ap.parse_args()

    for sub in ("data", "models", "hlo", "results"):
        os.makedirs(os.path.join(args.out, sub), exist_ok=True)

    manifest = {"version": 2, "batches": list(BATCHES), "datasets": []}
    for name in args.datasets:
        manifest["datasets"].append(build_one(name, args.out))

    with open(os.path.join(args.out, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"[aot] wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
