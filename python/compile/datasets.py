"""Synthetic multi-sensor dataset generators (UCI substitute).

The paper evaluates on seven UCI datasets (SPECTF, Arrhythmia, Gas Sensor,
Epileptic Seizure, Activity Recognition, Parkinsons, HAR).  This environment
has no network access, so we generate deterministic synthetic datasets with
the *same feature/class dimensionalities* and with explicit feature
redundancy (correlated sensor groups + irrelevant channels) so that
Redundant Feature Pruning has real structure to exploit.  See DESIGN.md
§Substitutions.

Each dataset is a Gaussian mixture over a low-rank latent space:

    z_c ~ per-class latent anchor in R^k
    x   = U @ z_y + eps,   with redundant feature groups sharing U rows
          and a fraction of pure-noise (irrelevant) features.

`difficulty` scales the noise so that trained-model accuracies land in the
same regime the paper reports (Table 1: 61.8% for 12-class Arrhythmia up to
96.9% for HAR).

Inputs are quantized per-feature to 4-bit unsigned [0, 15] using train-set
min/max, exactly what the printed circuit's ADCs deliver.
"""

from __future__ import annotations

import dataclasses
import struct

import numpy as np

MAGIC = 0x504D4C50  # "PMLP"
VERSION = 2


@dataclasses.dataclass(frozen=True)
class DatasetConfig:
    """Static configuration for one paper dataset."""

    name: str
    features: int
    classes: int
    hidden: int
    n_train: int
    n_test: int
    # Fraction of features that are near-duplicates of another feature
    # (sensor redundancy) and fraction that are pure noise (irrelevant).
    redundant_frac: float
    noise_frac: float
    # Gaussian noise scale relative to class-anchor spread (higher = harder).
    difficulty: float
    # Weight quantization: sign + power-of-2 with p in [0, pmax].
    pmax: int
    w_bits: int
    # Synthesis clocks from paper §4.1 (ms).
    seq_clock_ms: float
    comb_clock_ms: float
    seed: int


# Hidden sizes chosen so coefficient counts track the paper's ordering
# (Fig. 6 orders datasets by coefficient count; HAR tops out at ~8.5k
# coefficients, Parkinsons has the most inputs, 753). See DESIGN.md.
CONFIGS: dict[str, DatasetConfig] = {
    c.name: c
    for c in [
        DatasetConfig("spectf", 44, 2, 3, 1200, 400, 0.20, 0.10, 10.3, 6, 8, 80.0, 200.0, 101),
        DatasetConfig("arrhythmia", 274, 12, 4, 1600, 400, 0.22, 0.12, 9.5, 6, 8, 100.0, 320.0, 102),
        DatasetConfig("gas", 128, 6, 10, 1600, 400, 0.20, 0.10, 7.0, 6, 8, 100.0, 320.0, 103),
        DatasetConfig("epileptic", 178, 5, 10, 1600, 400, 0.20, 0.10, 8.5, 6, 8, 120.0, 320.0, 104),
        DatasetConfig("activity", 533, 4, 4, 1600, 400, 0.25, 0.12, 21.0, 6, 8, 120.0, 320.0, 105),
        DatasetConfig("parkinsons", 753, 2, 5, 1600, 400, 0.25, 0.15, 35.0, 6, 8, 120.0, 320.0, 106),
        DatasetConfig("har", 561, 6, 15, 2000, 500, 0.22, 0.10, 10.0, 12, 14, 100.0, 320.0, 107),
    ]
}

DATASET_ORDER = ["spectf", "arrhythmia", "gas", "epileptic", "activity", "parkinsons", "har"]


@dataclasses.dataclass
class Dataset:
    """A generated dataset, quantized to the circuit's input format."""

    config: DatasetConfig
    x_train: np.ndarray  # (n_train, F) uint8 in [0, 15]
    y_train: np.ndarray  # (n_train,) uint16
    x_test: np.ndarray
    y_test: np.ndarray


def _latent_anchors(rng: np.random.Generator, classes: int, k: int) -> np.ndarray:
    """Well-separated class anchors on a scaled sphere in R^k."""
    z = rng.normal(size=(classes, k))
    z /= np.linalg.norm(z, axis=1, keepdims=True)
    return z * 3.0


def generate(cfg: DatasetConfig) -> Dataset:
    """Deterministically generate one dataset from its config."""
    rng = np.random.default_rng(cfg.seed)
    k = max(6, min(16, cfg.classes + 4))
    anchors = _latent_anchors(rng, cfg.classes, k)

    f = cfg.features
    n_noise = int(round(f * cfg.noise_frac))
    n_red = int(round(f * cfg.redundant_frac))
    n_base = f - n_noise - n_red

    # Base projection: each informative feature mixes a few latent dims.
    u = rng.normal(size=(n_base, k)) * rng.uniform(0.3, 1.5, size=(n_base, 1))

    # Redundant features duplicate a random base *sensor reading* (signal
    # AND noise) with a gain mismatch plus a small independent jitter — the
    # "more sensors => more correlated features" effect of §3.2.2.
    dup_src = rng.integers(0, n_base, size=n_red)
    dup_gain = rng.uniform(0.8, 1.2, size=n_red)

    # Shuffle feature order so redundancy is not positional.
    perm = rng.permutation(f)

    def sample(n: int) -> tuple[np.ndarray, np.ndarray]:
        y = rng.integers(0, cfg.classes, size=n)
        base = anchors[y] @ u.T + rng.normal(size=(n, n_base)) * cfg.difficulty
        dup = base[:, dup_src] * dup_gain[None, :] + rng.normal(size=(n, n_red)) * (
            0.2 * cfg.difficulty
        )
        # Pure-noise features carry no class signal but look "alive".
        noise = rng.normal(size=(n, n_noise)) * (1.0 + cfg.difficulty)
        x = np.concatenate([base, dup, noise], axis=1)
        return x[:, perm], y

    xr_train, y_train = sample(cfg.n_train)
    xr_test, y_test = sample(cfg.n_test)

    # 4-bit ADC quantization with train-set calibration.
    lo = xr_train.min(axis=0)
    hi = xr_train.max(axis=0)
    span = np.maximum(hi - lo, 1e-9)

    def quant(xr: np.ndarray) -> np.ndarray:
        q = np.round((xr - lo) / span * 15.0)
        return np.clip(q, 0, 15).astype(np.uint8)

    return Dataset(
        config=cfg,
        x_train=quant(xr_train),
        y_train=y_train.astype(np.uint16),
        x_test=quant(xr_test),
        y_test=y_test.astype(np.uint16),
    )


def save_bin(ds: Dataset, path: str) -> None:
    """Write the compact binary interchange format consumed by rust/src/data.

    Layout (little-endian):
      u32 magic, u32 version, u32 n_train, u32 n_test, u32 features,
      u32 classes, then x_train (n_train*F u8), y_train (n_train u16),
      x_test, y_test.
    """
    c = ds.config
    with open(path, "wb") as fh:
        fh.write(
            struct.pack(
                "<6I", MAGIC, VERSION, len(ds.y_train), len(ds.y_test), c.features, c.classes
            )
        )
        fh.write(ds.x_train.tobytes(order="C"))
        fh.write(ds.y_train.astype("<u2").tobytes())
        fh.write(ds.x_test.tobytes(order="C"))
        fh.write(ds.y_test.astype("<u2").tobytes())


def load_bin(path: str) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, int]:
    """Read back `save_bin` output (used by tests for round-trip checks)."""
    with open(path, "rb") as fh:
        magic, version, n_train, n_test, features, classes = struct.unpack("<6I", fh.read(24))
        if magic != MAGIC or version != VERSION:
            raise ValueError(f"bad dataset file {path}: magic={magic:#x} version={version}")
        x_train = np.frombuffer(fh.read(n_train * features), dtype=np.uint8).reshape(
            n_train, features
        )
        y_train = np.frombuffer(fh.read(n_train * 2), dtype="<u2")
        x_test = np.frombuffer(fh.read(n_test * features), dtype=np.uint8).reshape(
            n_test, features
        )
        y_test = np.frombuffer(fh.read(n_test * 2), dtype="<u2")
    return x_train, y_train, x_test, y_test, classes
