"""Build-time training: float pretrain + power-of-2 QAT (QKeras substitute).

Pipeline (per dataset):
  1. Float pretrain: standard 2-layer ReLU MLP, Adam, cross-entropy.
  2. One-shot pow2 quantization into integer units (per-layer scale folded
     into the qReLU truncation, so argmax is preserved).
  3. QAT fine-tune with straight-through estimators for the pow2 weight
     quantizer, bias rounding, and the qReLU floor — the forward pass
     mirrors the integer circuit semantics exactly (§3.2.1).
  4. Emit the final integer model (signs, powers, biases, trunc) and its
     bit-exact accuracies measured with the int32 reference oracle.

Only ever runs at `make artifacts` time; nothing here is on the request
path.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets
from .kernels import ref


@dataclasses.dataclass
class QuantModel:
    """Final integer model in circuit units."""

    cfg: datasets.DatasetConfig
    w1p: np.ndarray  # (H, F) int32 powers
    w1s: np.ndarray  # (H, F) int32 signs in {-1, 0, +1}
    b1: np.ndarray  # (H,) int32
    w2p: np.ndarray  # (C, H) int32
    w2s: np.ndarray  # (C, H) int32
    b2: np.ndarray  # (C,) int32
    trunc: int
    float_acc: float
    train_acc: float
    test_acc: float


# ---------------------------------------------------------------------------
# Float pretrain
# ---------------------------------------------------------------------------


def _init_params(rng, f, h, c):
    k1, k2 = jax.random.split(rng)
    return {
        "w1": jax.random.normal(k1, (h, f)) * (1.0 / np.sqrt(f)),
        "b1": jnp.zeros((h,)),
        "w2": jax.random.normal(k2, (c, h)) * (1.0 / np.sqrt(h)),
        "b2": jnp.zeros((c,)),
    }


def _float_forward(params, x):
    hid = jax.nn.relu(x @ params["w1"].T + params["b1"])
    return hid @ params["w2"].T + params["b2"]


def _ce_loss(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def _adam(grads, state, params, lr, step, b1=0.9, b2=0.999, eps=1e-8):
    m, v = state
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mh = jax.tree.map(lambda a: a / (1 - b1**step), m)
    vh = jax.tree.map(lambda a: a / (1 - b2**step), v)
    params = jax.tree.map(lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), params, mh, vh)
    return params, (m, v)


def train_float(ds: datasets.Dataset, steps: int = 600, lr: float = 1e-2, seed: int = 0):
    """Full-batch Adam on the float model.

    Training runs on standardized inputs (zero mean / unit variance per
    feature) for conditioning, then the standardization affine is folded
    back into (w1, b1):  w1·(x-μ)/σ = (w1/σ)·x - (w1/σ)·μ.  The returned
    params therefore consume raw `x/15` — the same structure the integer
    circuit implements — with no approximation.
    """
    cfg = ds.config
    x_raw = jnp.asarray(ds.x_train, jnp.float32) / 15.0
    mu = x_raw.mean(axis=0)
    sd = jnp.maximum(x_raw.std(axis=0), 1e-3)
    x = (x_raw - mu) / sd
    y = jnp.asarray(ds.y_train, jnp.int32)
    params = _init_params(jax.random.PRNGKey(seed + cfg.seed), cfg.features, cfg.hidden, cfg.classes)
    state = (jax.tree.map(jnp.zeros_like, params), jax.tree.map(jnp.zeros_like, params))

    @jax.jit
    def step_fn(params, state, step):
        loss, grads = jax.value_and_grad(lambda p: _ce_loss(_float_forward(p, x), y))(params)
        params, state = _adam(grads, state, params, lr, step)
        return params, state, loss

    for i in range(1, steps + 1):
        params, state, _ = step_fn(params, state, jnp.float32(i))

    # Fold standardization into layer 1 so the model consumes raw x/15.
    w1 = params["w1"] / sd[None, :]
    b1 = params["b1"] - w1 @ mu
    return {"w1": w1, "b1": b1, "w2": params["w2"], "b2": params["b2"]}


def float_accuracy(params, x_u8, y) -> float:
    logits = _float_forward(params, jnp.asarray(x_u8, jnp.float32) / 15.0)
    return float(jnp.mean(jnp.argmax(logits, axis=1) == jnp.asarray(y, jnp.int32)))


# ---------------------------------------------------------------------------
# Pow2 quantization + QAT
# ---------------------------------------------------------------------------


def _pow2_quantize_np(w: np.ndarray, pmax: int) -> tuple[np.ndarray, np.ndarray]:
    """Map integer-unit float weights to (sign, power); |w| < 0.5 -> zero."""
    mag = np.abs(w)
    s = np.where(mag < 0.5, 0, np.sign(w)).astype(np.int32)
    with np.errstate(divide="ignore"):
        p = np.clip(np.round(np.log2(np.maximum(mag, 1e-12))), 0, pmax).astype(np.int32)
    p = np.where(s == 0, 0, p)
    return p, s


def _ste_pow2(u, pmax):
    """Forward: s*2^clamp(round(log2|u|),0,pmax) (0 if |u|<0.5); grad: identity."""
    mag = jnp.abs(u)
    p = jnp.clip(jnp.round(jnp.log2(jnp.maximum(mag, 1e-12))), 0, pmax)
    q = jnp.where(mag < 0.5, 0.0, jnp.sign(u) * jnp.exp2(p))
    return u + jax.lax.stop_gradient(q - u)


def _ste_round(u):
    return u + jax.lax.stop_gradient(jnp.round(u) - u)


def _ste_floor(u):
    return u + jax.lax.stop_gradient(jnp.floor(u) - u)


def _quant_forward(params, x_int, trunc, pmax):
    """Differentiable mirror of the integer circuit forward."""
    w1 = _ste_pow2(params["w1"], pmax)
    w2 = _ste_pow2(params["w2"], pmax)
    b1 = _ste_round(params["b1"])
    b2 = _ste_round(params["b2"])
    acc = x_int @ w1.T + b1
    hid = jnp.clip(_ste_floor(jnp.maximum(acc, 0.0) / (2.0**trunc)), 0.0, 15.0)
    return hid @ w2.T + b2


def _integer_unit_params(params, pmax: int):
    """Rescale float params into integer units (see module docstring)."""
    w1, b1 = np.asarray(params["w1"]), np.asarray(params["b1"])
    w2, b2 = np.asarray(params["w2"]), np.asarray(params["b2"])
    g1 = np.abs(w1).max() / (2.0**pmax)
    # Float model consumed x/15; integer model consumes x, so bias scales
    # by 15/g1 in layer 1.
    u1 = w1 / g1
    ub1 = 15.0 * b1 / g1
    return u1, ub1, w2, b2, g1


def _calibrate(u1, ub1, x_int, pmax) -> int:
    """Pick qReLU truncation so the 99th-pct activation fills [0, 15]."""
    p, s = _pow2_quantize_np(u1, pmax)
    w1q = s * (2.0**p)
    acc = np.maximum(x_int @ w1q.T + np.round(ub1), 0.0)
    a99 = np.quantile(acc, 0.99)
    return max(0, int(np.ceil(np.log2(max(a99, 1.0) / 15.0 + 1e-9))))


def quantize_and_qat(
    ds: datasets.Dataset,
    params,
    qat_steps: int = 250,
    lr: float = 5e-3,
    trunc_search: tuple[int, ...] = (-2, -1, 0, 1),
) -> QuantModel:
    """QAT with a small search over the qReLU truncation.

    The activation-quantile calibration is a good starting point, but the
    best truncation also depends on how the 4-bit hidden code interacts
    with the output layer; a short QAT probe per candidate (then a full
    run on the winner) recovers several accuracy points on the harder
    datasets (e.g. 12-class Arrhythmia).
    """
    best: QuantModel | None = None
    for off in trunc_search:
        probe = _quantize_and_qat_fixed(ds, params, qat_steps=80, lr=lr, trunc_off=off)
        if best is None or probe.train_acc > best.train_acc:
            best = probe
            best_off = off
    return _quantize_and_qat_fixed(ds, params, qat_steps=qat_steps, lr=lr, trunc_off=best_off)


def _quantize_and_qat_fixed(
    ds: datasets.Dataset,
    params,
    qat_steps: int,
    lr: float,
    trunc_off: int = 0,
) -> QuantModel:
    cfg = ds.config
    x_int = np.asarray(ds.x_train, np.float32)
    y = jnp.asarray(ds.y_train, jnp.int32)
    pmax = cfg.pmax

    u1, ub1, w2f, b2f, g1 = _integer_unit_params(params, pmax)
    trunc = max(0, _calibrate(u1, ub1, x_int, pmax) + trunc_off)

    # Layer-2 rescale: hidden is now ~[0,15]; float hidden was relu(a_f).
    # kappa = 15/(g1*2^trunc) maps float hidden to integer hidden.
    g2 = np.abs(w2f).max() / (2.0**pmax)
    kappa = 15.0 / (g1 * (2.0**trunc))
    u2 = w2f / g2
    ub2 = kappa * b2f / g2

    qp = {
        "w1": jnp.asarray(u1),
        "b1": jnp.asarray(ub1),
        "w2": jnp.asarray(u2),
        "b2": jnp.asarray(ub2),
    }
    state = (jax.tree.map(jnp.zeros_like, qp), jax.tree.map(jnp.zeros_like, qp))
    xj = jnp.asarray(x_int)

    @jax.jit
    def step_fn(qp, state, step):
        def loss_fn(p):
            logits = _quant_forward(p, xj, trunc, pmax)
            # Normalize logit scale for a sane softmax temperature.
            tau = jax.lax.stop_gradient(jnp.maximum(jnp.std(logits), 1.0))
            return _ce_loss(logits / tau, y)

        loss, grads = jax.value_and_grad(loss_fn)(qp)
        qp, state = _adam(grads, state, qp, lr, step)
        return qp, state, loss

    for i in range(1, qat_steps + 1):
        qp, state, _ = step_fn(qp, state, jnp.float32(i))

    w1p, w1s = _pow2_quantize_np(np.asarray(qp["w1"]), pmax)
    w2p, w2s = _pow2_quantize_np(np.asarray(qp["w2"]), pmax)
    b1 = np.round(np.asarray(qp["b1"])).astype(np.int32)
    b2 = np.round(np.asarray(qp["b2"])).astype(np.int32)

    model = QuantModel(
        cfg=cfg,
        w1p=w1p,
        w1s=w1s,
        b1=b1,
        w2p=w2p,
        w2s=w2s,
        b2=b2,
        trunc=trunc,
        float_acc=float_accuracy(params, ds.x_test, ds.y_test),
        train_acc=0.0,
        test_acc=0.0,
    )
    model.train_acc = quant_accuracy(model, ds.x_train, ds.y_train)
    model.test_acc = quant_accuracy(model, ds.x_test, ds.y_test)
    return model


def quant_accuracy(m: QuantModel, x_u8: np.ndarray, y: np.ndarray) -> float:
    """Bit-exact int32 accuracy via the reference oracle (exact neurons)."""
    h = m.cfg.hidden
    f = m.cfg.features
    pred, _ = ref.mlp_ref(
        jnp.asarray(x_u8, jnp.int32),
        jnp.asarray(m.w1p),
        jnp.asarray(m.w1s),
        jnp.asarray(m.b1),
        jnp.asarray(m.w2p),
        jnp.asarray(m.w2s),
        jnp.asarray(m.b2),
        jnp.ones((f,), jnp.int32),
        jnp.zeros((h,), jnp.int32),
        jnp.zeros((h, 2), jnp.int32),
        jnp.zeros((h, 2), jnp.int32),
        jnp.zeros((h, 2), jnp.int32),
        jnp.zeros((h, 2), jnp.int32),
        jnp.zeros((h,), jnp.int32),
        m.trunc,
    )
    return float(jnp.mean(pred == jnp.asarray(y, jnp.int32)))
