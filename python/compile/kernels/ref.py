"""Pure-jnp oracles for the Pallas kernels.

These define the *bit-exact functional semantics* shared by every layer of
the stack (Pallas kernel, the Rust functional model in `rust/src/model`,
and the gate-level netlist simulator).  All arithmetic is int32; power-of-2
multiplication is a left shift, exactly as the barrel shifter in the
printed circuit performs it (DESIGN.md §Functional semantics).

Conventions:
  x        : (B, F) int32, 4-bit unsigned values in [0, 15]
  p        : (H, F) int32, shift amount (weight power), p in [0, pmax]
  s        : (H, F) int32, weight sign in {-1, 0, +1}; 0 == pruned weight
  bias     : (H,)  int32, accumulator units
  feat_mask: (F,)  int32 in {0, 1}; 0 == feature pruned by RFP
"""

from __future__ import annotations

import jax.numpy as jnp


def pow2_matvec_ref(x, p, s, bias, feat_mask):
    """acc[b,h] = bias[h] + sum_f mask[f] * s[h,f] * (x[b,f] << p[h,f])."""
    x = x.astype(jnp.int32)
    shifted = jnp.left_shift(x[:, None, :], p[None, :, :])  # (B, H, F)
    terms = shifted * s[None, :, :] * feat_mask[None, None, :]
    return bias[None, :] + jnp.sum(terms, axis=2)


def qrelu_ref(acc, trunc):
    """Quantized ReLU: clamp(max(acc, 0) >> trunc, 0, 15)  (§3.2.1).

    Truncates `trunc` LSBs and saturates to the 4-bit input range of the
    next layer, avoiding any re-quantization step.
    """
    pos = jnp.maximum(acc, 0)
    return jnp.minimum(jnp.right_shift(pos, trunc), 15)


def approx_accum_ref(x_imp, pos, l1, sign, imp_mask, base):
    """Single-cycle (approximated) neuron accumulator (Fig. 2c / Fig. 5).

    x_imp    : (B, H, 2) int32 — the two most-important inputs per neuron,
               gathered by the caller (the circuit receives them on their
               scheduled cycle via en0/en1).
    pos      : (H, 2) int32 — bit position probed in each input
               (expected-leading-1 minus the weight power, clamped to the
               4-bit input width).
    l1       : (H, 2) int32 — expected leading-1 position of the product;
               the 1-bit sum is rewired (shifted) to this column.
    sign     : (H, 2) int32 in {-1, 0, +1} — weight sign (0: input pruned).
    imp_mask : (H, 2) int32 in {0, 1} — feat_mask gathered at the
               important-input indices.
    base     : (H,) int32 — the hardwired constant the two bit
               contributions modulate: bias plus the rounded expected
               contribution of every other active feature (the §3.1.2
               realignment; folds into the reset constant, so free).

    acc[b,h] = base[h]
             + sum_k sign[h,k] * (bit(x_imp[b,h,k], pos[h,k]) << l1[h,k])
    """
    bit = jnp.right_shift(x_imp, pos[None, :, :]) & 1  # (B, H, 2)
    contrib = sign[None, :, :] * jnp.left_shift(bit, l1[None, :, :])
    contrib = contrib * imp_mask[None, :, :]
    return base[None, :] + jnp.sum(contrib, axis=2)


def hybrid_hidden_ref(
    x, p, s, bias, feat_mask, approx_mask, x_imp, pos, l1, sign, imp_mask, base, trunc
):
    """Hidden layer with per-neuron exact/approx selection (§3.1.3)."""
    exact = pow2_matvec_ref(x, p, s, bias, feat_mask)
    approx = approx_accum_ref(x_imp, pos, l1, sign, imp_mask, base)
    acc = jnp.where(approx_mask[None, :] == 1, approx, exact)
    return qrelu_ref(acc, trunc)


def mlp_ref(
    x,
    w1p,
    w1s,
    b1,
    w2p,
    w2s,
    b2,
    feat_mask,
    approx_mask,
    imp_idx,
    imp_pos,
    imp_l1,
    imp_sign,
    imp_base,
    trunc,
):
    """Full hybrid MLP forward: hidden (qReLU) -> output -> (pred, logits).

    The output layer is always exact multi-cycle (the paper only
    approximates hidden neurons; outputs feed the argmax directly), and
    hidden "features" are never pruned, so its mask is all-ones.
    """
    x_imp = jnp.take(x, imp_idx.reshape(-1), axis=1).reshape(x.shape[0], -1, 2)
    imp_mask = jnp.take(feat_mask, imp_idx.reshape(-1)).reshape(-1, 2)
    hid = hybrid_hidden_ref(
        x, w1p, w1s, b1, feat_mask, approx_mask, x_imp, imp_pos, imp_l1, imp_sign,
        imp_mask, imp_base, trunc
    )
    hid_mask = jnp.ones((w1p.shape[0],), dtype=jnp.int32)
    logits = pow2_matvec_ref(hid, w2p, w2s, b2, hid_mask)
    pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
    return pred, logits
