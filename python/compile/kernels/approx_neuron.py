"""Layer-1 Pallas kernel: single-cycle (approximated) neuron accumulator.

Functional model of the paper's Fig. 2c / Fig. 5 single-cycle neuron: the
two most-important inputs are probed at one bit position each (the
expected-leading-1 of their products, computed offline from avg_prod,
Eq. 1); the two bits feed a 1-bit adder whose output is rewired to the
leading-1 column.  Here that is `(bit << l1)` with sign and mask applied —
bit-exact w.r.t. the hybrid netlist generated in `rust/src/circuits`.

The gather of the two important inputs per neuron happens in the L2 model
(XLA gathers are cheap and fuse); this kernel is the arithmetic part, so
it stays a pure elementwise/reduce block over (bt, H, 2) tiles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_imp_ref, pos_ref, l1_ref, sign_ref, imp_mask_ref, bias_ref, o_ref):
    x_imp = x_imp_ref[...]  # (bt, H, 2)
    pos = pos_ref[...]  # (H, 2)
    l1 = l1_ref[...]  # (H, 2)
    sign = sign_ref[...]  # (H, 2)
    mask = imp_mask_ref[...]  # (H, 2)
    bias = bias_ref[...]  # (H,)

    bit = jnp.right_shift(x_imp, pos[None, :, :]) & 1
    contrib = sign[None, :, :] * jnp.left_shift(bit, l1[None, :, :]) * mask[None, :, :]
    o_ref[...] = bias[None, :] + jnp.sum(contrib, axis=2)


def approx_accum(x_imp, pos, l1, sign, imp_mask, bias, *, bt: int = 256):
    """acc[b,h] = bias[h] + sum_k sign*(bit(x_imp, pos) << l1)*mask.

    Shapes: x_imp (B, H, 2) int32; pos, l1, sign, imp_mask (H, 2); bias (H,).
    """
    b, h, _ = x_imp.shape
    bt = min(bt, max(b, 1))
    bp = -b % bt
    if bp:
        x_imp = jnp.pad(x_imp, ((0, bp), (0, 0), (0, 0)))
    out = pl.pallas_call(
        _kernel,
        grid=((b + bp) // bt,),
        in_specs=[
            pl.BlockSpec((bt, h, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((h, 2), lambda i: (0, 0)),
            pl.BlockSpec((h, 2), lambda i: (0, 0)),
            pl.BlockSpec((h, 2), lambda i: (0, 0)),
            pl.BlockSpec((h, 2), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b + bp, h), jnp.int32),
        interpret=True,
    )(x_imp, pos, l1, sign, imp_mask, bias)
    return out[:b]
