"""Layer-1 Pallas kernel: power-of-2 shift-add matvec with qReLU epilogue.

This is the compute hot-spot of the whole stack: every RFP sweep step and
every NSGA-II fitness evaluation runs the quantized MLP forward over a
training batch, and both layers of that forward are this kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the printed circuit
time-multiplexes ONE barrel shifter per neuron across feature cycles; on a
vector machine we instead tile the (batch × feature) plane into VMEM-sized
blocks with BlockSpec and evaluate the shift-add contraction densely —
`x << p` is the barrel shifter, the block-local accumulation is the
accumulator register.  Power-of-2 multiply is a shift, so int32 semantics
are bit-exact w.r.t. the netlist simulator.

The kernel is lowered with `interpret=True`: the CPU PJRT client cannot
execute Mosaic custom-calls, and correctness (not TPU wallclock) is what
this environment can validate.  Block shapes are still chosen as if for a
real TPU VMEM budget; see EXPERIMENTS.md §Perf for the footprint analysis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  (bt × ft) int32 input block + (H × ft) weight blocks;
# with bt=64, ft=128, H<=16 the working set is
#   x: 64*128*4 = 32 KiB, p+s: 2*16*128*4 = 16 KiB, acc: 64*16*4 = 4 KiB
# comfortably inside a 16 MiB VMEM budget even with double buffering.
DEFAULT_BT = 64
DEFAULT_FT = 128


def _kernel(x_ref, p_ref, s_ref, bias_ref, mask_ref, o_ref, *, nf: int):
    """One (batch-tile, feature-tile) grid cell.

    Accumulates partial shift-add sums into o_ref across the feature-tile
    grid dimension (the classic K-loop accumulation pattern).
    """
    j = pl.program_id(1)

    x = x_ref[...]  # (bt, ft) int32
    p = p_ref[...]  # (H, ft) int32
    s = s_ref[...]  # (H, ft) int32
    mask = mask_ref[...]  # (ft,)  int32

    # Barrel shifter: x << p, sign/zero via s in {-1, 0, +1}, RFP via mask.
    shifted = jnp.left_shift(x[:, None, :], p[None, :, :])  # (bt, H, ft)
    part = jnp.sum(shifted * (s * mask[None, :])[None, :, :], axis=2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.broadcast_to(bias_ref[...][None, :], o_ref.shape) + part

    @pl.when(j != 0)
    def _accum():
        o_ref[...] += part

    # nf is static; silence "unused" for the 1-tile case.
    del nf


def pow2_matvec(x, p, s, bias, feat_mask, *, bt: int = DEFAULT_BT, ft: int = DEFAULT_FT):
    """acc[b,h] = bias[h] + sum_f mask[f]*s[h,f]*(x[b,f] << p[h,f]).

    Shapes: x (B, F) int32; p, s (H, F) int32; bias (H,); feat_mask (F,).
    B and F need not be tile-aligned: inputs are padded here and padding
    features are masked out (mask=0), so padding is bit-exact-neutral.
    """
    b, f = x.shape
    h = p.shape[0]
    bt = min(bt, max(b, 1))
    ft = min(ft, max(f, 1))
    bp = -b % bt
    fp = -f % ft
    if bp or fp:
        x = jnp.pad(x, ((0, bp), (0, fp)))
        p = jnp.pad(p, ((0, 0), (0, fp)))
        s = jnp.pad(s, ((0, 0), (0, fp)))
        feat_mask = jnp.pad(feat_mask, (0, fp))
    nb = (b + bp) // bt
    nf = (f + fp) // ft

    out = pl.pallas_call(
        functools.partial(_kernel, nf=nf),
        grid=(nb, nf),
        in_specs=[
            pl.BlockSpec((bt, ft), lambda i, j: (i, j)),
            pl.BlockSpec((h, ft), lambda i, j: (0, j)),
            pl.BlockSpec((h, ft), lambda i, j: (0, j)),
            pl.BlockSpec((h,), lambda i, j: (0,)),
            pl.BlockSpec((ft,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bt, h), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(((b + bp), h), jnp.int32),
        interpret=True,
    )(x, p, s, bias, feat_mask)
    return out[:b]


def _qrelu_kernel(acc_ref, o_ref, *, trunc: int):
    pos = jnp.maximum(acc_ref[...], 0)
    o_ref[...] = jnp.minimum(jnp.right_shift(pos, trunc), 15)


def qrelu(acc, trunc: int, *, bt: int = 256):
    """Quantized ReLU epilogue: clamp(max(acc,0) >> trunc, 0, 15)."""
    b, h = acc.shape
    bt = min(bt, max(b, 1))
    bp = -b % bt
    if bp:
        acc = jnp.pad(acc, ((0, bp), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_qrelu_kernel, trunc=trunc),
        grid=((b + bp) // bt,),
        in_specs=[pl.BlockSpec((bt, h), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b + bp, h), jnp.int32),
        interpret=True,
    )(acc)
    return out[:b]
