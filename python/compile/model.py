"""Layer-2 JAX model: the hybrid sequential super-TinyML MLP forward.

Composes the Layer-1 Pallas kernels (`kernels.pow2_matvec`,
`kernels.approx_neuron`) into the full classifier forward that the Rust
coordinator executes through PJRT:

    hidden  = qReLU( pow2_matvec(x, W1) | approx_accum(...) per neuron )
    logits  = pow2_matvec(hidden, W2)
    pred    = argmax(logits)

Every RFP / NSGA-II design decision is a *runtime argument* (feature mask,
approx mask, important-input tables), so one AOT-compiled artifact per
dataset serves the entire optimization loop without recompilation.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.approx_neuron import approx_accum
from .kernels.pow2_matvec import pow2_matvec, qrelu


def mlp_forward(
    x,
    w1p,
    w1s,
    b1,
    w2p,
    w2s,
    b2,
    feat_mask,
    approx_mask,
    imp_idx,
    imp_pos,
    imp_l1,
    imp_sign,
    imp_base,
    *,
    trunc: int,
):
    """Full hybrid forward.  Returns (pred (B,), logits (B, C)) int32.

    Static: shapes and `trunc` (baked per dataset at AOT time).
    Dynamic: everything else, including the masks and approx tables.
    """
    h = w1p.shape[0]
    x = x.astype(jnp.int32)

    # Exact multi-cycle path for every hidden neuron.
    acc_exact = pow2_matvec(x, w1p, w1s, b1, feat_mask)

    # Single-cycle path: gather the two most-important inputs per neuron
    # (the circuit sees them arrive on their scheduled cycle, en0/en1).
    bsz = x.shape[0]
    x_imp = jnp.take(x, imp_idx.reshape(-1), axis=1).reshape(bsz, h, 2)
    imp_mask = jnp.take(feat_mask, imp_idx.reshape(-1)).reshape(h, 2)
    acc_approx = approx_accum(x_imp, imp_pos, imp_l1, imp_sign, imp_mask, imp_base)

    acc = jnp.where(approx_mask[None, :] == 1, acc_approx, acc_exact)
    hidden = qrelu(acc, trunc)

    # Output layer: always exact; hidden values are never pruned.
    hid_mask = jnp.ones((h,), dtype=jnp.int32)
    logits = pow2_matvec(hidden, w2p, w2s, b2, hid_mask)
    pred = jnp.argmax(logits, axis=1).astype(jnp.int32)
    return pred, logits


def example_args(cfg, batch: int):
    """ShapeDtypeStructs matching `mlp_forward`'s signature for AOT lowering."""
    import jax

    f, h, c = cfg.features, cfg.hidden, cfg.classes
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    return (
        sds((batch, f), i32),  # x
        sds((h, f), i32),  # w1p
        sds((h, f), i32),  # w1s
        sds((h,), i32),  # b1
        sds((c, h), i32),  # w2p
        sds((c, h), i32),  # w2s
        sds((c,), i32),  # b2
        sds((f,), i32),  # feat_mask
        sds((h,), i32),  # approx_mask
        sds((h, 2), i32),  # imp_idx
        sds((h, 2), i32),  # imp_pos
        sds((h, 2), i32),  # imp_l1
        sds((h, 2), i32),  # imp_sign
        sds((h,), i32),  # imp_base
    )
