"""QAT pipeline invariants on a small dataset (fast smoke, not full build)."""

import dataclasses

import numpy as np
import pytest

from compile import datasets, train


@pytest.fixture(scope="module")
def trained():
    cfg = dataclasses.replace(datasets.CONFIGS["spectf"], n_train=600, n_test=200)
    ds = datasets.generate(cfg)
    params = train.train_float(ds, steps=200)
    qm = train.quantize_and_qat(ds, params, qat_steps=80)
    return ds, params, qm


def test_float_beats_chance(trained):
    ds, params, _ = trained
    acc = train.float_accuracy(params, ds.x_test, ds.y_test)
    assert acc > 0.7, acc


def test_quant_model_invariants(trained):
    _, _, qm = trained
    cfg = qm.cfg
    for s in (qm.w1s, qm.w2s):
        assert set(np.unique(s)) <= {-1, 0, 1}
    for p in (qm.w1p, qm.w2p):
        assert p.min() >= 0 and p.max() <= cfg.pmax
    assert qm.w1p.shape == (cfg.hidden, cfg.features)
    assert qm.w2p.shape == (cfg.classes, cfg.hidden)
    assert qm.trunc >= 0


def test_quant_close_to_float(trained):
    _, _, qm = trained
    assert qm.test_acc > qm.float_acc - 0.15, (qm.float_acc, qm.test_acc)


def test_quant_accuracy_reproducible(trained):
    ds, _, qm = trained
    again = train.quant_accuracy(qm, ds.x_test, ds.y_test)
    assert abs(again - qm.test_acc) < 1e-9


def test_pow2_quantizer_mapping():
    p, s = train._pow2_quantize_np(np.array([0.0, 0.4, 0.6, 1.0, -3.0, 100.0, -0.49]), pmax=6)
    np.testing.assert_array_equal(s, [0, 0, 1, 1, -1, 1, 0])
    # 0.6 -> 2^round(log2 0.6)=2^-1 clamped to 0; 3 -> 2^round(1.58)=2^2
    np.testing.assert_array_equal(p, [0, 0, 0, 0, 2, 6, 0])


def test_standardization_fold_is_exact():
    """train_float's fold must make the returned params consume raw x/15."""
    cfg = dataclasses.replace(datasets.CONFIGS["spectf"], n_train=300, n_test=100)
    ds = datasets.generate(cfg)
    params = train.train_float(ds, steps=30)
    # Recompute the accuracy two ways: folded params on raw inputs vs
    # checking the fold algebra directly on a few samples.
    import jax.numpy as jnp

    x = jnp.asarray(ds.x_test[:8], jnp.float32) / 15.0
    h = jnp.maximum(x @ params["w1"].T + params["b1"], 0.0)
    logits = h @ params["w2"].T + params["b2"]
    assert np.isfinite(np.asarray(logits)).all()
