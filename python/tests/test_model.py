"""L2 correctness: the composed hybrid MLP forward vs the oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

settings.register_profile("model", max_examples=15, deadline=None)
settings.load_profile("model")


def rand_mlp(rng, b, f, h, c, pmax=6):
    return dict(
        x=jnp.asarray(rng.integers(0, 16, size=(b, f), dtype=np.int32)),
        w1p=jnp.asarray(rng.integers(0, pmax + 1, size=(h, f), dtype=np.int32)),
        w1s=jnp.asarray(rng.integers(-1, 2, size=(h, f), dtype=np.int32)),
        b1=jnp.asarray(rng.integers(-200, 200, size=(h,), dtype=np.int32)),
        w2p=jnp.asarray(rng.integers(0, pmax + 1, size=(c, h), dtype=np.int32)),
        w2s=jnp.asarray(rng.integers(-1, 2, size=(c, h), dtype=np.int32)),
        b2=jnp.asarray(rng.integers(-200, 200, size=(c,), dtype=np.int32)),
        feat_mask=jnp.asarray(rng.integers(0, 2, size=(f,), dtype=np.int32)),
        approx_mask=jnp.asarray(rng.integers(0, 2, size=(h,), dtype=np.int32)),
        imp_idx=jnp.asarray(rng.integers(0, f, size=(h, 2), dtype=np.int32)),
        imp_pos=jnp.asarray(rng.integers(0, 4, size=(h, 2), dtype=np.int32)),
        imp_l1=jnp.asarray(rng.integers(0, 16, size=(h, 2), dtype=np.int32)),
        imp_sign=jnp.asarray(rng.integers(-1, 2, size=(h, 2), dtype=np.int32)),
        imp_base=jnp.asarray(rng.integers(-200, 200, size=(h,), dtype=np.int32)),
    )


@given(
    b=st.integers(1, 40),
    f=st.integers(2, 120),
    h=st.integers(1, 12),
    c=st.integers(2, 8),
    trunc=st.integers(0, 10),
    seed=st.integers(0, 2**31),
)
def test_mlp_forward_matches_ref(b, f, h, c, trunc, seed):
    rng = np.random.default_rng(seed)
    args = rand_mlp(rng, b, f, h, c)
    pred_k, log_k = model.mlp_forward(*args.values(), trunc=trunc)
    pred_r, log_r = ref.mlp_ref(*args.values(), trunc)
    np.testing.assert_array_equal(np.asarray(log_k), np.asarray(log_r))
    np.testing.assert_array_equal(np.asarray(pred_k), np.asarray(pred_r))


def test_output_shapes():
    rng = np.random.default_rng(0)
    args = rand_mlp(rng, 9, 30, 5, 4)
    pred, logits = model.mlp_forward(*args.values(), trunc=3)
    assert pred.shape == (9,)
    assert logits.shape == (9, 4)
    assert pred.dtype == jnp.int32 and logits.dtype == jnp.int32


def test_pred_in_class_range():
    rng = np.random.default_rng(1)
    args = rand_mlp(rng, 32, 50, 6, 5)
    pred, _ = model.mlp_forward(*args.values(), trunc=2)
    out = np.asarray(pred)
    assert out.min() >= 0 and out.max() < 5


def test_example_args_match_signature():
    """AOT lowering shapes must exactly match what the model consumes."""
    from compile import datasets

    cfg = datasets.CONFIGS["spectf"]
    args = model.example_args(cfg, 8)
    assert args[0].shape == (8, cfg.features)
    assert args[1].shape == (cfg.hidden, cfg.features)
    assert args[4].shape == (cfg.classes, cfg.hidden)
    assert len(args) == 14
