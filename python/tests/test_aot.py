"""AOT lowering: HLO text emission sanity (the Rust loader's contract)."""

import dataclasses

import pytest

from compile import aot, datasets


@pytest.fixture(scope="module")
def hlo_text():
    cfg = dataclasses.replace(datasets.CONFIGS["spectf"])
    return aot.lower_dataset(cfg, trunc=7, batch=4)


def test_hlo_is_text_module(hlo_text):
    assert hlo_text.startswith("HloModule"), hlo_text[:80]
    assert "ENTRY" in hlo_text


def test_hlo_has_expected_signature(hlo_text):
    # 13 parameters, int32 domain, and a tuple root (return_tuple=True).
    assert "s32[4,44]" in hlo_text  # x
    assert "s32[3,44]" in hlo_text  # w1p/w1s
    assert "s32[2,3]" in hlo_text  # w2p/w2s
    assert "(s32[4]" in hlo_text or "tuple" in hlo_text


def test_hlo_deterministic():
    cfg = datasets.CONFIGS["spectf"]
    a = aot.lower_dataset(cfg, trunc=7, batch=2)
    b = aot.lower_dataset(cfg, trunc=7, batch=2)
    assert a == b


def test_batch_changes_shapes_only():
    cfg = datasets.CONFIGS["spectf"]
    a = aot.lower_dataset(cfg, trunc=7, batch=2)
    assert "s32[2,44]" in a and "s32[4,44]" not in a
