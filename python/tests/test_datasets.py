"""Dataset generator invariants + binary interchange round-trip."""

import dataclasses
import os
import tempfile

import numpy as np
import pytest

from compile import datasets


@pytest.fixture(scope="module")
def spectf():
    return datasets.generate(datasets.CONFIGS["spectf"])


def test_deterministic(spectf):
    again = datasets.generate(datasets.CONFIGS["spectf"])
    np.testing.assert_array_equal(spectf.x_train, again.x_train)
    np.testing.assert_array_equal(spectf.y_test, again.y_test)


def test_quantized_range(spectf):
    assert spectf.x_train.dtype == np.uint8
    assert spectf.x_train.min() >= 0 and spectf.x_train.max() <= 15


def test_shapes_match_config():
    for name, cfg in datasets.CONFIGS.items():
        if cfg.features > 300:
            continue  # keep the test fast; large ones covered by aot build
        ds = datasets.generate(cfg)
        assert ds.x_train.shape == (cfg.n_train, cfg.features), name
        assert ds.x_test.shape == (cfg.n_test, cfg.features), name
        assert set(np.unique(ds.y_train)) <= set(range(cfg.classes))


def test_all_classes_present(spectf):
    assert len(np.unique(spectf.y_train)) == spectf.config.classes


def test_redundant_features_exist(spectf):
    """The generator must create strongly correlated feature pairs —
    that's what RFP exploits (§3.2.2)."""
    x = spectf.x_train.astype(np.float64)
    c = np.corrcoef(x.T)
    np.fill_diagonal(c, 0.0)
    n_high = (np.abs(c) > 0.9).sum() // 2
    assert n_high >= 3, f"expected redundant pairs, found {n_high}"


def test_roundtrip_binary(spectf):
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.bin")
        datasets.save_bin(spectf, path)
        xtr, ytr, xte, yte, classes = datasets.load_bin(path)
        np.testing.assert_array_equal(xtr, spectf.x_train)
        np.testing.assert_array_equal(ytr, spectf.y_train)
        np.testing.assert_array_equal(xte, spectf.x_test)
        np.testing.assert_array_equal(yte, spectf.y_test)
        assert classes == spectf.config.classes


def test_bad_magic_rejected(spectf):
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.bin")
        datasets.save_bin(spectf, path)
        raw = bytearray(open(path, "rb").read())
        raw[0] ^= 0xFF
        open(path, "wb").write(raw)
        with pytest.raises(ValueError):
            datasets.load_bin(path)


def test_difficulty_monotone_hurts_separation():
    cfg = datasets.CONFIGS["spectf"]
    easy = datasets.generate(dataclasses.replace(cfg, difficulty=0.5))
    hard = datasets.generate(dataclasses.replace(cfg, difficulty=30.0))

    def class_gap(ds):
        x = ds.x_train.astype(np.float64)
        m0 = x[ds.y_train == 0].mean(axis=0)
        m1 = x[ds.y_train == 1].mean(axis=0)
        sd = x.std(axis=0) + 1e-9
        return float(np.abs((m0 - m1) / sd).mean())

    assert class_gap(easy) > class_gap(hard)
