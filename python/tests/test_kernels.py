"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/values; everything must match bit-exactly (int32
semantics, no tolerance).
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.approx_neuron import approx_accum
from compile.kernels.pow2_matvec import pow2_matvec, qrelu

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def rand_layer(rng, b, f, h, pmax=6):
    x = rng.integers(0, 16, size=(b, f), dtype=np.int32)
    p = rng.integers(0, pmax + 1, size=(h, f), dtype=np.int32)
    s = rng.integers(-1, 2, size=(h, f), dtype=np.int32)
    bias = rng.integers(-500, 500, size=(h,), dtype=np.int32)
    mask = rng.integers(0, 2, size=(f,), dtype=np.int32)
    return map(jnp.asarray, (x, p, s, bias, mask))


@given(
    b=st.integers(1, 70),
    f=st.integers(1, 300),
    h=st.integers(1, 16),
    seed=st.integers(0, 2**31),
)
def test_pow2_matvec_matches_ref(b, f, h, seed):
    rng = np.random.default_rng(seed)
    x, p, s, bias, mask = rand_layer(rng, b, f, h)
    got = pow2_matvec(x, p, s, bias, mask)
    want = ref.pow2_matvec_ref(x, p, s, bias, mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    b=st.integers(1, 70),
    f=st.integers(1, 200),
    h=st.integers(1, 16),
    bt=st.sampled_from([1, 8, 64]),
    ft=st.sampled_from([32, 128]),
    seed=st.integers(0, 2**31),
)
def test_pow2_matvec_tile_invariance(b, f, h, bt, ft, seed):
    """The BlockSpec tiling must never change the numbers."""
    rng = np.random.default_rng(seed)
    x, p, s, bias, mask = rand_layer(rng, b, f, h)
    base = ref.pow2_matvec_ref(x, p, s, bias, mask)
    got = pow2_matvec(x, p, s, bias, mask, bt=bt, ft=ft)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


@given(
    b=st.integers(1, 128),
    h=st.integers(1, 16),
    trunc=st.integers(0, 16),
    seed=st.integers(0, 2**31),
)
def test_qrelu_matches_ref(b, h, trunc, seed):
    rng = np.random.default_rng(seed)
    acc = jnp.asarray(rng.integers(-(2**24), 2**24, size=(b, h), dtype=np.int32))
    got = qrelu(acc, trunc)
    want = ref.qrelu_ref(acc, trunc)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    out = np.asarray(got)
    assert out.min() >= 0 and out.max() <= 15


@given(
    b=st.integers(1, 80),
    h=st.integers(1, 16),
    seed=st.integers(0, 2**31),
)
def test_approx_accum_matches_ref(b, h, seed):
    rng = np.random.default_rng(seed)
    x_imp = jnp.asarray(rng.integers(0, 16, size=(b, h, 2), dtype=np.int32))
    pos = jnp.asarray(rng.integers(0, 4, size=(h, 2), dtype=np.int32))
    l1 = jnp.asarray(rng.integers(0, 20, size=(h, 2), dtype=np.int32))
    sign = jnp.asarray(rng.integers(-1, 2, size=(h, 2), dtype=np.int32))
    mask = jnp.asarray(rng.integers(0, 2, size=(h, 2), dtype=np.int32))
    bias = jnp.asarray(rng.integers(-500, 500, size=(h,), dtype=np.int32))
    got = approx_accum(x_imp, pos, l1, sign, mask, bias)
    want = ref.approx_accum_ref(x_imp, pos, l1, sign, mask, bias)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_zero_mask_yields_bias():
    """All features pruned -> accumulator is exactly the bias."""
    b, f, h = 4, 10, 3
    rng = np.random.default_rng(0)
    x, p, s, bias, _ = rand_layer(rng, b, f, h)
    mask = jnp.zeros((f,), jnp.int32)
    got = np.asarray(pow2_matvec(x, p, s, bias, mask))
    np.testing.assert_array_equal(got, np.broadcast_to(np.asarray(bias), (b, h)))


def test_qrelu_saturates():
    acc = jnp.asarray([[10_000_000, -5, 15, 16, 31, 32]], jnp.int32)
    out = np.asarray(qrelu(acc, 1))
    np.testing.assert_array_equal(out, [[15, 0, 7, 8, 15, 15]])


def test_shift_is_pow2_multiply():
    """x << p == x * 2^p for the whole operand range used by the circuit."""
    x = jnp.asarray(np.arange(16, dtype=np.int32)[None, :])
    for p in range(13):
        pp = jnp.full((1, 16), p, jnp.int32)
        s = jnp.ones((1, 16), jnp.int32)
        bias = jnp.zeros((1,), jnp.int32)
        mask = jnp.ones((16,), jnp.int32)
        got = np.asarray(pow2_matvec(x, pp, s, bias, mask))[0, 0]
        assert got == int(np.arange(16).sum() * 2**p)
