//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links libxla/PJRT and is unavailable in this offline
//! build environment, so this stub mirrors exactly the API surface
//! `printed_mlp::runtime::pjrt` compiles against.  Every entry point that
//! would touch the runtime returns an error from [`PjRtClient::cpu`]
//! onward, which the coordinator's `Backend::Auto` resolution catches to
//! fall back to the bit-exact native evaluator.
//!
//! To use real PJRT execution, point the `xla` dependency in the root
//! `Cargo.toml` at the actual xla-rs crate — the type and method names
//! here match, so no `printed_mlp` source changes are needed.

use std::fmt;
use std::path::Path;

/// Stub error carrying a description of the unavailable operation.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT/XLA runtime unavailable in this offline build \
         (vendored stub — see rust/README.md to link the real xla crate)"
    )))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        ))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (stub: execution always fails).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host literal (stub: constructible so evaluator setup code compiles,
/// but all data movement fails).
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_construction_is_ok() {
        let lit = Literal::vec1(&[1i32, 2, 3]).reshape(&[3]).unwrap();
        assert!(lit.to_vec::<i32>().is_err());
    }
}
