//! Minimal offline substitute for the `anyhow` crate (crates.io is
//! unavailable in this build environment — see DESIGN.md §Substitutions).
//!
//! Implements exactly the API surface `printed_mlp` uses: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!` /
//! `bail!` / `ensure!` macros.  Error chains are stored as flattened
//! strings; `{:#}` formatting prints the full `outer: inner: ...` chain
//! like the real crate, and `{:?}` prints a `Caused by:` listing.
//!
//! Like real anyhow, [`Error`] deliberately does **not** implement
//! `std::error::Error`: that is what lets the blanket
//! `From<E: std::error::Error>` conversion coexist with the identity
//! behaviour of `?` on `Result<_, Error>`.

use std::fmt;

/// A flattened error chain; `chain[0]` is the outermost message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod private {
    /// Sealed conversion used by [`crate::Context`]: covers both plain
    /// `std::error::Error` types and [`crate::Error`] itself (which does
    /// not implement `std::error::Error`, so the impls cannot overlap).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` (any error convertible to [`Error`]) and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: private::IntoError,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn from_std_error_and_context() {
        let e: Error = io_err().into();
        let e = e.context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: missing thing");
        assert_eq!(e.root_cause(), "missing thing");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: missing thing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("slot {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "slot 3");
    }

    #[test]
    fn context_on_anyhow_result_chains() {
        fn inner() -> Result<()> {
            bail!("inner failure");
        }
        let e = inner().context("outer step").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer step: inner failure");
    }

    #[test]
    fn macros() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "bad flag {}", ok);
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(format!("{}", f(false).unwrap_err()), "bad flag false");
        let e = anyhow!("x = {}", 42);
        assert_eq!(format!("{e}"), "x = 42");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xFF])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }
}
