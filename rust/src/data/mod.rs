//! Dataset registry and binary loader.
//!
//! Datasets are generated at build time by `python/compile/datasets.py`
//! (deterministic synthetic multi-sensor data — see DESIGN.md
//! §Substitutions) and stored in the PMLP binary format.
//!
//! # The PMLP binary dataset format
//!
//! Everything is little-endian, with no padding or alignment between
//! fields.  A 24-byte header is followed by four tightly packed payload
//! sections:
//!
//! | offset | size            | field     | contents                            |
//! |--------|-----------------|-----------|-------------------------------------|
//! | 0      | 4               | magic     | `0x504D_4C50` (ASCII `"PMLP"`)      |
//! | 4      | 4               | version   | [`VERSION`] (currently 2)           |
//! | 8      | 4               | n_train   | number of training samples          |
//! | 12     | 4               | n_test    | number of test samples              |
//! | 16     | 4               | features  | feature count `F` per sample        |
//! | 20     | 4               | classes   | label arity                         |
//! | 24     | `n_train * F`   | x_train   | row-major `u8` inputs, each in 0..=15 |
//! | …      | `2 * n_train`   | y_train   | `u16` labels, each `< classes`      |
//! | …      | `n_test * F`    | x_test    | as x_train                          |
//! | …      | `2 * n_test`    | y_test    | as y_train                          |
//!
//! Inputs are 4-bit sensor words (the paper's ADC width), so any byte
//! above 15 is rejected, as are out-of-range labels, truncated payloads,
//! and trailing bytes.  [`Dataset::to_bytes`] serializes and
//! [`Dataset::from_bytes`] parses/validates; [`Dataset::load`] is the
//! file-backed wrapper the [`ArtifactStore`] uses.
//!
//! Round-tripping a tiny in-memory dataset:
//!
//! ```
//! use printed_mlp::data::{Dataset, Split};
//!
//! let ds = Dataset {
//!     name: "tiny".into(),
//!     classes: 2,
//!     train: Split { xs: vec![1, 2, 3, 4, 5, 6], ys: vec![0, 1], features: 3 },
//!     test: Split { xs: vec![15, 0, 7], ys: vec![1], features: 3 },
//! };
//! let bytes = ds.to_bytes();
//! let magic = u32::from_le_bytes(bytes[..4].try_into().unwrap());
//! assert_eq!(magic, printed_mlp::data::MAGIC);
//! let back = Dataset::from_bytes("tiny", &bytes).unwrap();
//! assert_eq!(back.classes, 2);
//! assert_eq!(back.train.xs, ds.train.xs);
//! assert_eq!(back.train.ys, ds.train.ys);
//! assert_eq!(back.test.xs, ds.test.xs);
//! assert_eq!(back.test.ys, ds.test.ys);
//! assert_eq!(back.train.row(1), &[4, 5, 6]);
//! ```

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub const MAGIC: u32 = 0x504D_4C50; // "PMLP"
pub const VERSION: u32 = 2;

/// The seven paper datasets in Fig. 6 order (by coefficient count).
pub const DATASET_ORDER: [&str; 7] = [
    "spectf",
    "arrhythmia",
    "gas",
    "epileptic",
    "activity",
    "parkinsons",
    "har",
];

/// One split (train or test): row-major 4-bit inputs plus labels.
#[derive(Clone, Debug)]
pub struct Split {
    pub xs: Vec<u8>,
    pub ys: Vec<u16>,
    pub features: usize,
}

impl Split {
    pub fn len(&self) -> usize {
        self.ys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ys.is_empty()
    }

    pub fn row(&self, i: usize) -> &[u8] {
        &self.xs[i * self.features..(i + 1) * self.features]
    }

    /// A sub-split view (copy) of the first `n` samples.
    pub fn head(&self, n: usize) -> Split {
        let n = n.min(self.len());
        Split {
            xs: self.xs[..n * self.features].to_vec(),
            ys: self.ys[..n].to_vec(),
            features: self.features,
        }
    }
}

/// A loaded dataset (both splits + metadata).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub classes: usize,
    pub train: Split,
    pub test: Split,
}

fn read_u32(b: &[u8], off: &mut usize) -> Result<u32> {
    if *off + 4 > b.len() {
        bail!("truncated dataset file at byte {off}");
    }
    let v = u32::from_le_bytes(b[*off..*off + 4].try_into().unwrap());
    *off += 4;
    Ok(v)
}

impl Dataset {
    /// Load and validate a PMLP-format file; the dataset name is the
    /// file stem.
    pub fn load(path: &Path) -> Result<Dataset> {
        let b = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_default();
        Self::from_bytes(&name, &b).with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse and validate a PMLP-format byte buffer (see the module docs
    /// for the layout).  Rejects bad magic/version, truncated or trailing
    /// bytes, inputs outside the 4-bit range, and out-of-range labels.
    pub fn from_bytes(name: &str, b: &[u8]) -> Result<Dataset> {
        let mut off = 0usize;
        let magic = read_u32(b, &mut off)?;
        let version = read_u32(b, &mut off)?;
        if magic != MAGIC {
            bail!("{name}: bad magic {magic:#x}");
        }
        if version != VERSION {
            bail!("{name}: version {version}, want {VERSION}");
        }
        let n_train = read_u32(b, &mut off)? as usize;
        let n_test = read_u32(b, &mut off)? as usize;
        let features = read_u32(b, &mut off)? as usize;
        let classes = read_u32(b, &mut off)? as usize;

        let take = |off: &mut usize, n: usize| -> Result<Vec<u8>> {
            if *off + n > b.len() {
                bail!("truncated dataset payload");
            }
            let v = b[*off..*off + n].to_vec();
            *off += n;
            Ok(v)
        };
        let take_u16 = |off: &mut usize, n: usize| -> Result<Vec<u16>> {
            if *off + 2 * n > b.len() {
                bail!("truncated dataset labels");
            }
            let v = b[*off..*off + 2 * n]
                .chunks_exact(2)
                .map(|c| u16::from_le_bytes([c[0], c[1]]))
                .collect();
            *off += 2 * n;
            Ok(v)
        };

        let x_train = take(&mut off, n_train * features)?;
        let y_train = take_u16(&mut off, n_train)?;
        let x_test = take(&mut off, n_test * features)?;
        let y_test = take_u16(&mut off, n_test)?;
        if off != b.len() {
            bail!("{name}: {} trailing bytes", b.len() - off);
        }
        for &x in x_train.iter().chain(&x_test) {
            if x > 15 {
                bail!("input value {x} exceeds 4-bit range");
            }
        }
        for &y in y_train.iter().chain(&y_test) {
            if y as usize >= classes {
                bail!("label {y} out of range (classes={classes})");
            }
        }
        Ok(Dataset {
            name: name.to_string(),
            classes,
            train: Split {
                xs: x_train,
                ys: y_train,
                features,
            },
            test: Split {
                xs: x_test,
                ys: y_test,
                features,
            },
        })
    }

    /// Serialize to the PMLP binary format (see the module docs); the
    /// exact inverse of [`Dataset::from_bytes`] for valid datasets.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut b = Vec::new();
        for v in [
            MAGIC,
            VERSION,
            self.train.len() as u32,
            self.test.len() as u32,
            self.train.features as u32,
            self.classes as u32,
        ] {
            b.extend(v.to_le_bytes());
        }
        b.extend_from_slice(&self.train.xs);
        for &y in &self.train.ys {
            b.extend(y.to_le_bytes());
        }
        b.extend_from_slice(&self.test.xs);
        for &y in &self.test.ys {
            b.extend(y.to_le_bytes());
        }
        b
    }
}

/// Resolves artifact paths; root defaults to `$PRINTED_MLP_ARTIFACTS` or
/// `./artifacts`.
#[derive(Clone, Debug)]
pub struct ArtifactStore {
    pub root: PathBuf,
}

impl ArtifactStore {
    pub fn new(root: impl Into<PathBuf>) -> Self {
        ArtifactStore { root: root.into() }
    }

    pub fn discover() -> Self {
        let root = std::env::var("PRINTED_MLP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        ArtifactStore::new(root)
    }

    pub fn dataset_path(&self, name: &str) -> PathBuf {
        self.root.join("data").join(format!("{name}.bin"))
    }

    pub fn model_path(&self, name: &str) -> PathBuf {
        self.root.join("models").join(format!("{name}.json"))
    }

    pub fn hlo_path(&self, name: &str, batch: usize) -> PathBuf {
        self.root.join("hlo").join(format!("{name}_b{batch}.hlo.txt"))
    }

    pub fn results_dir(&self) -> PathBuf {
        self.root.join("results")
    }

    pub fn dataset(&self, name: &str) -> Result<Dataset> {
        Dataset::load(&self.dataset_path(name))
    }

    pub fn model(&self, name: &str) -> Result<crate::model::QuantModel> {
        crate::model::QuantModel::load(&self.model_path(name))
    }

    /// True when `make artifacts` has produced everything for `name`.
    pub fn has(&self, name: &str) -> bool {
        self.dataset_path(name).exists() && self.model_path(name).exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(bytes: &[u8]) -> PathBuf {
        let dir = std::env::temp_dir().join("printed_mlp_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("ds_{}.bin", std::process::id()));
        std::fs::write(&path, bytes).unwrap();
        path
    }

    fn sample_file() -> Vec<u8> {
        let mut b = Vec::new();
        for v in [MAGIC, VERSION, 2u32, 1u32, 3u32, 2u32] {
            b.extend(v.to_le_bytes());
        }
        b.extend([1u8, 2, 3, 4, 5, 6]); // x_train 2x3
        b.extend(0u16.to_le_bytes());
        b.extend(1u16.to_le_bytes()); // y_train
        b.extend([7u8, 8, 9]); // x_test 1x3
        b.extend(1u16.to_le_bytes()); // y_test
        b
    }

    #[test]
    fn loads_valid_file() {
        let path = write_tmp(&sample_file());
        let ds = Dataset::load(&path).unwrap();
        assert_eq!(ds.train.len(), 2);
        assert_eq!(ds.test.len(), 1);
        assert_eq!(ds.train.row(1), &[4, 5, 6]);
        assert_eq!(ds.classes, 2);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bytes_roundtrip_in_memory() {
        let ds = Dataset::from_bytes("mem", &sample_file()).unwrap();
        let bytes = ds.to_bytes();
        assert_eq!(bytes, sample_file(), "to_bytes inverts from_bytes");
        let back = Dataset::from_bytes("mem", &bytes).unwrap();
        assert_eq!(back.train.xs, ds.train.xs);
        assert_eq!(back.train.ys, ds.train.ys);
        assert_eq!(back.test.xs, ds.test.xs);
        assert_eq!(back.test.ys, ds.test.ys);
        assert_eq!(back.classes, ds.classes);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut f = sample_file();
        f[0] = 0;
        let path = write_tmp(&f);
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let f = sample_file();
        let path = write_tmp(&f[..f.len() - 1]);
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).ok();
        let mut g = sample_file();
        g.push(0);
        let path = write_tmp(&g);
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_out_of_range_values() {
        let mut f = sample_file();
        f[24] = 16; // first x_train byte > 15
        let path = write_tmp(&f);
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn head_takes_prefix() {
        let path = write_tmp(&sample_file());
        let ds = Dataset::load(&path).unwrap();
        let h = ds.train.head(1);
        assert_eq!(h.len(), 1);
        assert_eq!(h.row(0), &[1, 2, 3]);
        std::fs::remove_file(path).ok();
    }
}
