//! Dataset registry and binary loader.
//!
//! Datasets are generated at build time by `python/compile/datasets.py`
//! (deterministic synthetic multi-sensor data — see DESIGN.md
//! §Substitutions) and stored in a compact little-endian binary format:
//!
//! ```text
//! u32 magic "PMLP" | u32 version | u32 n_train | u32 n_test |
//! u32 features | u32 classes |
//! x_train (n_train*F u8) | y_train (n_train u16) | x_test | y_test
//! ```

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub const MAGIC: u32 = 0x504D_4C50; // "PMLP"
pub const VERSION: u32 = 2;

/// The seven paper datasets in Fig. 6 order (by coefficient count).
pub const DATASET_ORDER: [&str; 7] = [
    "spectf",
    "arrhythmia",
    "gas",
    "epileptic",
    "activity",
    "parkinsons",
    "har",
];

/// One split (train or test): row-major 4-bit inputs plus labels.
#[derive(Clone, Debug)]
pub struct Split {
    pub xs: Vec<u8>,
    pub ys: Vec<u16>,
    pub features: usize,
}

impl Split {
    pub fn len(&self) -> usize {
        self.ys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ys.is_empty()
    }

    pub fn row(&self, i: usize) -> &[u8] {
        &self.xs[i * self.features..(i + 1) * self.features]
    }

    /// A sub-split view (copy) of the first `n` samples.
    pub fn head(&self, n: usize) -> Split {
        let n = n.min(self.len());
        Split {
            xs: self.xs[..n * self.features].to_vec(),
            ys: self.ys[..n].to_vec(),
            features: self.features,
        }
    }
}

/// A loaded dataset (both splits + metadata).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub classes: usize,
    pub train: Split,
    pub test: Split,
}

fn read_u32(b: &[u8], off: &mut usize) -> Result<u32> {
    if *off + 4 > b.len() {
        bail!("truncated dataset file at byte {off}");
    }
    let v = u32::from_le_bytes(b[*off..*off + 4].try_into().unwrap());
    *off += 4;
    Ok(v)
}

impl Dataset {
    pub fn load(path: &Path) -> Result<Dataset> {
        let b = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().to_string())
            .unwrap_or_default();
        let mut off = 0usize;
        let magic = read_u32(&b, &mut off)?;
        let version = read_u32(&b, &mut off)?;
        if magic != MAGIC {
            bail!("{}: bad magic {magic:#x}", path.display());
        }
        if version != VERSION {
            bail!("{}: version {version}, want {VERSION}", path.display());
        }
        let n_train = read_u32(&b, &mut off)? as usize;
        let n_test = read_u32(&b, &mut off)? as usize;
        let features = read_u32(&b, &mut off)? as usize;
        let classes = read_u32(&b, &mut off)? as usize;

        let take = |off: &mut usize, n: usize| -> Result<Vec<u8>> {
            if *off + n > b.len() {
                bail!("truncated dataset payload");
            }
            let v = b[*off..*off + n].to_vec();
            *off += n;
            Ok(v)
        };
        let take_u16 = |off: &mut usize, n: usize| -> Result<Vec<u16>> {
            if *off + 2 * n > b.len() {
                bail!("truncated dataset labels");
            }
            let v = b[*off..*off + 2 * n]
                .chunks_exact(2)
                .map(|c| u16::from_le_bytes([c[0], c[1]]))
                .collect();
            *off += 2 * n;
            Ok(v)
        };

        let x_train = take(&mut off, n_train * features)?;
        let y_train = take_u16(&mut off, n_train)?;
        let x_test = take(&mut off, n_test * features)?;
        let y_test = take_u16(&mut off, n_test)?;
        if off != b.len() {
            bail!("{}: {} trailing bytes", path.display(), b.len() - off);
        }
        for &x in x_train.iter().chain(&x_test) {
            if x > 15 {
                bail!("input value {x} exceeds 4-bit range");
            }
        }
        for &y in y_train.iter().chain(&y_test) {
            if y as usize >= classes {
                bail!("label {y} out of range (classes={classes})");
            }
        }
        Ok(Dataset {
            name,
            classes,
            train: Split {
                xs: x_train,
                ys: y_train,
                features,
            },
            test: Split {
                xs: x_test,
                ys: y_test,
                features,
            },
        })
    }
}

/// Resolves artifact paths; root defaults to `$PRINTED_MLP_ARTIFACTS` or
/// `./artifacts`.
#[derive(Clone, Debug)]
pub struct ArtifactStore {
    pub root: PathBuf,
}

impl ArtifactStore {
    pub fn new(root: impl Into<PathBuf>) -> Self {
        ArtifactStore { root: root.into() }
    }

    pub fn discover() -> Self {
        let root = std::env::var("PRINTED_MLP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        ArtifactStore::new(root)
    }

    pub fn dataset_path(&self, name: &str) -> PathBuf {
        self.root.join("data").join(format!("{name}.bin"))
    }

    pub fn model_path(&self, name: &str) -> PathBuf {
        self.root.join("models").join(format!("{name}.json"))
    }

    pub fn hlo_path(&self, name: &str, batch: usize) -> PathBuf {
        self.root.join("hlo").join(format!("{name}_b{batch}.hlo.txt"))
    }

    pub fn results_dir(&self) -> PathBuf {
        self.root.join("results")
    }

    pub fn dataset(&self, name: &str) -> Result<Dataset> {
        Dataset::load(&self.dataset_path(name))
    }

    pub fn model(&self, name: &str) -> Result<crate::model::QuantModel> {
        crate::model::QuantModel::load(&self.model_path(name))
    }

    /// True when `make artifacts` has produced everything for `name`.
    pub fn has(&self, name: &str) -> bool {
        self.dataset_path(name).exists() && self.model_path(name).exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(bytes: &[u8]) -> PathBuf {
        let dir = std::env::temp_dir().join("printed_mlp_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("ds_{}.bin", std::process::id()));
        std::fs::write(&path, bytes).unwrap();
        path
    }

    fn sample_file() -> Vec<u8> {
        let mut b = Vec::new();
        for v in [MAGIC, VERSION, 2u32, 1u32, 3u32, 2u32] {
            b.extend(v.to_le_bytes());
        }
        b.extend([1u8, 2, 3, 4, 5, 6]); // x_train 2x3
        b.extend(0u16.to_le_bytes());
        b.extend(1u16.to_le_bytes()); // y_train
        b.extend([7u8, 8, 9]); // x_test 1x3
        b.extend(1u16.to_le_bytes()); // y_test
        b
    }

    #[test]
    fn loads_valid_file() {
        let path = write_tmp(&sample_file());
        let ds = Dataset::load(&path).unwrap();
        assert_eq!(ds.train.len(), 2);
        assert_eq!(ds.test.len(), 1);
        assert_eq!(ds.train.row(1), &[4, 5, 6]);
        assert_eq!(ds.classes, 2);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let mut f = sample_file();
        f[0] = 0;
        let path = write_tmp(&f);
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let f = sample_file();
        let path = write_tmp(&f[..f.len() - 1]);
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).ok();
        let mut g = sample_file();
        g.push(0);
        let path = write_tmp(&g);
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_out_of_range_values() {
        let mut f = sample_file();
        f[24] = 16; // first x_train byte > 15
        let path = write_tmp(&f);
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn head_takes_prefix() {
        let path = write_tmp(&sample_file());
        let ds = Dataset::load(&path).unwrap();
        let h = ds.train.head(1);
        assert_eq!(h.len(), 1);
        assert_eq!(h.row(0), &[1, 2, 3]);
        std::fs::remove_file(path).ok();
    }
}
