//! Neuron-approximation framework (§3.2.3): decides which hidden neurons
//! become single-cycle (Fig. 2c) using NSGA-II over boolean genomes.
//!
//! Objectives (all maximized): the number of approximated neurons — an
//! abstract stand-in for circuit area savings, per the paper — and the
//! training accuracy.  The final design for an accuracy-drop budget
//! (1%/2%/5% in Fig. 7) is the Pareto solution with the most approximated
//! neurons whose accuracy stays within the budget.
//!
//! With the measured-energy objective on (`[nsga] energy_objective` /
//! `--energy-objective`), a third objective — *negated* energy per
//! inference from the activity-profiled simulator (`sim` §Activity +
//! `tech::energy_report`) — rides along through the same machinery: the
//! NSGA-II core, the genome→objectives memo, and the serial/batched
//! bit-identical contract are all objective-count generic, so
//! [`explore_energy`]/[`explore_parallel_energy`] differ from their
//! 2-objective twins only in the appended objective
//! (`tests/nsga_parallel.rs` locks the 3-tuple invariants down).
//!
//! Fitness evaluation itself runs through `model::cache::FitnessCache`
//! by default (DESIGN.md §Perf): one precompute pass over the split
//! collapses every genome evaluation to baseline-plus-selected-deltas,
//! bit-identical to the scalar forward.  `nsga.cached_fitness = false`,
//! `--no-fitness-cache`, or `PRINTED_MLP_NO_FITNESS_CACHE=1` restores
//! the scalar oracle path.

use crate::data::Split;
use crate::model::cache::{CacheScratch, FitnessCache};
use crate::model::{importance, ApproxTables, QuantModel};
use crate::nsga::{self, FitnessEval, Individual, NsgaConfig, SearchStats};
use crate::util::pool;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// `PRINTED_MLP_NO_FITNESS_CACHE=1|true|yes` disables the delta-logit
/// fitness cache at use time, forcing every genome through the scalar
/// `QuantModel::accuracy` oracle (mirrors `PRINTED_MLP_NO_COMPILE_SIM`).
/// Both paths are bit-identical; this exists for debugging and for
/// measuring the cache's speedup (`nsga_throughput`).
pub fn fitness_cache_env_disabled() -> bool {
    matches!(
        std::env::var("PRINTED_MLP_NO_FITNESS_CACHE").as_deref(),
        Ok("1") | Ok("true") | Ok("yes")
    )
}

/// A chosen hybrid configuration.
#[derive(Clone, Debug)]
pub struct Selection {
    pub approx_mask: Vec<u8>,
    pub n_approx: usize,
    pub accuracy: f64,
}

/// Build the single-cycle tables for a model + RFP mask from training
/// statistics (Eq. 1 + expected leading-1, Fig. 5).
pub fn build_tables(
    model: &QuantModel,
    train_xs: &[u8],
    n_train: usize,
    feat_mask: &[u8],
) -> ApproxTables {
    importance::approx_tables(model, train_xs, n_train, feat_mask)
}

/// The fixed "approximate every other hidden neuron" demo mask shared by
/// the CLI's `--arch hybrid` inspection path and the fault campaign's
/// hybrid architecture.  The NSGA-II search ([`explore`]) is the real
/// selector; this is the deterministic stand-in for contexts with no
/// search artifacts (synthetic serve, quick inspection).
pub fn demo_hybrid_mask(hidden: usize) -> Vec<u8> {
    (0..hidden).map(|h| (h % 2 == 0) as u8).collect()
}

/// Run the genetic exploration.  `eval(approx_mask) -> accuracy` evaluates
/// the hybrid model on the training set (PJRT-backed on the hot path).
pub fn explore<F>(hidden: usize, cfg: &NsgaConfig, mut eval: F) -> Vec<Individual>
where
    F: FnMut(&[u8]) -> f64,
{
    nsga::run(hidden, cfg, |genome| {
        let mask: Vec<u8> = genome.iter().map(|&b| b as u8).collect();
        let acc = eval(&mask);
        vec![genome.iter().filter(|&&b| b).count() as f64, acc]
    })
}

/// Measured-energy fitness hook: maps an approximation mask to the
/// hybrid design's energy per inference (mJ, lower is better).  The
/// search negates it so all objectives maximize uniformly; `Sync`
/// because [`ParallelFitness`] calls it from the worker pool.
pub type EnergyEval<'a> = &'a (dyn Fn(&[u8]) -> f64 + Sync);

/// [`explore`] with the measured-energy third objective: objective
/// vectors become `(#approximated, accuracy, -energy_mj)` 3-tuples.
pub fn explore_energy<F>(
    hidden: usize,
    cfg: &NsgaConfig,
    mut eval: F,
    energy: EnergyEval<'_>,
) -> Vec<Individual>
where
    F: FnMut(&[u8]) -> f64,
{
    nsga::run(hidden, cfg, |genome| {
        let mask: Vec<u8> = genome.iter().map(|&b| b as u8).collect();
        let acc = eval(&mask);
        vec![
            genome.iter().filter(|&&b| b).count() as f64,
            acc,
            -energy(&mask),
        ]
    })
}

/// Parallel batch fitness for the approximation search (DESIGN.md §Perf):
/// a generation's genomes fan out across worker threads via
/// [`pool::scope_map_with`], all workers sharing one read-only
/// [`FitnessCache`] — per-sample baseline logits under the all-exact
/// mask plus per-(neuron, class) delta-logit columns, built lazily on
/// the first batch.  A genome evaluation is then
/// `base + Σ_{h∈mask} Δ[h]` + argmax, O(n·|mask_diff|·classes) instead
/// of the scalar path's O(n·hidden·features) full forward, and each
/// worker keeps a persistent [`CacheScratch`] (claimed from a slot pool
/// by atomic counter) so successive generations re-apply only the
/// XOR-diff between the previous and next mask.  No model/tables clones
/// and no per-genome `Vec<bool>`→`Vec<u8>` allocations survive on
/// either path — workers borrow shared state and reuse one mask buffer.
///
/// The cache is exact, not approximate (see `model::cache` for the
/// bit-identity argument), and [`with_cached`](Self::with_cached)`(false)`
/// or `PRINTED_MLP_NO_FITNESS_CACHE=1` falls back to the scalar
/// `QuantModel::accuracy` oracle.  Objectives match [`explore`]'s
/// exactly — (#approximated neurons, training accuracy on the split) —
/// and fitness is a pure function of the genome, so
/// [`nsga::run_batched`] over this evaluator is bit-identical to the
/// serial path at equal seeds on both the cached and scalar routes.
pub struct ParallelFitness<'a> {
    model: &'a QuantModel,
    split: &'a Split,
    feat_mask: &'a [u8],
    tables: &'a ApproxTables,
    threads: usize,
    /// Optional measured-energy third objective (appended negated).
    energy: Option<EnergyEval<'a>>,
    /// Delta-logit cache toggle (`nsga.cached_fitness`); the env var
    /// [`fitness_cache_env_disabled`] is consulted per batch on top.
    use_cache: bool,
    /// Lazily-built shared cache; `None` until the first cached batch.
    cache: Option<FitnessCache>,
    /// One persistent scratch per worker slot, reused across
    /// generations so the incremental mask-diff path can kick in.
    scratches: Vec<Mutex<CacheScratch>>,
}

impl<'a> ParallelFitness<'a> {
    pub fn new(
        model: &'a QuantModel,
        split: &'a Split,
        feat_mask: &'a [u8],
        tables: &'a ApproxTables,
        threads: usize,
    ) -> Self {
        ParallelFitness {
            model,
            split,
            feat_mask,
            tables,
            threads: threads.max(1),
            energy: None,
            use_cache: true,
            cache: None,
            scratches: Vec::new(),
        }
    }

    /// Append the measured-energy objective: every objective vector this
    /// evaluator produces becomes `(#approximated, accuracy,
    /// -energy(mask))` — matching [`explore_energy`]'s serial tuples, so
    /// the bit-identical serial/batched contract carries over unchanged.
    pub fn with_energy(mut self, energy: EnergyEval<'a>) -> Self {
        self.energy = Some(energy);
        self
    }

    /// Toggle the delta-logit fitness cache (`nsga.cached_fitness`).
    /// Off routes every genome through the scalar accuracy oracle;
    /// fronts are bit-identical either way.
    pub fn with_cached(mut self, on: bool) -> Self {
        self.use_cache = on;
        self
    }
}

impl FitnessEval for ParallelFitness<'_> {
    fn eval_batch(&mut self, genomes: &[Vec<bool>]) -> Vec<Vec<f64>> {
        let use_cache = self.use_cache && !fitness_cache_env_disabled();
        if use_cache && self.cache.is_none() {
            self.cache = Some(FitnessCache::build(
                self.model,
                &self.split.xs,
                &self.split.ys,
                self.feat_mask,
                self.tables,
            ));
        }
        if use_cache {
            // One scratch per worker the pool may spawn for this batch;
            // slots persist across batches so each worker's incremental
            // logits survive between generations.
            let want = self.threads.clamp(1, genomes.len().max(1));
            let cache = self.cache.as_ref().expect("cache built above");
            while self.scratches.len() < want {
                self.scratches.push(Mutex::new(cache.new_scratch()));
            }
        }
        let cache = if use_cache { self.cache.as_ref() } else { None };
        let scratches = &self.scratches;
        let (model, split) = (self.model, self.split);
        let (feat_mask, tables) = (self.feat_mask, self.tables);
        let energy = self.energy;
        // Workers claim scratch slots by atomic counter; the pool spawns
        // at most `threads.clamp(1, genomes.len())` workers, so every
        // claim lands on a distinct slot and the lock never contends.
        let slot = AtomicUsize::new(0);
        pool::scope_map_with(
            genomes.len(),
            self.threads,
            || {
                let guard =
                    cache.map(|_| scratches[slot.fetch_add(1, Ordering::Relaxed)].lock().unwrap());
                (guard, vec![0u8; model.hidden])
            },
            |state, i| {
                let (guard, mask) = state;
                for (mj, &b) in mask.iter_mut().zip(&genomes[i]) {
                    *mj = b as u8;
                }
                let acc = match (cache, guard.as_mut()) {
                    (Some(c), Some(s)) => c.accuracy(s, &mask[..]),
                    _ => model.accuracy(&split.xs, &split.ys, feat_mask, &mask[..], tables),
                };
                let mut obj = vec![genomes[i].iter().filter(|&&b| b).count() as f64, acc];
                if let Some(e) = energy {
                    obj.push(-e(&mask[..]));
                }
                obj
            },
        )
    }
}

/// [`explore`] through the parallel, memoized batch path: NSGA-II with
/// per-generation offspring slates evaluated by [`ParallelFitness`] over
/// `threads` workers.  Returns the front plus [`SearchStats`] (unique
/// evaluations vs memo hits).
pub fn explore_parallel(
    model: &QuantModel,
    split: &Split,
    feat_mask: &[u8],
    tables: &ApproxTables,
    cfg: &NsgaConfig,
    threads: usize,
) -> (Vec<Individual>, SearchStats) {
    let mut fitness = ParallelFitness::new(model, split, feat_mask, tables, threads)
        .with_cached(cfg.cached_fitness);
    nsga::run_batched(model.hidden, cfg, &mut fitness)
}

/// [`explore_parallel`] with the measured-energy third objective (see
/// [`explore_energy`]).  The genome→objectives memo stores whatever
/// length the evaluator returns, so 3-tuples hit the cache exactly as
/// 2-tuples do.
pub fn explore_parallel_energy(
    model: &QuantModel,
    split: &Split,
    feat_mask: &[u8],
    tables: &ApproxTables,
    cfg: &NsgaConfig,
    threads: usize,
    energy: EnergyEval<'_>,
) -> (Vec<Individual>, SearchStats) {
    let mut fitness = ParallelFitness::new(model, split, feat_mask, tables, threads)
        .with_energy(energy)
        .with_cached(cfg.cached_fitness);
    nsga::run_batched(model.hidden, cfg, &mut fitness)
}

/// Pick the most-approximated Pareto solution within the accuracy budget.
/// Falls back to the all-exact design when nothing fits.
pub fn select(front: &[Individual], baseline_acc: f64, max_drop: f64) -> Selection {
    let floor = baseline_acc - max_drop;
    let mut best: Option<&Individual> = None;
    for ind in front {
        if ind.objectives[1] + 1e-12 >= floor {
            let better = match best {
                None => true,
                Some(b) => {
                    ind.objectives[0] > b.objectives[0]
                        || (ind.objectives[0] == b.objectives[0]
                            && ind.objectives[1] > b.objectives[1])
                }
            };
            if better {
                best = Some(ind);
            }
        }
    }
    match best {
        Some(ind) => Selection {
            approx_mask: ind.genome.iter().map(|&b| b as u8).collect(),
            n_approx: ind.objectives[0] as usize,
            accuracy: ind.objectives[1],
        },
        None => Selection {
            approx_mask: vec![0; front.first().map(|i| i.genome.len()).unwrap_or(0)],
            n_approx: 0,
            accuracy: baseline_acc,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::testutil::rand_model;
    use crate::util::prng::Rng;

    #[test]
    fn explore_finds_harmless_neurons() {
        // Synthetic fitness: neurons 0 and 2 are free to approximate,
        // each other approximated neuron costs 10% accuracy.
        let cfg = NsgaConfig {
            pop_size: 16,
            generations: 15,
            ..Default::default()
        };
        let front = explore(5, &cfg, |mask| {
            let harmful = mask
                .iter()
                .enumerate()
                .filter(|(i, &m)| m == 1 && *i != 0 && *i != 2)
                .count();
            1.0 - 0.1 * harmful as f64
        });
        let sel = select(&front, 1.0, 0.005);
        assert_eq!(sel.n_approx, 2, "exactly the two free neurons");
        assert_eq!(sel.approx_mask[0], 1);
        assert_eq!(sel.approx_mask[2], 1);
    }

    #[test]
    fn select_respects_budget_ordering() {
        // Larger budgets must never select fewer approximated neurons.
        let cfg = NsgaConfig {
            pop_size: 16,
            generations: 12,
            ..Default::default()
        };
        let front = explore(6, &cfg, |mask| {
            1.0 - 0.02 * mask.iter().filter(|&&m| m == 1).count() as f64
        });
        let s1 = select(&front, 1.0, 0.01);
        let s2 = select(&front, 1.0, 0.02);
        let s5 = select(&front, 1.0, 0.05);
        assert!(s1.n_approx <= s2.n_approx && s2.n_approx <= s5.n_approx);
        assert!(s1.accuracy >= 0.99 - 1e-9);
    }

    #[test]
    fn select_falls_back_to_exact() {
        let front = vec![Individual {
            genome: vec![true, true],
            objectives: vec![2.0, 0.1],
            rank: 0,
            crowding: 0.0,
        }];
        let sel = select(&front, 0.9, 0.01);
        assert_eq!(sel.n_approx, 0);
        assert_eq!(sel.approx_mask, vec![0, 0]);
    }

    #[test]
    fn parallel_explore_matches_serial() {
        let m = rand_model(17, 10, 5, 3);
        let mut r = Rng::new(5);
        let n = 48;
        let xs: Vec<u8> = (0..n * 10).map(|_| r.below(16) as u8).collect();
        let ys: Vec<u16> = (0..n).map(|_| r.below(3) as u16).collect();
        let split = Split {
            xs,
            ys,
            features: 10,
        };
        let fm = vec![1u8; 10];
        let tables = build_tables(&m, &split.xs, n, &fm);
        let cfg = NsgaConfig {
            pop_size: 10,
            generations: 6,
            ..Default::default()
        };
        let serial = explore(m.hidden, &cfg, |mask| {
            m.accuracy(&split.xs, &split.ys, &fm, mask, &tables)
        });
        for threads in [1usize, 3] {
            let (par, stats) = explore_parallel(&m, &split, &fm, &tables, &cfg, threads);
            assert_eq!(serial.len(), par.len(), "front size ({threads} threads)");
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.genome, b.genome);
                assert_eq!(a.objectives, b.objectives);
            }
            assert_eq!(stats.evals + stats.cache_hits, stats.requested);
        }
    }

    #[test]
    fn energy_objective_appends_negated_tuples() {
        // Serial 3-objective exploration: every front member carries
        // (count, accuracy, -energy) with the energy closure's value.
        let cfg = NsgaConfig {
            pop_size: 10,
            generations: 4,
            ..Default::default()
        };
        let energy = |mask: &[u8]| 5.0 - mask.iter().filter(|&&m| m == 1).count() as f64;
        let front = explore_energy(4, &cfg, |_| 1.0, &energy);
        assert!(!front.is_empty());
        for ind in &front {
            assert_eq!(ind.objectives.len(), 3);
            let mask: Vec<u8> = ind.genome.iter().map(|&b| b as u8).collect();
            assert_eq!(ind.objectives[2], -energy(&mask));
        }
    }

    #[test]
    fn parallel_energy_matches_serial_energy() {
        let m = rand_model(17, 10, 5, 3);
        let mut r = Rng::new(5);
        let n = 48;
        let xs: Vec<u8> = (0..n * 10).map(|_| r.below(16) as u8).collect();
        let ys: Vec<u16> = (0..n).map(|_| r.below(3) as u16).collect();
        let split = Split {
            xs,
            ys,
            features: 10,
        };
        let fm = vec![1u8; 10];
        let tables = build_tables(&m, &split.xs, n, &fm);
        let cfg = NsgaConfig {
            pop_size: 10,
            generations: 6,
            ..Default::default()
        };
        let energy = |mask: &[u8]| 3.0 + mask.iter().map(|&m| (1 - m) as f64).sum::<f64>();
        let serial = explore_energy(
            m.hidden,
            &cfg,
            |mask| m.accuracy(&split.xs, &split.ys, &fm, mask, &tables),
            &energy,
        );
        for threads in [1usize, 3] {
            let (par, stats) =
                explore_parallel_energy(&m, &split, &fm, &tables, &cfg, threads, &energy);
            assert_eq!(serial.len(), par.len(), "front size ({threads} threads)");
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.genome, b.genome);
                assert_eq!(a.objectives, b.objectives);
            }
            assert_eq!(stats.evals + stats.cache_hits, stats.requested);
        }
    }

    #[test]
    fn end_to_end_with_native_model() {
        // Full wiring on a random model: tables + NSGA + selection, using
        // the bit-exact functional model as the evaluator.
        let m = rand_model(61, 12, 4, 3);
        let mut r = Rng::new(8);
        let n = 60;
        let xs: Vec<u8> = (0..n * 12).map(|_| r.below(16) as u8).collect();
        let ys: Vec<u16> = (0..n).map(|_| r.below(3) as u16).collect();
        let fm = vec![1u8; 12];
        let tables = build_tables(&m, &xs, n, &fm);
        let baseline = m.accuracy(&xs, &ys, &fm, &[0u8; 4], &tables);
        let cfg = NsgaConfig {
            pop_size: 12,
            generations: 8,
            ..Default::default()
        };
        let front = explore(4, &cfg, |mask| m.accuracy(&xs, &ys, &fm, mask, &tables));
        let sel = select(&front, baseline, 0.05);
        // The selected mask's accuracy must satisfy the constraint.
        let acc = m.accuracy(&xs, &ys, &fm, &sel.approx_mask, &tables);
        assert!(acc + 1e-12 >= baseline - 0.05);
    }
}
