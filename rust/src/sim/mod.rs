//! Cycle-accurate gate-level simulator (VCS substitute).
//!
//! Executes the netlist IR directly — the same cells the Verilog emitter
//! prints — so simulated behaviour and emitted RTL cannot diverge.
//!
//! Performance: 2-valued simulation with 64 samples packed per machine
//! word (bit-parallel across *samples*, not bits), plus a levelized
//! (topologically ordered) compiled evaluation pass.  A full test-set
//! accuracy run of the largest circuit is a few million lane-parallel
//! gate evaluations.
//!
//! §Sharding: the levelization pass is factored into an immutable
//! [`SimPlan`] so an `n`-sample workload can be split into 64-lane blocks
//! dispatched across worker threads (see [`batch`]), each worker owning a
//! cheap [`Sim`] (two `u64` vectors) over the shared plan.  Every
//! primitive-cell update is a bitwise, per-lane operation, so a sample's
//! outputs depend only on its own lane — sharded and serial runs are
//! bit-identical by construction (enforced by `tests/sim_sharding.rs`).

pub mod batch;
pub mod testbench;

use std::sync::Arc;

use crate::netlist::{Cell, NetId, Netlist};

/// Immutable levelized evaluation plan for one netlist, shareable across
/// simulator instances and threads.
///
/// Building a plan runs the Kahn topological sort and DFF extraction once;
/// every [`Sim`] created from the same `Arc<SimPlan>` then reads the cell
/// array and ordering in place.  That is what makes sharded simulation
/// cheap: N workers cost one plan plus N small mutable state vectors, not
/// N topo sorts and cell-array clones.
pub struct SimPlan {
    cells: Vec<Cell>,
    /// Combinational cell indices in topological order.
    order: Vec<u32>,
    /// DFF cell indices.
    dffs: Vec<u32>,
    n_nets: usize,
}

impl SimPlan {
    pub fn new(n: &Netlist) -> SimPlan {
        let order = n.topo_order().into_iter().map(|i| i as u32).collect();
        let dffs = n
            .cells
            .iter()
            .enumerate()
            .filter(|(_, c)| c.is_seq())
            .map(|(i, _)| i as u32)
            .collect::<Vec<_>>();
        SimPlan {
            cells: n.cells.clone(),
            order,
            dffs,
            n_nets: n.n_nets(),
        }
    }

    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }

    pub fn n_dffs(&self) -> usize {
        self.dffs.len()
    }

    pub fn n_nets(&self) -> usize {
        self.n_nets
    }
}

/// Packed 64-lane two-valued simulator state over a shared [`SimPlan`].
pub struct Sim {
    plan: Arc<SimPlan>,
    /// Current value of every net, one bit per lane.
    vals: Vec<u64>,
    /// Scratch for the two-phase register update.
    next_q: Vec<u64>,
}

impl Sim {
    pub fn new(n: &Netlist) -> Sim {
        Sim::from_plan(Arc::new(SimPlan::new(n)))
    }

    /// Fresh simulator state over a shared plan — the sharded entry point:
    /// workers each call this with a clone of one `Arc<SimPlan>`.
    pub fn from_plan(plan: Arc<SimPlan>) -> Sim {
        let mut vals = vec![0u64; plan.n_nets];
        vals[1] = !0u64; // CONST1
        Sim {
            next_q: vec![0; plan.dffs.len()],
            plan,
            vals,
        }
    }

    /// The shared levelized plan this simulator executes.
    pub fn plan(&self) -> &Arc<SimPlan> {
        &self.plan
    }

    /// Number of parallel lanes.
    pub const LANES: usize = 64;

    #[inline]
    pub fn set(&mut self, net: NetId, packed: u64) {
        debug_assert!(net >= 2, "cannot drive constant nets");
        self.vals[net as usize] = packed;
    }

    #[inline]
    pub fn get(&self, net: NetId) -> u64 {
        self.vals[net as usize]
    }

    /// Drive a word with per-lane integer values (bit i of value v goes to
    /// lane `lane` of net `word[i]`).
    pub fn set_word_lanes(&mut self, word: &[NetId], values: &[i64]) {
        assert!(values.len() <= Self::LANES);
        for (bit, &net) in word.iter().enumerate() {
            let mut packed = 0u64;
            for (lane, &v) in values.iter().enumerate() {
                packed |= (((v >> bit) & 1) as u64) << lane;
            }
            self.set(net, packed);
        }
    }

    /// Broadcast one value to all lanes of a word.
    pub fn set_word_all(&mut self, word: &[NetId], value: i64) {
        for (bit, &net) in word.iter().enumerate() {
            let v = if (value >> bit) & 1 == 1 { !0u64 } else { 0u64 };
            self.set(net, v);
        }
    }

    /// Read a word back for one lane, two's-complement sign-extended.
    pub fn get_word_lane_signed(&self, word: &[NetId], lane: usize) -> i64 {
        let mut v: i64 = 0;
        for (bit, &net) in word.iter().enumerate() {
            if (self.vals[net as usize] >> lane) & 1 == 1 {
                v |= 1 << bit;
            }
        }
        let w = word.len();
        if w < 64 && (v >> (w - 1)) & 1 == 1 {
            v -= 1 << w;
        }
        v
    }

    /// Read a word back for one lane, unsigned.
    pub fn get_word_lane(&self, word: &[NetId], lane: usize) -> u64 {
        let mut v: u64 = 0;
        for (bit, &net) in word.iter().enumerate() {
            if (self.vals[net as usize] >> lane) & 1 == 1 {
                v |= 1 << bit;
            }
        }
        v
    }

    /// Propagate combinational logic.
    pub fn eval(&mut self) {
        let plan = &*self.plan;
        for &ci in &plan.order {
            let c = plan.cells[ci as usize];
            let v = &mut self.vals;
            match c {
                Cell::Inv { a, y } => v[y as usize] = !v[a as usize],
                Cell::Buf { a, y } => v[y as usize] = v[a as usize],
                Cell::Nand2 { a, b, y } => v[y as usize] = !(v[a as usize] & v[b as usize]),
                Cell::Nor2 { a, b, y } => v[y as usize] = !(v[a as usize] | v[b as usize]),
                Cell::And2 { a, b, y } => v[y as usize] = v[a as usize] & v[b as usize],
                Cell::Or2 { a, b, y } => v[y as usize] = v[a as usize] | v[b as usize],
                Cell::Xor2 { a, b, y } => v[y as usize] = v[a as usize] ^ v[b as usize],
                Cell::Xnor2 { a, b, y } => v[y as usize] = !(v[a as usize] ^ v[b as usize]),
                Cell::Mux2 { a, b, sel, y } => {
                    let s = v[sel as usize];
                    v[y as usize] = (v[a as usize] & !s) | (v[b as usize] & s);
                }
                Cell::Dff { .. } => unreachable!("DFF in comb order"),
            }
        }
    }

    /// One clock edge: propagate combinational logic from the current
    /// inputs, capture register inputs (two-phase), and commit.
    ///
    /// §Perf: register outputs are updated but downstream logic is NOT
    /// re-propagated here — the next `step()` (or a final [`Sim::settle`])
    /// does that once, halving combinational work per cycle compared to
    /// the naive eval-capture-commit-eval loop.  Call `settle()` before
    /// reading outputs after the last step.
    pub fn step(&mut self) {
        self.eval();
        let plan = &*self.plan;
        for (slot, &ci) in plan.dffs.iter().enumerate() {
            if let Cell::Dff {
                d,
                q,
                en,
                rst,
                rstval,
            } = plan.cells[ci as usize]
            {
                let v = &self.vals;
                let rv = if rstval { !0u64 } else { 0u64 };
                let held = (v[en as usize] & v[d as usize]) | (!v[en as usize] & v[q as usize]);
                self.next_q[slot] = (v[rst as usize] & rv) | (!v[rst as usize] & held);
            }
        }
        for (slot, &ci) in plan.dffs.iter().enumerate() {
            let q = plan.cells[ci as usize].output();
            self.vals[q as usize] = self.next_q[slot];
        }
    }

    /// Propagate combinational logic so outputs reflect the last commit.
    pub fn settle(&mut self) {
        self.eval();
    }

    /// Reset all registers to their reset values (as if rst had been held
    /// high for one cycle), then propagate.
    pub fn reset(&mut self) {
        let plan = &*self.plan;
        for &ci in plan.dffs.iter() {
            if let Cell::Dff { q, rstval, .. } = plan.cells[ci as usize] {
                self.vals[q as usize] = if rstval { !0u64 } else { 0u64 };
            }
        }
        self.eval();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{Netlist, CONST0, CONST1};

    #[test]
    fn comb_logic_all_lanes() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let y = n.xor2(a, b);
        n.add_output("y", vec![y]);
        let mut s = Sim::new(&n);
        s.set(a, 0b1100);
        s.set(b, 0b1010);
        s.eval();
        assert_eq!(s.get(y) & 0xF, 0b0110);
    }

    #[test]
    fn mux_semantics() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let sel = n.add_input("s", 1)[0];
        let y = n.mux2(sel, a, b);
        let mut s = Sim::new(&n);
        s.set(a, 0b01);
        s.set(b, 0b10);
        s.set(sel, 0b10); // lane0: sel=0 -> a; lane1: sel=1 -> b
        s.eval();
        assert_eq!(s.get(y) & 0b11, 0b11);
    }

    #[test]
    fn dff_enable_and_reset() {
        let mut n = Netlist::new("t");
        let d = n.add_input("d", 1)[0];
        let en = n.add_input("en", 1)[0];
        let rst = n.add_input("rst", 1)[0];
        let q = n.dff(d, en, rst, true);
        n.add_output("q", vec![q]);
        let mut s = Sim::new(&n);
        // reset loads rstval=1
        s.set(d, 0);
        s.set(en, !0);
        s.set(rst, !0);
        s.step();
        assert_eq!(s.get(q), !0u64);
        // enabled capture of d=0
        s.set(rst, 0);
        s.set(d, 0);
        s.step();
        assert_eq!(s.get(q), 0);
        // disabled: hold
        s.set(en, 0);
        s.set(d, !0);
        s.step();
        assert_eq!(s.get(q), 0);
    }

    #[test]
    fn counter_via_feedback() {
        // 3-bit counter: q + 1 computed with xor/and chain.
        let mut n = Netlist::new("t");
        let (q0, c0) = n.dff_deferred(CONST1, CONST0, false);
        let (q1, c1) = n.dff_deferred(CONST1, CONST0, false);
        let (q2, c2) = n.dff_deferred(CONST1, CONST0, false);
        let d0 = n.inv(q0);
        let d1 = n.xor2(q1, q0);
        let carry = n.and2(q0, q1);
        let d2 = n.xor2(q2, carry);
        n.set_dff_d(c0, d0);
        n.set_dff_d(c1, d1);
        n.set_dff_d(c2, d2);
        let word = vec![q0, q1, q2];
        let mut s = Sim::new(&n);
        s.reset();
        for expect in 1..=7u64 {
            s.step();
            assert_eq!(s.get_word_lane(&word, 0), expect % 8);
        }
    }

    #[test]
    fn word_lane_roundtrip_signed() {
        let mut n = Netlist::new("t");
        let w = n.add_input("w", 6);
        let mut s = Sim::new(&n);
        let vals = [-32i64, -1, 0, 1, 31, 5, -17, 12];
        s.set_word_lanes(&w, &vals);
        for (lane, &v) in vals.iter().enumerate() {
            assert_eq!(s.get_word_lane_signed(&w, lane), v);
        }
    }

    #[test]
    fn shared_plan_sims_are_independent_and_equal() {
        // Two Sims over one plan behave exactly like two fresh Sims.
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let y = n.and2(a, b);
        n.add_output("y", vec![y]);
        let plan = Arc::new(SimPlan::new(&n));
        let mut s1 = Sim::from_plan(plan.clone());
        let mut s2 = Sim::from_plan(plan.clone());
        s1.set(a, 0b11);
        s1.set(b, 0b01);
        s2.set(a, 0b10);
        s2.set(b, 0b10);
        s1.eval();
        s2.eval();
        assert_eq!(s1.get(y) & 0b11, 0b01);
        assert_eq!(s2.get(y) & 0b11, 0b10);
        assert_eq!(plan.n_cells(), 1);
        assert_eq!(plan.n_dffs(), 0);
    }
}
