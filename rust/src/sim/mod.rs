//! Cycle-accurate gate-level simulator (VCS substitute).
//!
//! Executes the netlist IR directly — the same cells the Verilog emitter
//! prints — so simulated behaviour and emitted RTL cannot diverge.
//!
//! Performance: 2-valued simulation with 64 samples packed per machine
//! word (bit-parallel across *samples*, not bits), plus a levelized
//! (topologically ordered) compiled evaluation pass.  A full test-set
//! accuracy run of the largest circuit is a few million lane-parallel
//! gate evaluations.
//!
//! §Sharding: the levelization pass is factored into an immutable
//! [`SimPlan`] so an `n`-sample workload can be split into 64-lane blocks
//! dispatched across worker threads (see [`batch`]), each worker owning a
//! cheap [`Sim`] (two `u64` vectors) over the shared plan.  Every
//! primitive-cell update is a bitwise, per-lane operation, so a sample's
//! outputs depend only on its own lane — sharded and serial runs are
//! bit-identical by construction (enforced by `tests/sim_sharding.rs`).
//!
//! §Compiled plans: [`SimPlan::compiled`] lowers the levelized netlist
//! into a flat structure-of-arrays micro-op stream (one opcode byte per
//! surviving gate plus parallel `u32` operand arrays), after running the
//! netlist strength-reduction passes at plan-build time — constant
//! folding through `CONST0`/`CONST1`, buffer and double-inverter chain
//! collapsing, INV-into-producer fusion onto the complementary
//! NAND/NOR/XNOR opcodes, and dead-net elimination — and **renumbering
//! the surviving nets densely in topological order**, so `vals` holds
//! live nets only and each level's reads and writes stay cache-local.  A
//! compact `u32 → u32` port map translates external [`Sim::set`] /
//! [`Sim::get`] net ids, so testbenches drive compiled and interpreted
//! simulators identically.  The interpreted path is retained unchanged as
//! the reference oracle; `tests/sim_compiled.rs` enforces bit-identical
//! behaviour on every lane, including partial final blocks and reset
//! semantics.  Plans built lazily by the circuit wrappers compile by
//! default — `--no-compile-sim`, `sim.compile = false`, or
//! `PRINTED_MLP_NO_COMPILE_SIM=1` select the interpreted oracle instead
//! (see [`compile_default`]).

pub mod batch;
pub mod testbench;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::netlist::{opt, Cell, NetId, Netlist, Port, CONST0, CONST1};

/// Process-wide default for whether lazily-built circuit plans (e.g.
/// [`crate::circuits::SeqCircuit::sim_plan`]) compile their netlist into
/// the micro-op stream.  On by default; the CLI's `--no-compile-sim`,
/// the `sim.compile` config key, and the `PRINTED_MLP_NO_COMPILE_SIM`
/// environment variable (any value but `0`) turn it off, forcing the
/// interpreted reference path everywhere.
static COMPILE_DEFAULT: AtomicBool = AtomicBool::new(true);

/// Whether circuit plans compile by default (see [`set_compile_default`];
/// `PRINTED_MLP_NO_COMPILE_SIM` overrides the process-wide flag).
pub fn compile_default() -> bool {
    match std::env::var_os("PRINTED_MLP_NO_COMPILE_SIM") {
        Some(v) if !v.is_empty() && v != "0" => false,
        _ => COMPILE_DEFAULT.load(Ordering::Relaxed),
    }
}

/// Set the process-wide compile default (the `--no-compile-sim` escape
/// hatch).  Affects plans built *after* the call; circuits cache their
/// plan on first use.
pub fn set_compile_default(on: bool) {
    COMPILE_DEFAULT.store(on, Ordering::Relaxed);
}

// Micro-op opcodes: one byte per surviving gate, dispatched over
// contiguous arrays (branch-predictable, cache-dense — no enum payload
// loads from a scattered `Vec<Cell>`).
const OP_INV: u8 = 0;
const OP_BUF: u8 = 1;
const OP_NAND: u8 = 2;
const OP_NOR: u8 = 3;
const OP_AND: u8 = 4;
const OP_OR: u8 = 5;
const OP_XOR: u8 = 6;
const OP_XNOR: u8 = 7;
const OP_MUX: u8 = 8;

/// A netlist lowered to a flat structure-of-arrays micro-op stream with
/// densely renumbered nets — the compiled form [`Sim::eval`] executes.
///
/// Built once per netlist by [`SimPlan::compiled`] and shared read-only
/// by every sharded worker.  Compilation clones the netlist and runs
/// [`opt::fold_collapse`] → [`opt::fuse_inversions`] → [`opt::dce`], so
/// the stream never contains a gate the strength reduction could remove;
/// the property suite checks compilation never *increases* gate count.
pub struct CompiledPlan {
    /// One opcode byte per micro-op, in topological order.
    ops: Vec<u8>,
    /// First operand (dense slot) per micro-op.
    src_a: Vec<u32>,
    /// Second operand; slot 0 (constant-0) for unary ops.
    src_b: Vec<u32>,
    /// Third operand (mux select); slot 0 for non-mux ops.
    src_c: Vec<u32>,
    /// Destination slot per micro-op.
    dst: Vec<u32>,
    // DFF state, struct-of-arrays (dense slots).
    dff_d: Vec<u32>,
    dff_q: Vec<u32>,
    dff_en: Vec<u32>,
    dff_rst: Vec<u32>,
    /// Reset value broadcast across all 64 lanes (`!0` or `0`).
    dff_rstval: Vec<u64>,
    /// Dense value-vector length (live nets only; slots 0/1 = constants).
    n_dense: usize,
    /// External net id → dense slot for reads (`u32::MAX` = eliminated
    /// net, reads 0).  Folded nets translate to their surviving alias,
    /// so port reads observe identical values on the compiled and
    /// interpreted paths.
    port_map: Vec<u32>,
    /// External net id → dense slot for writes: like `port_map` but with
    /// NO alias following — driving a net the plan folded away is a
    /// silent no-op (on the oracle the next `eval` would overwrite such
    /// a write anyway; following the alias could clobber a live input).
    write_map: Vec<u32>,
}

impl CompiledPlan {
    fn build(src: &Netlist) -> CompiledPlan {
        let ext_nets = src.n_nets();
        let mut net = src.clone();
        // Plan-time strength reduction (netlist-level passes shared with
        // `opt::optimize`), then sweep anything unobservable.
        let repl = opt::fold_collapse(&mut net);
        opt::fuse_inversions(&mut net);
        // Registers are externally observable state (`Sim::get` on a q
        // net needs no output port), so root every register through a
        // synthetic port for the dead-logic sweep — plan compilation
        // must never silence state the interpreted oracle keeps.
        let state_roots: Vec<NetId> = net
            .cells
            .iter()
            .filter(|c| c.is_seq())
            .map(|c| c.output())
            .collect();
        net.outputs.push(Port {
            name: "__state_roots".into(),
            bits: state_roots,
        });
        opt::dce(&mut net);
        net.outputs.pop();

        // Dense renumbering: constants, then external sources (input
        // ports), then register state, then combinational outputs in
        // topological order — the order eval writes them.
        let order = net.topo_order();
        let mut dense = vec![u32::MAX; ext_nets];
        dense[CONST0 as usize] = 0;
        dense[CONST1 as usize] = 1;
        let mut next = 2u32;
        {
            let mut assign = |id: NetId| {
                let slot = &mut dense[id as usize];
                if *slot == u32::MAX {
                    *slot = next;
                    next += 1;
                }
            };
            for port in &net.inputs {
                for &b in &port.bits {
                    assign(b);
                }
            }
            for c in &net.cells {
                if c.is_seq() {
                    assign(c.output());
                }
            }
            for &ci in &order {
                assign(net.cells[ci].output());
            }
            // Safety net: a surviving cell may read an undriven non-port
            // net (legal; reads as all-zero) — give it a slot too.
            for c in &net.cells {
                c.for_each_input(&mut assign);
            }
        }

        let d = |id: NetId| dense[id as usize];
        let n_ops = order.len();
        let mut ops = Vec::with_capacity(n_ops);
        let mut src_a = Vec::with_capacity(n_ops);
        let mut src_b = Vec::with_capacity(n_ops);
        let mut src_c = Vec::with_capacity(n_ops);
        let mut dst = Vec::with_capacity(n_ops);
        for &ci in &order {
            let c = net.cells[ci];
            let (op, a, b, sel) = match c {
                Cell::Inv { a, .. } => (OP_INV, a, CONST0, CONST0),
                Cell::Buf { a, .. } => (OP_BUF, a, CONST0, CONST0),
                Cell::Nand2 { a, b, .. } => (OP_NAND, a, b, CONST0),
                Cell::Nor2 { a, b, .. } => (OP_NOR, a, b, CONST0),
                Cell::And2 { a, b, .. } => (OP_AND, a, b, CONST0),
                Cell::Or2 { a, b, .. } => (OP_OR, a, b, CONST0),
                Cell::Xor2 { a, b, .. } => (OP_XOR, a, b, CONST0),
                Cell::Xnor2 { a, b, .. } => (OP_XNOR, a, b, CONST0),
                Cell::Mux2 { a, b, sel, .. } => (OP_MUX, a, b, sel),
                Cell::Dff { .. } => unreachable!("DFF in comb order"),
            };
            ops.push(op);
            src_a.push(d(a));
            src_b.push(d(b));
            src_c.push(d(sel));
            dst.push(d(c.output()));
        }

        let mut dff_d = Vec::new();
        let mut dff_q = Vec::new();
        let mut dff_en = Vec::new();
        let mut dff_rst = Vec::new();
        let mut dff_rstval = Vec::new();
        for c in &net.cells {
            if let Cell::Dff {
                d: dd,
                q,
                en,
                rst,
                rstval,
            } = *c
            {
                dff_d.push(d(dd));
                dff_q.push(d(q));
                dff_en.push(d(en));
                dff_rst.push(d(rst));
                dff_rstval.push(if rstval { !0u64 } else { 0u64 });
            }
        }

        // Output-port bits whose driving cell folded away must NOT alias
        // their surviving source directly: the interpreted oracle updates
        // comb nets only during `eval`, so a direct alias of a register
        // output would observe the *post-commit* value after `step` (and
        // an alias of an input would observe a driven value before any
        // `eval`).  Materialize one BUF micro-op per such bit instead —
        // ports are few — giving the observed net its own slot that
        // updates exactly when the oracle's comb net does.  Each BUF
        // replaces at least the one folded cell that drove the bit, so
        // compilation still never increases the op count.
        for port in &src.outputs {
            for &o in &port.bits {
                if dense[o as usize] != u32::MAX {
                    continue;
                }
                let t = repl[o as usize];
                let slot = next;
                next += 1;
                dense[o as usize] = slot;
                if t != o && dense[t as usize] != u32::MAX {
                    ops.push(OP_BUF);
                    src_a.push(dense[t as usize]);
                    src_b.push(0);
                    src_c.push(0);
                    dst.push(slot);
                }
                // else: an undriven port bit — a bare slot (reads 0,
                // externally drivable), matching the interpreted vals.
            }
        }

        // External translation: live nets (now including every port bit)
        // map straight to their dense slot; for reads, other folded nets
        // additionally map to their surviving alias (post-`eval`
        // observation only — the external contract covers ports and
        // register outputs); the rest are dead.
        let write_map = dense.clone();
        let mut port_map = dense.clone();
        for (o, slot) in port_map.iter_mut().enumerate() {
            if *slot == u32::MAX {
                let t = repl[o] as usize;
                if t != o {
                    *slot = dense[t];
                }
            }
        }

        CompiledPlan {
            ops,
            src_a,
            src_b,
            src_c,
            dst,
            dff_d,
            dff_q,
            dff_en,
            dff_rst,
            dff_rstval,
            n_dense: next as usize,
            port_map,
            write_map,
        }
    }

    /// Number of combinational micro-ops in the stream.
    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// Number of registers in the compiled state (every source register
    /// is kept — state is externally observable via [`Sim::get`]).
    pub fn n_state(&self) -> usize {
        self.dff_q.len()
    }

    /// Dense value-vector length (live nets incl. the two constants).
    pub fn n_dense_nets(&self) -> usize {
        self.n_dense
    }
}

/// Immutable levelized evaluation plan for one netlist, shareable across
/// simulator instances and threads.
///
/// Building a plan runs the Kahn topological sort and DFF extraction once;
/// every [`Sim`] created from the same `Arc<SimPlan>` then reads the cell
/// array and ordering in place.  That is what makes sharded simulation
/// cheap: N workers cost one plan plus N small mutable state vectors, not
/// N topo sorts and cell-array clones.
pub struct SimPlan {
    cells: Vec<Cell>,
    /// Combinational cell indices in topological order.
    order: Vec<u32>,
    /// DFF cell indices.
    dffs: Vec<u32>,
    n_nets: usize,
    /// Lowered micro-op stream (None = interpreted reference path).
    compiled: Option<CompiledPlan>,
}

impl SimPlan {
    /// Interpreted plan — the reference oracle the compiled path is
    /// differentially tested against.
    pub fn new(n: &Netlist) -> SimPlan {
        let order = n.topo_order().into_iter().map(|i| i as u32).collect();
        let dffs = n
            .cells
            .iter()
            .enumerate()
            .filter(|(_, c)| c.is_seq())
            .map(|(i, _)| i as u32)
            .collect::<Vec<_>>();
        SimPlan {
            cells: n.cells.clone(),
            order,
            dffs,
            n_nets: n.n_nets(),
            compiled: None,
        }
    }

    /// Compiled plan: interpreted metadata (kept as the oracle and for
    /// [`SimPlan::n_cells`]-style reporting) plus the strength-reduced,
    /// densely renumbered micro-op stream that [`Sim::eval`] executes.
    pub fn compiled(n: &Netlist) -> SimPlan {
        let mut plan = SimPlan::new(n);
        plan.compiled = Some(CompiledPlan::build(n));
        plan
    }

    /// [`SimPlan::compiled`] or [`SimPlan::new`] per the process-wide
    /// [`compile_default`] — what the circuit wrappers' lazy plans use.
    pub fn with_default_mode(n: &Netlist) -> SimPlan {
        if compile_default() {
            SimPlan::compiled(n)
        } else {
            SimPlan::new(n)
        }
    }

    /// Source-netlist cell count (the interpreted view, independent of
    /// how many micro-ops strength reduction left).
    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }

    pub fn n_dffs(&self) -> usize {
        self.dffs.len()
    }

    /// Source-netlist net count (external ids run to this bound).
    pub fn n_nets(&self) -> usize {
        self.n_nets
    }

    pub fn is_compiled(&self) -> bool {
        self.compiled.is_some()
    }

    /// The lowered stream, when this plan was built with
    /// [`SimPlan::compiled`].
    pub fn compiled_plan(&self) -> Option<&CompiledPlan> {
        self.compiled.as_ref()
    }

    /// Map an external (source-netlist) net id to this plan's value slot
    /// for reads (aliases follow their survivor); `u32::MAX` when plan
    /// compilation eliminated the net.
    #[inline]
    fn read_slot(&self, net: NetId) -> u32 {
        match &self.compiled {
            Some(cp) => cp.port_map[net as usize],
            None => net,
        }
    }

    /// Map an external net id to this plan's value slot for writes —
    /// aliases are NOT followed (driving a folded net is a no-op), so an
    /// external `set` can never clobber a live survivor.
    #[inline]
    fn write_slot(&self, net: NetId) -> u32 {
        match &self.compiled {
            Some(cp) => cp.write_map[net as usize],
            None => net,
        }
    }
}

/// Packed 64-lane two-valued simulator state over a shared [`SimPlan`].
pub struct Sim {
    plan: Arc<SimPlan>,
    /// Current value of every net, one bit per lane.
    vals: Vec<u64>,
    /// Scratch for the two-phase register update.
    next_q: Vec<u64>,
}

impl Sim {
    pub fn new(n: &Netlist) -> Sim {
        Sim::from_plan(Arc::new(SimPlan::new(n)))
    }

    /// Fresh simulator state over a shared plan — the sharded entry point:
    /// workers each call this with a clone of one `Arc<SimPlan>`.
    ///
    /// Over a compiled plan the value vector is sized to the dense live
    /// nets only (cache-local levels); over an interpreted plan it spans
    /// every source net, exactly as before compilation existed.
    pub fn from_plan(plan: Arc<SimPlan>) -> Sim {
        let n_vals = plan.compiled.as_ref().map_or(plan.n_nets, |c| c.n_dense);
        let n_state = plan
            .compiled
            .as_ref()
            .map_or(plan.dffs.len(), |c| c.dff_q.len());
        let mut vals = vec![0u64; n_vals];
        vals[1] = !0u64; // CONST1
        Sim {
            next_q: vec![0; n_state],
            plan,
            vals,
        }
    }

    /// The shared levelized plan this simulator executes.
    pub fn plan(&self) -> &Arc<SimPlan> {
        &self.plan
    }

    /// Number of parallel lanes.
    pub const LANES: usize = 64;

    /// Drive a net with one packed 64-lane word.  `net` is always a
    /// *source-netlist* id; on a compiled plan it is translated through
    /// the write map, and driving a net compilation eliminated or folded
    /// away (e.g. a pruned input that feeds only dead logic) is a silent
    /// no-op — never a write to the folded net's survivor.
    #[inline]
    pub fn set(&mut self, net: NetId, packed: u64) {
        debug_assert!(net >= 2, "cannot drive constant nets");
        let slot = self.plan.write_slot(net);
        if slot != u32::MAX {
            debug_assert!(slot >= 2, "cannot drive a constant slot");
            self.vals[slot as usize] = packed;
        }
    }

    /// Read a net's packed 64-lane word (source-netlist id; compiled
    /// plans translate through the port map — a net folded onto an alias
    /// or constant reads that survivor's value, an eliminated net reads
    /// 0).  The external contract covers port bits and register outputs;
    /// arbitrary internal nets are only observable on interpreted plans.
    #[inline]
    pub fn get(&self, net: NetId) -> u64 {
        let slot = self.plan.read_slot(net);
        if slot == u32::MAX {
            0
        } else {
            self.vals[slot as usize]
        }
    }

    /// Drive a word with per-lane integer values (bit i of value v goes to
    /// lane `lane` of net `word[i]`).
    pub fn set_word_lanes(&mut self, word: &[NetId], values: &[i64]) {
        assert!(values.len() <= Self::LANES);
        for (bit, &net) in word.iter().enumerate() {
            let mut packed = 0u64;
            for (lane, &v) in values.iter().enumerate() {
                packed |= (((v >> bit) & 1) as u64) << lane;
            }
            self.set(net, packed);
        }
    }

    /// Broadcast one value to all lanes of a word.
    pub fn set_word_all(&mut self, word: &[NetId], value: i64) {
        for (bit, &net) in word.iter().enumerate() {
            let v = if (value >> bit) & 1 == 1 { !0u64 } else { 0u64 };
            self.set(net, v);
        }
    }

    /// Read a word back for one lane, two's-complement sign-extended.
    pub fn get_word_lane_signed(&self, word: &[NetId], lane: usize) -> i64 {
        let mut v: i64 = 0;
        for (bit, &net) in word.iter().enumerate() {
            if (self.get(net) >> lane) & 1 == 1 {
                v |= 1 << bit;
            }
        }
        let w = word.len();
        if w < 64 && (v >> (w - 1)) & 1 == 1 {
            v -= 1 << w;
        }
        v
    }

    /// Read a word back for one lane, unsigned.
    pub fn get_word_lane(&self, word: &[NetId], lane: usize) -> u64 {
        let mut v: u64 = 0;
        for (bit, &net) in word.iter().enumerate() {
            if (self.get(net) >> lane) & 1 == 1 {
                v |= 1 << bit;
            }
        }
        v
    }

    /// Propagate combinational logic.
    ///
    /// Compiled plans run the flat micro-op stream: a byte-dispatch over
    /// four contiguous operand arrays with densely renumbered slots —
    /// no enum payload decode, no scattered `vals` indexing.  Interpreted
    /// plans walk the levelized `Vec<Cell>` exactly as before (the
    /// oracle the differential suite compares against).
    pub fn eval(&mut self) {
        let plan = &*self.plan;
        if let Some(cp) = &plan.compiled {
            // Local equal-length slices let the compiler hoist the
            // operand-array bounds checks out of the micro-op loop.
            let n_ops = cp.ops.len();
            let (ops, src_a, src_b) = (&cp.ops[..n_ops], &cp.src_a[..n_ops], &cp.src_b[..n_ops]);
            let (src_c, dst) = (&cp.src_c[..n_ops], &cp.dst[..n_ops]);
            let v = &mut self.vals;
            for i in 0..n_ops {
                let op = ops[i];
                let a = v[src_a[i] as usize];
                let b = v[src_b[i] as usize];
                let r = match op {
                    OP_INV => !a,
                    OP_BUF => a,
                    OP_NAND => !(a & b),
                    OP_NOR => !(a | b),
                    OP_AND => a & b,
                    OP_OR => a | b,
                    OP_XOR => a ^ b,
                    OP_XNOR => !(a ^ b),
                    _ => {
                        debug_assert_eq!(op, OP_MUX);
                        let s = v[src_c[i] as usize];
                        (a & !s) | (b & s)
                    }
                };
                v[dst[i] as usize] = r;
            }
            return;
        }
        for &ci in &plan.order {
            let c = plan.cells[ci as usize];
            let v = &mut self.vals;
            match c {
                Cell::Inv { a, y } => v[y as usize] = !v[a as usize],
                Cell::Buf { a, y } => v[y as usize] = v[a as usize],
                Cell::Nand2 { a, b, y } => v[y as usize] = !(v[a as usize] & v[b as usize]),
                Cell::Nor2 { a, b, y } => v[y as usize] = !(v[a as usize] | v[b as usize]),
                Cell::And2 { a, b, y } => v[y as usize] = v[a as usize] & v[b as usize],
                Cell::Or2 { a, b, y } => v[y as usize] = v[a as usize] | v[b as usize],
                Cell::Xor2 { a, b, y } => v[y as usize] = v[a as usize] ^ v[b as usize],
                Cell::Xnor2 { a, b, y } => v[y as usize] = !(v[a as usize] ^ v[b as usize]),
                Cell::Mux2 { a, b, sel, y } => {
                    let s = v[sel as usize];
                    v[y as usize] = (v[a as usize] & !s) | (v[b as usize] & s);
                }
                Cell::Dff { .. } => unreachable!("DFF in comb order"),
            }
        }
    }

    /// One clock edge: propagate combinational logic from the current
    /// inputs, capture register inputs (two-phase), and commit.
    ///
    /// §Perf: register outputs are updated but downstream logic is NOT
    /// re-propagated here — the next `step()` (or a final [`Sim::settle`])
    /// does that once, halving combinational work per cycle compared to
    /// the naive eval-capture-commit-eval loop.  Call `settle()` before
    /// reading outputs after the last step.
    pub fn step(&mut self) {
        self.eval();
        let plan = &*self.plan;
        if let Some(cp) = &plan.compiled {
            for i in 0..cp.dff_q.len() {
                let v = &self.vals;
                let d = v[cp.dff_d[i] as usize];
                let en = v[cp.dff_en[i] as usize];
                let rst = v[cp.dff_rst[i] as usize];
                let q = v[cp.dff_q[i] as usize];
                let held = (en & d) | (!en & q);
                self.next_q[i] = (rst & cp.dff_rstval[i]) | (!rst & held);
            }
            for (&qslot, &nq) in cp.dff_q.iter().zip(self.next_q.iter()) {
                self.vals[qslot as usize] = nq;
            }
            return;
        }
        for (slot, &ci) in plan.dffs.iter().enumerate() {
            if let Cell::Dff {
                d,
                q,
                en,
                rst,
                rstval,
            } = plan.cells[ci as usize]
            {
                let v = &self.vals;
                let rv = if rstval { !0u64 } else { 0u64 };
                let held = (v[en as usize] & v[d as usize]) | (!v[en as usize] & v[q as usize]);
                self.next_q[slot] = (v[rst as usize] & rv) | (!v[rst as usize] & held);
            }
        }
        for (slot, &ci) in plan.dffs.iter().enumerate() {
            let q = plan.cells[ci as usize].output();
            self.vals[q as usize] = self.next_q[slot];
        }
    }

    /// Propagate combinational logic so outputs reflect the last commit.
    pub fn settle(&mut self) {
        self.eval();
    }

    /// Reset all registers to their reset values (as if rst had been held
    /// high for one cycle), then propagate.
    pub fn reset(&mut self) {
        if let Some(cp) = &self.plan.compiled {
            for (&qslot, &rv) in cp.dff_q.iter().zip(cp.dff_rstval.iter()) {
                self.vals[qslot as usize] = rv;
            }
        } else {
            let plan = &*self.plan;
            for &ci in plan.dffs.iter() {
                if let Cell::Dff { q, rstval, .. } = plan.cells[ci as usize] {
                    self.vals[q as usize] = if rstval { !0u64 } else { 0u64 };
                }
            }
        }
        self.eval();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{Netlist, CONST0, CONST1};

    #[test]
    fn comb_logic_all_lanes() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let y = n.xor2(a, b);
        n.add_output("y", vec![y]);
        let mut s = Sim::new(&n);
        s.set(a, 0b1100);
        s.set(b, 0b1010);
        s.eval();
        assert_eq!(s.get(y) & 0xF, 0b0110);
    }

    #[test]
    fn mux_semantics() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let sel = n.add_input("s", 1)[0];
        let y = n.mux2(sel, a, b);
        let mut s = Sim::new(&n);
        s.set(a, 0b01);
        s.set(b, 0b10);
        s.set(sel, 0b10); // lane0: sel=0 -> a; lane1: sel=1 -> b
        s.eval();
        assert_eq!(s.get(y) & 0b11, 0b11);
    }

    #[test]
    fn dff_enable_and_reset() {
        let mut n = Netlist::new("t");
        let d = n.add_input("d", 1)[0];
        let en = n.add_input("en", 1)[0];
        let rst = n.add_input("rst", 1)[0];
        let q = n.dff(d, en, rst, true);
        n.add_output("q", vec![q]);
        let mut s = Sim::new(&n);
        // reset loads rstval=1
        s.set(d, 0);
        s.set(en, !0);
        s.set(rst, !0);
        s.step();
        assert_eq!(s.get(q), !0u64);
        // enabled capture of d=0
        s.set(rst, 0);
        s.set(d, 0);
        s.step();
        assert_eq!(s.get(q), 0);
        // disabled: hold
        s.set(en, 0);
        s.set(d, !0);
        s.step();
        assert_eq!(s.get(q), 0);
    }

    #[test]
    fn counter_via_feedback() {
        // 3-bit counter: q + 1 computed with xor/and chain.
        let mut n = Netlist::new("t");
        let (q0, c0) = n.dff_deferred(CONST1, CONST0, false);
        let (q1, c1) = n.dff_deferred(CONST1, CONST0, false);
        let (q2, c2) = n.dff_deferred(CONST1, CONST0, false);
        let d0 = n.inv(q0);
        let d1 = n.xor2(q1, q0);
        let carry = n.and2(q0, q1);
        let d2 = n.xor2(q2, carry);
        n.set_dff_d(c0, d0);
        n.set_dff_d(c1, d1);
        n.set_dff_d(c2, d2);
        let word = vec![q0, q1, q2];
        let mut s = Sim::new(&n);
        s.reset();
        for expect in 1..=7u64 {
            s.step();
            assert_eq!(s.get_word_lane(&word, 0), expect % 8);
        }
    }

    #[test]
    fn word_lane_roundtrip_signed() {
        let mut n = Netlist::new("t");
        let w = n.add_input("w", 6);
        let mut s = Sim::new(&n);
        let vals = [-32i64, -1, 0, 1, 31, 5, -17, 12];
        s.set_word_lanes(&w, &vals);
        for (lane, &v) in vals.iter().enumerate() {
            assert_eq!(s.get_word_lane_signed(&w, lane), v);
        }
    }

    #[test]
    fn compiled_comb_matches_interpreted_and_shrinks() {
        // x_all → adder-ish logic with a buffer + double inverter thrown
        // in; the compiled stream must reduce it and agree on every lane.
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let x = n.and2(a, b);
        let buf = n.fresh();
        n.cells.push(Cell::Buf { a: x, y: buf });
        let i1 = n.inv(buf);
        let i2 = n.inv(i1);
        let y = n.xor2(i2, a);
        let z = n.or2(x, CONST0); // alias of x after folding
        n.add_output("y", vec![y]);
        n.add_output("z", vec![z]);
        let interp = Arc::new(SimPlan::new(&n));
        let comp = Arc::new(SimPlan::compiled(&n));
        assert!(comp.is_compiled() && !interp.is_compiled());
        let cp = comp.compiled_plan().unwrap();
        assert!(cp.n_ops() < n.cells.len(), "strength reduction must bite");
        assert!(cp.n_dense_nets() <= n.n_nets());
        let mut si = Sim::from_plan(interp);
        let mut sc = Sim::from_plan(comp);
        for (pa, pb) in [(0u64, 0u64), (!0, 0), (0xDEAD_BEEF, 0xF00D_CAFE), (!0, !0)] {
            for s in [&mut si, &mut sc] {
                s.set(a, pa);
                s.set(b, pb);
                s.eval();
            }
            assert_eq!(si.get(y), sc.get(y), "y lanes");
            assert_eq!(si.get(z), sc.get(z), "z (folded alias) lanes");
        }
    }

    #[test]
    fn compiled_counter_matches_interpreted_over_steps_and_reset() {
        let mut n = Netlist::new("t");
        let (q0, c0) = n.dff_deferred(CONST1, CONST0, false);
        let (q1, c1) = n.dff_deferred(CONST1, CONST0, false);
        let (q2, c2) = n.dff_deferred(CONST1, CONST0, true); // rstval mix
        let d0 = n.inv(q0);
        let d1 = n.xor2(q1, q0);
        let carry = n.and2(q0, q1);
        let d2 = n.xor2(q2, carry);
        n.set_dff_d(c0, d0);
        n.set_dff_d(c1, d1);
        n.set_dff_d(c2, d2);
        let word = vec![q0, q1, q2];
        n.add_output("q", word.clone());
        let mut si = Sim::from_plan(Arc::new(SimPlan::new(&n)));
        let mut sc = Sim::from_plan(Arc::new(SimPlan::compiled(&n)));
        si.reset();
        sc.reset();
        assert_eq!(si.get_word_lane(&word, 0), sc.get_word_lane(&word, 0));
        for step in 0..12 {
            si.step();
            sc.step();
            for lane in [0usize, 17, 63] {
                assert_eq!(
                    si.get_word_lane(&word, lane),
                    sc.get_word_lane(&word, lane),
                    "step {step} lane {lane}"
                );
            }
        }
        // Mid-run reset must land both on the same state.
        si.reset();
        sc.reset();
        assert_eq!(si.get_word_lane(&word, 0), sc.get_word_lane(&word, 0));
    }

    #[test]
    fn compiled_port_map_observes_folded_and_dead_nets() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let unused = n.add_input("unused", 1)[0];
        let buf = n.fresh();
        n.cells.push(Cell::Buf { a, y: buf });
        let dead = n.and2(unused, a); // drives nothing
        n.add_output("y", vec![buf]);
        n.add_output("k1", vec![CONST1]);
        let mut s = Sim::from_plan(Arc::new(SimPlan::compiled(&n)));
        s.set(a, 0b1010);
        s.set(unused, !0u64); // feeds only dead logic: harmless
        s.eval();
        assert_eq!(s.get(buf) & 0xF, 0b1010, "folded output aliases its source");
        assert_eq!(s.get(CONST1), !0u64, "constant net still reads all-ones");
        assert_eq!(s.get(dead), 0, "eliminated net reads 0");
    }

    #[test]
    fn compile_default_toggle_selects_plan_kind() {
        assert!(compile_default(), "compiled is the default");
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        n.add_output("y", vec![a]);
        set_compile_default(false);
        let p = SimPlan::with_default_mode(&n);
        set_compile_default(true);
        assert!(!p.is_compiled());
        assert!(SimPlan::with_default_mode(&n).is_compiled());
    }

    #[test]
    fn shared_plan_sims_are_independent_and_equal() {
        // Two Sims over one plan behave exactly like two fresh Sims.
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let y = n.and2(a, b);
        n.add_output("y", vec![y]);
        let plan = Arc::new(SimPlan::new(&n));
        let mut s1 = Sim::from_plan(plan.clone());
        let mut s2 = Sim::from_plan(plan.clone());
        s1.set(a, 0b11);
        s1.set(b, 0b01);
        s2.set(a, 0b10);
        s2.set(b, 0b10);
        s1.eval();
        s2.eval();
        assert_eq!(s1.get(y) & 0b11, 0b01);
        assert_eq!(s2.get(y) & 0b11, 0b10);
        assert_eq!(plan.n_cells(), 1);
        assert_eq!(plan.n_dffs(), 0);
    }
}
