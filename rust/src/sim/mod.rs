//! Cycle-accurate gate-level simulator (VCS substitute).
//!
//! Executes the netlist IR directly — the same cells the Verilog emitter
//! prints — so simulated behaviour and emitted RTL cannot diverge.
//!
//! Performance: 2-valued simulation with 64 samples packed per machine
//! word (bit-parallel across *samples*, not bits), plus a levelized
//! (topologically ordered) compiled evaluation pass.  A full test-set
//! accuracy run of the largest circuit is a few million lane-parallel
//! gate evaluations.
//!
//! §Sharding: the levelization pass is factored into an immutable
//! [`SimPlan`] so an `n`-sample workload can be split into 64-lane blocks
//! dispatched across worker threads (see [`batch`]), each worker owning a
//! cheap [`Sim`] (two `u64` vectors) over the shared plan.  Every
//! primitive-cell update is a bitwise, per-lane operation, so a sample's
//! outputs depend only on its own lane — sharded and serial runs are
//! bit-identical by construction (enforced by `tests/sim_sharding.rs`).
//!
//! §Compiled plans: [`SimPlan::compiled`] lowers the levelized netlist
//! into a flat structure-of-arrays micro-op stream (one opcode byte per
//! surviving gate plus parallel `u32` operand arrays), after running the
//! netlist strength-reduction passes at plan-build time — constant
//! folding through `CONST0`/`CONST1`, buffer and double-inverter chain
//! collapsing, INV-into-producer fusion onto the complementary
//! NAND/NOR/XNOR opcodes, and dead-net elimination — and **renumbering
//! the surviving nets densely in topological order**, so `vals` holds
//! live nets only and each level's reads and writes stay cache-local.  A
//! compact `u32 → u32` port map translates external [`Sim::set`] /
//! [`Sim::get`] net ids, so testbenches drive compiled and interpreted
//! simulators identically.  The interpreted path is retained unchanged as
//! the reference oracle; `tests/sim_compiled.rs` enforces bit-identical
//! behaviour on every lane, including partial final blocks and reset
//! semantics.  Plans built lazily by the circuit wrappers compile by
//! default — `--no-compile-sim`, `sim.compile = false`, or
//! `PRINTED_MLP_NO_COMPILE_SIM=1` select the interpreted oracle instead
//! (see [`compile_default`]).
//!
//! §Super-lanes: a [`Sim`] holds `W` consecutive `u64` words per net
//! (`W ∈ {1, 2, 4, 8}`, runtime-selected — [`Sim::from_plan_wide`]), so
//! one pass simulates up to `W·64 = 512` samples and every micro-op
//! dispatch amortizes over the whole block; the per-word kernels are
//! monomorphized over `W` ([`u64; W]` loads/stores on contiguous memory),
//! which LLVM autovectorizes into SSE/AVX2/AVX-512 bitwise ops.  On top
//! of that, [`SimPlan::compiled`] sorts each topological level of the
//! micro-op stream into maximal same-opcode runs and `eval` executes each
//! run as one homogeneous tight loop (`run_binary(!(a & b))`, …) instead
//! of a per-op `match` — no opcode branch inside a run.  Reordering
//! within a level is sound because same-level ops never read each other's
//! outputs (a reader's level is strictly greater than its producer's),
//! and runs merging across adjacent levels stay sound because the array
//! order still respects dependencies.  `W = 1` keeps the exact oracle
//! geometry; every width is bit-identical per lane (`tests/sim_compiled.rs`
//! W-sweep + lane-isolation properties).  The process-wide default width
//! comes from [`lane_words_default`] — `sim.lanes`, `--sim-lanes`, or
//! `PRINTED_MLP_SIM_LANES`, auto-picked from the detected SIMD width when
//! unset.
//!
//! §Faults: [`Sim::set_faults`] lowers a [`fault::FaultList`] against the
//! plan and `eval`/`step` force the resulting per-net masks at the points
//! the [`fault`] module documents — stuck-at and seed-deterministic
//! transient corruption that stays bit-identical across widths, thread
//! counts, and the interpreted/compiled split.
//!
//! §Activity: [`Sim::set_activity`] turns on per-net toggle counters —
//! each micro-op (and each register commit) adds
//! `popcount((new ^ old) & mask)` over all `W` lane words to its output
//! net's counter, where `mask` zeroes the padded tail lanes of a partial
//! block ([`Sim::activity_begin_block`]).  Per-lane bitwise semantics
//! make the counts **bit-identical across `W ∈ {1,2,4,8}` and thread
//! counts** (per-shard [`Activity`] snapshots sum after the pool join)
//! and equal to a naive per-sample count — enforced by
//! `tests/activity_energy.rs`.  Counting happens *before* any scheduled
//! fault mask forces the net (see [`fault`]), so fault campaigns never
//! double-count forced transitions.  Off (the default) the hot loops pay
//! nothing; on, [`SimPlan::gate_activity`] resolves the counters into
//! per-gate [`GateActivity`] rows that `tech::energy_report` prices.
//! Process-wide default: [`profile_activity_default`]
//! (`sim.profile_activity` / `--profile-activity` /
//! `PRINTED_MLP_PROFILE_ACTIVITY`).

pub mod batch;
pub mod fault;
pub mod fuse;
pub mod testbench;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::netlist::{opt, Cell, NetId, Netlist, Port, CONST0, CONST1};

/// Process-wide default for whether lazily-built circuit plans (e.g.
/// [`crate::circuits::SeqCircuit::sim_plan`]) compile their netlist into
/// the micro-op stream.  On by default; the CLI's `--no-compile-sim`,
/// the `sim.compile` config key, and the `PRINTED_MLP_NO_COMPILE_SIM`
/// environment variable (any value but `0`) turn it off, forcing the
/// interpreted reference path everywhere.
static COMPILE_DEFAULT: AtomicBool = AtomicBool::new(true);

/// Whether circuit plans compile by default (see [`set_compile_default`];
/// `PRINTED_MLP_NO_COMPILE_SIM` overrides the process-wide flag).
pub fn compile_default() -> bool {
    match std::env::var_os("PRINTED_MLP_NO_COMPILE_SIM") {
        Some(v) if !v.is_empty() && v != "0" => false,
        _ => COMPILE_DEFAULT.load(Ordering::Relaxed),
    }
}

/// Set the process-wide compile default (the `--no-compile-sim` escape
/// hatch).  Affects plans built *after* the call; circuits cache their
/// plan on first use.
pub fn set_compile_default(on: bool) {
    COMPILE_DEFAULT.store(on, Ordering::Relaxed);
}

/// Valid super-lane widths: `u64` words per net (`W`), i.e. `W·64`
/// samples per simulator pass.
pub const LANE_WORD_CHOICES: [usize; 4] = [1, 2, 4, 8];

/// Process-wide default super-lane width (0 = auto-pick from the
/// detected SIMD width).  Set by `sim.lanes` / `--sim-lanes`; read by
/// every consumer that does not pass an explicit width.
static LANE_WORDS_DEFAULT: AtomicUsize = AtomicUsize::new(0);

/// Super-lane width matched to the host's widest SIMD unit: 8 words
/// (512 bits) with AVX-512, 4 with AVX2, else 2 — two words still
/// amortize the per-op dispatch over 128 samples on any 128-bit unit.
pub fn auto_lane_words() -> usize {
    detected_simd_words()
}

#[cfg(target_arch = "x86_64")]
fn detected_simd_words() -> usize {
    if is_x86_feature_detected!("avx512f") {
        8
    } else if is_x86_feature_detected!("avx2") {
        4
    } else {
        2
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detected_simd_words() -> usize {
    2
}

/// Is `w` a valid `sim.lanes`-style knob value?  `0` means auto; the
/// config parser, the CLI, and [`set_lane_words_default`] all share this
/// membership check so the valid set can never drift between them.
pub fn valid_lane_words(w: usize) -> bool {
    w == 0 || LANE_WORD_CHOICES.contains(&w)
}

/// The `PRINTED_MLP_SIM_LANES` environment override, when set to a valid
/// width.  It beats every other knob — the process-wide default here and
/// the explicit `serve`/evaluator configuration alike — so one exported
/// variable pins the width across subcommands.
pub fn lane_words_env() -> Option<usize> {
    let v = std::env::var_os("PRINTED_MLP_SIM_LANES")?;
    match v.to_string_lossy().parse::<usize>() {
        Ok(n) if n != 0 && LANE_WORD_CHOICES.contains(&n) => Some(n),
        _ => None,
    }
}

/// The resolved process-wide super-lane width (`sim.lanes` config key /
/// `--sim-lanes`; [`lane_words_env`] overrides the flag, and `0`/unset
/// auto-picks via [`auto_lane_words`]).  Always one of
/// [`LANE_WORD_CHOICES`].
pub fn lane_words_default() -> usize {
    if let Some(n) = lane_words_env() {
        return n;
    }
    match LANE_WORDS_DEFAULT.load(Ordering::Relaxed) {
        0 => auto_lane_words(),
        n => n,
    }
}

/// Set the process-wide super-lane width (`0` = auto).  Panics on a
/// width outside [`LANE_WORD_CHOICES`] — config/CLI validate first via
/// [`valid_lane_words`].
pub fn set_lane_words_default(w: usize) {
    assert!(
        valid_lane_words(w),
        "sim lanes must be 0 (auto) or one of {LANE_WORD_CHOICES:?}, got {w}"
    );
    LANE_WORDS_DEFAULT.store(w, Ordering::Relaxed);
}

/// Process-wide default for activity profiling (per-net toggle counters,
/// §Activity).  Off by default — the clean hot path must pay nothing;
/// `--profile-activity`, the `sim.profile_activity` config key, or the
/// `PRINTED_MLP_PROFILE_ACTIVITY` environment variable (any value but
/// `0`) turn it on.
static PROFILE_ACTIVITY_DEFAULT: AtomicBool = AtomicBool::new(false);

/// Whether activity profiling is on by default (see
/// [`set_profile_activity_default`]; `PRINTED_MLP_PROFILE_ACTIVITY`
/// overrides the process-wide flag, mirroring the other sim knobs).
pub fn profile_activity_default() -> bool {
    match std::env::var_os("PRINTED_MLP_PROFILE_ACTIVITY") {
        Some(v) if !v.is_empty() && v != "0" => true,
        _ => PROFILE_ACTIVITY_DEFAULT.load(Ordering::Relaxed),
    }
}

/// Set the process-wide activity-profiling default (the
/// `--profile-activity` knob).  Affects runs started *after* the call.
pub fn set_profile_activity_default(on: bool) {
    PROFILE_ACTIVITY_DEFAULT.store(on, Ordering::Relaxed);
}

/// Process-wide default for activity-gated (event-driven) evaluation
/// (§Gating).  Off by default — it is a pure optimisation and the
/// differential suite pins it bit-identical either way; `--gate-activity`,
/// the `sim.gate_on_activity` config key, or the
/// `PRINTED_MLP_GATE_ACTIVITY` environment variable (any value but `0`)
/// turn it on.
static GATE_ACTIVITY_DEFAULT: AtomicBool = AtomicBool::new(false);

/// Whether activity-gated evaluation is on by default (see
/// [`set_gate_on_activity_default`]; `PRINTED_MLP_GATE_ACTIVITY`
/// overrides the process-wide flag, mirroring the other sim knobs).
pub fn gate_on_activity_default() -> bool {
    match std::env::var_os("PRINTED_MLP_GATE_ACTIVITY") {
        Some(v) if !v.is_empty() && v != "0" => true,
        _ => GATE_ACTIVITY_DEFAULT.load(Ordering::Relaxed),
    }
}

/// Set the process-wide activity-gating default (the `--gate-activity`
/// knob).  Simulators built *after* the call pick it up —
/// [`Sim::from_plan_wide`] auto-enables gating on compiled plans, so
/// serve and pipeline paths need no signature changes.
pub fn set_gate_on_activity_default(on: bool) {
    GATE_ACTIVITY_DEFAULT.store(on, Ordering::Relaxed);
}

// Micro-op opcodes: one byte per surviving gate, dispatched over
// contiguous arrays (branch-predictable, cache-dense — no enum payload
// loads from a scattered `Vec<Cell>`).
const OP_INV: u8 = 0;
const OP_BUF: u8 = 1;
const OP_NAND: u8 = 2;
const OP_NOR: u8 = 3;
const OP_AND: u8 = 4;
const OP_OR: u8 = 5;
const OP_XOR: u8 = 6;
const OP_XNOR: u8 = 7;
const OP_MUX: u8 = 8;

/// Dirty-block granularity for activity gating (§Gating): value slots
/// are grouped 16 to a block (`slot >> 4`), one dirty bit per block.
/// Coarser blocks false-share — e.g. a free-running cycle counter
/// renumbered next to settled accumulator state would keep its whole
/// block permanently dirty — while finer blocks inflate the per-run gate
/// lists; 16 slots keeps both small at the paper's circuit sizes.
const GATE_BLOCK_SHIFT: u32 = 4;

/// Mark the dirty bit of `slot`'s block in a gating bitmap.
#[inline(always)]
pub(crate) fn mark_dirty(dirty: &mut [u64], slot: u32) {
    let b = slot >> GATE_BLOCK_SHIFT;
    dirty[(b >> 6) as usize] |= 1u64 << (b & 63);
}

/// Per-run input-block gate lists for activity-gated evaluation
/// (§Gating): run `ri` may be skipped when it is not pinned hot and none
/// of `blocks[off[ri]..off[ri+1]]` is dirty.  Built once per run table —
/// [`CompiledPlan::build`] builds the clean table's lists, and the
/// fault-split table builds its own (run re-splitting composes because
/// the lists are a pure function of whichever run table executes),
/// pinning runs with scheduled transient flips hot so a flip mask is
/// never XORed on top of a stale store.
#[derive(Clone, Debug)]
pub(crate) struct RunGates {
    /// CSR offsets into `blocks`, one span per run (`runs.len() + 1`).
    off: Vec<u32>,
    /// Sorted, deduplicated input block ids per run.  Operand slots that
    /// an opcode does not read (`src_b` of a unary op, `src_c` of
    /// anything but a mux) are excluded — they are parked on constant
    /// slot 0, whose block also holds real low-numbered inputs, and
    /// including them would false-wake every unary run.
    blocks: Vec<u32>,
    /// Runs that must execute every eval regardless of dirt.
    hot: Vec<bool>,
}

impl RunGates {
    pub(crate) fn build(
        runs: &[(u8, u32, u32)],
        src_a: &[u32],
        src_b: &[u32],
        src_c: &[u32],
    ) -> RunGates {
        let mut off = Vec::with_capacity(runs.len() + 1);
        let mut blocks = Vec::new();
        let mut scratch: Vec<u32> = Vec::new();
        off.push(0);
        for &(op, start, len) in runs {
            let r = start as usize..start as usize + len as usize;
            scratch.clear();
            scratch.extend(src_a[r.clone()].iter().map(|&s| s >> GATE_BLOCK_SHIFT));
            if op >= OP_NAND {
                scratch.extend(src_b[r.clone()].iter().map(|&s| s >> GATE_BLOCK_SHIFT));
            }
            if op == OP_MUX {
                scratch.extend(src_c[r].iter().map(|&s| s >> GATE_BLOCK_SHIFT));
            }
            scratch.sort_unstable();
            scratch.dedup();
            blocks.extend_from_slice(&scratch);
            off.push(blocks.len() as u32);
        }
        RunGates {
            off,
            blocks,
            hot: vec![false; runs.len()],
        }
    }

    /// Pin one run hot (always executed).
    pub(crate) fn pin_hot(&mut self, ri: usize) {
        self.hot[ri] = true;
    }

    /// Must run `ri` execute this eval?
    #[inline(always)]
    pub(crate) fn is_hot(&self, ri: usize, dirty: &[u64]) -> bool {
        self.hot[ri]
            || self.blocks[self.off[ri] as usize..self.off[ri + 1] as usize]
                .iter()
                .any(|&b| dirty[(b >> 6) as usize] & (1u64 << (b & 63)) != 0)
    }
}

/// Executed/skipped run counters harvested from one gated simulator
/// (§Gating).  Skips are the win: a skipped run pays one gate-list probe
/// instead of its whole lane-block loop.
#[derive(Clone, Copy, Debug, Default)]
pub struct GateStats {
    /// Homogeneous opcode runs executed.
    pub executed: u64,
    /// Runs skipped because every input block was clean.
    pub skipped: u64,
}

impl GateStats {
    /// Accumulate another worker's counters.
    pub fn merge(&mut self, other: &GateStats) {
        self.executed += other.executed;
        self.skipped += other.skipped;
    }

    /// Fraction of runs skipped (`0.0` when nothing ran).
    pub fn skip_rate(&self) -> f64 {
        let total = self.executed + self.skipped;
        if total == 0 {
            0.0
        } else {
            self.skipped as f64 / total as f64
        }
    }
}

/// A netlist lowered to a flat structure-of-arrays micro-op stream with
/// densely renumbered nets — the compiled form [`Sim::eval`] executes.
///
/// Built once per netlist by [`SimPlan::compiled`] and shared read-only
/// by every sharded worker.  Compilation clones the netlist and runs
/// [`opt::fold_collapse`] → [`opt::fuse_inversions`] → [`opt::dce`], so
/// the stream never contains a gate the strength reduction could remove;
/// the property suite checks compilation never *increases* gate count.
pub struct CompiledPlan {
    /// One opcode byte per micro-op, in topological order.
    ops: Vec<u8>,
    /// First operand (dense slot) per micro-op.
    src_a: Vec<u32>,
    /// Second operand; slot 0 (constant-0) for unary ops.
    src_b: Vec<u32>,
    /// Third operand (mux select); slot 0 for non-mux ops.
    src_c: Vec<u32>,
    /// Destination slot per micro-op.
    dst: Vec<u32>,
    /// Maximal same-opcode spans of the (level-sorted) stream:
    /// `(opcode, start, len)` — `eval` runs each span as one homogeneous
    /// tight loop with no per-op opcode branch.  Sorting ops by
    /// `(level, opcode)` is sound because same-level ops are independent,
    /// and a span merging across adjacent levels stays sound because the
    /// array order still respects every producer→reader dependency.
    runs: Vec<(u8, u32, u32)>,
    /// Topological level per micro-op (same permutation as `ops`), kept
    /// for per-level activity attribution ([`SimPlan::gate_activity`]).
    op_level: Vec<u32>,
    // DFF state, struct-of-arrays (dense slots).
    dff_d: Vec<u32>,
    dff_q: Vec<u32>,
    dff_en: Vec<u32>,
    dff_rst: Vec<u32>,
    /// Reset value broadcast across all 64 lanes (`!0` or `0`).
    dff_rstval: Vec<u64>,
    /// Dense value-vector length (live nets only; slots 0/1 = constants).
    n_dense: usize,
    /// External net id → dense slot for reads (`u32::MAX` = eliminated
    /// net, reads 0).  Folded nets translate to their surviving alias,
    /// so port reads observe identical values on the compiled and
    /// interpreted paths.
    port_map: Vec<u32>,
    /// External net id → dense slot for writes: like `port_map` but with
    /// NO alias following — driving a net the plan folded away is a
    /// silent no-op (on the oracle the next `eval` would overwrite such
    /// a write anyway; following the alias could clobber a live input).
    write_map: Vec<u32>,
    /// Per-run input-block gate lists for activity-gated evaluation
    /// (§Gating).
    run_gates: RunGates,
}

impl CompiledPlan {
    fn build(src: &Netlist) -> CompiledPlan {
        let ext_nets = src.n_nets();
        let mut net = src.clone();
        // Plan-time strength reduction (netlist-level passes shared with
        // `opt::optimize`), then sweep anything unobservable.
        let repl = opt::fold_collapse(&mut net);
        opt::fuse_inversions(&mut net);
        // Registers are externally observable state (`Sim::get` on a q
        // net needs no output port), so root every register through a
        // synthetic port for the dead-logic sweep — plan compilation
        // must never silence state the interpreted oracle keeps.
        let state_roots: Vec<NetId> = net
            .cells
            .iter()
            .filter(|c| c.is_seq())
            .map(|c| c.output())
            .collect();
        net.outputs.push(Port {
            name: "__state_roots".into(),
            bits: state_roots,
        });
        opt::dce(&mut net);
        net.outputs.pop();

        // Dense renumbering: constants, then external sources (input
        // ports), then register state, then combinational outputs in
        // topological order — the order eval writes them.
        let order = net.topo_order();
        let mut dense = vec![u32::MAX; ext_nets];
        dense[CONST0 as usize] = 0;
        dense[CONST1 as usize] = 1;
        let mut next = 2u32;
        {
            let mut assign = |id: NetId| {
                let slot = &mut dense[id as usize];
                if *slot == u32::MAX {
                    *slot = next;
                    next += 1;
                }
            };
            for port in &net.inputs {
                for &b in &port.bits {
                    assign(b);
                }
            }
            for c in &net.cells {
                if c.is_seq() {
                    assign(c.output());
                }
            }
            for &ci in &order {
                assign(net.cells[ci].output());
            }
            // Safety net: a surviving cell may read an undriven non-port
            // net (legal; reads as all-zero) — give it a slot too.
            for c in &net.cells {
                c.for_each_input(&mut assign);
            }
        }

        let d = |id: NetId| dense[id as usize];
        let n_ops = order.len();
        let mut ops = Vec::with_capacity(n_ops);
        let mut src_a = Vec::with_capacity(n_ops);
        let mut src_b = Vec::with_capacity(n_ops);
        let mut src_c = Vec::with_capacity(n_ops);
        let mut dst = Vec::with_capacity(n_ops);
        for &ci in &order {
            let c = net.cells[ci];
            let (op, a, b, sel) = match c {
                Cell::Inv { a, .. } => (OP_INV, a, CONST0, CONST0),
                Cell::Buf { a, .. } => (OP_BUF, a, CONST0, CONST0),
                Cell::Nand2 { a, b, .. } => (OP_NAND, a, b, CONST0),
                Cell::Nor2 { a, b, .. } => (OP_NOR, a, b, CONST0),
                Cell::And2 { a, b, .. } => (OP_AND, a, b, CONST0),
                Cell::Or2 { a, b, .. } => (OP_OR, a, b, CONST0),
                Cell::Xor2 { a, b, .. } => (OP_XOR, a, b, CONST0),
                Cell::Xnor2 { a, b, .. } => (OP_XNOR, a, b, CONST0),
                Cell::Mux2 { a, b, sel, .. } => (OP_MUX, a, b, sel),
                Cell::Dff { .. } => unreachable!("DFF in comb order"),
            };
            ops.push(op);
            src_a.push(d(a));
            src_b.push(d(b));
            src_c.push(d(sel));
            dst.push(d(c.output()));
        }

        let mut dff_d = Vec::new();
        let mut dff_q = Vec::new();
        let mut dff_en = Vec::new();
        let mut dff_rst = Vec::new();
        let mut dff_rstval = Vec::new();
        for c in &net.cells {
            if let Cell::Dff {
                d: dd,
                q,
                en,
                rst,
                rstval,
            } = *c
            {
                dff_d.push(d(dd));
                dff_q.push(d(q));
                dff_en.push(d(en));
                dff_rst.push(d(rst));
                dff_rstval.push(if rstval { !0u64 } else { 0u64 });
            }
        }

        // Output-port bits whose driving cell folded away must NOT alias
        // their surviving source directly: the interpreted oracle updates
        // comb nets only during `eval`, so a direct alias of a register
        // output would observe the *post-commit* value after `step` (and
        // an alias of an input would observe a driven value before any
        // `eval`).  Materialize one BUF micro-op per such bit instead —
        // ports are few — giving the observed net its own slot that
        // updates exactly when the oracle's comb net does.  Each BUF
        // replaces at least the one folded cell that drove the bit, so
        // compilation still never increases the op count.
        for port in &src.outputs {
            for &o in &port.bits {
                if dense[o as usize] != u32::MAX {
                    continue;
                }
                let t = repl[o as usize];
                let slot = next;
                next += 1;
                dense[o as usize] = slot;
                if t != o && dense[t as usize] != u32::MAX {
                    ops.push(OP_BUF);
                    src_a.push(dense[t as usize]);
                    src_b.push(0);
                    src_c.push(0);
                    dst.push(slot);
                }
                // else: an undriven port bit — a bare slot (reads 0,
                // externally drivable), matching the interpreted vals.
            }
        }

        // External translation: live nets (now including every port bit)
        // map straight to their dense slot; for reads, other folded nets
        // additionally map to their surviving alias (post-`eval`
        // observation only — the external contract covers ports and
        // register outputs); the rest are dead.
        let write_map = dense.clone();
        let mut port_map = dense.clone();
        for (o, slot) in port_map.iter_mut().enumerate() {
            if *slot == u32::MAX {
                let t = repl[o] as usize;
                if t != o {
                    *slot = dense[t];
                }
            }
        }

        // Opcode-run scheduling: compute each op's level (longest path
        // from an externally-written slot — inputs, registers, constants
        // and undriven nets sit at level 0), stable-sort the stream by
        // (level, opcode), and record maximal same-opcode spans.  The
        // stream is in dependency order before the sort (topo order plus
        // trailing port BUFs that read only already-assigned slots), so
        // levels are well-defined in one forward pass; the sort keeps
        // every producer before its readers (reader level > producer
        // level), which is all `eval`'s sequential span walk needs.
        let n_stream = ops.len();
        let mut slot_level = vec![0u32; next as usize];
        let mut op_level = vec![0u32; n_stream];
        for i in 0..n_stream {
            let lvl = 1 + slot_level[src_a[i] as usize]
                .max(slot_level[src_b[i] as usize])
                .max(slot_level[src_c[i] as usize]);
            op_level[i] = lvl;
            slot_level[dst[i] as usize] = lvl;
        }
        let mut idx: Vec<u32> = (0..n_stream as u32).collect();
        idx.sort_by_key(|&i| (op_level[i as usize], ops[i as usize]));
        let permute_u8 = |src: &[u8]| -> Vec<u8> { idx.iter().map(|&i| src[i as usize]).collect() };
        let permute = |src: &[u32]| -> Vec<u32> { idx.iter().map(|&i| src[i as usize]).collect() };
        let ops = permute_u8(&ops);
        let src_a = permute(&src_a);
        let src_b = permute(&src_b);
        let src_c = permute(&src_c);
        let dst = permute(&dst);
        let op_level = permute(&op_level);
        let mut runs: Vec<(u8, u32, u32)> = Vec::new();
        for (i, &op) in ops.iter().enumerate() {
            match runs.last_mut() {
                Some((last, _, len)) if *last == op => *len += 1,
                _ => runs.push((op, i as u32, 1)),
            }
        }
        let run_gates = RunGates::build(&runs, &src_a, &src_b, &src_c);

        CompiledPlan {
            ops,
            src_a,
            src_b,
            src_c,
            dst,
            runs,
            op_level,
            dff_d,
            dff_q,
            dff_en,
            dff_rst,
            dff_rstval,
            n_dense: next as usize,
            port_map,
            write_map,
            run_gates,
        }
    }

    /// Number of combinational micro-ops in the stream.
    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    /// Number of registers in the compiled state (every source register
    /// is kept — state is externally observable via [`Sim::get`]).
    pub fn n_state(&self) -> usize {
        self.dff_q.len()
    }

    /// Dense value-vector length (live nets incl. the two constants).
    pub fn n_dense_nets(&self) -> usize {
        self.n_dense
    }

    /// Number of homogeneous opcode runs the stream executes as — at
    /// most [`CompiledPlan::n_ops`]; the gap between the two is how much
    /// per-op dispatch the run scheduler eliminated.
    pub fn n_runs(&self) -> usize {
        self.runs.len()
    }
}

/// Cell-library name for a micro-op opcode (matches
/// [`crate::netlist::Cell::type_name`], which is what `tech::cell_spec`
/// prices).
fn opcode_name(op: u8) -> &'static str {
    match op {
        OP_INV => "INV",
        OP_BUF => "BUF",
        OP_NAND => "NAND2",
        OP_NOR => "NOR2",
        OP_AND => "AND2",
        OP_OR => "OR2",
        OP_XOR => "XOR2",
        OP_XNOR => "XNOR2",
        _ => "MUX2",
    }
}

/// Per-net toggle counters harvested from one simulator (§Activity):
/// `counts[slot]` is the number of masked lane bits whose value changed
/// when the slot's producer stored it (or, for register state, when the
/// commit overwrote it).  Snapshots from sharded workers [`Activity::
/// merge`] into the run total — addition is exactly what per-lane
/// independence guarantees is order-insensitive.
#[derive(Clone, Debug, Default)]
pub struct Activity {
    counts: Vec<u64>,
}

impl Activity {
    /// No counters collected (profiling off, or an empty workload).
    pub fn is_empty(&self) -> bool {
        self.counts.is_empty()
    }

    /// Sum of every net's toggle count.
    pub fn total_toggles(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Accumulate another snapshot (per-slot sum).  Merging with an
    /// empty snapshot — either side — is the identity.
    pub fn merge(&mut self, other: &Activity) {
        if other.counts.is_empty() {
            return;
        }
        if self.counts.is_empty() {
            self.counts = other.counts.clone();
            return;
        }
        assert_eq!(
            self.counts.len(),
            other.counts.len(),
            "activity snapshots from different plans cannot merge"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }
}

/// One gate's switching activity, resolved against the plan: the cell
/// kind `tech` prices, its topological level (registers report level 0),
/// and the accumulated toggle count of its output net.
#[derive(Clone, Debug)]
pub struct GateActivity {
    pub kind: &'static str,
    pub level: u32,
    pub toggles: u64,
}

/// Immutable levelized evaluation plan for one netlist, shareable across
/// simulator instances and threads.
///
/// Building a plan runs the Kahn topological sort and DFF extraction once;
/// every [`Sim`] created from the same `Arc<SimPlan>` then reads the cell
/// array and ordering in place.  That is what makes sharded simulation
/// cheap: N workers cost one plan plus N small mutable state vectors, not
/// N topo sorts and cell-array clones.
pub struct SimPlan {
    cells: Vec<Cell>,
    /// Combinational cell indices in topological order.
    order: Vec<u32>,
    /// DFF cell indices.
    dffs: Vec<u32>,
    n_nets: usize,
    /// Lowered micro-op stream (None = interpreted reference path).
    compiled: Option<CompiledPlan>,
}

impl SimPlan {
    /// Interpreted plan — the reference oracle the compiled path is
    /// differentially tested against.
    pub fn new(n: &Netlist) -> SimPlan {
        let order = n.topo_order().into_iter().map(|i| i as u32).collect();
        let dffs = n
            .cells
            .iter()
            .enumerate()
            .filter(|(_, c)| c.is_seq())
            .map(|(i, _)| i as u32)
            .collect::<Vec<_>>();
        SimPlan {
            cells: n.cells.clone(),
            order,
            dffs,
            n_nets: n.n_nets(),
            compiled: None,
        }
    }

    /// Compiled plan: interpreted metadata (kept as the oracle and for
    /// [`SimPlan::n_cells`]-style reporting) plus the strength-reduced,
    /// densely renumbered micro-op stream that [`Sim::eval`] executes.
    pub fn compiled(n: &Netlist) -> SimPlan {
        let mut plan = SimPlan::new(n);
        plan.compiled = Some(CompiledPlan::build(n));
        plan
    }

    /// [`SimPlan::compiled`] or [`SimPlan::new`] per the process-wide
    /// [`compile_default`] — what the circuit wrappers' lazy plans use.
    pub fn with_default_mode(n: &Netlist) -> SimPlan {
        if compile_default() {
            SimPlan::compiled(n)
        } else {
            SimPlan::new(n)
        }
    }

    /// Source-netlist cell count (the interpreted view, independent of
    /// how many micro-ops strength reduction left).
    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }

    pub fn n_dffs(&self) -> usize {
        self.dffs.len()
    }

    /// Source-netlist net count (external ids run to this bound).
    pub fn n_nets(&self) -> usize {
        self.n_nets
    }

    pub fn is_compiled(&self) -> bool {
        self.compiled.is_some()
    }

    /// The lowered stream, when this plan was built with
    /// [`SimPlan::compiled`].
    pub fn compiled_plan(&self) -> Option<&CompiledPlan> {
        self.compiled.as_ref()
    }

    /// Map an external (source-netlist) net id to this plan's value slot
    /// for reads (aliases follow their survivor); `u32::MAX` when plan
    /// compilation eliminated the net.
    #[inline]
    fn read_slot(&self, net: NetId) -> u32 {
        match &self.compiled {
            Some(cp) => cp.port_map[net as usize],
            None => net,
        }
    }

    /// Map an external net id to this plan's value slot for writes —
    /// aliases are NOT followed (driving a folded net is a no-op), so an
    /// external `set` can never clobber a live survivor.
    #[inline]
    fn write_slot(&self, net: NetId) -> u32 {
        match &self.compiled {
            Some(cp) => cp.write_map[net as usize],
            None => net,
        }
    }

    /// Can a fault on `net` be expressed against this plan?  True when
    /// the net owns a writable value slot of its own — constants, nets
    /// plan compilation eliminated, and nets folded onto an alias are
    /// not faultable (forcing an alias's survivor would corrupt a
    /// *different* source net than the one named).
    pub fn faultable(&self, net: NetId) -> bool {
        if net as usize >= self.n_nets {
            return false;
        }
        let slot = self.write_slot(net);
        slot != u32::MAX && slot >= 2
    }

    /// Resolve harvested toggle counters into per-gate rows: one
    /// [`GateActivity`] per micro-op (compiled) or combinational cell
    /// (interpreted), plus one per register (kind `"DFF"`, level 0,
    /// counting commit transitions of its q net).  Returns an empty list
    /// for an empty snapshot.  Counts are only meaningful against the
    /// plan that produced them — compiled and interpreted plans
    /// legitimately disagree on *internal* nets (inversion fusing), so
    /// keep a differential within one plan form.
    pub fn gate_activity(&self, act: &Activity) -> Vec<GateActivity> {
        if act.counts.is_empty() {
            return Vec::new();
        }
        let mut out = Vec::new();
        match &self.compiled {
            Some(cp) => {
                debug_assert_eq!(act.counts.len(), cp.n_dense);
                for i in 0..cp.ops.len() {
                    out.push(GateActivity {
                        kind: opcode_name(cp.ops[i]),
                        level: cp.op_level[i],
                        toggles: act.counts[cp.dst[i] as usize],
                    });
                }
                for &q in &cp.dff_q {
                    out.push(GateActivity {
                        kind: "DFF",
                        level: 0,
                        toggles: act.counts[q as usize],
                    });
                }
            }
            None => {
                debug_assert_eq!(act.counts.len(), self.n_nets);
                let mut level = vec![0u32; self.n_nets];
                for &ci in &self.order {
                    let c = &self.cells[ci as usize];
                    let mut lvl = 0u32;
                    c.for_each_input(&mut |id: NetId| {
                        lvl = lvl.max(level[id as usize]);
                    });
                    let lvl = lvl + 1;
                    level[c.output() as usize] = lvl;
                    out.push(GateActivity {
                        kind: c.type_name(),
                        level: lvl,
                        toggles: act.counts[c.output() as usize],
                    });
                }
                for &ci in &self.dffs {
                    let q = self.cells[ci as usize].output();
                    out.push(GateActivity {
                        kind: "DFF",
                        level: 0,
                        toggles: act.counts[q as usize],
                    });
                }
            }
        }
        out
    }
}

/// Load one net's `[u64; W]` super-lane block from the slot-major value
/// vector (slot `s` owns words `s*W .. s*W+W`).
#[inline(always)]
fn load<const W: usize>(v: &[u64], slot: u32) -> [u64; W] {
    let base = slot as usize * W;
    let mut out = [0u64; W];
    out.copy_from_slice(&v[base..base + W]);
    out
}

/// Store one net's `[u64; W]` super-lane block.
#[inline(always)]
fn store<const W: usize>(v: &mut [u64], slot: u32, val: [u64; W]) {
    let base = slot as usize * W;
    v[base..base + W].copy_from_slice(&val);
}

/// Homogeneous unary-op run: `dst[i] = f(a[i])` over whole lane blocks.
/// `W` is a compile-time constant, so the per-word loop unrolls and
/// autovectorizes; there is no opcode branch anywhere in the loop.
#[inline(always)]
fn run_unary<const W: usize>(v: &mut [u64], a: &[u32], d: &[u32], f: impl Fn(u64) -> u64) {
    for (&ai, &di) in a.iter().zip(d) {
        let va = load::<W>(v, ai);
        let mut out = [0u64; W];
        for (o, x) in out.iter_mut().zip(va.iter()) {
            *o = f(*x);
        }
        store::<W>(v, di, out);
    }
}

/// Homogeneous binary-op run: `dst[i] = f(a[i], b[i])`.
#[inline(always)]
fn run_binary<const W: usize>(
    v: &mut [u64],
    a: &[u32],
    b: &[u32],
    d: &[u32],
    f: impl Fn(u64, u64) -> u64,
) {
    for ((&ai, &bi), &di) in a.iter().zip(b).zip(d) {
        let va = load::<W>(v, ai);
        let vb = load::<W>(v, bi);
        let mut out = [0u64; W];
        for (j, o) in out.iter_mut().enumerate() {
            *o = f(va[j], vb[j]);
        }
        store::<W>(v, di, out);
    }
}

/// Homogeneous mux run: `dst[i] = (a[i] & !sel[i]) | (b[i] & sel[i])`.
#[inline(always)]
fn run_mux<const W: usize>(v: &mut [u64], a: &[u32], b: &[u32], c: &[u32], d: &[u32]) {
    for (((&ai, &bi), &si), &di) in a.iter().zip(b).zip(c).zip(d) {
        let va = load::<W>(v, ai);
        let vb = load::<W>(v, bi);
        let vs = load::<W>(v, si);
        let mut out = [0u64; W];
        for (j, o) in out.iter_mut().enumerate() {
            *o = (va[j] & !vs[j]) | (vb[j] & vs[j]);
        }
        store::<W>(v, di, out);
    }
}

/// Masked toggle popcount between a net's old and new lane blocks:
/// padding lanes of a partial tail block contribute nothing, so counts
/// are identical at every super-lane width and block split (§Activity).
#[inline(always)]
fn count_toggles<const W: usize>(old: &[u64; W], new: &[u64; W], mask: &[u64]) -> u64 {
    let mut t = 0u64;
    for j in 0..W {
        t += ((old[j] ^ new[j]) & mask[j]).count_ones() as u64;
    }
    t
}

/// [`run_unary`] plus store-time toggle accumulation into
/// `counts[dst]`.  The old value is loaded *before* the store and any
/// fault mask is applied strictly after the run — forced transitions are
/// never counted.
#[inline(always)]
fn run_unary_counted<const W: usize>(
    v: &mut [u64],
    a: &[u32],
    d: &[u32],
    counts: &mut [u64],
    mask: &[u64],
    f: impl Fn(u64) -> u64,
) {
    for (&ai, &di) in a.iter().zip(d) {
        let va = load::<W>(v, ai);
        let old = load::<W>(v, di);
        let mut out = [0u64; W];
        for (o, x) in out.iter_mut().zip(va.iter()) {
            *o = f(*x);
        }
        counts[di as usize] += count_toggles::<W>(&old, &out, mask);
        store::<W>(v, di, out);
    }
}

/// [`run_binary`] plus store-time toggle accumulation.
#[inline(always)]
fn run_binary_counted<const W: usize>(
    v: &mut [u64],
    a: &[u32],
    b: &[u32],
    d: &[u32],
    counts: &mut [u64],
    mask: &[u64],
    f: impl Fn(u64, u64) -> u64,
) {
    for ((&ai, &bi), &di) in a.iter().zip(b).zip(d) {
        let va = load::<W>(v, ai);
        let vb = load::<W>(v, bi);
        let old = load::<W>(v, di);
        let mut out = [0u64; W];
        for (j, o) in out.iter_mut().enumerate() {
            *o = f(va[j], vb[j]);
        }
        counts[di as usize] += count_toggles::<W>(&old, &out, mask);
        store::<W>(v, di, out);
    }
}

/// [`run_mux`] plus store-time toggle accumulation.
#[inline(always)]
fn run_mux_counted<const W: usize>(
    v: &mut [u64],
    a: &[u32],
    b: &[u32],
    c: &[u32],
    d: &[u32],
    counts: &mut [u64],
    mask: &[u64],
) {
    for (((&ai, &bi), &si), &di) in a.iter().zip(b).zip(c).zip(d) {
        let va = load::<W>(v, ai);
        let vb = load::<W>(v, bi);
        let vs = load::<W>(v, si);
        let old = load::<W>(v, di);
        let mut out = [0u64; W];
        for (j, o) in out.iter_mut().enumerate() {
            *o = (va[j] & !vs[j]) | (vb[j] & vs[j]);
        }
        counts[di as usize] += count_toggles::<W>(&old, &out, mask);
        store::<W>(v, di, out);
    }
}

/// Dispatch one homogeneous opcode span through the clean kernels —
/// shared by the compiled run walk and (one op at a time) the
/// interpreted cell walk.
#[inline(always)]
fn exec_run<const W: usize>(v: &mut [u64], op: u8, a: &[u32], b: &[u32], c: &[u32], d: &[u32]) {
    match op {
        OP_INV => run_unary::<W>(v, a, d, |x| !x),
        OP_BUF => run_unary::<W>(v, a, d, |x| x),
        OP_NAND => run_binary::<W>(v, a, b, d, |x, y| !(x & y)),
        OP_NOR => run_binary::<W>(v, a, b, d, |x, y| !(x | y)),
        OP_AND => run_binary::<W>(v, a, b, d, |x, y| x & y),
        OP_OR => run_binary::<W>(v, a, b, d, |x, y| x | y),
        OP_XOR => run_binary::<W>(v, a, b, d, |x, y| x ^ y),
        OP_XNOR => run_binary::<W>(v, a, b, d, |x, y| !(x ^ y)),
        _ => {
            debug_assert_eq!(op, OP_MUX);
            run_mux::<W>(v, a, b, c, d);
        }
    }
}

/// [`exec_run`] through the counting kernels — identical values, plus
/// toggle accumulation (the branch between the two is taken once per
/// run, so profiling off costs the hot loops nothing).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn exec_run_counted<const W: usize>(
    v: &mut [u64],
    op: u8,
    a: &[u32],
    b: &[u32],
    c: &[u32],
    d: &[u32],
    counts: &mut [u64],
    mask: &[u64],
) {
    match op {
        OP_INV => run_unary_counted::<W>(v, a, d, counts, mask, |x| !x),
        OP_BUF => run_unary_counted::<W>(v, a, d, counts, mask, |x| x),
        OP_NAND => run_binary_counted::<W>(v, a, b, d, counts, mask, |x, y| !(x & y)),
        OP_NOR => run_binary_counted::<W>(v, a, b, d, counts, mask, |x, y| !(x | y)),
        OP_AND => run_binary_counted::<W>(v, a, b, d, counts, mask, |x, y| x & y),
        OP_OR => run_binary_counted::<W>(v, a, b, d, counts, mask, |x, y| x | y),
        OP_XOR => run_binary_counted::<W>(v, a, b, d, counts, mask, |x, y| x ^ y),
        OP_XNOR => run_binary_counted::<W>(v, a, b, d, counts, mask, |x, y| !(x ^ y)),
        _ => {
            debug_assert_eq!(op, OP_MUX);
            run_mux_counted::<W>(v, a, b, c, d, counts, mask);
        }
    }
}

/// [`run_unary`] with store-time dirty marking and skip-on-equal stores
/// (§Gating): the freshly computed block is compared against the
/// standing value; an unchanged store is elided, a changed one marks the
/// destination's dirty block so downstream runs wake.
#[inline(always)]
fn run_unary_gated<const W: usize>(
    v: &mut [u64],
    a: &[u32],
    d: &[u32],
    dirty: &mut [u64],
    f: impl Fn(u64) -> u64,
) {
    for (&ai, &di) in a.iter().zip(d) {
        let va = load::<W>(v, ai);
        let old = load::<W>(v, di);
        let mut out = [0u64; W];
        let mut diff = 0u64;
        for (j, o) in out.iter_mut().enumerate() {
            *o = f(va[j]);
            diff |= *o ^ old[j];
        }
        if diff != 0 {
            mark_dirty(dirty, di);
            store::<W>(v, di, out);
        }
    }
}

/// [`run_binary`] with store-time dirty marking (§Gating).
#[inline(always)]
fn run_binary_gated<const W: usize>(
    v: &mut [u64],
    a: &[u32],
    b: &[u32],
    d: &[u32],
    dirty: &mut [u64],
    f: impl Fn(u64, u64) -> u64,
) {
    for ((&ai, &bi), &di) in a.iter().zip(b).zip(d) {
        let va = load::<W>(v, ai);
        let vb = load::<W>(v, bi);
        let old = load::<W>(v, di);
        let mut out = [0u64; W];
        let mut diff = 0u64;
        for (j, o) in out.iter_mut().enumerate() {
            *o = f(va[j], vb[j]);
            diff |= *o ^ old[j];
        }
        if diff != 0 {
            mark_dirty(dirty, di);
            store::<W>(v, di, out);
        }
    }
}

/// [`run_mux`] with store-time dirty marking (§Gating).
#[inline(always)]
fn run_mux_gated<const W: usize>(
    v: &mut [u64],
    a: &[u32],
    b: &[u32],
    c: &[u32],
    d: &[u32],
    dirty: &mut [u64],
) {
    for (((&ai, &bi), &si), &di) in a.iter().zip(b).zip(c).zip(d) {
        let va = load::<W>(v, ai);
        let vb = load::<W>(v, bi);
        let vs = load::<W>(v, si);
        let old = load::<W>(v, di);
        let mut out = [0u64; W];
        let mut diff = 0u64;
        for (j, o) in out.iter_mut().enumerate() {
            *o = (va[j] & !vs[j]) | (vb[j] & vs[j]);
            diff |= *o ^ old[j];
        }
        if diff != 0 {
            mark_dirty(dirty, di);
            store::<W>(v, di, out);
        }
    }
}

/// [`exec_run`] through the marking kernels — identical values, plus
/// downstream wake-up via the dirty bitmap (§Gating).
#[inline(always)]
fn exec_run_gated<const W: usize>(
    v: &mut [u64],
    op: u8,
    a: &[u32],
    b: &[u32],
    c: &[u32],
    d: &[u32],
    dirty: &mut [u64],
) {
    match op {
        OP_INV => run_unary_gated::<W>(v, a, d, dirty, |x| !x),
        OP_BUF => run_unary_gated::<W>(v, a, d, dirty, |x| x),
        OP_NAND => run_binary_gated::<W>(v, a, b, d, dirty, |x, y| !(x & y)),
        OP_NOR => run_binary_gated::<W>(v, a, b, d, dirty, |x, y| !(x | y)),
        OP_AND => run_binary_gated::<W>(v, a, b, d, dirty, |x, y| x & y),
        OP_OR => run_binary_gated::<W>(v, a, b, d, dirty, |x, y| x | y),
        OP_XOR => run_binary_gated::<W>(v, a, b, d, dirty, |x, y| x ^ y),
        OP_XNOR => run_binary_gated::<W>(v, a, b, d, dirty, |x, y| !(x ^ y)),
        _ => {
            debug_assert_eq!(op, OP_MUX);
            run_mux_gated::<W>(v, a, b, c, d, dirty);
        }
    }
}

/// Lower one interpreted cell to its micro-op view `(op, a, b, sel, y)`
/// so both plan forms share the [`exec_run`]/[`exec_run_counted`]
/// dispatch (interpreted slots are the source net ids themselves).
#[inline(always)]
fn cell_microop(c: &Cell) -> (u8, NetId, NetId, NetId, NetId) {
    match *c {
        Cell::Inv { a, y } => (OP_INV, a, CONST0, CONST0, y),
        Cell::Buf { a, y } => (OP_BUF, a, CONST0, CONST0, y),
        Cell::Nand2 { a, b, y } => (OP_NAND, a, b, CONST0, y),
        Cell::Nor2 { a, b, y } => (OP_NOR, a, b, CONST0, y),
        Cell::And2 { a, b, y } => (OP_AND, a, b, CONST0, y),
        Cell::Or2 { a, b, y } => (OP_OR, a, b, CONST0, y),
        Cell::Xor2 { a, b, y } => (OP_XOR, a, b, CONST0, y),
        Cell::Xnor2 { a, b, y } => (OP_XNOR, a, b, CONST0, y),
        Cell::Mux2 { a, b, sel, y } => (OP_MUX, a, b, sel, y),
        Cell::Dff { .. } => unreachable!("DFF in comb order"),
    }
}

/// Internal activity-profiling state (§Activity): one toggle counter per
/// value slot plus the per-lane-word population mask of the current
/// block.
struct ActivityState {
    /// Toggle count per value slot (dense slot on compiled plans, source
    /// net id on interpreted ones).
    counts: Vec<u64>,
    /// Per-lane-word mask of real samples in the current block —
    /// zero-padded tail lanes never count.
    mask: Vec<u64>,
}

/// Internal activity-gating state (§Gating): one dirty bit per 16-slot
/// value block, plus executed/skipped run counters.
struct GateState {
    /// Dirty bitmap over [`GATE_BLOCK_SHIFT`] slot blocks.
    dirty: Vec<u64>,
    stats: GateStats,
}

/// Packed super-lane two-valued simulator state over a shared
/// [`SimPlan`]: `W` consecutive `u64` words per net, one sample per bit
/// (`W·64` samples per pass; `W = 1` is the original 64-lane geometry).
pub struct Sim {
    plan: Arc<SimPlan>,
    /// Super-lane width: `u64` words per net (one of
    /// [`LANE_WORD_CHOICES`]).
    w: usize,
    /// Current value of every net, slot-major: slot `s`, word `j` lives
    /// at `vals[s * w + j]`; bit `l` of word `j` is sample `j*64 + l`.
    vals: Vec<u64>,
    /// Scratch for the two-phase register update (`n_state * w` words).
    next_q: Vec<u64>,
    /// Injected faults, lowered against the plan (`None` = clean run —
    /// the common case pays one branch per eval).
    faults: Option<Box<fault::FaultState>>,
    /// Activity profiling (`None` = off — the default; one branch per
    /// opcode run when on).
    activity: Option<Box<ActivityState>>,
    /// Activity gating (`None` = off — every run executes; see
    /// [`Sim::set_gating`]).
    gate: Option<Box<GateState>>,
}

impl Sim {
    pub fn new(n: &Netlist) -> Sim {
        Sim::from_plan(Arc::new(SimPlan::new(n)))
    }

    /// Fresh 64-lane (`W = 1`) simulator state over a shared plan — the
    /// width every pre-super-lane caller gets, with `set`/`get` exactly
    /// as before.  See [`Sim::from_plan_wide`] for wider blocks.
    pub fn from_plan(plan: Arc<SimPlan>) -> Sim {
        Sim::from_plan_wide(plan, 1)
    }

    /// Fresh simulator state with `lane_words` `u64` words per net —
    /// the sharded entry point: workers each call this with a clone of
    /// one `Arc<SimPlan>` and the run's super-lane width.
    ///
    /// Over a compiled plan the value vector is sized to the dense live
    /// nets only (cache-local levels); over an interpreted plan it spans
    /// every source net.  Both paths and every width are bit-identical
    /// per lane — `W` only changes how many samples ride one pass.
    pub fn from_plan_wide(plan: Arc<SimPlan>, lane_words: usize) -> Sim {
        assert!(
            LANE_WORD_CHOICES.contains(&lane_words),
            "lane words must be one of {LANE_WORD_CHOICES:?}, got {lane_words}"
        );
        let n_vals = plan.compiled.as_ref().map_or(plan.n_nets, |c| c.n_dense);
        let n_state = plan
            .compiled
            .as_ref()
            .map_or(plan.dffs.len(), |c| c.dff_q.len());
        let mut vals = vec![0u64; n_vals * lane_words];
        for j in 0..lane_words {
            vals[lane_words + j] = !0u64; // CONST1 (slot 1), every word
        }
        let mut sim = Sim {
            next_q: vec![0; n_state * lane_words],
            plan,
            w: lane_words,
            vals,
            faults: None,
            activity: None,
            gate: None,
        };
        if gate_on_activity_default() {
            sim.set_gating(true);
        }
        sim
    }

    /// Inject a fault list: lower it against this simulator's plan so
    /// every subsequent `eval`/`step` forces the masks.  Faults on nets
    /// the plan does not materialize are dropped (see
    /// [`SimPlan::faultable`]); an empty surviving set costs nothing.
    /// Call [`Sim::fault_begin_block`] when this simulator's lanes start
    /// at a nonzero sample offset (sharded runs).
    pub fn set_faults(&mut self, list: &fault::FaultList) {
        self.faults = fault::FaultState::build(&self.plan, list).map(Box::new);
        self.gate_all_dirty();
    }

    /// Remove every injected fault.
    pub fn clear_faults(&mut self) {
        self.faults = None;
        self.gate_all_dirty();
    }

    /// Whether any fault survived lowering.
    pub fn has_faults(&self) -> bool {
        self.faults.is_some()
    }

    /// Pin the transient-flip key space to a lane block whose first
    /// sample is `base_sample` (a multiple of 64) and restart the
    /// per-block eval counter — what makes sharded fault runs
    /// bit-identical to a serial one.  No-op on a clean simulator.
    pub fn fault_begin_block(&mut self, base_sample: usize) {
        debug_assert_eq!(base_sample % Self::LANES, 0);
        if let Some(fs) = &mut self.faults {
            fs.begin_block(base_sample);
            // The transient key space just moved: every flip mask may
            // change next eval, so nothing is provably clean.
            if let Some(g) = self.gate.as_deref_mut() {
                g.dirty.fill(!0u64);
            }
        }
    }

    /// Turn per-net toggle counting on or off (§Activity).  Turning it
    /// on allocates one counter per value slot (starting at zero);
    /// turning it off drops the counters — either way predictions are
    /// untouched.
    pub fn set_activity(&mut self, on: bool) {
        if on {
            let n = self.vals.len() / self.w;
            self.activity = Some(Box::new(ActivityState {
                counts: vec![0; n],
                mask: vec![!0u64; self.w],
            }));
        } else {
            self.activity = None;
        }
    }

    /// Whether toggle counting is on.
    pub fn activity_enabled(&self) -> bool {
        self.activity.is_some()
    }

    /// Begin a block of `lanes` real samples (`lanes ≤ lanes()`): set
    /// the per-word population masks so padded tail lanes never count,
    /// and restore the canonical fresh-simulator start state (all nets
    /// zero, CONST1 all-ones, registers unset) — a worker reused across
    /// blocks would otherwise count first-eval transitions *from the
    /// previous block's values*, making counts depend on how blocks land
    /// on workers.  Predictions never depend on the pre-drive state (the
    /// testbench protocols fully re-drive every block — the sharding
    /// differentials prove it), so the wipe is invisible outside the
    /// counters.  No-op with profiling off.
    pub fn activity_begin_block(&mut self, lanes: usize) {
        if self.activity.is_none() {
            return;
        }
        assert!(lanes <= self.lanes(), "block larger than the super-lane");
        let w = self.w;
        if let Some(st) = self.activity.as_deref_mut() {
            for (j, m) in st.mask.iter_mut().enumerate() {
                let lo = j * Self::LANES;
                *m = if lanes >= lo + Self::LANES {
                    !0u64
                } else if lanes <= lo {
                    0
                } else {
                    (1u64 << (lanes - lo)) - 1
                };
            }
        }
        self.vals.fill(0);
        for j in 0..w {
            self.vals[w + j] = !0u64; // CONST1 (slot 1), every word
        }
        // The wipe invalidated every value slot for the gating map too.
        self.gate_all_dirty();
    }

    /// Harvest the accumulated counters as an [`Activity`] snapshot and
    /// reset them to zero (profiling stays on).  Returns an empty
    /// snapshot when profiling is off.
    pub fn take_activity(&mut self) -> Activity {
        match self.activity.as_deref_mut() {
            Some(st) => {
                let n = st.counts.len();
                Activity {
                    counts: std::mem::replace(&mut st.counts, vec![0; n]),
                }
            }
            None => Activity::default(),
        }
    }

    /// Turn activity-gated evaluation on or off (§Gating).  Gating is a
    /// pure optimisation over compiled plans: a homogeneous opcode run
    /// is skipped when none of its input blocks changed since the
    /// previous eval, which the differential suite pins bit-identical to
    /// the ungated walk at every width, thread count, and fault list.
    /// On an interpreted plan this is a silent no-op — the oracle always
    /// pays full price.  Turning it on starts all-dirty (the first eval
    /// executes everything).  While activity *profiling* is on, gating
    /// is suspended — the counted kernels must observe every store — and
    /// resumes correctly afterwards because ungated evals never clear
    /// the dirty map.
    pub fn set_gating(&mut self, on: bool) {
        if on && self.plan.is_compiled() {
            let slots = self.vals.len() / self.w;
            let words = slots.div_ceil(1usize << (GATE_BLOCK_SHIFT + 6)).max(1);
            self.gate = Some(Box::new(GateState {
                dirty: vec![!0u64; words],
                stats: GateStats::default(),
            }));
        } else {
            self.gate = None;
        }
    }

    /// Whether activity-gated evaluation is on.
    pub fn gating_enabled(&self) -> bool {
        self.gate.is_some()
    }

    /// Harvest the executed/skipped run counters and reset them (gating
    /// stays on).  Zeroed stats when gating is off.
    pub fn take_gate_stats(&mut self) -> GateStats {
        match self.gate.as_deref_mut() {
            Some(g) => std::mem::take(&mut g.stats),
            None => GateStats::default(),
        }
    }

    /// Conservatively mark every gating block dirty (cheap; the next
    /// gated eval simply recomputes everything).
    fn gate_all_dirty(&mut self) {
        if let Some(g) = self.gate.as_deref_mut() {
            g.dirty.fill(!0u64);
        }
    }

    /// The shared levelized plan this simulator executes.
    pub fn plan(&self) -> &Arc<SimPlan> {
        &self.plan
    }

    /// Number of parallel lanes per `u64` word.
    pub const LANES: usize = 64;

    /// Super-lane width: `u64` words per net.
    #[inline]
    pub fn lane_words(&self) -> usize {
        self.w
    }

    /// Total parallel samples per pass (`lane_words * 64`).
    #[inline]
    pub fn lanes(&self) -> usize {
        self.w * Self::LANES
    }

    /// Drive lane word 0 of a net with one packed 64-lane word — the
    /// `W = 1` contract, unchanged; words 1.. are untouched (use
    /// [`Sim::set_lane_word`] / [`Sim::fill`] for wide blocks).  `net` is
    /// always a *source-netlist* id; on a compiled plan it is translated
    /// through the write map, and driving a net compilation eliminated or
    /// folded away (e.g. a pruned input that feeds only dead logic) is a
    /// silent no-op — never a write to the folded net's survivor.
    #[inline]
    pub fn set(&mut self, net: NetId, packed: u64) {
        self.set_lane_word(net, 0, packed);
    }

    /// Drive one lane word (`word < lane_words`, bit `l` = sample
    /// `word*64 + l`) of a net; same translation rules as [`Sim::set`].
    #[inline]
    pub fn set_lane_word(&mut self, net: NetId, word: usize, packed: u64) {
        debug_assert!(net >= 2, "cannot drive constant nets");
        debug_assert!(word < self.w, "lane word out of range");
        let slot = self.plan.write_slot(net);
        if slot != u32::MAX {
            debug_assert!(slot >= 2, "cannot drive a constant slot");
            let idx = slot as usize * self.w + word;
            let old = self.vals[idx];
            self.vals[idx] = packed;
            if old != packed {
                if let Some(g) = self.gate.as_deref_mut() {
                    mark_dirty(&mut g.dirty, slot);
                }
            }
        }
    }

    /// Broadcast one packed word to every lane word of a net (e.g. a
    /// reset or enable that must assert across all `W·64` samples).
    #[inline]
    pub fn fill(&mut self, net: NetId, packed: u64) {
        for word in 0..self.w {
            self.set_lane_word(net, word, packed);
        }
    }

    /// Read lane word 0 of a net — the `W = 1` contract, unchanged
    /// (source-netlist id; compiled plans translate through the port map —
    /// a net folded onto an alias or constant reads that survivor's
    /// value, an eliminated net reads 0).  The external contract covers
    /// port bits and register outputs; arbitrary internal nets are only
    /// observable on interpreted plans.
    #[inline]
    pub fn get(&self, net: NetId) -> u64 {
        self.get_lane_word(net, 0)
    }

    /// Read one lane word of a net; same translation rules as
    /// [`Sim::get`].
    #[inline]
    pub fn get_lane_word(&self, net: NetId, word: usize) -> u64 {
        debug_assert!(word < self.w, "lane word out of range");
        let slot = self.plan.read_slot(net);
        if slot == u32::MAX {
            0
        } else {
            self.vals[slot as usize * self.w + word]
        }
    }

    /// Drive one lane word of a *dense value slot* directly — the fused
    /// plan's IO path (§Fusion), where per-model port slots are
    /// pre-translated and there is no source netlist to map through.
    /// Compare-and-marks the gating map like [`Sim::set_lane_word`].
    #[inline]
    pub(crate) fn set_slot_word(&mut self, slot: u32, word: usize, packed: u64) {
        debug_assert!(slot >= 2, "cannot drive a constant slot");
        debug_assert!(word < self.w, "lane word out of range");
        let idx = slot as usize * self.w + word;
        let old = self.vals[idx];
        self.vals[idx] = packed;
        if old != packed {
            if let Some(g) = self.gate.as_deref_mut() {
                mark_dirty(&mut g.dirty, slot);
            }
        }
    }

    /// Read one lane word of a dense value slot (§Fusion); constant
    /// slots 0/1 read their constant value.
    #[inline]
    pub(crate) fn get_slot_word(&self, slot: u32, word: usize) -> u64 {
        debug_assert!(word < self.w, "lane word out of range");
        self.vals[slot as usize * self.w + word]
    }

    /// Drive a word with per-lane integer values (bit i of value v goes
    /// to lane `lane` of net `word[i]`).  Accepts up to [`Sim::lanes`]
    /// values and zeroes every lane beyond `values.len()` — the partial
    /// final block of a sharded run never sees a stale lane.
    pub fn set_word_lanes(&mut self, word: &[NetId], values: &[i64]) {
        assert!(values.len() <= self.lanes());
        for (bit, &net) in word.iter().enumerate() {
            for j in 0..self.w {
                let chunk = values.iter().skip(j * Self::LANES).take(Self::LANES);
                let mut packed = 0u64;
                for (lane, &v) in chunk.enumerate() {
                    packed |= (((v >> bit) & 1) as u64) << lane;
                }
                self.set_lane_word(net, j, packed);
            }
        }
    }

    /// Broadcast one value to all lanes (every lane word) of a word.
    pub fn set_word_all(&mut self, word: &[NetId], value: i64) {
        for (bit, &net) in word.iter().enumerate() {
            let v = if (value >> bit) & 1 == 1 { !0u64 } else { 0u64 };
            self.fill(net, v);
        }
    }

    /// Read a word back for one lane (`lane < lanes()`), two's-complement
    /// sign-extended.
    pub fn get_word_lane_signed(&self, word: &[NetId], lane: usize) -> i64 {
        let mut v: i64 = 0;
        let (wd, bit_in) = (lane / Self::LANES, lane % Self::LANES);
        for (bit, &net) in word.iter().enumerate() {
            if (self.get_lane_word(net, wd) >> bit_in) & 1 == 1 {
                v |= 1 << bit;
            }
        }
        let w = word.len();
        if w < 64 && (v >> (w - 1)) & 1 == 1 {
            v -= 1 << w;
        }
        v
    }

    /// Read a word back for one lane, unsigned.
    pub fn get_word_lane(&self, word: &[NetId], lane: usize) -> u64 {
        let mut v: u64 = 0;
        let (wd, bit_in) = (lane / Self::LANES, lane % Self::LANES);
        for (bit, &net) in word.iter().enumerate() {
            if (self.get_lane_word(net, wd) >> bit_in) & 1 == 1 {
                v |= 1 << bit;
            }
        }
        v
    }

    /// Propagate combinational logic.
    ///
    /// Compiled plans execute the opcode-run schedule: one homogeneous
    /// tight loop per run over four contiguous operand arrays with
    /// densely renumbered slots — no enum payload decode, no per-op
    /// opcode branch, and whole `[u64; W]` lane blocks per micro-op.
    /// Interpreted plans walk the levelized `Vec<Cell>` (the oracle the
    /// differential suite compares against), widened to the same `W`.
    pub fn eval(&mut self) {
        match self.w {
            1 => self.eval_w::<1>(),
            2 => self.eval_w::<2>(),
            4 => self.eval_w::<4>(),
            _ => self.eval_w::<8>(),
        }
    }

    fn eval_w<const W: usize>(&mut self) {
        debug_assert_eq!(self.w, W);
        // Gated fast path: compiled plan, gating on, profiling off (the
        // counted kernels must see every store, so profiling suspends
        // gating for the duration).
        if self.gate.is_some() && self.activity.is_none() && self.plan.is_compiled() {
            self.eval_gated_w::<W>();
            return;
        }
        let plan = &*self.plan;
        let v = &mut self.vals;
        let fs = self.faults.as_deref();
        let mut act = self.activity.as_deref_mut();
        if let Some(fs) = fs {
            // Externally-written slots (inputs, register state, undriven
            // nets) are forced before propagation so every reader sees
            // the corrupted value.  Source nets have no producing
            // micro-op, so the counters never see these forces.
            for af in &fs.sources {
                fs.apply::<W>(v, af);
            }
        }
        if let Some(cp) = &plan.compiled {
            // With scheduled faults the fault-split run table executes
            // (every faulted producer ends a run, so a same-run reader
            // can never observe the clean value); clean runs pay nothing.
            let runs: &[(u8, u32, u32)] = match fs.and_then(|f| f.runs.as_deref()) {
                Some(split) => split,
                None => &cp.runs,
            };
            let mut cursor = 0usize;
            for (ri, &(op, start, len)) in runs.iter().enumerate() {
                let r = start as usize..start as usize + len as usize;
                let a = &cp.src_a[r.clone()];
                let b = &cp.src_b[r.clone()];
                let c = &cp.src_c[r.clone()];
                let d = &cp.dst[r];
                match act.as_deref_mut() {
                    Some(st) => {
                        exec_run_counted::<W>(v, op, a, b, c, d, &mut st.counts, &st.mask)
                    }
                    None => exec_run::<W>(v, op, a, b, c, d),
                }
                // Scheduled fault masks force nets strictly *after* the
                // producing run (and its store-time toggle count).
                if let Some(fs) = fs {
                    while cursor < fs.scheduled.len() && fs.scheduled[cursor].0 == ri as u32 {
                        fs.apply::<W>(v, &fs.scheduled[cursor].1);
                        cursor += 1;
                    }
                }
            }
        } else {
            let mut cursor = 0usize;
            for (pos, &ci) in plan.order.iter().enumerate() {
                let (op, a, b, sel, y) = cell_microop(&plan.cells[ci as usize]);
                match act.as_deref_mut() {
                    Some(st) => exec_run_counted::<W>(
                        v,
                        op,
                        &[a],
                        &[b],
                        &[sel],
                        &[y],
                        &mut st.counts,
                        &st.mask,
                    ),
                    None => exec_run::<W>(v, op, &[a], &[b], &[sel], &[y]),
                }
                if let Some(fs) = fs {
                    while cursor < fs.scheduled.len() && fs.scheduled[cursor].0 == pos as u32 {
                        fs.apply::<W>(v, &fs.scheduled[cursor].1);
                        cursor += 1;
                    }
                }
            }
        }
        if let Some(fs) = self.faults.as_deref_mut() {
            fs.end_eval();
        }
    }

    /// Activity-gated compiled eval (§Gating): walk the same run table
    /// as the ungated path, but probe each run's input-block gate list
    /// first and skip it when nothing it reads changed since the last
    /// eval.  Executed runs store through the marking kernels so
    /// downstream runs wake; fault masks are applied through
    /// [`fault::FaultState::apply_marked`] so a forced change propagates
    /// dirt exactly like a computed one.  After the walk every slot is
    /// consistent with the current inputs (skipped runs were already
    /// consistent), so the dirty map is cleared wholesale — external
    /// writes, register commits, and fault-key changes re-mark it.
    fn eval_gated_w<const W: usize>(&mut self) {
        let plan = &*self.plan;
        let cp = plan.compiled.as_ref().expect("gated eval needs a compiled plan");
        let v = &mut self.vals;
        let g = self.gate.as_deref_mut().expect("gated eval needs gating on");
        let fs = self.faults.as_deref();
        if let Some(fs) = fs {
            for af in &fs.sources {
                fs.apply_marked::<W>(v, af, &mut g.dirty);
            }
        }
        let (runs, gates): (&[(u8, u32, u32)], &RunGates) =
            match fs.and_then(|f| f.runs.as_deref().zip(f.run_gates.as_ref())) {
                Some((split, rg)) => (split, rg),
                None => (&cp.runs, &cp.run_gates),
            };
        let mut cursor = 0usize;
        for (ri, &(op, start, len)) in runs.iter().enumerate() {
            if gates.is_hot(ri, &g.dirty) {
                let r = start as usize..start as usize + len as usize;
                exec_run_gated::<W>(
                    v,
                    op,
                    &cp.src_a[r.clone()],
                    &cp.src_b[r.clone()],
                    &cp.src_c[r.clone()],
                    &cp.dst[r],
                    &mut g.dirty,
                );
                g.stats.executed += 1;
            } else {
                g.stats.skipped += 1;
            }
            if let Some(fs) = fs {
                while cursor < fs.scheduled.len() && fs.scheduled[cursor].0 == ri as u32 {
                    fs.apply_marked::<W>(v, &fs.scheduled[cursor].1, &mut g.dirty);
                    cursor += 1;
                }
            }
        }
        g.dirty.fill(0);
        if let Some(fs) = self.faults.as_deref_mut() {
            fs.end_eval();
        }
    }

    /// One clock edge: propagate combinational logic from the current
    /// inputs, capture register inputs (two-phase), and commit.
    ///
    /// §Perf: register outputs are updated but downstream logic is NOT
    /// re-propagated here — the next `step()` (or a final [`Sim::settle`])
    /// does that once, halving combinational work per cycle compared to
    /// the naive eval-capture-commit-eval loop.  Call `settle()` before
    /// reading outputs after the last step.
    pub fn step(&mut self) {
        self.eval();
        match self.w {
            1 => self.commit_state::<1>(),
            2 => self.commit_state::<2>(),
            4 => self.commit_state::<4>(),
            _ => self.commit_state::<8>(),
        }
        // The register commit just overwrote state slots; re-force the
        // stuck component of every source fault so post-step observation
        // stays coherent (transient flips are NOT re-drawn — they are a
        // pure function of the eval count).
        if self.faults.is_some() {
            match self.w {
                1 => self.reforce_stuck::<1>(),
                2 => self.reforce_stuck::<2>(),
                4 => self.reforce_stuck::<4>(),
                _ => self.reforce_stuck::<8>(),
            }
        }
    }

    fn reforce_stuck<const W: usize>(&mut self) {
        if let Some(fs) = self.faults.as_deref() {
            fs.reforce_stuck::<W>(&mut self.vals);
        }
    }

    /// Commit only the given DFF index ranges (`[lo, hi)` pairs, per the
    /// compiled DFF SoA order) — the fused driver's freeze primitive
    /// (§Fusion): a tenant whose clock schedule has finished is simply
    /// left out, so its registers hold and its combinational cone stays
    /// a pure function of held state, bit-identical to a standalone
    /// settle.  Compiled plans only.
    pub fn commit_state_ranges(&mut self, ranges: &[(u32, u32)]) {
        for &(lo, hi) in ranges {
            match self.w {
                1 => self.commit_dff_range::<1>(lo as usize, hi as usize),
                2 => self.commit_dff_range::<2>(lo as usize, hi as usize),
                4 => self.commit_dff_range::<4>(lo as usize, hi as usize),
                _ => self.commit_dff_range::<8>(lo as usize, hi as usize),
            }
        }
    }

    /// Two-phase commit of compiled DFF indices `lo..hi`: capture every
    /// next-state word, count commit toggles (profiling), then copy —
    /// marking each q slot whose value changed in the gating map (the
    /// commit is the only writer of register slots, so this is the only
    /// place settled state can wake downstream runs).
    fn commit_dff_range<const W: usize>(&mut self, lo: usize, hi: usize) {
        let plan = &*self.plan;
        let cp = plan
            .compiled
            .as_ref()
            .expect("range commit needs a compiled plan");
        for i in lo..hi {
            let v = &self.vals;
            let d = load::<W>(v, cp.dff_d[i]);
            let en = load::<W>(v, cp.dff_en[i]);
            let rst = load::<W>(v, cp.dff_rst[i]);
            let q = load::<W>(v, cp.dff_q[i]);
            let rv = cp.dff_rstval[i];
            for j in 0..W {
                let held = (en[j] & d[j]) | (!en[j] & q[j]);
                self.next_q[i * W + j] = (rst[j] & rv) | (!rst[j] & held);
            }
        }
        // Count commit transitions of each q slot before the copy —
        // register state nets have no combinational producer, so the
        // commit is the only place they toggle.
        if let Some(st) = self.activity.as_deref_mut() {
            for i in lo..hi {
                let qslot = cp.dff_q[i] as usize;
                let base = qslot * W;
                let mut t = 0u64;
                for j in 0..W {
                    t += ((self.vals[base + j] ^ self.next_q[i * W + j]) & st.mask[j])
                        .count_ones() as u64;
                }
                st.counts[qslot] += t;
            }
        }
        for i in lo..hi {
            let qslot = cp.dff_q[i];
            let base = qslot as usize * W;
            let mut changed = 0u64;
            for j in 0..W {
                changed |= self.vals[base + j] ^ self.next_q[i * W + j];
            }
            self.vals[base..base + W].copy_from_slice(&self.next_q[i * W..i * W + W]);
            if changed != 0 {
                if let Some(g) = self.gate.as_deref_mut() {
                    mark_dirty(&mut g.dirty, qslot);
                }
            }
        }
    }

    fn commit_state<const W: usize>(&mut self) {
        debug_assert_eq!(self.w, W);
        if self.plan.compiled.is_some() {
            let n = self.plan.compiled.as_ref().map_or(0, |c| c.dff_q.len());
            self.commit_dff_range::<W>(0, n);
            return;
        }
        let plan = &*self.plan;
        for (slot, &ci) in plan.dffs.iter().enumerate() {
            if let Cell::Dff {
                d,
                q,
                en,
                rst,
                rstval,
            } = plan.cells[ci as usize]
            {
                let v = &self.vals;
                let rv = if rstval { !0u64 } else { 0u64 };
                let vd = load::<W>(v, d);
                let ven = load::<W>(v, en);
                let vrst = load::<W>(v, rst);
                let vq = load::<W>(v, q);
                for j in 0..W {
                    let held = (ven[j] & vd[j]) | (!ven[j] & vq[j]);
                    self.next_q[slot * W + j] = (vrst[j] & rv) | (!vrst[j] & held);
                }
            }
        }
        if let Some(st) = self.activity.as_deref_mut() {
            for (slot, &ci) in plan.dffs.iter().enumerate() {
                let q = plan.cells[ci as usize].output();
                let base = q as usize * W;
                let mut t = 0u64;
                for j in 0..W {
                    t += ((self.vals[base + j] ^ self.next_q[slot * W + j]) & st.mask[j])
                        .count_ones() as u64;
                }
                st.counts[q as usize] += t;
            }
        }
        for (slot, &ci) in plan.dffs.iter().enumerate() {
            let q = plan.cells[ci as usize].output();
            let base = q as usize * W;
            self.vals[base..base + W].copy_from_slice(&self.next_q[slot * W..slot * W + W]);
        }
    }

    /// Propagate combinational logic so outputs reflect the last commit.
    pub fn settle(&mut self) {
        self.eval();
    }

    /// Reset all registers to their reset values (as if rst had been held
    /// high for one cycle) across every lane word, then propagate.
    pub fn reset(&mut self) {
        let w = self.w;
        if let Some(cp) = &self.plan.compiled {
            for (&qslot, &rv) in cp.dff_q.iter().zip(cp.dff_rstval.iter()) {
                let base = qslot as usize * w;
                self.vals[base..base + w].fill(rv);
            }
        } else {
            let plan = &*self.plan;
            for &ci in plan.dffs.iter() {
                if let Cell::Dff { q, rstval, .. } = plan.cells[ci as usize] {
                    let rv = if rstval { !0u64 } else { 0u64 };
                    let base = q as usize * w;
                    self.vals[base..base + w].fill(rv);
                }
            }
        }
        // Register slots were rewritten wholesale; nothing is provably
        // clean for the propagate below.
        self.gate_all_dirty();
        self.eval();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{Netlist, CONST0, CONST1};

    #[test]
    fn comb_logic_all_lanes() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let y = n.xor2(a, b);
        n.add_output("y", vec![y]);
        let mut s = Sim::new(&n);
        s.set(a, 0b1100);
        s.set(b, 0b1010);
        s.eval();
        assert_eq!(s.get(y) & 0xF, 0b0110);
    }

    #[test]
    fn mux_semantics() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let sel = n.add_input("s", 1)[0];
        let y = n.mux2(sel, a, b);
        let mut s = Sim::new(&n);
        s.set(a, 0b01);
        s.set(b, 0b10);
        s.set(sel, 0b10); // lane0: sel=0 -> a; lane1: sel=1 -> b
        s.eval();
        assert_eq!(s.get(y) & 0b11, 0b11);
    }

    #[test]
    fn dff_enable_and_reset() {
        let mut n = Netlist::new("t");
        let d = n.add_input("d", 1)[0];
        let en = n.add_input("en", 1)[0];
        let rst = n.add_input("rst", 1)[0];
        let q = n.dff(d, en, rst, true);
        n.add_output("q", vec![q]);
        let mut s = Sim::new(&n);
        // reset loads rstval=1
        s.set(d, 0);
        s.set(en, !0);
        s.set(rst, !0);
        s.step();
        assert_eq!(s.get(q), !0u64);
        // enabled capture of d=0
        s.set(rst, 0);
        s.set(d, 0);
        s.step();
        assert_eq!(s.get(q), 0);
        // disabled: hold
        s.set(en, 0);
        s.set(d, !0);
        s.step();
        assert_eq!(s.get(q), 0);
    }

    #[test]
    fn counter_via_feedback() {
        // 3-bit counter: q + 1 computed with xor/and chain.
        let mut n = Netlist::new("t");
        let (q0, c0) = n.dff_deferred(CONST1, CONST0, false);
        let (q1, c1) = n.dff_deferred(CONST1, CONST0, false);
        let (q2, c2) = n.dff_deferred(CONST1, CONST0, false);
        let d0 = n.inv(q0);
        let d1 = n.xor2(q1, q0);
        let carry = n.and2(q0, q1);
        let d2 = n.xor2(q2, carry);
        n.set_dff_d(c0, d0);
        n.set_dff_d(c1, d1);
        n.set_dff_d(c2, d2);
        let word = vec![q0, q1, q2];
        let mut s = Sim::new(&n);
        s.reset();
        for expect in 1..=7u64 {
            s.step();
            assert_eq!(s.get_word_lane(&word, 0), expect % 8);
        }
    }

    #[test]
    fn word_lane_roundtrip_signed() {
        let mut n = Netlist::new("t");
        let w = n.add_input("w", 6);
        let mut s = Sim::new(&n);
        let vals = [-32i64, -1, 0, 1, 31, 5, -17, 12];
        s.set_word_lanes(&w, &vals);
        for (lane, &v) in vals.iter().enumerate() {
            assert_eq!(s.get_word_lane_signed(&w, lane), v);
        }
    }

    #[test]
    fn compiled_comb_matches_interpreted_and_shrinks() {
        // x_all → adder-ish logic with a buffer + double inverter thrown
        // in; the compiled stream must reduce it and agree on every lane.
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let x = n.and2(a, b);
        let buf = n.fresh();
        n.cells.push(Cell::Buf { a: x, y: buf });
        let i1 = n.inv(buf);
        let i2 = n.inv(i1);
        let y = n.xor2(i2, a);
        let z = n.or2(x, CONST0); // alias of x after folding
        n.add_output("y", vec![y]);
        n.add_output("z", vec![z]);
        let interp = Arc::new(SimPlan::new(&n));
        let comp = Arc::new(SimPlan::compiled(&n));
        assert!(comp.is_compiled() && !interp.is_compiled());
        let cp = comp.compiled_plan().unwrap();
        assert!(cp.n_ops() < n.cells.len(), "strength reduction must bite");
        assert!(cp.n_dense_nets() <= n.n_nets());
        let mut si = Sim::from_plan(interp);
        let mut sc = Sim::from_plan(comp);
        for (pa, pb) in [(0u64, 0u64), (!0, 0), (0xDEAD_BEEF, 0xF00D_CAFE), (!0, !0)] {
            for s in [&mut si, &mut sc] {
                s.set(a, pa);
                s.set(b, pb);
                s.eval();
            }
            assert_eq!(si.get(y), sc.get(y), "y lanes");
            assert_eq!(si.get(z), sc.get(z), "z (folded alias) lanes");
        }
    }

    #[test]
    fn compiled_counter_matches_interpreted_over_steps_and_reset() {
        let mut n = Netlist::new("t");
        let (q0, c0) = n.dff_deferred(CONST1, CONST0, false);
        let (q1, c1) = n.dff_deferred(CONST1, CONST0, false);
        let (q2, c2) = n.dff_deferred(CONST1, CONST0, true); // rstval mix
        let d0 = n.inv(q0);
        let d1 = n.xor2(q1, q0);
        let carry = n.and2(q0, q1);
        let d2 = n.xor2(q2, carry);
        n.set_dff_d(c0, d0);
        n.set_dff_d(c1, d1);
        n.set_dff_d(c2, d2);
        let word = vec![q0, q1, q2];
        n.add_output("q", word.clone());
        let mut si = Sim::from_plan(Arc::new(SimPlan::new(&n)));
        let mut sc = Sim::from_plan(Arc::new(SimPlan::compiled(&n)));
        si.reset();
        sc.reset();
        assert_eq!(si.get_word_lane(&word, 0), sc.get_word_lane(&word, 0));
        for step in 0..12 {
            si.step();
            sc.step();
            for lane in [0usize, 17, 63] {
                assert_eq!(
                    si.get_word_lane(&word, lane),
                    sc.get_word_lane(&word, lane),
                    "step {step} lane {lane}"
                );
            }
        }
        // Mid-run reset must land both on the same state.
        si.reset();
        sc.reset();
        assert_eq!(si.get_word_lane(&word, 0), sc.get_word_lane(&word, 0));
    }

    #[test]
    fn compiled_port_map_observes_folded_and_dead_nets() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let unused = n.add_input("unused", 1)[0];
        let buf = n.fresh();
        n.cells.push(Cell::Buf { a, y: buf });
        let dead = n.and2(unused, a); // drives nothing
        n.add_output("y", vec![buf]);
        n.add_output("k1", vec![CONST1]);
        let mut s = Sim::from_plan(Arc::new(SimPlan::compiled(&n)));
        s.set(a, 0b1010);
        s.set(unused, !0u64); // feeds only dead logic: harmless
        s.eval();
        assert_eq!(s.get(buf) & 0xF, 0b1010, "folded output aliases its source");
        assert_eq!(s.get(CONST1), !0u64, "constant net still reads all-ones");
        assert_eq!(s.get(dead), 0, "eliminated net reads 0");
    }

    #[test]
    fn compile_default_toggle_selects_plan_kind() {
        assert!(compile_default(), "compiled is the default");
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        n.add_output("y", vec![a]);
        set_compile_default(false);
        let p = SimPlan::with_default_mode(&n);
        set_compile_default(true);
        assert!(!p.is_compiled());
        assert!(SimPlan::with_default_mode(&n).is_compiled());
    }

    #[test]
    fn shared_plan_sims_are_independent_and_equal() {
        // Two Sims over one plan behave exactly like two fresh Sims.
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let y = n.and2(a, b);
        n.add_output("y", vec![y]);
        let plan = Arc::new(SimPlan::new(&n));
        let mut s1 = Sim::from_plan(plan.clone());
        let mut s2 = Sim::from_plan(plan.clone());
        s1.set(a, 0b11);
        s1.set(b, 0b01);
        s2.set(a, 0b10);
        s2.set(b, 0b10);
        s1.eval();
        s2.eval();
        assert_eq!(s1.get(y) & 0b11, 0b01);
        assert_eq!(s2.get(y) & 0b11, 0b10);
        assert_eq!(plan.n_cells(), 1);
        assert_eq!(plan.n_dffs(), 0);
    }

    #[test]
    fn wide_lane_words_isolate_and_match_w1() {
        // Same xor circuit at W ∈ {2,4,8}: each lane word must compute
        // independently and agree with a W=1 sim fed that word alone —
        // on both the interpreted and compiled paths.
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let y = n.xor2(a, b);
        n.add_output("y", vec![y]);
        for plan in [Arc::new(SimPlan::new(&n)), Arc::new(SimPlan::compiled(&n))] {
            for w in [2usize, 4, 8] {
                let mut wide = Sim::from_plan_wide(plan.clone(), w);
                assert_eq!(wide.lane_words(), w);
                assert_eq!(wide.lanes(), w * 64);
                for j in 0..w {
                    let pa = 0x1111_2222_3333_4444u64.wrapping_mul(j as u64 + 1);
                    let pb = 0xAAAA_5555_F0F0_0F0Fu64.rotate_left(j as u32);
                    wide.set_lane_word(a, j, pa);
                    wide.set_lane_word(b, j, pb);
                }
                wide.eval();
                for j in 0..w {
                    let pa = 0x1111_2222_3333_4444u64.wrapping_mul(j as u64 + 1);
                    let pb = 0xAAAA_5555_F0F0_0F0Fu64.rotate_left(j as u32);
                    let mut narrow = Sim::from_plan(plan.clone());
                    narrow.set(a, pa);
                    narrow.set(b, pb);
                    narrow.eval();
                    assert_eq!(wide.get_lane_word(y, j), narrow.get(y), "word {j} w={w}");
                }
            }
        }
    }

    #[test]
    fn wide_word_helpers_roundtrip_across_words() {
        let mut n = Netlist::new("t");
        let w6 = n.add_input("w", 6);
        let mut s = Sim::from_plan_wide(Arc::new(SimPlan::new(&n)), 4);
        // 200 values spans three lane words plus a partial fourth.
        let vals: Vec<i64> = (0..200).map(|i| ((i * 7) % 64) - 32).collect();
        s.set_word_lanes(&w6, &vals);
        for (lane, &v) in vals.iter().enumerate() {
            assert_eq!(s.get_word_lane_signed(&w6, lane), v, "lane {lane}");
        }
        // Lanes beyond the provided values read as zero (masked).
        for lane in 200..256 {
            assert_eq!(s.get_word_lane(&w6, lane), 0, "stale lane {lane}");
        }
        // Broadcast fills every word.
        s.set_word_all(&w6, 0b101101);
        for lane in [0usize, 63, 64, 130, 255] {
            assert_eq!(s.get_word_lane(&w6, lane), 0b101101, "lane {lane}");
        }
    }

    #[test]
    fn wide_counter_steps_and_resets_every_word() {
        let mut n = Netlist::new("t");
        let (q0, c0) = n.dff_deferred(CONST1, CONST0, false);
        let (q1, c1) = n.dff_deferred(CONST1, CONST0, true);
        let d0 = n.inv(q0);
        let d1 = n.xor2(q1, q0);
        n.set_dff_d(c0, d0);
        n.set_dff_d(c1, d1);
        let word = vec![q0, q1];
        n.add_output("q", word.clone());
        for plan in [Arc::new(SimPlan::new(&n)), Arc::new(SimPlan::compiled(&n))] {
            let mut s = Sim::from_plan_wide(plan.clone(), 4);
            s.reset();
            let start = s.get_word_lane(&word, 0);
            for lane in [1usize, 65, 200] {
                assert_eq!(s.get_word_lane(&word, lane), start, "reset lane {lane}");
            }
            for _ in 0..5 {
                s.step();
            }
            let after = s.get_word_lane(&word, 0);
            for lane in [63usize, 64, 255] {
                assert_eq!(s.get_word_lane(&word, lane), after, "step lane {lane}");
            }
        }
    }

    #[test]
    fn opcode_runs_cover_the_stream_and_shrink_dispatch() {
        // A layer of parallel same-kind gates must collapse into a few
        // homogeneous runs, and the run spans must partition the stream.
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 8);
        let b = n.add_input("b", 8);
        let mut outs = Vec::new();
        for i in 0..8 {
            outs.push(n.nand2(a[i], b[i]));
        }
        for i in 0..8 {
            outs.push(n.xor2(a[i], b[i]));
        }
        n.add_output("y", outs);
        let plan = SimPlan::compiled(&n);
        let cp = plan.compiled_plan().unwrap();
        let covered: usize = cp.runs.iter().map(|&(_, _, len)| len as usize).sum();
        assert_eq!(covered, cp.n_ops(), "runs must partition the op stream");
        for pair in cp.runs.windows(2) {
            assert_eq!(
                pair[0].1 + pair[0].2,
                pair[1].1,
                "runs must be contiguous and ordered"
            );
        }
        assert!(
            cp.n_runs() <= 2,
            "16 one-level gates of two kinds must form at most 2 runs, got {}",
            cp.n_runs()
        );
    }

    #[test]
    fn lane_words_default_resolves_to_a_valid_choice() {
        assert!(LANE_WORD_CHOICES.contains(&auto_lane_words()));
        assert!(LANE_WORD_CHOICES.contains(&lane_words_default()));
        // An explicit width wins until reset to auto.
        set_lane_words_default(2);
        assert_eq!(lane_words_default(), 2);
        set_lane_words_default(0);
        assert!(LANE_WORD_CHOICES.contains(&lane_words_default()));
    }

    #[test]
    fn profile_activity_default_toggle() {
        assert!(!profile_activity_default(), "profiling is off by default");
        set_profile_activity_default(true);
        assert!(profile_activity_default());
        set_profile_activity_default(false);
        assert!(!profile_activity_default());
    }

    #[test]
    fn activity_counts_match_hand_computed_toggles() {
        // y = a ^ b on both plan forms: drive known transitions and
        // check the counter is exactly the popcount of each change.
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let y = n.xor2(a, b);
        n.add_output("y", vec![y]);
        for plan in [Arc::new(SimPlan::new(&n)), Arc::new(SimPlan::compiled(&n))] {
            let mut s = Sim::from_plan(plan.clone());
            s.set_activity(true);
            assert!(s.activity_enabled());
            s.activity_begin_block(64);
            s.set(a, 0);
            s.set(b, 0);
            s.eval(); // y: 0 → 0, no toggles
            s.set(a, !0u64);
            s.eval(); // y: 0 → !0, 64 toggles
            s.set(b, 0xFF);
            s.eval(); // y: !0 → !0xFF, 8 toggles
            let act = s.take_activity();
            assert_eq!(act.total_toggles(), 72, "inputs are uncounted sources");
            let gates = plan.gate_activity(&act);
            let xor: Vec<_> = gates.iter().filter(|g| g.kind == "XOR2").collect();
            assert_eq!(xor.len(), 1);
            assert_eq!(xor[0].toggles, 72);
            assert_eq!(xor[0].level, 1);
            // Harvesting reset the counters; profiling stays on.
            assert!(s.activity_enabled());
            assert_eq!(s.take_activity().total_toggles(), 0);
        }
    }

    #[test]
    fn activity_mask_excludes_padding_lanes() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let y = n.inv(a);
        n.add_output("y", vec![y]);
        for w in [1usize, 2, 4] {
            let mut s = Sim::from_plan_wide(Arc::new(SimPlan::compiled(&n)), w);
            s.set_activity(true);
            // 3 real samples: the INV's first eval flips every lane
            // (0 → !a with a = 0), but only 3 may count.
            s.activity_begin_block(3);
            s.eval();
            assert_eq!(s.take_activity().total_toggles(), 3, "w={w}");
            // Crossing a word boundary: 64 + 2 real samples.
            if w >= 2 {
                s.activity_begin_block(66);
                s.eval();
                assert_eq!(s.take_activity().total_toggles(), 66, "w={w}");
            }
        }
    }

    #[test]
    fn activity_begin_block_restores_canonical_state() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let y = n.inv(a);
        n.add_output("y", vec![y]);
        let mut s = Sim::from_plan_wide(Arc::new(SimPlan::compiled(&n)), 2);
        s.set_activity(true);
        s.activity_begin_block(128);
        s.fill(a, !0u64);
        s.eval();
        let dirty = s.take_activity();
        assert_eq!(dirty.total_toggles(), 0, "y stays 0 when a is high");
        // A new block must start from the fresh-sim state (a=0, y=0), so
        // the first eval counts the full 0 → 1 flip of y again — not a
        // diff against the previous block's values.
        s.activity_begin_block(128);
        assert_eq!(s.get(CONST1), !0u64, "constants survive the wipe");
        assert_eq!(s.get(a), 0, "inputs wiped to the fresh-sim state");
        s.eval();
        assert_eq!(s.take_activity().total_toggles(), 128);
    }

    #[test]
    fn activity_counts_register_commits() {
        let mut n = Netlist::new("t");
        let d = n.add_input("d", 1)[0];
        let en = n.add_input("en", 1)[0];
        let rst = n.add_input("rst", 1)[0];
        let q = n.dff(d, en, rst, false);
        n.add_output("q", vec![q]);
        for plan in [Arc::new(SimPlan::new(&n)), Arc::new(SimPlan::compiled(&n))] {
            let mut s = Sim::from_plan(plan.clone());
            s.set_activity(true);
            s.activity_begin_block(64);
            s.set(en, !0u64);
            s.set(rst, 0);
            s.set(d, !0u64);
            s.step(); // q: 0 → !0 at the commit, 64 toggles
            s.set(d, 0xF);
            s.step(); // q: !0 → 0xF, 60 toggles
            let act = s.take_activity();
            let gates = plan.gate_activity(&act);
            let dff: Vec<_> = gates.iter().filter(|g| g.kind == "DFF").collect();
            assert_eq!(dff.len(), 1);
            assert_eq!(dff[0].toggles, 124);
            assert_eq!(act.total_toggles(), 124, "this circuit has no comb gates");
        }
    }

    #[test]
    fn activity_merge_sums_per_slot_and_handles_empty() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let y = n.inv(a);
        n.add_output("y", vec![y]);
        let plan = Arc::new(SimPlan::compiled(&n));
        let mut s = Sim::from_plan(plan.clone());
        s.set_activity(true);
        s.activity_begin_block(64);
        s.eval(); // y flips all 64 lanes
        let one = s.take_activity();
        let mut total = Activity::default();
        total.merge(&one);
        total.merge(&Activity::default()); // identity
        total.merge(&one);
        assert_eq!(total.total_toggles(), 2 * one.total_toggles());
        assert!(!total.is_empty() && Activity::default().is_empty());
        assert!(plan.gate_activity(&Activity::default()).is_empty());
    }

    #[test]
    fn activity_off_allocates_nothing_and_takes_empty() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        n.add_output("y", vec![a]);
        let mut s = Sim::from_plan(Arc::new(SimPlan::compiled(&n)));
        assert!(!s.activity_enabled());
        s.activity_begin_block(10); // no-op off
        s.eval();
        assert!(s.take_activity().is_empty());
        s.set_activity(true);
        s.set_activity(false);
        assert!(!s.activity_enabled());
    }
}
