//! Testbenches: drive the generated circuits with dataset samples using
//! the paper's I/O protocol and collect predictions.
//!
//! Sequential protocol (Fig. 3b): a 1-cycle reset pulse, then one 4-bit
//! feature per cycle in the RFP schedule order, then `hidden + classes`
//! drain cycles; `class_out` is valid after the final argmax cycle.
//!
//! Up to `W·64` samples are simulated per pass (one per lane — see
//! `sim` §Super-lanes), and passes are sharded across worker threads via
//! [`batch::run_sharded_wide`]: the circuit's levelized
//! [`crate::sim::SimPlan`] is built once (cached on the circuit, compiled
//! to the micro-op stream unless [`crate::sim::compile_default`] is off)
//! and shared read-only by every worker.  Both protocols run through one
//! generic block driver (`run_blocks`) that owns the per-lane feature
//! gather and the class-word readback; the protocols differ only in the
//! closure that clocks the simulator.  `run_sequential` /
//! `run_combinational` use [`pool::default_threads`]
//! (`PRINTED_MLP_THREADS` overrides) and the process-wide super-lane
//! width ([`crate::sim::lane_words_default`] — `--sim-lanes`); the
//! `*_threads` variants take an explicit thread count — `1` is the exact
//! serial path the differential tests compare against — and the `*_plan`
//! variants take an explicit plan *and* width, which is how the benches
//! drive the compiled and interpreted paths over the same netlist at
//! every width.

use std::sync::Arc;

use crate::circuits::{CombCircuit, SeqCircuit};
use crate::netlist::{NetId, Netlist, Word};
use crate::sim::fault::FaultList;
use crate::sim::{batch, Activity, GateStats, Sim, SimPlan};
use crate::util::pool;

pub(crate) fn input_port<'a>(n: &'a Netlist, name: &str) -> &'a Word {
    &n.inputs
        .iter()
        .find(|p| p.name == name)
        .unwrap_or_else(|| panic!("missing input port {name}"))
        .bits
}

pub(crate) fn output_port<'a>(n: &'a Netlist, name: &str) -> &'a Word {
    &n.outputs
        .iter()
        .find(|p| p.name == name)
        .unwrap_or_else(|| panic!("missing output port {name}"))
        .bits
}

/// One block's sample window plus a reusable per-lane gather buffer —
/// what a protocol closure needs to feed features to the simulator.
pub struct BlockIo<'a> {
    xs: &'a [u8],
    features: usize,
    base: usize,
    lanes: usize,
    scratch: Vec<i64>,
}

impl<'a> BlockIo<'a> {
    /// Gather feature `f` of every sample in the block into the lane
    /// buffer and drive it onto `word` (lanes beyond the block's count
    /// are zeroed by [`Sim::set_word_lanes`]).
    pub fn drive_feature(&mut self, sim: &mut Sim, word: &[NetId], f: usize) {
        self.scratch.clear();
        for lane in 0..self.lanes {
            self.scratch.push(self.xs[(self.base + lane) * self.features + f] as i64);
        }
        sim.set_word_lanes(word, &self.scratch);
    }
}

/// The shared block driver both protocols run on: shard `n` samples into
/// super-lane blocks, hand each block's [`BlockIo`] to the protocol
/// closure, then read `class_out` back per lane.
#[allow(clippy::too_many_arguments)]
fn run_blocks<D>(
    plan: &Arc<SimPlan>,
    class_out: &[NetId],
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
    lane_words: usize,
    faults: Option<&FaultList>,
    drive: D,
) -> Vec<u16>
where
    D: Fn(&mut Sim, &mut BlockIo) + Sync,
{
    batch::run_sharded_wide_faulted(plan, n, threads, lane_words, faults, |sim, base, lanes| {
        let mut io = BlockIo {
            xs,
            features,
            base,
            lanes,
            scratch: Vec::with_capacity(lanes),
        };
        drive(sim, &mut io);
        (0..lanes)
            .map(|lane| sim.get_word_lane(class_out, lane) as u16)
            .collect()
    })
}

/// [`run_blocks`] with per-net toggle counting: same sharding, same
/// protocol closure, but every worker profiles activity and the merged
/// [`Activity`] snapshot rides back with the predictions.
#[allow(clippy::too_many_arguments)]
fn run_blocks_activity<D>(
    plan: &Arc<SimPlan>,
    class_out: &[NetId],
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
    lane_words: usize,
    faults: Option<&FaultList>,
    drive: D,
) -> (Vec<u16>, Activity)
where
    D: Fn(&mut Sim, &mut BlockIo) + Sync,
{
    batch::run_sharded_wide_activity(plan, n, threads, lane_words, faults, |sim, base, lanes| {
        let mut io = BlockIo {
            xs,
            features,
            base,
            lanes,
            scratch: Vec::with_capacity(lanes),
        };
        drive(sim, &mut io);
        (0..lanes)
            .map(|lane| sim.get_word_lane(class_out, lane) as u16)
            .collect()
    })
}

/// [`run_blocks`] with activity-gated evaluation (`sim` §Gating): same
/// sharding, same protocol closure, identical predictions, plus the
/// merged executed/skipped run counters — the skip rate is the measured
/// win the benches report.
#[allow(clippy::too_many_arguments)]
fn run_blocks_gated<D>(
    plan: &Arc<SimPlan>,
    class_out: &[NetId],
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
    lane_words: usize,
    faults: Option<&FaultList>,
    drive: D,
) -> (Vec<u16>, GateStats)
where
    D: Fn(&mut Sim, &mut BlockIo) + Sync,
{
    batch::run_sharded_wide_gated(plan, n, threads, lane_words, faults, |sim, base, lanes| {
        let mut io = BlockIo {
            xs,
            features,
            base,
            lanes,
            scratch: Vec::with_capacity(lanes),
        };
        drive(sim, &mut io);
        (0..lanes)
            .map(|lane| sim.get_word_lane(class_out, lane) as u16)
            .collect()
    })
}

/// The sequential I/O protocol (reset pulse, one feature per cycle in
/// RFP order, drain) as a reusable block closure — shared by the plain,
/// faulted, and activity-profiling entry points.
fn seq_drive<'a>(
    circ: &'a SeqCircuit,
    x: &'a [NetId],
    rst: NetId,
) -> impl Fn(&mut Sim, &mut BlockIo) + Sync + 'a {
    move |sim, io| {
        // Reset pulse across every lane word.
        sim.fill(rst, !0u64);
        sim.set_word_all(x, 0);
        sim.step();
        sim.fill(rst, 0);
        // Hidden phase: feature active[t] on the bus at cycle t.
        for t in 0..circ.cycles {
            if t < circ.active.len() {
                io.drive_feature(sim, x, circ.active[t]);
            } else {
                sim.set_word_all(x, 0);
            }
            sim.step();
        }
        sim.settle();
    }
}

/// The combinational protocol (all scheduled features on the flat bus,
/// one evaluation) as a reusable block closure.
fn comb_drive<'a>(
    circ: &'a CombCircuit,
    x_all: &'a [NetId],
) -> impl Fn(&mut Sim, &mut BlockIo) + Sync + 'a {
    move |sim, io| {
        for (slot, &f) in circ.active.iter().enumerate() {
            io.drive_feature(sim, &x_all[slot * 4..(slot + 1) * 4], f);
        }
        sim.eval();
    }
}

/// Run `n` samples (row-major `features`-wide 4-bit values) through a
/// sequential circuit; returns predicted class per sample.  Sharded
/// across [`pool::default_threads`] workers at the default super-lane
/// width.
pub fn run_sequential(circ: &SeqCircuit, xs: &[u8], n: usize, features: usize) -> Vec<u16> {
    run_sequential_threads(circ, xs, n, features, pool::default_threads())
}

/// [`run_sequential`] with an explicit worker count (`1` = serial path).
pub fn run_sequential_threads(
    circ: &SeqCircuit,
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
) -> Vec<u16> {
    run_sequential_plan(circ, &circ.sim_plan(), xs, n, features, threads, 0)
}

/// [`run_sequential_threads`] over an explicit plan and super-lane width
/// (`0` = process default) instead of the circuit's cached plan — how
/// the benches drive the compiled and interpreted paths side by side
/// over the same netlist at every width.
pub fn run_sequential_plan(
    circ: &SeqCircuit,
    plan: &Arc<SimPlan>,
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
    lane_words: usize,
) -> Vec<u16> {
    run_sequential_plan_faulted(circ, plan, xs, n, features, threads, lane_words, None)
}

/// [`run_sequential_plan`] with an optional injected [`FaultList`] — the
/// fault campaign's sequential entry point.  `None` is exactly the clean
/// path; a fault list is lowered once per worker and applied
/// deterministically per block (see [`crate::sim::fault`]).
#[allow(clippy::too_many_arguments)]
pub fn run_sequential_plan_faulted(
    circ: &SeqCircuit,
    plan: &Arc<SimPlan>,
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
    lane_words: usize,
    faults: Option<&FaultList>,
) -> Vec<u16> {
    let net = &circ.netlist;
    let x = input_port(net, "x").clone();
    let rst = input_port(net, "rst")[0];
    let class_out = output_port(net, "class_out").clone();

    run_blocks(
        plan,
        &class_out,
        xs,
        n,
        features,
        threads,
        lane_words,
        faults,
        seq_drive(circ, &x, rst),
    )
}

/// [`run_sequential_plan_faulted`] with per-net toggle counting: returns
/// the (identical) predictions plus the merged [`Activity`] snapshot —
/// the measured-energy path's sequential entry point.  Counts are
/// bit-identical across super-lane widths and thread counts (see `sim`
/// §Activity; enforced by `tests/activity_energy.rs`).
#[allow(clippy::too_many_arguments)]
pub fn run_sequential_plan_activity(
    circ: &SeqCircuit,
    plan: &Arc<SimPlan>,
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
    lane_words: usize,
    faults: Option<&FaultList>,
) -> (Vec<u16>, Activity) {
    let net = &circ.netlist;
    let x = input_port(net, "x").clone();
    let rst = input_port(net, "rst")[0];
    let class_out = output_port(net, "class_out").clone();

    run_blocks_activity(
        plan,
        &class_out,
        xs,
        n,
        features,
        threads,
        lane_words,
        faults,
        seq_drive(circ, &x, rst),
    )
}

/// [`run_sequential_plan_faulted`] with activity-gated evaluation:
/// returns the (identical) predictions plus the merged [`GateStats`] —
/// how the benches and the skip-rate property test measure what gating
/// actually skips on the multi-cycle protocol.
#[allow(clippy::too_many_arguments)]
pub fn run_sequential_plan_gated(
    circ: &SeqCircuit,
    plan: &Arc<SimPlan>,
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
    lane_words: usize,
    faults: Option<&FaultList>,
) -> (Vec<u16>, GateStats) {
    let net = &circ.netlist;
    let x = input_port(net, "x").clone();
    let rst = input_port(net, "rst")[0];
    let class_out = output_port(net, "class_out").clone();

    run_blocks_gated(
        plan,
        &class_out,
        xs,
        n,
        features,
        threads,
        lane_words,
        faults,
        seq_drive(circ, &x, rst),
    )
}

/// Run `n` samples through a combinational circuit (single evaluation
/// per super-lane block).  Sharded across [`pool::default_threads`]
/// workers at the default super-lane width.
pub fn run_combinational(circ: &CombCircuit, xs: &[u8], n: usize, features: usize) -> Vec<u16> {
    run_combinational_threads(circ, xs, n, features, pool::default_threads())
}

/// [`run_combinational`] with an explicit worker count (`1` = serial path).
pub fn run_combinational_threads(
    circ: &CombCircuit,
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
) -> Vec<u16> {
    run_combinational_plan(circ, &circ.sim_plan(), xs, n, features, threads, 0)
}

/// [`run_combinational_threads`] over an explicit plan and super-lane
/// width (see [`run_sequential_plan`]).
pub fn run_combinational_plan(
    circ: &CombCircuit,
    plan: &Arc<SimPlan>,
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
    lane_words: usize,
) -> Vec<u16> {
    run_combinational_plan_faulted(circ, plan, xs, n, features, threads, lane_words, None)
}

/// [`run_combinational_plan`] with an optional injected [`FaultList`]
/// (see [`run_sequential_plan_faulted`]).
#[allow(clippy::too_many_arguments)]
pub fn run_combinational_plan_faulted(
    circ: &CombCircuit,
    plan: &Arc<SimPlan>,
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
    lane_words: usize,
    faults: Option<&FaultList>,
) -> Vec<u16> {
    let net = &circ.netlist;
    let x_all = input_port(net, "x_all").clone();
    let class_out = output_port(net, "class_out").clone();
    assert_eq!(x_all.len(), 4 * circ.active.len());

    run_blocks(
        plan,
        &class_out,
        xs,
        n,
        features,
        threads,
        lane_words,
        faults,
        comb_drive(circ, &x_all),
    )
}

/// [`run_combinational_plan_faulted`] with per-net toggle counting (see
/// [`run_sequential_plan_activity`]).
#[allow(clippy::too_many_arguments)]
pub fn run_combinational_plan_activity(
    circ: &CombCircuit,
    plan: &Arc<SimPlan>,
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
    lane_words: usize,
    faults: Option<&FaultList>,
) -> (Vec<u16>, Activity) {
    let net = &circ.netlist;
    let x_all = input_port(net, "x_all").clone();
    let class_out = output_port(net, "class_out").clone();
    assert_eq!(x_all.len(), 4 * circ.active.len());

    run_blocks_activity(
        plan,
        &class_out,
        xs,
        n,
        features,
        threads,
        lane_words,
        faults,
        comb_drive(circ, &x_all),
    )
}

/// Accuracy helper shared by the harnesses.
pub fn accuracy(preds: &[u16], ys: &[u16]) -> f64 {
    let correct = preds.iter().zip(ys).filter(|(p, y)| p == y).count();
    correct as f64 / ys.len().max(1) as f64
}
