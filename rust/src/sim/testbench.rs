//! Testbenches: drive the generated circuits with dataset samples using
//! the paper's I/O protocol and collect predictions.
//!
//! Sequential protocol (Fig. 3b): a 1-cycle reset pulse, then one 4-bit
//! feature per cycle in the RFP schedule order, then `hidden + classes`
//! drain cycles; `class_out` is valid after the final argmax cycle.
//!
//! 64 samples are simulated per pass (one per lane).

use crate::circuits::{CombCircuit, SeqCircuit};
use crate::netlist::{Netlist, Word};
use crate::sim::Sim;

fn input_port<'a>(n: &'a Netlist, name: &str) -> &'a Word {
    &n.inputs
        .iter()
        .find(|p| p.name == name)
        .unwrap_or_else(|| panic!("missing input port {name}"))
        .bits
}

fn output_port<'a>(n: &'a Netlist, name: &str) -> &'a Word {
    &n.outputs
        .iter()
        .find(|p| p.name == name)
        .unwrap_or_else(|| panic!("missing output port {name}"))
        .bits
}

/// Run `n` samples (row-major `features`-wide 4-bit values) through a
/// sequential circuit; returns predicted class per sample.
pub fn run_sequential(circ: &SeqCircuit, xs: &[u8], n: usize, features: usize) -> Vec<u16> {
    let net = &circ.netlist;
    let x = input_port(net, "x").clone();
    let rst = input_port(net, "rst")[0];
    let class_out = output_port(net, "class_out").clone();

    let mut sim = Sim::new(net);
    let mut preds = Vec::with_capacity(n);
    let mut lane_vals = vec![0i64; Sim::LANES];

    let mut base = 0usize;
    while base < n {
        let lanes = (n - base).min(Sim::LANES);
        // Reset pulse.
        sim.set(rst, !0u64);
        sim.set_word_all(&x, 0);
        sim.step();
        sim.set(rst, 0);
        // Hidden phase: feature active[t] on the bus at cycle t.
        for t in 0..circ.cycles {
            if t < circ.active.len() {
                let f = circ.active[t];
                for lane in 0..lanes {
                    lane_vals[lane] = xs[(base + lane) * features + f] as i64;
                }
                sim.set_word_lanes(&x, &lane_vals[..lanes]);
            } else {
                sim.set_word_all(&x, 0);
            }
            sim.step();
        }
        sim.settle();
        for lane in 0..lanes {
            preds.push(sim.get_word_lane(&class_out, lane) as u16);
        }
        base += lanes;
    }
    preds
}

/// Run `n` samples through a combinational circuit (single evaluation).
pub fn run_combinational(circ: &CombCircuit, xs: &[u8], n: usize, features: usize) -> Vec<u16> {
    let net = &circ.netlist;
    let x_all = input_port(net, "x_all").clone();
    let class_out = output_port(net, "class_out").clone();
    assert_eq!(x_all.len(), 4 * circ.active.len());

    let mut sim = Sim::new(net);
    let mut preds = Vec::with_capacity(n);
    let mut base = 0usize;
    let mut lane_vals = vec![0i64; Sim::LANES];
    while base < n {
        let lanes = (n - base).min(Sim::LANES);
        for (slot, &f) in circ.active.iter().enumerate() {
            let word: Word = x_all[slot * 4..(slot + 1) * 4].to_vec();
            for lane in 0..lanes {
                lane_vals[lane] = xs[(base + lane) * features + f] as i64;
            }
            sim.set_word_lanes(&word, &lane_vals[..lanes]);
        }
        sim.eval();
        for lane in 0..lanes {
            preds.push(sim.get_word_lane(&class_out, lane) as u16);
        }
        base += lanes;
    }
    preds
}

/// Accuracy helper shared by the harnesses.
pub fn accuracy(preds: &[u16], ys: &[u16]) -> f64 {
    let correct = preds.iter().zip(ys).filter(|(p, y)| p == y).count();
    correct as f64 / ys.len().max(1) as f64
}
