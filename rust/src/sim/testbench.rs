//! Testbenches: drive the generated circuits with dataset samples using
//! the paper's I/O protocol and collect predictions.
//!
//! Sequential protocol (Fig. 3b): a 1-cycle reset pulse, then one 4-bit
//! feature per cycle in the RFP schedule order, then `hidden + classes`
//! drain cycles; `class_out` is valid after the final argmax cycle.
//!
//! 64 samples are simulated per pass (one per lane), and passes are
//! sharded across worker threads via [`batch::run_sharded`]: the circuit's
//! levelized [`crate::sim::SimPlan`] is built once (cached on the circuit,
//! compiled to the micro-op stream unless
//! [`crate::sim::compile_default`] is off) and shared read-only by every
//! worker.  `run_sequential` / `run_combinational` use
//! [`pool::default_threads`] (`PRINTED_MLP_THREADS` overrides); the
//! `*_threads` variants take an explicit count — `1` is the exact serial
//! path the differential tests compare against — and the `*_plan`
//! variants take an explicit plan, which is how the benches drive the
//! compiled and interpreted paths over the same netlist.

use crate::circuits::{CombCircuit, SeqCircuit};
use crate::netlist::{Netlist, Word};
use crate::sim::{batch, Sim};
use crate::util::pool;

fn input_port<'a>(n: &'a Netlist, name: &str) -> &'a Word {
    &n.inputs
        .iter()
        .find(|p| p.name == name)
        .unwrap_or_else(|| panic!("missing input port {name}"))
        .bits
}

fn output_port<'a>(n: &'a Netlist, name: &str) -> &'a Word {
    &n.outputs
        .iter()
        .find(|p| p.name == name)
        .unwrap_or_else(|| panic!("missing output port {name}"))
        .bits
}

/// Run `n` samples (row-major `features`-wide 4-bit values) through a
/// sequential circuit; returns predicted class per sample.  Sharded
/// across [`pool::default_threads`] workers.
pub fn run_sequential(circ: &SeqCircuit, xs: &[u8], n: usize, features: usize) -> Vec<u16> {
    run_sequential_threads(circ, xs, n, features, pool::default_threads())
}

/// [`run_sequential`] with an explicit worker count (`1` = serial path).
pub fn run_sequential_threads(
    circ: &SeqCircuit,
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
) -> Vec<u16> {
    run_sequential_plan(circ, &circ.sim_plan(), xs, n, features, threads)
}

/// [`run_sequential_threads`] over an explicit plan instead of the
/// circuit's cached one — how the benches drive the compiled and
/// interpreted paths side by side over the same netlist.
pub fn run_sequential_plan(
    circ: &SeqCircuit,
    plan: &std::sync::Arc<crate::sim::SimPlan>,
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
) -> Vec<u16> {
    let net = &circ.netlist;
    let x = input_port(net, "x").clone();
    let rst = input_port(net, "rst")[0];
    let class_out = output_port(net, "class_out").clone();

    batch::run_sharded(plan, n, threads, |sim, base, lanes| {
        let mut lane_vals = [0i64; Sim::LANES];
        // Reset pulse.
        sim.set(rst, !0u64);
        sim.set_word_all(&x, 0);
        sim.step();
        sim.set(rst, 0);
        // Hidden phase: feature active[t] on the bus at cycle t.
        for t in 0..circ.cycles {
            if t < circ.active.len() {
                let f = circ.active[t];
                for lane in 0..lanes {
                    lane_vals[lane] = xs[(base + lane) * features + f] as i64;
                }
                sim.set_word_lanes(&x, &lane_vals[..lanes]);
            } else {
                sim.set_word_all(&x, 0);
            }
            sim.step();
        }
        sim.settle();
        (0..lanes)
            .map(|lane| sim.get_word_lane(&class_out, lane) as u16)
            .collect()
    })
}

/// Run `n` samples through a combinational circuit (single evaluation per
/// 64-lane block).  Sharded across [`pool::default_threads`] workers.
pub fn run_combinational(circ: &CombCircuit, xs: &[u8], n: usize, features: usize) -> Vec<u16> {
    run_combinational_threads(circ, xs, n, features, pool::default_threads())
}

/// [`run_combinational`] with an explicit worker count (`1` = serial path).
pub fn run_combinational_threads(
    circ: &CombCircuit,
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
) -> Vec<u16> {
    run_combinational_plan(circ, &circ.sim_plan(), xs, n, features, threads)
}

/// [`run_combinational_threads`] over an explicit plan (see
/// [`run_sequential_plan`]).
pub fn run_combinational_plan(
    circ: &CombCircuit,
    plan: &std::sync::Arc<crate::sim::SimPlan>,
    xs: &[u8],
    n: usize,
    features: usize,
    threads: usize,
) -> Vec<u16> {
    let net = &circ.netlist;
    let x_all = input_port(net, "x_all").clone();
    let class_out = output_port(net, "class_out").clone();
    assert_eq!(x_all.len(), 4 * circ.active.len());

    batch::run_sharded(plan, n, threads, |sim, base, lanes| {
        let mut lane_vals = [0i64; Sim::LANES];
        for (slot, &f) in circ.active.iter().enumerate() {
            for lane in 0..lanes {
                lane_vals[lane] = xs[(base + lane) * features + f] as i64;
            }
            sim.set_word_lanes(&x_all[slot * 4..(slot + 1) * 4], &lane_vals[..lanes]);
        }
        sim.eval();
        (0..lanes)
            .map(|lane| sim.get_word_lane(&class_out, lane) as u16)
            .collect()
    })
}

/// Accuracy helper shared by the harnesses.
pub fn accuracy(preds: &[u16], ys: &[u16]) -> f64 {
    let correct = preds.iter().zip(ys).filter(|(p, y)| p == y).count();
    correct as f64 / ys.len().max(1) as f64
}
