//! Sharded batch simulation: split an `n`-sample workload into
//! super-lane blocks of `W·64` samples and dispatch them across worker
//! threads, each worker owning its own [`Sim`] built from a shared
//! levelized [`SimPlan`].
//!
//! Correctness: every primitive-cell update in [`Sim`] is a bitwise
//! (per-lane) operation, so a sample's outputs depend only on its own
//! lane regardless of which block, lane word, or worker simulated it.
//! Sharded and wide runs are therefore bit-identical to the serial
//! 64-lane path — enforced by the differential suites in
//! `tests/sim_sharding.rs` and `tests/sim_compiled.rs` (W-sweep).
//!
//! Scheduling: blocks are claimed from an atomic cursor
//! ([`scope_map_with`]), so uneven per-block cost balances automatically;
//! the plan — including a compiled plan's micro-op stream and port map
//! ([`crate::sim::SimPlan::compiled`]), which is built once per netlist,
//! never per worker — is shared read-only, and each worker allocates its
//! two state vectors once, not once per block.  The super-lane width
//! (`u64` words per net) defaults to [`crate::sim::lane_words_default`];
//! a partial final block is masked by the drive helpers
//! ([`Sim::set_word_lanes`] zeroes lanes beyond the block's count) and
//! lanes beyond the count are never read.

use std::sync::Arc;

use crate::sim::fault::FaultList;
use crate::sim::{Activity, GateStats, Sim, SimPlan};
use crate::util::pool::scope_map_with;

/// Samples per block at a given super-lane width (`W·64`).
pub fn block_lanes(lane_words: usize) -> usize {
    lane_words * Sim::LANES
}

/// Number of `W·64`-sample blocks needed for `n` samples.
pub fn n_blocks(n: usize, lane_words: usize) -> usize {
    n.div_ceil(block_lanes(lane_words))
}

/// [`run_sharded_wide`] at the process-wide default super-lane width.
pub fn run_sharded<T, F>(plan: &Arc<SimPlan>, n: usize, threads: usize, drive: F) -> Vec<T>
where
    T: Send,
    F: Fn(&mut Sim, usize, usize) -> Vec<T> + Sync,
{
    run_sharded_wide(plan, n, threads, 0, drive)
}

/// Run `n` samples through `drive`, sharded across up to `threads`
/// workers in super-lane blocks of `lane_words * 64` samples (0 =
/// [`crate::sim::lane_words_default`]), and concatenate the per-block
/// results in sample order.
///
/// `drive` is called once per block with a simulator over `plan`, the
/// block's base sample index, and its lane count ([`Sim::lanes`] except
/// for a smaller final partial block).  It must return one result per
/// lane.
///
/// With `threads <= 1` (or a single block) no threads are spawned: the
/// calling thread reuses one simulator across blocks, matching the
/// pre-sharding behaviour exactly.  Lane isolation makes reuse safe: a
/// sequential driver re-pulses reset per block, and lanes beyond a
/// block's count are never read.
pub fn run_sharded_wide<T, F>(
    plan: &Arc<SimPlan>,
    n: usize,
    threads: usize,
    lane_words: usize,
    drive: F,
) -> Vec<T>
where
    T: Send,
    F: Fn(&mut Sim, usize, usize) -> Vec<T> + Sync,
{
    run_sharded_wide_faulted(plan, n, threads, lane_words, None, drive)
}

/// [`run_sharded_wide`] with an optional injected fault list: every
/// worker simulator carries the same lowered faults, and each block is
/// announced via [`Sim::fault_begin_block`] before `drive` runs, so
/// transient flips key on the block's absolute sample base — sharded,
/// wide, and serial fault runs stay bit-identical (block bases are
/// multiples of `W·64` for every valid width, and every block executes
/// the same eval sequence).
pub fn run_sharded_wide_faulted<T, F>(
    plan: &Arc<SimPlan>,
    n: usize,
    threads: usize,
    lane_words: usize,
    faults: Option<&FaultList>,
    drive: F,
) -> Vec<T>
where
    T: Send,
    F: Fn(&mut Sim, usize, usize) -> Vec<T> + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let w = if lane_words == 0 {
        crate::sim::lane_words_default()
    } else {
        lane_words
    };
    let bl = block_lanes(w);
    let blocks = n.div_ceil(bl);
    let shards = scope_map_with(
        blocks,
        threads.clamp(1, blocks),
        || {
            let mut sim = Sim::from_plan_wide(plan.clone(), w);
            if let Some(fl) = faults {
                sim.set_faults(fl);
            }
            sim
        },
        |sim, b| {
            let base = b * bl;
            let lanes = (n - base).min(bl);
            sim.fault_begin_block(base);
            drive(sim, base, lanes)
        },
    );
    shards.into_iter().flatten().collect()
}

/// [`run_sharded_wide_faulted`] with per-net toggle counting turned on:
/// every worker simulator profiles activity, each block announces its
/// lane count via [`Sim::activity_begin_block`] (masking zero-padded
/// partial tail lanes and canonicalizing reused worker state), and the
/// per-block [`Activity`] snapshots are summed after the join — so the
/// total counts are bit-identical across super-lane widths, thread
/// counts, and block→worker schedules (see `sim` §Activity).
pub fn run_sharded_wide_activity<T, F>(
    plan: &Arc<SimPlan>,
    n: usize,
    threads: usize,
    lane_words: usize,
    faults: Option<&FaultList>,
    drive: F,
) -> (Vec<T>, Activity)
where
    T: Send,
    F: Fn(&mut Sim, usize, usize) -> Vec<T> + Sync,
{
    if n == 0 {
        return (Vec::new(), Activity::default());
    }
    let w = if lane_words == 0 {
        crate::sim::lane_words_default()
    } else {
        lane_words
    };
    let bl = block_lanes(w);
    let blocks = n.div_ceil(bl);
    let shards = scope_map_with(
        blocks,
        threads.clamp(1, blocks),
        || {
            let mut sim = Sim::from_plan_wide(plan.clone(), w);
            if let Some(fl) = faults {
                sim.set_faults(fl);
            }
            sim.set_activity(true);
            sim
        },
        |sim, b| {
            let base = b * bl;
            let lanes = (n - base).min(bl);
            sim.fault_begin_block(base);
            sim.activity_begin_block(lanes);
            let out = drive(sim, base, lanes);
            (out, sim.take_activity())
        },
    );
    let mut activity = Activity::default();
    let mut outs = Vec::with_capacity(n);
    for (out, act) in shards {
        outs.extend(out);
        activity.merge(&act);
    }
    (outs, activity)
}

/// [`run_sharded_wide_faulted`] with activity-gated evaluation turned on
/// (`sim` §Gating): every worker simulator skips homogeneous opcode runs
/// whose input blocks are clean, and the per-worker executed/skipped
/// counters are summed after the join.  Predictions are bit-identical to
/// the ungated runner at every width, thread count, and fault list (the
/// gating differential suite enforces it); the stats are diagnostic —
/// the skip rate is what the benches report.  On interpreted plans
/// gating is a no-op and the stats come back zero.
pub fn run_sharded_wide_gated<T, F>(
    plan: &Arc<SimPlan>,
    n: usize,
    threads: usize,
    lane_words: usize,
    faults: Option<&FaultList>,
    drive: F,
) -> (Vec<T>, GateStats)
where
    T: Send,
    F: Fn(&mut Sim, usize, usize) -> Vec<T> + Sync,
{
    if n == 0 {
        return (Vec::new(), GateStats::default());
    }
    let w = if lane_words == 0 {
        crate::sim::lane_words_default()
    } else {
        lane_words
    };
    let bl = block_lanes(w);
    let blocks = n.div_ceil(bl);
    let shards = scope_map_with(
        blocks,
        threads.clamp(1, blocks),
        || {
            let mut sim = Sim::from_plan_wide(plan.clone(), w);
            if let Some(fl) = faults {
                sim.set_faults(fl);
            }
            sim.set_gating(true);
            sim
        },
        |sim, b| {
            let base = b * bl;
            let lanes = (n - base).min(bl);
            sim.fault_begin_block(base);
            let out = drive(sim, base, lanes);
            (out, sim.take_gate_stats())
        },
    );
    let mut stats = GateStats::default();
    let mut outs = Vec::with_capacity(n);
    for (out, st) in shards {
        outs.extend(out);
        stats.merge(&st);
    }
    (outs, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::Netlist;

    #[test]
    fn block_math() {
        assert_eq!(n_blocks(1, 1), 1);
        assert_eq!(n_blocks(64, 1), 1);
        assert_eq!(n_blocks(65, 1), 2);
        assert_eq!(n_blocks(130, 1), 3);
        // Wider blocks absorb more samples per pass.
        assert_eq!(block_lanes(4), 256);
        assert_eq!(n_blocks(130, 4), 1);
        assert_eq!(n_blocks(256, 4), 1);
        assert_eq!(n_blocks(257, 4), 2);
        assert_eq!(n_blocks(1, 8), 1);
    }

    #[test]
    fn sharded_equals_serial_on_partial_blocks() {
        // y = a XOR b, driven per-lane with sample data; results must be
        // identical for 1 thread, many threads, every width, and any n
        // (incl. n < one block and a partial final block).
        let mut net = Netlist::new("t");
        let a = net.add_input("a", 1)[0];
        let b = net.add_input("b", 1)[0];
        let y = net.xor2(a, b);
        net.add_output("y", vec![y]);
        let plan = Arc::new(SimPlan::new(&net));

        let data: Vec<(u8, u8)> =
            (0..300u32).map(|i| ((i % 2) as u8, ((i / 2) % 2) as u8)).collect();
        let drive = |sim: &mut Sim, base: usize, lanes: usize| -> Vec<u8> {
            let va: Vec<i64> = (0..lanes).map(|l| data[base + l].0 as i64).collect();
            let vb: Vec<i64> = (0..lanes).map(|l| data[base + l].1 as i64).collect();
            sim.set_word_lanes(&[a], &va);
            sim.set_word_lanes(&[b], &vb);
            sim.eval();
            (0..lanes).map(|lane| sim.get_word_lane(&[y], lane) as u8).collect()
        };

        for n in [1usize, 63, 64, 65, 130, 300] {
            let want: Vec<u8> = data[..n].iter().map(|&(x, z)| x ^ z).collect();
            for w in crate::sim::LANE_WORD_CHOICES {
                let serial = run_sharded_wide(&plan, n, 1, w, drive);
                let sharded = run_sharded_wide(&plan, n, 4, w, drive);
                assert_eq!(serial, want, "serial n={n} w={w}");
                assert_eq!(sharded, want, "sharded n={n} w={w}");
            }
        }
    }

    #[test]
    fn default_width_entry_point_matches_explicit() {
        let mut net = Netlist::new("t");
        let a = net.add_input("a", 1)[0];
        net.add_output("y", vec![a]);
        let plan = Arc::new(SimPlan::new(&net));
        let vals: Vec<i64> = (0..100).map(|i| (i % 2) as i64).collect();
        let drive = |sim: &mut Sim, base: usize, lanes: usize| -> Vec<u8> {
            sim.set_word_lanes(&[a], &vals[base..base + lanes]);
            sim.eval();
            (0..lanes).map(|lane| sim.get_word_lane(&[a], lane) as u8).collect()
        };
        let auto = run_sharded(&plan, 100, 2, drive);
        let w1 = run_sharded_wide(&plan, 100, 2, 1, drive);
        assert_eq!(auto, w1);
    }

    #[test]
    fn activity_counts_invariant_across_widths_threads_and_blocks() {
        // Toggle totals must not depend on how samples are split into
        // blocks, which worker ran a block, or the super-lane width —
        // including a partial final block.
        let mut net = Netlist::new("t");
        let a = net.add_input("a", 1)[0];
        let b = net.add_input("b", 1)[0];
        let y = net.xor2(a, b);
        net.add_output("y", vec![y]);
        let plan = Arc::new(SimPlan::new(&net));

        let data: Vec<(u8, u8)> =
            (0..300u32).map(|i| ((i % 3 % 2) as u8, ((i / 2) % 2) as u8)).collect();
        let drive = |sim: &mut Sim, base: usize, lanes: usize| -> Vec<u8> {
            let va: Vec<i64> = (0..lanes).map(|l| data[base + l].0 as i64).collect();
            let vb: Vec<i64> = (0..lanes).map(|l| data[base + l].1 as i64).collect();
            sim.set_word_lanes(&[a], &va);
            sim.set_word_lanes(&[b], &vb);
            sim.eval();
            (0..lanes).map(|lane| sim.get_word_lane(&[y], lane) as u8).collect()
        };

        for n in [1usize, 65, 300] {
            let mut reference: Option<u64> = None;
            for w in crate::sim::LANE_WORD_CHOICES {
                for threads in [1usize, 4] {
                    let (out, act) =
                        run_sharded_wide_activity(&plan, n, threads, w, None, drive);
                    assert_eq!(out.len(), n);
                    let total = act.total_toggles();
                    match reference {
                        None => reference = Some(total),
                        Some(r) => {
                            assert_eq!(total, r, "n={n} w={w} threads={threads}")
                        }
                    }
                }
            }
            // Each fresh block starts from the canonical zero state, so
            // the XOR output toggles exactly once per lane where a^b=1.
            let want: u64 =
                data[..n].iter().filter(|&&(x, z)| x ^ z == 1).count() as u64;
            assert_eq!(reference.unwrap(), want, "n={n}");
        }
    }

    #[test]
    fn empty_workload() {
        let mut net = Netlist::new("t");
        let a = net.add_input("a", 1)[0];
        net.add_output("y", vec![a]);
        let plan = Arc::new(SimPlan::new(&net));
        let out: Vec<u8> = run_sharded(&plan, 0, 8, |_, _, _| vec![]);
        assert!(out.is_empty());
    }
}
