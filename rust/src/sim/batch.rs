//! Sharded batch simulation: split an `n`-sample workload into 64-lane
//! blocks and dispatch them across worker threads, each worker owning its
//! own [`Sim`] built from a shared levelized [`SimPlan`].
//!
//! Correctness: every primitive-cell update in [`Sim`] is a bitwise
//! (per-lane) operation, so a sample's outputs depend only on its own
//! lane regardless of which block or worker simulated it.  Sharded runs
//! are therefore bit-identical to the serial path — enforced by the
//! differential suite in `tests/sim_sharding.rs`.
//!
//! Scheduling: blocks are claimed from an atomic cursor
//! ([`scope_map_with`]), so uneven per-block cost balances automatically;
//! the plan — including a compiled plan's micro-op stream and port map
//! ([`crate::sim::SimPlan::compiled`]), which is built once per netlist,
//! never per worker — is shared read-only, and each worker allocates its
//! two `u64` state vectors once, not once per block.

use std::sync::Arc;

use crate::sim::{Sim, SimPlan};
use crate::util::pool::scope_map_with;

/// Number of 64-lane blocks needed for `n` samples.
pub fn n_blocks(n: usize) -> usize {
    n.div_ceil(Sim::LANES)
}

/// Run `n` samples through `drive`, sharded across up to `threads`
/// workers, and concatenate the per-block results in sample order.
///
/// `drive` is called once per block with a simulator over `plan`, the
/// block's base sample index, and its lane count (`Sim::LANES` except for
/// a smaller final partial block).  It must return one result per lane.
///
/// With `threads <= 1` (or a single block) no threads are spawned: the
/// calling thread reuses one simulator across blocks, matching the
/// pre-sharding behaviour exactly.  Lane isolation makes reuse safe: a
/// sequential driver re-pulses reset per block, and lanes beyond a
/// block's count are never read.
pub fn run_sharded<T, F>(plan: &Arc<SimPlan>, n: usize, threads: usize, drive: F) -> Vec<T>
where
    T: Send,
    F: Fn(&mut Sim, usize, usize) -> Vec<T> + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let blocks = n_blocks(n);
    let shards = scope_map_with(
        blocks,
        threads.clamp(1, blocks),
        || Sim::from_plan(plan.clone()),
        |sim, b| {
            let base = b * Sim::LANES;
            let lanes = (n - base).min(Sim::LANES);
            drive(sim, base, lanes)
        },
    );
    shards.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::Netlist;

    #[test]
    fn block_math() {
        assert_eq!(n_blocks(1), 1);
        assert_eq!(n_blocks(64), 1);
        assert_eq!(n_blocks(65), 2);
        assert_eq!(n_blocks(130), 3);
    }

    #[test]
    fn sharded_equals_serial_on_partial_blocks() {
        // y = a XOR b, driven per-lane with sample data; results must be
        // identical for 1 thread, many threads, and any n (incl. n < 64
        // and a partial final block).
        let mut net = Netlist::new("t");
        let a = net.add_input("a", 1)[0];
        let b = net.add_input("b", 1)[0];
        let y = net.xor2(a, b);
        net.add_output("y", vec![y]);
        let plan = Arc::new(SimPlan::new(&net));

        let data: Vec<(u8, u8)> = (0..130u32).map(|i| ((i % 2) as u8, ((i / 2) % 2) as u8)).collect();
        let drive = |sim: &mut Sim, base: usize, lanes: usize| -> Vec<u8> {
            let mut pa = 0u64;
            let mut pb = 0u64;
            for lane in 0..lanes {
                pa |= (data[base + lane].0 as u64) << lane;
                pb |= (data[base + lane].1 as u64) << lane;
            }
            sim.set(a, pa);
            sim.set(b, pb);
            sim.eval();
            let py = sim.get(y);
            (0..lanes).map(|lane| ((py >> lane) & 1) as u8).collect()
        };

        for n in [1usize, 63, 64, 65, 130] {
            let serial = run_sharded(&plan, n, 1, drive);
            let sharded = run_sharded(&plan, n, 4, drive);
            let want: Vec<u8> = data[..n].iter().map(|&(x, z)| x ^ z).collect();
            assert_eq!(serial, want, "serial n={n}");
            assert_eq!(sharded, want, "sharded n={n}");
        }
    }

    #[test]
    fn empty_workload() {
        let mut net = Netlist::new("t");
        let a = net.add_input("a", 1)[0];
        net.add_output("y", vec![a]);
        let plan = Arc::new(SimPlan::new(&net));
        let out: Vec<u8> = run_sharded(&plan, 0, 8, |_, _, _| vec![]);
        assert!(out.is_empty());
    }
}
