//! Fault injection for the gate-level simulator (DESIGN.md §Faults).
//!
//! Printed electronics' low yield and device variability mean a deployed
//! sequential PMLP sees **stuck-at** faults (a net welded to 0 or 1 by a
//! printing defect) and **transient** bit-flips (supply droop, coupling)
//! that the clean simulator never measures.  This module injects both at
//! simulation time, on top of an unmodified [`SimPlan`]:
//!
//! - A [`Fault`] names a *source-netlist* net and a [`FaultKind`].  At
//!   [`crate::sim::Sim::set_faults`] time the list is lowered against the
//!   simulator's plan into a [`FaultState`]: each fault becomes a per-net
//!   `(and_mask, or_mask)` pair applied to the net's lane words
//!   (`v = (v & and) | or`) — stuck-at-0 is `(0, 0)`, stuck-at-1 is
//!   `(!0, !0)`, and a transient fault additionally XORs in a
//!   seed-deterministic flip mask.
//! - Masks are applied **after the micro-op run (or interpreted cell)
//!   that produces the net**, so every downstream reader observes the
//!   corrupted value; nets written externally (primary inputs, register
//!   state, undriven nets) are forced *before* combinational propagation
//!   instead.  On compiled plans a run that merged across levels could
//!   let a same-run reader see the clean value, so the opcode-run
//!   schedule is re-split at each faulted producer (the split schedule
//!   lives here; the fault-free path executes the original runs
//!   untouched).  Activity profiling (`sim` §Activity) counts toggles at
//!   the producing store, strictly *before* this mask application — a
//!   forced transition is a defect, not switching activity, so fault
//!   campaigns never double-count it (and source-net forces touch only
//!   producer-less slots the counters never attribute; regression in
//!   `tests/fault_injection.rs`).
//! - Determinism: stuck masks are lane-uniform, so they cannot depend on
//!   batching.  Transient flip masks are keyed on
//!   `(seed, net, cycle-in-block, global word index)` where the global
//!   word index is `base_sample/64 + word` — block bases are multiples
//!   of `W·64`, so the mask a sample sees is identical for every
//!   super-lane width `W ∈ {1,2,4,8}`, any thread count, and the
//!   interpreted oracle (`tests/fault_injection.rs` differentials).
//!   [`crate::sim::Sim::fault_begin_block`] pins the block base and
//!   resets the cycle counter; the sharded driver calls it per block.
//!
//! Fault sites are restricted to nets the plan actually materializes
//! ([`SimPlan::faultable`]): a net strength reduction folded away has no
//! slot of its own, and forcing its survivor would corrupt a *different*
//! net than the one named.  [`FaultList::sample`] draws sites from the
//! netlist's [`NetRole`] classification, so campaigns can target inputs,
//! register state, or the combinational cloud separately.

use std::sync::Arc;

use crate::netlist::{NetId, Netlist, NetRole};
use crate::sim::SimPlan;
use crate::util::prng::Rng;

/// What a fault does to its net.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Net welded low: every lane reads 0.
    StuckAt0,
    /// Net welded high: every lane reads 1.
    StuckAt1,
    /// Seed-deterministic per-cycle bit-flips at the list's `flip_rate`.
    Transient,
}

impl FaultKind {
    pub fn label(self) -> &'static str {
        match self {
            FaultKind::StuckAt0 => "sa0",
            FaultKind::StuckAt1 => "sa1",
            FaultKind::Transient => "flip",
        }
    }
}

/// One injected fault on a source-netlist net.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fault {
    pub net: NetId,
    pub kind: FaultKind,
}

/// A reproducible set of faults plus the transient-flip parameters —
/// what campaigns sweep and evaluators carry.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultList {
    pub faults: Vec<Fault>,
    /// Seed for the transient flip masks (also records the sampling
    /// seed when the list came from [`FaultList::sample`]).
    pub seed: u64,
    /// Per-bit flip probability for [`FaultKind::Transient`] faults.
    pub flip_rate: f64,
}

impl FaultList {
    /// Draw `n_stuck` stuck-at faults (polarity coin-flipped per site)
    /// and `n_transient` transient faults on distinct nets whose role is
    /// in `roles` and which the plan materializes ([`SimPlan::faultable`]).
    /// Deterministic in `seed`; counts clip to the candidate pool.
    pub fn sample(
        plan: &SimPlan,
        netlist: &Netlist,
        roles: &[NetRole],
        n_stuck: usize,
        n_transient: usize,
        flip_rate: f64,
        seed: u64,
    ) -> FaultList {
        let all_roles = netlist.net_roles();
        let candidates: Vec<NetId> = (0..netlist.n_nets() as NetId)
            .filter(|&id| roles.contains(&all_roles[id as usize]) && plan.faultable(id))
            .collect();
        let mut rng = Rng::new(seed);
        let want = (n_stuck + n_transient).min(candidates.len());
        let stuck = n_stuck.min(want);
        let picked = rng.sample_indices(candidates.len(), want);
        let faults = picked
            .iter()
            .enumerate()
            .map(|(k, &ci)| Fault {
                net: candidates[ci],
                kind: if k < stuck {
                    if rng.chance(0.5) {
                        FaultKind::StuckAt1
                    } else {
                        FaultKind::StuckAt0
                    }
                } else {
                    FaultKind::Transient
                },
            })
            .collect();
        FaultList {
            faults,
            seed,
            flip_rate,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn stuck_count(&self) -> usize {
        self.faults.iter().filter(|f| f.kind != FaultKind::Transient).count()
    }

    pub fn transient_count(&self) -> usize {
        self.faults.len() - self.stuck_count()
    }
}

/// A fault lowered against one plan: the value slot it forces plus the
/// precomputed lane masks.
#[derive(Clone, Debug)]
pub(crate) struct ActiveFault {
    pub(crate) slot: u32,
    pub(crate) and_mask: u64,
    pub(crate) or_mask: u64,
    pub(crate) transient: bool,
    /// Source-netlist id — the transient flip-mask key, so every plan
    /// form and width draws identical masks for the same fault.
    pub(crate) net: NetId,
}

impl ActiveFault {
    fn new(slot: u32, net: NetId, kind: FaultKind) -> ActiveFault {
        let (and_mask, or_mask, transient) = match kind {
            FaultKind::StuckAt0 => (0, 0, false),
            FaultKind::StuckAt1 => (!0, !0, false),
            FaultKind::Transient => (!0, 0, true),
        };
        ActiveFault {
            slot,
            and_mask,
            or_mask,
            transient,
            net,
        }
    }
}

/// A [`FaultList`] lowered against one [`SimPlan`], ready for the eval
/// loop: source-net faults, a producer-indexed schedule, and (for
/// compiled plans with scheduled faults) the opcode-run schedule
/// re-split at each faulted producer.
#[derive(Clone, Debug)]
pub(crate) struct FaultState {
    /// Faults on externally-written slots (inputs, register state,
    /// undriven nets): forced before combinational propagation and
    /// stuck-re-forced after the register commit.
    pub(crate) sources: Vec<ActiveFault>,
    /// Faults on combinationally produced slots, keyed by producer:
    /// the index into [`FaultState::runs`] on compiled plans (the run
    /// ending at the producing op), or the producing cell's position in
    /// the interpreted topological order.  Sorted ascending; applied by
    /// a cursor walk as eval advances.
    pub(crate) scheduled: Vec<(u32, ActiveFault)>,
    /// Compiled plans only: the plan's opcode runs split so every
    /// faulted producer ends a run — a run merged across levels may
    /// otherwise contain a reader of the faulted net.  `None` when no
    /// fault needs mid-stream application (the plan's own runs execute).
    pub(crate) runs: Option<Vec<(u8, u32, u32)>>,
    /// Per-run gate lists for the split run table (`Some` exactly when
    /// `runs` is) — activity gating composes with run re-splitting
    /// because the lists are rebuilt from whichever table executes.
    /// Runs with scheduled transient flips are pinned hot: the flip mask
    /// changes every eval, so the producing store must never be skipped
    /// (a stale store would be double-flipped).
    pub(crate) run_gates: Option<crate::sim::RunGates>,
    seed: u64,
    /// Flip probability in 24-bit fixed point (`P = rate_q24 / 2^24`).
    rate_q24: u64,
    /// Evals since [`FaultState::begin_block`] — transient masks are
    /// keyed on it, and every protocol drives the same eval sequence
    /// per block, so the key is batching-independent.
    cycle: u64,
    /// `base_sample / 64` for the current block; word `j` of a slot is
    /// global word `base_word + j` regardless of `W`.
    base_word: u64,
}

impl FaultState {
    /// Lower `list` against `plan`.  Faults on nets the plan does not
    /// materialize are dropped (see [`SimPlan::faultable`]); returns
    /// `None` when nothing survives, so an empty list costs nothing.
    pub(crate) fn build(plan: &SimPlan, list: &FaultList) -> Option<FaultState> {
        let mut sources = Vec::new();
        // (producing op index or interpreted order position, fault).
        let mut by_producer: Vec<(u32, ActiveFault)> = Vec::new();
        if let Some(cp) = plan.compiled_plan() {
            let mut slot_writer = vec![u32::MAX; cp.n_dense_nets()];
            for (i, &d) in cp.dst.iter().enumerate() {
                slot_writer[d as usize] = i as u32;
            }
            for f in &list.faults {
                if f.net as usize >= plan.n_nets() {
                    continue;
                }
                let slot = cp.write_map[f.net as usize];
                if slot == u32::MAX || slot < 2 {
                    continue;
                }
                let af = ActiveFault::new(slot, f.net, f.kind);
                match slot_writer[slot as usize] {
                    u32::MAX => sources.push(af),
                    op => by_producer.push((op, af)),
                }
            }
        } else {
            let mut net_writer = vec![u32::MAX; plan.n_nets()];
            for (pos, &ci) in plan.order.iter().enumerate() {
                net_writer[plan.cells[ci as usize].output() as usize] = pos as u32;
            }
            for f in &list.faults {
                if (f.net as usize) < 2 || f.net as usize >= plan.n_nets() {
                    continue;
                }
                let af = ActiveFault::new(f.net, f.net, f.kind);
                match net_writer[f.net as usize] {
                    u32::MAX => sources.push(af),
                    pos => by_producer.push((pos, af)),
                }
            }
        }
        if sources.is_empty() && by_producer.is_empty() {
            return None;
        }
        by_producer.sort_by_key(|&(pos, ref af)| (pos, af.slot));

        // Compiled plans execute homogeneous opcode runs; split each run
        // at faulted producers so the mask lands before any later op in
        // the (possibly level-merged) run can read the clean value, and
        // re-key the schedule by the run that now ends at the producer.
        let (runs, run_gates, scheduled) = match plan.compiled_plan() {
            Some(cp) if !by_producer.is_empty() => {
                let mut cuts: Vec<u32> = by_producer.iter().map(|&(op, _)| op).collect();
                cuts.dedup();
                let mut runs = Vec::with_capacity(cp.runs.len() + cuts.len());
                let mut scheduled = Vec::with_capacity(by_producer.len());
                let mut fi = 0usize; // cursor over by_producer (op-sorted)
                for &(op, start, len) in &cp.runs {
                    let end = start + len;
                    let mut s = start;
                    for &c in cuts.iter().filter(|&&c| c >= start && c < end) {
                        runs.push((op, s, c + 1 - s));
                        let run_idx = (runs.len() - 1) as u32;
                        while fi < by_producer.len() && by_producer[fi].0 == c {
                            scheduled.push((run_idx, by_producer[fi].1.clone()));
                            fi += 1;
                        }
                        s = c + 1;
                    }
                    if end > s {
                        runs.push((op, s, end - s));
                    }
                }
                let mut rg = crate::sim::RunGates::build(&runs, &cp.src_a, &cp.src_b, &cp.src_c);
                for &(ri, ref af) in &scheduled {
                    if af.transient {
                        rg.pin_hot(ri as usize);
                    }
                }
                (Some(runs), Some(rg), scheduled)
            }
            _ => (None, None, by_producer),
        };

        let rate_q24 = (list.flip_rate.clamp(0.0, 1.0) * (1u64 << 24) as f64).round() as u64;
        Some(FaultState {
            sources,
            scheduled,
            runs,
            run_gates,
            seed: list.seed,
            rate_q24,
            cycle: 0,
            base_word: 0,
        })
    }

    /// Start a super-lane block whose first sample is `base_sample`
    /// (always a multiple of `W·64` in the sharded driver): reset the
    /// per-block eval counter and pin the global word base.
    pub(crate) fn begin_block(&mut self, base_sample: usize) {
        self.cycle = 0;
        self.base_word = (base_sample / 64) as u64;
    }

    /// Called once at the end of every combinational propagation.
    pub(crate) fn end_eval(&mut self) {
        self.cycle += 1;
    }

    /// The transient flip mask for one lane word: seed-deterministic in
    /// `(seed, net, cycle, global word)`, with each bit set independently
    /// at probability `rate_q24 / 2^24`.
    fn flip_word(&self, net: NetId, word: u64) -> u64 {
        let mut rng = Rng::new(
            self.seed
                ^ (net as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)
                ^ self.cycle.wrapping_mul(0x94D0_49BB_1331_11EB)
                ^ word.wrapping_mul(0xD6E8_FEB8_6659_FD93),
        );
        bernoulli_mask(&mut rng, self.rate_q24)
    }

    /// Force one fault into its slot's lane words.
    #[inline]
    pub(crate) fn apply<const W: usize>(&self, v: &mut [u64], af: &ActiveFault) {
        let base = af.slot as usize * W;
        for j in 0..W {
            let mut x = (v[base + j] & af.and_mask) | af.or_mask;
            if af.transient && self.rate_q24 > 0 {
                x ^= self.flip_word(af.net, self.base_word + j as u64);
            }
            v[base + j] = x;
        }
    }

    /// [`FaultState::apply`] with gating dirt (`sim` §Gating): any lane
    /// word the force actually changed marks the slot's dirty block, so
    /// a forced transition wakes downstream runs exactly like a computed
    /// one.  Idempotent stuck re-forces produce no diff and no dirt.
    #[inline]
    pub(crate) fn apply_marked<const W: usize>(
        &self,
        v: &mut [u64],
        af: &ActiveFault,
        dirty: &mut [u64],
    ) {
        let base = af.slot as usize * W;
        let mut diff = 0u64;
        for j in 0..W {
            let old = v[base + j];
            let mut x = (old & af.and_mask) | af.or_mask;
            if af.transient && self.rate_q24 > 0 {
                x ^= self.flip_word(af.net, self.base_word + j as u64);
            }
            v[base + j] = x;
            diff |= x ^ old;
        }
        if diff != 0 {
            crate::sim::mark_dirty(dirty, af.slot);
        }
    }

    /// Re-force the stuck component of every source fault (after the
    /// register commit overwrites state slots) — transient flips are
    /// NOT re-drawn, so observation stays a pure function of the eval
    /// count.
    pub(crate) fn reforce_stuck<const W: usize>(&self, v: &mut [u64]) {
        for af in &self.sources {
            if af.transient {
                continue;
            }
            let base = af.slot as usize * W;
            for x in &mut v[base..base + W] {
                *x = (*x & af.and_mask) | af.or_mask;
            }
        }
    }
}

/// A 64-lane word whose bits are independently 1 with probability
/// `q24 / 2^24` (24-bit fixed point), built from 24 uniform draws by the
/// bitwise Bernoulli construction: walking the probability's bits LSB →
/// MSB, `m = bit ? (m | r) : (m & r)` halves-and-offsets the density so
/// the final per-bit probability is exactly the fixed-point value.
pub fn bernoulli_mask(rng: &mut Rng, q24: u64) -> u64 {
    if q24 == 0 {
        return 0;
    }
    if q24 >= 1 << 24 {
        return !0;
    }
    let mut m = 0u64;
    for i in 0..24 {
        let r = rng.next_u64();
        m = if (q24 >> i) & 1 == 1 { m | r } else { m & r };
    }
    m
}

/// Convenience: the roles campaigns fault by default — everything that
/// physically exists as a wire (inputs, register state, combinational
/// outputs); constants and floating nets are excluded.
pub fn default_roles() -> Vec<NetRole> {
    vec![NetRole::Input, NetRole::State, NetRole::Comb]
}

/// Shared handle form used by evaluators and campaign configs.
pub type SharedFaultList = Arc<FaultList>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{Netlist, CONST0, CONST1};
    use crate::sim::Sim;

    fn toy() -> (Netlist, NetId, NetId, NetId, NetId) {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let x = n.xor2(a, b);
        let y = n.and2(x, a);
        n.add_output("y", vec![y]);
        (n, a, b, x, y)
    }

    #[test]
    fn stuck_at_forces_value_on_both_plan_forms() {
        let (n, a, b, x, y) = toy();
        for plan in [
            Arc::new(SimPlan::new(&n)),
            Arc::new(SimPlan::compiled(&n)),
        ] {
            let list = FaultList {
                faults: vec![Fault {
                    net: x,
                    kind: FaultKind::StuckAt1,
                }],
                seed: 1,
                flip_rate: 0.0,
            };
            let mut s = Sim::from_plan(plan);
            s.set_faults(&list);
            s.set(a, 0b0011);
            s.set(b, 0b0101);
            s.eval();
            assert_eq!(s.get(x), !0, "stuck-at-1 forces every lane");
            // Downstream sees the forced value: y = x & a = a.
            assert_eq!(s.get(y), s.get(a), "reader observes the fault");
        }
    }

    #[test]
    fn empty_and_unmaterialized_lists_are_free() {
        let (n, _, _, _, _) = toy();
        let plan = Arc::new(SimPlan::compiled(&n));
        assert!(FaultState::build(&plan, &FaultList::default()).is_none());
        // A fault on a constant net never lowers.
        let consts = FaultList {
            faults: vec![
                Fault {
                    net: CONST0,
                    kind: FaultKind::StuckAt1,
                },
                Fault {
                    net: CONST1,
                    kind: FaultKind::StuckAt0,
                },
            ],
            seed: 0,
            flip_rate: 0.0,
        };
        assert!(FaultState::build(&plan, &consts).is_none());
    }

    #[test]
    fn sample_respects_roles_counts_and_determinism() {
        let (n, _, _, _, _) = toy();
        let plan = SimPlan::compiled(&n);
        let roles = vec![NetRole::Input];
        let l1 = FaultList::sample(&plan, &n, &roles, 1, 1, 0.01, 42);
        let l2 = FaultList::sample(&plan, &n, &roles, 1, 1, 0.01, 42);
        assert_eq!(l1, l2, "sampling is seed-deterministic");
        assert_eq!(l1.faults.len(), 2);
        assert_eq!(l1.stuck_count(), 1);
        assert_eq!(l1.transient_count(), 1);
        let all = n.net_roles();
        for f in &l1.faults {
            assert_eq!(all[f.net as usize], NetRole::Input);
        }
        // Counts clip to the candidate pool (2 inputs here).
        let clipped = FaultList::sample(&plan, &n, &roles, 10, 10, 0.0, 7);
        assert_eq!(clipped.faults.len(), 2);
    }

    #[test]
    fn bernoulli_mask_endpoints_and_density() {
        let mut r = Rng::new(3);
        assert_eq!(bernoulli_mask(&mut r, 0), 0);
        assert_eq!(bernoulli_mask(&mut r, 1 << 24), !0);
        // Density ≈ 1/4 over many words.
        let q = 1u64 << 22; // p = 0.25
        let mut ones = 0u32;
        for _ in 0..512 {
            ones += bernoulli_mask(&mut r, q).count_ones();
        }
        let p = ones as f64 / (512.0 * 64.0);
        assert!((p - 0.25).abs() < 0.02, "density {p}");
    }

    #[test]
    fn transient_flips_are_deterministic_and_rate_scaled() {
        let (n, a, b, x, _) = toy();
        let plan = Arc::new(SimPlan::compiled(&n));
        let list = FaultList {
            faults: vec![Fault {
                net: x,
                kind: FaultKind::Transient,
            }],
            seed: 9,
            flip_rate: 0.5,
        };
        let run = || {
            let mut s = Sim::from_plan(plan.clone());
            s.set_faults(&list);
            s.fault_begin_block(0);
            s.set(a, 0);
            s.set(b, 0);
            s.eval();
            s.get(x)
        };
        let v1 = run();
        assert_eq!(v1, run(), "same seed + block + cycle → same flips");
        assert_ne!(v1, 0, "rate 0.5 flips something in 64 lanes");
        // Zero rate leaves the clean value.
        let clean = FaultList {
            flip_rate: 0.0,
            ..list.clone()
        };
        let mut s = Sim::from_plan(plan.clone());
        s.set_faults(&clean);
        s.set(a, 0);
        s.set(b, 0);
        s.eval();
        assert_eq!(s.get(x), 0);
    }
}
