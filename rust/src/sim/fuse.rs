//! Cross-model fused simulation (§Fusion): concatenate several hosted
//! models' compiled micro-op streams into one mega-plan so a multi-tenant
//! batch drains every model's block in a single sharded pass.
//!
//! Layout: each model's dense slots `2..n_dense` are relocated by a
//! per-model base offset (`fused = base + slot - 2`); slots 0/1 stay the
//! shared constants.  Net ranges are disjoint by construction, so
//! concatenating the op streams preserves every producer→reader
//! dependency; per-op topological levels carry over unchanged (levels
//! are relative to externally-written slots, which relocation does not
//! disturb), and one global stable sort by `(level, opcode)` level-merges
//! the models so same-opcode runs span tenants — the fused stream pays
//! one run dispatch where N per-model streams paid N.  DFF state arrays
//! concatenate the same way, and each model records its `[lo, hi)` index
//! range so the fused clock driver can commit models independently.
//!
//! Clocking: tenants may need different cycle counts.  Extra clock edges
//! past a model's schedule are **not** safe — the sequential circuits'
//! free-running phase counter wraps and re-activates datapath enables —
//! so the fused driver *freezes* finished models instead: it simply stops
//! committing their DFF range ([`crate::sim::Sim::commit_state_ranges`])
//! and stops touching their inputs.  A frozen model's combinational cone
//! is then a pure function of held registers and inputs, so every
//! re-evaluation reproduces its standalone settled values bit-for-bit
//! (and under activity gating those runs are all clean and skip).
//!
//! IO goes through pre-translated fused slots (`Sim::set_slot_word`) —
//! there is no meaningful source-netlist id space for the fused plan, so
//! its external `port_map`/`write_map` are empty and
//! [`crate::sim::Sim::set`]/[`crate::sim::Sim::get`] must not be used on
//! a fused simulator.  Fault injection is likewise not supported here
//! (faults name source nets of one model); the campaign paths keep using
//! per-model plans.
//!
//! Differential guarantee: fused predictions are bit-identical to each
//! model's own [`crate::sim::testbench::run_sequential_plan`] output —
//! enforced per-backend in `tests/sim_gating.rs` and end-to-end through
//! the server in `tests/server_batching.rs`.

use std::sync::Arc;

use crate::netlist::NetId;
use crate::sim::{batch, CompiledPlan, RunGates, Sim, SimPlan};

/// One tenant's contribution to a fused plan: its compiled plan plus the
/// sequential protocol ports and schedule.
pub struct FusedModelSpec<'a> {
    pub plan: &'a SimPlan,
    /// The 4-bit feature bus ("x").
    pub x: &'a [NetId],
    /// Reset input ("rst").
    pub rst: NetId,
    /// Class output word ("class_out").
    pub class_out: &'a [NetId],
    /// Total clock cycles after the reset pulse.
    pub cycles: usize,
    /// RFP feature schedule (`active[t]` is on the bus at cycle `t`).
    pub active: &'a [usize],
    /// Feature count of the model's sample rows.
    pub features: usize,
}

/// Per-model IO resolved against the fused slot space.
struct FusedModelIo {
    /// Fused write slots of the feature bus (`u32::MAX` = pruned bit).
    x: Vec<u32>,
    /// Fused write slot of the reset input (`u32::MAX` = pruned).
    rst: u32,
    /// Fused read slots of the class word (`u32::MAX` reads 0).
    class_out: Vec<u32>,
    cycles: usize,
    active: Vec<usize>,
    features: usize,
    /// `[lo, hi)` into the fused DFF SoA — the commit/freeze handle.
    dff_range: (u32, u32),
}

/// One model's sample batch for a fused pass: row-major
/// `features`-wide 4-bit values, `n` rows.
pub struct FusedBatch<'a> {
    pub xs: &'a [u8],
    pub n: usize,
}

/// All hosted models' compiled streams concatenated, level-merged, and
/// re-run-scheduled into one shareable [`SimPlan`].
pub struct FusedPlan {
    plan: Arc<SimPlan>,
    models: Vec<FusedModelIo>,
    max_cycles: usize,
}

impl FusedPlan {
    /// Concatenate the models' compiled streams.  Every spec's plan must
    /// be compiled ([`SimPlan::compiled`]); panics otherwise — the fused
    /// path is an optimisation of the compiled backend only.
    pub fn build(specs: &[FusedModelSpec]) -> FusedPlan {
        assert!(!specs.is_empty(), "fusing zero models");
        let mut ops: Vec<u8> = Vec::new();
        let mut src_a: Vec<u32> = Vec::new();
        let mut src_b: Vec<u32> = Vec::new();
        let mut src_c: Vec<u32> = Vec::new();
        let mut dst: Vec<u32> = Vec::new();
        let mut op_level: Vec<u32> = Vec::new();
        let mut dff_d = Vec::new();
        let mut dff_q = Vec::new();
        let mut dff_en = Vec::new();
        let mut dff_rst = Vec::new();
        let mut dff_rstval = Vec::new();
        let mut models = Vec::with_capacity(specs.len());
        let mut base = 2u32;
        for spec in specs {
            let cp = spec
                .plan
                .compiled_plan()
                .expect("fused plans require compiled per-model plans");
            // Relocate this model's dense slots; constants are shared.
            let t = |s: u32| if s < 2 { s } else { base + s - 2 };
            let tm = |s: u32| if s == u32::MAX { u32::MAX } else { t(s) };
            for i in 0..cp.ops.len() {
                ops.push(cp.ops[i]);
                src_a.push(t(cp.src_a[i]));
                src_b.push(t(cp.src_b[i]));
                src_c.push(t(cp.src_c[i]));
                dst.push(t(cp.dst[i]));
                op_level.push(cp.op_level[i]);
            }
            let dff_lo = dff_q.len() as u32;
            for i in 0..cp.dff_q.len() {
                dff_d.push(t(cp.dff_d[i]));
                dff_q.push(t(cp.dff_q[i]));
                dff_en.push(t(cp.dff_en[i]));
                dff_rst.push(t(cp.dff_rst[i]));
                dff_rstval.push(cp.dff_rstval[i]);
            }
            let wslot = |net: NetId| tm(cp.write_map[net as usize]);
            let rslot = |net: NetId| tm(cp.port_map[net as usize]);
            models.push(FusedModelIo {
                x: spec.x.iter().map(|&b| wslot(b)).collect(),
                rst: wslot(spec.rst),
                class_out: spec.class_out.iter().map(|&b| rslot(b)).collect(),
                cycles: spec.cycles,
                active: spec.active.to_vec(),
                features: spec.features,
                dff_range: (dff_lo, dff_q.len() as u32),
            });
            base += cp.n_dense as u32 - 2;
        }

        // Global level merge: the same stable `(level, opcode)` sort the
        // per-model compiler uses, now spanning tenants, then rebuild
        // the homogeneous runs and their gate lists.
        let n_stream = ops.len();
        let mut idx: Vec<u32> = (0..n_stream as u32).collect();
        idx.sort_by_key(|&i| (op_level[i as usize], ops[i as usize]));
        let permute_u8 = |src: &[u8]| -> Vec<u8> { idx.iter().map(|&i| src[i as usize]).collect() };
        let permute = |src: &[u32]| -> Vec<u32> { idx.iter().map(|&i| src[i as usize]).collect() };
        let ops = permute_u8(&ops);
        let src_a = permute(&src_a);
        let src_b = permute(&src_b);
        let src_c = permute(&src_c);
        let dst = permute(&dst);
        let op_level = permute(&op_level);
        let mut runs: Vec<(u8, u32, u32)> = Vec::new();
        for (i, &op) in ops.iter().enumerate() {
            match runs.last_mut() {
                Some((last, _, len)) if *last == op => *len += 1,
                _ => runs.push((op, i as u32, 1)),
            }
        }
        let run_gates = RunGates::build(&runs, &src_a, &src_b, &src_c);

        let compiled = CompiledPlan {
            ops,
            src_a,
            src_b,
            src_c,
            dst,
            runs,
            op_level,
            dff_d,
            dff_q,
            dff_en,
            dff_rst,
            dff_rstval,
            n_dense: base as usize,
            // No source-netlist id space exists for the fused plan:
            // external set/get must go through the fused slot IO.
            port_map: Vec::new(),
            write_map: Vec::new(),
            run_gates,
        };
        let max_cycles = models.iter().map(|m| m.cycles).max().unwrap_or(0);
        FusedPlan {
            plan: Arc::new(SimPlan {
                cells: Vec::new(),
                order: Vec::new(),
                dffs: Vec::new(),
                n_nets: 2,
                compiled: Some(compiled),
            }),
            models,
            max_cycles,
        }
    }

    pub fn n_models(&self) -> usize {
        self.models.len()
    }

    /// Total fused micro-op count (reporting).
    pub fn n_ops(&self) -> usize {
        self.plan.compiled_plan().map_or(0, |c| c.n_ops())
    }

    /// The shared fused [`SimPlan`].
    pub fn sim_plan(&self) -> &Arc<SimPlan> {
        &self.plan
    }

    /// Run one batch per model through the fused plan, sharded into
    /// super-lane blocks like [`crate::sim::testbench`] (lane `l` of a
    /// block carries sample `base + l` of *every* tenant at once).
    /// Batches may be ragged: a model whose rows run out early is frozen
    /// for the remaining lanes' protocol (its padding-lane outputs are
    /// never read).  Returns one prediction vector per model, in spec
    /// order, each of its own batch length.
    pub fn run(&self, batches: &[FusedBatch], threads: usize, lane_words: usize) -> Vec<Vec<u16>> {
        assert_eq!(batches.len(), self.models.len(), "one batch per model");
        let n = batches.iter().map(|b| b.n).max().unwrap_or(0);
        if n == 0 {
            return self.models.iter().map(|_| Vec::new()).collect();
        }
        let flat: Vec<Vec<u16>> =
            batch::run_sharded_wide(&self.plan, n, threads, lane_words, |sim, base, lanes| {
                self.drive_block(sim, batches, base, lanes);
                (0..lanes)
                    .map(|lane| {
                        self.models
                            .iter()
                            .map(|m| read_class(sim, &m.class_out, lane))
                            .collect()
                    })
                    .collect()
            });
        let mut out: Vec<Vec<u16>> = batches.iter().map(|b| Vec::with_capacity(b.n)).collect();
        for (i, lane_vals) in flat.iter().enumerate() {
            for (m, &v) in lane_vals.iter().enumerate() {
                if i < batches[m].n {
                    out[m].push(v);
                }
            }
        }
        out
    }

    /// One super-lane block of the fused sequential protocol: a shared
    /// reset edge, then per-cycle feature drive + range commit for every
    /// model still inside its schedule (finished models freeze), then a
    /// final settle.
    fn drive_block(&self, sim: &mut Sim, batches: &[FusedBatch], base: usize, lanes: usize) {
        let mut scratch: Vec<i64> = Vec::with_capacity(lanes);
        let all_ranges: Vec<(u32, u32)> =
            self.models.iter().map(|m| m.dff_range).collect();
        // Reset pulse across every model.
        for m in &self.models {
            fill_slot(sim, m.rst, !0u64);
            set_slot_word_all(sim, &m.x, 0);
        }
        sim.eval();
        sim.commit_state_ranges(&all_ranges);
        for m in &self.models {
            fill_slot(sim, m.rst, 0);
        }
        // Clocked phase: each model follows its own schedule and is
        // frozen (no drive, no commit) once `t` passes its last cycle.
        let mut live_ranges: Vec<(u32, u32)> = Vec::with_capacity(self.models.len());
        for t in 0..self.max_cycles {
            live_ranges.clear();
            for (m, b) in self.models.iter().zip(batches) {
                if t >= m.cycles {
                    continue;
                }
                live_ranges.push(m.dff_range);
                if t < m.active.len() {
                    drive_feature(sim, m, b, base, lanes, m.active[t], &mut scratch);
                } else {
                    set_slot_word_all(sim, &m.x, 0);
                }
            }
            sim.eval();
            sim.commit_state_ranges(&live_ranges);
        }
        sim.settle();
    }
}

/// Gather feature `f` of every in-range sample into the lane buffer and
/// drive it onto the model's fused feature-bus slots (padding lanes and
/// lanes past the model's batch read as 0).
fn drive_feature(
    sim: &mut Sim,
    m: &FusedModelIo,
    b: &FusedBatch,
    base: usize,
    lanes: usize,
    f: usize,
    scratch: &mut Vec<i64>,
) {
    scratch.clear();
    for lane in 0..lanes {
        let row = base + lane;
        scratch.push(if row < b.n {
            b.xs[row * m.features + f] as i64
        } else {
            0
        });
    }
    set_slot_word_lanes(sim, &m.x, scratch);
}

/// [`Sim::set_word_lanes`] over fused slots: bit `i` of value `v` drives
/// lane `l` of slot `slots[i]`; lanes beyond `values.len()` are zeroed.
fn set_slot_word_lanes(sim: &mut Sim, slots: &[u32], values: &[i64]) {
    let w = sim.lane_words();
    for (bit, &slot) in slots.iter().enumerate() {
        if slot == u32::MAX {
            continue;
        }
        for j in 0..w {
            let chunk = values.iter().skip(j * Sim::LANES).take(Sim::LANES);
            let mut packed = 0u64;
            for (lane, &v) in chunk.enumerate() {
                packed |= (((v >> bit) & 1) as u64) << lane;
            }
            sim.set_slot_word(slot, j, packed);
        }
    }
}

/// Broadcast one value to every lane of a word of fused slots.
fn set_slot_word_all(sim: &mut Sim, slots: &[u32], value: i64) {
    let w = sim.lane_words();
    for (bit, &slot) in slots.iter().enumerate() {
        if slot == u32::MAX {
            continue;
        }
        let v = if (value >> bit) & 1 == 1 { !0u64 } else { 0u64 };
        for j in 0..w {
            sim.set_slot_word(slot, j, v);
        }
    }
}

/// Broadcast one packed word to every lane word of one fused slot.
fn fill_slot(sim: &mut Sim, slot: u32, packed: u64) {
    if slot == u32::MAX {
        return;
    }
    let w = sim.lane_words();
    for j in 0..w {
        sim.set_slot_word(slot, j, packed);
    }
}

/// Read one lane of a fused class word (eliminated bits read 0).
fn read_class(sim: &Sim, slots: &[u32], lane: usize) -> u16 {
    let (wd, bit_in) = (lane / Sim::LANES, lane % Sim::LANES);
    let mut v = 0u16;
    for (bit, &slot) in slots.iter().enumerate() {
        if slot == u32::MAX {
            continue;
        }
        if (sim.get_slot_word(slot, wd) >> bit_in) & 1 == 1 {
            v |= 1 << bit;
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::SeqCircuit;
    use crate::netlist::{Netlist, CONST1};
    use crate::sim::testbench;

    /// A tiny 2-bit wrap-around counter with the sequential protocol's
    /// port shape: counts cycles where x != 0.
    fn toy_counter(name: &str, cycles: usize) -> SeqCircuit {
        let mut n = Netlist::new(name);
        let x = n.add_input("x", 4);
        let rst = n.add_input("rst", 1)[0];
        let a = n.or2(x[0], x[1]);
        let b = n.or2(x[2], x[3]);
        let any = n.or2(a, b);
        let (q0, c0) = n.dff_deferred(CONST1, rst, false);
        let (q1, c1) = n.dff_deferred(CONST1, rst, false);
        // q += any (2-bit wrap).
        let d0 = n.xor2(q0, any);
        let carry = n.and2(q0, any);
        let d1 = n.xor2(q1, carry);
        n.set_dff_d(c0, d0);
        n.set_dff_d(c1, d1);
        n.add_output("class_out", vec![q0, q1]);
        let active = (0..cycles.min(3)).collect();
        SeqCircuit::new(n, cycles, active, 0)
    }

    #[test]
    fn fused_matches_per_model_on_toy_counters() {
        let c1 = toy_counter("m1", 3);
        let c2 = toy_counter("m2", 5);
        let p1 = Arc::new(SimPlan::compiled(&c1.netlist));
        let p2 = Arc::new(SimPlan::compiled(&c2.netlist));
        // Samples with 8 features each (only the scheduled ones matter);
        // ragged batch sizes exercise the freeze-on-padding path.
        let feats = 8usize;
        let xs1: Vec<u8> = (0..100 * feats).map(|i| (i % 5) as u8 & 0xF).collect();
        let xs2: Vec<u8> = (0..70 * feats).map(|i| (i % 7) as u8 & 0xF).collect();
        let want1 = testbench::run_sequential_plan(&c1, &p1, &xs1, 100, feats, 1, 1);
        let want2 = testbench::run_sequential_plan(&c2, &p2, &xs2, 70, feats, 1, 1);

        let port = |n: &Netlist, name: &str| -> Vec<crate::netlist::NetId> {
            n.inputs
                .iter()
                .chain(n.outputs.iter())
                .find(|p| p.name == name)
                .unwrap()
                .bits
                .clone()
        };
        let (x1, x2) = (port(&c1.netlist, "x"), port(&c2.netlist, "x"));
        let (o1, o2) = (port(&c1.netlist, "class_out"), port(&c2.netlist, "class_out"));
        let fused = FusedPlan::build(&[
            FusedModelSpec {
                plan: &p1,
                x: &x1,
                rst: port(&c1.netlist, "rst")[0],
                class_out: &o1,
                cycles: c1.cycles,
                active: &c1.active,
                features: feats,
            },
            FusedModelSpec {
                plan: &p2,
                x: &x2,
                rst: port(&c2.netlist, "rst")[0],
                class_out: &o2,
                cycles: c2.cycles,
                active: &c2.active,
                features: feats,
            },
        ]);
        assert_eq!(fused.n_models(), 2);
        for (threads, w) in [(1usize, 1usize), (2, 2), (4, 4)] {
            let got = fused.run(
                &[
                    FusedBatch { xs: &xs1, n: 100 },
                    FusedBatch { xs: &xs2, n: 70 },
                ],
                threads,
                w,
            );
            assert_eq!(got[0], want1, "model 1, threads={threads} w={w}");
            assert_eq!(got[1], want2, "model 2, threads={threads} w={w}");
        }
    }
}
