//! Redundant Feature Pruning — Algorithm 1 (§3.2.2).
//!
//! Ranks features by their average expected product (Eq. 1 relevance),
//! then greedily finds the minimum prefix N of the ranked features whose
//! accuracy meets the threshold (the quantized model's own accuracy).
//! The evaluation callback runs the quantized MLP over the training set —
//! through the PJRT artifact on the hot path (masks are runtime inputs,
//! so no recompilation per step).
//!
//! `Strategy::Bisect` is our §Perf optimization: when the accuracy curve
//! over N is monotone-ish, a galloping + binary search finds the same
//! frontier in O(log F) evaluations instead of O(F); the result is
//! post-validated against the threshold, and the greedy sweep remains the
//! reference implementation.

use crate::data::Split;
use crate::model::{importance, QuantModel};

/// Outcome of the pruning pass.
#[derive(Clone, Debug)]
pub struct RfpResult {
    /// All features ordered by decreasing relevance.
    pub order: Vec<usize>,
    /// Number of features kept (`N` in Algorithm 1).
    pub kept: usize,
    /// Mask over the original feature indices.
    pub feat_mask: Vec<u8>,
    /// The kept features in arrival-schedule order (`order[..kept]`).
    pub active: Vec<usize>,
    /// Training accuracy achieved with the kept set.
    pub accuracy: f64,
    pub threshold: f64,
    /// Number of accuracy evaluations performed.
    pub evals: usize,
}

impl RfpResult {
    /// Fraction of features retained (the paper reports 81% on average).
    pub fn retention(&self) -> f64 {
        self.kept as f64 / self.order.len().max(1) as f64
    }
}

/// Search strategy for the minimum-N frontier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Paper-faithful greedy sweep (Algorithm 1): N = 1, 2, 3, ...
    Greedy,
    /// Galloping + binary search (§Perf optimization).
    Bisect,
}

fn mask_for(order: &[usize], n: usize, features: usize) -> Vec<u8> {
    let mut m = vec![0u8; features];
    for &f in &order[..n] {
        m[f] = 1;
    }
    m
}

/// Run Algorithm 1.  `eval(feat_mask) -> accuracy` must evaluate the
/// quantized model on the training set.
pub fn prune<F>(
    model: &QuantModel,
    train: &Split,
    threshold: f64,
    strategy: Strategy,
    mut eval: F,
) -> RfpResult
where
    F: FnMut(&[u8]) -> f64,
{
    let features = model.features;
    let means = importance::feature_means(&train.xs, train.len(), features);
    let rel = importance::feature_relevance(model, &means);
    let order = importance::relevance_order(&rel);

    let mut evals = 0usize;
    let mut check = |n: usize, evals: &mut usize| -> f64 {
        *evals += 1;
        eval(&mask_for(&order, n, features))
    };

    let (kept, accuracy) = match strategy {
        Strategy::Greedy => {
            let mut found = (features, f64::NAN);
            for n in 1..=features {
                let acc = check(n, &mut evals);
                if acc >= threshold {
                    found = (n, acc);
                    break;
                }
                if n == features {
                    found = (features, acc);
                }
            }
            found
        }
        Strategy::Bisect => {
            // Gallop to an upper bound that meets the threshold.
            let mut hi = 1usize;
            let mut acc_hi = check(hi, &mut evals);
            while acc_hi < threshold && hi < features {
                hi = (hi * 2).min(features);
                acc_hi = check(hi, &mut evals);
            }
            if acc_hi < threshold {
                (features, acc_hi)
            } else {
                // Smallest n in (hi/2, hi] meeting the threshold.
                let mut lo = hi / 2; // fails (or 0)
                let mut best = (hi, acc_hi);
                while hi - lo > 1 {
                    let mid = (lo + hi) / 2;
                    let acc = check(mid, &mut evals);
                    if acc >= threshold {
                        hi = mid;
                        best = (mid, acc);
                    } else {
                        lo = mid;
                    }
                }
                best
            }
        }
    };

    let feat_mask = mask_for(&order, kept, features);
    RfpResult {
        active: order[..kept].to_vec(),
        order,
        kept,
        feat_mask,
        accuracy,
        threshold,
        evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::testutil::rand_model;
    use crate::util::prng::Rng;

    fn toy_split(features: usize, n: usize, seed: u64) -> Split {
        let mut r = Rng::new(seed);
        Split {
            xs: (0..n * features).map(|_| r.below(16) as u8).collect(),
            ys: (0..n).map(|_| r.below(2) as u16).collect(),
            features,
        }
    }

    /// Synthetic accuracy curve: rises with the number of kept features.
    fn curve_eval(mask: &[u8]) -> f64 {
        let kept = mask.iter().filter(|&&m| m == 1).count();
        0.5 + 0.5 * (kept as f64 / mask.len() as f64).min(0.8) / 0.8
    }

    #[test]
    fn greedy_finds_minimum_prefix() {
        let m = rand_model(51, 20, 3, 2);
        let split = toy_split(20, 50, 1);
        let r = prune(&m, &split, 0.9, Strategy::Greedy, curve_eval);
        // 0.9 needs kept/20*0.625 >= 0.4 => kept >= 12.8 => 13
        assert_eq!(r.kept, 13);
        assert!(r.accuracy >= 0.9);
        assert_eq!(r.evals, 13);
        assert_eq!(r.active.len(), 13);
        assert_eq!(r.feat_mask.iter().filter(|&&x| x == 1).count(), 13);
    }

    #[test]
    fn bisect_agrees_with_greedy_on_monotone_curves() {
        let m = rand_model(52, 33, 3, 2);
        let split = toy_split(33, 50, 2);
        for thr in [0.6, 0.75, 0.9, 0.99] {
            let g = prune(&m, &split, thr, Strategy::Greedy, curve_eval);
            let b = prune(&m, &split, thr, Strategy::Bisect, curve_eval);
            assert_eq!(g.kept, b.kept, "thr={thr}");
            assert!(b.evals <= g.evals, "bisect must not do more evals");
        }
    }

    #[test]
    fn unreachable_threshold_keeps_all() {
        let m = rand_model(53, 10, 3, 2);
        let split = toy_split(10, 50, 3);
        for s in [Strategy::Greedy, Strategy::Bisect] {
            let r = prune(&m, &split, 2.0, s, curve_eval);
            assert_eq!(r.kept, 10);
            assert_eq!(r.retention(), 1.0);
        }
    }

    #[test]
    fn order_is_by_relevance() {
        // Features the model weighs heavily (and that have high means)
        // must come first.
        let mut m = rand_model(54, 4, 1, 2);
        m.w1p = vec![0, 6, 0, 3];
        m.w1s = vec![1, 1, 0, 1]; // f2 is dead weight
        let split = Split {
            xs: vec![8u8; 4 * 10], // uniform means
            ys: vec![0; 10],
            features: 4,
        };
        let r = prune(&m, &split, 0.0, Strategy::Greedy, |_| 1.0);
        assert_eq!(r.order[0], 1); // 2^6 weight
        assert_eq!(r.order[1], 3);
        assert_eq!(r.order[3], 2); // zero weight last
        assert_eq!(r.kept, 1, "threshold 0 met with one feature");
    }

    #[test]
    fn real_model_eval_wiring() {
        // End-to-end with the native evaluator on a random model: the
        // threshold equals the full-model accuracy, so RFP must return a
        // mask achieving at least it.
        let m = rand_model(55, 16, 3, 3);
        let split = toy_split(16, 80, 4);
        let full_mask = vec![1u8; 16];
        let am = vec![0u8; 3];
        let t = crate::model::ApproxTables::disabled(3);
        let full_acc = m.accuracy(&split.xs, &split.ys, &full_mask, &am, &t);
        let r = prune(&m, &split, full_acc, Strategy::Greedy, |mask| {
            m.accuracy(&split.xs, &split.ys, mask, &am, &t)
        });
        assert!(r.accuracy >= full_acc);
        assert!(r.kept <= 16);
    }
}
