//! # printed-mlp
//!
//! Full-stack reproduction of *"Sequential Printed Multilayer Perceptron
//! Circuits for Super-TinyML Multi-Sensory Applications"* (ASPDAC'25).
//!
//! The crate implements the paper's automated framework plus every
//! substrate it depends on:
//!
//! - [`model`] — bit-exact functional model of pow2-quantized hybrid MLPs
//!   (multi-cycle + single-cycle neurons, qReLU).
//! - [`data`] — the seven multi-sensor dataset configurations and loaders.
//! - [`runtime`] — the unified [`runtime::Evaluator`] backend abstraction:
//!   native functional model, PJRT executor for the AOT-compiled
//!   JAX/Pallas artifacts, and the gate-level simulator, selectable with
//!   `--backend native|pjrt|gatesim` (auto prefers PJRT, falls back to
//!   native).
//! - [`rfp`] — Redundant Feature Pruning (Algorithm 1).
//! - [`nsga`] — NSGA-II multi-objective optimizer: serial reference
//!   [`nsga::run`] plus the parallel, memoized batch driver
//!   [`nsga::run_batched`] (bit-identical fronts at equal seeds).
//! - [`approx`] — neuron-approximation framework (Eq. 1, Fig. 5), with
//!   [`approx::ParallelFitness`] fanning each generation's fitness batch
//!   across worker threads (`--search-threads`).
//! - [`netlist`] — gate-level IR, optimizer and Verilog emitter.
//! - [`circuits`] — the four architectures: combinational [14], sequential
//!   state-of-the-art [16], our multi-cycle sequential, and the hybrid.
//! - [`tech`] — printed-EGFET cell library and synthesis-lite estimation.
//! - [`sim`] — cycle-accurate netlist simulator (VCS substitute), 64
//!   samples packed per word and sharded across worker threads over a
//!   shared levelized [`sim::SimPlan`] (see [`sim::batch`]); plans
//!   compile by default into a strength-reduced, densely renumbered
//!   micro-op stream ([`sim::SimPlan::compiled`]; `--no-compile-sim`
//!   falls back to the interpreted oracle).  `PRINTED_MLP_THREADS` caps
//!   the worker count.  [`sim::fault`] injects stuck-at and seeded
//!   transient faults into compiled-plan execution, bit-identically
//!   across lane widths and thread counts.
//! - [`coordinator`] — pipeline orchestration across datasets.
//! - [`server`] — the multi-tenant model server: [`server::ModelRegistry`]
//!   (per-dataset artifacts loaded once, shared read-only) hosted in
//!   hot-swappable versioned [`server::ModelSlot`]s (zero-downtime
//!   reload with optional canary shadowing), per-model dynamic-batching
//!   queues with bounded capacity, per-tenant SLO-class admission
//!   ceilings (gold/silver/bronze — overload sheds bronze first) and
//!   deadline shedding, drained gold-first by a worker pool; an optional
//!   hand-rolled non-blocking TCP ingress ([`server::frontend`], binary
//!   length-prefixed frames, graceful drain) with open-loop socket
//!   clients, scenario-driven load generation (steady / bursty / ramp /
//!   multi-sensory fanin / recorded trace), and the [`server::campaign`]
//!   fault-injection sweep reporting accuracy degradation and SLO impact
//!   per architecture.
//! - [`report`] — table/figure emitters for the paper's evaluation.
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `rust/README.md` for backend selection and threading guidance.

pub mod approx;
pub mod circuits;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod model;
pub mod netlist;
pub mod nsga;
pub mod report;
pub mod rfp;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod tech;
pub mod util;
