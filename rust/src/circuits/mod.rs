//! Circuit architecture generators: the four designs the paper evaluates.
//!
//! - [`combinational`] — fully-parallel bespoke MLP (the DATE'23 [14]
//!   baseline style): shift-add trees, combinational qReLU, comparator-tree
//!   argmax; one (long) cycle per inference.
//! - [`seq_sota`] — conventional sequential (MICRO'20 [16] style): weights
//!   and inter-layer values in shift registers.
//! - [`seq_multicycle`] — the paper's contribution: registers replaced by
//!   multiplexers over hardwired coefficients (§3.1.4), one barrel shifter
//!   + accumulator per neuron.
//! - [`hybrid`] — multi-cycle plus single-cycle (approximated) neurons
//!   (§3.1.2) selected by NSGA-II.
//!
//! All generators consume the same [`QuantModel`] and an `active` feature
//! schedule (RFP output: kept features in arrival order) and must be
//! bit-exact w.r.t. `model::QuantModel::forward` — enforced by the
//! `circuits_vs_model` integration tests.

pub mod combinational;
pub mod hybrid;
pub mod rtl;
pub mod seq_multicycle;
pub mod seq_sota;

use std::sync::{Arc, OnceLock};

use crate::model::QuantModel;
use crate::netlist::Netlist;
use crate::sim::SimPlan;
use rtl::width_for_range;

/// A generated sequential circuit plus its execution contract.
pub struct SeqCircuit {
    pub netlist: Netlist,
    /// Total cycles per inference **after** the reset cycle:
    /// `active.len() + hidden + classes`.
    pub cycles: usize,
    /// Feature arrival schedule (dataset feature index per input cycle).
    pub active: Vec<usize>,
    /// Cell count before the CSE+DCE cleanup (ablation A3).
    pub raw_cells: usize,
    /// Lazily-built levelized simulation plan, shared by all sim shards.
    sim_plan: OnceLock<Arc<SimPlan>>,
}

impl SeqCircuit {
    pub fn new(netlist: Netlist, cycles: usize, active: Vec<usize>, raw_cells: usize) -> SeqCircuit {
        SeqCircuit {
            netlist,
            cycles,
            active,
            raw_cells,
            sim_plan: OnceLock::new(),
        }
    }

    /// The circuit's levelized [`SimPlan`]: topo order + DFF extraction
    /// (plus micro-op compilation unless [`crate::sim::compile_default`]
    /// is off) run once on first use, then every simulator shard shares
    /// the `Arc`.
    pub fn sim_plan(&self) -> Arc<SimPlan> {
        self.sim_plan
            .get_or_init(|| Arc::new(SimPlan::with_default_mode(&self.netlist)))
            .clone()
    }
}

/// A generated combinational circuit (single-cycle inference).
pub struct CombCircuit {
    pub netlist: Netlist,
    pub active: Vec<usize>,
    /// Cell count before the CSE+DCE cleanup (ablation A3).
    pub raw_cells: usize,
    /// Lazily-built levelized simulation plan, shared by all sim shards.
    sim_plan: OnceLock<Arc<SimPlan>>,
}

impl CombCircuit {
    pub fn new(netlist: Netlist, active: Vec<usize>, raw_cells: usize) -> CombCircuit {
        CombCircuit {
            netlist,
            active,
            raw_cells,
            sim_plan: OnceLock::new(),
        }
    }

    /// The circuit's levelized [`SimPlan`] (see [`SeqCircuit::sim_plan`]).
    pub fn sim_plan(&self) -> Arc<SimPlan> {
        self.sim_plan
            .get_or_init(|| Arc::new(SimPlan::with_default_mode(&self.netlist)))
            .clone()
    }
}

/// Signed accumulator ranges for layer 1 (over the active features only)
/// and layer 2 — used to size every datapath identically across the four
/// architectures (fair comparison, no hidden overflow).
pub struct AccWidths {
    pub acc1: usize,
    pub acc2: usize,
}

pub fn acc_widths(m: &QuantModel, active: &[usize]) -> AccWidths {
    let mut lo1 = 0i64;
    let mut hi1 = 0i64;
    for h in 0..m.hidden {
        let b = m.b1[h] as i64;
        let mut lo = b.min(0);
        let mut hi = b.max(0);
        for &f in active {
            let i = h * m.features + f;
            let mag = 15i64 << m.w1p[i];
            match m.w1s[i] {
                1 => hi += mag,
                -1 => lo -= mag,
                _ => {}
            }
        }
        lo1 = lo1.min(lo);
        hi1 = hi1.max(hi);
    }
    let mut lo2 = 0i64;
    let mut hi2 = 0i64;
    for c in 0..m.classes {
        let b = m.b2[c] as i64;
        let mut lo = b.min(0);
        let mut hi = b.max(0);
        for h in 0..m.hidden {
            let i = c * m.hidden + h;
            let mag = 15i64 << m.w2p[i];
            match m.w2s[i] {
                1 => hi += mag,
                -1 => lo -= mag,
                _ => {}
            }
        }
        lo2 = lo2.min(lo);
        hi2 = hi2.max(hi);
    }
    AccWidths {
        acc1: width_for_range(lo1, hi1),
        acc2: width_for_range(lo2, hi2),
    }
}

/// Bits needed for the weight power field.
pub fn power_bits(pmax: u32) -> usize {
    width_for_range(0, pmax as i64).max(1)
}

/// Bits for an unsigned index in `[0, n)`.
pub fn index_bits(n: usize) -> usize {
    if n <= 1 {
        1
    } else {
        (usize::BITS - (n - 1).leading_zeros()) as usize
    }
}

/// Encoded weight word for the mux/shift-register storage:
/// `[p (pw bits), sub, nz]`.
pub fn encode_weight(p: i32, s: i32, pw: usize) -> i64 {
    let nz = (s != 0) as i64;
    let sub = (s < 0) as i64;
    (p as i64 & ((1 << pw) - 1)) | (sub << pw) | (nz << (pw + 1))
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::model::QuantModel;
    use crate::util::prng::Rng;

    /// Random valid model for generator tests.
    pub fn rand_model(seed: u64, features: usize, hidden: usize, classes: usize) -> QuantModel {
        let mut r = Rng::new(seed);
        let pmax = 6u32;
        let mut w1p = vec![0i32; hidden * features];
        let mut w1s = vec![0i32; hidden * features];
        for i in 0..hidden * features {
            w1p[i] = r.below(pmax as u64 + 1) as i32;
            w1s[i] = [-1, 0, 1][r.usize_below(3)];
        }
        let mut w2p = vec![0i32; classes * hidden];
        let mut w2s = vec![0i32; classes * hidden];
        for i in 0..classes * hidden {
            w2p[i] = r.below(pmax as u64 + 1) as i32;
            w2s[i] = [-1, 0, 1][r.usize_below(3)];
        }
        QuantModel {
            name: format!("rand{seed}"),
            features,
            classes,
            hidden,
            in_bits: 4,
            w_bits: 8,
            pmax,
            trunc: (r.below(6) + 2) as u32,
            seq_clock_ms: 100.0,
            comb_clock_ms: 320.0,
            float_acc: 0.0,
            train_acc: 0.0,
            test_acc: 0.0,
            w1p,
            w1s,
            b1: (0..hidden).map(|_| r.i32_range(-300, 300)).collect(),
            w2p,
            w2s,
            b2: (0..classes).map(|_| r.i32_range(-300, 300)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_cover_worst_case() {
        let m = testutil::rand_model(3, 10, 4, 3);
        let active: Vec<usize> = (0..10).collect();
        let w = acc_widths(&m, &active);
        // Worst case positive sum for any neuron must fit.
        for h in 0..m.hidden {
            let mut hi = (m.b1[h] as i64).max(0);
            for f in 0..10 {
                if m.w1s[h * 10 + f] == 1 {
                    hi += 15 << m.w1p[h * 10 + f];
                }
            }
            assert!(hi < (1 << (w.acc1 - 1)), "h={h}");
        }
    }

    #[test]
    fn index_bits_edges() {
        assert_eq!(index_bits(1), 1);
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(3), 2);
        assert_eq!(index_bits(8), 3);
        assert_eq!(index_bits(9), 4);
    }

    #[test]
    fn weight_encoding_fields() {
        let pw = 3;
        let w = encode_weight(5, -1, pw);
        assert_eq!(w & 0b111, 5);
        assert_eq!((w >> 3) & 1, 1); // sub
        assert_eq!((w >> 4) & 1, 1); // nz
        assert_eq!(encode_weight(2, 0, pw) >> 4, 0);
    }
}
