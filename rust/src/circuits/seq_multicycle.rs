//! The paper's proposed sequential super-TinyML architecture (Fig. 3b).
//!
//! One 4-bit input arrives per cycle (one ADC active, §3.1.3).  Each
//! hidden neuron owns a mux over its *hardwired* weight constants
//! (selected by the controller state), a barrel shifter, an add/sub unit
//! and an accumulator register that resets to the bias.  The output layer
//! reuses the same structure over the hidden activations (selected by
//! muxes — no inter-layer shift registers), and a single sequential
//! comparator performs the argmax.
//!
//! `generate` produces the exact multi-cycle design; `generate_hybrid`
//! (same builder) additionally implements NSGA-II-selected neurons as
//! single-cycle approximations (Fig. 2c): a 1-bit register, a 1-bit add
//! and a rewire to the expected leading-1 column.
//!
//! Schedule (after a 1-cycle reset pulse):
//!   cycle 0..N'      — hidden phase, feature `active[cycle]` on the bus
//!   cycle N'..N'+H   — output phase (hidden values muxed through)
//!   cycle N'+H..+C   — argmax phase (one comparison per class)

use crate::model::{ApproxTables, QuantModel};
use crate::netlist::{Netlist, CONST0, CONST1};

use super::rtl::{
    addsub, barrel_shift_left, connect_reg, counter, eq_const, gt_signed, in_range, mux_tree,
    qrelu_unit, reg_word, zext,
};
use super::{acc_widths, encode_weight, index_bits, power_bits, SeqCircuit};

/// Exact multi-cycle design (no approximation).
pub fn generate(model: &QuantModel, active: &[usize]) -> SeqCircuit {
    let approx = vec![false; model.hidden];
    generate_hybrid(model, active, &approx, &ApproxTables::disabled(model.hidden))
}

/// Hybrid design: `approx[h]` selects the single-cycle implementation for
/// hidden neuron `h`, using the offline `tables` (most-important inputs,
/// probed bit, leading-1 column, sign).
///
/// The §3.1.4 common-denominator factoring is applied like a synthesis
/// tool would: both variants are generated and the smaller one kept
/// (factoring wins when the shared weight power is large enough to pay
/// for the bias re-add adder).
pub fn generate_hybrid(
    model: &QuantModel,
    active: &[usize],
    approx: &[bool],
    tables: &ApproxTables,
) -> SeqCircuit {
    let plain = generate_hybrid_opts(model, active, approx, tables, false);
    let factored = generate_hybrid_opts(model, active, approx, tables, true);
    if factored.netlist.cells.len() < plain.netlist.cells.len() {
        factored
    } else {
        plain
    }
}

fn generate_hybrid_opts(
    model: &QuantModel,
    active: &[usize],
    approx: &[bool],
    tables: &ApproxTables,
    factoring: bool,
) -> SeqCircuit {
    assert_eq!(approx.len(), model.hidden);
    let kind = if approx.iter().any(|&a| a) {
        "hybrid"
    } else {
        "seq_multicycle"
    };
    let mut n = Netlist::new(&format!("{}_{kind}", model.name));
    let nf = active.len();
    let (h, c) = (model.hidden, model.classes);
    let cycles = nf + h + c;
    let w = acc_widths(model, active);
    let pw = power_bits(model.pmax);

    // -- controller (§3.1.3): counter state machine -------------------------
    let x = n.add_input("x", 4);
    let rst = n.add_input("rst", 1)[0];
    let statew = index_bits(cycles + 1);
    let state = counter(&mut n, statew, CONST1, rst);
    let hidden_phase = in_range(&mut n, &state, 0, nf as u64);
    let out_phase = in_range(&mut n, &state, nf as u64, (nf + h) as u64);
    let arg_phase = in_range(&mut n, &state, (nf + h) as u64, cycles as u64);
    let out_idx = counter(&mut n, index_bits(h), out_phase, rst);
    let arg_idx = counter(&mut n, index_bits(c), arg_phase, rst);

    // -- hidden layer ---------------------------------------------------------
    let mut hid_vals = Vec::with_capacity(h);
    for nh in 0..h {
        let acc = if approx[nh] {
            approx_neuron(&mut n, model, active, tables, nh, &state, hidden_phase, rst, w.acc1)
        } else {
            exact_neuron(
                &mut n, model, active, nh, &state, &x, hidden_phase, rst, w.acc1, pw, factoring,
            )
        };
        hid_vals.push(qrelu_unit(&mut n, &acc, model.trunc as usize));
    }

    // -- output layer: same datapath, hidden values muxed (no shift regs) ----
    let pw2 = pw;
    let mut out_accs = Vec::with_capacity(c);
    for cc in 0..c {
        let hid_sel = mux_tree(&mut n, &out_idx, &hid_vals);
        let words: Vec<_> = (0..h)
            .map(|j| {
                let i = cc * h + j;
                n.const_word(encode_weight(model.w2p[i], model.w2s[i], pw2), pw2 + 2)
            })
            .collect();
        let wsel = mux_tree(&mut n, &out_idx, &words);
        let p = wsel[..pw2].to_vec();
        let sub = wsel[pw2];
        let nz = wsel[pw2 + 1];
        let term = barrel_shift_left(&mut n, &hid_sel, &p, w.acc2);
        let en = n.and2(out_phase, nz);
        let (q, cells) = reg_word(&mut n, w.acc2, en, rst, model.b2[cc] as i64);
        let sum = addsub(&mut n, &q, &term, sub);
        connect_reg(&mut n, &cells, &sum);
        out_accs.push(q);
    }

    // -- sequential argmax (single comparator, Fig. 3b) ----------------------
    let cur = mux_tree(&mut n, &arg_idx, &out_accs);
    let (best_q, best_cells) = reg_word(&mut n, w.acc2, CONST0, rst, 0);
    let (idx_q, idx_cells) = reg_word(&mut n, index_bits(c), CONST0, rst, 0);
    let gt = gt_signed(&mut n, &cur, &best_q);
    let first = eq_const(&mut n, &arg_idx, 0);
    let take = n.or2(first, gt);
    let upd = n.and2(arg_phase, take);
    // Patch enables: reg_word created them with en=CONST0; rebuild with upd.
    set_reg_enable(&mut n, &best_cells, upd);
    set_reg_enable(&mut n, &idx_cells, upd);
    connect_reg(&mut n, &best_cells, &cur);
    let idx_d = zext(&arg_idx, index_bits(c));
    connect_reg(&mut n, &idx_cells, &idx_d);

    n.add_output("class_out", idx_q);
    let raw_cells = n.cells.len();
    crate::netlist::opt::optimize(&mut n);
    SeqCircuit::new(n, cycles, active.to_vec(), raw_cells)
}

/// Multi-cycle exact neuron (Fig. 2b): weight mux over hardwired
/// constants + barrel shifter + add/sub + accumulator register.
///
/// Implements the §3.1.4 *common-denominator* optimization: the minimum
/// power `cp` shared by the neuron's nonzero weights is factored out of
/// the mux (weights stored as `p − cp`, narrowing both the mux words and
/// the barrel shifter/accumulator by `cp` bits), and multiplied back
/// "afterwards" as free wiring (a static left shift) when the bias is
/// re-added in front of the qReLU.  Bit-exact: every term is a multiple
/// of `2^cp`, so no precision is lost.
#[allow(clippy::too_many_arguments)]
fn exact_neuron(
    n: &mut Netlist,
    model: &QuantModel,
    active: &[usize],
    nh: usize,
    state: &[crate::netlist::NetId],
    x: &[crate::netlist::NetId],
    hidden_phase: crate::netlist::NetId,
    rst: crate::netlist::NetId,
    accw: usize,
    pw: usize,
    factoring: bool,
) -> crate::netlist::Word {
    // Common power denominator + bias-free term range of this neuron.
    let mut cp = i32::MAX;
    let (mut lo, mut hi) = (0i64, 0i64);
    for &f in active {
        let i = nh * model.features + f;
        match model.w1s[i] {
            1 => {
                cp = cp.min(model.w1p[i]);
                hi += 15i64 << model.w1p[i];
            }
            -1 => {
                cp = cp.min(model.w1p[i]);
                lo -= 15i64 << model.w1p[i];
            }
            _ => {}
        }
    }
    if cp == i32::MAX || !factoring {
        cp = 0;
    }

    if cp == 0 {
        // No common factor: classic datapath, bias in the reset constant.
        let words: Vec<_> = active
            .iter()
            .map(|&f| {
                let i = nh * model.features + f;
                n.const_word(encode_weight(model.w1p[i], model.w1s[i], pw), pw + 2)
            })
            .collect();
        let wsel = mux_tree(n, state, &words);
        let p = wsel[..pw].to_vec();
        let sub = wsel[pw];
        let nz = wsel[pw + 1];
        let term = barrel_shift_left(n, x, &p, accw);
        let en = n.and2(hidden_phase, nz);
        let (q, cells) = reg_word(n, accw, en, rst, model.b1[nh] as i64);
        let sum = addsub(n, &q, &term, sub);
        connect_reg(n, &cells, &sum);
        return q;
    }

    // Reduced-scale datapath: accumulate sum_f s*(x << (p-cp)).
    let pw_r = super::rtl::width_for_range(0, (model.pmax as i64 - cp as i64).max(0)).max(1);
    let accw_r = super::rtl::width_for_range(lo >> cp, hi >> cp);
    let words: Vec<_> = active
        .iter()
        .map(|&f| {
            let i = nh * model.features + f;
            let p_r = if model.w1s[i] == 0 { 0 } else { model.w1p[i] - cp };
            n.const_word(encode_weight(p_r, model.w1s[i], pw_r), pw_r + 2)
        })
        .collect();
    let wsel = mux_tree(n, state, &words);
    let p = wsel[..pw_r].to_vec();
    let sub = wsel[pw_r];
    let nz = wsel[pw_r + 1];
    let term = barrel_shift_left(n, x, &p, accw_r);
    let en = n.and2(hidden_phase, nz);
    let (q, cells) = reg_word(n, accw_r, en, rst, 0);
    let sum = addsub(n, &q, &term, sub);
    connect_reg(n, &cells, &sum);

    // Multiply the common denominator back (free wiring: static shift),
    // then re-add the bias in front of the qReLU.
    let mut shifted = vec![crate::netlist::CONST0; cp as usize];
    shifted.extend(super::rtl::sext(&q, accw - cp as usize));
    let bias = n.const_word(model.b1[nh] as i64, accw);
    super::rtl::add(n, &shifted, &bias)
}

/// Single-cycle approximated neuron (Fig. 2c / Fig. 5): capture one bit of
/// each of the two most-important inputs when they arrive (en0/en1 decoded
/// from the controller state), rewire the bits to the expected leading-1
/// columns, and add them to the hardwired bias.
#[allow(clippy::too_many_arguments)]
fn approx_neuron(
    n: &mut Netlist,
    model: &QuantModel,
    active: &[usize],
    tables: &ApproxTables,
    nh: usize,
    state: &[crate::netlist::NetId],
    hidden_phase: crate::netlist::NetId,
    rst: crate::netlist::NetId,
    accw: usize,
) -> crate::netlist::Word {
    // The input bus is the first module input ("x").
    let x: crate::netlist::Word = n.inputs[0].bits.clone();
    // Hardwired expected base (bias + expected dropped contributions) —
    // a constant word, i.e. pure wiring.
    let _ = model;
    let mut acc = n.const_word(tables.base[nh] as i64, accw);
    for k in 0..2 {
        let t = nh * 2 + k;
        let sign = tables.sign[t];
        if sign == 0 {
            continue;
        }
        let feat = tables.idx[t] as usize;
        // Arrival cycle of this input in the RFP schedule; a pruned
        // important input contributes nothing (the framework re-derives
        // tables after RFP, so this only guards hostile inputs).
        let Some(sched) = active.iter().position(|&f| f == feat) else {
            continue;
        };
        let en_cycle = eq_const(n, state, sched as u64);
        let en = n.and2(hidden_phase, en_cycle);
        let bit_in = x[tables.pos[t] as usize];
        // 1-bit register captures the probed bit when the input arrives.
        let (bit_q, cell) = reg_word(n, 1, en, rst, 0);
        connect_reg(n, &cell, &[bit_in]);
        // Rewire to the leading-1 column and add/sub into the constant acc.
        let l1 = tables.l1[t] as usize;
        let mut term = vec![CONST0; accw];
        if l1 < accw {
            term[l1] = bit_q[0];
        }
        acc = addsub(n, &acc, &term, if sign < 0 { CONST1 } else { CONST0 });
    }
    acc
}

/// Replace the enable input of an already-created register word.
fn set_reg_enable(n: &mut Netlist, cells: &[usize], en: crate::netlist::NetId) {
    for &ci in cells {
        if let crate::netlist::Cell::Dff { en: slot, .. } = &mut n.cells[ci] {
            *slot = en;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::testutil::rand_model;
    use crate::sim::testbench;

    #[test]
    fn tiny_model_matches_functional() {
        let m = rand_model(7, 6, 2, 3);
        let active: Vec<usize> = (0..6).collect();
        let circ = generate(&m, &active);
        let mut xs = Vec::new();
        let mut r = crate::util::prng::Rng::new(1);
        let samples = 20;
        for _ in 0..samples * m.features {
            xs.push(r.below(16) as u8);
        }
        let preds = testbench::run_sequential(&circ, &xs, samples, m.features);
        for i in 0..samples {
            let x: Vec<i32> = (0..m.features)
                .map(|f| xs[i * m.features + f] as i32)
                .collect();
            let (want, _) = m.forward_exact(&x);
            assert_eq!(preds[i] as usize, want, "sample {i}");
        }
    }

    #[test]
    fn common_denominator_path_is_bit_exact_and_smaller() {
        // Force cp > 0: every nonzero weight power >= 3.
        let mut m = rand_model(71, 8, 3, 3);
        for i in 0..m.w1p.len() {
            if m.w1s[i] != 0 {
                m.w1p[i] = 3 + (m.w1p[i] % 4); // powers in [3, 6]
            }
        }
        let active: Vec<usize> = (0..8).collect();
        let circ = generate(&m, &active);
        let mut r = crate::util::prng::Rng::new(4);
        let samples = 25;
        let xs: Vec<u8> = (0..samples * m.features).map(|_| r.below(16) as u8).collect();
        let preds = testbench::run_sequential(&circ, &xs, samples, m.features);
        for i in 0..samples {
            let x: Vec<i32> = (0..m.features).map(|f| xs[i * m.features + f] as i32).collect();
            let (want, _) = m.forward_exact(&x);
            assert_eq!(preds[i] as usize, want, "sample {i}");
        }
        // And the factored design must not be larger than the unfactored
        // one (same model with powers shifted down to force cp == 0).
        let mut m0 = m.clone();
        for i in 0..m0.w1p.len() {
            if m0.w1s[i] != 0 && m0.w1p[i] > 0 {
                // introduce one p=0 weight per neuron to kill the factor
            }
        }
        if let Some(slot) = m0.w1s.iter().position(|&s| s != 0) {
            m0.w1p[slot] = 0;
        }
        let unfactored = generate(&m0, &active);
        assert!(
            crate::tech::report(&circ.netlist).area_cm2
                <= crate::tech::report(&unfactored.netlist).area_cm2 + 1e-9,
            "factoring must not grow the circuit"
        );
    }

    #[test]
    fn cycles_contract() {
        let m = rand_model(9, 5, 2, 2);
        let active = vec![0, 2, 4];
        let c = generate(&m, &active);
        assert_eq!(c.cycles, 3 + 2 + 2);
    }

    #[test]
    fn mux_hardwiring_beats_registers_in_dffs() {
        // The whole point of §3.1.4: our design has far fewer DFFs than a
        // weight-register design would need (which is F*neuron words).
        let m = rand_model(11, 32, 4, 3);
        let active: Vec<usize> = (0..32).collect();
        let c = generate(&m, &active);
        let weight_reg_dffs = 32 * 4 * 5; // what seq_sota would spend
        assert!(
            c.netlist.n_dffs() < weight_reg_dffs / 2,
            "dffs={} vs reg design {}",
            c.netlist.n_dffs(),
            weight_reg_dffs
        );
    }
}
