//! Hybrid sequential architecture: multi-cycle neurons plus
//! NSGA-II-selected single-cycle (approximated) neurons — the paper's full
//! proposed design (§3.1.2 + §3.2.3).  The builder lives in
//! [`super::seq_multicycle`]; this module re-exports it under the paper's
//! name and carries the hybrid-specific tests.

pub use super::seq_multicycle::generate_hybrid as generate;

#[cfg(test)]
mod tests {
    use crate::circuits::testutil::rand_model;
    use crate::model::importance;
    use crate::sim::testbench;

    #[test]
    fn hybrid_matches_functional_model() {
        let m = rand_model(41, 9, 4, 3);
        let active: Vec<usize> = (0..9).collect();
        let mut r = crate::util::prng::Rng::new(3);
        let samples = 30;
        let xs: Vec<u8> = (0..samples * m.features).map(|_| r.below(16) as u8).collect();

        // Tables from the sample statistics, like the real framework.
        let fm = vec![1u8; m.features];
        let tables = importance::approx_tables(&m, &xs, samples, &fm);

        for approx_pattern in [[true, false, false, false], [true, true, false, true], [true; 4]] {
            let approx: Vec<bool> = approx_pattern.to_vec();
            let circ = super::generate(&m, &active, &approx, &tables);
            let preds = testbench::run_sequential(&circ, &xs, samples, m.features);
            let am: Vec<u8> = approx.iter().map(|&b| b as u8).collect();
            for i in 0..samples {
                let x: Vec<i32> =
                    (0..m.features).map(|f| xs[i * m.features + f] as i32).collect();
                let (want, _) = m.forward(&x, &fm, &am, &tables);
                assert_eq!(preds[i] as usize, want, "pattern {approx_pattern:?} sample {i}");
            }
        }
    }

    #[test]
    fn approx_neurons_shrink_area() {
        let m = rand_model(42, 40, 4, 3);
        let active: Vec<usize> = (0..40).collect();
        let xs: Vec<u8> = (0..64 * 40).map(|i| (i % 16) as u8).collect();
        let tables = importance::approx_tables(&m, &xs, 64, &[1u8; 40]);

        let exact = super::super::seq_multicycle::generate(&m, &active);
        let hybrid = super::generate(&m, &active, &[true, true, true, false], &tables);
        let a_exact = crate::tech::report(&exact.netlist).area_cm2;
        let a_hybrid = crate::tech::report(&hybrid.netlist).area_cm2;
        assert!(
            a_hybrid < a_exact,
            "hybrid {a_hybrid} must be smaller than exact {a_exact}"
        );
    }

    #[test]
    fn hybrid_with_rfp_schedule_matches() {
        // Approximation composed with feature pruning: tables derived from
        // the masked feature set, circuit built on the pruned schedule.
        let m = rand_model(43, 12, 3, 2);
        let active = vec![0, 2, 3, 5, 7, 8, 11];
        let mut fm = vec![0u8; 12];
        for &f in &active {
            fm[f] = 1;
        }
        let samples = 25;
        let mut r = crate::util::prng::Rng::new(9);
        let xs: Vec<u8> = (0..samples * 12).map(|_| r.below(16) as u8).collect();
        let tables = importance::approx_tables(&m, &xs, samples, &fm);
        let approx = vec![true, false, true];
        let circ = super::generate(&m, &active, &approx, &tables);
        let preds = testbench::run_sequential(&circ, &xs, samples, 12);
        let am: Vec<u8> = approx.iter().map(|&b| b as u8).collect();
        for i in 0..samples {
            let x: Vec<i32> = (0..12).map(|f| xs[i * 12 + f] as i32).collect();
            let (want, _) = m.forward(&x, &fm, &am, &tables);
            assert_eq!(preds[i] as usize, want, "sample {i}");
        }
    }
}
