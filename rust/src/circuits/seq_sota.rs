//! Conventional sequential MLP — the MICRO'20 [16]-style baseline
//! (Fig. 3a): weights live in per-neuron circulating shift registers and
//! hidden activations cross to the output layer through a parallel-load
//! shift-register chain.  Identical datapath (barrel shifter, add/sub,
//! accumulator) and controller to the proposed design, so the *only*
//! difference Fig. 6 measures is register storage vs mux hardwiring.

use crate::model::QuantModel;
use crate::netlist::{Netlist, NetId, Word, CONST1};

use super::rtl::{
    addsub, barrel_shift_left, connect_reg, counter, eq_const, gt_signed, in_range, mux_tree,
    mux_word, qrelu_unit, reg_word, zext,
};
use super::{acc_widths, encode_weight, index_bits, power_bits, SeqCircuit};

/// A circulating shift register of `words.len()` entries; entry 0 is the
/// readable head.  Reset loads the constant contents; `en` rotates by one.
fn circulating_regfile(n: &mut Netlist, contents: &[i64], width: usize, en: NetId, rst: NetId) -> Word {
    let k = contents.len();
    let mut qs: Vec<Word> = Vec::with_capacity(k);
    let mut cells: Vec<Vec<usize>> = Vec::with_capacity(k);
    for &c in contents {
        let (q, cs) = reg_word(n, width, en, rst, c);
        qs.push(q);
        cells.push(cs);
    }
    // word_i <= word_{i+1}; word_{k-1} <= word_0 (recirculate).
    for i in 0..k {
        let src = qs[(i + 1) % k].clone();
        connect_reg(n, &cells[i], &src);
    }
    qs[0].clone()
}

/// Generate the conventional sequential design.
pub fn generate(model: &QuantModel, active: &[usize]) -> SeqCircuit {
    let mut n = Netlist::new(&format!("{}_seq_sota", model.name));
    let nf = active.len();
    let (h, c) = (model.hidden, model.classes);
    let cycles = nf + h + c;
    let w = acc_widths(model, active);
    let pw = power_bits(model.pmax);

    let x = n.add_input("x", 4);
    let rst = n.add_input("rst", 1)[0];
    let statew = index_bits(cycles + 1);
    let state = counter(&mut n, statew, CONST1, rst);
    let hidden_phase = in_range(&mut n, &state, 0, nf as u64);
    let out_phase = in_range(&mut n, &state, nf as u64, (nf + h) as u64);
    let arg_phase = in_range(&mut n, &state, (nf + h) as u64, cycles as u64);
    let arg_idx = counter(&mut n, index_bits(c), arg_phase, rst);

    // Hidden neurons: weight shift register + shared datapath.
    let mut hid_vals = Vec::with_capacity(h);
    for nh in 0..h {
        let contents: Vec<i64> = active
            .iter()
            .map(|&f| {
                let i = nh * model.features + f;
                encode_weight(model.w1p[i], model.w1s[i], pw)
            })
            .collect();
        let wsel = circulating_regfile(&mut n, &contents, pw + 2, hidden_phase, rst);
        let p = wsel[..pw].to_vec();
        let sub = wsel[pw];
        let nz = wsel[pw + 1];
        let term = barrel_shift_left(&mut n, &x, &p, w.acc1);
        let en = n.and2(hidden_phase, nz);
        let (q, cells) = reg_word(&mut n, w.acc1, en, rst, model.b1[nh] as i64);
        let sum = addsub(&mut n, &q, &term, sub);
        connect_reg(&mut n, &cells, &sum);
        hid_vals.push(qrelu_unit(&mut n, &q, model.trunc as usize));
    }

    // Inter-layer shifting registers (the costly part [16]): parallel-load
    // the qReLU outputs when the hidden phase ends, then shift one value
    // per output cycle toward the head.
    let load = eq_const(&mut n, &state, nf as u64); // first output cycle
    let shift_en = n.or2(out_phase, load);
    let mut chain_q: Vec<Word> = Vec::with_capacity(h);
    let mut chain_cells: Vec<Vec<usize>> = Vec::with_capacity(h);
    for _ in 0..h {
        let (q, cs) = reg_word(&mut n, 4, shift_en, rst, 0);
        chain_q.push(q);
        chain_cells.push(cs);
    }
    for i in 0..h {
        let shifted = if i + 1 < h {
            chain_q[i + 1].clone()
        } else {
            vec![crate::netlist::CONST0; 4]
        };
        // During the load cycle hid[0] is consumed via the bypass below, so
        // the chain captures hid[i+1] (pre-shifted by one); afterwards it
        // shifts one value toward the head per output cycle.
        let loaded = if i + 1 < h {
            hid_vals[i + 1].clone()
        } else {
            vec![crate::netlist::CONST0; 4]
        };
        let d = mux_word(&mut n, load, &shifted, &loaded);
        connect_reg(&mut n, &chain_cells[i], &d);
    }
    // NOTE on timing: `load` is asserted during the first output cycle, so
    // the chain head holds hid[0] from the *second* output cycle on.  To
    // keep the same total cycle count as the proposed design, output
    // neurons consume hid[0] combinationally during the load cycle (the
    // mux below) and the shifted chain afterwards — the standard bypass.
    let head_bypass = mux_word(&mut n, load, &chain_q[0], &hid_vals[0]);

    // Output neurons: weight shift registers + shared datapath over the
    // chain head.
    let mut out_accs = Vec::with_capacity(c);
    for cc in 0..c {
        let contents: Vec<i64> = (0..h)
            .map(|j| {
                let i = cc * h + j;
                encode_weight(model.w2p[i], model.w2s[i], pw)
            })
            .collect();
        let wsel = circulating_regfile(&mut n, &contents, pw + 2, out_phase, rst);
        let p = wsel[..pw].to_vec();
        let sub = wsel[pw];
        let nz = wsel[pw + 1];
        let term = barrel_shift_left(&mut n, &head_bypass, &p, w.acc2);
        let en = n.and2(out_phase, nz);
        let (q, cells) = reg_word(&mut n, w.acc2, en, rst, model.b2[cc] as i64);
        let sum = addsub(&mut n, &q, &term, sub);
        connect_reg(&mut n, &cells, &sum);
        out_accs.push(q);
    }

    // Sequential argmax, same as the proposed design.
    let cur = mux_tree(&mut n, &arg_idx, &out_accs);
    let (best_q, best_cells) = reg_word(&mut n, w.acc2, crate::netlist::CONST0, rst, 0);
    let (idx_q, idx_cells) = reg_word(&mut n, index_bits(c), crate::netlist::CONST0, rst, 0);
    let gt = gt_signed(&mut n, &cur, &best_q);
    let first = eq_const(&mut n, &arg_idx, 0);
    let take = n.or2(first, gt);
    let upd = n.and2(arg_phase, take);
    for &ci in best_cells.iter().chain(&idx_cells) {
        if let crate::netlist::Cell::Dff { en: slot, .. } = &mut n.cells[ci] {
            *slot = upd;
        }
    }
    connect_reg(&mut n, &best_cells, &cur);
    let idx_d = zext(&arg_idx, index_bits(c));
    connect_reg(&mut n, &idx_cells, &idx_d);

    n.add_output("class_out", idx_q);
    let raw_cells = n.cells.len();
    crate::netlist::opt::optimize(&mut n);
    SeqCircuit::new(n, cycles, active.to_vec(), raw_cells)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::testutil::rand_model;
    use crate::sim::testbench;

    #[test]
    fn matches_functional_model() {
        let m = rand_model(31, 7, 3, 3);
        let active: Vec<usize> = (0..7).collect();
        let circ = generate(&m, &active);
        let mut r = crate::util::prng::Rng::new(2);
        let samples = 30;
        let xs: Vec<u8> = (0..samples * m.features).map(|_| r.below(16) as u8).collect();
        let preds = testbench::run_sequential(&circ, &xs, samples, m.features);
        for i in 0..samples {
            let x: Vec<i32> = (0..m.features).map(|f| xs[i * m.features + f] as i32).collect();
            let (want, _) = m.forward_exact(&x);
            assert_eq!(preds[i] as usize, want, "sample {i}");
        }
    }

    #[test]
    fn register_heavy_as_expected() {
        // Weight storage alone: (nf*h + h*c) words of (pw+2) bits.
        let m = rand_model(32, 16, 3, 2);
        let active: Vec<usize> = (0..16).collect();
        let circ = generate(&m, &active);
        let pw = power_bits(m.pmax);
        let weight_dffs = (16 * 3 + 3 * 2) * (pw + 2);
        assert!(
            circ.netlist.n_dffs() >= weight_dffs,
            "dffs={} want >= {weight_dffs}",
            circ.netlist.n_dffs()
        );
    }

    #[test]
    fn more_dffs_than_multicycle() {
        let m = rand_model(33, 24, 4, 3);
        let active: Vec<usize> = (0..24).collect();
        let sota = generate(&m, &active);
        let ours = super::super::seq_multicycle::generate(&m, &active);
        assert!(sota.netlist.n_dffs() > 2 * ours.netlist.n_dffs());
    }
}
