//! Fully-parallel bespoke combinational MLP — the [14]-style baseline
//! (Fig. 3a left input stage, no registers at all).
//!
//! Every active feature is an input port; power-of-2 multiplies are pure
//! wiring (shift), positive and negative products are summed in separate
//! minimal-width unsigned adder trees, and
//! `acc = bias + sum_pos - sum_neg` closes the neuron.  qReLU and a
//! comparator-tree argmax complete the single-cycle datapath.

use crate::model::QuantModel;
use crate::netlist::{Netlist, Word, CONST0};

use super::rtl::{add_cin, gt_signed, mux_word, qrelu_unit, sext, width_for_range, zext};
use super::{acc_widths, index_bits, CombCircuit};

/// An unsigned partial sum with its statically known maximum value.
struct Term {
    word: Word,
    max: i64,
}

/// Unsigned add with just enough output width.
fn add_u(n: &mut Netlist, a: &Term, b: &Term) -> Term {
    let max = a.max + b.max;
    let w = width_for_range(0, max);
    let aw = zext(&a.word, w);
    let bw = zext(&b.word, w);
    Term {
        word: add_cin(n, &aw, &bw, CONST0),
        max,
    }
}

/// Balanced tree reduction of unsigned terms.
fn sum_tree(n: &mut Netlist, mut terms: Vec<Term>) -> Term {
    if terms.is_empty() {
        return Term {
            word: vec![CONST0],
            max: 0,
        };
    }
    while terms.len() > 1 {
        let mut next = Vec::with_capacity(terms.len().div_ceil(2));
        let mut it = terms.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(add_u(n, &a, &b)),
                None => next.push(a),
            }
        }
        terms = next;
    }
    terms.pop().unwrap()
}

/// acc = bias + pos - neg, at `accw` bits (signed).
fn close_accumulator(n: &mut Netlist, bias: i64, pos: Term, neg: Term, accw: usize) -> Word {
    let p = zext(&pos.word, accw);
    let m = zext(&neg.word, accw);
    // p - m
    let minv: Word = m.iter().map(|&b| n.inv(b)).collect();
    let diff = add_cin(n, &p, &minv, crate::netlist::CONST1);
    // + bias (constant add folds heavily)
    let bw = n.const_word(bias, accw);
    add_cin(n, &diff, &bw, CONST0)
}

/// Shift-add terms of one neuron over `inputs` (each 4-bit unsigned).
/// Power-of-2 multiplies are pure wiring, so no gates are emitted here.
fn neuron_terms(
    _n: &mut Netlist,
    inputs: &[Word],
    powers: &[i32],
    signs: &[i32],
) -> (Vec<Term>, Vec<Term>) {
    let mut pos = Vec::new();
    let mut neg = Vec::new();
    for (x, (&p, &s)) in inputs.iter().zip(powers.iter().zip(signs)) {
        if s == 0 {
            continue;
        }
        // x << p is wiring: p zero LSBs then the input bits.
        let mut word = vec![CONST0; p as usize];
        word.extend_from_slice(x);
        let t = Term {
            word,
            max: 15i64 << p,
        };
        if s > 0 {
            pos.push(t);
        } else {
            neg.push(t);
        }
    }
    (pos, neg)
}

/// Comparator-tree argmax over signed words; returns the index word.
fn argmax_tree(n: &mut Netlist, values: &[Word], accw: usize) -> Word {
    let iw = index_bits(values.len());
    let mut layer: Vec<(Word, Word)> = values
        .iter()
        .enumerate()
        .map(|(i, v)| (sext(v, accw), n.const_word(i as i64, iw)))
        .collect();
    while layer.len() > 1 {
        let mut next = Vec::with_capacity(layer.len().div_ceil(2));
        let mut it = layer.into_iter();
        while let Some((av, ai)) = it.next() {
            match it.next() {
                Some((bv, bi)) => {
                    // Strict >: on ties the lower index (a) wins, matching
                    // the functional model and jnp.argmax.
                    let bgt = gt_signed(n, &bv, &av);
                    let v = mux_word(n, bgt, &av, &bv);
                    let i = mux_word(n, bgt, &ai, &bi);
                    next.push((v, i));
                }
                None => next.push((av, ai)),
            }
        }
        layer = next;
    }
    layer.pop().unwrap().1
}

/// Generate the combinational design over the active feature set.
pub fn generate(model: &QuantModel, active: &[usize]) -> CombCircuit {
    let mut n = Netlist::new(&format!("{}_comb", model.name));
    let w = acc_widths(model, active);

    // One wide input port, 4 bits per active feature (ADC outputs).
    let x_all = n.add_input("x_all", 4 * active.len());
    let inputs: Vec<Word> = (0..active.len())
        .map(|i| x_all[i * 4..(i + 1) * 4].to_vec())
        .collect();

    // Hidden layer.
    let mut hid = Vec::with_capacity(model.hidden);
    for h in 0..model.hidden {
        let powers: Vec<i32> = active.iter().map(|&f| model.w1p[h * model.features + f]).collect();
        let signs: Vec<i32> = active.iter().map(|&f| model.w1s[h * model.features + f]).collect();
        let (pos, neg) = neuron_terms(&mut n, &inputs, &powers, &signs);
        let pos = sum_tree(&mut n, pos);
        let neg = sum_tree(&mut n, neg);
        let acc = close_accumulator(&mut n, model.b1[h] as i64, pos, neg, w.acc1);
        hid.push(qrelu_unit(&mut n, &acc, model.trunc as usize));
    }

    // Output layer.
    let mut logits = Vec::with_capacity(model.classes);
    for c in 0..model.classes {
        let powers: Vec<i32> = (0..model.hidden).map(|h| model.w2p[c * model.hidden + h]).collect();
        let signs: Vec<i32> = (0..model.hidden).map(|h| model.w2s[c * model.hidden + h]).collect();
        let (pos, neg) = neuron_terms(&mut n, &hid, &powers, &signs);
        let pos = sum_tree(&mut n, pos);
        let neg = sum_tree(&mut n, neg);
        logits.push(close_accumulator(&mut n, model.b2[c] as i64, pos, neg, w.acc2));
    }

    let idx = argmax_tree(&mut n, &logits, w.acc2);
    n.add_output("class_out", idx);
    let raw_cells = n.cells.len();
    crate::netlist::opt::optimize(&mut n);
    CombCircuit::new(n, active.to_vec(), raw_cells)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::testutil::rand_model;
    use crate::sim::testbench;

    #[test]
    fn matches_functional_model() {
        let m = rand_model(21, 8, 3, 4);
        let active: Vec<usize> = (0..8).collect();
        let circ = generate(&m, &active);
        let mut r = crate::util::prng::Rng::new(5);
        let samples = 40;
        let xs: Vec<u8> = (0..samples * m.features).map(|_| r.below(16) as u8).collect();
        let preds = testbench::run_combinational(&circ, &xs, samples, m.features);
        for i in 0..samples {
            let x: Vec<i32> = (0..m.features).map(|f| xs[i * m.features + f] as i32).collect();
            let (want, _) = m.forward_exact(&x);
            assert_eq!(preds[i] as usize, want, "sample {i}");
        }
    }

    #[test]
    fn no_registers() {
        let m = rand_model(22, 6, 2, 2);
        let circ = generate(&m, &(0..6).collect::<Vec<_>>());
        assert_eq!(circ.netlist.n_dffs(), 0);
    }

    #[test]
    fn respects_feature_mask() {
        // Pruned features must not appear as inputs at all.
        let m = rand_model(23, 10, 2, 2);
        let active = vec![1, 3, 5];
        let circ = generate(&m, &active);
        let x_all = &circ.netlist.inputs[0].bits;
        assert_eq!(x_all.len(), 12);
        // Functional equivalence under the matching mask:
        let mut fm = vec![0u8; 10];
        for &f in &active {
            fm[f] = 1;
        }
        let am = vec![0u8; 2];
        let t = crate::model::ApproxTables::disabled(2);
        let mut r = crate::util::prng::Rng::new(6);
        let xs: Vec<u8> = (0..20 * 10).map(|_| r.below(16) as u8).collect();
        let preds = testbench::run_combinational(&circ, &xs, 20, 10);
        for i in 0..20 {
            let x: Vec<i32> = (0..10).map(|f| xs[i * 10 + f] as i32).collect();
            let (want, _) = m.forward(&x, &fm, &am, &t);
            assert_eq!(preds[i] as usize, want, "sample {i}");
        }
    }
}
