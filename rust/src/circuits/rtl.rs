//! Word-level RTL building blocks over the gate IR: ripple-carry
//! adders/subtractors, barrel shifters, mux trees, comparators, counters,
//! registers and the qReLU unit — the components Fig. 2/Fig. 3 compose.
//!
//! All words are LSB-first two's complement unless stated otherwise.

use crate::netlist::{NetId, Netlist, Word, CONST0, CONST1};

/// Zero-extend (unsigned) to `width`.
pub fn zext(w: &[NetId], width: usize) -> Word {
    let mut out = w.to_vec();
    while out.len() < width {
        out.push(CONST0);
    }
    out.truncate(width);
    out
}

/// Sign-extend (two's complement) to `width`.
pub fn sext(w: &[NetId], width: usize) -> Word {
    let mut out = w.to_vec();
    let msb = *out.last().unwrap_or(&CONST0);
    while out.len() < width {
        out.push(msb);
    }
    out.truncate(width);
    out
}

/// Full adder on three bits; returns (sum, carry).
pub fn full_adder(n: &mut Netlist, a: NetId, b: NetId, c: NetId) -> (NetId, NetId) {
    let axb = n.xor2(a, b);
    let s = n.xor2(axb, c);
    let t1 = n.and2(a, b);
    let t2 = n.and2(axb, c);
    let cout = n.or2(t1, t2);
    (s, cout)
}

/// Ripple-carry add with carry-in; output has the width of the inputs
/// (caller sizes words to avoid overflow).
pub fn add_cin(n: &mut Netlist, a: &[NetId], b: &[NetId], cin: NetId) -> Word {
    assert_eq!(a.len(), b.len());
    let mut carry = cin;
    let mut out = Vec::with_capacity(a.len());
    for i in 0..a.len() {
        let (s, c) = full_adder(n, a[i], b[i], carry);
        out.push(s);
        carry = c;
    }
    out
}

pub fn add(n: &mut Netlist, a: &[NetId], b: &[NetId]) -> Word {
    add_cin(n, a, b, CONST0)
}

/// a - b (two's complement, same width).
pub fn sub(n: &mut Netlist, a: &[NetId], b: &[NetId]) -> Word {
    let nb: Word = b.iter().map(|&x| n.inv(x)).collect();
    add_cin(n, a, &nb, CONST1)
}

/// a + (sub ? -b : b): conditional subtract (the neuron's ±product path,
/// Fig. 2b: "multiplexer with and without inverters").
pub fn addsub(n: &mut Netlist, a: &[NetId], b: &[NetId], sub_sel: NetId) -> Word {
    assert_eq!(a.len(), b.len());
    let bx: Word = b.iter().map(|&x| n.xor2(x, sub_sel)).collect();
    add_cin(n, a, &bx, sub_sel)
}

/// Word-wise 2:1 mux.
pub fn mux_word(n: &mut Netlist, sel: NetId, a: &[NetId], b: &[NetId]) -> Word {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| n.mux2(sel, x, y))
        .collect()
}

/// N:1 mux tree: `items[i]` selected when `sel == i`.  Items beyond the
/// list repeat the last entry (don't-care).  Constant leaves collapse in
/// the builder, which is exactly how hardwired-weight muxes get cheap
/// (§3.1.4).
pub fn mux_tree(n: &mut Netlist, sel: &[NetId], items: &[Word]) -> Word {
    assert!(!items.is_empty());
    let width = items[0].len();
    debug_assert!(items.iter().all(|w| w.len() == width));
    let mut layer: Vec<Word> = items.to_vec();
    for &s in sel {
        if layer.len() == 1 {
            break;
        }
        let mut next = Vec::with_capacity(layer.len().div_ceil(2));
        for pair in layer.chunks(2) {
            if pair.len() == 2 {
                next.push(mux_word(n, s, &pair[0], &pair[1]));
            } else {
                next.push(pair[0].clone());
            }
        }
        layer = next;
    }
    assert_eq!(layer.len(), 1, "sel too narrow for {} items", items.len());
    layer.pop().unwrap()
}

/// Left barrel shifter: `x << sh`, output `out_width` bits (unsigned x).
pub fn barrel_shift_left(n: &mut Netlist, x: &[NetId], sh: &[NetId], out_width: usize) -> Word {
    let mut cur = zext(x, out_width);
    for (k, &s) in sh.iter().enumerate() {
        let amount = 1usize << k;
        if amount >= out_width {
            // Shifting everything out: result must be 0 when s=1; the
            // generators never produce this (sh is sized to pmax), but
            // keep it correct anyway.
            let zero = vec![CONST0; out_width];
            cur = mux_word(n, s, &cur, &zero);
            continue;
        }
        let mut shifted = vec![CONST0; amount];
        shifted.extend_from_slice(&cur[..out_width - amount]);
        cur = mux_word(n, s, &cur, &shifted);
    }
    cur
}

/// Signed greater-than: a > b (two's complement, equal widths).
pub fn gt_signed(n: &mut Netlist, a: &[NetId], b: &[NetId]) -> NetId {
    // a > b  <=>  (b - a) is negative XOR overflow; compute b - a and take
    // the "true sign" = msb ^ overflow. Simpler: extend one bit then sub.
    let w = a.len() + 1;
    let ax = sext(a, w);
    let bx = sext(b, w);
    let d = sub(n, &bx, &ax); // b - a
    d[w - 1] // sign bit: 1 when b - a < 0 i.e. a > b
}

/// Equality against a constant.
pub fn eq_const(n: &mut Netlist, w: &[NetId], value: u64) -> NetId {
    let mut acc = CONST1;
    for (i, &bit) in w.iter().enumerate() {
        let want1 = (value >> i) & 1 == 1;
        let term = if want1 { bit } else { n.inv(bit) };
        acc = n.and2(acc, term);
    }
    acc
}

/// Unsigned `w < value` (constant bound) — used for phase decoding.
pub fn lt_const(n: &mut Netlist, w: &[NetId], value: u64) -> NetId {
    // Classic magnitude comparator against a constant, MSB down.
    let mut lt = CONST0;
    let mut eq = CONST1;
    for (i, &bit) in w.iter().enumerate().rev() {
        let c = (value >> i) & 1 == 1;
        if c {
            let nb = n.inv(bit);
            let t = n.and2(eq, nb);
            lt = n.or2(lt, t);
            eq = n.and2(eq, bit);
        } else {
            let nb = n.inv(bit);
            eq = n.and2(eq, nb);
        }
    }
    lt
}

/// `lo <= w < hi` phase decode.
pub fn in_range(n: &mut Netlist, w: &[NetId], lo: u64, hi: u64) -> NetId {
    let below_hi = lt_const(n, w, hi);
    if lo == 0 {
        below_hi
    } else {
        let below_lo = lt_const(n, w, lo);
        let ge_lo = n.inv(below_lo);
        n.and2(ge_lo, below_hi)
    }
}

/// A register word with enable + synchronous reset to a constant value.
/// Returns `(q, cell_indices)`; connect data with [`connect_reg`].
pub fn reg_word(
    n: &mut Netlist,
    width: usize,
    en: NetId,
    rst: NetId,
    rstval: i64,
) -> (Word, Vec<usize>) {
    let mut q = Vec::with_capacity(width);
    let mut idx = Vec::with_capacity(width);
    for i in 0..width {
        let bit = (rstval >> i) & 1 == 1;
        let (qi, ci) = n.dff_deferred(en, rst, bit);
        q.push(qi);
        idx.push(ci);
    }
    (q, idx)
}

pub fn connect_reg(n: &mut Netlist, cells: &[usize], d: &[NetId]) {
    assert_eq!(cells.len(), d.len());
    for (&c, &bit) in cells.iter().zip(d) {
        n.set_dff_d(c, bit);
    }
}

/// Free-running counter: increments every cycle when `en`, resets to 0.
pub fn counter(n: &mut Netlist, width: usize, en: NetId, rst: NetId) -> Word {
    let (q, cells) = reg_word(n, width, en, rst, 0);
    let one = n.const_word(1, width);
    let d = add(n, &q, &one);
    connect_reg(n, &cells, &d);
    q
}

/// qReLU (§3.2.1): `clamp(max(acc,0) >> trunc, 0, 15)` over a signed
/// accumulator word; 4-bit output.
pub fn qrelu_unit(n: &mut Netlist, acc: &[NetId], trunc: usize) -> Word {
    let w = acc.len();
    let sign = acc[w - 1];
    // Saturate when any bit above the extracted window is set (positive).
    let hi_start = trunc + 4;
    let mut any_hi = CONST0;
    for i in hi_start..w - 1 {
        any_hi = n.or2(any_hi, acc[i]);
    }
    let npos = n.inv(sign);
    let mut out = Vec::with_capacity(4);
    for i in 0..4 {
        let bit = if trunc + i < w - 1 { acc[trunc + i] } else { CONST0 };
        // bit OR saturation, then gated by positive sign.
        let sat = n.or2(bit, any_hi);
        out.push(n.and2(sat, npos));
    }
    out
}

/// Exact number of bits to represent the signed range [lo, hi].
pub fn width_for_range(lo: i64, hi: i64) -> usize {
    let mut w = 1;
    while ((-(1i64 << (w - 1))) > lo) || ((1i64 << (w - 1)) - 1 < hi) {
        w += 1;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Sim;

    fn harness<F: FnOnce(&mut Netlist) -> (Vec<Word>, Word)>(f: F) -> (Netlist, Vec<Word>, Word) {
        let mut n = Netlist::new("t");
        let (ins, out) = f(&mut n);
        n.add_output("y", out.clone());
        (n, ins, out)
    }

    #[test]
    fn adder_exhaustive_6bit() {
        let (n, ins, out) = harness(|n| {
            let a = n.add_input("a", 6);
            let b = n.add_input("b", 6);
            let y = add(n, &a, &b);
            (vec![a, b], y)
        });
        let mut s = Sim::new(&n);
        for a in -8i64..8 {
            let lanes_b: Vec<i64> = (-32..32).collect();
            s.set_word_all(&ins[0], a);
            s.set_word_lanes(&ins[1], &lanes_b);
            s.eval();
            for (lane, &b) in lanes_b.iter().enumerate() {
                let want = (a + b) & 0x3F;
                let got = s.get_word_lane(&out, lane) as i64;
                assert_eq!(got, want, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn addsub_matches_reference() {
        let (n, ins, out) = harness(|n| {
            let a = n.add_input("a", 8);
            let b = n.add_input("b", 8);
            let sel = n.add_input("s", 1);
            let y = addsub(n, &a, &b, sel[0]);
            (vec![a, b, sel], y)
        });
        let mut s = Sim::new(&n);
        for (a, b) in [(5i64, 3i64), (-20, 7), (100, 100), (-128, 1), (0, -1)] {
            for sel in [0i64, 1] {
                s.set_word_all(&ins[0], a);
                s.set_word_all(&ins[1], b);
                s.set_word_all(&ins[2], sel);
                s.eval();
                let want = if sel == 1 { a - b } else { a + b };
                assert_eq!(
                    s.get_word_lane_signed(&out, 0),
                    ((want + 128) & 0xFF) - 128,
                    "a={a} b={b} sel={sel}"
                );
            }
        }
    }

    #[test]
    fn barrel_shifter_all_amounts() {
        let (n, ins, out) = harness(|n| {
            let x = n.add_input("x", 4);
            let sh = n.add_input("sh", 4);
            let y = barrel_shift_left(n, &x, &sh, 20);
            (vec![x, sh], y)
        });
        let mut s = Sim::new(&n);
        for x in 0..16i64 {
            for sh in 0..16i64 {
                s.set_word_all(&ins[0], x);
                s.set_word_all(&ins[1], sh);
                s.eval();
                let want = if sh >= 20 { 0 } else { (x << sh) & ((1 << 20) - 1) };
                assert_eq!(s.get_word_lane(&out, 0) as i64, want, "x={x} sh={sh}");
            }
        }
    }

    #[test]
    fn mux_tree_selects() {
        let items: Vec<i64> = vec![3, 9, 1, 14, 7, 0, 12, 5];
        let (n, ins, out) = harness(|n| {
            let sel = n.add_input("sel", 3);
            let words: Vec<Word> = items.iter().map(|&v| n.const_word(v, 4)).collect();
            let y = mux_tree(n, &sel, &words);
            (vec![sel], y)
        });
        let mut s = Sim::new(&n);
        for (i, &want) in items.iter().enumerate() {
            s.set_word_all(&ins[0], i as i64);
            s.eval();
            assert_eq!(s.get_word_lane(&out, 0) as i64, want, "sel={i}");
        }
    }

    #[test]
    fn gt_signed_cases() {
        let (n, ins, out) = harness(|n| {
            let a = n.add_input("a", 6);
            let b = n.add_input("b", 6);
            let y = gt_signed(n, &a, &b);
            (vec![a, b], vec![y])
        });
        let mut s = Sim::new(&n);
        for (a, b) in [(0i64, 0i64), (5, -5), (-5, 5), (-32, 31), (31, 30), (-1, -2)] {
            s.set_word_all(&ins[0], a);
            s.set_word_all(&ins[1], b);
            s.eval();
            assert_eq!(s.get_word_lane(&out, 0) == 1, a > b, "a={a} b={b}");
        }
    }

    #[test]
    fn range_decode() {
        let (n, ins, out) = harness(|n| {
            let w = n.add_input("w", 5);
            let y = in_range(n, &w, 3, 11);
            (vec![w], vec![y])
        });
        let mut s = Sim::new(&n);
        for v in 0..32i64 {
            s.set_word_all(&ins[0], v);
            s.eval();
            assert_eq!(s.get_word_lane(&out, 0) == 1, (3..11).contains(&v), "v={v}");
        }
    }

    #[test]
    fn qrelu_unit_matches_model() {
        use crate::model::qrelu as qrelu_sw;
        let (n, ins, out) = harness(|n| {
            let acc = n.add_input("acc", 12);
            let y = qrelu_unit(n, &acc, 3);
            (vec![acc], y)
        });
        let mut s = Sim::new(&n);
        for v in (-2048i64..2048).step_by(7) {
            s.set_word_all(&ins[0], v);
            s.eval();
            let want = qrelu_sw(v as i32, 3) as u64;
            assert_eq!(s.get_word_lane(&out, 0), want, "acc={v}");
        }
    }

    #[test]
    fn counter_counts() {
        let mut n = Netlist::new("t");
        let en = n.add_input("en", 1)[0];
        let rst = n.add_input("rst", 1)[0];
        let q = counter(&mut n, 4, en, rst);
        n.add_output("q", q.clone());
        let mut s = Sim::new(&n);
        s.set(en, !0);
        s.set(rst, !0);
        s.step();
        assert_eq!(s.get_word_lane(&q, 0), 0);
        s.set(rst, 0);
        for want in 1..=15u64 {
            s.step();
            assert_eq!(s.get_word_lane(&q, 0), want);
        }
    }

    #[test]
    fn width_for_range_bounds() {
        assert_eq!(width_for_range(0, 1), 2);
        assert_eq!(width_for_range(-1, 0), 1);
        assert_eq!(width_for_range(-8, 7), 4);
        assert_eq!(width_for_range(-9, 7), 5);
        assert_eq!(width_for_range(0, 255), 9);
    }
}
