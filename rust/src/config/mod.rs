//! Minimal configuration file support (TOML subset): `key = value` pairs
//! with optional `[section]` headers, `#` comments, strings, numbers,
//! booleans and comma lists.  Feeds [`crate::coordinator::PipelineConfig`]
//! and [`crate::server::ServeConfig`]; every key can be overridden on the
//! CLI.
//!
//! Example (`printed-mlp.toml`):
//! ```toml
//! [pipeline]
//! datasets = spectf, gas
//! threads = 4
//! search_threads = 4      # NSGA fitness-batch workers (0 = auto)
//! fit_subset = 512
//! rfp_strategy = bisect
//! gate_level_accuracy = true
//!
//! [nsga]
//! pop_size = 40
//! generations = 30
//! memoize = true          # genome→objectives cache (perf only)
//! cached_fitness = true   # delta-logit fitness cache (perf only)
//! energy_objective = false # 3rd objective: measured energy/inference
//!
//! [sim]
//! compile = true          # micro-op-compiled gate-level sim (perf only)
//! lanes = 0               # super-lane width in u64 words: 0 = auto
//!                         # (detected SIMD width), else 1|2|4|8
//! profile_activity = false # per-net toggle counters + measured energy
//! gate_on_activity = false # skip clean compiled runs (bit-identical)
//!
//! [serve]
//! datasets = spectf, arrhythmia, gas
//! scenario = steady       # steady | bursty | ramp | fanin | trace
//! rate_hz = 2000
//! secs = 3
//! sensors = 4
//! workers = 0             # drain workers (0 = one per core)
//! batch = 64
//! queue_cap = 1024        # bounded per-model queue; overflow is shed
//! max_wait_ms = 2
//! slo_ms = 50
//! backend = native        # native | gatesim (pjrt is thread-bound)
//! synthetic = false       # artifact-free deterministic models
//! trace = day.trace       # trace scenario: replay this file
//! trace_out = out.trace   # write the replayed/synthesized trace
//! classes = gold, silver, bronze  # per-model SLO class (positional)
//! shed_late = false       # refuse queued frames already past slo_ms
//! listen = 127.0.0.1:7070 # TCP frontend; sensors become socket clients
//! reload_secs = 1.5       # stage+promote a hot reload at this offset
//! canary_frac = 0.1       # shadow this fraction of batches on the candidate
//! fuse_models = false     # one fused gatesim plan drains every tenant
//!
//! [campaign]
//! archs = ours, hybrid, comb
//! levels = 0:0, 4:0, 16:0, 4:4   # stuck:transient fault counts
//! flip_rate = 0.001       # per-bit transient flip probability
//! fault_seed = 1024369    # fault sampling / flip-mask base seed
//! ```

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::PipelineConfig;
use crate::nsga::NsgaConfig;
use crate::rfp::Strategy;
use crate::runtime::Backend;
use crate::server::{CampaignConfig, ServeConfig};

/// Parsed configuration: `section.key -> raw value string`.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: malformed section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("line {}: expected key = value", lineno + 1);
            };
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, v.trim().trim_matches('"').to_string());
        }
        Ok(Config { values })
    }

    pub fn load(path: &std::path::Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse::<usize>().with_context(|| format!("{key}={v}")))
            .transpose()
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse::<f64>().with_context(|| format!("{key}={v}")))
            .transpose()
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>> {
        self.get(key)
            .map(|v| match v {
                "true" | "1" | "yes" => Ok(true),
                "false" | "0" | "no" => Ok(false),
                other => bail!("{key}: expected bool, got `{other}`"),
            })
            .transpose()
    }

    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key).map(|v| {
            v.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
    }

    /// Materialize the pipeline configuration with defaults filled in.
    pub fn pipeline(&self) -> Result<PipelineConfig> {
        let mut cfg = PipelineConfig::default();
        if let Some(ds) = self.get_list("pipeline.datasets") {
            for d in &ds {
                if !crate::data::DATASET_ORDER.contains(&d.as_str()) {
                    bail!("unknown dataset `{d}`");
                }
            }
            cfg.datasets = ds;
        }
        if let Some(t) = self.get_usize("pipeline.threads")? {
            cfg.threads = t.max(1);
        }
        if let Some(t) = self.get_usize("pipeline.search_threads")? {
            cfg.search_threads = t;
        }
        if let Some(b) = self.get_bool("pipeline.use_pjrt")? {
            // Back-compat alias from the pre-backend config format.  An
            // explicit `use_pjrt = true` keeps its old hard requirement
            // (fail if no PJRT client) rather than degrading to Auto's
            // silent native fallback.
            cfg.backend = if b { Backend::Pjrt } else { Backend::Native };
        }
        if let Some(s) = self.get("pipeline.backend") {
            cfg.backend = s.parse().with_context(|| format!("pipeline.backend={s}"))?;
        }
        if let Some(b) = self.get_bool("pipeline.gate_level_accuracy")? {
            cfg.gate_level_accuracy = b;
        }
        if let Some(b) = self.get_bool("pipeline.cache")? {
            cfg.cache = b;
        }
        if let Some(n) = self.get_usize("pipeline.fit_subset")? {
            cfg.fit_subset = n;
        }
        if let Some(s) = self.get("pipeline.rfp_strategy") {
            cfg.rfp_strategy = match s {
                "greedy" => Strategy::Greedy,
                "bisect" => Strategy::Bisect,
                other => bail!("rfp_strategy: `{other}` (want greedy|bisect)"),
            };
        }
        if let Some(ds) = self.get_list("pipeline.drops") {
            cfg.drops = ds
                .iter()
                .map(|d| d.parse::<f64>().with_context(|| format!("drops: {d}")))
                .collect::<Result<Vec<_>>>()?;
        }
        let mut nsga = NsgaConfig::default();
        if let Some(n) = self.get_usize("nsga.pop_size")? {
            nsga.pop_size = n.max(4);
        }
        if let Some(n) = self.get_usize("nsga.generations")? {
            nsga.generations = n;
        }
        if let Some(p) = self.get_f64("nsga.mutation_prob")? {
            nsga.mutation_prob = p;
        }
        if let Some(p) = self.get_f64("nsga.crossover_prob")? {
            nsga.crossover_prob = p;
        }
        if let Some(s) = self.get_usize("nsga.seed")? {
            nsga.seed = s as u64;
        }
        if let Some(b) = self.get_bool("nsga.memoize")? {
            nsga.memoize = b;
        }
        if let Some(b) = self.get_bool("nsga.cached_fitness")? {
            nsga.cached_fitness = b;
        }
        cfg.nsga = nsga;
        if let Some(b) = self.get_bool("nsga.energy_objective")? {
            cfg.energy_objective = b;
        }
        if let Some(b) = self.get_bool("sim.compile")? {
            cfg.sim_compile = b;
        }
        if let Some(w) = self.sim_lanes()? {
            cfg.sim_lanes = w;
        }
        if let Some(b) = self.get_bool("sim.profile_activity")? {
            cfg.profile_activity = b;
        }
        if let Some(b) = self.get_bool("sim.gate_on_activity")? {
            cfg.gate_activity = b;
        }
        Ok(cfg)
    }

    /// The `sim.lanes` key (shared by the pipeline and serve paths):
    /// gate-level super-lane width in `u64` words, `0` = auto-pick from
    /// the detected SIMD width.
    fn sim_lanes(&self) -> Result<Option<usize>> {
        let Some(w) = self.get_usize("sim.lanes")? else {
            return Ok(None);
        };
        if !crate::sim::valid_lane_words(w) {
            bail!(
                "sim.lanes: expected 0 (auto) or one of {:?}, got {w}",
                crate::sim::LANE_WORD_CHOICES
            );
        }
        Ok(Some(w))
    }

    /// Materialize the serve configuration with defaults filled in.
    /// Dataset names are validated against the registry at load time
    /// (synthetic mode accepts arbitrary names), not here.
    pub fn serve(&self) -> Result<ServeConfig> {
        let mut cfg = ServeConfig::default();
        if let Some(ds) = self.get_list("serve.datasets") {
            cfg.datasets = ds;
        }
        if let Some(s) = self.get("serve.scenario") {
            cfg.scenario = s.parse().with_context(|| format!("serve.scenario={s}"))?;
        }
        if let Some(v) = self.get_f64("serve.rate_hz")? {
            cfg.rate_hz = v.max(1e-3);
        }
        if let Some(v) = self.get_f64("serve.secs")? {
            cfg.duration = Duration::from_secs_f64(v.max(0.0));
        }
        if let Some(v) = self.get_f64("serve.max_wait_ms")? {
            cfg.max_wait = Duration::from_secs_f64(v.max(0.0) / 1e3);
        }
        if let Some(n) = self.get_usize("serve.sensors")? {
            cfg.sensors = n.max(1);
        }
        if let Some(n) = self.get_usize("serve.workers")? {
            cfg.workers = n;
        }
        if let Some(n) = self.get_usize("serve.batch")? {
            cfg.batch = n.max(1);
        }
        if let Some(n) = self.get_usize("serve.queue_cap")? {
            cfg.queue_cap = n.max(1);
        }
        if let Some(v) = self.get_f64("serve.slo_ms")? {
            cfg.slo_ms = v;
        }
        if let Some(n) = self.get_usize("serve.seed")? {
            cfg.seed = n as u64;
        }
        // serve.backend wins; otherwise inherit the pipeline backend so a
        // one-line `[pipeline] backend = gatesim` config steers both.
        // Inherited PJRT is skipped (valid for the pipeline, but the serve
        // worker pool would reject it) — serve keeps its auto→native
        // default; an explicit serve.backend = pjrt still errors at run.
        if let Some(s) = self.get("serve.backend") {
            cfg.backend = s.parse().with_context(|| format!("serve.backend={s}"))?;
        } else if let Some(s) = self.get("pipeline.backend") {
            let b: Backend = s.parse().with_context(|| format!("pipeline.backend={s}"))?;
            if b != Backend::Pjrt {
                cfg.backend = b;
            }
        }
        if let Some(b) = self.get_bool("serve.synthetic")? {
            cfg.synthetic = b;
        }
        if let Some(w) = self.sim_lanes()? {
            cfg.sim_lanes = w;
        }
        if let Some(p) = self.get("serve.trace") {
            cfg.trace = Some(std::path::PathBuf::from(p));
        }
        if let Some(p) = self.get("serve.trace_out") {
            cfg.trace_out = Some(std::path::PathBuf::from(p));
        }
        if let Some(cs) = self.get_list("serve.classes") {
            cfg.classes = cs
                .iter()
                .map(|c| c.parse().with_context(|| format!("serve.classes: {c}")))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(b) = self.get_bool("serve.shed_late")? {
            cfg.shed_late = b;
        }
        if let Some(addr) = self.get("serve.listen") {
            cfg.listen = Some(addr.to_string());
        }
        if let Some(v) = self.get_f64("serve.reload_secs")? {
            ensure!(v >= 0.0, "serve.reload_secs: {v} must be >= 0");
            cfg.reload_at = Some(Duration::from_secs_f64(v));
        }
        if let Some(v) = self.get_f64("serve.canary_frac")? {
            ensure!(
                (0.0..=1.0).contains(&v),
                "serve.canary_frac: {v} outside [0, 1]"
            );
            cfg.canary_frac = v;
        }
        if let Some(b) = self.get_bool("serve.fuse_models")? {
            cfg.fuse_models = b;
        }
        Ok(cfg)
    }

    /// Materialize the fault-campaign configuration: the `[serve]`
    /// section supplies the load shape, `[campaign]` the fault sweep.
    pub fn campaign(&self) -> Result<CampaignConfig> {
        let mut cfg = CampaignConfig {
            serve: self.serve()?,
            ..CampaignConfig::default()
        };
        if let Some(archs) = self.get_list("campaign.archs") {
            cfg.archs = archs
                .iter()
                .map(|a| a.parse().with_context(|| format!("campaign.archs: {a}")))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(s) = self.get("campaign.levels") {
            cfg.levels =
                crate::server::campaign::parse_levels(s).with_context(|| "campaign.levels")?;
        }
        if let Some(r) = self.get_f64("campaign.flip_rate")? {
            ensure!(
                (0.0..=1.0).contains(&r),
                "campaign.flip_rate: {r} outside [0, 1]"
            );
            cfg.flip_rate = r;
        }
        if let Some(s) = self.get_usize("campaign.fault_seed")? {
            cfg.fault_seed = s as u64;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(
            "# comment\n[pipeline]\nthreads = 3\nuse_pjrt = false\ndatasets = spectf, gas\n\n[nsga]\npop_size = 10\n",
        )
        .unwrap();
        assert_eq!(c.get_usize("pipeline.threads").unwrap(), Some(3));
        assert_eq!(c.get_bool("pipeline.use_pjrt").unwrap(), Some(false));
        assert_eq!(
            c.get_list("pipeline.datasets").unwrap(),
            vec!["spectf".to_string(), "gas".to_string()]
        );
        let p = c.pipeline().unwrap();
        assert_eq!(p.threads, 3);
        assert_eq!(p.backend, Backend::Native);
        assert_eq!(p.nsga.pop_size, 10);
    }

    #[test]
    fn backend_key_parses_and_wins_over_alias() {
        let c = Config::parse("[pipeline]\nuse_pjrt = false\nbackend = gatesim\n").unwrap();
        assert_eq!(c.pipeline().unwrap().backend, Backend::GateSim);
        let c = Config::parse("[pipeline]\nbackend = warp-drive\n").unwrap();
        assert!(c.pipeline().is_err());
    }

    #[test]
    fn search_threads_and_memoize_keys() {
        let c = Config::parse("[pipeline]\nsearch_threads = 6\n").unwrap();
        assert_eq!(c.pipeline().unwrap().search_threads, 6);
        let c = Config::parse("[nsga]\nmemoize = false\n").unwrap();
        assert!(!c.pipeline().unwrap().nsga.memoize);
        let c = Config::parse("[nsga]\ncached_fitness = false\n").unwrap();
        assert!(!c.pipeline().unwrap().nsga.cached_fitness);
        // Defaults: auto-derived search threads, both caches on.
        let d = Config::default().pipeline().unwrap();
        assert_eq!(d.search_threads, 0);
        assert!(d.nsga.memoize);
        assert!(d.nsga.cached_fitness);
    }

    #[test]
    fn sim_compile_key() {
        let c = Config::parse("[sim]\ncompile = false\n").unwrap();
        assert!(!c.pipeline().unwrap().sim_compile);
        // Default: compiled plans on.
        assert!(Config::default().pipeline().unwrap().sim_compile);
    }

    #[test]
    fn activity_and_energy_objective_keys() {
        let c = Config::parse("[sim]\nprofile_activity = true\n").unwrap();
        assert!(c.pipeline().unwrap().profile_activity);
        let c = Config::parse("[sim]\ngate_on_activity = true\n").unwrap();
        assert!(c.pipeline().unwrap().gate_activity);
        assert!(!Config::default().pipeline().unwrap().gate_activity);
        assert!(Config::parse("[sim]\ngate_on_activity = maybe\n")
            .unwrap()
            .pipeline()
            .is_err());
        let c = Config::parse("[nsga]\nenergy_objective = yes\n").unwrap();
        assert!(c.pipeline().unwrap().energy_objective);
        // Defaults: both off — the clean path pays nothing.
        let d = Config::default().pipeline().unwrap();
        assert!(!d.profile_activity && !d.energy_objective);
        // Garbage rejected.
        let c = Config::parse("[sim]\nprofile_activity = maybe\n").unwrap();
        assert!(c.pipeline().is_err());
    }

    #[test]
    fn sim_lanes_key_feeds_pipeline_and_serve() {
        let c = Config::parse("[sim]\nlanes = 4\n").unwrap();
        assert_eq!(c.pipeline().unwrap().sim_lanes, 4);
        assert_eq!(c.serve().unwrap().sim_lanes, 4);
        // 0 = auto; anything outside {0,1,2,4,8} is rejected.
        let c = Config::parse("[sim]\nlanes = 0\n").unwrap();
        assert_eq!(c.pipeline().unwrap().sim_lanes, 0);
        let c = Config::parse("[sim]\nlanes = 3\n").unwrap();
        assert!(c.pipeline().is_err());
        assert!(c.serve().is_err());
        // Default: auto.
        assert_eq!(Config::default().pipeline().unwrap().sim_lanes, 0);
        assert_eq!(Config::default().serve().unwrap().sim_lanes, 0);
    }

    #[test]
    fn serve_section_parses() {
        let c = Config::parse(
            "[serve]\ndatasets = a, b, c\nscenario = fanin\nrate_hz = 500\nsecs = 0.5\n\
             workers = 3\nbatch = 16\nqueue_cap = 9\nmax_wait_ms = 4\nslo_ms = 20\n\
             backend = gatesim\nsynthetic = true\n",
        )
        .unwrap();
        let s = c.serve().unwrap();
        assert_eq!(s.datasets, vec!["a".to_string(), "b".into(), "c".into()]);
        assert_eq!(s.scenario, crate::server::Scenario::FanIn);
        assert_eq!(s.rate_hz, 500.0);
        assert_eq!(s.duration, Duration::from_secs_f64(0.5));
        assert_eq!(s.workers, 3);
        assert_eq!(s.batch, 16);
        assert_eq!(s.queue_cap, 9);
        assert_eq!(s.max_wait, Duration::from_secs_f64(0.004));
        assert_eq!(s.slo_ms, 20.0);
        assert_eq!(s.backend, Backend::GateSim);
        assert!(s.synthetic);
        // Unknown scenario errors.
        let c = Config::parse("[serve]\nscenario = chaos\n").unwrap();
        assert!(c.serve().is_err());
    }

    #[test]
    fn serve_inherits_pipeline_backend() {
        let c = Config::parse("[pipeline]\nbackend = gatesim\n").unwrap();
        assert_eq!(c.serve().unwrap().backend, Backend::GateSim);
        // serve.backend wins over the pipeline key.
        let c = Config::parse("[pipeline]\nbackend = gatesim\n[serve]\nbackend = native\n").unwrap();
        assert_eq!(c.serve().unwrap().backend, Backend::Native);
        // Inherited PJRT is skipped (the serve pool would reject it);
        // serve keeps its auto default instead of hard-failing.
        let c = Config::parse("[pipeline]\nbackend = pjrt\n").unwrap();
        assert_eq!(c.serve().unwrap().backend, Backend::Auto);
        // Defaults: three datasets, steady, auto backend.
        let d = Config::default().serve().unwrap();
        assert_eq!(d.datasets.len(), 3);
        assert_eq!(d.backend, Backend::Auto);
        assert!(!d.synthetic);
    }

    #[test]
    fn serve_trace_keys_parse() {
        let c = Config::parse("[serve]\nscenario = trace\ntrace = day.trace\ntrace_out = o.trace\n")
            .unwrap();
        let s = c.serve().unwrap();
        assert_eq!(s.scenario, crate::server::Scenario::Trace);
        assert_eq!(s.trace, Some(std::path::PathBuf::from("day.trace")));
        assert_eq!(s.trace_out, Some(std::path::PathBuf::from("o.trace")));
        // Defaults: no trace files.
        let d = Config::default().serve().unwrap();
        assert!(d.trace.is_none() && d.trace_out.is_none());
    }

    #[test]
    fn serve_ingress_keys_parse_and_validate() {
        use crate::server::SloClass;
        let c = Config::parse(
            "[serve]\nclasses = gold, bronze, silver\nshed_late = true\n\
             listen = 127.0.0.1:7070\nreload_secs = 1.5\ncanary_frac = 0.25\n\
             fuse_models = true\n",
        )
        .unwrap();
        let s = c.serve().unwrap();
        assert_eq!(s.classes, vec![SloClass::Gold, SloClass::Bronze, SloClass::Silver]);
        assert!(s.shed_late);
        assert_eq!(s.listen.as_deref(), Some("127.0.0.1:7070"));
        assert_eq!(s.reload_at, Some(Duration::from_secs_f64(1.5)));
        assert_eq!(s.canary_frac, 0.25);
        assert!(s.fuse_models);
        // Defaults: classless, in-process, no reload, canary off,
        // per-model drains.
        let d = Config::default().serve().unwrap();
        assert!(d.classes.is_empty() && !d.shed_late);
        assert!(d.listen.is_none() && d.reload_at.is_none());
        assert_eq!(d.canary_frac, 0.0);
        assert!(!d.fuse_models);
        // Garbage rejected.
        assert!(Config::parse("[serve]\nclasses = platinum\n").unwrap().serve().is_err());
        assert!(Config::parse("[serve]\ncanary_frac = 1.5\n").unwrap().serve().is_err());
        assert!(Config::parse("[serve]\nreload_secs = -1\n").unwrap().serve().is_err());
    }

    #[test]
    fn campaign_section_parses_and_validates() {
        use crate::server::ArchKind;
        let c = Config::parse(
            "[serve]\nsynthetic = true\n[campaign]\narchs = ours, comb\nlevels = 0:0, 2:1\n\
             flip_rate = 0.01\nfault_seed = 99\n",
        )
        .unwrap();
        let k = c.campaign().unwrap();
        assert!(k.serve.synthetic);
        assert_eq!(k.archs, vec![ArchKind::Ours, ArchKind::Comb]);
        assert_eq!(k.levels, vec![(0, 0), (2, 1)]);
        assert_eq!(k.flip_rate, 0.01);
        assert_eq!(k.fault_seed, 99);
        // Defaults: full arch cast, the standard sweep.
        let d = Config::default().campaign().unwrap();
        assert_eq!(d.archs.len(), 3);
        assert_eq!(d.levels, vec![(0, 0), (4, 0), (16, 0), (4, 4)]);
        // Garbage rejected.
        assert!(Config::parse("[campaign]\narchs = warp\n").unwrap().campaign().is_err());
        assert!(Config::parse("[campaign]\nlevels = 4\n").unwrap().campaign().is_err());
        assert!(Config::parse("[campaign]\nflip_rate = 2\n").unwrap().campaign().is_err());
    }

    #[test]
    fn rejects_unknown_dataset() {
        let c = Config::parse("[pipeline]\ndatasets = nosuch\n").unwrap();
        assert!(c.pipeline().is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[broken\n").is_err());
        assert!(Config::parse("novalue\n").is_err());
    }

    #[test]
    fn overrides_apply() {
        let mut c = Config::default();
        c.set("pipeline.fit_subset", "64");
        assert_eq!(c.pipeline().unwrap().fit_subset, 64);
    }

    #[test]
    fn defaults_without_file() {
        let c = Config::default();
        let p = c.pipeline().unwrap();
        assert_eq!(p.datasets.len(), 7);
    }
}
