//! Printed-EGFET cell library + synthesis-lite estimation
//! (Synopsys DC / PrimeTime / EGFET PDK substitute — see DESIGN.md
//! §Substitutions).
//!
//! Cell costs are calibrated to the published EGFET characteristics used
//! by the paper ([6] Bleier et al., "Printed Microprocessors"; [16]
//! Mubarik et al., MICRO'20):
//!
//! * areas scale with transistor count at ≈0.0018 cm² per transistor
//!   (1V electrolyte-gated FETs print at mm-scale feature sizes);
//! * a DFF costs exactly 2× a MUX2 in area, reproducing the paper's
//!   Fig. 4 observation that one 2:1 mux replaces two 1-bit shift
//!   registers at a 1:4 area ratio;
//! * registers burn disproportionately more power than combinational
//!   cells (§4.2.1: "registers consume more power in ratio to other logic
//!   gates than they occupy area") — 0.8 mW/cm² vs 0.45 mW/cm²;
//! * per-gate delays are ms-scale, in line with the few-Hz..KHz printed
//!   circuits the paper synthesizes at 80–320 ms clocks [15].

use std::collections::BTreeMap;

use crate::netlist::Netlist;
use crate::sim::GateActivity;

/// Area of one EGFET transistor (cm²).
pub const CM2_PER_TRANSISTOR: f64 = 0.0018;

/// Power densities (mW per cm²).
pub const COMB_MW_PER_CM2: f64 = 0.45;
pub const DFF_MW_PER_CM2: f64 = 0.8;

/// Switching energy per cm² of cell area per output toggle (mJ).
///
/// EGFET dynamic dissipation is dominated by charging the large printed
/// gate capacitances, which scale with the cell's printed area, so one
/// constant relates measured toggle counts to dynamic energy the same
/// way `CM2_PER_TRANSISTOR` relates transistor counts to area.
/// Calibrated so a typical generated classifier's dynamic energy lands
/// at a few percent of its static (leakage + biasing) energy at the
/// paper's 80–320 ms clocks — dynamic is the smaller component for
/// always-on printed electrolyte-gated logic.
pub const DYN_MJ_PER_CM2_TOGGLE: f64 = 0.02;

/// Per-cell characterization.
#[derive(Clone, Copy, Debug)]
pub struct CellSpec {
    pub transistors: u32,
    pub area_cm2: f64,
    pub power_mw: f64,
    pub delay_ms: f64,
}

fn spec(transistors: u32, delay_ms: f64, is_dff: bool) -> CellSpec {
    let area = transistors as f64 * CM2_PER_TRANSISTOR;
    let density = if is_dff {
        DFF_MW_PER_CM2
    } else {
        COMB_MW_PER_CM2
    };
    CellSpec {
        transistors,
        area_cm2: area,
        power_mw: area * density,
        delay_ms,
    }
}

/// Look up the EGFET library entry for a cell type name.
pub fn cell_spec(type_name: &str) -> CellSpec {
    match type_name {
        "INV" => spec(2, 0.4, false),
        "BUF" => spec(4, 0.7, false),
        "NAND2" => spec(4, 0.6, false),
        "NOR2" => spec(4, 0.6, false),
        "AND2" => spec(6, 0.9, false),
        "OR2" => spec(6, 0.9, false),
        "XOR2" => spec(8, 1.3, false),
        "XNOR2" => spec(8, 1.3, false),
        "MUX2" => spec(10, 1.1, false),
        "DFF" => spec(20, 2.4, true),
        other => panic!("unknown cell type {other}"),
    }
}

/// Synthesis-lite report for one netlist.
#[derive(Clone, Debug)]
pub struct CircuitReport {
    pub name: String,
    pub cells: BTreeMap<&'static str, usize>,
    pub n_cells: usize,
    pub n_dffs: usize,
    pub area_cm2: f64,
    pub power_mw: f64,
    pub crit_path_ms: f64,
    pub logic_depth: usize,
}

impl CircuitReport {
    /// Energy for a full inference (mJ): power × cycles × clock period.
    pub fn energy_mj(&self, cycles: usize, clock_ms: f64) -> f64 {
        self.power_mw * cycles as f64 * clock_ms * 1e-3
    }

    /// Whether the circuit closes timing at the given clock.
    pub fn meets_clock(&self, clock_ms: f64) -> bool {
        self.crit_path_ms <= clock_ms
    }
}

/// Characterize a netlist against the EGFET library.
pub fn report(n: &Netlist) -> CircuitReport {
    let cells = n.count_by_type();
    let mut area = 0.0;
    let mut power = 0.0;
    for (ty, count) in &cells {
        let s = cell_spec(ty);
        area += s.area_cm2 * *count as f64;
        power += s.power_mw * *count as f64;
    }

    // Critical path: longest delay-weighted combinational path, plus DFF
    // clk-to-q at the start and setup at the end when registers exist.
    let nets = n.n_nets();
    let mut arrive = vec![0.0f64; nets];
    let order = n.topo_order();
    let mut crit: f64 = 0.0;
    for ci in order {
        let c = &n.cells[ci];
        let d = cell_spec(c.type_name()).delay_ms;
        let mut t = 0.0f64;
        c.for_each_input(|i| t = t.max(arrive[i as usize]));
        let t = t + d;
        arrive[c.output() as usize] = t;
        crit = crit.max(t);
    }
    let n_dffs = n.n_dffs();
    if n_dffs > 0 {
        crit += cell_spec("DFF").delay_ms; // clk-to-q + setup margin
    }

    CircuitReport {
        name: n.name.clone(),
        cells,
        n_cells: n.cells.len(),
        n_dffs,
        area_cm2: area,
        power_mw: power,
        crit_path_ms: crit,
        logic_depth: n.logic_depth(),
    }
}

/// Measured per-inference energy breakdown: the static (worst-case
/// power-density) component [`CircuitReport::energy_mj`] always
/// reported, plus a dynamic component derived from per-gate switching
/// activity harvested by the simulator (`sim` §Activity).
///
/// All energies are mJ *per inference* — toggle counts are averaged
/// over the `samples` inferences that produced them, so profiling more
/// samples refines the estimate without inflating it.
#[derive(Clone, Debug)]
pub struct EnergyReport {
    pub name: String,
    /// Inferences profiled (toggle counts are normalized by this).
    pub samples: u64,
    /// Static energy per inference (power × cycles × clock).
    pub static_mj: f64,
    /// Activity-derived dynamic energy per inference.
    pub dynamic_mj: f64,
    /// Dynamic energy attributed per cell kind (INV, NAND2, …, DFF).
    pub per_kind: BTreeMap<&'static str, f64>,
    /// Dynamic energy attributed per topological level (registers at 0).
    pub per_level: Vec<f64>,
    /// Total masked toggles over all profiled inferences.
    pub toggles: u64,
}

impl EnergyReport {
    /// Static + dynamic energy per inference (mJ).
    pub fn total_mj(&self) -> f64 {
        self.static_mj + self.dynamic_mj
    }
}

/// Price per-gate switching activity into an [`EnergyReport`].
///
/// Each gate contributes `area × DYN_MJ_PER_CM2_TOGGLE × toggles /
/// samples` mJ of dynamic energy; the static component is
/// [`CircuitReport::energy_mj`] at the circuit's cycle count and clock.
/// `samples = 0` (or an empty gate list) yields a zero-dynamic report —
/// the static estimate this measurement replaces.
pub fn energy_report(
    report: &CircuitReport,
    gates: &[GateActivity],
    cycles: usize,
    clock_ms: f64,
    samples: u64,
) -> EnergyReport {
    let mut per_kind: BTreeMap<&'static str, f64> = BTreeMap::new();
    let mut per_level: Vec<f64> = Vec::new();
    let mut dynamic = 0.0;
    let mut toggles = 0u64;
    if samples > 0 {
        for g in gates {
            let e = cell_spec(g.kind).area_cm2 * DYN_MJ_PER_CM2_TOGGLE * g.toggles as f64
                / samples as f64;
            dynamic += e;
            toggles += g.toggles;
            *per_kind.entry(g.kind).or_insert(0.0) += e;
            let lvl = g.level as usize;
            if per_level.len() <= lvl {
                per_level.resize(lvl + 1, 0.0);
            }
            per_level[lvl] += e;
        }
    }
    EnergyReport {
        name: report.name.clone(),
        samples,
        static_mj: report.energy_mj(cycles, clock_ms),
        dynamic_mj: dynamic,
        per_kind,
        per_level,
        toggles,
    }
}

/// Area of an n-input, `width`-bit shift-register chain vs the equivalent
/// mux-based selector — the Fig. 4 comparison, exposed for the bench.
pub fn shift_register_area(n_inputs: usize, width: usize) -> f64 {
    cell_spec("DFF").area_cm2 * (n_inputs * width) as f64
}

pub fn mux_selector_area(n_inputs: usize, width: usize) -> f64 {
    // A full n:1 mux tree needs (n-1) MUX2 per bit.
    cell_spec("MUX2").area_cm2 * ((n_inputs.saturating_sub(1)) * width) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{Netlist, CONST0, CONST1};

    #[test]
    fn fig4_anchor_ratio() {
        // One MUX2 vs two 1-bit shift registers: the paper's 1:4 ratio.
        let mux = cell_spec("MUX2").area_cm2;
        let two_dff = 2.0 * cell_spec("DFF").area_cm2;
        assert!((mux / two_dff - 0.25).abs() < 1e-12);
    }

    #[test]
    fn registers_more_power_hungry_per_area() {
        let dff = cell_spec("DFF");
        let nand = cell_spec("NAND2");
        assert!(dff.power_mw / dff.area_cm2 > nand.power_mw / nand.area_cm2);
    }

    #[test]
    fn report_sums_cells() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let x = n.and2(a, b);
        let q = n.dff(x, CONST1, CONST0, false);
        n.add_output("q", vec![q]);
        let r = report(&n);
        assert_eq!(r.n_cells, 2);
        assert_eq!(r.n_dffs, 1);
        let want = cell_spec("AND2").area_cm2 + cell_spec("DFF").area_cm2;
        assert!((r.area_cm2 - want).abs() < 1e-12);
    }

    #[test]
    fn crit_path_weights_delays() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let x = n.xor2(a, b); // 1.3
        let y = n.xor2(x, b); // 2.6
        n.add_output("y", vec![y]);
        let r = report(&n);
        assert!((r.crit_path_ms - 2.6).abs() < 1e-9);
        assert!(r.meets_clock(3.0) && !r.meets_clock(2.0));
    }

    #[test]
    fn energy_scales_with_cycles() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let x = n.inv(a);
        n.add_output("y", vec![x]);
        let r = report(&n);
        assert!((r.energy_mj(10, 100.0) - r.power_mw * 1.0).abs() < 1e-12);
    }

    #[test]
    fn energy_report_prices_activity_and_attributes_it() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let x = n.and2(a, b);
        let q = n.dff(x, CONST1, CONST0, false);
        n.add_output("q", vec![q]);
        let r = report(&n);

        let gates = vec![
            GateActivity { kind: "AND2", level: 1, toggles: 100 },
            GateActivity { kind: "DFF", level: 0, toggles: 40 },
        ];
        let er = energy_report(&r, &gates, 10, 100.0, 50);
        assert_eq!(er.samples, 50);
        assert_eq!(er.toggles, 140);
        assert!((er.static_mj - r.energy_mj(10, 100.0)).abs() < 1e-12);
        let want_and = cell_spec("AND2").area_cm2 * DYN_MJ_PER_CM2_TOGGLE * 100.0 / 50.0;
        let want_dff = cell_spec("DFF").area_cm2 * DYN_MJ_PER_CM2_TOGGLE * 40.0 / 50.0;
        assert!((er.dynamic_mj - (want_and + want_dff)).abs() < 1e-12);
        assert!((er.per_kind["AND2"] - want_and).abs() < 1e-12);
        assert!((er.per_kind["DFF"] - want_dff).abs() < 1e-12);
        assert_eq!(er.per_level.len(), 2);
        assert!((er.per_level[0] - want_dff).abs() < 1e-12);
        assert!((er.per_level[1] - want_and).abs() < 1e-12);
        assert!((er.total_mj() - (er.static_mj + er.dynamic_mj)).abs() < 1e-12);
        // Attribution partitions the total exactly.
        let kinds: f64 = er.per_kind.values().sum();
        let levels: f64 = er.per_level.iter().sum();
        assert!((kinds - er.dynamic_mj).abs() < 1e-12);
        assert!((levels - er.dynamic_mj).abs() < 1e-12);
    }

    #[test]
    fn energy_report_without_activity_is_the_static_estimate() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let y = n.inv(a);
        n.add_output("y", vec![y]);
        let r = report(&n);
        let er = energy_report(&r, &[], 5, 80.0, 0);
        assert_eq!(er.dynamic_mj, 0.0);
        assert_eq!(er.toggles, 0);
        assert!(er.per_kind.is_empty() && er.per_level.is_empty());
        assert!((er.total_mj() - r.energy_mj(5, 80.0)).abs() < 1e-12);
    }

    #[test]
    fn dynamic_energy_monotone_in_toggles() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let y = n.inv(a);
        n.add_output("y", vec![y]);
        let r = report(&n);
        let lo = energy_report(
            &r,
            &[GateActivity { kind: "INV", level: 1, toggles: 10 }],
            5,
            80.0,
            4,
        );
        let hi = energy_report(
            &r,
            &[GateActivity { kind: "INV", level: 1, toggles: 200 }],
            5,
            80.0,
            4,
        );
        assert!(hi.dynamic_mj > lo.dynamic_mj);
        assert!((hi.static_mj - lo.static_mj).abs() < 1e-12);
    }

    #[test]
    fn fig4_slopes_diverge() {
        // Generic (non-hardwired) storage: registers scale 2x steeper than
        // muxes; the 4x+ total gains of Fig. 4 additionally come from
        // constant-folding the hardwired-weight mux trees (§3.1.4), which
        // the fig4 bench measures on real neurons.
        let r32 = shift_register_area(32, 4);
        let m32 = mux_selector_area(32, 4);
        let r64 = shift_register_area(64, 4);
        let m64 = mux_selector_area(64, 4);
        assert!((r64 - r32) > (m64 - m32) * 1.9);
    }
}
