//! Table/figure emitters for the paper's evaluation (§4): each function
//! renders paper-vs-measured rows as markdown and writes a CSV under
//! `artifacts/results/`.  Aggregate gains use the geometric mean (they are
//! ratios), printed next to the paper's reported averages.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

use crate::coordinator::DatasetOutcome;
use crate::util::stats::geomean;

/// Paper-reported reference numbers (Table 1, Figs. 6–8).
pub struct PaperRef {
    pub name: &'static str,
    pub accuracy: f64,
    pub sota_area_cm2: f64,
    pub sota_power_mw: f64,
    pub area_gain: f64,
    pub power_gain: f64,
}

pub const PAPER_TABLE1: [PaperRef; 7] = [
    PaperRef { name: "spectf", accuracy: 0.875, sota_area_cm2: 48.2, sota_power_mw: 37.7, area_gain: 3.8, power_gain: 5.5 },
    PaperRef { name: "arrhythmia", accuracy: 0.618, sota_area_cm2: 106.7, sota_power_mw: 71.1, area_gain: 4.4, power_gain: 6.5 },
    PaperRef { name: "gas", accuracy: 0.907, sota_area_cm2: 182.1, sota_power_mw: 128.9, area_gain: 7.3, power_gain: 10.9 },
    PaperRef { name: "epileptic", accuracy: 0.935, sota_area_cm2: 275.8, sota_power_mw: 187.8, area_gain: 11.0, power_gain: 16.5 },
    PaperRef { name: "activity", accuracy: 0.805, sota_area_cm2: 313.0, sota_power_mw: 209.0, area_gain: 11.7, power_gain: 18.7 },
    PaperRef { name: "parkinsons", accuracy: 0.855, sota_area_cm2: 437.1, sota_power_mw: 317.4, area_gain: 18.5, power_gain: 31.1 },
    PaperRef { name: "har", accuracy: 0.969, sota_area_cm2: 1276.2, sota_power_mw: 969.2, area_gain: 18.1, power_gain: 34.3 },
];

pub fn paper_ref(name: &str) -> Option<&'static PaperRef> {
    PAPER_TABLE1.iter().find(|r| r.name == name)
}

fn write_csv(dir: &Path, file: &str, header: &str, rows: &[String]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut text = String::from(header);
    text.push('\n');
    for r in rows {
        text.push_str(r);
        text.push('\n');
    }
    std::fs::write(dir.join(file), text)?;
    Ok(())
}

/// Table 1: accuracy + [16] area/power + our multi-cycle gains.
pub fn table1(outs: &[DatasetOutcome], results_dir: &Path) -> Result<String> {
    let mut md = String::new();
    let _ = writeln!(md, "\n## Table 1 — Accuracy, Area and Power (paper vs measured)\n");
    let _ = writeln!(md, "| Dataset | Acc paper | Acc meas | [16] area paper | [16] area meas | [16] power paper | [16] power meas | Area gain paper | Area gain meas | Power gain paper | Power gain meas |");
    let _ = writeln!(md, "|---|---|---|---|---|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    let mut ag = Vec::new();
    let mut pg = Vec::new();
    for o in outs {
        let p = paper_ref(&o.name);
        let area_gain = o.sota.report.area_cm2 / o.ours.report.area_cm2;
        let power_gain = o.sota.report.power_mw / o.ours.report.power_mw;
        ag.push(area_gain);
        pg.push(power_gain);
        let (pa, paa, pap, pagn, papg) = p
            .map(|p| (p.accuracy, p.sota_area_cm2, p.sota_power_mw, p.area_gain, p.power_gain))
            .unwrap_or((f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN));
        let _ = writeln!(
            md,
            "| {} | {:.1}% | {:.1}% | {:.1} cm² | {:.1} cm² | {:.1} mW | {:.1} mW | {:.1}× | {:.1}× | {:.1}× | {:.1}× |",
            o.name, pa * 100.0, o.ours.test_acc * 100.0, paa, o.sota.report.area_cm2,
            pap, o.sota.report.power_mw, pagn, area_gain, papg, power_gain
        );
        rows.push(format!(
            "{},{:.4},{:.4},{:.2},{:.2},{:.2},{:.2},{:.2},{:.2}",
            o.name, o.ours.test_acc, o.sota.test_acc, o.sota.report.area_cm2,
            o.ours.report.area_cm2, o.sota.report.power_mw, o.ours.report.power_mw,
            area_gain, power_gain
        ));
    }
    let _ = writeln!(
        md,
        "\nGeomean gains (measured): area **{:.1}×**, power **{:.1}×** (paper avg: 10.7× / 17.6× vs [16]).",
        geomean(&ag),
        geomean(&pg)
    );
    write_csv(
        results_dir,
        "table1.csv",
        "dataset,ours_acc,sota_acc,sota_area_cm2,ours_area_cm2,sota_power_mw,ours_power_mw,area_gain,power_gain",
        &rows,
    )?;
    Ok(md)
}

/// Fig. 6: area + power of combinational [14] / sequential [16] / ours.
pub fn fig6(outs: &[DatasetOutcome], results_dir: &Path) -> Result<String> {
    let mut md = String::new();
    let _ = writeln!(md, "\n## Figure 6 — Area & power: comb [14] vs seq [16] vs multi-cycle (ours)\n");
    let _ = writeln!(md, "| Dataset | comb area | seq[16] area | ours area | comb power | seq[16] power | ours power |");
    let _ = writeln!(md, "|---|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    let (mut a16_14, mut p16_14, mut ao_16, mut po_16, mut ao_14, mut po_14) =
        (vec![], vec![], vec![], vec![], vec![], vec![]);
    for o in outs {
        let _ = writeln!(
            md,
            "| {} | {:.1} cm² | {:.1} cm² | {:.1} cm² | {:.1} mW | {:.1} mW | {:.1} mW |",
            o.name, o.comb.report.area_cm2, o.sota.report.area_cm2, o.ours.report.area_cm2,
            o.comb.report.power_mw, o.sota.report.power_mw, o.ours.report.power_mw
        );
        rows.push(format!(
            "{},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3}",
            o.name, o.comb.report.area_cm2, o.sota.report.area_cm2, o.ours.report.area_cm2,
            o.comb.report.power_mw, o.sota.report.power_mw, o.ours.report.power_mw
        ));
        a16_14.push(o.sota.report.area_cm2 / o.comb.report.area_cm2);
        p16_14.push(o.sota.report.power_mw / o.comb.report.power_mw);
        ao_16.push(o.sota.report.area_cm2 / o.ours.report.area_cm2);
        po_16.push(o.sota.report.power_mw / o.ours.report.power_mw);
        ao_14.push(o.comb.report.area_cm2 / o.ours.report.area_cm2);
        po_14.push(o.comb.report.power_mw / o.ours.report.power_mw);
    }
    let _ = writeln!(md, "\n| Ratio (geomean) | paper | measured |");
    let _ = writeln!(md, "|---|---|---|");
    let _ = writeln!(md, "| [16] / [14] area | 1.7× | {:.1}× |", geomean(&a16_14));
    let _ = writeln!(md, "| [16] / [14] power | 4.0× | {:.1}× |", geomean(&p16_14));
    let _ = writeln!(md, "| ours vs [16] area | 10.7× | {:.1}× |", geomean(&ao_16));
    let _ = writeln!(md, "| ours vs [16] power | 17.6× | {:.1}× |", geomean(&po_16));
    let _ = writeln!(md, "| ours vs [14] area | 6.9× | {:.1}× |", geomean(&ao_14));
    let _ = writeln!(md, "| ours vs [14] power | 4.7× | {:.1}× |", geomean(&po_14));
    // Crossover check: the paper notes SPECTF power is *worse* than comb.
    if let Some(o) = outs.iter().find(|o| o.name == "spectf") {
        let _ = writeln!(
            md,
            "\nSPECTF crossover (paper: sequential power 1.1× *worse* than comb): measured ours/comb power ratio = {:.2}×.",
            o.ours.report.power_mw / o.comb.report.power_mw
        );
    }
    write_csv(
        results_dir,
        "fig6.csv",
        "dataset,comb_area,sota_area,ours_area,comb_power,sota_power,ours_power",
        &rows,
    )?;
    Ok(md)
}

/// Fig. 7: hybrid (1/2/5% drop) vs multi-cycle gains.
pub fn fig7(outs: &[DatasetOutcome], results_dir: &Path) -> Result<String> {
    let mut md = String::new();
    let _ = writeln!(md, "\n## Figure 7 — Neuron approximation: hybrid vs multi-cycle\n");
    let _ = writeln!(md, "| Dataset | drop | #approx/H | area gain | power gain | test acc |");
    let _ = writeln!(md, "|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    let mut per_drop: std::collections::BTreeMap<String, (Vec<f64>, Vec<f64>)> = Default::default();
    for o in outs {
        let h = o.selections.first().map(|(_, s)| s.approx_mask.len()).unwrap_or(0);
        for ((drop, sel), (_, hy)) in o.selections.iter().zip(&o.hybrids) {
            let again = o.ours.report.area_cm2 / hy.report.area_cm2;
            let pgain = o.ours.report.power_mw / hy.report.power_mw;
            let _ = writeln!(
                md,
                "| {} | {:.0}% | {}/{} | {:.2}× | {:.2}× | {:.1}% |",
                o.name, drop * 100.0, sel.n_approx, h, again, pgain, hy.test_acc * 100.0
            );
            rows.push(format!(
                "{},{:.2},{},{},{:.3},{:.3},{:.4}",
                o.name, drop, sel.n_approx, h, again, pgain, hy.test_acc
            ));
            let e = per_drop.entry(format!("{:.0}%", drop * 100.0)).or_default();
            e.0.push(again);
            e.1.push(pgain);
        }
    }
    let _ = writeln!(md, "\n| Drop | paper area gain | measured | paper power gain | measured |");
    let _ = writeln!(md, "|---|---|---|---|---|");
    let paper = [("1%", 1.7, 1.7), ("2%", 1.8, 1.7), ("5%", 1.9, 1.8)];
    for (label, pa, pp) in paper {
        if let Some((a, p)) = per_drop.get(label) {
            let _ = writeln!(md, "| {label} | {pa}× | {:.2}× | {pp}× | {:.2}× |", geomean(a), geomean(p));
        }
    }
    write_csv(
        results_dir,
        "fig7.csv",
        "dataset,drop,n_approx,hidden,area_gain,power_gain,test_acc",
        &rows,
    )?;
    Ok(md)
}

/// Fig. 8: per-inference energy of all four architectures.  Uses
/// [`crate::coordinator::DesignReport::best_energy_mj`]: the measured
/// static+dynamic energy when the pipeline ran with activity profiling
/// (`--profile-activity`), the static estimate otherwise.
pub fn fig8(outs: &[DatasetOutcome], results_dir: &Path) -> Result<String> {
    let mut md = String::new();
    let measured = outs.iter().any(|o| o.ours.measured.is_some());
    let _ = writeln!(
        md,
        "\n## Figure 8 — Energy per inference (mJ, {})\n",
        if measured { "measured switching activity" } else { "static estimate" }
    );
    let _ = writeln!(md, "| Dataset | comb [14] | seq [16] | multi-cycle | hybrid@5% |");
    let _ = writeln!(md, "|---|---|---|---|---|");
    let mut rows = Vec::new();
    let (mut e16_14, mut eo_14, mut eh_14, mut e16_h) = (vec![], vec![], vec![], vec![]);
    for o in outs {
        let hybrid = o
            .hybrids
            .iter()
            .map(|(_, h)| h)
            .last()
            .unwrap_or(&o.ours);
        let (ec, es, eo, eh) = (
            o.comb.best_energy_mj(),
            o.sota.best_energy_mj(),
            o.ours.best_energy_mj(),
            hybrid.best_energy_mj(),
        );
        let _ = writeln!(md, "| {} | {ec:.2} | {es:.2} | {eo:.2} | {eh:.2} |", o.name);
        rows.push(format!("{},{ec:.4},{es:.4},{eo:.4},{eh:.4}", o.name));
        e16_14.push(es / ec);
        eo_14.push(eo / ec);
        eh_14.push(eh / ec);
        e16_h.push(es / eh);
    }
    let _ = writeln!(md, "\n| Energy ratio (geomean) | paper | measured |");
    let _ = writeln!(md, "|---|---|---|");
    let _ = writeln!(md, "| seq [16] / comb [14] | 363× | {:.0}× |", geomean(&e16_14));
    let _ = writeln!(md, "| multi-cycle / comb [14] | 20× | {:.1}× |", geomean(&eo_14));
    let _ = writeln!(md, "| hybrid / comb [14] | 11.5× | {:.1}× |", geomean(&eh_14));
    let _ = writeln!(md, "| seq [16] / hybrid | 31.6× | {:.1}× |", geomean(&e16_h));
    if measured {
        let _ = writeln!(md, "\n| Dataset | multi-cycle static mJ | dynamic mJ | toggles/sample |");
        let _ = writeln!(md, "|---|---|---|---|");
        for o in outs {
            if let Some(m) = &o.ours.measured {
                let tps = m.toggles as f64 / m.samples.max(1) as f64;
                let _ = writeln!(
                    md,
                    "| {} | {:.2} | {:.2} | {:.0} |",
                    o.name, m.static_mj, m.dynamic_mj, tps
                );
            }
        }
    }
    write_csv(
        results_dir,
        "fig8.csv",
        "dataset,comb_energy_mj,sota_energy_mj,ours_energy_mj,hybrid_energy_mj",
        &rows,
    )?;
    Ok(md)
}

/// §3.2.2 companion: RFP retention summary (paper: 81% average kept).
pub fn rfp_summary(outs: &[DatasetOutcome], results_dir: &Path) -> Result<String> {
    let mut md = String::new();
    let _ = writeln!(md, "\n## RFP (Algorithm 1) — features kept\n");
    let _ = writeln!(md, "| Dataset | kept | total | retention | evals |");
    let _ = writeln!(md, "|---|---|---|---|---|");
    let mut rows = Vec::new();
    let mut rets = Vec::new();
    for o in outs {
        let _ = writeln!(
            md,
            "| {} | {} | {} | {:.0}% | {} |",
            o.name,
            o.rfp.kept,
            o.rfp.order.len(),
            o.rfp.retention() * 100.0,
            o.rfp.evals
        );
        rows.push(format!(
            "{},{},{},{:.4},{}",
            o.name,
            o.rfp.kept,
            o.rfp.order.len(),
            o.rfp.retention(),
            o.rfp.evals
        ));
        rets.push(o.rfp.retention());
    }
    let mean_ret = rets.iter().sum::<f64>() / rets.len().max(1) as f64;
    let _ = writeln!(
        md,
        "\nAverage retention: **{:.0}%** (paper: 81% kept / 19% pruned).",
        mean_ret * 100.0
    );
    write_csv(results_dir, "rfp.csv", "dataset,kept,total,retention,evals", &rows)?;
    Ok(md)
}

/// Serve-mode summary: one row per hosted model plus run totals
/// (markdown + `serve.csv`).
pub fn serve_report(rep: &crate::server::ServerReport, results_dir: &Path) -> Result<String> {
    let mut md = String::new();
    let _ = writeln!(
        md,
        "\n## Serve — {} scenario, backend {}, {} workers, {:.2}s\n",
        rep.scenario.label(),
        rep.backend,
        rep.workers,
        rep.elapsed_s
    );
    let _ = writeln!(
        md,
        "| Model | class | v | requests | answered | errors | shed | late | batches | mean batch | fill | req/s | p50 ms | p99 ms | SLO>{:.0}ms | canary | accuracy |",
        rep.models.first().map(|m| m.slo_ms).unwrap_or(0.0)
    );
    let _ = writeln!(md, "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    for m in &rep.models {
        let _ = writeln!(
            md,
            "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {:.1} | {:.2} | {:.0} | {:.2} | {:.2} | {} | {}/{} | {:.3} |",
            m.name,
            m.class.label(),
            m.version,
            m.requests,
            m.answered,
            m.errors,
            m.shed,
            m.late,
            m.batches,
            m.mean_batch,
            m.fill,
            m.throughput_rps,
            m.p50_ms,
            m.p99_ms,
            m.slo_violations,
            m.canary_mismatches,
            m.canary_checked,
            m.accuracy
        );
        rows.push(format!(
            "{},{},{},{},{},{},{},{},{},{:.2},{:.4},{:.1},{:.3},{:.3},{},{},{},{:.4}",
            m.name,
            m.class.label(),
            m.version,
            m.requests,
            m.answered,
            m.errors,
            m.shed,
            m.late,
            m.batches,
            m.mean_batch,
            m.fill,
            m.throughput_rps,
            m.p50_ms,
            m.p99_ms,
            m.slo_violations,
            m.canary_checked,
            m.canary_mismatches,
            m.accuracy
        ));
    }
    let _ = writeln!(
        md,
        "\nTotals: **{}** requests, **{}** answered, **{}** errored, **{}** shed, **{}** late, **{:.0}** req/s across {} models.",
        rep.total_requests(),
        rep.total_answered(),
        rep.total_errors(),
        rep.total_shed(),
        rep.total_late(),
        rep.total_rps(),
        rep.models.len()
    );
    let classes = rep.class_rows();
    if classes.len() > 1 {
        let _ = writeln!(md, "\n| Class | models | requests | answered | shed | late | SLO viol | worst p99 ms |");
        let _ = writeln!(md, "|---|---|---|---|---|---|---|---|");
        for c in &classes {
            let _ = writeln!(
                md,
                "| {} | {} | {} | {} | {} | {} | {} | {:.2} |",
                c.class.label(),
                c.models,
                c.requests,
                c.answered,
                c.shed,
                c.late,
                c.slo_violations,
                c.p99_ms
            );
        }
    }
    if let Some(ing) = &rep.ingress {
        let _ = writeln!(
            md,
            "\nIngress {}: **{}** connections, **{}** frames in, **{}** refused, **{}** malformed, **{}** deadline-closed; clients sent **{}**, answered **{}**, lost **{}**.",
            ing.listen,
            ing.connections,
            ing.frames_in,
            ing.refused,
            ing.malformed,
            ing.deadline_closed,
            ing.client_sent,
            ing.client_answered,
            ing.client_lost
        );
    }
    write_csv(
        results_dir,
        "serve.csv",
        "model,class,version,requests,answered,errors,shed,late,batches,mean_batch,fill,rps,p50_ms,p99_ms,slo_violations,canary_checked,canary_mismatches,accuracy",
        &rows,
    )?;
    Ok(md)
}

/// Fault-campaign summary: one row per `(architecture, fault level,
/// model)` cell with the deterministic accuracy-degradation columns and
/// the serve-path SLO columns (markdown + `campaign.csv`).
pub fn campaign_report(
    rep: &crate::server::CampaignReport,
    results_dir: &Path,
) -> Result<String> {
    let mut md = String::new();
    let _ = writeln!(
        md,
        "\n## Fault campaign — {} scenario, {} rows\n",
        rep.scenario.label(),
        rep.rows.len()
    );
    let _ = writeln!(
        md,
        "| Arch | Model | stuck | flips | flip rate | clean acc | fault acc | degradation | requests | errors | shed | p99 ms | SLO viol | serve acc |"
    );
    let _ = writeln!(md, "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|");
    let mut rows = Vec::new();
    for r in &rep.rows {
        let _ = writeln!(
            md,
            "| {} | {} | {} | {} | {:.4} | {:.3} | {:.3} | {:+.3} | {} | {} | {} | {:.2} | {} | {:.3} |",
            r.arch.label(),
            r.model,
            r.stuck,
            r.transient,
            r.flip_rate,
            r.baseline_accuracy,
            r.fault_accuracy,
            r.degradation,
            r.serve.requests,
            r.serve.errors,
            r.serve.shed,
            r.serve.p99_ms,
            r.serve.slo_violations,
            r.serve.accuracy
        );
        rows.push(format!(
            "{},{},{},{},{:.6},{:.4},{:.4},{:.4},{},{},{},{},{:.1},{:.3},{:.3},{},{:.4}",
            r.arch.label(),
            r.model,
            r.stuck,
            r.transient,
            r.flip_rate,
            r.baseline_accuracy,
            r.fault_accuracy,
            r.degradation,
            r.serve.requests,
            r.serve.answered,
            r.serve.errors,
            r.serve.shed,
            r.serve.throughput_rps,
            r.serve.p50_ms,
            r.serve.p99_ms,
            r.serve.slo_violations,
            r.serve.accuracy
        ));
    }
    write_csv(
        results_dir,
        "campaign.csv",
        "arch,model,stuck,transient,flip_rate,baseline_acc,fault_acc,degradation,\
         requests,answered,errors,shed,rps,p50_ms,p99_ms,slo_violations,serve_acc",
        &rows,
    )?;
    Ok(md)
}

/// All experiment sections in one report.
pub fn full_report(outs: &[DatasetOutcome], results_dir: &Path) -> Result<String> {
    let mut md = String::from("# printed-mlp — paper reproduction report\n");
    md.push_str(&rfp_summary(outs, results_dir)?);
    md.push_str(&table1(outs, results_dir)?);
    md.push_str(&fig6(outs, results_dir)?);
    md.push_str(&fig7(outs, results_dir)?);
    md.push_str(&fig8(outs, results_dir)?);
    std::fs::write(results_dir.join("report.md"), &md)?;
    Ok(md)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_refs_complete() {
        for name in crate::data::DATASET_ORDER {
            assert!(paper_ref(name).is_some(), "missing paper ref for {name}");
        }
        assert!(paper_ref("nope").is_none());
    }

    #[test]
    fn paper_gain_ranges_match_text() {
        // §4.2.1: area gains 3.8–18.5×, power gains 5.5–34.3×.
        let min_a = PAPER_TABLE1.iter().map(|r| r.area_gain).fold(f64::MAX, f64::min);
        let max_a = PAPER_TABLE1.iter().map(|r| r.area_gain).fold(0.0, f64::max);
        assert_eq!(min_a, 3.8);
        assert_eq!(max_a, 18.5);
    }

    #[test]
    fn serve_report_renders_and_writes_csv() {
        use crate::server::{IngressReport, ModelReport, Scenario, ServerReport, SloClass};
        let rep = ServerReport {
            backend: "native",
            scenario: Scenario::Steady,
            workers: 2,
            elapsed_s: 1.0,
            models: vec![
                ModelReport {
                    name: "toy".into(),
                    class: SloClass::Gold,
                    version: 2,
                    requests: 10,
                    answered: 9,
                    errors: 0,
                    shed: 1,
                    late: 0,
                    batches: 3,
                    mean_batch: 3.0,
                    fill: 0.75,
                    throughput_rps: 9.0,
                    p50_ms: 1.5,
                    p99_ms: 4.0,
                    slo_ms: 50.0,
                    slo_violations: 0,
                    canary_checked: 5,
                    canary_mismatches: 0,
                    accuracy: 1.0,
                },
                ModelReport {
                    name: "bkg".into(),
                    class: SloClass::Bronze,
                    version: 1,
                    requests: 8,
                    answered: 5,
                    errors: 0,
                    shed: 2,
                    late: 1,
                    batches: 2,
                    mean_batch: 2.5,
                    fill: 1.0,
                    throughput_rps: 5.0,
                    p50_ms: 2.0,
                    p99_ms: 9.0,
                    slo_ms: 50.0,
                    slo_violations: 1,
                    canary_checked: 0,
                    canary_mismatches: 0,
                    accuracy: 0.8,
                },
            ],
            ingress: Some(IngressReport {
                listen: "127.0.0.1:9".into(),
                connections: 4,
                frames_in: 18,
                refused: 0,
                malformed: 0,
                deadline_closed: 0,
                client_sent: 18,
                client_answered: 18,
                client_lost: 0,
            }),
        };
        let dir = std::env::temp_dir().join(format!("pmlp_serve_rep_{}", std::process::id()));
        let md = serve_report(&rep, &dir).unwrap();
        assert!(md.contains("steady"));
        assert!(md.contains("| toy | gold | 2 | 10 | 9 | 0 | 1 | 0 |"));
        assert!(md.contains("| bkg | bronze | 1 | 8 | 5 | 0 | 2 | 1 |"));
        assert!(md.contains("**3** shed"));
        assert!(md.contains("**1** late"));
        assert!(md.contains("**0** errored"));
        // Per-class table, gold first; ingress totals line.
        assert!(md.contains("| gold | 1 | 10 |"));
        assert!(md.contains("| bronze | 1 | 8 |"));
        assert!(md.contains("Ingress 127.0.0.1:9"));
        assert!(md.contains("lost **0**"));
        let csv = std::fs::read_to_string(dir.join("serve.csv")).unwrap();
        assert!(csv.starts_with("model,class,version,requests,answered,errors,shed,late"));
        assert!(csv.contains("toy,gold,2,10,9,0,1,0,3"));
        assert!(csv.contains("bkg,bronze,1,8,5,0,2,1,2"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn campaign_report_renders_and_writes_csv() {
        use crate::server::{ArchKind, CampaignReport, CampaignRow, ModelReport, Scenario, SloClass};
        let serve = ModelReport {
            name: "toy".into(),
            class: SloClass::Gold,
            version: 1,
            requests: 20,
            answered: 20,
            errors: 0,
            shed: 0,
            late: 0,
            batches: 4,
            mean_batch: 5.0,
            fill: 1.0,
            throughput_rps: 40.0,
            p50_ms: 1.0,
            p99_ms: 2.0,
            slo_ms: 50.0,
            slo_violations: 0,
            canary_checked: 0,
            canary_mismatches: 0,
            accuracy: 0.9,
        };
        let rep = CampaignReport {
            scenario: Scenario::Trace,
            rows: vec![CampaignRow {
                arch: ArchKind::Ours,
                model: "toy".into(),
                stuck: 4,
                transient: 2,
                flip_rate: 0.001,
                baseline_accuracy: 0.95,
                fault_accuracy: 0.9,
                degradation: 0.05,
                serve,
            }],
        };
        let dir = std::env::temp_dir().join(format!("pmlp_campaign_rep_{}", std::process::id()));
        let md = campaign_report(&rep, &dir).unwrap();
        assert!(md.contains("Fault campaign"));
        assert!(md.contains("| ours | toy | 4 | 2 |"));
        assert!(md.contains("+0.050"));
        let csv = std::fs::read_to_string(dir.join("campaign.csv")).unwrap();
        assert!(csv.starts_with("arch,model,stuck,transient,flip_rate"));
        assert!(csv.contains("ours,toy,4,2,0.001000,0.9500,0.9000,0.0500,20,20,0,0"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
