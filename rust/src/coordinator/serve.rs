//! Multi-sensory streaming "serve" mode: the deployment story of the
//! paper's intro (wearables streaming sensor frames), run against a
//! dynamic batcher — the L3 request path with Python nowhere in sight.
//!
//! Sensor threads push frames into a channel; the leader drains up to the
//! compiled batch size (or until `max_wait` expires), executes one batch
//! on the selected [`Evaluator`] backend (PJRT, native functional model,
//! or the sharded gate-level simulator), and records per-request latency.
//! This is the standard dynamic batching trade-off (throughput vs tail
//! latency) in miniature.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::data::{ArtifactStore, Dataset};
use crate::model::ApproxTables;
use crate::runtime::{
    Backend, Evaluator, GateSimEvaluator, NativeEvaluator, PjrtEvaluator, BATCH_THROUGHPUT,
};
use crate::util::prng::Rng;
use crate::util::stats;

/// Serve-mode configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub dataset: String,
    /// Offered load, frames per second across all sensors.
    pub rate_hz: f64,
    pub duration: Duration,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    pub sensors: usize,
    pub seed: u64,
    /// Evaluator backend on the request path.
    pub backend: Backend,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            dataset: "spectf".into(),
            rate_hz: 2000.0,
            duration: Duration::from_secs(3),
            max_wait: Duration::from_millis(2),
            sensors: 4,
            seed: 7,
            backend: Backend::Auto,
        }
    }
}

/// Latency/throughput summary of a serve run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Resolved backend that actually served the run.
    pub backend: &'static str,
    pub requests: usize,
    pub batches: usize,
    pub throughput_rps: f64,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub accuracy: f64,
}

struct Frame {
    sample: usize,
    enqueued: Instant,
}

/// Run the streaming workload; returns the latency/throughput report.
pub fn run(store: &ArtifactStore, cfg: &ServeConfig) -> Result<ServeReport> {
    let model = store.model(&cfg.dataset)?;
    let ds: Dataset = store.dataset(&cfg.dataset)?;
    // Backend selection; the engine (if any) must outlive the evaluator.
    let (engine, backend) = cfg.backend.resolve()?;
    let eval: Box<dyn Evaluator + '_> = match backend {
        Backend::Pjrt => Box::new(PjrtEvaluator::new(
            engine.as_ref().expect("pjrt backend implies an engine"),
            &store.hlo_path(&cfg.dataset, BATCH_THROUGHPUT),
            &model,
            BATCH_THROUGHPUT,
        )?),
        Backend::Native => Box::new(NativeEvaluator { model: &model }),
        Backend::GateSim => Box::new(GateSimEvaluator::new(&model)),
        Backend::Auto => unreachable!("resolve() returns a concrete backend"),
    };
    let features = model.features;
    let fm = vec![1u8; features];
    let am = vec![0u8; model.hidden];
    let tables = ApproxTables::disabled(model.hidden);

    let (tx, rx) = mpsc::channel::<Frame>();
    let deadline = Instant::now() + cfg.duration;

    // Sensor threads: Poisson-ish arrivals at rate/sensors each.
    std::thread::scope(|scope| -> Result<ServeReport> {
        for s in 0..cfg.sensors {
            let tx = tx.clone();
            let per_sensor = cfg.rate_hz / cfg.sensors as f64;
            let n_test = ds.test.len();
            let seed = cfg.seed + s as u64;
            scope.spawn(move || {
                let mut rng = Rng::new(seed);
                while Instant::now() < deadline {
                    // Exponential inter-arrival.
                    let gap = -rng.f64().max(1e-12).ln() / per_sensor;
                    std::thread::sleep(Duration::from_secs_f64(gap.min(0.05)));
                    let sample = rng.usize_below(n_test);
                    if tx
                        .send(Frame {
                            sample,
                            enqueued: Instant::now(),
                        })
                        .is_err()
                    {
                        break;
                    }
                }
            });
        }
        drop(tx);

        // Leader: dynamic batcher on this thread (PJRT handles are !Send).
        let mut latencies = Vec::new();
        let mut batches = 0usize;
        let mut batch_sizes = Vec::new();
        let mut correct = 0usize;
        let mut total = 0usize;
        let started = Instant::now();
        let mut xbuf: Vec<u8> = Vec::with_capacity(BATCH_THROUGHPUT * features);
        let mut frames: Vec<Frame> = Vec::with_capacity(BATCH_THROUGHPUT);

        'outer: loop {
            frames.clear();
            xbuf.clear();
            // Block for the first frame (or finish when producers hang up).
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(f) => frames.push(f),
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if Instant::now() >= deadline {
                        break 'outer;
                    }
                    continue;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => break 'outer,
            }
            // Fill the batch until full or max_wait.
            let wait_until = Instant::now() + cfg.max_wait;
            while frames.len() < BATCH_THROUGHPUT {
                let now = Instant::now();
                if now >= wait_until {
                    break;
                }
                match rx.recv_timeout(wait_until - now) {
                    Ok(f) => frames.push(f),
                    Err(_) => break,
                }
            }
            for f in &frames {
                xbuf.extend_from_slice(ds.test.row(f.sample));
            }
            let preds = eval.predict(&xbuf, frames.len(), &fm, &am, &tables)?;
            let done = Instant::now();
            batches += 1;
            batch_sizes.push(frames.len() as f64);
            for (f, &p) in frames.iter().zip(&preds) {
                latencies.push((done - f.enqueued).as_secs_f64() * 1e3);
                total += 1;
                if p == ds.test.ys[f.sample] as i32 {
                    correct += 1;
                }
            }
        }

        let elapsed = started.elapsed().as_secs_f64();
        Ok(ServeReport {
            backend: eval.name(),
            requests: total,
            batches,
            throughput_rps: total as f64 / elapsed.max(1e-9),
            mean_batch: stats::mean(&batch_sizes),
            p50_ms: stats::percentile(&latencies, 50.0),
            p99_ms: stats::percentile(&latencies, 99.0),
            accuracy: correct as f64 / total.max(1) as f64,
        })
    })
}
