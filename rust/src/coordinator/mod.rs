//! Pipeline coordinator — the paper's "automated framework" as a leader /
//! worker system.
//!
//! For each dataset the pipeline runs: load artifacts → RFP (Algorithm 1)
//! → single-cycle tables (Eq. 1) → NSGA-II neuron-approximation search →
//! generate the four architectures → synthesis-lite characterization →
//! gate-level accuracy validation.  Datasets fan out across worker
//! threads; each worker owns its own PJRT engine (the `xla` handles are
//! `!Send`).  Stage outputs are cached to `artifacts/results/` as JSON so
//! expensive stages (NSGA) are re-used across harness runs.
//!
//! The gate-level validation stage here simulates the *clean* circuits;
//! the fault campaign (`server::campaign`, DESIGN.md §Faults) reuses the
//! same simulator path with injected stuck-at / transient faults to score
//! degradation under printed-hardware defect models.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::approx::{self, Selection};
use crate::circuits::{combinational, hybrid, seq_multicycle, seq_sota};
use crate::data::ArtifactStore;
use crate::model::ApproxTables;
use crate::nsga::NsgaConfig;
use crate::rfp::{self, RfpResult, Strategy};
use crate::runtime::{
    build_evaluator, Backend, BuiltEvaluator, EvalOpts, Evaluator, BATCH_THROUGHPUT,
};
use crate::sim::testbench;
use crate::tech::{self, CircuitReport};
use crate::util::json::{self, Json};
use crate::util::pool::{default_threads, scope_map};

/// Pipeline configuration (see `config` for the file format).
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub datasets: Vec<String>,
    pub threads: usize,
    /// Evaluator backend for the fitness/accuracy loops (`Auto` prefers
    /// PJRT and falls back to the bit-exact native model).
    pub backend: Backend,
    pub rfp_strategy: Strategy,
    pub nsga: NsgaConfig,
    /// Worker threads for the NSGA-II fitness batch on the native backend
    /// (0 = derive from the divided per-dataset thread budget, like the
    /// sim shards).  The PJRT path stays serial — its prepared-input fast
    /// path holds `!Send` device handles.
    pub search_threads: usize,
    /// Accuracy-drop budgets for Fig. 7 (fractions).
    pub drops: Vec<f64>,
    /// Training samples used for fitness evaluation (0 = all).
    pub fit_subset: usize,
    /// Validate ours/hybrid accuracy at gate level (slower, exact).
    pub gate_level_accuracy: bool,
    /// Compile gate-level sim plans to the micro-op stream (§Perf).  Off
    /// (`--no-compile-sim` / `sim.compile = false`) forces the
    /// interpreted reference path everywhere the pipeline simulates.
    pub sim_compile: bool,
    /// Gate-level super-lane width in `u64` words (`sim.lanes` /
    /// `--sim-lanes`; 0 = auto-pick from the detected SIMD width) —
    /// every simulation the pipeline runs packs `W·64` samples per pass.
    pub sim_lanes: usize,
    /// Profile per-net switching activity during gate-level validation
    /// (`sim.profile_activity` / `--profile-activity`): every
    /// [`DesignReport`] gains a measured static+dynamic
    /// [`tech::EnergyReport`] that `report`/`fig8_energy` use in place
    /// of the static estimate.
    pub profile_activity: bool,
    /// Activity-gated gate-level evaluation (`sim.gate_on_activity` /
    /// `--gate-activity`): compiled simulators skip homogeneous runs
    /// whose input blocks did not change since the last eval (§Perf).
    /// Bit-identical to ungated evaluation by construction
    /// (`tests/sim_gating.rs`), so this is purely a speed knob.
    pub gate_activity: bool,
    /// Feed measured energy per inference in as a third NSGA objective
    /// (`nsga.energy_objective` / `--energy-objective`): each candidate
    /// mask's hybrid circuit is generated and activity-profiled on a
    /// small deterministic slice of the fitness split (see `approx`).
    pub energy_objective: bool,
    /// Reuse cached per-dataset outcomes from disk when present.
    pub cache: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            datasets: crate::data::DATASET_ORDER.iter().map(|s| s.to_string()).collect(),
            threads: default_threads(),
            backend: Backend::Auto,
            rfp_strategy: Strategy::Bisect,
            nsga: NsgaConfig::default(),
            search_threads: 0,
            drops: vec![0.01, 0.02, 0.05],
            fit_subset: 512,
            gate_level_accuracy: true,
            sim_compile: true,
            sim_lanes: 0,
            profile_activity: false,
            gate_activity: false,
            energy_objective: false,
            cache: true,
        }
    }
}

/// Synthesis + validation record for one architecture instance.
#[derive(Clone, Debug)]
pub struct DesignReport {
    pub arch: &'static str,
    pub report: CircuitReport,
    pub cycles: usize,
    pub clock_ms: f64,
    /// Static worst-case energy estimate per inference
    /// ([`CircuitReport::energy_mj`]) — always present.
    pub energy_mj: f64,
    /// Measured static+dynamic energy from activity profiling
    /// (`PipelineConfig::profile_activity`); `None` with profiling off.
    pub measured: Option<tech::EnergyReport>,
    pub test_acc: f64,
}

impl DesignReport {
    /// The best energy number available: the measured total when
    /// activity profiling ran, else the static estimate — what `report`
    /// and `fig8_energy` print.
    pub fn best_energy_mj(&self) -> f64 {
        self.measured.as_ref().map_or(self.energy_mj, |m| m.total_mj())
    }
}

/// Everything the harnesses need for one dataset.
#[derive(Clone, Debug)]
pub struct DatasetOutcome {
    pub name: String,
    pub quant_test_acc: f64,
    pub rfp: RfpResult,
    pub tables: ApproxTables,
    /// (drop budget, selection) pairs, one per configured budget.
    pub selections: Vec<(f64, Selection)>,
    /// comb / seq_sota / multicycle, plus one hybrid per drop budget
    /// (named `hybrid@<drop>`).
    pub comb: DesignReport,
    pub sota: DesignReport,
    pub ours: DesignReport,
    pub hybrids: Vec<(f64, DesignReport)>,
}

/// Run the full pipeline for one dataset.
pub fn run_dataset(
    store: &ArtifactStore,
    name: &str,
    cfg: &PipelineConfig,
) -> Result<DatasetOutcome> {
    let model = store.model(name)?;
    let ds = store.dataset(name)?;

    // Datasets fan out across up to min(threads, n_datasets) workers, so
    // anything inside run_dataset that spawns its own sim workers gets the
    // thread budget divided between in-flight datasets (ceil, min 1) —
    // otherwise every dataset would spawn cfg.threads CPU-bound threads
    // and oversubscribe to threads².
    let in_flight = cfg.threads.min(cfg.datasets.len()).max(1);
    let sim_threads = cfg.threads.max(1).div_ceil(in_flight);

    // Backend selection: `Auto` probes for a PJRT client and falls back
    // to native; the engine must outlive any PJRT evaluator built on it.
    // Construction goes through the shared `runtime::build_evaluator`
    // factory (the serve-mode registry uses the same one).
    let (engine, backend) = cfg.backend.resolve()?;
    let eval = build_evaluator(
        backend,
        engine.as_ref(),
        &model,
        &EvalOpts {
            hlo_path: Some(store.hlo_path(name, BATCH_THROUGHPUT)),
            batch: BATCH_THROUGHPUT,
            sim_threads,
            sim_lanes: cfg.sim_lanes,
        },
    )?;

    let fit_split = if cfg.fit_subset > 0 {
        ds.train.head(cfg.fit_subset)
    } else {
        ds.train.clone()
    };
    // §Perf: stage the fitness split's input literals once — RFP and NSGA
    // evaluate the same split hundreds of times with different masks, and
    // rebuilding the B×F input literal per call dominated the fitness path.
    let prep = match &eval {
        BuiltEvaluator::Pjrt(e) => Some(e.prepare(&fit_split)?),
        BuiltEvaluator::Shared(_) => None,
    };
    let fit_acc = |fm: &[u8], am: &[u8], t: &ApproxTables| -> f64 {
        match (&eval, &prep) {
            (BuiltEvaluator::Pjrt(e), Some(p)) => e
                .accuracy_prepared(p, fm, am, t)
                .expect("PJRT evaluation failed mid-pipeline"),
            _ => eval
                .as_dyn()
                .accuracy(&fit_split, fm, am, t)
                .expect("evaluation failed mid-pipeline"),
        }
    };
    let h = model.hidden;
    let no_approx = vec![0u8; h];
    let no_tables = ApproxTables::disabled(h);

    // --- Stage 1: RFP (Algorithm 1) ----------------------------------------
    let full_mask = vec![1u8; model.features];
    let threshold = fit_acc(&full_mask, &no_approx, &no_tables);
    let rfp = rfp::prune(&model, &fit_split, threshold, cfg.rfp_strategy, |mask| {
        fit_acc(mask, &no_approx, &no_tables)
    });

    // --- Stage 2: single-cycle tables + NSGA-II ----------------------------
    let tables = approx::build_tables(&model, &fit_split.xs, fit_split.len(), &rfp.feat_mask);
    let baseline = rfp.accuracy;
    // §Perf: on the native backend each generation's offspring slate fans
    // out across search workers sharing one read-only delta-logit
    // FitnessCache (model::cache; nsga.cached_fitness /
    // --no-fitness-cache to fall back to the scalar oracle) with a
    // genome→objectives memo on top — bit-identical to the serial path
    // at equal seeds (tests/nsga_parallel.rs, tests/fitness_cache.rs).
    // PJRT and gatesim keep the serial reference loop: PJRT's
    // prepared-input handles are `!Send`, and the gatesim evaluator
    // regenerates its circuit per mask anyway.
    let search_threads = if cfg.search_threads > 0 {
        cfg.search_threads
    } else {
        sim_threads
    };
    // Measured-energy objective: each candidate mask's hybrid circuit is
    // generated and activity-profiled on a small deterministic slice of
    // the fitness split (single sim thread — the closure already runs
    // inside a search worker), priced by `tech::energy_report`.  The
    // NSGA memo dedups repeat genomes, so each unique mask pays the
    // circuit generation + profiled passes once.
    let energy_n = fit_split.len().min(64);
    let energy_eval = |mask: &[u8]| -> f64 {
        let ab: Vec<bool> = mask.iter().map(|&m| m == 1).collect();
        let circ = hybrid::generate(&model, &rfp.active, &ab, &tables);
        let plan = circ.sim_plan();
        let (_, act) = testbench::run_sequential_plan_activity(
            &circ,
            &plan,
            &fit_split.xs,
            energy_n,
            model.features,
            1,
            cfg.sim_lanes,
            None,
        );
        let rep = tech::report(&circ.netlist);
        let gates = plan.gate_activity(&act);
        tech::energy_report(&rep, &gates, circ.cycles + 1, model.seq_clock_ms, energy_n as u64)
            .total_mj()
    };
    let front = if backend == Backend::Native {
        let (front, _stats) = if cfg.energy_objective {
            approx::explore_parallel_energy(
                &model,
                &fit_split,
                &rfp.feat_mask,
                &tables,
                &cfg.nsga,
                search_threads,
                &energy_eval,
            )
        } else {
            approx::explore_parallel(
                &model,
                &fit_split,
                &rfp.feat_mask,
                &tables,
                &cfg.nsga,
                search_threads,
            )
        };
        front
    } else if cfg.energy_objective {
        approx::explore_energy(
            h,
            &cfg.nsga,
            |mask| fit_acc(&rfp.feat_mask, mask, &tables),
            &energy_eval,
        )
    } else {
        approx::explore(h, &cfg.nsga, |mask| fit_acc(&rfp.feat_mask, mask, &tables))
    };
    let selections: Vec<(f64, Selection)> = cfg
        .drops
        .iter()
        .map(|&d| (d, approx::select(&front, baseline, d)))
        .collect();

    // --- Stage 3: circuits + synthesis-lite + validation -------------------
    let active = &rfp.active;
    let test = &ds.test;
    // Gate-level validation runs the sharded simulator on the same
    // divided budget as the GateSim fitness evaluator above.
    let mk_seq_report = |circ: &crate::circuits::SeqCircuit,
                         arch: &'static str,
                         am: &[u8],
                         tb: &ApproxTables|
     -> DesignReport {
        let rep = tech::report(&circ.netlist);
        let mut measured = None;
        let acc = if cfg.profile_activity {
            // One activity-profiled pass over the test split yields both
            // the predictions and the measured energy breakdown; with
            // gate-level accuracy off the predictions are discarded and
            // the evaluator scores accuracy as before.
            let plan = circ.sim_plan();
            let (preds, act) = testbench::run_sequential_plan_activity(
                circ,
                &plan,
                &test.xs,
                test.len(),
                model.features,
                sim_threads,
                cfg.sim_lanes,
                None,
            );
            let gates = plan.gate_activity(&act);
            measured = Some(tech::energy_report(
                &rep,
                &gates,
                circ.cycles + 1,
                model.seq_clock_ms,
                test.len() as u64,
            ));
            if cfg.gate_level_accuracy {
                testbench::accuracy(&preds, &test.ys)
            } else {
                eval.as_dyn()
                    .accuracy(test, &rfp.feat_mask, am, tb)
                    .expect("evaluation failed mid-pipeline")
            }
        } else if cfg.gate_level_accuracy {
            let preds = testbench::run_sequential_threads(
                circ,
                &test.xs,
                test.len(),
                model.features,
                sim_threads,
            );
            testbench::accuracy(&preds, &test.ys)
        } else {
            eval.as_dyn()
                .accuracy(test, &rfp.feat_mask, am, tb)
                .expect("evaluation failed mid-pipeline")
        };
        DesignReport {
            arch,
            cycles: circ.cycles + 1, // + reset cycle
            clock_ms: model.seq_clock_ms,
            energy_mj: rep.energy_mj(circ.cycles + 1, model.seq_clock_ms),
            measured,
            test_acc: acc,
            report: rep,
        }
    };

    let ours_c = seq_multicycle::generate(&model, active);
    let ours = mk_seq_report(&ours_c, "multicycle", &no_approx, &no_tables);

    let sota_c = seq_sota::generate(&model, active);
    let sota = mk_seq_report(&sota_c, "seq_sota", &no_approx, &no_tables);

    let comb_c = combinational::generate(&model, active);
    let comb = {
        let rep = tech::report(&comb_c.netlist);
        let mut measured = None;
        let acc = if cfg.profile_activity {
            let plan = comb_c.sim_plan();
            let (preds, act) = testbench::run_combinational_plan_activity(
                &comb_c,
                &plan,
                &test.xs,
                test.len(),
                model.features,
                sim_threads,
                cfg.sim_lanes,
                None,
            );
            let gates = plan.gate_activity(&act);
            measured = Some(tech::energy_report(
                &rep,
                &gates,
                1,
                model.comb_clock_ms,
                test.len() as u64,
            ));
            if cfg.gate_level_accuracy {
                testbench::accuracy(&preds, &test.ys)
            } else {
                eval.as_dyn()
                    .accuracy(test, &rfp.feat_mask, &no_approx, &no_tables)
                    .expect("evaluation failed mid-pipeline")
            }
        } else if cfg.gate_level_accuracy {
            let preds = testbench::run_combinational_threads(
                &comb_c,
                &test.xs,
                test.len(),
                model.features,
                sim_threads,
            );
            testbench::accuracy(&preds, &test.ys)
        } else {
            eval.as_dyn()
                .accuracy(test, &rfp.feat_mask, &no_approx, &no_tables)
                .expect("evaluation failed mid-pipeline")
        };
        DesignReport {
            arch: "combinational",
            cycles: 1,
            clock_ms: model.comb_clock_ms,
            energy_mj: rep.energy_mj(1, model.comb_clock_ms),
            measured,
            test_acc: acc,
            report: rep,
        }
    };

    let mut hybrids = Vec::new();
    for (drop, sel) in &selections {
        let approx_b: Vec<bool> = sel.approx_mask.iter().map(|&m| m == 1).collect();
        let circ = hybrid::generate(&model, active, &approx_b, &tables);
        hybrids.push((
            *drop,
            mk_seq_report(&circ, "hybrid", &sel.approx_mask, &tables),
        ));
    }

    Ok(DatasetOutcome {
        name: name.to_string(),
        quant_test_acc: model.test_acc,
        rfp,
        tables,
        selections,
        comb,
        sota,
        ours,
        hybrids,
    })
}

/// Fan the pipeline out over datasets (one worker thread each, each with
/// its own PJRT engine), honoring the JSON stage cache.
pub fn run_pipeline(store: &ArtifactStore, cfg: &PipelineConfig) -> Result<Vec<DatasetOutcome>> {
    // Plans the circuit wrappers build lazily inside the workers follow
    // the process-wide compile default, and simulators the testbenches
    // build follow the super-lane width default; apply both before
    // fan-out.
    crate::sim::set_compile_default(cfg.sim_compile);
    crate::sim::set_lane_words_default(cfg.sim_lanes);
    crate::sim::set_profile_activity_default(cfg.profile_activity);
    crate::sim::set_gate_on_activity_default(cfg.gate_activity);
    let results = scope_map(cfg.datasets.len(), cfg.threads, |i| {
        let name = &cfg.datasets[i];
        if cfg.cache {
            if let Some(out) = load_cached(store, name, cfg) {
                return Ok(out);
            }
        }
        let out = run_dataset(store, name, cfg)
            .with_context(|| format!("pipeline failed for dataset {name}"))?;
        if cfg.cache {
            let _ = save_cached(store, &out, cfg);
        }
        Ok(out)
    });
    results.into_iter().collect()
}

// ---------------------------------------------------------------------------
// Disk cache (artifacts/results/pipeline_<ds>.json)
// ---------------------------------------------------------------------------

fn cache_key(cfg: &PipelineConfig) -> String {
    format!(
        "v4-fit{}-pop{}-gen{}-{:?}-act{}-eobj{}",
        cfg.fit_subset,
        cfg.nsga.pop_size,
        cfg.nsga.generations,
        cfg.rfp_strategy,
        cfg.profile_activity as u8,
        cfg.energy_objective as u8
    )
}

fn cache_path(store: &ArtifactStore, name: &str) -> PathBuf {
    store.results_dir().join(format!("pipeline_{name}.json"))
}

fn design_to_json(d: &DesignReport) -> Json {
    let mut fields = vec![
        ("arch", json::s(d.arch)),
        ("cells", json::num(d.report.n_cells as f64)),
        ("dffs", json::num(d.report.n_dffs as f64)),
        ("area_cm2", json::num(d.report.area_cm2)),
        ("power_mw", json::num(d.report.power_mw)),
        ("crit_path_ms", json::num(d.report.crit_path_ms)),
        ("logic_depth", json::num(d.report.logic_depth as f64)),
        ("cycles", json::num(d.cycles as f64)),
        ("clock_ms", json::num(d.clock_ms)),
        ("energy_mj", json::num(d.energy_mj)),
        ("test_acc", json::num(d.test_acc)),
    ];
    if let Some(m) = &d.measured {
        // Scalars only: attribution detail (per kind/level) is cheap to
        // recompute and not worth a lossless schema in the stage cache.
        fields.push((
            "measured",
            json::obj(vec![
                ("samples", json::num(m.samples as f64)),
                ("static_mj", json::num(m.static_mj)),
                ("dynamic_mj", json::num(m.dynamic_mj)),
                ("toggles", json::num(m.toggles as f64)),
            ]),
        ));
    }
    json::obj(fields)
}

fn design_from_json(j: &Json, arch: &'static str) -> Result<DesignReport> {
    let measured = match j.get("measured") {
        Ok(m) => Some(tech::EnergyReport {
            name: arch.to_string(),
            samples: m.get("samples")?.int()? as u64,
            static_mj: m.get("static_mj")?.num()?,
            dynamic_mj: m.get("dynamic_mj")?.num()?,
            per_kind: Default::default(),
            per_level: Vec::new(),
            toggles: m.get("toggles")?.int()? as u64,
        }),
        Err(_) => None,
    };
    Ok(DesignReport {
        arch,
        report: CircuitReport {
            name: arch.to_string(),
            cells: Default::default(),
            n_cells: j.get("cells")?.int()? as usize,
            n_dffs: j.get("dffs")?.int()? as usize,
            area_cm2: j.get("area_cm2")?.num()?,
            power_mw: j.get("power_mw")?.num()?,
            crit_path_ms: j.get("crit_path_ms")?.num()?,
            logic_depth: j.get("logic_depth")?.int()? as usize,
        },
        cycles: j.get("cycles")?.int()? as usize,
        clock_ms: j.get("clock_ms")?.num()?,
        energy_mj: j.get("energy_mj")?.num()?,
        measured,
        test_acc: j.get("test_acc")?.num()?,
    })
}

fn save_cached(store: &ArtifactStore, out: &DatasetOutcome, cfg: &PipelineConfig) -> Result<()> {
    let sels = out
        .selections
        .iter()
        .map(|(d, s)| {
            json::obj(vec![
                ("drop", json::num(*d)),
                ("n_approx", json::num(s.n_approx as f64)),
                ("accuracy", json::num(s.accuracy)),
                (
                    "mask",
                    Json::Arr(s.approx_mask.iter().map(|&m| json::num(m as f64)).collect()),
                ),
            ])
        })
        .collect();
    let hybrids = out
        .hybrids
        .iter()
        .map(|(d, r)| {
            json::obj(vec![("drop", json::num(*d)), ("design", design_to_json(r))])
        })
        .collect();
    let j = json::obj(vec![
        ("key", json::s(&cache_key(cfg))),
        ("name", json::s(&out.name)),
        ("quant_test_acc", json::num(out.quant_test_acc)),
        ("rfp_kept", json::num(out.rfp.kept as f64)),
        ("rfp_total", json::num(out.rfp.order.len() as f64)),
        ("rfp_acc", json::num(out.rfp.accuracy)),
        ("rfp_threshold", json::num(out.rfp.threshold)),
        ("rfp_evals", json::num(out.rfp.evals as f64)),
        (
            "rfp_order",
            Json::Arr(out.rfp.order.iter().map(|&f| json::num(f as f64)).collect()),
        ),
        (
            "tables",
            json::obj(vec![
                ("idx", Json::Arr(out.tables.idx.iter().map(|&v| json::num(v as f64)).collect())),
                ("pos", Json::Arr(out.tables.pos.iter().map(|&v| json::num(v as f64)).collect())),
                ("l1", Json::Arr(out.tables.l1.iter().map(|&v| json::num(v as f64)).collect())),
                ("sign", Json::Arr(out.tables.sign.iter().map(|&v| json::num(v as f64)).collect())),
                ("base", Json::Arr(out.tables.base.iter().map(|&v| json::num(v as f64)).collect())),
            ]),
        ),
        ("selections", Json::Arr(sels)),
        ("comb", design_to_json(&out.comb)),
        ("sota", design_to_json(&out.sota)),
        ("ours", design_to_json(&out.ours)),
        ("hybrids", Json::Arr(hybrids)),
    ]);
    std::fs::create_dir_all(store.results_dir())?;
    std::fs::write(cache_path(store, &out.name), j.to_string())?;
    Ok(())
}

fn load_cached(store: &ArtifactStore, name: &str, cfg: &PipelineConfig) -> Option<DatasetOutcome> {
    let text = std::fs::read_to_string(cache_path(store, name)).ok()?;
    let j = Json::parse(&text).ok()?;
    if j.get("key").ok()?.str().ok()? != cache_key(cfg) {
        return None;
    }
    let order: Vec<usize> = j
        .get("rfp_order")
        .ok()?
        .i32_vec()
        .ok()?
        .into_iter()
        .map(|v| v as usize)
        .collect();
    let kept = j.get("rfp_kept").ok()?.int().ok()? as usize;
    let total = j.get("rfp_total").ok()?.int().ok()? as usize;
    let mut feat_mask = vec![0u8; total];
    for &f in &order[..kept] {
        feat_mask[f] = 1;
    }
    let t = j.get("tables").ok()?;
    let tables = ApproxTables {
        idx: t.get("idx").ok()?.i32_vec().ok()?,
        pos: t.get("pos").ok()?.i32_vec().ok()?,
        l1: t.get("l1").ok()?.i32_vec().ok()?,
        sign: t.get("sign").ok()?.i32_vec().ok()?,
        base: t.get("base").ok()?.i32_vec().ok()?,
    };
    let mut selections = Vec::new();
    for s in j.get("selections").ok()?.arr().ok()? {
        selections.push((
            s.get("drop").ok()?.num().ok()?,
            Selection {
                approx_mask: s
                    .get("mask")
                    .ok()?
                    .i32_vec()
                    .ok()?
                    .into_iter()
                    .map(|v| v as u8)
                    .collect(),
                n_approx: s.get("n_approx").ok()?.int().ok()? as usize,
                accuracy: s.get("accuracy").ok()?.num().ok()?,
            },
        ));
    }
    let mut hybrids = Vec::new();
    for hj in j.get("hybrids").ok()?.arr().ok()? {
        hybrids.push((
            hj.get("drop").ok()?.num().ok()?,
            design_from_json(hj.get("design").ok()?, "hybrid").ok()?,
        ));
    }
    Some(DatasetOutcome {
        name: name.to_string(),
        quant_test_acc: j.get("quant_test_acc").ok()?.num().ok()?,
        rfp: RfpResult {
            active: order[..kept].to_vec(),
            order,
            kept,
            feat_mask,
            accuracy: j.get("rfp_acc").ok()?.num().ok()?,
            threshold: j.get("rfp_threshold").ok()?.num().ok()?,
            evals: j.get("rfp_evals").ok()?.int().ok()? as usize,
        },
        tables,
        selections,
        comb: design_from_json(j.get("comb").ok()?, "combinational").ok()?,
        sota: design_from_json(j.get("sota").ok()?, "seq_sota").ok()?,
        ours: design_from_json(j.get("ours").ok()?, "multicycle").ok()?,
        hybrids,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_sane() {
        let c = PipelineConfig::default();
        assert_eq!(c.datasets.len(), 7);
        assert!(c.threads >= 1);
        assert_eq!(c.drops, vec![0.01, 0.02, 0.05]);
        // Activity profiling and the energy objective are opt-in: the
        // clean pipeline must not pay for counters it didn't ask for.
        assert!(!c.profile_activity);
        assert!(!c.gate_activity, "gating is an opt-in perf knob");
        assert!(!c.energy_objective);
    }

    #[test]
    fn cache_roundtrip() {
        let dir = std::env::temp_dir().join(format!("pmlp_cache_{}", std::process::id()));
        std::fs::create_dir_all(dir.join("results")).unwrap();
        let store = ArtifactStore::new(&dir);
        let cfg = PipelineConfig::default();
        let rep = CircuitReport {
            name: "x".into(),
            cells: Default::default(),
            n_cells: 10,
            n_dffs: 2,
            area_cm2: 1.5,
            power_mw: 0.7,
            crit_path_ms: 12.0,
            logic_depth: 9,
        };
        let d = DesignReport {
            arch: "multicycle",
            report: rep,
            cycles: 50,
            clock_ms: 100.0,
            energy_mj: 3.5,
            measured: Some(tech::EnergyReport {
                name: "x".into(),
                samples: 128,
                static_mj: 3.5,
                dynamic_mj: 0.25,
                per_kind: Default::default(),
                per_level: Vec::new(),
                toggles: 4096,
            }),
            test_acc: 0.9,
        };
        let out = DatasetOutcome {
            name: "toy".into(),
            quant_test_acc: 0.91,
            rfp: RfpResult {
                order: vec![2, 0, 1],
                kept: 2,
                feat_mask: vec![1, 0, 1],
                active: vec![2, 0],
                accuracy: 0.9,
                threshold: 0.89,
                evals: 3,
            },
            tables: ApproxTables::disabled(2),
            selections: vec![(
                0.01,
                Selection {
                    approx_mask: vec![1, 0],
                    n_approx: 1,
                    accuracy: 0.89,
                },
            )],
            comb: d.clone(),
            sota: d.clone(),
            ours: d.clone(),
            hybrids: vec![(0.01, d.clone())],
        };
        save_cached(&store, &out, &cfg).unwrap();
        let back = load_cached(&store, "toy", &cfg).expect("cache load");
        assert_eq!(back.rfp.kept, 2);
        assert_eq!(back.rfp.active, vec![2, 0]);
        assert_eq!(back.selections[0].1.approx_mask, vec![1, 0]);
        assert_eq!(back.ours.cycles, 50);
        let m = back.ours.measured.as_ref().expect("measured energy survives the cache");
        assert_eq!(m.samples, 128);
        assert_eq!(m.toggles, 4096);
        assert!((m.dynamic_mj - 0.25).abs() < 1e-12);
        assert!((back.ours.best_energy_mj() - 3.75).abs() < 1e-12);
        // Different key invalidates.
        let mut cfg2 = cfg.clone();
        cfg2.fit_subset = 99;
        assert!(load_cached(&store, "toy", &cfg2).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
