//! Hand-rolled CLI (clap is unavailable offline — DESIGN.md
//! §Substitutions).
//!
//! ```text
//! printed-mlp pipeline  [--datasets a,b] [--threads N] [--backend B]
//!                       [--search-threads N] [--no-nsga-cache]
//!                       [--no-fitness-cache] [--native] [--no-cache]
//!                       [--fit-subset N] [--no-compile-sim] [--sim-lanes W]
//!                       [--profile-activity] [--gate-activity]
//!                       [--energy-objective] [--config FILE]
//! printed-mlp search    --synthetic [--hidden N] [--features N] [--classes N]
//!                       [--samples N] [--seed N] [--verify] [pipeline flags]
//! printed-mlp reproduce [--exp table1|fig4|fig6|fig7|fig8|rfp|all] [...]
//! printed-mlp verilog   --dataset NAME [--arch ours|hybrid|comb|sota] [--out FILE]
//! printed-mlp simulate  --dataset NAME [--arch ...] [--samples N] [--threads N]
//!                       [--no-compile-sim] [--sim-lanes W]
//!                       [--profile-activity] [--gate-activity] [--synthetic]
//! printed-mlp serve     [--datasets a,b,..] [--scenario S] [--rate HZ] [--secs S]
//!                       [--workers N] [--queue-cap N] [--batch N] [--backend B]
//!                       [--sim-lanes W] [--synthetic] [--fuse-models]
//!                       [--trace FILE] [--trace-out FILE] [--config FILE]
//! printed-mlp campaign  [serve flags] [--archs ours,hybrid,comb]
//!                       [--fault-levels S:T,..] [--flip-rate P] [--fault-seed N]
//! printed-mlp info
//! ```
//!
//! `B` selects the [`crate::runtime::Evaluator`] backend:
//! `auto|native|pjrt|gatesim`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Config;
use crate::coordinator;
use crate::data::ArtifactStore;
use crate::report;
use crate::server;

/// Parsed flags: `--key value` or bare `--flag`.
pub struct Flags {
    pub positional: Vec<String>,
    named: BTreeMap<String, String>,
}

impl Flags {
    pub fn parse(args: &[String]) -> Result<Flags> {
        let mut positional = Vec::new();
        let mut named = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(name) = a.strip_prefix("--") {
                let next_is_value = args
                    .get(i + 1)
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false);
                if next_is_value {
                    named.insert(name.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    named.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Flags { positional, named })
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.named.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.named.contains_key(name)
    }
}

const USAGE: &str = "printed-mlp — Sequential Printed MLP Circuits (ASPDAC'25) reproduction

USAGE:
  printed-mlp pipeline  [--datasets a,b,..] [--threads N] [--native]
                        [--backend auto|native|pjrt|gatesim]
                        [--search-threads N] [--no-nsga-cache]
                        [--no-fitness-cache] [--no-cache] [--fit-subset N]
                        [--pop N] [--gens N]
                        [--no-compile-sim] [--sim-lanes 0|1|2|4|8]
                        [--profile-activity] [--gate-activity]
                        [--energy-objective] [--config FILE] [--fast]
  printed-mlp search    --synthetic [--hidden N] [--features N] [--classes N]
                        [--samples N] [--seed N] [--pop N] [--gens N]
                        [--search-threads N] [--no-nsga-cache]
                        [--no-fitness-cache] [--verify]
  printed-mlp reproduce [--exp table1|fig6|fig7|fig8|rfp|all] [pipeline flags]
  printed-mlp verilog   --dataset NAME [--arch ours|hybrid|comb|sota] [--out FILE]
  printed-mlp simulate  --dataset NAME [--arch ours|comb|sota] [--samples N]
                        [--threads N] [--no-compile-sim] [--sim-lanes W]
                        [--profile-activity] [--gate-activity] [--synthetic]
  printed-mlp serve     [--datasets a,b,..]
                        [--scenario steady|bursty|ramp|fanin|trace]
                        [--rate HZ] [--secs S] [--sensors N] [--workers N]
                        [--batch N] [--queue-cap N] [--max-wait-ms MS]
                        [--slo-ms MS] [--seed N] [--backend native|gatesim]
                        [--sim-lanes W] [--synthetic] [--trace FILE]
                        [--trace-out FILE] [--config FILE]
                        [--listen ADDR:PORT] [--classes gold,silver,..]
                        [--shed-late] [--reload S] [--canary-frac F]
                        [--fuse-models]
  printed-mlp campaign  [serve flags] [--archs ours,hybrid,comb]
                        [--fault-levels 0:0,4:0,16:0,4:4] [--flip-rate P]
                        [--fault-seed N]
  printed-mlp info

Backends: auto prefers PJRT and falls back to the native functional model;
gatesim validates on the sharded gate-level netlist simulator.
Serve hosts every --datasets model concurrently behind per-model bounded
batching queues drained by a --workers pool; overflow is shed and counted.
--listen ADDR:PORT puts a hand-rolled non-blocking TCP frontend in front
(length-prefixed binary frames; port 0 picks an ephemeral port) and turns
the sensors into open-loop socket clients.  --classes assigns each model a
tenant SLO class positionally (gold|silver|bronze; unlisted = gold):
overload sheds bronze first via per-class admission ceilings, and workers
drain gold queues first.  --shed-late refuses queued frames already older
than --slo-ms instead of evaluating them (counted separately as `late`).
--reload S hot-reloads every model at S seconds: the candidate is built
and warmed off the request path, then atomically swapped in with zero
downtime; with --canary-frac F the candidate first shadows that fraction
of live batches and prediction mismatches are counted before promotion.
Scenarios: steady (fixed rate, round-robin), bursty (Poisson on/off),
ramp (0.1x -> 2x rate over the run), fanin (each sensor window feeds every
model), trace (replay a recorded arrival trace — --trace FILE, or a
seed-deterministic synthesized diurnal curve; --trace-out saves the
replayed trace).  --synthetic serves deterministic self-labeled models
with no artifacts (accuracy 1.000 expected on an exact backend).
Campaign sweeps printed-hardware faults (stuck-at + seed-deterministic
transient bit-flips) over gate-level evaluators per architecture:
--fault-levels takes stuck:transient count pairs, --flip-rate the per-bit
transient flip probability.  Rows report deterministic clean/faulted
accuracy plus serve-path SLO impact (campaign.csv).
On the native backend the NSGA-II approximation search fans each
generation's fitness batch across --search-threads workers (0 = auto)
with a genome memo cache (--no-nsga-cache disables it) and a shared
delta-logit fitness cache (nsga.cached_fitness config key): one
precompute pass over the split collapses every genome evaluation to
baseline-plus-selected-delta adds, re-applying only the mask diff
between generations.  --no-fitness-cache (or
PRINTED_MLP_NO_FITNESS_CACHE=1) falls back to the scalar accuracy
oracle; both paths and every thread count are bit-identical to the
serial search at the same seed.  search --synthetic exercises exactly
this machinery on a deterministic artifact-free model (--verify
re-checks the front against the serial scalar oracle; the CI smoke
path).
Gate-level simulation compiles each netlist into a strength-reduced
micro-op stream (sim.compile config key); --no-compile-sim (or
PRINTED_MLP_NO_COMPILE_SIM=1) falls back to the interpreted reference
simulator, which is bit-identical but slower.  --sim-lanes W (sim.lanes
config key, PRINTED_MLP_SIM_LANES env) sets the super-lane width: each
simulator pass packs W x 64 samples (W in {1,2,4,8}; 0 = auto-pick from
the detected SIMD width) — every width is bit-identical per lane.
--profile-activity (sim.profile_activity config key,
PRINTED_MLP_PROFILE_ACTIVITY env) turns on per-net toggle counters in the
gate simulator: reports then price dynamic switching energy from measured
activity instead of the static-only estimate.  Counts are bit-identical
across every --sim-lanes width and thread count.  --energy-objective
(nsga.energy_objective config key) feeds that measured energy-per-inference
to the NSGA-II search as a third objective alongside feature count and
accuracy.  simulate --synthetic runs a deterministic self-labeled model
with no artifacts (the CI smoke path).
--gate-activity (sim.gate_on_activity config key,
PRINTED_MLP_GATE_ACTIVITY env) turns on activity-gated evaluation of the
compiled micro-op stream: runs whose input blocks did not toggle since the
last pass are skipped.  Results are bit-identical to the ungated simulator
at every --sim-lanes width, thread count, and fault list; sequential
circuits with held inputs settle early and skip most of the work.
serve --fuse-models (serve.fuse_models config key, gatesim backend only)
concatenates every hosted model's compiled plan into one level-merged
fused plan and drains all tenant queues through a single simulator pass
per sweep, so small per-tenant batches share super-lane fill; --workers
then sets the fused simulator's shard threads.  Predictions are
bit-identical to per-model serving; hot reload still works (the fused
plan is rebuilt when any tenant promotes a new version).
Artifacts root: $PRINTED_MLP_ARTIFACTS (default ./artifacts); build with `make artifacts`.";

/// CLI entrypoint.
pub fn run(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first().cloned() else {
        println!("{USAGE}");
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    let store = ArtifactStore::discover();
    match cmd.as_str() {
        "pipeline" => cmd_pipeline(&store, &flags),
        "search" => cmd_search(&flags),
        "reproduce" => cmd_reproduce(&store, &flags),
        "verilog" => cmd_verilog(&store, &flags),
        "simulate" => cmd_simulate(&store, &flags),
        "serve" => cmd_serve(&store, &flags),
        "campaign" => cmd_campaign(&store, &flags),
        "info" => cmd_info(&store),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
}

/// Build a PipelineConfig from config file + CLI overrides.
pub fn pipeline_config(flags: &Flags) -> Result<coordinator::PipelineConfig> {
    let mut conf = match flags.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?,
        None => Config::default(),
    };
    if let Some(v) = flags.get("datasets") {
        conf.set("pipeline.datasets", v);
    }
    if let Some(v) = flags.get("threads") {
        conf.set("pipeline.threads", v);
    }
    if let Some(v) = flags.get("search-threads") {
        conf.set("pipeline.search_threads", v);
    }
    if flags.has("no-nsga-cache") {
        conf.set("nsga.memoize", "false");
    }
    if flags.has("no-fitness-cache") {
        conf.set("nsga.cached_fitness", "false");
    }
    if flags.has("native") {
        conf.set("pipeline.backend", "native");
    }
    if let Some(v) = flags.get("backend") {
        conf.set("pipeline.backend", v);
    }
    if flags.has("no-cache") {
        conf.set("pipeline.cache", "false");
    }
    if flags.has("no-compile-sim") {
        conf.set("sim.compile", "false");
    }
    if let Some(v) = flags.get("sim-lanes") {
        conf.set("sim.lanes", v);
    }
    if flags.has("profile-activity") {
        conf.set("sim.profile_activity", "true");
    }
    if flags.has("gate-activity") {
        conf.set("sim.gate_on_activity", "true");
    }
    if flags.has("energy-objective") {
        conf.set("nsga.energy_objective", "true");
    }
    if let Some(v) = flags.get("fit-subset") {
        conf.set("pipeline.fit_subset", v);
    }
    if let Some(v) = flags.get("pop") {
        conf.set("nsga.pop_size", v);
    }
    if let Some(v) = flags.get("gens") {
        conf.set("nsga.generations", v);
    }
    if flags.has("fast") {
        // Quick smoke settings for demos/tests.
        conf.set("pipeline.fit_subset", "192");
        conf.set("nsga.pop_size", "12");
        conf.set("nsga.generations", "8");
    }
    conf.pipeline()
}

fn require_artifacts(store: &ArtifactStore, datasets: &[String]) -> Result<()> {
    for d in datasets {
        if !store.has(d) {
            bail!(
                "artifacts for `{d}` not found under {} — run `make artifacts` first",
                store.root.display()
            );
        }
    }
    Ok(())
}

fn cmd_pipeline(store: &ArtifactStore, flags: &Flags) -> Result<()> {
    let cfg = pipeline_config(flags)?;
    require_artifacts(store, &cfg.datasets)?;
    let t0 = std::time::Instant::now();
    let outs = coordinator::run_pipeline(store, &cfg)?;
    println!(
        "pipeline: {} datasets in {:.1}s ({} threads, backend {})",
        outs.len(),
        t0.elapsed().as_secs_f64(),
        cfg.threads,
        cfg.backend.label()
    );
    let md = report::full_report(&outs, &store.results_dir())?;
    println!("{md}");
    println!("CSV + report.md written to {}", store.results_dir().display());
    Ok(())
}

/// Artifact-free NSGA-II search smoke on a deterministic synthetic
/// model: the CI path for the delta-logit fitness cache (and its
/// `--no-fitness-cache` scalar-oracle twin).  `--verify` re-runs the
/// serial scalar search and fails unless the Pareto fronts are
/// bit-identical.
fn cmd_search(flags: &Flags) -> Result<()> {
    if !flags.has("synthetic") {
        bail!("search runs on synthetic models only (pass --synthetic); dataset searches run inside `pipeline`");
    }
    let cfg = pipeline_config(flags)?;
    let seed: u64 = flags.get("seed").unwrap_or("7").parse()?;
    let features: usize = flags.get("features").unwrap_or("16").parse()?;
    let hidden: usize = flags.get("hidden").unwrap_or("12").parse()?;
    let classes: usize = flags.get("classes").unwrap_or("4").parse()?;
    let samples: usize = flags.get("samples").unwrap_or("128").parse()?;
    let model = crate::model::synth::rand_model(seed, features, hidden, classes);
    let split = crate::model::synth::rand_split(&model, seed ^ 0x5EED, samples);
    let fm = vec![1u8; model.features];
    let tables = crate::approx::build_tables(&model, &split.xs, split.len(), &fm);
    let threads = if cfg.search_threads > 0 {
        cfg.search_threads
    } else {
        crate::util::pool::default_threads()
    };
    let t0 = std::time::Instant::now();
    let (front, stats) =
        crate::approx::explore_parallel(&model, &split, &fm, &tables, &cfg.nsga, threads);
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "search: front {} of pop {} after {} gens, {} samples, {:.2}s \
         ({threads} threads, fitness cache {}, {:.0} genome-evals/s, memo hit rate {:.2})",
        front.len(),
        cfg.nsga.pop_size,
        cfg.nsga.generations,
        split.len(),
        secs,
        if cfg.nsga.cached_fitness { "on" } else { "off" },
        stats.requested as f64 / secs.max(1e-9),
        stats.hit_rate(),
    );
    if flags.has("verify") {
        let serial = crate::approx::explore(model.hidden, &cfg.nsga, |mask| {
            model.accuracy(&split.xs, &split.ys, &fm, mask, &tables)
        });
        if serial.len() != front.len()
            || serial
                .iter()
                .zip(&front)
                .any(|(a, b)| a.genome != b.genome || a.objectives != b.objectives)
        {
            bail!("cached/parallel front diverged from the serial scalar oracle");
        }
        println!("verify: front bit-identical to the serial scalar oracle");
    }
    Ok(())
}

fn cmd_reproduce(store: &ArtifactStore, flags: &Flags) -> Result<()> {
    let exp = flags.get("exp").unwrap_or("all");
    let cfg = pipeline_config(flags)?;
    require_artifacts(store, &cfg.datasets)?;
    let outs = coordinator::run_pipeline(store, &cfg)?;
    let dir = store.results_dir();
    let md = match exp {
        "table1" => report::table1(&outs, &dir)?,
        "fig6" => report::fig6(&outs, &dir)?,
        "fig7" => report::fig7(&outs, &dir)?,
        "fig8" => report::fig8(&outs, &dir)?,
        "rfp" => report::rfp_summary(&outs, &dir)?,
        "all" => report::full_report(&outs, &dir)?,
        other => bail!("unknown experiment `{other}` (want table1|fig6|fig7|fig8|rfp|all; fig4 is `cargo bench --bench fig4_reg_vs_mux`)"),
    };
    println!("{md}");
    Ok(())
}

/// Build one architecture for a dataset (full feature set, no RFP) —
/// used by the verilog/simulate commands for quick inspection.
fn build_arch(
    store: &ArtifactStore,
    name: &str,
    arch: &str,
) -> Result<(crate::netlist::Netlist, usize)> {
    let model = store.model(name)?;
    let ds = store.dataset(name)?;
    let active: Vec<usize> = (0..model.features).collect();
    Ok(match arch {
        "ours" | "multicycle" => {
            let c = crate::circuits::seq_multicycle::generate(&model, &active);
            (c.netlist, c.cycles)
        }
        "sota" => {
            let c = crate::circuits::seq_sota::generate(&model, &active);
            (c.netlist, c.cycles)
        }
        "comb" | "combinational" => {
            let c = crate::circuits::combinational::generate(&model, &active);
            (c.netlist, 1)
        }
        "hybrid" => {
            let tables = crate::approx::build_tables(
                &model,
                &ds.train.xs,
                ds.train.len(),
                &vec![1u8; model.features],
            );
            let approx: Vec<bool> = crate::approx::demo_hybrid_mask(model.hidden)
                .iter()
                .map(|&b| b == 1)
                .collect();
            let c = crate::circuits::hybrid::generate(&model, &active, &approx, &tables);
            (c.netlist, c.cycles)
        }
        other => bail!("unknown arch `{other}` (want ours|hybrid|comb|sota)"),
    })
}

fn cmd_verilog(store: &ArtifactStore, flags: &Flags) -> Result<()> {
    let name = flags.get("dataset").ok_or_else(|| anyhow!("--dataset required"))?;
    let arch = flags.get("arch").unwrap_or("ours");
    let (netlist, _) = build_arch(store, name, arch)?;
    let text = crate::netlist::verilog::emit(&netlist);
    let rep = crate::tech::report(&netlist);
    match flags.get("out") {
        Some(path) => {
            std::fs::write(path, &text).with_context(|| format!("writing {path}"))?;
            println!(
                "wrote {path}: {} cells ({} DFFs), {:.1} cm², {:.1} mW, depth {}",
                rep.n_cells, rep.n_dffs, rep.area_cm2, rep.power_mw, rep.logic_depth
            );
        }
        None => println!("{text}"),
    }
    Ok(())
}

/// Gate-simulate a sequential circuit, optionally with toggle counters
/// feeding a measured [`crate::tech::EnergyReport`].
fn simulate_seq(
    circ: &crate::circuits::SeqCircuit,
    split: &crate::data::Split,
    features: usize,
    threads: usize,
    clock_ms: f64,
    profile: bool,
) -> (Vec<u16>, Option<crate::tech::EnergyReport>) {
    if profile {
        let plan = circ.sim_plan();
        let (preds, act) = crate::sim::testbench::run_sequential_plan_activity(
            circ,
            &plan,
            &split.xs,
            split.len(),
            features,
            threads,
            0,
            None,
        );
        let rep = crate::tech::report(&circ.netlist);
        let gates = plan.gate_activity(&act);
        let er = crate::tech::energy_report(
            &rep,
            &gates,
            circ.cycles + 1,
            clock_ms,
            split.len() as u64,
        );
        (preds, Some(er))
    } else {
        let preds = crate::sim::testbench::run_sequential_threads(
            circ,
            &split.xs,
            split.len(),
            features,
            threads,
        );
        (preds, None)
    }
}

/// Combinational twin of [`simulate_seq`].
fn simulate_comb(
    circ: &crate::circuits::CombCircuit,
    split: &crate::data::Split,
    features: usize,
    threads: usize,
    clock_ms: f64,
    profile: bool,
) -> (Vec<u16>, Option<crate::tech::EnergyReport>) {
    if profile {
        let plan = circ.sim_plan();
        let (preds, act) = crate::sim::testbench::run_combinational_plan_activity(
            circ,
            &plan,
            &split.xs,
            split.len(),
            features,
            threads,
            0,
            None,
        );
        let rep = crate::tech::report(&circ.netlist);
        let gates = plan.gate_activity(&act);
        let er = crate::tech::energy_report(&rep, &gates, 1, clock_ms, split.len() as u64);
        (preds, Some(er))
    } else {
        let preds = crate::sim::testbench::run_combinational_threads(
            circ,
            &split.xs,
            split.len(),
            features,
            threads,
        );
        (preds, None)
    }
}

fn cmd_simulate(store: &ArtifactStore, flags: &Flags) -> Result<()> {
    let synthetic = flags.has("synthetic");
    let name = match flags.get("dataset") {
        Some(n) => n.to_string(),
        None if synthetic => "synthetic".to_string(),
        None => bail!("--dataset required (or --synthetic for an artifact-free smoke)"),
    };
    let arch = flags.get("arch").unwrap_or("ours");
    let profile = flags.has("profile-activity");
    if flags.has("no-compile-sim") {
        crate::sim::set_compile_default(false);
    }
    if flags.has("gate-activity") {
        crate::sim::set_gate_on_activity_default(true);
    }
    if let Some(v) = flags.get("sim-lanes") {
        let w: usize = v.parse().with_context(|| format!("--sim-lanes {v}"))?;
        if !crate::sim::valid_lane_words(w) {
            bail!(
                "--sim-lanes: expected 0 (auto) or one of {:?}, got {w}",
                crate::sim::LANE_WORD_CHOICES
            );
        }
        crate::sim::set_lane_words_default(w);
    }
    let samples: usize = flags.get("samples").unwrap_or("256").parse()?;
    let threads: usize = match flags.get("threads") {
        Some(v) => v.parse::<usize>()?.max(1),
        None => crate::util::pool::default_threads(),
    };
    let (model, split) = if synthetic {
        // Deterministic self-labeled model: an exact gate-level run scores
        // accuracy 1.000, so the CI smoke doubles as a correctness check.
        let model = crate::model::synth::rand_model(7, 8, 6, 3);
        let split = crate::model::synth::rand_split(&model, 0x5EED, samples);
        (model, split)
    } else {
        let model = store.model(&name)?;
        let ds = store.dataset(&name)?;
        (model, ds.test.head(samples))
    };
    let active: Vec<usize> = (0..model.features).collect();
    let t0 = std::time::Instant::now();
    let (preds, measured) = match arch {
        "comb" | "combinational" => {
            let c = crate::circuits::combinational::generate(&model, &active);
            simulate_comb(&c, &split, model.features, threads, model.comb_clock_ms, profile)
        }
        "sota" => {
            let c = crate::circuits::seq_sota::generate(&model, &active);
            simulate_seq(&c, &split, model.features, threads, model.seq_clock_ms, profile)
        }
        _ => {
            let c = crate::circuits::seq_multicycle::generate(&model, &active);
            simulate_seq(&c, &split, model.features, threads, model.seq_clock_ms, profile)
        }
    };
    let acc = crate::sim::testbench::accuracy(&preds, &split.ys);
    println!(
        "{name}/{arch}: {} samples, gate-level accuracy {:.3} (recorded {:.3}), {:.2}s ({threads} sim threads)",
        split.len(),
        acc,
        model.test_acc,
        t0.elapsed().as_secs_f64()
    );
    if let Some(er) = &measured {
        println!(
            "  energy/inference: {:.4} mJ static + {:.4} mJ dynamic = {:.4} mJ ({} toggles over {} samples)",
            er.static_mj,
            er.dynamic_mj,
            er.total_mj(),
            er.toggles,
            er.samples
        );
        let mut kinds: Vec<_> = er.per_kind.iter().collect();
        kinds.sort_by(|a, b| b.1.partial_cmp(a.1).unwrap());
        for (kind, mj) in kinds.iter().take(4) {
            println!("    {kind:<6} {mj:.4} mJ dynamic");
        }
    }
    Ok(())
}

/// Map the serve-family CLI flags onto config keys (shared by the serve
/// and campaign subcommands).
fn apply_serve_flags(flags: &Flags, conf: &mut Config) {
    // `--dataset` stays as a single-model alias of `--datasets`.
    if let Some(v) = flags.get("datasets").or_else(|| flags.get("dataset")) {
        conf.set("serve.datasets", v);
    }
    if let Some(v) = flags.get("scenario") {
        conf.set("serve.scenario", v);
    }
    if let Some(v) = flags.get("rate") {
        conf.set("serve.rate_hz", v);
    }
    if let Some(v) = flags.get("secs") {
        conf.set("serve.secs", v);
    }
    if let Some(v) = flags.get("sensors") {
        conf.set("serve.sensors", v);
    }
    if let Some(v) = flags.get("workers") {
        conf.set("serve.workers", v);
    }
    if let Some(v) = flags.get("batch") {
        conf.set("serve.batch", v);
    }
    if let Some(v) = flags.get("queue-cap") {
        conf.set("serve.queue_cap", v);
    }
    if let Some(v) = flags.get("max-wait-ms") {
        conf.set("serve.max_wait_ms", v);
    }
    if let Some(v) = flags.get("slo-ms") {
        conf.set("serve.slo_ms", v);
    }
    if let Some(v) = flags.get("seed") {
        conf.set("serve.seed", v);
    }
    if let Some(v) = flags.get("backend") {
        conf.set("serve.backend", v);
    }
    if let Some(v) = flags.get("sim-lanes") {
        conf.set("sim.lanes", v);
    }
    if flags.has("synthetic") {
        conf.set("serve.synthetic", "true");
    }
    if let Some(v) = flags.get("trace") {
        conf.set("serve.trace", v);
    }
    if let Some(v) = flags.get("trace-out") {
        conf.set("serve.trace_out", v);
    }
    if let Some(v) = flags.get("classes") {
        conf.set("serve.classes", v);
    }
    if flags.has("shed-late") {
        conf.set("serve.shed_late", "true");
    }
    if let Some(v) = flags.get("listen") {
        conf.set("serve.listen", v);
    }
    if let Some(v) = flags.get("reload") {
        conf.set("serve.reload_secs", v);
    }
    if let Some(v) = flags.get("canary-frac") {
        conf.set("serve.canary_frac", v);
    }
    if flags.has("fuse-models") {
        conf.set("serve.fuse_models", "true");
    }
}

fn load_config(flags: &Flags) -> Result<Config> {
    match flags.get("config") {
        Some(path) => Config::load(std::path::Path::new(path)),
        None => Ok(Config::default()),
    }
}

/// Build a ServeConfig from config file + CLI overrides (mirrors
/// [`pipeline_config`]).
pub fn serve_config(flags: &Flags) -> Result<server::ServeConfig> {
    let mut conf = load_config(flags)?;
    apply_serve_flags(flags, &mut conf);
    conf.serve()
}

/// Build a CampaignConfig: the serve flags shape the load, the campaign
/// flags shape the fault sweep.
pub fn campaign_config(flags: &Flags) -> Result<server::CampaignConfig> {
    let mut conf = load_config(flags)?;
    apply_serve_flags(flags, &mut conf);
    if let Some(v) = flags.get("archs") {
        conf.set("campaign.archs", v);
    }
    if let Some(v) = flags.get("fault-levels") {
        conf.set("campaign.levels", v);
    }
    if let Some(v) = flags.get("flip-rate") {
        conf.set("campaign.flip_rate", v);
    }
    if let Some(v) = flags.get("fault-seed") {
        conf.set("campaign.fault_seed", v);
    }
    conf.campaign()
}

fn cmd_serve(store: &ArtifactStore, flags: &Flags) -> Result<()> {
    let cfg = serve_config(flags)?;
    if !cfg.synthetic {
        require_artifacts(store, &cfg.datasets)?;
    }
    let rep = server::run(store, &cfg)?;
    let md = report::serve_report(&rep, &store.results_dir())?;
    println!("{md}");
    if let Some(ing) = &rep.ingress {
        println!(
            "ingress {}: {} conns, {} frames in, {} refused, {} malformed, client {}/{} answered, {} lost",
            ing.listen,
            ing.connections,
            ing.frames_in,
            ing.refused,
            ing.malformed,
            ing.client_answered,
            ing.client_sent,
            ing.client_lost
        );
        // The socket boundary keeps the exactly-once contract: every
        // accepted frame must come back as *some* response.  A nonzero
        // lost count is a server bug — fail loudly (CI smoke relies on
        // this exit code).
        if ing.client_lost > 0 {
            anyhow::bail!(
                "ingress: {} accepted frames went unanswered",
                ing.client_lost
            );
        }
    }
    Ok(())
}

fn cmd_campaign(store: &ArtifactStore, flags: &Flags) -> Result<()> {
    let cfg = campaign_config(flags)?;
    if !cfg.serve.synthetic {
        require_artifacts(store, &cfg.serve.datasets)?;
    }
    let rep = server::campaign::run_campaign(store, &cfg)?;
    let md = report::campaign_report(&rep, &store.results_dir())?;
    println!("{md}");
    Ok(())
}

fn cmd_info(store: &ArtifactStore) -> Result<()> {
    println!("artifacts root: {}", store.root.display());
    for name in crate::data::DATASET_ORDER {
        if !store.has(name) {
            println!("  {name:<12} (missing — run `make artifacts`)");
            continue;
        }
        let m = store.model(name)?;
        let ds = store.dataset(name)?;
        println!(
            "  {name:<12} F={:<4} H={:<3} C={:<3} coeffs={:<5} train/test={}/{} trunc={} quant_acc={:.3}",
            m.features,
            m.hidden,
            m.classes,
            m.coefficients(),
            ds.train.len(),
            ds.test.len(),
            m.trunc,
            m.test_acc
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_parse_pairs_and_bools() {
        let args: Vec<String> = ["--datasets", "a,b", "--native", "--threads", "4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = Flags::parse(&args).unwrap();
        assert_eq!(f.get("datasets"), Some("a,b"));
        assert!(f.has("native"));
        assert_eq!(f.get("threads"), Some("4"));
        assert!(f.positional.is_empty());
    }

    #[test]
    fn pipeline_config_overrides() {
        let args: Vec<String> = ["--fit-subset", "64", "--pop", "8", "--native"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = Flags::parse(&args).unwrap();
        let cfg = pipeline_config(&f).unwrap();
        assert_eq!(cfg.fit_subset, 64);
        assert_eq!(cfg.nsga.pop_size, 8);
        assert_eq!(cfg.backend, crate::runtime::Backend::Native);
    }

    #[test]
    fn search_threads_and_nsga_cache_flags() {
        let args: Vec<String> = ["--search-threads", "3", "--no-nsga-cache"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = Flags::parse(&args).unwrap();
        let cfg = pipeline_config(&f).unwrap();
        assert_eq!(cfg.search_threads, 3);
        assert!(!cfg.nsga.memoize);
        // Defaults when the flags are absent.
        let cfg = pipeline_config(&Flags::parse(&[]).unwrap()).unwrap();
        assert_eq!(cfg.search_threads, 0);
        assert!(cfg.nsga.memoize);
    }

    #[test]
    fn no_fitness_cache_flag_reaches_config() {
        let args: Vec<String> = ["--no-fitness-cache"].iter().map(|s| s.to_string()).collect();
        let f = Flags::parse(&args).unwrap();
        assert!(!pipeline_config(&f).unwrap().nsga.cached_fitness);
        // Default: the delta-logit fitness cache is on.
        assert!(pipeline_config(&Flags::parse(&[]).unwrap()).unwrap().nsga.cached_fitness);
    }

    #[test]
    fn search_synthetic_smoke_verifies_against_oracle() {
        // The CI smoke path for the cached-fitness machinery: no
        // artifacts, deterministic model, --verify cross-checks the
        // front against the serial scalar oracle.
        let args: Vec<String> = [
            "search", "--synthetic", "--hidden", "6", "--features", "8", "--classes", "3",
            "--samples", "32", "--pop", "8", "--gens", "3", "--search-threads", "2", "--verify",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run(args).unwrap();
    }

    #[test]
    fn search_scalar_oracle_smoke() {
        // The --no-fitness-cache twin keeps the scalar path green in CI.
        let args: Vec<String> = [
            "search", "--synthetic", "--hidden", "5", "--features", "6", "--classes", "3",
            "--samples", "24", "--pop", "6", "--gens", "2", "--no-fitness-cache", "--verify",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run(args).unwrap();
    }

    #[test]
    fn search_requires_synthetic() {
        assert!(run(vec!["search".into()]).is_err());
    }

    #[test]
    fn sim_lanes_flag_reaches_both_configs_and_validates() {
        let args: Vec<String> = ["--sim-lanes", "8"].iter().map(|s| s.to_string()).collect();
        let f = Flags::parse(&args).unwrap();
        assert_eq!(pipeline_config(&f).unwrap().sim_lanes, 8);
        assert_eq!(serve_config(&f).unwrap().sim_lanes, 8);
        let args: Vec<String> = ["--sim-lanes", "5"].iter().map(|s| s.to_string()).collect();
        let f = Flags::parse(&args).unwrap();
        assert!(pipeline_config(&f).is_err());
        assert!(serve_config(&f).is_err());
        // Default: auto (0).
        assert_eq!(pipeline_config(&Flags::parse(&[]).unwrap()).unwrap().sim_lanes, 0);
    }

    #[test]
    fn no_compile_sim_flag_disables_compiled_plans() {
        let args: Vec<String> = ["--no-compile-sim"].iter().map(|s| s.to_string()).collect();
        let f = Flags::parse(&args).unwrap();
        assert!(!pipeline_config(&f).unwrap().sim_compile);
        // Default stays on.
        assert!(pipeline_config(&Flags::parse(&[]).unwrap()).unwrap().sim_compile);
    }

    #[test]
    fn activity_and_energy_objective_flags() {
        let args: Vec<String> = ["--profile-activity", "--energy-objective"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = Flags::parse(&args).unwrap();
        let cfg = pipeline_config(&f).unwrap();
        assert!(cfg.profile_activity);
        assert!(cfg.energy_objective);
        // Both default off: zero overhead unless asked for.
        let cfg = pipeline_config(&Flags::parse(&[]).unwrap()).unwrap();
        assert!(!cfg.profile_activity);
        assert!(!cfg.energy_objective);
    }

    #[test]
    fn gate_activity_flag_reaches_config() {
        let args: Vec<String> = ["--gate-activity"].iter().map(|s| s.to_string()).collect();
        let f = Flags::parse(&args).unwrap();
        assert!(pipeline_config(&f).unwrap().gate_activity);
        // Opt-in: plain runs never pay the dirty-tracking bookkeeping.
        assert!(!pipeline_config(&Flags::parse(&[]).unwrap()).unwrap().gate_activity);
    }

    #[test]
    fn simulate_synthetic_smoke_is_artifact_free() {
        // The CI smoke path: no artifacts, deterministic model, measured
        // energy printed.  Must succeed without `make artifacts`.
        let args: Vec<String> = [
            "simulate",
            "--synthetic",
            "--arch",
            "comb",
            "--samples",
            "16",
            "--threads",
            "1",
            "--profile-activity",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run(args).unwrap();
    }

    #[test]
    fn simulate_requires_dataset_unless_synthetic() {
        assert!(run(vec!["simulate".into()]).is_err());
    }

    #[test]
    fn backend_flag_selects_backend() {
        let args: Vec<String> = ["--backend", "gatesim"].iter().map(|s| s.to_string()).collect();
        let f = Flags::parse(&args).unwrap();
        let cfg = pipeline_config(&f).unwrap();
        assert_eq!(cfg.backend, crate::runtime::Backend::GateSim);

        let args: Vec<String> = ["--backend", "nosuch"].iter().map(|s| s.to_string()).collect();
        let f = Flags::parse(&args).unwrap();
        assert!(pipeline_config(&f).is_err());
    }

    #[test]
    fn serve_config_overrides() {
        let args: Vec<String> = [
            "--datasets", "a,b,c", "--scenario", "ramp", "--rate", "123", "--secs", "0.25",
            "--workers", "2", "--queue-cap", "17", "--batch", "8", "--synthetic", "--backend",
            "gatesim",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let f = Flags::parse(&args).unwrap();
        let cfg = serve_config(&f).unwrap();
        assert_eq!(cfg.datasets, vec!["a".to_string(), "b".into(), "c".into()]);
        assert_eq!(cfg.scenario, crate::server::Scenario::Ramp);
        assert_eq!(cfg.rate_hz, 123.0);
        assert_eq!(cfg.duration, std::time::Duration::from_secs_f64(0.25));
        assert_eq!(cfg.workers, 2);
        assert_eq!(cfg.queue_cap, 17);
        assert_eq!(cfg.batch, 8);
        assert!(cfg.synthetic);
        assert_eq!(cfg.backend, crate::runtime::Backend::GateSim);
    }

    #[test]
    fn serve_trace_flags_reach_config() {
        let args: Vec<String> = ["--scenario", "trace", "--trace", "in.trace", "--trace-out", "o.trace"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = Flags::parse(&args).unwrap();
        let cfg = serve_config(&f).unwrap();
        assert_eq!(cfg.scenario, crate::server::Scenario::Trace);
        assert_eq!(cfg.trace, Some(std::path::PathBuf::from("in.trace")));
        assert_eq!(cfg.trace_out, Some(std::path::PathBuf::from("o.trace")));
    }

    #[test]
    fn campaign_config_overrides() {
        use crate::server::ArchKind;
        let args: Vec<String> = [
            "--synthetic", "--archs", "ours,comb", "--fault-levels", "0:0,8:2", "--flip-rate",
            "0.01", "--fault-seed", "77", "--rate", "200", "--secs", "0.1",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let f = Flags::parse(&args).unwrap();
        let cfg = campaign_config(&f).unwrap();
        assert!(cfg.serve.synthetic);
        assert_eq!(cfg.serve.rate_hz, 200.0);
        assert_eq!(cfg.archs, vec![ArchKind::Ours, ArchKind::Comb]);
        assert_eq!(cfg.levels, vec![(0, 0), (8, 2)]);
        assert_eq!(cfg.flip_rate, 0.01);
        assert_eq!(cfg.fault_seed, 77);
        // Defaults: the standard sweep.
        let d = campaign_config(&Flags::parse(&[]).unwrap()).unwrap();
        assert_eq!(d.archs.len(), 3);
        assert_eq!(d.levels.len(), 4);
        // Bad levels rejected.
        let args: Vec<String> = ["--fault-levels", "bogus"].iter().map(|s| s.to_string()).collect();
        assert!(campaign_config(&Flags::parse(&args).unwrap()).is_err());
    }

    #[test]
    fn serve_ingress_flags_reach_config() {
        use crate::server::SloClass;
        let args: Vec<String> = [
            "--listen", "127.0.0.1:0", "--classes", "gold,bronze", "--shed-late", "--reload",
            "0.2", "--canary-frac", "0.5",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let f = Flags::parse(&args).unwrap();
        let cfg = serve_config(&f).unwrap();
        assert_eq!(cfg.listen.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(cfg.classes, vec![SloClass::Gold, SloClass::Bronze]);
        assert!(cfg.shed_late);
        assert_eq!(cfg.reload_at, Some(std::time::Duration::from_secs_f64(0.2)));
        assert_eq!(cfg.canary_frac, 0.5);
        // Bad class names / canary fractions are rejected.
        let bad: Vec<String> = ["--classes", "platinum"].iter().map(|s| s.to_string()).collect();
        assert!(serve_config(&Flags::parse(&bad).unwrap()).is_err());
        let bad: Vec<String> = ["--canary-frac", "2"].iter().map(|s| s.to_string()).collect();
        assert!(serve_config(&Flags::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn fuse_models_flag_reaches_config() {
        let args: Vec<String> =
            ["--fuse-models", "--backend", "gatesim"].iter().map(|s| s.to_string()).collect();
        let cfg = serve_config(&Flags::parse(&args).unwrap()).unwrap();
        assert!(cfg.fuse_models);
        assert!(!serve_config(&Flags::parse(&[]).unwrap()).unwrap().fuse_models);
    }

    #[test]
    fn serve_single_dataset_alias() {
        let args: Vec<String> = ["--dataset", "spectf"].iter().map(|s| s.to_string()).collect();
        let f = Flags::parse(&args).unwrap();
        let cfg = serve_config(&f).unwrap();
        assert_eq!(cfg.datasets, vec!["spectf".to_string()]);
        // Defaults host three datasets.
        let cfg = serve_config(&Flags::parse(&[]).unwrap()).unwrap();
        assert_eq!(cfg.datasets.len(), 3);
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(vec!["frobnicate".into()]).is_err());
    }

    #[test]
    fn no_args_prints_usage() {
        assert!(run(vec![]).is_ok());
    }
}
