//! Small summary statistics shared by benches and reports.

use crate::util::prng::Rng;

/// Bounded latency sample store: exact below the cap, deterministic
/// reservoir sampling (Algorithm R, seeded) above it, so long serve
/// campaigns keep O(cap) memory while percentiles stay an unbiased
/// estimate of the full stream.  Replaces the previously unbounded
/// latency `Vec` in the server's per-model stats.
#[derive(Clone, Debug)]
pub struct Reservoir {
    cap: usize,
    seen: u64,
    rng: Rng,
    samples: Vec<f64>,
}

/// Default reservoir capacity: exact percentiles for any serve run under
/// 65 536 answered frames per model, ~512 KiB worst-case per model above.
pub const RESERVOIR_CAP: usize = 65_536;

impl Reservoir {
    /// Seeded reservoir — same stream + same seed ⇒ same samples.
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        assert!(cap > 0, "reservoir needs a nonzero capacity");
        Reservoir {
            cap,
            seen: 0,
            rng: Rng::new(seed),
            samples: Vec::new(),
        }
    }

    /// Offer one observation.  The first `cap` observations are kept
    /// exactly; after that, observation `k` (1-based) replaces a random
    /// held sample with probability `cap / k` — each stream element ends
    /// up retained with equal probability (Algorithm R).
    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            let j = self.rng.below(self.seen);
            if (j as usize) < self.cap {
                self.samples[j as usize] = x;
            }
        }
    }

    /// The held samples (unordered).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Total observations offered (≥ `len`).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

impl Default for Reservoir {
    /// [`RESERVOIR_CAP`] capacity with a fixed seed — what the server's
    /// per-model stats construct, so runs stay reproducible without
    /// threading a seed through stat construction.
    fn default() -> Reservoir {
        Reservoir::new(RESERVOIR_CAP, 0x5A17)
    }
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean (the paper reports average gain factors; geomean is the
/// right aggregate for ratios).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// p-th percentile (nearest-rank) of an unsorted slice.
///
/// Sorts under IEEE-754 total order (`f64::total_cmp`), so NaN inputs
/// are handled deterministically instead of panicking the way the
/// previous `partial_cmp().unwrap()` comparator did on any NaN (e.g. a
/// ratio metric dividing by a zero baseline).  Under total order NaNs
/// sort to the extremes by sign bit — negative NaN before -inf, positive
/// NaN after +inf — so a NaN in the data surfaces in the end percentiles
/// rather than aborting the whole report.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_ranks() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn stddev_basic() {
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138).abs() < 1e-3);
    }

    #[test]
    fn percentile_edge_cases() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.5], 0.0), 7.5);
        assert_eq!(percentile(&[7.5], 50.0), 7.5);
        assert_eq!(percentile(&[7.5], 100.0), 7.5);
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn percentile_tolerates_nan() {
        // Regression: the old partial_cmp().unwrap() comparator panicked
        // on NaN.  Under total order NaNs land at the extremes by sign
        // bit: positive NaN after +inf, negative NaN before -inf.
        let xs = [1.0, f64::NAN, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!(percentile(&xs, 100.0).is_nan());
        // A sign-flipped NaN (what 0.0/0.0 produces on x86-SSE) must not
        // panic either; it sorts first, so the top percentile is finite.
        let neg_nan = f64::from_bits(f64::NAN.to_bits() | (1u64 << 63));
        let xs = [1.0, neg_nan, 3.0];
        assert!(percentile(&xs, 0.0).is_nan());
        assert_eq!(percentile(&xs, 100.0), 3.0);
    }

    #[test]
    fn reservoir_exact_below_cap() {
        let mut r = Reservoir::new(100, 1);
        for i in 0..50 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 50);
        assert_eq!(r.seen(), 50);
        // Exact retention ⇒ percentiles agree with the full stream.
        let full: Vec<f64> = (0..50).map(|i| i as f64).collect();
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(percentile(r.samples(), p), percentile(&full, p));
        }
    }

    #[test]
    fn reservoir_bounded_unbiased_and_deterministic_above_cap() {
        let cap = 256;
        let n = 20_000;
        let run = |seed| {
            let mut r = Reservoir::new(cap, seed);
            for i in 0..n {
                r.push(i as f64);
            }
            r
        };
        let r = run(7);
        assert_eq!(r.len(), cap, "memory stays bounded at the cap");
        assert_eq!(r.seen(), n as u64);
        assert_eq!(
            r.samples(),
            run(7).samples(),
            "same stream + seed ⇒ same reservoir"
        );
        // Unbiased: the sample median of a uniform ramp tracks the true
        // median within sampling error (3σ ≈ n/(2·√cap) · 3/√cap ⇒ use a
        // generous 20% band).
        let med = percentile(r.samples(), 50.0);
        let true_med = n as f64 / 2.0;
        assert!(
            (med - true_med).abs() < true_med * 0.2,
            "median {med} vs {true_med}"
        );
        // Every held sample came from the stream.
        assert!(r.samples().iter().all(|&x| x >= 0.0 && x < n as f64));
    }

    #[test]
    fn mean_geomean_stddev_edges() {
        assert_eq!(mean(&[42.0]), 42.0);
        assert_eq!(geomean(&[]), 0.0);
        assert!((geomean(&[4.0]) - 4.0).abs() < 1e-12);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(stddev(&[3.0]), 0.0);
    }
}
