//! Small summary statistics shared by benches and reports.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean (the paper reports average gain factors; geomean is the
/// right aggregate for ratios).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// p-th percentile (nearest-rank) of an unsorted slice.
///
/// Sorts under IEEE-754 total order (`f64::total_cmp`), so NaN inputs
/// are handled deterministically instead of panicking the way the
/// previous `partial_cmp().unwrap()` comparator did on any NaN (e.g. a
/// ratio metric dividing by a zero baseline).  Under total order NaNs
/// sort to the extremes by sign bit — negative NaN before -inf, positive
/// NaN after +inf — so a NaN in the data surfaces in the end percentiles
/// rather than aborting the whole report.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_ranks() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn stddev_basic() {
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138).abs() < 1e-3);
    }

    #[test]
    fn percentile_edge_cases() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.5], 0.0), 7.5);
        assert_eq!(percentile(&[7.5], 50.0), 7.5);
        assert_eq!(percentile(&[7.5], 100.0), 7.5);
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn percentile_tolerates_nan() {
        // Regression: the old partial_cmp().unwrap() comparator panicked
        // on NaN.  Under total order NaNs land at the extremes by sign
        // bit: positive NaN after +inf, negative NaN before -inf.
        let xs = [1.0, f64::NAN, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!(percentile(&xs, 100.0).is_nan());
        // A sign-flipped NaN (what 0.0/0.0 produces on x86-SSE) must not
        // panic either; it sorts first, so the top percentile is finite.
        let neg_nan = f64::from_bits(f64::NAN.to_bits() | (1u64 << 63));
        let xs = [1.0, neg_nan, 3.0];
        assert!(percentile(&xs, 0.0).is_nan());
        assert_eq!(percentile(&xs, 100.0), 3.0);
    }

    #[test]
    fn mean_geomean_stddev_edges() {
        assert_eq!(mean(&[42.0]), 42.0);
        assert_eq!(geomean(&[]), 0.0);
        assert!((geomean(&[4.0]) - 4.0).abs() < 1e-12);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(stddev(&[3.0]), 0.0);
    }
}
