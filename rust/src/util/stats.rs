//! Small summary statistics shared by benches and reports.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Geometric mean (the paper reports average gain factors; geomean is the
/// right aggregate for ratios).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// p-th percentile (nearest-rank) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_of_ratios() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_ranks() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn stddev_basic() {
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138).abs() < 1e-3);
    }
}
