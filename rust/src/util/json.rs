//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Supports exactly what the artifact interchange needs: objects, arrays,
//! strings (with escapes), f64 numbers, bools, null.  Numbers are stored
//! as f64 — all artifact integers (powers, signs, biases) fit exactly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key `{key}`")),
            _ => bail!("not an object (looking up `{key}`)"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn num(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn int(&self) -> Result<i64> {
        let n = self.num()?;
        if n.fract() != 0.0 {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }

    pub fn str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    /// Flat i32 vector from an array of numbers.
    pub fn i32_vec(&self) -> Result<Vec<i32>> {
        self.arr()?
            .iter()
            .map(|v| Ok(v.int()? as i32))
            .collect::<Result<Vec<_>>>()
            .context("i32 array")
    }

    /// Row-major i32 matrix from an array of arrays; checks rectangularity.
    pub fn i32_matrix(&self) -> Result<(Vec<i32>, usize, usize)> {
        let rows = self.arr()?;
        let nrows = rows.len();
        let ncols = if nrows == 0 { 0 } else { rows[0].arr()?.len() };
        let mut flat = Vec::with_capacity(nrows * ncols);
        for r in rows {
            let r = r.i32_vec()?;
            if r.len() != ncols {
                bail!("ragged matrix: {} vs {ncols}", r.len());
            }
            flat.extend(r);
        }
        Ok((flat, nrows, ncols))
    }

    // -- writer --------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building reports.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected `{}` at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected `,` or `}}`, got `{}`", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected `,` or `]`, got `{}`", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape `\\{}`", e as char),
                    }
                }
                c => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + width).min(self.b.len());
                        out.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(
            text.parse::<f64>()
                .with_context(|| format!("bad number `{text}` at byte {start}"))?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "0", "-12", "3.5"] {
            let v = Json::parse(t).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().arr().unwrap()[2].get("b").unwrap().str().unwrap(),
            "x\ny"
        );
    }

    #[test]
    fn i32_matrix_checks_shape() {
        let v = Json::parse("[[1,2,3],[4,5,6]]").unwrap();
        let (flat, r, c) = v.i32_matrix().unwrap();
        assert_eq!((r, c), (2, 3));
        assert_eq!(flat, vec![1, 2, 3, 4, 5, 6]);
        assert!(Json::parse("[[1],[2,3]]").unwrap().i32_matrix().is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""μW/cm²""#).unwrap();
        assert_eq!(v.str().unwrap(), "μW/cm²");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
