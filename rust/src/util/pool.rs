//! Minimal scoped thread pool (rayon/tokio substitute).
//!
//! The coordinator fans dataset jobs and NSGA-II fitness evaluations out
//! across cores with [`scope_map`]; workloads are coarse-grained, so a
//! simple work-stealing-free chunked scheme is sufficient and keeps the
//! implementation dependency-free.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (`PRINTED_MLP_THREADS` overrides).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PRINTED_MLP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Apply `f` to every item index in parallel, collecting results in order.
///
/// `f` must be `Sync`; items are claimed with an atomic cursor so uneven
/// job costs (e.g. HAR vs SPECTF) balance automatically.
pub fn scope_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    scope_map_with(n, threads, || (), move |_, i| f(i))
}

/// [`scope_map`] with per-worker state: each worker calls `init` once when
/// it starts and threads the state through every item it claims.
///
/// This is what lets expensive worker setup (e.g. a gate-level simulator's
/// lane state in `sim::batch`) be paid once per worker instead of once per
/// item; the state never crosses threads, so it needs no `Send`/`Sync`.
pub fn scope_map_with<S, T, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        let mut state = init();
        return (0..n).map(|i| f(&mut state, i)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut state = init();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(&mut state, i);
                    *slots[i].lock().unwrap() = Some(v);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = scope_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(scope_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty() {
        let out: Vec<usize> = scope_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_work_balances() {
        // Just exercises the atomic-cursor path with skewed costs.
        let out = scope_map(32, 4, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(out.len(), 32);
    }

    #[test]
    fn with_state_inits_once_per_worker() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let inits = AtomicUsize::new(0);
        let out = scope_map_with(
            64,
            4,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                0usize // per-worker counter of items it processed
            },
            |seen, i| {
                *seen += 1;
                i * 2
            },
        );
        assert_eq!(out, (0..64).map(|i| i * 2).collect::<Vec<_>>());
        let n_inits = inits.load(Ordering::Relaxed);
        assert!(
            (1..=4).contains(&n_inits),
            "state must be created per worker, not per item (got {n_inits})"
        );
    }

    #[test]
    fn more_threads_than_items_clamps_and_orders() {
        // threads is clamped to the item count; results stay in order.
        let out = scope_map(3, 64, |i| i * 10);
        assert_eq!(out, vec![0, 10, 20]);
    }

    #[test]
    fn worker_panic_propagates() {
        // A panicking worker must surface through thread::scope's join
        // (not deadlock or return partial results).
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            scope_map(8, 4, |i| {
                if i == 3 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(r.is_err(), "worker panic must propagate to the caller");
    }

    #[test]
    fn env_var_forces_thread_count() {
        // PRINTED_MLP_THREADS=1 forces the serial path everywhere
        // default_threads() feeds a pool (sim sharding included); 0 and
        // garbage fall back safely.
        std::env::set_var("PRINTED_MLP_THREADS", "1");
        assert_eq!(default_threads(), 1);
        std::env::set_var("PRINTED_MLP_THREADS", "0");
        assert_eq!(default_threads(), 1, "0 clamps to 1");
        std::env::set_var("PRINTED_MLP_THREADS", "not-a-number");
        assert!(default_threads() >= 1);
        std::env::remove_var("PRINTED_MLP_THREADS");
        assert!(default_threads() >= 1);
    }
}
