//! Minimal scoped thread pool (rayon/tokio substitute).
//!
//! The coordinator fans dataset jobs and NSGA-II fitness evaluations out
//! across cores with [`scope_map`]; workloads are coarse-grained, so a
//! simple work-stealing-free chunked scheme is sufficient and keeps the
//! implementation dependency-free.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (`PRINTED_MLP_THREADS` overrides).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PRINTED_MLP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Apply `f` to every item index in parallel, collecting results in order.
///
/// `f` must be `Sync`; items are claimed with an atomic cursor so uneven
/// job costs (e.g. HAR vs SPECTF) balance automatically.
pub fn scope_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                *slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = scope_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(scope_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty() {
        let out: Vec<usize> = scope_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_work_balances() {
        // Just exercises the atomic-cursor path with skewed costs.
        let out = scope_map(32, 4, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(out.len(), 32);
    }
}
