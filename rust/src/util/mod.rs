//! Offline-friendly utilities (substitutes for crates unavailable in this
//! environment — see DESIGN.md §Substitutions).
//!
//! - [`prng`] — xoshiro256** PRNG (rand substitute), deterministic.
//! - [`json`] — minimal JSON parser/writer (serde substitute).
//! - [`pool`] — scoped thread pool (tokio/rayon substitute) for fan-out.
//! - [`propcheck`] — mini property-testing kit (proptest substitute).
//! - [`stats`] — small summary-statistics helpers shared by benches.

pub mod json;
pub mod pool;
pub mod prng;
pub mod propcheck;
pub mod stats;
