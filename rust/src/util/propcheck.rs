//! Mini property-testing kit (proptest substitute — unavailable offline).
//!
//! Runs a property over many PRNG-generated cases; on failure it performs
//! a bounded greedy shrink by re-running the generator with smaller size
//! hints, then reports the seed so the case can be replayed exactly.
//!
//! ```
//! use printed_mlp::util::propcheck::{check, Gen};
//! check("reverse twice is identity", 200, |g| {
//!     let v = g.vec_i32(0..=64, -100..=100);
//!     let mut r = v.clone();
//!     r.reverse();
//!     r.reverse();
//!     r == v
//! });
//! ```

use std::ops::RangeInclusive;

use crate::netlist::{Cell, NetId, Netlist, CONST0, CONST1};
use crate::util::prng::Rng;

/// Case generator handed to properties; wraps the PRNG with size-aware
/// convenience constructors.
pub struct Gen {
    rng: Rng,
    /// Size budget in [0,1]; shrinking lowers it so generators produce
    /// structurally smaller cases.
    size: f64,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, r: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*r.start(), *r.end());
        let span = hi - lo;
        let scaled = ((span as f64 * self.size).ceil() as usize).min(span);
        lo + self.rng.usize_below(scaled + 1)
    }

    pub fn i32_in(&mut self, r: RangeInclusive<i32>) -> i32 {
        let (lo, hi) = (*r.start() as i64, *r.end() as i64);
        (lo + self.rng.below((hi - lo + 1) as u64) as i64) as i32
    }

    pub fn vec_i32(&mut self, len: RangeInclusive<usize>, vals: RangeInclusive<i32>) -> Vec<i32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.i32_in(vals.clone())).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.f64()
    }
}

/// Random well-formed gate-level netlist for differential simulator
/// tests: a handful of 1-bit primary inputs, a combinational cloud of
/// every primitive (with occasional constant operands and raw `BUF`
/// cells, so chain collapsing has something to chew on), inline DFFs,
/// and deferred-feedback DFFs whose `d` is connected after the cloud
/// exists (acyclic by construction — the register breaks every loop).
///
/// Every register output is exposed on a `state` output port and a
/// random sample of nets on an `obs` port, so external observation and
/// plan-compile liveness agree (`tests/sim_compiled.rs` compares the
/// ports of a compiled and an interpreted simulator bit-for-bit).
pub fn rand_netlist(g: &mut Gen) -> Netlist {
    let mut n = Netlist::new("prop");
    let n_inputs = g.usize_in(1..=6);
    // Candidate operand pool; constants included so gates fold.
    let mut pool: Vec<NetId> = vec![CONST0, CONST1];
    for i in 0..n_inputs {
        pool.push(n.add_input(&format!("in{i}"), 1)[0]);
    }
    // Feedback registers: data connected once the cloud exists.
    let n_feedback = g.usize_in(0..=3);
    let mut deferred = Vec::with_capacity(n_feedback);
    for _ in 0..n_feedback {
        let en = pool[g.rng().usize_below(pool.len())];
        let rst = pool[g.rng().usize_below(pool.len())];
        let rstval = g.bool();
        let (q, ci) = n.dff_deferred(en, rst, rstval);
        deferred.push(ci);
        pool.push(q);
    }
    let n_gates = g.usize_in(4..=48);
    for _ in 0..n_gates {
        let a = pool[g.rng().usize_below(pool.len())];
        let b = pool[g.rng().usize_below(pool.len())];
        let s = pool[g.rng().usize_below(pool.len())];
        let y = match g.usize_in(0..=10) {
            0 => n.inv(a),
            1 => {
                // Raw BUF — no builder constructor exists, and that is the
                // point: it exercises buffer-chain collapsing.
                let y = n.fresh();
                n.cells.push(Cell::Buf { a, y });
                y
            }
            2 => n.and2(a, b),
            3 => n.or2(a, b),
            4 => n.nand2(a, b),
            5 => n.nor2(a, b),
            6 => n.xor2(a, b),
            7 => n.xnor2(a, b),
            8 | 9 => n.mux2(s, a, b),
            _ => n.dff(a, b, s, g.bool()),
        };
        pool.push(y);
    }
    for ci in deferred {
        let d = pool[g.rng().usize_below(pool.len())];
        n.set_dff_d(ci, d);
    }
    let state: Vec<NetId> = n
        .cells
        .iter()
        .filter(|c| c.is_seq())
        .map(|c| c.output())
        .collect();
    if !state.is_empty() {
        n.add_output("state", state);
    }
    let n_obs = g.usize_in(1..=8);
    let obs: Vec<NetId> = (0..n_obs)
        .map(|_| pool[g.rng().usize_below(pool.len())])
        .collect();
    n.add_output("obs", obs);
    n
}

/// Run `prop` over `cases` generated inputs; panic with the failing seed
/// (after shrinking the size budget) if any case returns false.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> bool,
{
    let base = std::env::var("PROPCHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let mut g = Gen {
            rng: Rng::new(seed),
            size: 1.0,
        };
        if prop(&mut g) {
            continue;
        }
        // Shrink: lower the size budget; keep the smallest failing size.
        let mut failing_size = 1.0;
        for step in 1..=8 {
            let size = 1.0 - step as f64 / 8.0;
            if size <= 0.0 {
                break;
            }
            let mut g = Gen {
                rng: Rng::new(seed),
                size,
            };
            if !prop(&mut g) {
                failing_size = size;
            }
        }
        panic!(
            "property `{name}` failed: seed={seed} size={failing_size} \
             (replay with PROPCHECK_SEED={seed})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum is commutative", 100, |g| {
            let a = g.i32_in(-1000..=1000) as i64;
            let b = g.i32_in(-1000..=1000) as i64;
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails` failed")]
    fn failing_property_reports_seed() {
        check("always fails", 10, |_| false);
    }

    #[test]
    fn rand_netlist_is_acyclic_and_observable() {
        check("random netlists topo-sort and expose outputs", 60, |g| {
            let n = rand_netlist(g);
            let order = n.topo_order(); // panics on a combinational loop
            let n_comb = n.cells.iter().filter(|c| !c.is_seq()).count();
            order.len() == n_comb && !n.outputs.is_empty()
        });
    }

    #[test]
    fn rand_netlist_eventually_emits_every_primitive() {
        use std::cell::RefCell;
        use std::collections::BTreeSet;
        // Not a property: accumulate across cases, then check coverage.
        let seen: RefCell<BTreeSet<&'static str>> = RefCell::new(BTreeSet::new());
        check("collect cell types", 80, |g| {
            let n = rand_netlist(g);
            let mut s = seen.borrow_mut();
            for c in &n.cells {
                s.insert(c.type_name());
            }
            true
        });
        let seen = seen.into_inner();
        assert!(
            seen.contains("DFF") && seen.contains("MUX2") && seen.contains("BUF"),
            "generator must cover registers, muxes and buffer chains: {seen:?}"
        );
    }

    #[test]
    fn generators_respect_ranges() {
        check("ranges", 200, |g| {
            let n = g.usize_in(3..=17);
            let v = g.vec_i32(1..=9, -5..=5);
            (3..=17).contains(&n)
                && (1..=9).contains(&v.len())
                && v.iter().all(|x| (-5..=5).contains(x))
        });
    }
}
