//! Mini property-testing kit (proptest substitute — unavailable offline).
//!
//! Runs a property over many PRNG-generated cases; on failure it performs
//! a bounded greedy shrink by re-running the generator with smaller size
//! hints, then reports the seed so the case can be replayed exactly.
//!
//! ```
//! use printed_mlp::util::propcheck::{check, Gen};
//! check("reverse twice is identity", 200, |g| {
//!     let v = g.vec_i32(0..=64, -100..=100);
//!     let mut r = v.clone();
//!     r.reverse();
//!     r.reverse();
//!     r == v
//! });
//! ```

use std::ops::RangeInclusive;

use crate::util::prng::Rng;

/// Case generator handed to properties; wraps the PRNG with size-aware
/// convenience constructors.
pub struct Gen {
    rng: Rng,
    /// Size budget in [0,1]; shrinking lowers it so generators produce
    /// structurally smaller cases.
    size: f64,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, r: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*r.start(), *r.end());
        let span = hi - lo;
        let scaled = ((span as f64 * self.size).ceil() as usize).min(span);
        lo + self.rng.usize_below(scaled + 1)
    }

    pub fn i32_in(&mut self, r: RangeInclusive<i32>) -> i32 {
        let (lo, hi) = (*r.start() as i64, *r.end() as i64);
        (lo + self.rng.below((hi - lo + 1) as u64) as i64) as i32
    }

    pub fn vec_i32(&mut self, len: RangeInclusive<usize>, vals: RangeInclusive<i32>) -> Vec<i32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.i32_in(vals.clone())).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.f64()
    }
}

/// Run `prop` over `cases` generated inputs; panic with the failing seed
/// (after shrinking the size budget) if any case returns false.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> bool,
{
    let base = std::env::var("PROPCHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let mut g = Gen {
            rng: Rng::new(seed),
            size: 1.0,
        };
        if prop(&mut g) {
            continue;
        }
        // Shrink: lower the size budget; keep the smallest failing size.
        let mut failing_size = 1.0;
        for step in 1..=8 {
            let size = 1.0 - step as f64 / 8.0;
            if size <= 0.0 {
                break;
            }
            let mut g = Gen {
                rng: Rng::new(seed),
                size,
            };
            if !prop(&mut g) {
                failing_size = size;
            }
        }
        panic!(
            "property `{name}` failed: seed={seed} size={failing_size} \
             (replay with PROPCHECK_SEED={seed})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum is commutative", 100, |g| {
            let a = g.i32_in(-1000..=1000) as i64;
            let b = g.i32_in(-1000..=1000) as i64;
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails` failed")]
    fn failing_property_reports_seed() {
        check("always fails", 10, |_| false);
    }

    #[test]
    fn generators_respect_ranges() {
        check("ranges", 200, |g| {
            let n = g.usize_in(3..=17);
            let v = g.vec_i32(1..=9, -5..=5);
            (3..=17).contains(&n)
                && (1..=9).contains(&v.len())
                && v.iter().all(|x| (-5..=5).contains(x))
        });
    }
}
