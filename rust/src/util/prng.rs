//! xoshiro256** PRNG, seeded via SplitMix64 — deterministic, fast, and
//! good enough for NSGA-II, dataset shuffling and property tests.
//! (The `rand` crate is unavailable offline; see DESIGN.md §Substitutions.)

/// Fold a uniform `u64` onto `[0, n)` without modulo bias: the
/// multiply-high map `⌊x·n / 2^64⌋` distributes the 2^64 inputs across
/// the `n` buckets as evenly as possible (bucket sizes differ by at most
/// one), unlike `x % n`, which over-weights low residues whenever `n`
/// does not divide 2^64.  This is the *fixed-draw* counterpart of
/// [`Rng::below`]: given one recorded random word (a trace entry, a
/// fan-in window) it picks the bucket deterministically with no
/// rejection loop.
#[inline]
pub fn fold_u64(x: u64, n: u64) -> u64 {
    debug_assert!(n > 0, "fold_u64 onto an empty range");
    ((x as u128 * n as u128) >> 64) as u64
}

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed through SplitMix64 so even tiny seeds diffuse well.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (u64::MAX - n + 1) % n {
                return (m >> 64) as u64;
            }
        }
    }

    #[inline]
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform i32 in `[lo, hi]` (inclusive).
    #[inline]
    pub fn i32_range(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo <= hi);
        (lo as i64 + self.below((hi as i64 - lo as i64 + 1) as u64) as i64) as i32
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (polar form).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fold_u64_unbiased_and_in_range() {
        // Every bucket hit, and counts near-uniform for a non-power-of-two
        // n where `x % n` would visibly over-weight low indices.
        let n = 48u64; // spectf-sized test split
        let mut counts = vec![0u32; n as usize];
        let mut r = Rng::new(17);
        let draws = 48_000;
        for _ in 0..draws {
            let b = fold_u64(r.next_u64(), n);
            assert!(b < n);
            counts[b as usize] += 1;
        }
        let expect = draws as f64 / n as f64;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect).abs() < expect * 0.2,
                "bucket {i}: {c} vs {expect}"
            );
        }
        // Extremes map to the ends, never out of range.
        assert_eq!(fold_u64(0, 7), 0);
        assert_eq!(fold_u64(u64::MAX, 7), 6);
        assert_eq!(fold_u64(u64::MAX, 1), 0);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(100, 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(s.iter().all(|&i| i < 100));
    }
}
