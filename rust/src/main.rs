//! `printed-mlp` — leader entrypoint. See `cli` for subcommands.
fn main() {
    if let Err(e) = printed_mlp::cli::run(std::env::args().skip(1).collect()) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
