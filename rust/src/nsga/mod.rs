//! NSGA-II (Deb et al. 2002) — from-scratch implementation (the paper
//! deploys PyGAD's NSGA-II; see DESIGN.md §Substitutions).
//!
//! Generic over the fitness function: the framework maximizes a vector of
//! objectives over boolean genomes (here: which hidden neurons to
//! approximate, §3.2.3).  Implements fast non-dominated sorting, crowding
//! distance, binary-tournament selection on (rank, crowding), uniform
//! crossover and bit-flip mutation, plus the paper's biased initial
//! population (each initial solution approximates exactly one neuron).

use crate::util::prng::Rng;

/// A candidate solution: boolean genome + maximized objectives.
#[derive(Clone, Debug)]
pub struct Individual {
    pub genome: Vec<bool>,
    pub objectives: Vec<f64>,
    pub rank: usize,
    pub crowding: f64,
}

/// `a` Pareto-dominates `b` (all objectives >=, at least one >).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x < y {
            return false;
        }
        if x > y {
            strictly = true;
        }
    }
    strictly
}

/// Fast non-dominated sort; returns fronts of indices (front 0 = best)
/// and writes ranks into the individuals.
pub fn non_dominated_sort(pop: &mut [Individual]) -> Vec<Vec<usize>> {
    let n = pop.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut count = vec![0usize; n]; // how many dominate i
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&pop[i].objectives, &pop[j].objectives) {
                dominated_by[i].push(j);
                count[j] += 1;
            } else if dominates(&pop[j].objectives, &pop[i].objectives) {
                dominated_by[j].push(i);
                count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| count[i] == 0).collect();
    while !current.is_empty() {
        for &i in &current {
            pop[i].rank = fronts.len();
        }
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                count[j] -= 1;
                if count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Crowding distance within one front (writes into individuals).
pub fn crowding_distance(pop: &mut [Individual], front: &[usize]) {
    for &i in front {
        pop[i].crowding = 0.0;
    }
    if front.len() <= 2 {
        for &i in front {
            pop[i].crowding = f64::INFINITY;
        }
        return;
    }
    let m = pop[front[0]].objectives.len();
    for k in 0..m {
        let mut idx = front.to_vec();
        idx.sort_by(|&a, &b| {
            pop[a].objectives[k]
                .partial_cmp(&pop[b].objectives[k])
                .unwrap()
        });
        let lo = pop[idx[0]].objectives[k];
        let hi = pop[idx[idx.len() - 1]].objectives[k];
        pop[idx[0]].crowding = f64::INFINITY;
        pop[idx[idx.len() - 1]].crowding = f64::INFINITY;
        let span = (hi - lo).max(1e-12);
        for w in 1..idx.len() - 1 {
            let gain =
                (pop[idx[w + 1]].objectives[k] - pop[idx[w - 1]].objectives[k]) / span;
            pop[idx[w]].crowding += gain;
        }
    }
}

/// NSGA-II configuration.
#[derive(Clone, Debug)]
pub struct NsgaConfig {
    pub pop_size: usize,
    pub generations: usize,
    pub crossover_prob: f64,
    pub mutation_prob: f64, // per bit
    pub seed: u64,
}

impl Default for NsgaConfig {
    fn default() -> Self {
        NsgaConfig {
            pop_size: 40,
            generations: 30,
            crossover_prob: 0.9,
            mutation_prob: 0.05,
            seed: 0xA5D0,
        }
    }
}

fn tournament<'a>(pop: &'a [Individual], rng: &mut Rng) -> &'a Individual {
    let a = &pop[rng.usize_below(pop.len())];
    let b = &pop[rng.usize_below(pop.len())];
    if a.rank < b.rank || (a.rank == b.rank && a.crowding > b.crowding) {
        a
    } else {
        b
    }
}

/// Run NSGA-II and return the final population's first front, deduplicated
/// by genome.
///
/// `fitness(genome) -> objectives` is called once per *new* genome; a
/// memo table avoids re-evaluating genomes seen in earlier generations
/// (fitness evaluation dominates runtime — it runs the PJRT model over the
/// training set).
pub fn run<F>(genome_len: usize, cfg: &NsgaConfig, mut fitness: F) -> Vec<Individual>
where
    F: FnMut(&[bool]) -> Vec<f64>,
{
    use std::collections::HashMap;
    let mut rng = Rng::new(cfg.seed);
    let mut memo: HashMap<Vec<bool>, Vec<f64>> = HashMap::new();
    let eval = |g: &Vec<bool>, memo: &mut HashMap<Vec<bool>, Vec<f64>>, f: &mut F| {
        if let Some(o) = memo.get(g) {
            return o.clone();
        }
        let o = f(g);
        memo.insert(g.clone(), o.clone());
        o
    };

    // Biased initial population (§3.2.3): all-exact, plus each solution
    // approximating exactly one neuron, then random fill.
    let mut genomes: Vec<Vec<bool>> = Vec::with_capacity(cfg.pop_size);
    genomes.push(vec![false; genome_len]);
    for i in 0..genome_len.min(cfg.pop_size.saturating_sub(1)) {
        let mut g = vec![false; genome_len];
        g[i] = true;
        genomes.push(g);
    }
    while genomes.len() < cfg.pop_size {
        let g: Vec<bool> = (0..genome_len).map(|_| rng.chance(0.25)).collect();
        genomes.push(g);
    }

    let mut pop: Vec<Individual> = genomes
        .into_iter()
        .map(|g| {
            let o = eval(&g, &mut memo, &mut fitness);
            Individual {
                genome: g,
                objectives: o,
                rank: 0,
                crowding: 0.0,
            }
        })
        .collect();

    for _gen in 0..cfg.generations {
        let fronts = non_dominated_sort(&mut pop);
        for f in &fronts {
            crowding_distance(&mut pop, f);
        }
        // Offspring.
        let mut children: Vec<Individual> = Vec::with_capacity(cfg.pop_size);
        while children.len() < cfg.pop_size {
            let p1 = tournament(&pop, &mut rng).genome.clone();
            let p2 = tournament(&pop, &mut rng).genome.clone();
            let mut c = if rng.chance(cfg.crossover_prob) {
                // Uniform crossover.
                p1.iter()
                    .zip(&p2)
                    .map(|(&a, &b)| if rng.chance(0.5) { a } else { b })
                    .collect::<Vec<bool>>()
            } else {
                p1
            };
            for bit in c.iter_mut() {
                if rng.chance(cfg.mutation_prob) {
                    *bit = !*bit;
                }
            }
            let o = eval(&c, &mut memo, &mut fitness);
            children.push(Individual {
                genome: c,
                objectives: o,
                rank: 0,
                crowding: 0.0,
            });
        }
        // Environmental selection over parents + children.
        pop.extend(children);
        let fronts = non_dominated_sort(&mut pop);
        for f in &fronts {
            crowding_distance(&mut pop, f);
        }
        let mut next: Vec<Individual> = Vec::with_capacity(cfg.pop_size);
        for front in &fronts {
            if next.len() + front.len() <= cfg.pop_size {
                for &i in front {
                    next.push(pop[i].clone());
                }
            } else {
                let mut rest: Vec<usize> = front.clone();
                rest.sort_by(|&a, &b| pop[b].crowding.partial_cmp(&pop[a].crowding).unwrap());
                for &i in rest.iter().take(cfg.pop_size - next.len()) {
                    next.push(pop[i].clone());
                }
                break;
            }
        }
        pop = next;
    }

    // Final first front, deduplicated.
    let fronts = non_dominated_sort(&mut pop);
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    for &i in &fronts[0] {
        if seen.insert(pop[i].genome.clone()) {
            out.push(pop[i].clone());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominates_semantics() {
        assert!(dominates(&[1.0, 2.0], &[1.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]));
        assert!(!dominates(&[2.0, 0.0], &[1.0, 1.0]));
    }

    #[test]
    fn sort_ranks_fronts() {
        let mk = |o: Vec<f64>| Individual {
            genome: vec![],
            objectives: o,
            rank: 0,
            crowding: 0.0,
        };
        let mut pop = vec![
            mk(vec![2.0, 2.0]), // front 0
            mk(vec![1.0, 1.0]), // front 1 (dominated by 0)
            mk(vec![2.5, 1.5]), // front 0 (trade-off with 0)
            mk(vec![0.0, 0.0]), // front 2
        ];
        let fronts = non_dominated_sort(&mut pop);
        assert_eq!(fronts[0].len(), 2);
        assert!(fronts[0].contains(&0) && fronts[0].contains(&2));
        assert_eq!(pop[1].rank, 1);
        assert_eq!(pop[3].rank, 2);
    }

    #[test]
    fn crowding_prefers_extremes() {
        let mk = |o: Vec<f64>| Individual {
            genome: vec![],
            objectives: o,
            rank: 0,
            crowding: 0.0,
        };
        let mut pop = vec![
            mk(vec![0.0, 3.0]),
            mk(vec![1.0, 2.0]),
            mk(vec![2.0, 1.0]),
            mk(vec![3.0, 0.0]),
        ];
        let front: Vec<usize> = vec![0, 1, 2, 3];
        crowding_distance(&mut pop, &front);
        assert!(pop[0].crowding.is_infinite());
        assert!(pop[3].crowding.is_infinite());
        assert!(pop[1].crowding.is_finite() && pop[1].crowding > 0.0);
    }

    #[test]
    fn optimizes_known_pareto_front() {
        // Maximize (#ones, #zeros-in-prefix): front should include both
        // extremes of the count trade-off on a simple separable problem.
        let cfg = NsgaConfig {
            pop_size: 24,
            generations: 20,
            ..Default::default()
        };
        let front = run(12, &cfg, |g| {
            let ones = g.iter().filter(|&&b| b).count() as f64;
            let lead_zeros = g.iter().take_while(|&&b| !b).count() as f64;
            vec![ones, lead_zeros]
        });
        // The true front spans (12,0)..(0,12); expect a wide spread with
        // both extremes approached (all-zeros is trivially reachable from
        // the biased init; all-ones needs sustained selection pressure).
        let max_ones = front.iter().map(|i| i.objectives[0]).fold(0.0, f64::max);
        let has_allzeros = front.iter().any(|i| i.objectives[1] == 12.0);
        assert!(
            max_ones >= 9.0 && has_allzeros && front.len() >= 6,
            "front: {:?}",
            front.iter().map(|i| &i.objectives).collect::<Vec<_>>()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = NsgaConfig::default();
        let f = |g: &[bool]| vec![g.iter().filter(|&&b| b).count() as f64];
        let a = run(8, &cfg, f);
        let b = run(8, &cfg, f);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.genome, y.genome);
        }
    }

    #[test]
    fn front_is_mutually_nondominated() {
        let cfg = NsgaConfig {
            pop_size: 20,
            generations: 10,
            ..Default::default()
        };
        let front = run(10, &cfg, |g| {
            let ones = g.iter().filter(|&&b| b).count() as f64;
            vec![ones, 10.0 - ones]
        });
        for a in &front {
            for b in &front {
                assert!(!dominates(&a.objectives, &b.objectives) || a.genome == b.genome);
            }
        }
    }
}
