//! NSGA-II (Deb et al. 2002) — from-scratch implementation (the paper
//! deploys PyGAD's NSGA-II; see DESIGN.md §Substitutions).
//!
//! Generic over the fitness function: the framework maximizes a vector of
//! objectives over boolean genomes (here: which hidden neurons to
//! approximate, §3.2.3).  Implements fast non-dominated sorting, crowding
//! distance, binary-tournament selection on (rank, crowding), uniform
//! crossover and bit-flip mutation, plus the paper's biased initial
//! population (each initial solution approximates exactly one neuron).
//!
//! Two drivers share those building blocks (see DESIGN.md §Perf):
//!
//! - [`run`] — the serial reference: one `FnMut` fitness call per genome.
//! - [`run_batched`] — collects each generation's offspring first, then
//!   hands the whole slate to a [`FitnessEval`] in one call, so the
//!   evaluator can fan the batch out across worker threads (e.g.
//!   [`crate::approx::ParallelFitness`]).  A genome→objectives memo table
//!   ([`NsgaConfig::memoize`]) skips re-evaluating genomes that uniform
//!   crossover and bit-flip mutation re-produce across generations.
//!
//! Genome generation is RNG-driven and stays serial in both drivers;
//! fitness evaluation is pure per genome.  The two therefore consume
//! identical RNG streams and return bit-identical final fronts at equal
//! seeds — enforced differentially by `tests/nsga_parallel.rs`.
//!
//! Everything here is **objective-count generic**: domination, sorting,
//! crowding, and the memo table all key on `objectives.len()`, so the
//! 2-objective approximation search and the 3-objective
//! (count, accuracy, −energy) search
//! ([`crate::approx::explore_parallel_energy`]) run through identical
//! code — the memo simply stores 3-tuples — and the bit-identical and
//! rank/crowding invariants above hold for both
//! (`tests/nsga_parallel.rs` exercises the 3-tuple case explicitly).

use std::collections::HashMap;

use crate::util::prng::Rng;

/// A candidate solution: boolean genome + maximized objectives.
#[derive(Clone, Debug)]
pub struct Individual {
    pub genome: Vec<bool>,
    pub objectives: Vec<f64>,
    pub rank: usize,
    pub crowding: f64,
}

/// `a` Pareto-dominates `b` (all objectives >=, at least one >).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x < y {
            return false;
        }
        if x > y {
            strictly = true;
        }
    }
    strictly
}

/// Fast non-dominated sort; returns fronts of indices (front 0 = best)
/// and writes ranks into the individuals.
pub fn non_dominated_sort(pop: &mut [Individual]) -> Vec<Vec<usize>> {
    let n = pop.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut count = vec![0usize; n]; // how many dominate i
    for i in 0..n {
        for j in (i + 1)..n {
            if dominates(&pop[i].objectives, &pop[j].objectives) {
                dominated_by[i].push(j);
                count[j] += 1;
            } else if dominates(&pop[j].objectives, &pop[i].objectives) {
                dominated_by[j].push(i);
                count[i] += 1;
            }
        }
    }
    let mut fronts: Vec<Vec<usize>> = Vec::new();
    let mut current: Vec<usize> = (0..n).filter(|&i| count[i] == 0).collect();
    while !current.is_empty() {
        for &i in &current {
            pop[i].rank = fronts.len();
        }
        let mut next = Vec::new();
        for &i in &current {
            for &j in &dominated_by[i] {
                count[j] -= 1;
                if count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(std::mem::take(&mut current));
        current = next;
    }
    fronts
}

/// Crowding distance within one front (writes into individuals).
pub fn crowding_distance(pop: &mut [Individual], front: &[usize]) {
    for &i in front {
        pop[i].crowding = 0.0;
    }
    if front.len() <= 2 {
        for &i in front {
            pop[i].crowding = f64::INFINITY;
        }
        return;
    }
    let m = pop[front[0]].objectives.len();
    for k in 0..m {
        let mut idx = front.to_vec();
        idx.sort_by(|&a, &b| {
            pop[a].objectives[k]
                .partial_cmp(&pop[b].objectives[k])
                .unwrap()
        });
        let lo = pop[idx[0]].objectives[k];
        let hi = pop[idx[idx.len() - 1]].objectives[k];
        pop[idx[0]].crowding = f64::INFINITY;
        pop[idx[idx.len() - 1]].crowding = f64::INFINITY;
        let span = (hi - lo).max(1e-12);
        for w in 1..idx.len() - 1 {
            let gain =
                (pop[idx[w + 1]].objectives[k] - pop[idx[w - 1]].objectives[k]) / span;
            pop[idx[w]].crowding += gain;
        }
    }
}

/// NSGA-II configuration.
#[derive(Clone, Debug)]
pub struct NsgaConfig {
    pub pop_size: usize,
    pub generations: usize,
    pub crossover_prob: f64,
    pub mutation_prob: f64, // per bit
    pub seed: u64,
    /// Memoize genome→objectives (fitness must be deterministic per
    /// genome, which holds for every evaluator in this crate).  Purely a
    /// perf toggle: hits skip a full training-set pass without changing
    /// the search trajectory.
    pub memoize: bool,
    /// Route batched fitness through the shared delta-logit
    /// [`crate::model::cache::FitnessCache`] (`nsga.cached_fitness`,
    /// `--no-fitness-cache` to disable; `PRINTED_MLP_NO_FITNESS_CACHE=1`
    /// overrides at use time).  Bit-identical to the scalar accuracy
    /// oracle — purely a perf toggle, like [`memoize`](Self::memoize).
    pub cached_fitness: bool,
}

impl Default for NsgaConfig {
    fn default() -> Self {
        NsgaConfig {
            pop_size: 40,
            generations: 30,
            crossover_prob: 0.9,
            mutation_prob: 0.05,
            seed: 0xA5D0,
            memoize: true,
            cached_fitness: true,
        }
    }
}

/// Batch fitness interface for [`run_batched`]: evaluate a whole slate of
/// genomes at once, returning one objective vector per genome, in order.
///
/// Implementations may evaluate the slate in any order (or concurrently —
/// see [`crate::approx::ParallelFitness`]) but must be deterministic per
/// genome: the search calls this once per generation with only the
/// genomes the memo cache could not answer.
pub trait FitnessEval {
    fn eval_batch(&mut self, genomes: &[Vec<bool>]) -> Vec<Vec<f64>>;
}

/// Adapts a serial `FnMut(&[bool]) -> Vec<f64>` fitness closure to the
/// batch interface (evaluates genomes one at a time, in order).
pub struct SerialFitness<F>(pub F);

impl<F: FnMut(&[bool]) -> Vec<f64>> FitnessEval for SerialFitness<F> {
    fn eval_batch(&mut self, genomes: &[Vec<bool>]) -> Vec<Vec<f64>> {
        genomes.iter().map(|g| (self.0)(g)).collect()
    }
}

/// Evaluation accounting for one [`run_batched`] search.
#[derive(Clone, Debug, Default)]
pub struct SearchStats {
    /// Genomes whose objectives the memo cache answered.
    pub cache_hits: usize,
    /// Genomes actually handed to the [`FitnessEval`].
    pub evals: usize,
    /// Total genomes the search asked for (`evals + cache_hits`).
    pub requested: usize,
}

impl SearchStats {
    /// Fraction of requested evaluations the memo cache absorbed.
    pub fn hit_rate(&self) -> f64 {
        if self.requested == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.requested as f64
        }
    }
}

/// Evaluate one generation's genomes through the memo cache, batching
/// every miss into a single [`FitnessEval::eval_batch`] call.  Within a
/// batch, duplicate genomes are evaluated once and also count as hits.
fn eval_genomes<E: FitnessEval + ?Sized>(
    genomes: Vec<Vec<bool>>,
    memoize: bool,
    memo: &mut HashMap<Vec<bool>, Vec<f64>>,
    stats: &mut SearchStats,
    eval: &mut E,
) -> Vec<Individual> {
    use std::collections::hash_map::Entry;
    stats.requested += genomes.len();
    // Per genome: Ok(objectives) answered by the memo, Err(k) = k-th
    // entry of the pending batch.
    let mut pending: Vec<Vec<bool>> = Vec::new();
    let mut slots: Vec<Result<Vec<f64>, usize>> = Vec::with_capacity(genomes.len());
    let mut staged: HashMap<Vec<bool>, usize> = HashMap::new();
    for g in &genomes {
        if memoize {
            if let Some(o) = memo.get(g) {
                stats.cache_hits += 1;
                slots.push(Ok(o.clone()));
                continue;
            }
            match staged.entry(g.clone()) {
                Entry::Occupied(e) => {
                    stats.cache_hits += 1;
                    slots.push(Err(*e.get()));
                    continue;
                }
                Entry::Vacant(v) => {
                    v.insert(pending.len());
                }
            }
        }
        slots.push(Err(pending.len()));
        pending.push(g.clone());
    }
    let objs = eval.eval_batch(&pending);
    assert_eq!(
        objs.len(),
        pending.len(),
        "FitnessEval returned {} objective vectors for {} genomes",
        objs.len(),
        pending.len()
    );
    stats.evals += pending.len();
    if memoize {
        for (g, o) in pending.iter().zip(&objs) {
            memo.insert(g.clone(), o.clone());
        }
    }
    genomes
        .into_iter()
        .zip(slots)
        .map(|(genome, slot)| {
            let objectives = match slot {
                Ok(o) => o,
                Err(k) => objs[k].clone(),
            };
            Individual {
                genome,
                objectives,
                rank: 0,
                crowding: 0.0,
            }
        })
        .collect()
}

fn tournament<'a>(pop: &'a [Individual], rng: &mut Rng) -> &'a Individual {
    let a = &pop[rng.usize_below(pop.len())];
    let b = &pop[rng.usize_below(pop.len())];
    if a.rank < b.rank || (a.rank == b.rank && a.crowding > b.crowding) {
        a
    } else {
        b
    }
}

/// Run NSGA-II and return the final population's first front, deduplicated
/// by genome.
///
/// `fitness(genome) -> objectives` is called once per *new* genome; a
/// memo table avoids re-evaluating genomes seen in earlier generations
/// (fitness evaluation dominates runtime — it runs the PJRT model over the
/// training set).
pub fn run<F>(genome_len: usize, cfg: &NsgaConfig, mut fitness: F) -> Vec<Individual>
where
    F: FnMut(&[bool]) -> Vec<f64>,
{
    let mut rng = Rng::new(cfg.seed);
    let mut memo: HashMap<Vec<bool>, Vec<f64>> = HashMap::new();
    let memoize = cfg.memoize;
    let eval = move |g: &Vec<bool>, memo: &mut HashMap<Vec<bool>, Vec<f64>>, f: &mut F| {
        if memoize {
            if let Some(o) = memo.get(g) {
                return o.clone();
            }
        }
        let o = f(g);
        if memoize {
            memo.insert(g.clone(), o.clone());
        }
        o
    };

    // Biased initial population (§3.2.3): all-exact, plus each solution
    // approximating exactly one neuron, then random fill.
    let mut genomes: Vec<Vec<bool>> = Vec::with_capacity(cfg.pop_size);
    genomes.push(vec![false; genome_len]);
    for i in 0..genome_len.min(cfg.pop_size.saturating_sub(1)) {
        let mut g = vec![false; genome_len];
        g[i] = true;
        genomes.push(g);
    }
    while genomes.len() < cfg.pop_size {
        let g: Vec<bool> = (0..genome_len).map(|_| rng.chance(0.25)).collect();
        genomes.push(g);
    }

    let mut pop: Vec<Individual> = genomes
        .into_iter()
        .map(|g| {
            let o = eval(&g, &mut memo, &mut fitness);
            Individual {
                genome: g,
                objectives: o,
                rank: 0,
                crowding: 0.0,
            }
        })
        .collect();

    for _gen in 0..cfg.generations {
        let fronts = non_dominated_sort(&mut pop);
        for f in &fronts {
            crowding_distance(&mut pop, f);
        }
        // Offspring.
        let mut children: Vec<Individual> = Vec::with_capacity(cfg.pop_size);
        while children.len() < cfg.pop_size {
            let p1 = tournament(&pop, &mut rng).genome.clone();
            let p2 = tournament(&pop, &mut rng).genome.clone();
            let mut c = if rng.chance(cfg.crossover_prob) {
                // Uniform crossover.
                p1.iter()
                    .zip(&p2)
                    .map(|(&a, &b)| if rng.chance(0.5) { a } else { b })
                    .collect::<Vec<bool>>()
            } else {
                p1
            };
            for bit in c.iter_mut() {
                if rng.chance(cfg.mutation_prob) {
                    *bit = !*bit;
                }
            }
            let o = eval(&c, &mut memo, &mut fitness);
            children.push(Individual {
                genome: c,
                objectives: o,
                rank: 0,
                crowding: 0.0,
            });
        }
        // Environmental selection over parents + children.
        pop.extend(children);
        let fronts = non_dominated_sort(&mut pop);
        for f in &fronts {
            crowding_distance(&mut pop, f);
        }
        let mut next: Vec<Individual> = Vec::with_capacity(cfg.pop_size);
        for front in &fronts {
            if next.len() + front.len() <= cfg.pop_size {
                for &i in front {
                    next.push(pop[i].clone());
                }
            } else {
                let mut rest: Vec<usize> = front.clone();
                rest.sort_by(|&a, &b| pop[b].crowding.partial_cmp(&pop[a].crowding).unwrap());
                for &i in rest.iter().take(cfg.pop_size - next.len()) {
                    next.push(pop[i].clone());
                }
                break;
            }
        }
        pop = next;
    }

    // Final first front, deduplicated.
    let fronts = non_dominated_sort(&mut pop);
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    for &i in &fronts[0] {
        if seen.insert(pop[i].genome.clone()) {
            out.push(pop[i].clone());
        }
    }
    out
}

/// [`run`] with generation-batched fitness: every generation's offspring
/// slate is produced first (serial, RNG-driven), then evaluated through
/// the memo cache in a single [`FitnessEval::eval_batch`] call, which a
/// parallel evaluator can fan out across worker threads.
///
/// Bit-identical to [`run`] at equal seeds: genome generation consumes
/// the same RNG stream (fitness never touches the RNG), and objectives
/// are a pure function of the genome, so deferring and reordering their
/// evaluation cannot change selection.  `tests/nsga_parallel.rs` enforces
/// this differentially.
///
/// Returns the deduplicated final first front plus [`SearchStats`]
/// (unique evaluations vs memo hits).
pub fn run_batched<E: FitnessEval + ?Sized>(
    genome_len: usize,
    cfg: &NsgaConfig,
    eval: &mut E,
) -> (Vec<Individual>, SearchStats) {
    let mut rng = Rng::new(cfg.seed);
    let mut memo: HashMap<Vec<bool>, Vec<f64>> = HashMap::new();
    let mut stats = SearchStats::default();

    // Biased initial population — identical construction to [`run`].
    let mut genomes: Vec<Vec<bool>> = Vec::with_capacity(cfg.pop_size);
    genomes.push(vec![false; genome_len]);
    for i in 0..genome_len.min(cfg.pop_size.saturating_sub(1)) {
        let mut g = vec![false; genome_len];
        g[i] = true;
        genomes.push(g);
    }
    while genomes.len() < cfg.pop_size {
        let g: Vec<bool> = (0..genome_len).map(|_| rng.chance(0.25)).collect();
        genomes.push(g);
    }
    let mut pop = eval_genomes(genomes, cfg.memoize, &mut memo, &mut stats, eval);

    for _gen in 0..cfg.generations {
        let fronts = non_dominated_sort(&mut pop);
        for f in &fronts {
            crowding_distance(&mut pop, f);
        }
        // Offspring genomes first (same RNG consumption as [`run`],
        // which interleaves fitness calls that never touch the RNG)...
        let mut offspring: Vec<Vec<bool>> = Vec::with_capacity(cfg.pop_size);
        while offspring.len() < cfg.pop_size {
            let p1 = tournament(&pop, &mut rng).genome.clone();
            let p2 = tournament(&pop, &mut rng).genome.clone();
            let mut c = if rng.chance(cfg.crossover_prob) {
                // Uniform crossover.
                p1.iter()
                    .zip(&p2)
                    .map(|(&a, &b)| if rng.chance(0.5) { a } else { b })
                    .collect::<Vec<bool>>()
            } else {
                p1
            };
            for bit in c.iter_mut() {
                if rng.chance(cfg.mutation_prob) {
                    *bit = !*bit;
                }
            }
            offspring.push(c);
        }
        // ...then one batched evaluation for the whole generation.
        let children = eval_genomes(offspring, cfg.memoize, &mut memo, &mut stats, eval);
        // Environmental selection over parents + children.
        pop.extend(children);
        let fronts = non_dominated_sort(&mut pop);
        for f in &fronts {
            crowding_distance(&mut pop, f);
        }
        let mut next: Vec<Individual> = Vec::with_capacity(cfg.pop_size);
        for front in &fronts {
            if next.len() + front.len() <= cfg.pop_size {
                for &i in front {
                    next.push(pop[i].clone());
                }
            } else {
                let mut rest: Vec<usize> = front.clone();
                rest.sort_by(|&a, &b| pop[b].crowding.partial_cmp(&pop[a].crowding).unwrap());
                for &i in rest.iter().take(cfg.pop_size - next.len()) {
                    next.push(pop[i].clone());
                }
                break;
            }
        }
        pop = next;
    }

    // Final first front, deduplicated.
    let fronts = non_dominated_sort(&mut pop);
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    for &i in &fronts[0] {
        if seen.insert(pop[i].genome.clone()) {
            out.push(pop[i].clone());
        }
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominates_semantics() {
        assert!(dominates(&[1.0, 2.0], &[1.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]));
        assert!(!dominates(&[2.0, 0.0], &[1.0, 1.0]));
    }

    #[test]
    fn sort_ranks_fronts() {
        let mk = |o: Vec<f64>| Individual {
            genome: vec![],
            objectives: o,
            rank: 0,
            crowding: 0.0,
        };
        let mut pop = vec![
            mk(vec![2.0, 2.0]), // front 0
            mk(vec![1.0, 1.0]), // front 1 (dominated by 0)
            mk(vec![2.5, 1.5]), // front 0 (trade-off with 0)
            mk(vec![0.0, 0.0]), // front 2
        ];
        let fronts = non_dominated_sort(&mut pop);
        assert_eq!(fronts[0].len(), 2);
        assert!(fronts[0].contains(&0) && fronts[0].contains(&2));
        assert_eq!(pop[1].rank, 1);
        assert_eq!(pop[3].rank, 2);
    }

    #[test]
    fn crowding_prefers_extremes() {
        let mk = |o: Vec<f64>| Individual {
            genome: vec![],
            objectives: o,
            rank: 0,
            crowding: 0.0,
        };
        let mut pop = vec![
            mk(vec![0.0, 3.0]),
            mk(vec![1.0, 2.0]),
            mk(vec![2.0, 1.0]),
            mk(vec![3.0, 0.0]),
        ];
        let front: Vec<usize> = vec![0, 1, 2, 3];
        crowding_distance(&mut pop, &front);
        assert!(pop[0].crowding.is_infinite());
        assert!(pop[3].crowding.is_infinite());
        assert!(pop[1].crowding.is_finite() && pop[1].crowding > 0.0);
    }

    #[test]
    fn optimizes_known_pareto_front() {
        // Maximize (#ones, #zeros-in-prefix): front should include both
        // extremes of the count trade-off on a simple separable problem.
        let cfg = NsgaConfig {
            pop_size: 24,
            generations: 20,
            ..Default::default()
        };
        let front = run(12, &cfg, |g| {
            let ones = g.iter().filter(|&&b| b).count() as f64;
            let lead_zeros = g.iter().take_while(|&&b| !b).count() as f64;
            vec![ones, lead_zeros]
        });
        // The true front spans (12,0)..(0,12); expect a wide spread with
        // both extremes approached (all-zeros is trivially reachable from
        // the biased init; all-ones needs sustained selection pressure).
        let max_ones = front.iter().map(|i| i.objectives[0]).fold(0.0, f64::max);
        let has_allzeros = front.iter().any(|i| i.objectives[1] == 12.0);
        assert!(
            max_ones >= 9.0 && has_allzeros && front.len() >= 6,
            "front: {:?}",
            front.iter().map(|i| &i.objectives).collect::<Vec<_>>()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = NsgaConfig::default();
        let f = |g: &[bool]| vec![g.iter().filter(|&&b| b).count() as f64];
        let a = run(8, &cfg, f);
        let b = run(8, &cfg, f);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.genome, y.genome);
        }
    }

    #[test]
    fn run_batched_matches_run() {
        let cfg = NsgaConfig {
            pop_size: 14,
            generations: 12,
            ..Default::default()
        };
        let f = |g: &[bool]| {
            vec![
                g.iter().filter(|&&b| b).count() as f64,
                g.iter().take_while(|&&b| !b).count() as f64,
            ]
        };
        let serial = run(10, &cfg, f);
        let (batched, stats) = run_batched(10, &cfg, &mut SerialFitness(f));
        assert_eq!(serial.len(), batched.len());
        for (a, b) in serial.iter().zip(&batched) {
            assert_eq!(a.genome, b.genome);
            assert_eq!(a.objectives, b.objectives);
        }
        assert_eq!(stats.requested, cfg.pop_size * (cfg.generations + 1));
        assert_eq!(stats.evals + stats.cache_hits, stats.requested);
    }

    #[test]
    fn memo_dedups_within_and_across_batches() {
        // genome_len 2 → at most 4 distinct genomes, but pop_size 8: the
        // very first batch already holds duplicates (pigeonhole), so the
        // memo must absorb hits and cap unique evaluations at 4.
        let cfg = NsgaConfig {
            pop_size: 8,
            generations: 4,
            ..Default::default()
        };
        let mut calls = 0usize;
        let mut fit = SerialFitness(|g: &[bool]| {
            calls += 1;
            vec![g.iter().filter(|&&b| b).count() as f64]
        });
        let (_front, stats) = run_batched(2, &cfg, &mut fit);
        drop(fit);
        assert_eq!(calls, stats.evals);
        assert!(stats.evals <= 4, "only 4 distinct 2-bit genomes exist");
        assert!(stats.cache_hits > 0);
        assert_eq!(stats.evals + stats.cache_hits, stats.requested);
        assert!(stats.hit_rate() > 0.0);
    }

    #[test]
    fn cache_disabled_reevaluates_but_front_identical() {
        let on = NsgaConfig {
            pop_size: 10,
            generations: 6,
            ..Default::default()
        };
        let off = NsgaConfig {
            memoize: false,
            ..on.clone()
        };
        let f = |g: &[bool]| {
            vec![
                g.iter().filter(|&&b| b).count() as f64,
                g.iter().take_while(|&&b| !b).count() as f64,
            ]
        };
        let (a, sa) = run_batched(6, &on, &mut SerialFitness(f));
        let (b, sb) = run_batched(6, &off, &mut SerialFitness(f));
        assert_eq!(sb.cache_hits, 0);
        assert_eq!(sb.evals, sb.requested);
        assert!(sa.evals <= sb.evals);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.genome, y.genome);
            assert_eq!(x.objectives, y.objectives);
        }
    }

    #[test]
    fn front_is_mutually_nondominated() {
        let cfg = NsgaConfig {
            pop_size: 20,
            generations: 10,
            ..Default::default()
        };
        let front = run(10, &cfg, |g| {
            let ones = g.iter().filter(|&&b| b).count() as f64;
            vec![ones, 10.0 - ones]
        });
        for a in &front {
            for b in &front {
                assert!(!dominates(&a.objectives, &b.objectives) || a.genome == b.genome);
            }
        }
    }
}
