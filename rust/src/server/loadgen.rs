//! Scenario-driven load generation: sensor threads that turn a traffic
//! shape into [`Frame`]s pushed at the per-model [`BatchQueue`]s.
//!
//! Four shapes (`--scenario`):
//!
//! - `steady` — fixed inter-arrival at the offered rate, frames routed
//!   round-robin across models.  The zero-drama baseline: at the default
//!   rate nothing sheds and accuracy equals the direct evaluator's.
//! - `bursty` — Poisson arrivals (exponential gaps) modulated by an
//!   on/off square wave: 250 ms bursts at 1.8× the offered rate followed
//!   by 250 ms lulls at 0.2× (duty pair averages to 1.0, so the mean
//!   offered rate stays `rate_hz`).  Exercises queue growth and shedding.
//! - `ramp` — rate climbs linearly from 0.1× to 2× the offered rate over
//!   the run, so the server crosses its saturation point mid-run.
//! - `fanin` — the paper's multi-sensory story: each event is one frame
//!   *window* fanned out to **every** hosted model simultaneously (the
//!   wearable's shared sensor window feeding several bespoke
//!   classifiers).  `rate_hz` is the window rate, so each model sees the
//!   full rate.
//!
//! Each sensor thread owns a deterministic [`Rng`] seeded from
//! `seed ^ sensor`, so a serve run is reproducible modulo OS scheduling.

use std::str::FromStr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::bail;

use crate::server::batcher::{BatchQueue, Frame};
use crate::server::registry::ModelEntry;
use crate::server::ServeConfig;
use crate::util::prng::Rng;

/// Traffic shape for a serve run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    Steady,
    Bursty,
    Ramp,
    FanIn,
}

impl Scenario {
    pub fn label(self) -> &'static str {
        match self {
            Scenario::Steady => "steady",
            Scenario::Bursty => "bursty",
            Scenario::Ramp => "ramp",
            Scenario::FanIn => "fanin",
        }
    }
}

impl FromStr for Scenario {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Scenario> {
        Ok(match s {
            "steady" => Scenario::Steady,
            "bursty" | "poisson" => Scenario::Bursty,
            "ramp" => Scenario::Ramp,
            "fanin" | "fan-in" => Scenario::FanIn,
            other => bail!("unknown scenario `{other}` (want steady|bursty|ramp|fanin)"),
        })
    }
}

/// Burst phase length for the `bursty` scenario.
const BURST_PHASE_S: f64 = 0.25;
/// Longest single sleep *chunk*; keeps sensors responsive to the
/// deadline without flooring long inter-arrival gaps (the full gap is
/// always slept, in chunks of at most this).
const MAX_SLEEP_CHUNK: Duration = Duration::from_millis(50);

/// One sensor thread's generation loop: compute the scenario's current
/// inter-arrival gap, sleep it, and push the next frame(s).  All
/// offered/accepted/shed accounting lives in each queue's
/// [`crate::server::ModelStats`].
pub fn run_sensor(
    sensor: usize,
    entries: &[Arc<ModelEntry>],
    queues: &[BatchQueue],
    cfg: &ServeConfig,
    start: Instant,
    deadline: Instant,
    next_id: &AtomicU64,
) {
    let n_models = entries.len();
    let sensors = cfg.sensors.max(1) as f64;
    let per_sensor = (cfg.rate_hz / sensors).max(1e-6);
    let total_s = cfg.duration.as_secs_f64().max(1e-9);
    let mut rng = Rng::new(cfg.seed ^ (0xC0FFEE + sensor as u64));
    let mut target = sensor % n_models;
    loop {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let t = (now - start).as_secs_f64();
        let gap = match cfg.scenario {
            Scenario::Steady | Scenario::FanIn => 1.0 / per_sensor,
            Scenario::Bursty => {
                // 1.8x / 0.2x phases average to 1.0: the mean offered
                // rate stays rate_hz, comparable to steady at the same
                // --rate.
                let hot = ((t / BURST_PHASE_S) as u64) % 2 == 0;
                let rate = per_sensor * if hot { 1.8 } else { 0.2 };
                -rng.f64().max(1e-12).ln() / rate
            }
            Scenario::Ramp => {
                let rate = per_sensor * (0.1 + 1.9 * (t / total_s).min(1.0));
                1.0 / rate
            }
        };
        // Sleep the whole gap in deadline-responsive chunks: a single
        // capped sleep would silently inflate low offered rates (every
        // iteration would push after at most one chunk).
        let wake = now + Duration::from_secs_f64(gap);
        loop {
            let cur = Instant::now();
            if cur >= wake || cur >= deadline {
                break;
            }
            std::thread::sleep((wake - cur).min(MAX_SLEEP_CHUNK));
        }
        if Instant::now() >= deadline {
            break;
        }
        match cfg.scenario {
            Scenario::FanIn => {
                // One sensor window feeds every model: same random draw,
                // folded into each model's own sample space.
                let window = rng.next_u64();
                let enqueued = Instant::now();
                for (entry, queue) in entries.iter().zip(queues) {
                    let frame = Frame {
                        id: next_id.fetch_add(1, Ordering::Relaxed),
                        sample: (window % entry.test.len() as u64) as usize,
                        enqueued,
                    };
                    queue.push(frame);
                }
            }
            _ => {
                let entry = &entries[target];
                let frame = Frame {
                    id: next_id.fetch_add(1, Ordering::Relaxed),
                    sample: rng.usize_below(entry.test.len()),
                    enqueued: Instant::now(),
                };
                queues[target].push(frame);
                target = (target + 1) % n_models;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_labels_roundtrip() {
        for s in [Scenario::Steady, Scenario::Bursty, Scenario::Ramp, Scenario::FanIn] {
            assert_eq!(s.label().parse::<Scenario>().unwrap(), s);
        }
        assert_eq!("poisson".parse::<Scenario>().unwrap(), Scenario::Bursty);
        assert_eq!("fan-in".parse::<Scenario>().unwrap(), Scenario::FanIn);
        assert!("nosuch".parse::<Scenario>().is_err());
    }
}
