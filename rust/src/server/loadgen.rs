//! Scenario-driven load generation: sensor threads that turn a traffic
//! shape into [`Frame`]s pushed at the per-model [`BatchQueue`]s.
//!
//! Five shapes (`--scenario`):
//!
//! - `steady` — fixed inter-arrival at the offered rate, frames routed
//!   round-robin across models.  The zero-drama baseline: at the default
//!   rate nothing sheds and accuracy equals the direct evaluator's.
//! - `bursty` — Poisson arrivals (exponential gaps) modulated by an
//!   on/off square wave: 250 ms bursts at 1.8× the offered rate followed
//!   by 250 ms lulls at 0.2× (duty pair averages to 1.0, so the mean
//!   offered rate stays `rate_hz`).  Exercises queue growth and shedding.
//! - `ramp` — rate climbs linearly from 0.1× to 2× the offered rate over
//!   the run, so the server crosses its saturation point mid-run.
//! - `fanin` — the paper's multi-sensory story: each event is one frame
//!   *window* fanned out to **every** hosted model simultaneously (the
//!   wearable's shared sensor window feeding several bespoke
//!   classifiers).  `rate_hz` is the window rate, so each model sees the
//!   full rate.
//! - `trace` — replay a recorded arrival sequence ([`Trace`]): every
//!   request's arrival offset, target model, and sample draw come from
//!   the trace, so two runs over the same trace offer a bit-identical
//!   request stream (the fault campaign's load shape).  Without a trace
//!   file, [`Trace::synth_diurnal`] synthesizes a seed-deterministic
//!   diurnal day-curve — Poisson arrivals whose rate swings 0.2×–1.8×
//!   around the offered mean over the run.
//!
//! Each sensor thread owns a deterministic [`Rng`] seeded from
//! `seed ^ (0xC0FFEE + sensor)` (the offset keeps sensor 0 from sharing
//! the serve seed verbatim with other subsystems), so a serve run is
//! reproducible modulo OS scheduling; a trace replay additionally pins
//! the request *content* exactly.
//!
//! Every shape has two transports: [`run_sensor`] pushes frames straight
//! into the queues (in-process mode), and [`run_tcp_sensor`] drives the
//! same schedule through a real socket against the TCP
//! [`frontend`](crate::server::frontend).  The TCP client is **open
//! loop**: send instants are precomputed on an absolute schedule and
//! latency is measured from each frame's *scheduled* instant, not its
//! actual write — so server backpressure inflates the reported latency
//! instead of silently thinning the offered load
//! (coordinated-omission-correct, per Tene's "How NOT to Measure
//! Latency").

use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::Path;
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::server::batcher::{BatchQueue, Frame};
use crate::server::frontend::{self, Request, Status};
use crate::server::registry::ModelEntry;
use crate::server::ServeConfig;
use crate::util::prng::{fold_u64, Rng};

/// Traffic shape for a serve run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    Steady,
    Bursty,
    Ramp,
    FanIn,
    /// Replay a recorded/synthesized [`Trace`].
    Trace,
}

impl Scenario {
    /// Every scenario, in canonical order.  [`Scenario::wanted`] (and
    /// through it the `FromStr` error text) derives from this list, and
    /// the round-trip property test walks it — so the accepted set, the
    /// canonical labels, and the error message cannot drift apart.
    pub const ALL: [Scenario; 5] = [
        Scenario::Steady,
        Scenario::Bursty,
        Scenario::Ramp,
        Scenario::FanIn,
        Scenario::Trace,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Scenario::Steady => "steady",
            Scenario::Bursty => "bursty",
            Scenario::Ramp => "ramp",
            Scenario::FanIn => "fanin",
            Scenario::Trace => "trace",
        }
    }

    /// The `steady|bursty|…` list shown by the parse error and `--help`.
    pub fn wanted() -> String {
        Scenario::ALL.map(Scenario::label).join("|")
    }
}

impl std::fmt::Display for Scenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

impl FromStr for Scenario {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Scenario> {
        Ok(match s {
            "steady" => Scenario::Steady,
            "bursty" | "poisson" => Scenario::Bursty,
            "ramp" => Scenario::Ramp,
            "fanin" | "fan-in" => Scenario::FanIn,
            "trace" => Scenario::Trace,
            other => bail!("unknown scenario `{other}` (want {})", Scenario::wanted()),
        })
    }
}

/// Version line every trace artifact starts with.
const TRACE_HEADER: &str = "# printed-mlp trace v1";

/// A recorded arrival sequence: one entry per request, sorted by arrival
/// time.  Column-major so a multi-hour trace stays three flat vectors.
///
/// The sample index is stored as a raw `u64` *draw*, not a resolved row:
/// replaying the same trace against registries whose test splits differ
/// in length stays well-defined (the sensor folds the draw onto the
/// model's own sample space with the unbiased [`fold_u64`]).
///
/// The text artifact is deliberately trivial — a `#`-comment header then
/// `<arrival_us> <model> <draw>` per line — so traces can be produced by
/// anything that can print three integers.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    /// Arrival offset of each request in microseconds from run start
    /// (non-decreasing).
    pub arrivals_us: Vec<u64>,
    /// Target model index per request (folded onto the hosted model
    /// count at replay time, so a trace outlives registry changes).
    pub model: Vec<u32>,
    /// Raw 64-bit sample draw per request.
    pub draw: Vec<u64>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.arrivals_us.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals_us.is_empty()
    }

    /// Duration covered by the trace (arrival of the last request).
    pub fn span(&self) -> Duration {
        Duration::from_micros(self.arrivals_us.last().copied().unwrap_or(0))
    }

    /// Synthesize a seed-deterministic diurnal trace: an inhomogeneous
    /// Poisson process (by thinning) whose rate follows one cosine
    /// day-curve over the run — `λ(u) = rate·(0.2 + 0.8·(1 − cos 2πu))`
    /// for run fraction `u`, i.e. a 0.2× trough at the ends, a 1.8× peak
    /// mid-run, and a mean of exactly `rate_hz`.  Model targets and
    /// sample draws are drawn from the same seeded stream, so the whole
    /// request sequence is a pure function of the arguments.
    pub fn synth_diurnal(seed: u64, rate_hz: f64, duration: Duration, n_models: usize) -> Trace {
        let total_s = duration.as_secs_f64().max(1e-9);
        let rate = rate_hz.max(1e-6);
        let lam_max = rate * 1.8;
        let nm = n_models.max(1) as u64;
        let mut rng = Rng::new(seed ^ 0x7_2ACE);
        let mut tr = Trace::default();
        let mut t = 0.0f64;
        loop {
            // Thinning: candidate arrivals at the envelope rate, kept
            // with probability λ(t)/λ_max.
            t += -rng.f64().max(1e-12).ln() / lam_max;
            if t >= total_s {
                break;
            }
            let u = t / total_s;
            let lam = rate * (0.2 + 0.8 * (1.0 - (2.0 * std::f64::consts::PI * u).cos()));
            if rng.f64() * lam_max <= lam {
                tr.arrivals_us.push((t * 1e6) as u64);
                tr.model.push(rng.below(nm) as u32);
                tr.draw.push(rng.next_u64());
            }
        }
        tr
    }

    /// Render the text artifact (see [`Trace`] for the format).
    pub fn to_text(&self) -> String {
        let mut s = String::with_capacity(32 + self.len() * 32);
        s.push_str(TRACE_HEADER);
        s.push_str("\n# arrival_us model draw\n");
        for i in 0..self.len() {
            s.push_str(&format!(
                "{} {} {}\n",
                self.arrivals_us[i], self.model[i], self.draw[i]
            ));
        }
        s
    }

    /// Parse the text artifact; rejects a missing/foreign header,
    /// malformed lines, and out-of-order arrivals.
    pub fn parse(text: &str) -> Result<Trace> {
        let mut tr = Trace::default();
        let mut seen_header = false;
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            if !seen_header {
                ensure!(
                    line == TRACE_HEADER,
                    "trace line {}: expected `{TRACE_HEADER}`, got `{line}`",
                    ln + 1
                );
                seen_header = true;
                continue;
            }
            if line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let (a, m, d) = match (it.next(), it.next(), it.next(), it.next()) {
                (Some(a), Some(m), Some(d), None) => (a, m, d),
                _ => bail!("trace line {}: want `<arrival_us> <model> <draw>`", ln + 1),
            };
            let a: u64 = a
                .parse()
                .with_context(|| format!("trace line {}: bad arrival `{a}`", ln + 1))?;
            let m: u32 = m
                .parse()
                .with_context(|| format!("trace line {}: bad model `{m}`", ln + 1))?;
            let d: u64 = d
                .parse()
                .with_context(|| format!("trace line {}: bad draw `{d}`", ln + 1))?;
            if let Some(&prev) = tr.arrivals_us.last() {
                ensure!(
                    a >= prev,
                    "trace line {}: arrivals must be non-decreasing ({a} after {prev})",
                    ln + 1
                );
            }
            tr.arrivals_us.push(a);
            tr.model.push(m);
            tr.draw.push(d);
        }
        ensure!(seen_header, "trace: empty input (missing `{TRACE_HEADER}`)");
        Ok(tr)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_text())
            .with_context(|| format!("writing trace {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        Trace::parse(&text).with_context(|| format!("parsing trace {}", path.display()))
    }
}

/// Burst phase length for the `bursty` scenario.
const BURST_PHASE_S: f64 = 0.25;
/// Longest single sleep *chunk*; keeps sensors responsive to the
/// deadline without flooring long inter-arrival gaps (the full gap is
/// always slept, in chunks of at most this).
const MAX_SLEEP_CHUNK: Duration = Duration::from_millis(50);
/// How long a TCP sensor keeps reading after its schedule ends, waiting
/// for answers still owed; accepted frames unanswered past this are
/// counted in [`ClientStats::lost`].
const DRAIN_GRACE: Duration = Duration::from_secs(5);

/// Inter-arrival gap (seconds) the scenario dictates at run-time `t` —
/// the one schedule shared by the in-process and TCP transports.
fn scenario_gap(scenario: Scenario, t: f64, per_sensor: f64, total_s: f64, rng: &mut Rng) -> f64 {
    match scenario {
        Scenario::Steady | Scenario::FanIn | Scenario::Trace => 1.0 / per_sensor,
        Scenario::Bursty => {
            // 1.8x / 0.2x phases average to 1.0: the mean offered rate
            // stays rate_hz, comparable to steady at the same --rate.
            let hot = ((t / BURST_PHASE_S) as u64) % 2 == 0;
            let rate = per_sensor * if hot { 1.8 } else { 0.2 };
            -rng.f64().max(1e-12).ln() / rate
        }
        Scenario::Ramp => {
            let rate = per_sensor * (0.1 + 1.9 * (t / total_s).min(1.0));
            1.0 / rate
        }
    }
}

/// One sensor thread's generation loop: compute the scenario's current
/// inter-arrival gap, sleep it, and push the next frame(s).  All
/// offered/accepted/shed accounting lives in each queue's
/// [`crate::server::ModelStats`].
///
/// With a [`Trace`] the sensors stride-partition its entries (sensor `s`
/// replays entries `s, s+sensors, …`) and replay **every** entry — the
/// wall deadline does not cut a replay short, so the offered request
/// stream is a pure function of the trace.
#[allow(clippy::too_many_arguments)]
pub fn run_sensor(
    sensor: usize,
    entries: &[Arc<ModelEntry>],
    queues: &[BatchQueue],
    cfg: &ServeConfig,
    start: Instant,
    deadline: Instant,
    next_id: &AtomicU64,
    trace: Option<&Trace>,
) {
    if let Some(tr) = trace {
        return run_trace_sensor(sensor, entries, queues, cfg, start, next_id, tr);
    }
    let n_models = entries.len();
    let sensors = cfg.sensors.max(1) as f64;
    let per_sensor = (cfg.rate_hz / sensors).max(1e-6);
    let total_s = cfg.duration.as_secs_f64().max(1e-9);
    let mut rng = Rng::new(cfg.seed ^ (0xC0FFEE + sensor as u64));
    let mut target = sensor % n_models;
    loop {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let t = (now - start).as_secs_f64();
        let gap = scenario_gap(cfg.scenario, t, per_sensor, total_s, &mut rng);
        // Sleep the whole gap in deadline-responsive chunks: a single
        // capped sleep would silently inflate low offered rates (every
        // iteration would push after at most one chunk).
        let wake = now + Duration::from_secs_f64(gap);
        loop {
            let cur = Instant::now();
            if cur >= wake || cur >= deadline {
                break;
            }
            std::thread::sleep((wake - cur).min(MAX_SLEEP_CHUNK));
        }
        if Instant::now() >= deadline {
            break;
        }
        match cfg.scenario {
            Scenario::FanIn => {
                // One sensor window feeds every model: same random draw,
                // folded into each model's own sample space with the
                // unbiased multiply-high fold (a plain `window % len`
                // over-weights low sample indices whenever the split
                // length does not divide 2^64).
                let window = rng.next_u64();
                let enqueued = Instant::now();
                for (entry, queue) in entries.iter().zip(queues) {
                    let frame = Frame::at(
                        next_id.fetch_add(1, Ordering::Relaxed),
                        fold_u64(window, entry.test.len() as u64) as usize,
                        enqueued,
                    );
                    queue.push(frame);
                }
            }
            _ => {
                let entry = &entries[target];
                let frame = Frame::new(
                    next_id.fetch_add(1, Ordering::Relaxed),
                    rng.usize_below(entry.test.len()),
                );
                queues[target].push(frame);
                target = (target + 1) % n_models;
            }
        }
    }
}

/// Trace replay: sensor `s` replays entries `s, s+sensors, …` at their
/// recorded arrival offsets, every entry exactly once.
fn run_trace_sensor(
    sensor: usize,
    entries: &[Arc<ModelEntry>],
    queues: &[BatchQueue],
    cfg: &ServeConfig,
    start: Instant,
    next_id: &AtomicU64,
    tr: &Trace,
) {
    let n_models = entries.len();
    let sensors = cfg.sensors.max(1);
    let mut i = sensor;
    while i < tr.len() {
        let wake = start + Duration::from_micros(tr.arrivals_us[i]);
        loop {
            let cur = Instant::now();
            if cur >= wake {
                break;
            }
            std::thread::sleep((wake - cur).min(MAX_SLEEP_CHUNK));
        }
        let m = tr.model[i] as usize % n_models;
        let entry = &entries[m];
        queues[m].push(Frame::new(
            next_id.fetch_add(1, Ordering::Relaxed),
            fold_u64(tr.draw[i], entry.test.len() as u64) as usize,
        ));
        i += sensors;
    }
}

/// Client-side accounting for one hosted model over a TCP serve run.
///
/// Latency is open-loop — measured from each frame's *scheduled* send
/// instant — and accuracy is scored against the sensor's own snapshot of
/// the test split, so the numbers survive a mid-run hot reload on the
/// server.  `lost` counts accepted-side frames the client sent but never
/// got an answer for within [`DRAIN_GRACE`]; a healthy run reports zero.
#[derive(Clone, Debug, Default)]
pub struct ClientStats {
    pub sent: usize,
    pub ok: usize,
    pub shed: usize,
    pub late: usize,
    pub refused: usize,
    pub errors: usize,
    /// `ok` responses whose prediction matched the snapshot label.
    pub correct: usize,
    /// Sent frames never answered within the drain grace.
    pub lost: usize,
    /// Per-`ok`-frame latency from scheduled send to response decode.
    pub latencies_ms: Vec<f64>,
}

impl ClientStats {
    /// Frames that received *some* response — the client half of the
    /// exactly-once ledger (`sent == answered() + lost` after drain).
    pub fn answered(&self) -> usize {
        self.ok + self.shed + self.late + self.refused + self.errors
    }

    /// Fold another sensor's counters into this one.
    pub fn merge(&mut self, other: ClientStats) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.shed += other.shed;
        self.late += other.late;
        self.refused += other.refused;
        self.errors += other.errors;
        self.correct += other.correct;
        self.lost += other.lost;
        self.latencies_ms.extend(other.latencies_ms);
    }
}

/// One sensor's non-blocking connection to the frontend: outgoing frames
/// are written with spin-and-pump backpressure handling, responses are
/// matched back to in-flight requests by id.
struct TcpClient {
    stream: TcpStream,
    rxbuf: Vec<u8>,
    /// In-flight request id → (model, sample, scheduled send instant).
    pending: HashMap<u64, (usize, usize, Instant)>,
    server_closed: bool,
}

impl TcpClient {
    fn new(stream: TcpStream) -> TcpClient {
        TcpClient {
            stream,
            rxbuf: Vec::new(),
            pending: HashMap::new(),
            server_closed: false,
        }
    }

    /// Drain whatever the server has written so far; settle pending
    /// requests into `stats`.  A connection-level read failure marks the
    /// server closed (leftovers become `lost`); a *protocol* failure —
    /// unframeable bytes or a bad response — is a hard error.
    fn pump(&mut self, entries: &[Arc<ModelEntry>], stats: &mut [ClientStats]) -> Result<()> {
        let mut chunk = [0u8; 4096];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.server_closed = true;
                    break;
                }
                Ok(n) => self.rxbuf.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.server_closed = true;
                    break;
                }
            }
        }
        while let Some(payload) = frontend::split_frame(&mut self.rxbuf)
            .context("tcp client: unframeable response bytes")?
        {
            let resp = frontend::decode_response(&payload).context("tcp client: bad response")?;
            let done = Instant::now();
            if let Some((m, sample, sched)) = self.pending.remove(&resp.id) {
                let st = &mut stats[m];
                match resp.status {
                    Status::Ok => {
                        st.ok += 1;
                        st.latencies_ms
                            .push(done.duration_since(sched).as_secs_f64() * 1e3);
                        if entries[m].test.ys.get(sample).map(|&y| y as i32) == Some(resp.pred) {
                            st.correct += 1;
                        }
                    }
                    Status::Shed => st.shed += 1,
                    Status::Late => st.late += 1,
                    Status::Refused => st.refused += 1,
                    Status::Error => st.errors += 1,
                }
            }
        }
        Ok(())
    }

    /// Write one request frame, pumping responses whenever the socket
    /// pushes back (the frontend stops reading a connection at its
    /// in-flight bound, so draining answers *is* the flow control).
    #[allow(clippy::too_many_arguments)]
    fn send(
        &mut self,
        id: u64,
        m: usize,
        sample: usize,
        sched: Instant,
        entries: &[Arc<ModelEntry>],
        stats: &mut [ClientStats],
        hard_stop: Instant,
    ) -> Result<()> {
        if self.server_closed {
            return Ok(());
        }
        let req = Request {
            model: m as u16,
            id,
            features: entries[m].test.row(sample).to_vec(),
        };
        let bytes = frontend::encode_request(&req);
        stats[m].sent += 1;
        self.pending.insert(id, (m, sample, sched));
        let mut off = 0;
        while off < bytes.len() {
            match self.stream.write(&bytes[off..]) {
                Ok(0) => {
                    self.server_closed = true;
                    break;
                }
                Ok(n) => off += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    self.pump(entries, stats)?;
                    ensure!(
                        Instant::now() < hard_stop,
                        "tcp client write stalled past drain grace"
                    );
                    std::thread::sleep(Duration::from_micros(50));
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.server_closed = true;
                    break;
                }
            }
        }
        Ok(())
    }

    /// Sleep until `wake`, pumping responses in short chunks so latency
    /// timestamps stay honest and the receive path never backs up.
    fn sleep_until_pumping(
        &mut self,
        wake: Instant,
        entries: &[Arc<ModelEntry>],
        stats: &mut [ClientStats],
    ) -> Result<()> {
        loop {
            self.pump(entries, stats)?;
            let now = Instant::now();
            if now >= wake || self.server_closed {
                return Ok(());
            }
            std::thread::sleep((wake - now).min(Duration::from_millis(1)));
        }
    }
}

/// TCP twin of [`run_sensor`]: the same scenario schedule driven through
/// a real socket, open loop.  Send instants are precomputed on an
/// absolute timeline (`sched_t` accumulates scenario gaps from run
/// start), so server backpressure delays the *write* but never the
/// *schedule* — queueing shows up as latency, not as a thinner offered
/// load.  Returns per-model [`ClientStats`]; the sensor only returns
/// once every sent frame is answered or charged `lost` (bounded by
/// [`DRAIN_GRACE`]).
pub fn run_tcp_sensor(
    sensor: usize,
    entries: &[Arc<ModelEntry>],
    addr: SocketAddr,
    cfg: &ServeConfig,
    start: Instant,
    deadline: Instant,
    trace: Option<&Trace>,
) -> Result<Vec<ClientStats>> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("tcp sensor {sensor}: connecting {addr}"))?;
    stream.set_nodelay(true).ok();
    stream
        .set_nonblocking(true)
        .with_context(|| format!("tcp sensor {sensor}: set_nonblocking"))?;
    let mut client = TcpClient::new(stream);
    let mut stats = vec![ClientStats::default(); entries.len()];
    let n_models = entries.len();
    let hard_stop = deadline + DRAIN_GRACE;
    // Ids are globally unique without cross-sensor coordination: the
    // sensor index rides in the top 16 bits.
    let mut seq: u64 = 0;
    if let Some(tr) = trace {
        let sensors = cfg.sensors.max(1);
        let mut i = sensor;
        while i < tr.len() && !client.server_closed {
            let sched = start + Duration::from_micros(tr.arrivals_us[i]);
            client.sleep_until_pumping(sched, entries, &mut stats)?;
            let m = tr.model[i] as usize % n_models;
            let sample = fold_u64(tr.draw[i], entries[m].test.len() as u64) as usize;
            let id = ((sensor as u64) << 48) | seq;
            seq += 1;
            client.send(id, m, sample, sched, entries, &mut stats, hard_stop)?;
            i += sensors;
        }
    } else {
        let sensors = cfg.sensors.max(1) as f64;
        let per_sensor = (cfg.rate_hz / sensors).max(1e-6);
        let total_s = cfg.duration.as_secs_f64().max(1e-9);
        let mut rng = Rng::new(cfg.seed ^ (0xC0FFEE + sensor as u64));
        let mut target = sensor % n_models;
        let mut sched_t = 0.0f64;
        loop {
            sched_t += scenario_gap(cfg.scenario, sched_t, per_sensor, total_s, &mut rng);
            if sched_t >= total_s || client.server_closed {
                break;
            }
            let sched = start + Duration::from_secs_f64(sched_t);
            client.sleep_until_pumping(sched, entries, &mut stats)?;
            match cfg.scenario {
                Scenario::FanIn => {
                    let window = rng.next_u64();
                    for m in 0..n_models {
                        let sample = fold_u64(window, entries[m].test.len() as u64) as usize;
                        let id = ((sensor as u64) << 48) | seq;
                        seq += 1;
                        client.send(id, m, sample, sched, entries, &mut stats, hard_stop)?;
                    }
                }
                _ => {
                    let sample = rng.usize_below(entries[target].test.len());
                    let id = ((sensor as u64) << 48) | seq;
                    seq += 1;
                    client.send(id, target, sample, sched, entries, &mut stats, hard_stop)?;
                    target = (target + 1) % n_models;
                }
            }
        }
    }
    // Drain: wait (bounded) for every in-flight answer, then charge the
    // remainder as lost.  The server's graceful drain answers everything
    // it accepted, so `lost` stays zero unless something actually broke.
    while !client.pending.is_empty() && !client.server_closed && Instant::now() < hard_stop {
        client.pump(entries, &mut stats)?;
        std::thread::sleep(Duration::from_micros(200));
    }
    client.pump(entries, &mut stats)?;
    for &(m, _, _) in client.pending.values() {
        stats[m].lost += 1;
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_labels_roundtrip() {
        // Canonical labels round-trip through Display and FromStr.
        for s in Scenario::ALL {
            assert_eq!(s.label().parse::<Scenario>().unwrap(), s);
            assert_eq!(s.to_string(), s.label());
        }
        // Aliases parse but are not canonical.
        assert_eq!("poisson".parse::<Scenario>().unwrap(), Scenario::Bursty);
        assert_eq!("fan-in".parse::<Scenario>().unwrap(), Scenario::FanIn);
        // The error text lists exactly the canonical set.
        let err = "nosuch".parse::<Scenario>().unwrap_err().to_string();
        assert_eq!(
            err,
            format!("unknown scenario `nosuch` (want {})", Scenario::wanted())
        );
        assert_eq!(Scenario::wanted(), "steady|bursty|ramp|fanin|trace");
    }

    #[test]
    fn scenario_parse_display_roundtrip_property() {
        // Property: for ANY input string, parsing either fails with the
        // canonical want-list in the message, or succeeds on a value
        // whose Display re-parses to itself (parse ∘ display = id).
        crate::util::propcheck::check("scenario_roundtrip", 300, |g| {
            let pick = g.usize_in(0..=9);
            let s = if pick < Scenario::ALL.len() {
                Scenario::ALL[pick].label().to_string()
            } else {
                // Near-miss soup over the labels' own alphabet, so typos
                // and truncations (`stead`, `fanin-`) get exercised.
                let alphabet = b"abdefinprsty- ";
                (0..g.usize_in(0..=8))
                    .map(|_| alphabet[g.usize_in(0..=alphabet.len() - 1)] as char)
                    .collect()
            };
            match s.parse::<Scenario>() {
                Ok(sc) => sc.to_string().parse::<Scenario>().map(|x| x == sc).unwrap_or(false),
                Err(e) => e
                    .to_string()
                    .ends_with(&format!("(want {})", Scenario::wanted())),
            }
        });
    }

    #[test]
    fn synth_diurnal_is_deterministic_sorted_and_rate_shaped() {
        let tr = Trace::synth_diurnal(9, 2000.0, Duration::from_secs(2), 3);
        assert_eq!(tr, Trace::synth_diurnal(9, 2000.0, Duration::from_secs(2), 3));
        assert_ne!(tr, Trace::synth_diurnal(10, 2000.0, Duration::from_secs(2), 3));
        assert!(!tr.is_empty());
        assert!(tr.arrivals_us.windows(2).all(|w| w[0] <= w[1]), "sorted");
        assert!(tr.span() <= Duration::from_secs(2));
        assert!(tr.model.iter().all(|&m| m < 3));
        // Mean rate ≈ rate_hz: 2 s at 2000 Hz ⇒ ~4000 requests.
        let n = tr.len() as f64;
        assert!((2800.0..5200.0).contains(&n), "count {n}");
        // Diurnal shape: the mid-run half must carry well more traffic
        // than the trough halves combined would at a flat rate.
        let mid = tr
            .arrivals_us
            .iter()
            .filter(|&&a| (500_000..1_500_000).contains(&a))
            .count() as f64;
        assert!(mid / n > 0.6, "mid-run fraction {}", mid / n);
    }

    #[test]
    fn trace_text_roundtrip_and_rejects_garbage() {
        let tr = Trace::synth_diurnal(4, 300.0, Duration::from_millis(500), 2);
        let text = tr.to_text();
        assert!(text.starts_with(TRACE_HEADER));
        assert_eq!(Trace::parse(&text).unwrap(), tr);
        // Missing header / malformed lines / unsorted arrivals all fail.
        assert!(Trace::parse("1 0 2\n").is_err());
        assert!(Trace::parse("").is_err());
        assert!(Trace::parse(&format!("{TRACE_HEADER}\n1 2\n")).is_err());
        assert!(Trace::parse(&format!("{TRACE_HEADER}\n1 0 2 9\n")).is_err());
        assert!(Trace::parse(&format!("{TRACE_HEADER}\nx 0 2\n")).is_err());
        assert!(Trace::parse(&format!("{TRACE_HEADER}\n5 0 2\n3 0 2\n")).is_err());
        // Comments and blank lines after the header are fine.
        let ok = Trace::parse(&format!("{TRACE_HEADER}\n# c\n\n3 1 7\n3 0 9\n")).unwrap();
        assert_eq!(ok.len(), 2);
        assert_eq!(ok.model, vec![1, 0]);
    }

    #[test]
    fn trace_load_reports_file_and_line_context() {
        let path = std::env::temp_dir().join(format!("pmlp_trace_corrupt_{}.txt", std::process::id()));
        std::fs::write(&path, format!("{TRACE_HEADER}\n1 0 2\nbogus 0 2\n")).unwrap();
        let err = Trace::load(&path).unwrap_err();
        let chain = format!("{err:#}");
        assert!(
            chain.contains(&path.display().to_string()),
            "error should name the file: {chain}"
        );
        assert!(chain.contains("trace line 3"), "error should cite the line: {chain}");
        let _ = std::fs::remove_file(&path);
        assert!(Trace::load(Path::new("/nonexistent/pmlp/trace.txt")).is_err());
    }

    #[test]
    fn client_stats_merge_sums_counters_and_latencies() {
        let mut a = ClientStats {
            sent: 5,
            ok: 3,
            shed: 1,
            correct: 2,
            lost: 1,
            latencies_ms: vec![1.0, 2.0],
            ..ClientStats::default()
        };
        let b = ClientStats {
            sent: 4,
            ok: 2,
            late: 1,
            refused: 1,
            correct: 1,
            latencies_ms: vec![3.0],
            ..ClientStats::default()
        };
        assert_eq!(b.answered(), 4);
        a.merge(b);
        assert_eq!(a.sent, 9);
        assert_eq!(a.answered(), 8);
        assert_eq!(a.correct, 3);
        assert_eq!(a.lost, 1);
        assert_eq!(a.latencies_ms, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn scenario_gap_matches_shapes() {
        let mut rng = Rng::new(7);
        assert!((scenario_gap(Scenario::Steady, 0.3, 100.0, 1.0, &mut rng) - 0.01).abs() < 1e-12);
        // Ramp: 0.1x the offered rate at t=0, 2x at the end of the run.
        assert!((scenario_gap(Scenario::Ramp, 0.0, 100.0, 1.0, &mut rng) - 0.1).abs() < 1e-12);
        assert!((scenario_gap(Scenario::Ramp, 1.0, 100.0, 1.0, &mut rng) - 0.005).abs() < 1e-12);
        assert!(scenario_gap(Scenario::Bursty, 0.0, 100.0, 1.0, &mut rng) > 0.0);
    }
}
