//! Per-tenant SLO classes and the admission policy that sheds overload
//! in class order.
//!
//! Every hosted model (one tenant in the paper's multi-sensory story) is
//! assigned an [`SloClass`] — `gold`, `silver`, or `bronze` — via
//! `ServeConfig::classes` / the `serve.classes` config key / `--classes`.
//! The class buys two things on the request path:
//!
//! - **Admission depth** ([`SloClass::admit_limit`]): each class may only
//!   fill a fraction of the shared per-model queue capacity before its
//!   pushes shed (gold 100%, silver 75%, bronze 50%).  Under overload the
//!   shallow bronze queues hit their ceiling first, so bronze sheds
//!   first and bronze queueing delay is bounded at half the gold depth.
//! - **Drain priority** ([`drain_order`]): batcher workers sweep the
//!   model queues in class-rank order, so when the pool is saturated the
//!   gold queues are served first each sweep and gold tail latency stays
//!   inside its SLO while bronze absorbs the backlog.
//!
//! Both effects are pure bookkeeping — no frame is ever reordered within
//! a model's FIFO, and an unclassified model defaults to gold, which
//! reproduces the pre-admission behavior exactly (full queue depth,
//! registry drain order).

use std::str::FromStr;

use anyhow::{bail, ensure, Result};

/// Per-tenant service class, best first.  `Ord` follows priority:
/// `Gold < Silver < Bronze` sorts gold-first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SloClass {
    Gold,
    Silver,
    Bronze,
}

/// Every class, in drain-priority order (the report iterates this).
pub const CLASS_ORDER: [SloClass; 3] = [SloClass::Gold, SloClass::Silver, SloClass::Bronze];

impl SloClass {
    pub fn label(self) -> &'static str {
        match self {
            SloClass::Gold => "gold",
            SloClass::Silver => "silver",
            SloClass::Bronze => "bronze",
        }
    }

    /// Drain priority rank: lower drains first.
    pub fn rank(self) -> usize {
        match self {
            SloClass::Gold => 0,
            SloClass::Silver => 1,
            SloClass::Bronze => 2,
        }
    }

    /// Fraction of the shared queue capacity this class may occupy
    /// before admission sheds its pushes.
    pub fn admit_frac(self) -> f64 {
        match self {
            SloClass::Gold => 1.0,
            SloClass::Silver => 0.75,
            SloClass::Bronze => 0.5,
        }
    }

    /// Admission ceiling for a queue of `queue_cap` total slots: the
    /// class fraction of the capacity, floored, but never below one slot
    /// (a tenant that can never enqueue is a config bug, not a policy).
    pub fn admit_limit(self, queue_cap: usize) -> usize {
        let cap = queue_cap.max(1);
        (((cap as f64) * self.admit_frac()).floor() as usize).clamp(1, cap)
    }
}

impl FromStr for SloClass {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<SloClass> {
        Ok(match s {
            "gold" | "g" => SloClass::Gold,
            "silver" | "s" => SloClass::Silver,
            "bronze" | "b" => SloClass::Bronze,
            other => bail!("unknown SLO class `{other}` (want gold|silver|bronze)"),
        })
    }
}

/// Parse a `--classes`-style comma list (`gold,silver,bronze`).  Entries
/// align positionally with the dataset list; a shorter list leaves the
/// tail models gold ([`class_of`]).
pub fn parse_classes(s: &str) -> Result<Vec<SloClass>> {
    let classes: Vec<SloClass> = s
        .split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(|p| p.parse())
        .collect::<Result<_>>()?;
    ensure!(!classes.is_empty(), "SLO classes: empty list");
    Ok(classes)
}

/// Class of model index `i` under a configured class list: positional,
/// with models past the end of the list defaulting to gold (so an empty
/// list reproduces the classless server exactly).
pub fn class_of(classes: &[SloClass], i: usize) -> SloClass {
    classes.get(i).copied().unwrap_or(SloClass::Gold)
}

/// Priority drain order over `classes`: model indices sorted gold-first,
/// stably, so same-class models keep their registry order and the
/// workers' round-robin fairness within a class is preserved.
pub fn drain_order(classes: &[SloClass]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..classes.len()).collect();
    order.sort_by_key(|&i| classes[i].rank());
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_roundtrip_and_rank_orders_gold_first() {
        for c in CLASS_ORDER {
            assert_eq!(c.label().parse::<SloClass>().unwrap(), c);
        }
        assert_eq!("g".parse::<SloClass>().unwrap(), SloClass::Gold);
        assert!("platinum".parse::<SloClass>().is_err());
        assert!(SloClass::Gold.rank() < SloClass::Silver.rank());
        assert!(SloClass::Silver.rank() < SloClass::Bronze.rank());
        assert!(SloClass::Gold < SloClass::Bronze, "Ord follows priority");
    }

    #[test]
    fn admit_limits_shed_bronze_first() {
        assert_eq!(SloClass::Gold.admit_limit(32), 32);
        assert_eq!(SloClass::Silver.admit_limit(32), 24);
        assert_eq!(SloClass::Bronze.admit_limit(32), 16);
        // Never zero, never above the capacity.
        assert_eq!(SloClass::Bronze.admit_limit(1), 1);
        assert_eq!(SloClass::Gold.admit_limit(0), 1);
    }

    #[test]
    fn parse_classes_and_positional_defaults() {
        let cs = parse_classes("gold, bronze ,silver").unwrap();
        assert_eq!(cs, vec![SloClass::Gold, SloClass::Bronze, SloClass::Silver]);
        assert!(parse_classes("").is_err());
        assert!(parse_classes("gold,chrome").is_err());
        // Past-the-end models default to gold.
        assert_eq!(class_of(&cs, 1), SloClass::Bronze);
        assert_eq!(class_of(&cs, 7), SloClass::Gold);
        assert_eq!(class_of(&[], 0), SloClass::Gold);
    }

    #[test]
    fn drain_order_is_gold_first_and_stable() {
        use SloClass::*;
        let order = drain_order(&[Bronze, Gold, Silver, Gold, Bronze]);
        assert_eq!(order, vec![1, 3, 2, 0, 4]);
        assert_eq!(drain_order(&[]), Vec::<usize>::new());
    }
}
