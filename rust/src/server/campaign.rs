//! Printed-hardware fault campaign: sweep stuck-at / transient fault
//! levels across circuit architectures and measure what breaks.
//!
//! Printed (electrolyte-gated) circuits fail very differently from
//! silicon — shorted crossbars weld nets to a rail (stuck-at) and the
//! low-temperature substrate makes transient upsets routine — so the
//! paper's area/accuracy trade-off is only half the deployment story.
//! The campaign answers the other half: *how much accuracy and SLO
//! headroom does each architecture give up per injected fault?*
//!
//! For every `(architecture, fault level)` cell the driver:
//!
//! 1. builds one fault-capable [`GateSimEvaluator`] per hosted model
//!    ([`ArchKind::Ours`] → multi-cycle sequential, [`ArchKind::Hybrid`]
//!    → sequential with the demo approximation mask,
//!    [`ArchKind::Comb`] → the combinational baseline);
//! 2. samples a reproducible [`FaultList`] over the model's own circuit
//!    (stuck + transient counts from the level, nets drawn from
//!    [`fault::default_roles`], seeded per cell so cells are
//!    independent but re-runs identical);
//! 3. scores **accuracy degradation** with two deterministic full-split
//!    passes (clean vs faulted — no serving noise in the accuracy
//!    column);
//! 4. replays the serve path ([`serve_with`]) under the faulted
//!    evaluators for the **SLO impact** columns.
//!
//! The zero-fault level `(0, 0)` is the campaign's self-check: its
//! evaluators carry no faults, so its accuracy and predictions must be
//! bit-identical to a plain serve run (`tests/fault_injection.rs`).

use std::str::FromStr;
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::approx;
use crate::data::ArtifactStore;
use crate::runtime::{Evaluator, GateArch, GateSimEvaluator};
use crate::server::admission::class_of;
use crate::server::registry::{ModelEntry, ModelRegistry, ModelSlot};
use crate::server::{serve_with, ModelReport, Scenario, ServeConfig, ServerReport};
use crate::sim::fault::{self, FaultList};

/// Architecture variants the campaign sweeps (the paper's Fig. 6 cast,
/// minus SOTA which shares the sequential fault surface with `ours`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArchKind {
    /// The paper's multi-cycle sequential circuit.
    Ours,
    /// Sequential with the alternate-neuron demo approximation
    /// ([`approx::demo_hybrid_mask`]).
    Hybrid,
    /// Fully-parallel combinational baseline.
    Comb,
}

impl ArchKind {
    pub fn label(self) -> &'static str {
        match self {
            ArchKind::Ours => "ours",
            ArchKind::Hybrid => "hybrid",
            ArchKind::Comb => "comb",
        }
    }

    /// The gate architecture the evaluator generates for this variant.
    pub fn gate_arch(self) -> GateArch {
        match self {
            ArchKind::Ours | ArchKind::Hybrid => GateArch::Sequential,
            ArchKind::Comb => GateArch::Combinational,
        }
    }
}

impl FromStr for ArchKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<ArchKind> {
        Ok(match s {
            "ours" | "seq" | "multicycle" => ArchKind::Ours,
            "hybrid" => ArchKind::Hybrid,
            "comb" | "combinational" => ArchKind::Comb,
            other => bail!("unknown campaign arch `{other}` (want ours|hybrid|comb)"),
        })
    }
}

/// Parse a `stuck:transient[,stuck:transient...]` fault-level list
/// (the `--fault-levels` flag / `campaign.levels` config key).
pub fn parse_levels(s: &str) -> Result<Vec<(usize, usize)>> {
    let mut levels = Vec::new();
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let Some((a, b)) = part.split_once(':') else {
            bail!("fault level `{part}`: want `<stuck>:<transient>`");
        };
        let stuck = a.trim().parse::<usize>().map_err(|_| {
            anyhow::anyhow!("fault level `{part}`: bad stuck count `{a}`")
        })?;
        let transient = b.trim().parse::<usize>().map_err(|_| {
            anyhow::anyhow!("fault level `{part}`: bad transient count `{b}`")
        })?;
        levels.push((stuck, transient));
    }
    ensure!(!levels.is_empty(), "fault levels: empty list");
    Ok(levels)
}

/// Campaign configuration: a base serve shape plus the fault sweep.
#[derive(Clone, Debug)]
pub struct CampaignConfig {
    /// Load shape, hosted models, and serve knobs for the SLO columns.
    /// The backend field is ignored — the campaign always builds gatesim
    /// evaluators (faults only exist at gate level).
    pub serve: ServeConfig,
    pub archs: Vec<ArchKind>,
    /// `(stuck, transient)` fault counts per sweep level.
    pub levels: Vec<(usize, usize)>,
    /// Per-bit flip probability for transient faults.
    pub flip_rate: f64,
    /// Base seed for fault sampling and transient masks; each
    /// `(arch, level, model)` cell derives its own seed from it.
    pub fault_seed: u64,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        CampaignConfig {
            serve: ServeConfig::default(),
            archs: vec![ArchKind::Ours, ArchKind::Hybrid, ArchKind::Comb],
            levels: vec![(0, 0), (4, 0), (16, 0), (4, 4)],
            flip_rate: 1e-3,
            fault_seed: 0xFA171,
        }
    }
}

/// One `(architecture, fault level, model)` cell of the sweep.
#[derive(Clone, Debug)]
pub struct CampaignRow {
    pub arch: ArchKind,
    pub model: String,
    /// Stuck-at / transient faults actually injected (sampling clips to
    /// the circuit's candidate pool).
    pub stuck: usize,
    pub transient: usize,
    pub flip_rate: f64,
    /// Deterministic full-test-split accuracy, clean circuit.
    pub baseline_accuracy: f64,
    /// Deterministic full-test-split accuracy under the fault list.
    pub fault_accuracy: f64,
    /// `baseline_accuracy - fault_accuracy` (positive = faults hurt).
    pub degradation: f64,
    /// Serve-path report under the same faulted evaluators (SLO impact).
    pub serve: ModelReport,
}

/// Full sweep result, rows in `archs × levels × models` order.
#[derive(Clone, Debug)]
pub struct CampaignReport {
    pub scenario: Scenario,
    pub rows: Vec<CampaignRow>,
}

/// Seed for one sweep cell: independent across cells, stable across
/// runs, and keyed on the level *contents* so reordering the level list
/// does not reshuffle every cell's faults.
fn cell_seed(base: u64, arch: ArchKind, stuck: usize, transient: usize, model_idx: usize) -> u64 {
    base ^ ((arch as u64 + 1) << 56)
        ^ ((stuck as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        ^ ((transient as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
        ^ ((model_idx as u64) << 40)
}

/// Re-host a registry under the campaign architecture: `hybrid` swaps
/// every entry's approximation mask/tables for the demo hybrid lowering
/// (tables built from the entry's own test frames — the campaign must
/// stay artifact-free under `--synthetic`); the others serve the entries
/// unchanged.
fn arch_registry(base: &ModelRegistry, arch: ArchKind) -> ModelRegistry {
    let mut reg = ModelRegistry::new();
    for entry in base.entries() {
        let e = match arch {
            ArchKind::Hybrid => {
                let tables = approx::build_tables(
                    &entry.model,
                    &entry.test.xs,
                    entry.test.len(),
                    &entry.feat_mask,
                );
                ModelEntry {
                    name: entry.name.clone(),
                    model: entry.model.clone(),
                    test: entry.test.clone(),
                    feat_mask: entry.feat_mask.clone(),
                    approx_mask: approx::demo_hybrid_mask(entry.model.hidden),
                    tables,
                }
            }
            ArchKind::Ours | ArchKind::Comb => (**entry).clone(),
        };
        reg.insert(e);
    }
    reg
}

/// Run the sweep.  `store` is only touched when the serve config is not
/// `--synthetic`.
pub fn run_campaign(store: &ArtifactStore, cfg: &CampaignConfig) -> Result<CampaignReport> {
    ensure!(!cfg.serve.datasets.is_empty(), "campaign: no datasets requested");
    ensure!(!cfg.archs.is_empty(), "campaign: no architectures requested");
    ensure!(!cfg.levels.is_empty(), "campaign: no fault levels requested");
    ensure!(
        (0.0..=1.0).contains(&cfg.flip_rate),
        "campaign: flip rate {} outside [0, 1]",
        cfg.flip_rate
    );
    let base = if cfg.serve.synthetic {
        ModelRegistry::synthetic(&cfg.serve.datasets, cfg.serve.seed)
    } else {
        ModelRegistry::from_store(store, &cfg.serve.datasets)?
    };
    let roles = fault::default_roles();
    let mut rows = Vec::new();
    for &arch in &cfg.archs {
        let registry = arch_registry(&base, arch);
        for &(n_stuck, n_transient) in &cfg.levels {
            // Per-model fault-capable evaluators plus the two
            // deterministic accuracy passes (clean, faulted).  Each
            // evaluator is hosted in a ModelSlot so the campaign rides
            // the same serve path as production (classes included);
            // nothing reloads mid-cell, so version stays 1.
            let mut slots: Vec<Arc<ModelSlot>> = Vec::new();
            let mut meta = Vec::new();
            for (mi, entry) in registry.entries().iter().enumerate() {
                let mut ev = GateSimEvaluator::with_opts(&entry.model, 1, cfg.serve.sim_lanes)
                    .with_arch(arch.gate_arch());
                let baseline = ev.accuracy(
                    &entry.test,
                    &entry.feat_mask,
                    &entry.approx_mask,
                    &entry.tables,
                )?;
                let fl = ev.sample_faults(
                    &entry.feat_mask,
                    &entry.approx_mask,
                    &entry.tables,
                    &roles,
                    n_stuck,
                    n_transient,
                    cfg.flip_rate,
                    cell_seed(cfg.fault_seed, arch, n_stuck, n_transient, mi),
                )?;
                let (stuck, transient) = (fl.stuck_count(), fl.transient_count());
                if !fl.is_empty() {
                    ev.set_fault_list(Some(Arc::new(fl)));
                }
                let fault_acc = ev.accuracy(
                    &entry.test,
                    &entry.feat_mask,
                    &entry.approx_mask,
                    &entry.tables,
                )?;
                meta.push((baseline, fault_acc, stuck, transient));
                slots.push(Arc::new(ModelSlot::new(
                    entry.name.clone(),
                    class_of(&cfg.serve.classes, mi),
                    Arc::clone(entry),
                    Box::new(ev),
                )));
            }
            let report: ServerReport = serve_with(&slots, &cfg.serve)?;
            for (mr, &(baseline, fault_acc, stuck, transient)) in
                report.models.iter().zip(&meta)
            {
                rows.push(CampaignRow {
                    arch,
                    model: mr.name.clone(),
                    stuck,
                    transient,
                    flip_rate: cfg.flip_rate,
                    baseline_accuracy: baseline,
                    fault_accuracy: fault_acc,
                    degradation: baseline - fault_acc,
                    serve: mr.clone(),
                });
            }
        }
    }
    Ok(CampaignReport {
        scenario: cfg.serve.scenario,
        rows,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arch_labels_roundtrip_and_map_to_gate_archs() {
        for a in [ArchKind::Ours, ArchKind::Hybrid, ArchKind::Comb] {
            assert_eq!(a.label().parse::<ArchKind>().unwrap(), a);
        }
        assert!("nosuch".parse::<ArchKind>().is_err());
        assert_eq!(ArchKind::Ours.gate_arch(), GateArch::Sequential);
        assert_eq!(ArchKind::Hybrid.gate_arch(), GateArch::Sequential);
        assert_eq!(ArchKind::Comb.gate_arch(), GateArch::Combinational);
    }

    #[test]
    fn parse_levels_accepts_sweeps_and_rejects_garbage() {
        assert_eq!(
            parse_levels("0:0, 4:0,16:0 ,4:4").unwrap(),
            vec![(0, 0), (4, 0), (16, 0), (4, 4)]
        );
        assert!(parse_levels("").is_err());
        assert!(parse_levels("4").is_err());
        assert!(parse_levels("a:2").is_err());
        assert!(parse_levels("2:b").is_err());
    }

    #[test]
    fn cell_seeds_are_distinct_across_the_sweep() {
        let mut seen = std::collections::BTreeSet::new();
        for arch in [ArchKind::Ours, ArchKind::Hybrid, ArchKind::Comb] {
            for &(s, t) in &[(0usize, 0usize), (4, 0), (16, 0), (4, 4)] {
                for mi in 0..3 {
                    assert!(seen.insert(cell_seed(0xFA171, arch, s, t, mi)));
                }
            }
        }
    }

    #[test]
    fn hybrid_registry_flips_the_demo_mask() {
        let names = vec!["a".to_string(), "b".to_string()];
        let base = ModelRegistry::synthetic(&names, 5);
        let hy = arch_registry(&base, ArchKind::Hybrid);
        assert_eq!(hy.len(), base.len());
        for (h, b) in hy.entries().iter().zip(base.entries()) {
            assert_eq!(h.name, b.name);
            assert!(h.approx_mask.iter().any(|&m| m == 1), "demo mask applied");
            assert!(b.approx_mask.iter().all(|&m| m == 0), "base untouched");
        }
        let same = arch_registry(&base, ArchKind::Comb);
        assert!(same.entries().iter().all(|e| e.approx_mask.iter().all(|&m| m == 0)));
    }
}
