//! Model registry: every hosted model's artifacts, loaded once and shared
//! read-only across the whole server.
//!
//! A [`ModelEntry`] bundles everything the request path needs for one
//! dataset — the quantized model, the test split frames are drawn from,
//! the feature/approximation masks, and the [`ApproxTables`] — so the
//! batcher workers never touch the [`ArtifactStore`] (or any other
//! mutable state) while traffic is flowing.  Evaluators are built through
//! [`crate::runtime::build_evaluator`] and warmed before the load
//! generator starts, which forces lazy state (the gatesim circuit and its
//! compiled [`crate::sim::SimPlan`]) off the request path.

use std::sync::{Arc, RwLock};

use anyhow::{bail, ensure, Context, Result};

use crate::data::{ArtifactStore, Split};
use crate::model::{synth, ApproxTables, QuantModel};
use crate::runtime::{
    build_evaluator, owned_evaluator, Backend, EvalOpts, Evaluator, FusedGateSim, FusedSpec,
};
use crate::server::admission::{class_of, SloClass};

/// One hosted model and the read-only state its traffic needs.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub model: QuantModel,
    /// Frames are sampled from this split; `ys` scores accuracy.
    pub test: Split,
    pub feat_mask: Vec<u8>,
    pub approx_mask: Vec<u8>,
    pub tables: ApproxTables,
}

impl ModelEntry {
    /// Entry serving the full-precision model (all features, no neuron
    /// approximation) — the serve-mode default.
    pub fn full_precision(name: &str, model: QuantModel, test: Split) -> ModelEntry {
        let feat_mask = vec![1u8; model.features];
        let approx_mask = vec![0u8; model.hidden];
        let tables = ApproxTables::disabled(model.hidden);
        ModelEntry {
            name: name.to_string(),
            model,
            test,
            feat_mask,
            approx_mask,
            tables,
        }
    }
}

/// One immutable, warmed (entry, evaluator) pair.  The batcher resolves
/// a slot's current version at every batch boundary and holds this `Arc`
/// for the batch's duration, so a concurrent promote can never tear a
/// batch or stall the request path.
pub struct ModelVersion {
    /// Monotonic per slot, starting at 1.
    pub version: u64,
    pub entry: Arc<ModelEntry>,
    /// Owns its model state (`'static`) so versions can be swapped at
    /// runtime — built via [`owned_evaluator`], never borrowing the
    /// registry.
    pub eval: Box<dyn Evaluator + Send + Sync>,
}

/// One hosted tenant: the incumbent model version serving traffic, plus
/// an optional staged candidate for zero-downtime hot reload.
///
/// Reload protocol: [`ModelSlot::stage`] builds and warms the candidate
/// *off* the request path (traffic keeps hitting the incumbent), the
/// batcher optionally shadows a canary fraction of batches on it, and
/// [`ModelSlot::promote`] atomically swaps it in.  In-flight batches
/// finish on the version they resolved — nothing blocks, nothing drops.
pub struct ModelSlot {
    pub name: String,
    /// Tenant SLO class; fixed for the slot's lifetime (admission
    /// ceilings and drain order are derived from it once at startup).
    pub class: SloClass,
    incumbent: RwLock<Arc<ModelVersion>>,
    candidate: RwLock<Option<Arc<ModelVersion>>>,
}

impl ModelSlot {
    pub fn new(
        name: String,
        class: SloClass,
        entry: Arc<ModelEntry>,
        eval: Box<dyn Evaluator + Send + Sync>,
    ) -> ModelSlot {
        ModelSlot {
            name,
            class,
            incumbent: RwLock::new(Arc::new(ModelVersion {
                version: 1,
                entry,
                eval,
            })),
            candidate: RwLock::new(None),
        }
    }

    /// The version currently serving traffic.
    pub fn current(&self) -> Arc<ModelVersion> {
        self.incumbent.read().unwrap().clone()
    }

    /// The staged candidate, if any (shadow-evaluated by the batcher
    /// when a canary fraction is configured).
    pub fn candidate(&self) -> Option<Arc<ModelVersion>> {
        self.candidate.read().unwrap().clone()
    }

    pub fn version(&self) -> u64 {
        self.incumbent.read().unwrap().version
    }

    /// Stage a candidate version: warm it here — on the caller's
    /// thread, off the request path — then publish it for canary
    /// shadowing.  Returns the candidate's version number.  A
    /// previously staged candidate is replaced.
    pub fn stage(
        &self,
        entry: Arc<ModelEntry>,
        eval: Box<dyn Evaluator + Send + Sync>,
    ) -> Result<u64> {
        warm_one(&entry, eval.as_ref())
            .with_context(|| format!("warming candidate for `{}`", self.name))?;
        let version = self.version() + 1;
        *self.candidate.write().unwrap() = Some(Arc::new(ModelVersion {
            version,
            entry,
            eval,
        }));
        Ok(version)
    }

    /// Atomically swap the staged candidate in as the incumbent.
    /// Returns `false` when nothing is staged.  Batches already running
    /// hold their old `Arc<ModelVersion>` and finish undisturbed.
    pub fn promote(&self) -> bool {
        let cand = self.candidate.write().unwrap().take();
        match cand {
            Some(v) => {
                *self.incumbent.write().unwrap() = v;
                true
            }
            None => false,
        }
    }

    /// Drop the staged candidate (e.g. after canary mismatches).
    pub fn abort_candidate(&self) -> bool {
        self.candidate.write().unwrap().take().is_some()
    }
}

/// Lazily (re)built cross-model fused gatesim plan over a set of hosted
/// slots (§Fusion).  The batcher resolves it at batch boundaries exactly
/// as it resolves per-model versions: [`FusedSlot::resolve`] compares the
/// slots' current version vector against the cached one and rebuilds the
/// fused stream when any slot was promoted since — hot reload composes
/// with fusion for free, at the cost of one fused rebuild per promote
/// (paid on the drain thread at a batch boundary, never mid-batch).
pub struct FusedSlot {
    slots: Vec<Arc<ModelSlot>>,
    sim_threads: usize,
    sim_lanes: usize,
    #[allow(clippy::type_complexity)]
    cached: RwLock<Option<(Vec<u64>, Arc<FusedGateSim>)>>,
}

impl FusedSlot {
    pub fn new(slots: &[Arc<ModelSlot>], sim_threads: usize, sim_lanes: usize) -> FusedSlot {
        FusedSlot {
            slots: slots.to_vec(),
            sim_threads: sim_threads.max(1),
            sim_lanes,
            cached: RwLock::new(None),
        }
    }

    /// The fused evaluator for the slots' *current* versions, plus the
    /// resolved version vector itself (the batcher needs the entries for
    /// frame payloads and the version numbers for shadow accounting).
    /// Cache hit when no slot was promoted since the last call; rebuild
    /// otherwise.
    pub fn resolve(&self) -> Result<(Vec<Arc<ModelVersion>>, Arc<FusedGateSim>)> {
        let vers: Vec<Arc<ModelVersion>> = self.slots.iter().map(|s| s.current()).collect();
        let vv: Vec<u64> = vers.iter().map(|v| v.version).collect();
        if let Some((cached_vv, fused)) = self.cached.read().unwrap().as_ref() {
            if *cached_vv == vv {
                return Ok((vers, Arc::clone(fused)));
            }
        }
        let specs: Vec<FusedSpec> = vers
            .iter()
            .map(|v| FusedSpec {
                model: &v.entry.model,
                feat_mask: &v.entry.feat_mask,
                approx_mask: &v.entry.approx_mask,
                tables: &v.entry.tables,
            })
            .collect();
        let fused = Arc::new(
            FusedGateSim::build(&specs, self.sim_threads, self.sim_lanes)
                .context("building fused gatesim plan")?,
        );
        *self.cached.write().unwrap() = Some((vv, Arc::clone(&fused)));
        Ok((vers, fused))
    }
}

/// Force one prediction through an evaluator so lazily-built state
/// (gatesim circuit + compiled plan) is paid before traffic sees it.
fn warm_one(entry: &ModelEntry, eval: &dyn Evaluator) -> Result<()> {
    let mut out = Vec::with_capacity(1);
    eval.predict_into(
        entry.test.row(0),
        1,
        &entry.feat_mask,
        &entry.approx_mask,
        &entry.tables,
        &mut out,
    )
}

/// The set of models one server instance hosts, in request-routing order.
#[derive(Default)]
pub struct ModelRegistry {
    entries: Vec<Arc<ModelEntry>>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Load every requested dataset's artifacts exactly once (duplicate
    /// names collapse to one entry).
    pub fn from_store(store: &ArtifactStore, names: &[String]) -> Result<ModelRegistry> {
        let mut reg = ModelRegistry::new();
        for name in names {
            if reg.get(name).is_some() {
                continue;
            }
            let model = store
                .model(name)
                .with_context(|| format!("loading model artifacts for `{name}`"))?;
            let ds = store
                .dataset(name)
                .with_context(|| format!("loading dataset artifacts for `{name}`"))?;
            ensure!(!ds.test.is_empty(), "dataset `{name}` has an empty test split");
            reg.insert(ModelEntry::full_precision(name, model, ds.test));
        }
        Ok(reg)
    }

    /// Artifact-free registry of deterministic synthetic models (one per
    /// requested name, sizes varied per slot) with self-labeled splits —
    /// accuracy 1.0 on an exact backend, making serve accuracy a
    /// correctness signal.  Used by `--synthetic`, the batching tests,
    /// and the `serve_scaling` bench.
    pub fn synthetic(names: &[String], seed: u64) -> ModelRegistry {
        let mut reg = ModelRegistry::new();
        for (i, name) in names.iter().enumerate() {
            if reg.get(name).is_some() {
                continue;
            }
            let k = i as u64;
            let (f, h, c) = (8 + 2 * (i % 3), 5 + i % 3, 2 + i % 3);
            let model = synth::rand_model(seed.wrapping_add(k), f, h, c);
            let test = synth::rand_split(&model, seed.wrapping_add(0x5EED + k), 48);
            reg.insert(ModelEntry::full_precision(name, model, test));
        }
        reg
    }

    pub fn insert(&mut self, entry: ModelEntry) {
        self.entries.push(Arc::new(entry));
    }

    pub fn entries(&self) -> &[Arc<ModelEntry>] {
        &self.entries
    }

    pub fn get(&self, name: &str) -> Option<&Arc<ModelEntry>> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Build one thread-shareable evaluator per entry via the unified
    /// [`build_evaluator`] factory.  `sim_threads` is forced low (the
    /// batcher workers are already the parallelism); `sim_lanes` is the
    /// gatesim super-lane width in `u64` words (0 =
    /// [`crate::sim::lane_words_default`]) — the batcher aligns its
    /// drains to the resulting `W·64` block.  PJRT is rejected because
    /// its handles cannot cross the worker pool.
    pub fn evaluators(
        &self,
        backend: Backend,
        sim_threads: usize,
        sim_lanes: usize,
    ) -> Result<Vec<Box<dyn Evaluator + Send + Sync + '_>>> {
        if backend == Backend::Pjrt {
            bail!(
                "serve: PJRT handles are thread-bound (!Send) and cannot back the \
                 multi-model worker pool; use --backend native|gatesim"
            );
        }
        let opts = EvalOpts {
            sim_threads: sim_threads.max(1),
            sim_lanes,
            ..EvalOpts::default()
        };
        self.entries
            .iter()
            .map(|e| build_evaluator(backend, None, &e.model, &opts)?.into_shared())
            .collect()
    }

    /// Run one frame through every evaluator, forcing lazily-built state
    /// (gatesim circuit generation + plan compilation) before traffic.
    pub fn warmup(&self, evals: &[Box<dyn Evaluator + Send + Sync + '_>]) -> Result<()> {
        let mut out = Vec::with_capacity(1);
        for (entry, eval) in self.entries.iter().zip(evals) {
            eval.predict_into(
                entry.test.row(0),
                1,
                &entry.feat_mask,
                &entry.approx_mask,
                &entry.tables,
                &mut out,
            )
            .with_context(|| format!("warming up `{}`", entry.name))?;
        }
        Ok(())
    }

    /// Build one hot-swappable [`ModelSlot`] per entry, each owning a
    /// warmed `'static` evaluator ([`owned_evaluator`]) so versions can
    /// be staged and promoted at runtime.  `classes` assigns SLO classes
    /// positionally; models past its end default to gold.  PJRT is
    /// rejected for the same reason as in [`ModelRegistry::evaluators`].
    pub fn slots(
        &self,
        backend: Backend,
        sim_threads: usize,
        sim_lanes: usize,
        classes: &[SloClass],
    ) -> Result<Vec<Arc<ModelSlot>>> {
        if backend == Backend::Pjrt {
            bail!(
                "serve: PJRT handles are thread-bound (!Send) and cannot back the \
                 multi-model worker pool; use --backend native|gatesim"
            );
        }
        let opts = EvalOpts {
            sim_threads: sim_threads.max(1),
            sim_lanes,
            ..EvalOpts::default()
        };
        let mut slots = Vec::with_capacity(self.entries.len());
        for (i, entry) in self.entries.iter().enumerate() {
            let eval = owned_evaluator(backend, &entry.model, &opts)
                .with_context(|| format!("building evaluator for `{}`", entry.name))?;
            warm_one(entry, eval.as_ref())
                .with_context(|| format!("warming up `{}`", entry.name))?;
            slots.push(Arc::new(ModelSlot::new(
                entry.name.clone(),
                class_of(classes, i),
                Arc::clone(entry),
                eval,
            )));
        }
        Ok(slots)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_registry_dedupes_and_self_labels() {
        let names: Vec<String> = ["a", "b", "a", "c"].iter().map(|s| s.to_string()).collect();
        let reg = ModelRegistry::synthetic(&names, 42);
        assert_eq!(reg.len(), 3);
        assert!(reg.get("b").is_some());
        assert!(reg.get("nosuch").is_none());
        let evals = reg.evaluators(Backend::Native, 1, 0).unwrap();
        reg.warmup(&evals).unwrap();
        for (entry, eval) in reg.entries().iter().zip(&evals) {
            let acc = eval
                .accuracy(&entry.test, &entry.feat_mask, &entry.approx_mask, &entry.tables)
                .unwrap();
            assert_eq!(acc, 1.0, "synthetic split must be self-labeled");
        }
    }

    #[test]
    fn pjrt_backend_rejected_for_worker_pool() {
        let names = vec!["x".to_string()];
        let reg = ModelRegistry::synthetic(&names, 1);
        assert!(reg.evaluators(Backend::Pjrt, 1, 0).is_err());
        assert!(reg.slots(Backend::Pjrt, 1, 0, &[]).is_err());
    }

    #[test]
    fn slot_stage_and_promote_swap_versions_atomically() {
        let names = vec!["m".to_string()];
        let reg = ModelRegistry::synthetic(&names, 9);
        let slots = reg
            .slots(Backend::Native, 1, 0, &[SloClass::Silver])
            .unwrap();
        let slot = &slots[0];
        assert_eq!(slot.class, SloClass::Silver);
        assert_eq!(slot.version(), 1);
        assert!(slot.candidate().is_none());
        assert!(!slot.promote(), "nothing staged yet");

        let entry = Arc::clone(&slot.current().entry);
        let eval = owned_evaluator(Backend::Native, &entry.model, &EvalOpts::default()).unwrap();
        let v = slot.stage(Arc::clone(&entry), eval).unwrap();
        assert_eq!(v, 2);
        assert!(slot.candidate().is_some());
        assert_eq!(slot.version(), 1, "staging must not touch the incumbent");

        let held = slot.current();
        assert!(slot.promote());
        assert_eq!(slot.version(), 2);
        assert!(slot.candidate().is_none());
        assert_eq!(held.version, 1, "in-flight batches keep their version");

        // Abort path: stage again, then drop instead of promoting.
        let eval = owned_evaluator(Backend::Native, &entry.model, &EvalOpts::default()).unwrap();
        slot.stage(Arc::clone(&entry), eval).unwrap();
        assert!(slot.abort_candidate());
        assert!(!slot.abort_candidate());
        assert_eq!(slot.version(), 2);
    }

    #[test]
    fn fused_slot_caches_rebuilds_on_promote_and_matches_per_model() {
        let names: Vec<String> = ["f1", "f2"].iter().map(|s| s.to_string()).collect();
        let reg = ModelRegistry::synthetic(&names, 77);
        let slots = reg.slots(Backend::GateSim, 1, 1, &[]).unwrap();
        let fused_slot = FusedSlot::new(&slots, 1, 1);
        let (vers, f1) = fused_slot.resolve().unwrap();
        assert_eq!(vers.len(), 2);
        let (_, f2) = fused_slot.resolve().unwrap();
        assert!(Arc::ptr_eq(&f1, &f2), "same versions must hit the cache");

        // Fused predictions over the full test splits match each slot's
        // own evaluator bit-for-bit.
        let batches: Vec<(&[u8], usize)> = vers
            .iter()
            .map(|v| (v.entry.test.xs.as_slice(), v.entry.test.len()))
            .collect();
        let got = f1.predict_multi(&batches).unwrap();
        for (v, got_m) in vers.iter().zip(&got) {
            let e = &v.entry;
            let want = v
                .eval
                .predict(&e.test.xs, e.test.len(), &e.feat_mask, &e.approx_mask, &e.tables)
                .unwrap();
            assert_eq!(*got_m, want, "fused vs per-model for `{}`", e.name);
        }

        // Promote slot 0 → version vector changes → fused plan rebuilt.
        let entry = Arc::clone(&slots[0].current().entry);
        let eval = owned_evaluator(Backend::GateSim, &entry.model, &EvalOpts::default()).unwrap();
        slots[0].stage(Arc::clone(&entry), eval).unwrap();
        assert!(slots[0].promote());
        let (vers2, f3) = fused_slot.resolve().unwrap();
        assert_eq!(vers2[0].version, 2);
        assert!(!Arc::ptr_eq(&f1, &f3), "promote must invalidate the fused cache");
    }
}
