//! Per-model dynamic-batching queues and the worker pool that drains
//! them.
//!
//! Each hosted model owns one bounded [`BatchQueue`]; load-generator
//! threads push [`Frame`]s and a pool of drain workers (reusing
//! [`pool::scope_map_with`] so per-worker scratch buffers are allocated
//! once) pops up to `batch` frames at a time and runs them through the
//! model's shared [`Evaluator`].  Backpressure is load shedding: a push
//! into a full queue drops the frame and bumps the model's shed counter —
//! the queue never blocks a sensor thread and never grows without bound.
//!
//! The linger rule is the classic dynamic-batching trade-off in one
//! `if`: a worker takes a sub-full batch only once the oldest waiting
//! frame has aged past `max_wait` (or the server is draining to exit),
//! otherwise it leaves the frames to accumulate into a fuller batch.
//!
//! §Block alignment: each model's effective batch ceiling is
//! [`DrainConfig::batch`] rounded **up** to the backend's
//! [`Evaluator::batch_quantum`] (the gatesim backend reports its `W·64`
//! super-lane block, scalar backends report 1), so a deep queue drains in
//! whole simulator blocks with no idle lanes; only the lingered tail of a
//! burst pays for a partial block.  Per-batch lane-slot consumption is
//! counted in [`ModelStats::lane_slots`], and `fill = answered /
//! lane_slots` lands in the serve report.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::Evaluator;
use crate::server::registry::ModelEntry;
use crate::util::pool;
use crate::util::stats::Reservoir;

/// One in-flight inference request.
#[derive(Clone, Debug)]
pub struct Frame {
    /// Unique per run; lets tests assert exactly-once answering.
    pub id: u64,
    /// Row index into the model's test split.
    pub sample: usize,
    pub enqueued: Instant,
}

/// Per-model request-path counters and latency samples.
#[derive(Debug, Default)]
pub struct ModelStats {
    pub submitted: AtomicUsize,
    pub shed: AtomicUsize,
    pub answered: AtomicUsize,
    /// Frames popped whose batch then failed in the evaluator — they can
    /// never be answered, so exactly-once accounting is
    /// `submitted = answered + shed + errors + still-queued`.
    pub errors: AtomicUsize,
    pub correct: AtomicUsize,
    pub batches: AtomicUsize,
    /// Simulator lane slots consumed (batch sizes rounded up to the
    /// backend's block quantum) — `answered / lane_slots` is the
    /// super-lane fill ratio, 1.0 on scalar backends.
    pub lane_slots: AtomicUsize,
    pub slo_violations: AtomicUsize,
    /// Bounded by deterministic reservoir sampling ([`Reservoir`]):
    /// exact percentiles below the cap, an unbiased estimate above it —
    /// a long campaign no longer grows per-frame memory without limit.
    pub latencies_ms: Mutex<Reservoir>,
    /// `(frame id, prediction)` pairs; filled only when
    /// [`DrainConfig::collect_responses`] is set (tests).
    pub responses: Mutex<Vec<(u64, i32)>>,
}

/// Bounded FIFO of pending frames for one model.
pub struct BatchQueue {
    capacity: usize,
    q: Mutex<VecDeque<Frame>>,
    pub stats: ModelStats,
}

impl BatchQueue {
    pub fn new(capacity: usize) -> BatchQueue {
        BatchQueue {
            capacity: capacity.max(1),
            q: Mutex::new(VecDeque::new()),
            stats: ModelStats::default(),
        }
    }

    /// Enqueue a frame; returns `false` (and counts a shed) when the
    /// queue is at capacity.  Every push counts as submitted either way.
    pub fn push(&self, frame: Frame) -> bool {
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        let mut q = self.q.lock().unwrap();
        if q.len() >= self.capacity {
            drop(q);
            self.stats.shed.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        q.push_back(frame);
        true
    }

    pub fn len(&self) -> usize {
        self.q.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.lock().unwrap().is_empty()
    }

    /// Pop up to `max` frames into `out`.  A sub-full batch is released
    /// only when its oldest frame has waited at least `linger` or
    /// `force` is set (server draining to exit); returns the number of
    /// frames taken.
    pub fn pop_batch(
        &self,
        max: usize,
        linger: Duration,
        force: bool,
        out: &mut Vec<Frame>,
    ) -> usize {
        let mut q = self.q.lock().unwrap();
        if q.is_empty() {
            return 0;
        }
        if !force && q.len() < max {
            let oldest = q.front().expect("nonempty queue").enqueued;
            if oldest.elapsed() < linger {
                return 0;
            }
        }
        let take = q.len().min(max);
        for _ in 0..take {
            out.push(q.pop_front().expect("len checked"));
        }
        take
    }
}

/// Drain-loop tuning knobs.
#[derive(Clone, Debug)]
pub struct DrainConfig {
    pub workers: usize,
    /// Max frames per executed batch.
    pub batch: usize,
    /// Max time a sub-full batch lingers before it is released.
    pub max_wait: Duration,
    /// Per-frame latency SLO; frames above it count as violations.
    pub slo_ms: f64,
    /// Record `(frame id, prediction)` pairs (tests only).
    pub collect_responses: bool,
}

impl Default for DrainConfig {
    fn default() -> Self {
        DrainConfig {
            workers: 1,
            batch: 64,
            max_wait: Duration::from_millis(2),
            slo_ms: 50.0,
            collect_responses: false,
        }
    }
}

/// Execute one popped batch on the model's evaluator and record stats.
/// `quantum` is the backend's block granularity for lane-fill accounting.
#[allow(clippy::too_many_arguments)]
fn process_batch(
    queue: &BatchQueue,
    entry: &ModelEntry,
    eval: &dyn Evaluator,
    cfg: &DrainConfig,
    quantum: usize,
    frames: &[Frame],
    xbuf: &mut Vec<u8>,
    preds: &mut Vec<i32>,
) -> Result<()> {
    xbuf.clear();
    for fr in frames {
        xbuf.extend_from_slice(entry.test.row(fr.sample));
    }
    eval.predict_into(
        xbuf,
        frames.len(),
        &entry.feat_mask,
        &entry.approx_mask,
        &entry.tables,
        preds,
    )?;
    let done = Instant::now();
    let st = &queue.stats;
    st.batches.fetch_add(1, Ordering::Relaxed);
    st.answered.fetch_add(frames.len(), Ordering::Relaxed);
    st.lane_slots
        .fetch_add(frames.len().div_ceil(quantum) * quantum, Ordering::Relaxed);
    {
        let mut lat = st.latencies_ms.lock().unwrap();
        for (fr, &p) in frames.iter().zip(preds.iter()) {
            let ms = (done - fr.enqueued).as_secs_f64() * 1e3;
            lat.push(ms);
            if ms > cfg.slo_ms {
                st.slo_violations.fetch_add(1, Ordering::Relaxed);
            }
            if p == entry.test.ys[fr.sample] as i32 {
                st.correct.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    if cfg.collect_responses {
        let mut rs = st.responses.lock().unwrap();
        for (fr, &p) in frames.iter().zip(preds.iter()) {
            rs.push((fr.id, p));
        }
    }
    Ok(())
}

/// Drain every queue with a pool of `cfg.workers` threads until `stop`
/// is set **and** all queues are empty; each popped frame is answered
/// exactly once.  Workers sweep the models round-robin from a per-worker
/// offset so all models make progress even with one worker, and park
/// briefly when a full sweep finds nothing.
///
/// A failing batch does NOT kill its worker: the popped frames are
/// recorded in [`ModelStats::errors`] (they can never be answered — an
/// exiting worker would otherwise leave them silently unaccounted) and
/// the worker keeps draining, so sibling models and later frames still
/// complete.  The first error per worker is surfaced after the pool
/// joins.
pub fn drain(
    queues: &[BatchQueue],
    entries: &[Arc<ModelEntry>],
    evals: &[Box<dyn Evaluator + Send + Sync + '_>],
    cfg: &DrainConfig,
    stop: &AtomicBool,
) -> Result<()> {
    let n = queues.len();
    if n == 0 {
        return Ok(());
    }
    let workers = cfg.workers.max(1);
    // batch = 0 would pop nothing forever and make the exit condition
    // (stop + empty queues) unreachable; clamp here so every caller of
    // the public DrainConfig is safe, not just server::run.
    let batch = cfg.batch.max(1);
    // §Block alignment: round each model's batch ceiling up to its
    // backend's block quantum, so a deep queue drains in whole simulator
    // super-lane blocks (gatesim: W·64 samples) with no idle lanes.
    let quanta: Vec<usize> = evals.iter().map(|e| e.batch_quantum().max(1)).collect();
    let maxes: Vec<usize> = quanta.iter().map(|&q| batch.div_ceil(q) * q).collect();
    let results: Vec<Result<()>> = pool::scope_map_with(
        workers,
        workers,
        || (Vec::<Frame>::new(), Vec::<u8>::new(), Vec::<i32>::new()),
        |scratch, w| {
            let (frames, xbuf, preds) = scratch;
            let mut first_err: Option<anyhow::Error> = None;
            loop {
                // Read before the sweep: frames seen after `stop` was set
                // still drain (producers are done once it is set), and the
                // exit check below re-verifies emptiness.
                let stopping = stop.load(Ordering::Acquire);
                let mut did_work = false;
                for k in 0..n {
                    let m = (w + k) % n;
                    frames.clear();
                    if queues[m].pop_batch(maxes[m], cfg.max_wait, stopping, frames) == 0 {
                        continue;
                    }
                    did_work = true;
                    let eval = evals[m].as_ref();
                    if let Err(e) = process_batch(
                        &queues[m], &entries[m], eval, cfg, quanta[m], frames, xbuf, preds,
                    ) {
                        // The popped frames can never be answered now;
                        // account them so exactly-once bookkeeping still
                        // balances, and keep draining instead of exiting
                        // with sibling queues stranded.
                        queues[m]
                            .stats
                            .errors
                            .fetch_add(frames.len(), Ordering::Relaxed);
                        if first_err.is_none() {
                            first_err =
                                Some(e.context(format!("model `{}` batch failed", entries[m].name)));
                        }
                    }
                }
                if !did_work {
                    if stopping && queues.iter().all(|q| q.is_empty()) {
                        return match first_err.take() {
                            Some(e) => Err(e),
                            None => Ok(()),
                        };
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        },
    );
    results.into_iter().collect()
}
