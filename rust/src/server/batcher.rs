//! Per-model dynamic-batching queues and the worker pool that drains
//! them.
//!
//! Each hosted model owns one bounded [`BatchQueue`]; producers (the
//! in-process loadgen or the TCP [`frontend`](crate::server::frontend))
//! push [`Frame`]s and a pool of drain workers (reusing
//! [`pool::scope_map_with`] so per-worker scratch buffers are allocated
//! once) pops up to `batch` frames at a time and runs them through the
//! model slot's current [`Evaluator`].  Backpressure is load shedding: a
//! push past the queue's admission ceiling answers the frame `Shed` and
//! drops it — the queue never blocks a producer and never grows without
//! bound.  The ceiling is per-tenant: gold admits the full capacity,
//! silver 75%, bronze 50% ([`SloClass::admit_limit`]), so overload sheds
//! bronze first, and workers sweep the queues gold-first
//! ([`admission::drain_order`]) so gold tail latency holds under
//! saturation.
//!
//! The linger rule is the classic dynamic-batching trade-off in one
//! `if`: a worker takes a sub-full batch only once the oldest waiting
//! frame has aged past `max_wait` (or the server is draining to exit),
//! otherwise it leaves the frames to accumulate into a fuller batch.
//!
//! §Block alignment: each model's effective batch ceiling is
//! [`DrainConfig::batch`] rounded **up** to the backend's
//! [`Evaluator::batch_quantum`] (the gatesim backend reports its `W·64`
//! super-lane block, scalar backends report 1), so a deep queue drains in
//! whole simulator blocks with no idle lanes; only the lingered tail of a
//! burst pays for a partial block.  Per-batch lane-slot consumption is
//! counted in [`ModelStats::lane_slots`], and `fill = answered /
//! lane_slots` lands in the serve report.
//!
//! §Hot reload: workers resolve their model's [`ModelSlot`] version at
//! the top of every iteration, so an atomic promote takes effect at the
//! next batch boundary with zero downtime — in-flight batches finish on
//! the version they started with.  When a candidate is staged and
//! [`DrainConfig::canary_step`] is nonzero, a deterministic fraction of
//! batches is shadowed on the candidate and answer mismatches against
//! the incumbent are counted ([`ModelStats::canary_mismatches`]).
//!
//! Exactly-once accounting across all of this:
//! `submitted = answered + shed + late + errors + still-queued`,
//! and every accepted *network* frame gets exactly one response frame
//! (`Ok`, `Shed`, `Late`, or `Error`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::server::admission::{self, SloClass};
use crate::server::frontend::{ConnShared, Status};
use crate::server::registry::{FusedSlot, ModelEntry, ModelSlot, ModelVersion};
use crate::util::pool;
use crate::util::stats::Reservoir;

/// One in-flight inference request.
#[derive(Clone, Debug)]
pub struct Frame {
    /// Unique per run; lets tests assert exactly-once answering.
    pub id: u64,
    /// Model index this frame targets (echoed in network responses).
    pub model: u16,
    /// Row index into the model's test split (direct frames only).
    pub sample: usize,
    /// Feature bytes carried by a network frame; `None` for direct
    /// frames, which reference `sample` instead.
    pub payload: Option<Box<[u8]>>,
    /// Connection to answer on; `None` for direct frames.
    pub reply: Option<Arc<ConnShared>>,
    pub enqueued: Instant,
}

impl Frame {
    /// Direct (in-process loadgen) frame enqueued now.
    pub fn new(id: u64, sample: usize) -> Frame {
        Frame::at(id, sample, Instant::now())
    }

    /// Direct frame with an explicit enqueue instant (tests age frames
    /// artificially to exercise deadline shedding).
    pub fn at(id: u64, sample: usize, enqueued: Instant) -> Frame {
        Frame {
            id,
            model: 0,
            sample,
            payload: None,
            reply: None,
            enqueued,
        }
    }

    /// Network frame: carries its own feature bytes and the connection
    /// to answer on.
    pub fn remote(id: u64, model: u16, features: Vec<u8>, reply: Arc<ConnShared>) -> Frame {
        Frame {
            id,
            model,
            sample: 0,
            payload: Some(features.into_boxed_slice()),
            reply: Some(reply),
            enqueued: Instant::now(),
        }
    }

    /// Answer this frame's client; a no-op for direct frames.  Every
    /// accepted frame is answered exactly once on exactly one path
    /// (batch result, admission shed, deadline shed, or batch error).
    pub fn respond(&self, status: Status, pred: i32) {
        if let Some(reply) = &self.reply {
            reply.respond(self.model, self.id, status, pred);
        }
    }
}

/// Per-model request-path counters and latency samples.
#[derive(Debug, Default)]
pub struct ModelStats {
    pub submitted: AtomicUsize,
    pub shed: AtomicUsize,
    pub answered: AtomicUsize,
    /// Frames deadline-shed while queued: their SLO had already expired
    /// before a worker reached them, so evaluating them would burn lane
    /// slots on dead work ([`DrainConfig::shed_late`]).
    pub late: AtomicUsize,
    /// Frames popped whose batch then failed in the evaluator (or whose
    /// payload no longer matches the model's shape after a reload) —
    /// answered `Error`; exactly-once accounting is
    /// `submitted = answered + shed + late + errors + still-queued`.
    pub errors: AtomicUsize,
    pub correct: AtomicUsize,
    pub batches: AtomicUsize,
    /// Simulator lane slots consumed (batch sizes rounded up to the
    /// backend's block quantum) — `answered / lane_slots` is the
    /// super-lane fill ratio, 1.0 on scalar backends.
    pub lane_slots: AtomicUsize,
    pub slo_violations: AtomicUsize,
    /// Frames shadow-evaluated on a staged candidate version.
    pub canary_checked: AtomicUsize,
    /// Shadowed frames where the candidate disagreed with the incumbent.
    pub canary_mismatches: AtomicUsize,
    /// Fixed-point accumulator for the canary fraction: each batch adds
    /// [`DrainConfig::canary_step`]; a carry out of the low 32 bits
    /// selects the batch for shadowing (deterministic dithering, exact
    /// long-run fraction, no RNG on the hot path).
    pub canary_acc: AtomicU64,
    /// Bounded by deterministic reservoir sampling ([`Reservoir`]):
    /// exact percentiles below the cap, an unbiased estimate above it —
    /// a long campaign no longer grows per-frame memory without limit.
    pub latencies_ms: Mutex<Reservoir>,
    /// `(frame id, prediction)` pairs; filled only when
    /// [`DrainConfig::collect_responses`] is set (tests).
    pub responses: Mutex<Vec<(u64, i32)>>,
}

/// Bounded FIFO of pending frames for one model.
pub struct BatchQueue {
    /// Admission ceiling: pushes shed once the queue holds this many.
    /// Equals the full capacity for gold tenants, a class fraction of it
    /// otherwise ([`SloClass::admit_limit`]).
    admit: usize,
    q: Mutex<VecDeque<Frame>>,
    pub stats: ModelStats,
}

impl BatchQueue {
    pub fn new(capacity: usize) -> BatchQueue {
        BatchQueue::with_admission(capacity, capacity)
    }

    /// A queue of `capacity` slots that sheds once `admit` of them are
    /// occupied — the per-tenant admission ceiling.
    pub fn with_admission(capacity: usize, admit: usize) -> BatchQueue {
        let capacity = capacity.max(1);
        BatchQueue {
            admit: admit.clamp(1, capacity),
            q: Mutex::new(VecDeque::new()),
            stats: ModelStats::default(),
        }
    }

    /// Enqueue a frame; returns `false` (counting a shed and answering
    /// the frame `Shed`) when the queue is at its admission ceiling.
    /// Every push counts as submitted either way.
    pub fn push(&self, frame: Frame) -> bool {
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        let mut q = self.q.lock().unwrap();
        if q.len() >= self.admit {
            drop(q);
            self.stats.shed.fetch_add(1, Ordering::Relaxed);
            frame.respond(Status::Shed, -1);
            return false;
        }
        q.push_back(frame);
        true
    }

    pub fn len(&self) -> usize {
        self.q.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.lock().unwrap().is_empty()
    }

    /// Pop up to `max` frames into `out`.  A sub-full batch is released
    /// only when its oldest frame has waited at least `linger` or
    /// `force` is set (server draining to exit); returns the number of
    /// frames taken.
    pub fn pop_batch(
        &self,
        max: usize,
        linger: Duration,
        force: bool,
        out: &mut Vec<Frame>,
    ) -> usize {
        let mut q = self.q.lock().unwrap();
        if q.is_empty() {
            return 0;
        }
        if !force && q.len() < max {
            let oldest = q.front().expect("nonempty queue").enqueued;
            if oldest.elapsed() < linger {
                return 0;
            }
        }
        let take = q.len().min(max);
        for _ in 0..take {
            out.push(q.pop_front().expect("len checked"));
        }
        take
    }
}

/// Drain-loop tuning knobs.
#[derive(Clone, Debug)]
pub struct DrainConfig {
    pub workers: usize,
    /// Max frames per executed batch.
    pub batch: usize,
    /// Max time a sub-full batch lingers before it is released.
    pub max_wait: Duration,
    /// Per-frame latency SLO; frames above it count as violations.
    pub slo_ms: f64,
    /// Refuse (`Late`) frames whose SLO already expired while queued
    /// instead of evaluating them.  Off by default: the classless
    /// trace-replay paths assert `requests == answered` determinism.
    pub shed_late: bool,
    /// Canary fraction in 32-bit fixed point per batch (see
    /// [`canary_step`]); 0 disables shadowing.
    pub canary_step: u64,
    /// Record `(frame id, prediction)` pairs (tests only).
    pub collect_responses: bool,
}

impl Default for DrainConfig {
    fn default() -> Self {
        DrainConfig {
            workers: 1,
            batch: 64,
            max_wait: Duration::from_millis(2),
            slo_ms: 50.0,
            shed_late: false,
            canary_step: 0,
            collect_responses: false,
        }
    }
}

/// Convert a canary fraction in `[0, 1]` to the fixed-point batch step:
/// `1.0` → every batch shadowed, `0.5` → every other batch, `0.0` → off.
pub fn canary_step(frac: f64) -> u64 {
    (frac.clamp(0.0, 1.0) * 4_294_967_296.0) as u64
}

/// §Block alignment, shared by every drain path: a version's batch
/// quantum floored at 1 (scalar backends report 1 already; the floor
/// guards degenerate evaluators), and a count rounded **up** to whole
/// quanta.  Both the batch ceiling and the lane-slot accounting go
/// through these, so the two can never disagree again.
fn eval_quantum(ver: &ModelVersion) -> usize {
    ver.eval.batch_quantum().max(1)
}

fn align_up(n: usize, quantum: usize) -> usize {
    n.div_ceil(quantum) * quantum
}

/// Concatenate the batch's feature rows into `xbuf`: network frames
/// carry their own payload, direct frames reference the entry's test
/// split.  Sample indices are folded so a reload to a different-sized
/// split cannot send an already-queued direct frame out of bounds.
fn gather_features(entry: &ModelEntry, frames: &[Frame], xbuf: &mut Vec<u8>) {
    let rows = entry.test.len().max(1);
    xbuf.clear();
    for fr in frames {
        match &fr.payload {
            Some(p) => xbuf.extend_from_slice(p),
            None => xbuf.extend_from_slice(entry.test.row(fr.sample % rows)),
        }
    }
}

/// Execute one popped batch on the slot's current evaluator and record
/// stats; optionally shadow it on a staged candidate.  Every frame in
/// `frames` is answered here (`Ok` on success; the caller answers
/// `Error` when this returns `Err`).
#[allow(clippy::too_many_arguments)]
fn process_batch(
    queue: &BatchQueue,
    ver: &ModelVersion,
    candidate: Option<&ModelVersion>,
    cfg: &DrainConfig,
    frames: &[Frame],
    xbuf: &mut Vec<u8>,
    preds: &mut Vec<i32>,
    shadow: &mut Vec<i32>,
) -> Result<()> {
    let entry = &ver.entry;
    gather_features(entry, frames, xbuf);
    ver.eval.predict_into(
        xbuf,
        frames.len(),
        &entry.feat_mask,
        &entry.approx_mask,
        &entry.tables,
        preds,
    )?;
    record_batch(
        queue,
        ver,
        candidate,
        cfg,
        frames,
        xbuf,
        preds,
        shadow,
        eval_quantum(ver),
        Instant::now(),
    );
    Ok(())
}

/// Post-prediction bookkeeping shared by the per-model and fused drain
/// paths: stats, latency samples, client responses, and the optional
/// canary shadow.  `quantum` is the lane-slot accounting granularity
/// (the executing backend's — on the fused path, the fused plan's).
#[allow(clippy::too_many_arguments)]
fn record_batch(
    queue: &BatchQueue,
    ver: &ModelVersion,
    candidate: Option<&ModelVersion>,
    cfg: &DrainConfig,
    frames: &[Frame],
    xbuf: &[u8],
    preds: &[i32],
    shadow: &mut Vec<i32>,
    quantum: usize,
    done: Instant,
) {
    let entry = &ver.entry;
    let rows = entry.test.len().max(1);
    let st = &queue.stats;
    st.batches.fetch_add(1, Ordering::Relaxed);
    st.answered.fetch_add(frames.len(), Ordering::Relaxed);
    st.lane_slots
        .fetch_add(align_up(frames.len(), quantum), Ordering::Relaxed);
    {
        let mut lat = st.latencies_ms.lock().unwrap();
        for (fr, &p) in frames.iter().zip(preds.iter()) {
            let ms = (done - fr.enqueued).as_secs_f64() * 1e3;
            lat.push(ms);
            if ms > cfg.slo_ms {
                st.slo_violations.fetch_add(1, Ordering::Relaxed);
            }
            // Network frames carry raw features with no known label;
            // their correctness is scored client-side.
            if fr.payload.is_none() && p == entry.test.ys[fr.sample % rows] as i32 {
                st.correct.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    if cfg.collect_responses {
        let mut rs = st.responses.lock().unwrap();
        for (fr, &p) in frames.iter().zip(preds.iter()) {
            rs.push((fr.id, p));
        }
    }
    for (fr, &p) in frames.iter().zip(preds.iter()) {
        fr.respond(Status::Ok, p);
    }
    // §Canary shadow: clients were already answered from the incumbent
    // above, so the candidate run is off the response path — it only
    // scores agreement.  Shape-changed candidates are skipped (the xbuf
    // rows would be meaningless to them).
    if let Some(cand) = candidate {
        let acc = st.canary_acc.fetch_add(cfg.canary_step, Ordering::Relaxed);
        let carried = (acc.wrapping_add(cfg.canary_step) >> 32) != (acc >> 32);
        if carried
            && cand.entry.model.features == entry.model.features
            && cand
                .eval
                .predict_into(
                    xbuf,
                    frames.len(),
                    &cand.entry.feat_mask,
                    &cand.entry.approx_mask,
                    &cand.entry.tables,
                    shadow,
                )
                .is_ok()
        {
            st.canary_checked.fetch_add(frames.len(), Ordering::Relaxed);
            let mism = preds
                .iter()
                .zip(shadow.iter())
                .filter(|(a, b)| a != b)
                .count();
            if mism > 0 {
                st.canary_mismatches.fetch_add(mism, Ordering::Relaxed);
            }
        }
    }
}

/// Deadline-shed and shape-check a popped batch in place (shared by the
/// per-model and fused drain paths): frames whose SLO already expired
/// answer `Late` (when [`DrainConfig::shed_late`] is on), and network
/// payloads whose length no longer matches the possibly-reloaded model
/// answer `Error`.
fn filter_popped(
    frames: &mut Vec<Frame>,
    st: &ModelStats,
    want_features: usize,
    cfg: &DrainConfig,
) {
    if cfg.shed_late {
        let now = Instant::now();
        frames.retain(|fr| {
            let late = now.duration_since(fr.enqueued).as_secs_f64() * 1e3 > cfg.slo_ms;
            if late {
                st.late.fetch_add(1, Ordering::Relaxed);
                fr.respond(Status::Late, -1);
            }
            !late
        });
    }
    frames.retain(|fr| {
        let bad = fr.payload.as_ref().is_some_and(|p| p.len() != want_features);
        if bad {
            st.errors.fetch_add(1, Ordering::Relaxed);
            fr.respond(Status::Error, -1);
        }
        !bad
    });
}

/// Drain every queue with a pool of `cfg.workers` threads until `stop`
/// is set **and** all queues are empty; each popped frame is answered
/// exactly once.  Workers sweep the models in class-priority order
/// (gold first — [`admission::drain_order`]) so under saturation the
/// best tenants are served first each sweep, and park briefly when a
/// full sweep finds nothing.
///
/// Each iteration resolves the slot's *current* version before popping,
/// so an atomic hot-reload promote takes effect at the next batch
/// boundary; the in-flight batch keeps the `Arc` to the version it
/// started on (zero downtime, no torn batch).
///
/// A failing batch does NOT kill its worker: the popped frames are
/// recorded in [`ModelStats::errors`] and answered `Error` (an exiting
/// worker would otherwise leave them silently unaccounted) and the
/// worker keeps draining, so sibling models and later frames still
/// complete.  The first error per worker is surfaced after the pool
/// joins.
pub fn drain(
    queues: &[BatchQueue],
    slots: &[Arc<ModelSlot>],
    cfg: &DrainConfig,
    stop: &AtomicBool,
) -> Result<()> {
    let n = queues.len();
    if n == 0 {
        return Ok(());
    }
    debug_assert_eq!(n, slots.len());
    let workers = cfg.workers.max(1);
    // batch = 0 would pop nothing forever and make the exit condition
    // (stop + empty queues) unreachable; clamp here so every caller of
    // the public DrainConfig is safe, not just server::run.
    let batch = cfg.batch.max(1);
    let classes: Vec<SloClass> = slots.iter().map(|s| s.class).collect();
    let order = admission::drain_order(&classes);
    let results: Vec<Result<()>> = pool::scope_map_with(
        workers,
        workers,
        || {
            (
                Vec::<Frame>::new(),
                Vec::<u8>::new(),
                Vec::<i32>::new(),
                Vec::<i32>::new(),
            )
        },
        |scratch, _w| {
            let (frames, xbuf, preds, shadow) = scratch;
            let mut first_err: Option<anyhow::Error> = None;
            loop {
                // Read before the sweep: frames seen after `stop` was set
                // still drain (producers are done once it is set), and the
                // exit check below re-verifies emptiness.
                let stopping = stop.load(Ordering::Acquire);
                let mut did_work = false;
                for &m in &order {
                    let ver = slots[m].current();
                    // §Block alignment: round the batch ceiling up to the
                    // backend's block quantum so a deep queue drains in
                    // whole super-lane blocks with no idle lanes.
                    let max = align_up(batch, eval_quantum(&ver));
                    frames.clear();
                    if queues[m].pop_batch(max, cfg.max_wait, stopping, frames) == 0 {
                        continue;
                    }
                    did_work = true;
                    let st = &queues[m].stats;
                    filter_popped(frames, st, ver.entry.model.features, cfg);
                    if frames.is_empty() {
                        continue;
                    }
                    let candidate = if cfg.canary_step > 0 {
                        slots[m].candidate()
                    } else {
                        None
                    };
                    if let Err(e) = process_batch(
                        &queues[m],
                        &ver,
                        candidate.as_deref(),
                        cfg,
                        frames,
                        xbuf,
                        preds,
                        shadow,
                    ) {
                        // The popped frames can never be answered `Ok`
                        // now; account them and answer `Error` so
                        // exactly-once bookkeeping still balances, and
                        // keep draining instead of exiting with sibling
                        // queues stranded.
                        st.errors.fetch_add(frames.len(), Ordering::Relaxed);
                        for fr in frames.iter() {
                            fr.respond(Status::Error, -1);
                        }
                        if first_err.is_none() {
                            first_err = Some(
                                e.context(format!("model `{}` batch failed", ver.entry.name)),
                            );
                        }
                    }
                }
                if !did_work {
                    if stopping && queues.iter().all(|q| q.is_empty()) {
                        return match first_err.take() {
                            Some(e) => Err(e),
                            None => Ok(()),
                        };
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        },
    );
    results.into_iter().collect()
}

/// §Fusion: drain every tenant's queue through one cross-model fused
/// gatesim pass per sweep instead of one evaluator call per model
/// ([`crate::runtime::FusedGateSim`]) — the fan-in scenario's fast path,
/// where every model receives a frame per event and N per-model drains
/// would pay N sharded simulator passes for the same wall-clock window.
///
/// One sweep: resolve the fused plan against the slots' current versions
/// ([`FusedSlot::resolve`] — a hot-reload promote since the last sweep
/// rebuilds it, exactly the per-model batch-boundary rule), pop up to a
/// fused-quantum-aligned batch from every queue, and run all non-empty
/// batches in a single [`crate::runtime::FusedGateSim::predict_multi`]
/// call.  Parallelism comes from the fused simulator's shard threads, so
/// this runs on the calling thread alone; lane-slot accounting attributes
/// each tenant's aligned share of the shared super-lane blocks.  Canary
/// shadowing still runs per model on the candidate's own evaluator.
///
/// Exactly-once accounting is identical to [`drain`]: a failed fused
/// batch answers `Error` on every popped frame of every tenant and the
/// loop keeps draining, surfacing the first error at exit.
pub fn drain_fused(
    queues: &[BatchQueue],
    slots: &[Arc<ModelSlot>],
    fused: &FusedSlot,
    cfg: &DrainConfig,
    stop: &AtomicBool,
) -> Result<()> {
    let n = queues.len();
    if n == 0 {
        return Ok(());
    }
    debug_assert_eq!(n, slots.len());
    let batch = cfg.batch.max(1);
    let mut frames: Vec<Vec<Frame>> = (0..n).map(|_| Vec::new()).collect();
    let mut xbufs: Vec<Vec<u8>> = (0..n).map(|_| Vec::new()).collect();
    let mut shadow: Vec<i32> = Vec::new();
    let mut first_err: Option<anyhow::Error> = None;
    loop {
        let stopping = stop.load(Ordering::Acquire);
        // Batch boundary: resolve (and on promote, rebuild) the fused
        // plan before popping anything.
        let (vers, eval) = match fused.resolve() {
            Ok(r) => r,
            Err(e) => {
                // Nothing can be evaluated: answer `Error` on every
                // queued frame so accounting balances, then surface.
                for q in queues {
                    let mut buf = Vec::new();
                    while q.pop_batch(usize::MAX, Duration::ZERO, true, &mut buf) > 0 {
                        q.stats.errors.fetch_add(buf.len(), Ordering::Relaxed);
                        for fr in &buf {
                            fr.respond(Status::Error, -1);
                        }
                        buf.clear();
                    }
                }
                return Err(e);
            }
        };
        let quantum = eval.batch_quantum().max(1);
        let max = align_up(batch, quantum);
        let mut did_work = false;
        let mut any = false;
        for m in 0..n {
            frames[m].clear();
            if queues[m].pop_batch(max, cfg.max_wait, stopping, &mut frames[m]) == 0 {
                continue;
            }
            did_work = true;
            filter_popped(&mut frames[m], &queues[m].stats, vers[m].entry.model.features, cfg);
            any |= !frames[m].is_empty();
        }
        if any {
            for m in 0..n {
                gather_features(&vers[m].entry, &frames[m], &mut xbufs[m]);
            }
            let batches: Vec<(&[u8], usize)> = (0..n)
                .map(|m| (xbufs[m].as_slice(), frames[m].len()))
                .collect();
            match eval.predict_multi(&batches) {
                Ok(preds) => {
                    let done = Instant::now();
                    for m in 0..n {
                        if frames[m].is_empty() {
                            continue;
                        }
                        let candidate = if cfg.canary_step > 0 {
                            slots[m].candidate()
                        } else {
                            None
                        };
                        record_batch(
                            &queues[m],
                            &vers[m],
                            candidate.as_deref(),
                            cfg,
                            &frames[m],
                            &xbufs[m],
                            &preds[m],
                            &mut shadow,
                            quantum,
                            done,
                        );
                    }
                }
                Err(e) => {
                    for m in 0..n {
                        if frames[m].is_empty() {
                            continue;
                        }
                        queues[m]
                            .stats
                            .errors
                            .fetch_add(frames[m].len(), Ordering::Relaxed);
                        for fr in &frames[m] {
                            fr.respond(Status::Error, -1);
                        }
                    }
                    if first_err.is_none() {
                        first_err = Some(e.context("fused batch failed"));
                    }
                }
            }
        }
        if !did_work {
            if stopping && queues.iter().all(|q| q.is_empty()) {
                return match first_err.take() {
                    Some(e) => Err(e),
                    None => Ok(()),
                };
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }
}
