//! Multi-tenant model server: the deployment story of the paper's intro
//! (wearables streaming multi-sensory frames into several bespoke
//! sequential MLPs) as a first-class subsystem.
//!
//! Four pieces (DESIGN.md §Server, §Faults):
//!
//! - [`registry`] — [`registry::ModelRegistry`]: every hosted dataset's
//!   artifacts (model, masks, [`crate::model::ApproxTables`], and — via
//!   warmup — the gatesim circuit and its compiled
//!   [`crate::sim::SimPlan`]) loaded once and shared read-only.
//! - [`batcher`] — per-model bounded [`batcher::BatchQueue`]s with shed
//!   counters, drained by a [`crate::util::pool::scope_map_with`] worker
//!   pool running dynamic batching with a `max_wait` linger.
//! - [`loadgen`] — scenario-driven sensors ([`loadgen::Scenario`]:
//!   steady / bursty / ramp / fanin / trace) pushing frames at the
//!   queues; `trace` replays a recorded [`loadgen::Trace`] so the
//!   offered stream is bit-reproducible.
//! - [`campaign`] — the printed-hardware fault campaign: sweeps
//!   stuck-at / transient fault levels per circuit architecture and
//!   reports accuracy degradation and SLO impact through the same serve
//!   path.
//!
//! [`run`] wires registry + evaluators together and hands off to
//! [`serve_with`], which returns a [`ServerReport`] with per-model
//! requests, p50/p99 latency, shed/error counts, SLO violations, and
//! accuracy.  Under `steady` at the default rate nothing sheds and every
//! prediction is bit-identical to a direct [`Evaluator::predict`] call
//! (`tests/server_batching.rs`).

pub mod batcher;
pub mod campaign;
pub mod loadgen;
pub mod registry;

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::data::ArtifactStore;
use crate::runtime::{Backend, Evaluator};
use crate::util::pool::default_threads;
use crate::util::stats;

pub use batcher::{BatchQueue, DrainConfig, Frame, ModelStats};
pub use campaign::{ArchKind, CampaignConfig, CampaignReport, CampaignRow};
pub use loadgen::{Scenario, Trace};
pub use registry::{ModelEntry, ModelRegistry};

/// Server configuration (see `config` for the `[serve]` file section;
/// every key has a CLI override).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Datasets to host concurrently (one model + queue each).
    pub datasets: Vec<String>,
    pub scenario: Scenario,
    /// Offered load, frames per second across all sensors and models
    /// (for `fanin`: window rate — each window feeds every model).
    pub rate_hz: f64,
    pub duration: Duration,
    /// Max time the batcher lets a sub-full batch linger.
    pub max_wait: Duration,
    pub sensors: usize,
    /// Drain workers (0 = one per core).
    pub workers: usize,
    /// Max frames per executed batch.
    pub batch: usize,
    /// Bounded queue capacity per model; overflow is shed.
    pub queue_cap: usize,
    /// Per-frame latency SLO in milliseconds.
    pub slo_ms: f64,
    pub seed: u64,
    /// Evaluator backend on the request path (`Auto` → native; PJRT is
    /// rejected — its handles cannot cross the worker pool).
    pub backend: Backend,
    /// Gatesim super-lane width in `u64` words (0 =
    /// [`crate::sim::lane_words_default`]); the batcher aligns drains to
    /// the resulting `W·64`-sample block.
    pub sim_lanes: usize,
    /// Host deterministic synthetic models instead of store artifacts
    /// (artifact-free smoke/bench mode; accuracy 1.0 expected).
    pub synthetic: bool,
    /// `trace` scenario: replay this recorded trace file; when unset a
    /// diurnal trace is synthesized from `seed`/`rate_hz`/`duration`.
    pub trace: Option<PathBuf>,
    /// Write the trace actually replayed (loaded or synthesized) to this
    /// path — how a synthesized day-curve becomes a reusable artifact.
    pub trace_out: Option<PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            datasets: vec!["spectf".into(), "arrhythmia".into(), "gas".into()],
            scenario: Scenario::Steady,
            rate_hz: 2000.0,
            duration: Duration::from_secs(3),
            max_wait: Duration::from_millis(2),
            sensors: 4,
            workers: 0,
            batch: 64,
            queue_cap: 1024,
            slo_ms: 50.0,
            seed: 7,
            backend: Backend::Auto,
            sim_lanes: 0,
            synthetic: false,
            trace: None,
            trace_out: None,
        }
    }
}

/// Request-path summary for one hosted model.
#[derive(Clone, Debug)]
pub struct ModelReport {
    pub name: String,
    /// Frames offered (answered + shed + errors).
    pub requests: usize,
    pub answered: usize,
    /// Frames whose batch failed in the evaluator (see
    /// [`ModelStats::errors`]); 0 on a healthy run.
    pub errors: usize,
    pub shed: usize,
    pub batches: usize,
    pub mean_batch: f64,
    /// Super-lane fill ratio: answered frames / simulator lane slots
    /// consumed (1.0 on scalar backends and for perfectly aligned
    /// gatesim batches).
    pub fill: f64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub slo_ms: f64,
    pub slo_violations: usize,
    pub accuracy: f64,
}

/// Whole-run summary across every hosted model.
#[derive(Clone, Debug)]
pub struct ServerReport {
    /// Resolved backend that actually served the run.
    pub backend: &'static str,
    pub scenario: Scenario,
    pub workers: usize,
    pub elapsed_s: f64,
    pub models: Vec<ModelReport>,
}

impl ServerReport {
    pub fn total_requests(&self) -> usize {
        self.models.iter().map(|m| m.requests).sum()
    }

    pub fn total_answered(&self) -> usize {
        self.models.iter().map(|m| m.answered).sum()
    }

    pub fn total_shed(&self) -> usize {
        self.models.iter().map(|m| m.shed).sum()
    }

    pub fn total_errors(&self) -> usize {
        self.models.iter().map(|m| m.errors).sum()
    }

    pub fn total_rps(&self) -> f64 {
        self.total_answered() as f64 / self.elapsed_s.max(1e-9)
    }
}

/// Resolve the serve-path backend: `Auto` prefers native (the worker
/// pool needs `Send + Sync` evaluators, which rules out PJRT).
fn resolve_serve_backend(b: Backend) -> Backend {
    match b {
        Backend::Auto => Backend::Native,
        other => other,
    }
}

/// Run the multi-model streaming workload and report per-model stats.
pub fn run(store: &ArtifactStore, cfg: &ServeConfig) -> Result<ServerReport> {
    ensure!(!cfg.datasets.is_empty(), "serve: no datasets requested");
    let registry = if cfg.synthetic {
        ModelRegistry::synthetic(&cfg.datasets, cfg.seed)
    } else {
        ModelRegistry::from_store(store, &cfg.datasets)?
    };
    let backend = resolve_serve_backend(cfg.backend);
    // Sim shards stay at 1: the drain workers are already the
    // parallelism, and nesting pools would oversubscribe to threads².
    // The super-lane width rides through so warmup compiles the plan a
    // wide simulator will execute and the batcher can align to it.
    let evals = registry.evaluators(backend, 1, cfg.sim_lanes)?;
    registry.warmup(&evals)?;
    serve_with(&registry, &evals, cfg)
}

/// Serve an already-built registry through already-built evaluators —
/// the shared lower half of [`run`] and of the fault campaign (which
/// injects fault-carrying gatesim evaluators the plain entry point
/// would never construct).
pub fn serve_with(
    registry: &ModelRegistry,
    evals: &[Box<dyn Evaluator + Send + Sync + '_>],
    cfg: &ServeConfig,
) -> Result<ServerReport> {
    ensure!(!registry.is_empty(), "serve: empty model registry");
    ensure!(
        evals.len() == registry.len(),
        "serve: {} evaluators for {} models",
        evals.len(),
        registry.len()
    );
    let trace = if cfg.scenario == Scenario::Trace {
        let tr = match &cfg.trace {
            Some(path) => Trace::load(path)?,
            None => Trace::synth_diurnal(cfg.seed, cfg.rate_hz, cfg.duration, registry.len()),
        };
        ensure!(!tr.is_empty(), "trace scenario: trace has no requests");
        if let Some(out) = &cfg.trace_out {
            tr.save(out)?;
        }
        Some(tr)
    } else {
        None
    };
    let trace_ref = trace.as_ref();

    let workers = if cfg.workers == 0 { default_threads() } else { cfg.workers.max(1) };
    let queues: Vec<BatchQueue> =
        registry.entries().iter().map(|_| BatchQueue::new(cfg.queue_cap)).collect();
    let drain_cfg = DrainConfig {
        workers,
        batch: cfg.batch.max(1),
        max_wait: cfg.max_wait,
        slo_ms: cfg.slo_ms,
        collect_responses: false,
    };
    let stop = AtomicBool::new(false);
    let start = Instant::now();
    let deadline = start + cfg.duration;

    let entries = registry.entries();
    let queues_ref = &queues;
    let stop_ref = &stop;
    std::thread::scope(|scope| -> Result<()> {
        // Producer side: sensors run in a nested scope so `stop` flips
        // only after every producer has exited — workers then drain the
        // remainder and the exactly-once guarantee holds through exit.
        scope.spawn(move || {
            let next_id = AtomicU64::new(0);
            let next_id = &next_id;
            std::thread::scope(|sensors| {
                for s in 0..cfg.sensors.max(1) {
                    sensors.spawn(move || {
                        loadgen::run_sensor(
                            s, entries, queues_ref, cfg, start, deadline, next_id, trace_ref,
                        )
                    });
                }
            });
            stop_ref.store(true, Ordering::Release);
        });
        batcher::drain(queues_ref, entries, evals, &drain_cfg, stop_ref)
    })?;

    let elapsed_s = start.elapsed().as_secs_f64();
    let eval_name = evals
        .first()
        .map(|e| e.name())
        .unwrap_or(resolve_serve_backend(cfg.backend).label());
    let mut models = Vec::with_capacity(registry.len());
    for (entry, queue) in registry.entries().iter().zip(&queues) {
        let st = &queue.stats;
        let answered = st.answered.load(Ordering::Relaxed);
        let batches = st.batches.load(Ordering::Relaxed);
        let lane_slots = st.lane_slots.load(Ordering::Relaxed);
        let lat = st.latencies_ms.lock().unwrap();
        models.push(ModelReport {
            name: entry.name.clone(),
            requests: st.submitted.load(Ordering::Relaxed),
            answered,
            errors: st.errors.load(Ordering::Relaxed),
            shed: st.shed.load(Ordering::Relaxed),
            batches,
            mean_batch: answered as f64 / batches.max(1) as f64,
            fill: if lane_slots == 0 {
                1.0
            } else {
                answered as f64 / lane_slots as f64
            },
            throughput_rps: answered as f64 / elapsed_s.max(1e-9),
            p50_ms: stats::percentile(lat.samples(), 50.0),
            p99_ms: stats::percentile(lat.samples(), 99.0),
            slo_ms: cfg.slo_ms,
            slo_violations: st.slo_violations.load(Ordering::Relaxed),
            accuracy: st.correct.load(Ordering::Relaxed) as f64 / answered.max(1) as f64,
        });
    }
    Ok(ServerReport {
        backend: eval_name,
        scenario: cfg.scenario,
        workers,
        elapsed_s,
        models,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_hosts_three_datasets() {
        let c = ServeConfig::default();
        assert_eq!(c.datasets.len(), 3);
        assert_eq!(c.scenario, Scenario::Steady);
        assert!(c.queue_cap >= 1);
        assert!(!c.synthetic);
        assert!(c.trace.is_none() && c.trace_out.is_none());
    }

    #[test]
    fn auto_backend_resolves_to_native_for_serving() {
        assert_eq!(resolve_serve_backend(Backend::Auto), Backend::Native);
        assert_eq!(resolve_serve_backend(Backend::GateSim), Backend::GateSim);
    }

    #[test]
    fn empty_dataset_list_rejected() {
        let store = ArtifactStore::new("/nonexistent");
        let cfg = ServeConfig {
            datasets: Vec::new(),
            ..ServeConfig::default()
        };
        assert!(run(&store, &cfg).is_err());
    }

    #[test]
    fn serve_with_rejects_mismatched_evaluators() {
        let names = vec!["a".to_string(), "b".to_string()];
        let reg = ModelRegistry::synthetic(&names, 3);
        let evals = reg.evaluators(Backend::Native, 1, 0).unwrap();
        let one = ModelRegistry::synthetic(&names[..1], 3);
        assert!(serve_with(&one, &evals, &ServeConfig::default()).is_err());
        assert!(serve_with(&ModelRegistry::new(), &[], &ServeConfig::default()).is_err());
    }
}
