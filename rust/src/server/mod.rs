//! Multi-tenant model server: the deployment story of the paper's intro
//! (wearables streaming multi-sensory frames into several bespoke
//! sequential MLPs) as a first-class subsystem.
//!
//! Six pieces (DESIGN.md §Server, §Faults, §Ingress):
//!
//! - [`registry`] — [`registry::ModelRegistry`]: every hosted dataset's
//!   artifacts (model, masks, [`crate::model::ApproxTables`], and — via
//!   warmup — the gatesim circuit and its compiled
//!   [`crate::sim::SimPlan`]) loaded once; [`registry::ModelSlot`] wraps
//!   each in a versioned, hot-swappable slot for zero-downtime reload.
//! - [`admission`] — per-tenant SLO classes ([`SloClass`]:
//!   gold/silver/bronze) setting each queue's admission ceiling and the
//!   workers' drain priority, so overload sheds bronze first.
//! - [`batcher`] — per-model bounded [`batcher::BatchQueue`]s with shed
//!   counters, drained by a [`crate::util::pool::scope_map_with`] worker
//!   pool running dynamic batching with a `max_wait` linger, optional
//!   deadline shedding, and canary shadowing of staged candidates.
//! - [`frontend`] — the non-blocking TCP ingress speaking length-
//!   prefixed PMLP-style frames; every accepted frame is answered or
//!   explicitly refused, even through shutdown.
//! - [`loadgen`] — scenario-driven sensors ([`loadgen::Scenario`]:
//!   steady / bursty / ramp / fanin / trace) pushing frames at the
//!   queues directly or through a real socket
//!   ([`loadgen::run_tcp_sensor`], open-loop and coordinated-omission-
//!   correct); `trace` replays a recorded [`loadgen::Trace`] so the
//!   offered stream is bit-reproducible.
//! - [`campaign`] — the printed-hardware fault campaign: sweeps
//!   stuck-at / transient fault levels per circuit architecture and
//!   reports accuracy degradation and SLO impact through the same serve
//!   path.
//!
//! [`run`] wires registry + slots together and hands off to
//! [`serve_with`], which returns a [`ServerReport`] with per-model
//! requests, p50/p99 latency, shed/late/error counts, SLO violations,
//! canary agreement, and accuracy.  Under `steady` at the default rate
//! nothing sheds and every prediction is bit-identical to a direct
//! [`Evaluator::predict`] call (`tests/server_batching.rs`,
//! `tests/server_frontend.rs`).

pub mod admission;
pub mod batcher;
pub mod campaign;
pub mod frontend;
pub mod loadgen;
pub mod registry;

use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::data::ArtifactStore;
use crate::runtime::{owned_evaluator, Backend, EvalOpts, Evaluator};
use crate::util::pool::default_threads;
use crate::util::stats;

pub use admission::{SloClass, CLASS_ORDER};
pub use batcher::{BatchQueue, DrainConfig, Frame, ModelStats};
pub use campaign::{ArchKind, CampaignConfig, CampaignReport, CampaignRow};
pub use frontend::{Frontend, FrontendStats, Status};
pub use loadgen::{ClientStats, Scenario, Trace};
pub use registry::{FusedSlot, ModelEntry, ModelRegistry, ModelSlot, ModelVersion};

/// Server configuration (see `config` for the `[serve]` file section;
/// every key has a CLI override).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Datasets to host concurrently (one model + queue each).
    pub datasets: Vec<String>,
    /// Per-tenant SLO classes, positional with `datasets`; models past
    /// the end of the list default to gold (an empty list reproduces
    /// the classless server exactly).
    pub classes: Vec<SloClass>,
    pub scenario: Scenario,
    /// Offered load, frames per second across all sensors and models
    /// (for `fanin`: window rate — each window feeds every model).
    pub rate_hz: f64,
    pub duration: Duration,
    /// Max time the batcher lets a sub-full batch linger.
    pub max_wait: Duration,
    pub sensors: usize,
    /// Drain workers (0 = one per core).
    pub workers: usize,
    /// Max frames per executed batch.
    pub batch: usize,
    /// Bounded queue capacity per model; overflow is shed (non-gold
    /// tenants shed earlier — [`SloClass::admit_limit`]).
    pub queue_cap: usize,
    /// Per-frame latency SLO in milliseconds.
    pub slo_ms: f64,
    /// Refuse (`Late`) frames whose SLO already expired while queued
    /// instead of evaluating dead work.  Off by default so the
    /// trace-replay determinism paths keep `requests == answered`.
    pub shed_late: bool,
    pub seed: u64,
    /// Evaluator backend on the request path (`Auto` → native; PJRT is
    /// rejected — its handles cannot cross the worker pool).
    pub backend: Backend,
    /// Gatesim super-lane width in `u64` words (0 =
    /// [`crate::sim::lane_words_default`]); the batcher aligns drains to
    /// the resulting `W·64`-sample block.
    pub sim_lanes: usize,
    /// Host deterministic synthetic models instead of store artifacts
    /// (artifact-free smoke/bench mode; accuracy 1.0 expected).
    pub synthetic: bool,
    /// Serve over TCP: bind this address (port 0 = ephemeral) and drive
    /// the scenario through real sockets ([`loadgen::run_tcp_sensor`])
    /// instead of in-process queue pushes.  `None` = direct mode.
    pub listen: Option<String>,
    /// Hot reload: this long after start, stage a freshly built
    /// evaluator for every model and promote it (immediately, or after
    /// a canary window when `canary_frac > 0`).  Ignored when not
    /// before the run's end.
    pub reload_at: Option<Duration>,
    /// Fraction of batches shadow-evaluated on a staged candidate, with
    /// incumbent/candidate mismatches counted
    /// ([`ModelStats::canary_mismatches`]).  0 disables the canary.
    pub canary_frac: f64,
    /// §Fusion: drain every tenant through one cross-model fused gatesim
    /// plan per sweep ([`batcher::drain_fused`]) instead of per-model
    /// evaluator calls — the fan-in fast path.  Requires
    /// `--backend gatesim`; the drain-workers knob becomes the fused
    /// simulator's shard threads.
    pub fuse_models: bool,
    /// `trace` scenario: replay this recorded trace file; when unset a
    /// diurnal trace is synthesized from `seed`/`rate_hz`/`duration`.
    pub trace: Option<PathBuf>,
    /// Write the trace actually replayed (loaded or synthesized) to this
    /// path — how a synthesized day-curve becomes a reusable artifact.
    pub trace_out: Option<PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            datasets: vec!["spectf".into(), "arrhythmia".into(), "gas".into()],
            classes: Vec::new(),
            scenario: Scenario::Steady,
            rate_hz: 2000.0,
            duration: Duration::from_secs(3),
            max_wait: Duration::from_millis(2),
            sensors: 4,
            workers: 0,
            batch: 64,
            queue_cap: 1024,
            slo_ms: 50.0,
            shed_late: false,
            seed: 7,
            backend: Backend::Auto,
            sim_lanes: 0,
            synthetic: false,
            listen: None,
            reload_at: None,
            canary_frac: 0.0,
            fuse_models: false,
            trace: None,
            trace_out: None,
        }
    }
}

/// Request-path summary for one hosted model.
#[derive(Clone, Debug)]
pub struct ModelReport {
    pub name: String,
    /// Tenant SLO class the model served under.
    pub class: SloClass,
    /// Model version serving at the end of the run (2+ after a reload).
    pub version: u64,
    /// Frames offered (answered + shed + late + errors).
    pub requests: usize,
    pub answered: usize,
    /// Frames whose batch failed in the evaluator (see
    /// [`ModelStats::errors`]); 0 on a healthy run.
    pub errors: usize,
    pub shed: usize,
    /// Frames deadline-shed while queued ([`ServeConfig::shed_late`]).
    pub late: usize,
    pub batches: usize,
    pub mean_batch: f64,
    /// Super-lane fill ratio: answered frames / simulator lane slots
    /// consumed (1.0 on scalar backends and for perfectly aligned
    /// gatesim batches).
    pub fill: f64,
    pub throughput_rps: f64,
    /// In TCP mode these are client-side open-loop latencies measured
    /// from each frame's *scheduled* send instant (coordinated-omission
    /// correct); in direct mode, queue-to-answer latency.
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub slo_ms: f64,
    pub slo_violations: usize,
    /// Canary shadow volume and disagreements for this run.
    pub canary_checked: usize,
    pub canary_mismatches: usize,
    pub accuracy: f64,
}

/// Ingress-layer summary, present only for TCP (`--listen`) runs.
#[derive(Clone, Debug)]
pub struct IngressReport {
    /// Address actually bound (resolves port 0).
    pub listen: String,
    pub connections: usize,
    /// Well-formed request frames decoded.
    pub frames_in: usize,
    /// Refused at the frontend (unknown model / bad shape).
    pub refused: usize,
    pub malformed: usize,
    /// Connections closed by the partial-frame read deadline.
    pub deadline_closed: usize,
    /// Client-side totals across all sensors.
    pub client_sent: usize,
    pub client_answered: usize,
    /// Accepted frames that never got an answer — the socket-boundary
    /// exactly-once guarantee requires this to be 0.
    pub client_lost: usize,
}

/// Per-SLO-class aggregation of a run (see [`ServerReport::class_rows`]).
#[derive(Clone, Debug)]
pub struct ClassRow {
    pub class: SloClass,
    pub models: usize,
    pub requests: usize,
    pub answered: usize,
    pub shed: usize,
    pub late: usize,
    pub slo_violations: usize,
    /// Worst per-model p99 within the class.
    pub p99_ms: f64,
}

/// Whole-run summary across every hosted model.
#[derive(Clone, Debug)]
pub struct ServerReport {
    /// Resolved backend that actually served the run.
    pub backend: &'static str,
    pub scenario: Scenario,
    pub workers: usize,
    pub elapsed_s: f64,
    pub models: Vec<ModelReport>,
    /// TCP ingress stats; `None` for direct (in-process) runs.
    pub ingress: Option<IngressReport>,
}

impl ServerReport {
    pub fn total_requests(&self) -> usize {
        self.models.iter().map(|m| m.requests).sum()
    }

    pub fn total_answered(&self) -> usize {
        self.models.iter().map(|m| m.answered).sum()
    }

    pub fn total_shed(&self) -> usize {
        self.models.iter().map(|m| m.shed).sum()
    }

    pub fn total_late(&self) -> usize {
        self.models.iter().map(|m| m.late).sum()
    }

    pub fn total_errors(&self) -> usize {
        self.models.iter().map(|m| m.errors).sum()
    }

    pub fn total_rps(&self) -> f64 {
        self.total_answered() as f64 / self.elapsed_s.max(1e-9)
    }

    /// Aggregate the per-model rows by SLO class, gold first; classes
    /// hosting no model are omitted.
    pub fn class_rows(&self) -> Vec<ClassRow> {
        CLASS_ORDER
            .iter()
            .filter_map(|&class| {
                let ms: Vec<&ModelReport> =
                    self.models.iter().filter(|m| m.class == class).collect();
                if ms.is_empty() {
                    return None;
                }
                Some(ClassRow {
                    class,
                    models: ms.len(),
                    requests: ms.iter().map(|m| m.requests).sum(),
                    answered: ms.iter().map(|m| m.answered).sum(),
                    shed: ms.iter().map(|m| m.shed).sum(),
                    late: ms.iter().map(|m| m.late).sum(),
                    slo_violations: ms.iter().map(|m| m.slo_violations).sum(),
                    p99_ms: ms.iter().map(|m| m.p99_ms).fold(0.0, f64::max),
                })
            })
            .collect()
    }
}

/// Resolve the serve-path backend: `Auto` prefers native (the worker
/// pool needs `Send + Sync` evaluators, which rules out PJRT).
fn resolve_serve_backend(b: Backend) -> Backend {
    match b {
        Backend::Auto => Backend::Native,
        other => other,
    }
}

fn sleep_until(target: Instant) {
    let now = Instant::now();
    if target > now {
        std::thread::sleep(target - now);
    }
}

/// Run the multi-model streaming workload and report per-model stats.
pub fn run(store: &ArtifactStore, cfg: &ServeConfig) -> Result<ServerReport> {
    ensure!(!cfg.datasets.is_empty(), "serve: no datasets requested");
    let registry = if cfg.synthetic {
        ModelRegistry::synthetic(&cfg.datasets, cfg.seed)
    } else {
        ModelRegistry::from_store(store, &cfg.datasets)?
    };
    let backend = resolve_serve_backend(cfg.backend);
    // Sim shards stay at 1: the drain workers are already the
    // parallelism, and nesting pools would oversubscribe to threads².
    // The super-lane width rides through so warmup compiles the plan a
    // wide simulator will execute and the batcher can align to it.
    let slots = registry.slots(backend, 1, cfg.sim_lanes, &cfg.classes)?;
    serve_with(&slots, cfg)
}

/// Serve already-built model slots — the shared lower half of [`run`],
/// of the fault campaign (which stages fault-carrying gatesim
/// evaluators the plain entry point would never construct), and of the
/// tier-1 overload/reload tests.
///
/// Wiring: an optional TCP [`Frontend`] and an optional hot-reload
/// timer run beside the sensor threads; the batcher drains on the
/// calling thread.  Shutdown order is producers → frontend drain →
/// batcher drain, so every accepted frame is answered before anything
/// exits and the exactly-once guarantee extends across the socket
/// boundary.
pub fn serve_with(slots: &[Arc<ModelSlot>], cfg: &ServeConfig) -> Result<ServerReport> {
    ensure!(!slots.is_empty(), "serve: no model slots");
    let trace = if cfg.scenario == Scenario::Trace {
        let tr = match &cfg.trace {
            Some(path) => Trace::load(path)?,
            None => Trace::synth_diurnal(cfg.seed, cfg.rate_hz, cfg.duration, slots.len()),
        };
        ensure!(!tr.is_empty(), "trace scenario: trace has no requests");
        if let Some(out) = &cfg.trace_out {
            tr.save(out)?;
        }
        Some(tr)
    } else {
        None
    };
    let trace_ref = trace.as_ref();

    let workers = if cfg.workers == 0 { default_threads() } else { cfg.workers.max(1) };
    let queues: Vec<BatchQueue> = slots
        .iter()
        .map(|s| BatchQueue::with_admission(cfg.queue_cap, s.class.admit_limit(cfg.queue_cap)))
        .collect();
    let drain_cfg = DrainConfig {
        workers,
        batch: cfg.batch.max(1),
        max_wait: cfg.max_wait,
        slo_ms: cfg.slo_ms,
        shed_late: cfg.shed_late,
        canary_step: batcher::canary_step(cfg.canary_frac),
        collect_responses: false,
    };
    // §Fusion: one cross-model fused gatesim plan drains every tenant's
    // queue in a single sharded pass; the drain-workers knob becomes the
    // fused simulator's shard threads.  Resolve (build + warm) the fused
    // plan here, before any producer starts, so plan compilation is off
    // the request path — exactly like per-slot warmup.
    let fused = if cfg.fuse_models {
        ensure!(
            resolve_serve_backend(cfg.backend) == Backend::GateSim,
            "serve: --fuse-models requires --backend gatesim \
             (fusion concatenates compiled gate-level plans)"
        );
        let f = FusedSlot::new(slots, workers, cfg.sim_lanes);
        f.resolve()?;
        Some(f)
    } else {
        None
    };
    // Bind before anything starts so ephemeral ports resolve and
    // clients can connect from their first instant.
    let frontend = match &cfg.listen {
        Some(addr) => Some(Frontend::bind(addr)?),
        None => None,
    };
    let bound: Option<SocketAddr> = frontend.as_ref().map(|f| f.local_addr());

    // Entry snapshot for the load generators: samples (and client-side
    // labels) are drawn against the versions hosted at start, so a
    // mid-run reload does not disturb the offered stream.
    let entries: Vec<Arc<ModelEntry>> = slots
        .iter()
        .map(|s| Arc::clone(&s.current().entry))
        .collect();

    let stop = AtomicBool::new(false);
    let fe_stop = AtomicBool::new(false);
    let client_stats: Mutex<Vec<ClientStats>> =
        Mutex::new(vec![ClientStats::default(); slots.len()]);
    let side_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);

    let start = Instant::now();
    let deadline = start + cfg.duration;

    let queues_ref = &queues;
    let entries_ref = &entries[..];
    let stop_ref = &stop;
    let fe_stop_ref = &fe_stop;
    let client_stats_ref = &client_stats;
    let side_err_ref = &side_err;
    let backend = resolve_serve_backend(cfg.backend);

    std::thread::scope(|scope| -> Result<()> {
        if let Some(fe) = &frontend {
            scope.spawn(move || {
                if let Err(e) = fe.run(slots, queues_ref, fe_stop_ref) {
                    let mut slot = side_err_ref.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(e.context("ingress frontend failed"));
                    }
                }
            });
        }
        if let Some(at) = cfg.reload_at.filter(|&at| at < cfg.duration) {
            scope.spawn(move || {
                let opts = EvalOpts {
                    sim_threads: 1,
                    sim_lanes: cfg.sim_lanes,
                    ..EvalOpts::default()
                };
                let target = start + at;
                sleep_until(target);
                for slot in slots {
                    // Rebuild from the entry the slot currently hosts —
                    // the printed-deployment story re-fabricates the
                    // same logical model; stage() warms it off-path.
                    let entry = Arc::clone(&slot.current().entry);
                    let staged = owned_evaluator(backend, &entry.model, &opts)
                        .and_then(|eval| slot.stage(entry, eval));
                    if let Err(e) = staged {
                        let mut guard = side_err_ref.lock().unwrap();
                        if guard.is_none() {
                            *guard =
                                Some(e.context(format!("hot reload of `{}` failed", slot.name)));
                        }
                        return;
                    }
                }
                if cfg.canary_frac > 0.0 {
                    // Shadow the candidates on live traffic for half the
                    // remaining run before promoting, so the mismatch
                    // counters mean something.
                    sleep_until(target + (deadline - target) / 2);
                }
                for slot in slots {
                    slot.promote();
                }
            });
        }
        // Producer side: sensors run in a nested scope so `stop` flips
        // only after every producer has exited — workers then drain the
        // remainder and the exactly-once guarantee holds through exit.
        scope.spawn(move || {
            let next_id = AtomicU64::new(0);
            let next_id = &next_id;
            std::thread::scope(|sensors| {
                for s in 0..cfg.sensors.max(1) {
                    match bound {
                        Some(addr) => {
                            sensors.spawn(move || {
                                match loadgen::run_tcp_sensor(
                                    s, entries_ref, addr, cfg, start, deadline, trace_ref,
                                ) {
                                    Ok(per_model) => {
                                        let mut all = client_stats_ref.lock().unwrap();
                                        for (acc, got) in all.iter_mut().zip(per_model) {
                                            acc.merge(got);
                                        }
                                    }
                                    Err(e) => {
                                        let mut guard = side_err_ref.lock().unwrap();
                                        if guard.is_none() {
                                            *guard =
                                                Some(e.context(format!("tcp sensor {s} failed")));
                                        }
                                    }
                                }
                            });
                        }
                        None => {
                            sensors.spawn(move || {
                                loadgen::run_sensor(
                                    s, entries_ref, queues_ref, cfg, start, deadline, next_id,
                                    trace_ref,
                                )
                            });
                        }
                    }
                }
            });
            // Sensors have exited (TCP clients only return once every
            // accepted frame is answered or charged lost), so nothing
            // new can arrive: drain the frontend, then the batcher.
            fe_stop_ref.store(true, Ordering::Release);
            stop_ref.store(true, Ordering::Release);
        });
        match &fused {
            Some(f) => batcher::drain_fused(queues_ref, slots, f, &drain_cfg, stop_ref),
            None => batcher::drain(queues_ref, slots, &drain_cfg, stop_ref),
        }
    })?;

    let elapsed_s = start.elapsed().as_secs_f64();
    if let Some(e) = side_err.into_inner().unwrap() {
        return Err(e);
    }
    let merged = client_stats.into_inner().unwrap();
    let tcp = bound.is_some();
    let eval_name = slots[0].current().eval.name();
    let mut models = Vec::with_capacity(slots.len());
    for (i, (slot, queue)) in slots.iter().zip(&queues).enumerate() {
        let st = &queue.stats;
        let answered = st.answered.load(Ordering::Relaxed);
        let batches = st.batches.load(Ordering::Relaxed);
        let lane_slots = st.lane_slots.load(Ordering::Relaxed);
        // TCP runs score latency and accuracy client-side: open-loop
        // from the scheduled send instant, labels from the sensor's own
        // entry snapshot.  Direct runs keep the server-side view.
        let (p50_ms, p99_ms, accuracy) = if tcp {
            let cs = &merged[i];
            (
                stats::percentile(&cs.latencies_ms, 50.0),
                stats::percentile(&cs.latencies_ms, 99.0),
                cs.correct as f64 / cs.ok.max(1) as f64,
            )
        } else {
            let lat = st.latencies_ms.lock().unwrap();
            (
                stats::percentile(lat.samples(), 50.0),
                stats::percentile(lat.samples(), 99.0),
                st.correct.load(Ordering::Relaxed) as f64 / answered.max(1) as f64,
            )
        };
        models.push(ModelReport {
            name: slot.name.clone(),
            class: slot.class,
            version: slot.version(),
            requests: st.submitted.load(Ordering::Relaxed),
            answered,
            errors: st.errors.load(Ordering::Relaxed),
            shed: st.shed.load(Ordering::Relaxed),
            late: st.late.load(Ordering::Relaxed),
            batches,
            mean_batch: answered as f64 / batches.max(1) as f64,
            fill: if lane_slots == 0 {
                1.0
            } else {
                answered as f64 / lane_slots as f64
            },
            throughput_rps: answered as f64 / elapsed_s.max(1e-9),
            p50_ms,
            p99_ms,
            slo_ms: cfg.slo_ms,
            slo_violations: st.slo_violations.load(Ordering::Relaxed),
            canary_checked: st.canary_checked.load(Ordering::Relaxed),
            canary_mismatches: st.canary_mismatches.load(Ordering::Relaxed),
            accuracy,
        });
    }
    let ingress = frontend.as_ref().map(|fe| {
        let fs = &fe.stats;
        IngressReport {
            listen: fe.local_addr().to_string(),
            connections: fs.connections.load(Ordering::Relaxed),
            frames_in: fs.frames_in.load(Ordering::Relaxed),
            refused: fs.refused.load(Ordering::Relaxed),
            malformed: fs.malformed.load(Ordering::Relaxed),
            deadline_closed: fs.deadline_closed.load(Ordering::Relaxed),
            client_sent: merged.iter().map(|c| c.sent).sum(),
            client_answered: merged.iter().map(|c| c.answered()).sum(),
            client_lost: merged.iter().map(|c| c.lost).sum(),
        }
    });
    Ok(ServerReport {
        backend: eval_name,
        scenario: cfg.scenario,
        workers,
        elapsed_s,
        models,
        ingress,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_hosts_three_datasets() {
        let c = ServeConfig::default();
        assert_eq!(c.datasets.len(), 3);
        assert_eq!(c.scenario, Scenario::Steady);
        assert!(c.queue_cap >= 1);
        assert!(!c.synthetic);
        assert!(c.trace.is_none() && c.trace_out.is_none());
        // Ingress / admission / reload are all opt-in: the defaults
        // reproduce the classless in-process server exactly.
        assert!(c.classes.is_empty());
        assert!(c.listen.is_none());
        assert!(c.reload_at.is_none());
        assert_eq!(c.canary_frac, 0.0);
        assert!(!c.shed_late);
        assert!(!c.fuse_models, "fusion is opt-in");
    }

    #[test]
    fn auto_backend_resolves_to_native_for_serving() {
        assert_eq!(resolve_serve_backend(Backend::Auto), Backend::Native);
        assert_eq!(resolve_serve_backend(Backend::GateSim), Backend::GateSim);
    }

    #[test]
    fn empty_dataset_list_rejected() {
        let store = ArtifactStore::new("/nonexistent");
        let cfg = ServeConfig {
            datasets: Vec::new(),
            ..ServeConfig::default()
        };
        assert!(run(&store, &cfg).is_err());
    }

    #[test]
    fn serve_with_requires_slots() {
        assert!(serve_with(&[], &ServeConfig::default()).is_err());
    }

    #[test]
    fn fuse_models_requires_gatesim_backend() {
        let reg = ModelRegistry::synthetic(&["a".to_string()], 3);
        let slots = reg.slots(Backend::Native, 1, 0, &[]).unwrap();
        let cfg = ServeConfig {
            fuse_models: true,
            backend: Backend::Native,
            duration: Duration::from_millis(10),
            ..ServeConfig::default()
        };
        assert!(serve_with(&slots, &cfg).is_err());
    }

    #[test]
    fn class_rows_aggregate_gold_first() {
        let row = |name: &str, class: SloClass, shed: usize, p99: f64| ModelReport {
            name: name.into(),
            class,
            version: 1,
            requests: 10,
            answered: 10 - shed,
            errors: 0,
            shed,
            late: 0,
            batches: 1,
            mean_batch: (10 - shed) as f64,
            fill: 1.0,
            throughput_rps: 100.0,
            p50_ms: p99 / 2.0,
            p99_ms: p99,
            slo_ms: 50.0,
            slo_violations: 0,
            canary_checked: 0,
            canary_mismatches: 0,
            accuracy: 1.0,
        };
        let rep = ServerReport {
            backend: "native",
            scenario: Scenario::Steady,
            workers: 1,
            elapsed_s: 0.1,
            models: vec![
                row("b0", SloClass::Bronze, 4, 9.0),
                row("g0", SloClass::Gold, 0, 3.0),
                row("b1", SloClass::Bronze, 2, 7.0),
            ],
            ingress: None,
        };
        let rows = rep.class_rows();
        assert_eq!(rows.len(), 2, "silver hosts no model");
        assert_eq!(rows[0].class, SloClass::Gold);
        assert_eq!(rows[0].requests, 10);
        assert_eq!(rows[1].class, SloClass::Bronze);
        assert_eq!(rows[1].models, 2);
        assert_eq!(rows[1].shed, 6);
        assert_eq!(rows[1].p99_ms, 9.0);
        assert_eq!(rep.total_late(), 0);
    }
}
