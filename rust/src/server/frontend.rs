//! Non-blocking TCP ingress for the model server.
//!
//! The frontend speaks a length-prefixed binary frame protocol that
//! mirrors the PMLP artifact format (little-endian, magic-tagged):
//!
//! ```text
//! frame    := len:u32le payload              (len = payload bytes, ≤ 4096)
//! request  := magic:u32le kind:u8=1 model:u16le id:u64le
//!             nfeat:u16le feat:[u8; nfeat]
//! response := magic:u32le kind:u8=2 model:u16le id:u64le
//!             status:u8 pred:i32le
//! ```
//!
//! `magic` is `0x504D_4C46` — the ASCII bytes `"FLMP"` on the wire, the
//! frame-sibling of the `"PLMP"` data magic.  `status` is a
//! [`Status`] code; `pred` is `-1` for every non-[`Status::Ok`] answer.
//!
//! Design rules, in the spirit of the rest of the crate (no tokio, no
//! epoll bindings — one plain thread, non-blocking sockets, bounded
//! buffers):
//!
//! - **Every accepted frame is answered.** A decoded request either
//!   enters its model's [`BatchQueue`] (answered `Ok`/`Shed`/`Late`/
//!   `Error` by the batcher, exactly once) or is refused on the spot
//!   (`Refused`: unknown model id or feature-count mismatch).  Shutdown
//!   drains: the loop stops *reading* but keeps flushing until every
//!   in-flight frame has been answered and written back.
//! - **A bad client only loses its own connection.** Malformed frames
//!   (bad magic/kind/shape, oversized or runt length prefix) close that
//!   connection; the accept loop never unwinds.
//! - **Slow writers cannot pin memory.** A partial frame older than
//!   [`Frontend::read_deadline`] closes the connection, and at most
//!   [`Frontend::max_inflight`] frames per connection may be inside the
//!   server at once — past the bound the frontend simply stops reading
//!   that socket, which surfaces to the client as TCP backpressure.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::server::batcher::{BatchQueue, Frame};
use crate::server::registry::ModelSlot;

/// Frame magic: ASCII `"FLMP"` little-endian on the wire.
pub const FRAME_MAGIC: u32 = 0x504D_4C46;
pub const KIND_REQUEST: u8 = 1;
pub const KIND_RESPONSE: u8 = 2;
/// Maximum payload bytes per frame (the length prefix is not counted).
pub const MAX_FRAME: usize = 4096;
/// Bytes of the `u32` length prefix.
pub const LEN_PREFIX: usize = 4;

/// Request payload bytes before the feature vector.
const REQ_HEADER: usize = 17;
/// Response payload bytes (fixed-size).
const RESP_LEN: usize = 20;
/// Per-poll socket read size.
const READ_CHUNK: usize = 4096;

/// Outcome code carried in every response frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    /// Answered by the model; `pred` is the class label.
    Ok,
    /// Shed at admission (queue past the tenant class ceiling).
    Shed,
    /// Deadline-shed: the frame aged past its SLO while queued.
    Late,
    /// Refused at the frontend: unknown model or wrong feature count.
    Refused,
    /// The batch evaluating this frame failed.
    Error,
}

impl Status {
    pub fn code(self) -> u8 {
        match self {
            Status::Ok => 0,
            Status::Shed => 1,
            Status::Late => 2,
            Status::Refused => 3,
            Status::Error => 4,
        }
    }

    pub fn from_code(code: u8) -> Result<Status> {
        Ok(match code {
            0 => Status::Ok,
            1 => Status::Shed,
            2 => Status::Late,
            3 => Status::Refused,
            4 => Status::Error,
            other => bail!("unknown response status code {other}"),
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            Status::Ok => "ok",
            Status::Shed => "shed",
            Status::Late => "late",
            Status::Refused => "refused",
            Status::Error => "error",
        }
    }
}

/// A decoded request frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    pub model: u16,
    pub id: u64,
    pub features: Vec<u8>,
}

/// A decoded response frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Response {
    pub model: u16,
    pub id: u64,
    pub status: Status,
    pub pred: i32,
}

/// Encode a request as a wire frame, length prefix included.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let n = req.features.len();
    debug_assert!(REQ_HEADER + n <= MAX_FRAME);
    let len = REQ_HEADER + n;
    let mut buf = Vec::with_capacity(LEN_PREFIX + len);
    buf.extend_from_slice(&(len as u32).to_le_bytes());
    buf.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
    buf.push(KIND_REQUEST);
    buf.extend_from_slice(&req.model.to_le_bytes());
    buf.extend_from_slice(&req.id.to_le_bytes());
    buf.extend_from_slice(&(n as u16).to_le_bytes());
    buf.extend_from_slice(&req.features);
    buf
}

/// Encode a response as a wire frame, length prefix included.
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut buf = Vec::with_capacity(LEN_PREFIX + RESP_LEN);
    buf.extend_from_slice(&(RESP_LEN as u32).to_le_bytes());
    buf.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
    buf.push(KIND_RESPONSE);
    buf.extend_from_slice(&resp.model.to_le_bytes());
    buf.extend_from_slice(&resp.id.to_le_bytes());
    buf.push(resp.status.code());
    buf.extend_from_slice(&resp.pred.to_le_bytes());
    buf
}

/// Decode a request payload (frame bytes after the length prefix).
pub fn decode_request(payload: &[u8]) -> Result<Request> {
    ensure!(
        payload.len() >= REQ_HEADER,
        "request frame too short: {} bytes",
        payload.len()
    );
    let magic = u32::from_le_bytes(payload[0..4].try_into().unwrap());
    ensure!(magic == FRAME_MAGIC, "bad frame magic {magic:#010x}");
    ensure!(
        payload[4] == KIND_REQUEST,
        "unexpected frame kind {} (want request)",
        payload[4]
    );
    let model = u16::from_le_bytes(payload[5..7].try_into().unwrap());
    let id = u64::from_le_bytes(payload[7..15].try_into().unwrap());
    let nfeat = u16::from_le_bytes(payload[15..17].try_into().unwrap()) as usize;
    ensure!(
        payload.len() == REQ_HEADER + nfeat,
        "feature payload mismatch: header says {nfeat}, frame holds {}",
        payload.len() - REQ_HEADER
    );
    Ok(Request {
        model,
        id,
        features: payload[REQ_HEADER..].to_vec(),
    })
}

/// Decode a response payload (frame bytes after the length prefix).
pub fn decode_response(payload: &[u8]) -> Result<Response> {
    ensure!(
        payload.len() == RESP_LEN,
        "response frame is {} bytes (want {RESP_LEN})",
        payload.len()
    );
    let magic = u32::from_le_bytes(payload[0..4].try_into().unwrap());
    ensure!(magic == FRAME_MAGIC, "bad frame magic {magic:#010x}");
    ensure!(
        payload[4] == KIND_RESPONSE,
        "unexpected frame kind {} (want response)",
        payload[4]
    );
    Ok(Response {
        model: u16::from_le_bytes(payload[5..7].try_into().unwrap()),
        id: u64::from_le_bytes(payload[7..15].try_into().unwrap()),
        status: Status::from_code(payload[15])?,
        pred: i32::from_le_bytes(payload[16..20].try_into().unwrap()),
    })
}

/// Split one complete frame off the front of a receive buffer.
///
/// Returns `Ok(None)` when more bytes are needed, `Ok(Some(payload))`
/// with the prefix and payload drained from `buf`, and `Err` for a
/// length prefix that can never become a valid frame (oversized or
/// runt) — the caller must close the connection.
pub fn split_frame(buf: &mut Vec<u8>) -> Result<Option<Vec<u8>>> {
    if buf.len() < LEN_PREFIX {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[0..LEN_PREFIX].try_into().unwrap()) as usize;
    ensure!(len <= MAX_FRAME, "oversized frame: {len} bytes (max {MAX_FRAME})");
    ensure!(len >= 5, "runt frame: {len} bytes");
    if buf.len() < LEN_PREFIX + len {
        return Ok(None);
    }
    let payload = buf[LEN_PREFIX..LEN_PREFIX + len].to_vec();
    buf.drain(..LEN_PREFIX + len);
    Ok(Some(payload))
}

/// Per-connection state shared between the frontend thread (which owns
/// the socket) and the batcher workers (which answer frames).  Workers
/// append encoded response frames to `out`; the frontend flushes it.
/// `inflight` counts frames accepted off this connection that have not
/// yet been answered — the read bound and the drain barrier.
#[derive(Debug, Default)]
pub struct ConnShared {
    out: Mutex<Vec<u8>>,
    inflight: AtomicUsize,
}

impl ConnShared {
    /// Answer one accepted frame: enqueue the response and release its
    /// in-flight slot.  Called exactly once per accepted frame.
    pub fn respond(&self, model: u16, id: u64, status: Status, pred: i32) {
        let frame = encode_response(&Response {
            model,
            id,
            status,
            pred,
        });
        self.out.lock().unwrap().extend_from_slice(&frame);
        self.inflight.fetch_sub(1, Ordering::AcqRel);
    }

    /// Refuse a frame at the frontend (unknown model, bad shape).  The
    /// frame never entered a queue, so no in-flight slot is released.
    pub fn refuse(&self, model: u16, id: u64) {
        let frame = encode_response(&Response {
            model,
            id,
            status: Status::Refused,
            pred: -1,
        });
        self.out.lock().unwrap().extend_from_slice(&frame);
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }
}

/// Counters for the ingress layer itself (queue-level accounting lives
/// in `ModelStats`).
#[derive(Debug, Default)]
pub struct FrontendStats {
    /// Connections accepted over the run.
    pub connections: AtomicUsize,
    /// Well-formed request frames decoded.
    pub frames_in: AtomicUsize,
    /// Frames refused at the frontend (unknown model / bad shape).
    pub refused: AtomicUsize,
    /// Malformed frames (each also closes its connection).
    pub malformed: AtomicUsize,
    /// Connections closed by the partial-frame read deadline.
    pub deadline_closed: AtomicUsize,
}

struct Conn {
    stream: std::net::TcpStream,
    shared: Arc<ConnShared>,
    buf: Vec<u8>,
    /// Still reading new frames. Cleared on EOF, protocol error,
    /// deadline, or server drain; answers already owed keep flushing.
    open: bool,
    /// Write side failed — nothing more can reach this client.
    dead: bool,
    partial_since: Option<Instant>,
}

impl Conn {
    fn new(stream: std::net::TcpStream) -> Conn {
        Conn {
            stream,
            shared: Arc::new(ConnShared::default()),
            buf: Vec::new(),
            open: true,
            dead: false,
            partial_since: None,
        }
    }
}

/// The TCP ingress: accepts connections, frames requests into the model
/// queues, and writes back every answer.  Single event-loop thread,
/// non-blocking sockets throughout.
pub struct Frontend {
    listener: TcpListener,
    addr: SocketAddr,
    /// Max frames per connection inside the server at once; past the
    /// bound the socket simply stops being read (TCP backpressure).
    pub max_inflight: usize,
    /// How long a partial frame may sit before the connection is closed.
    pub read_deadline: Duration,
    pub stats: Arc<FrontendStats>,
}

impl Frontend {
    /// Bind (but do not yet serve) a listener.  `listen` is a socket
    /// address; port 0 picks an ephemeral port — read it back via
    /// [`Frontend::local_addr`] before spawning clients.
    pub fn bind(listen: &str) -> Result<Frontend> {
        let listener = TcpListener::bind(listen)
            .with_context(|| format!("ingress: binding `{listen}`"))?;
        listener
            .set_nonblocking(true)
            .context("ingress: set_nonblocking on listener")?;
        let addr = listener.local_addr().context("ingress: local_addr")?;
        Ok(Frontend {
            listener,
            addr,
            max_inflight: 64,
            read_deadline: Duration::from_secs(2),
            stats: Arc::new(FrontendStats::default()),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until `shutdown` is raised *and* every accepted frame has
    /// been answered and flushed.  `slots[i]` / `queues[i]` pair up by
    /// model id.  Runs on the calling thread.
    pub fn run(
        &self,
        slots: &[Arc<ModelSlot>],
        queues: &[BatchQueue],
        shutdown: &AtomicBool,
    ) -> Result<()> {
        let mut conns: Vec<Conn> = Vec::new();
        let mut chunk = [0u8; READ_CHUNK];
        loop {
            let stopping = shutdown.load(Ordering::Acquire);
            let mut progressed = false;
            if !stopping {
                loop {
                    match self.listener.accept() {
                        Ok((stream, _)) => {
                            if stream.set_nonblocking(true).is_err() {
                                continue;
                            }
                            let _ = stream.set_nodelay(true);
                            self.stats.connections.fetch_add(1, Ordering::Relaxed);
                            conns.push(Conn::new(stream));
                            progressed = true;
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        Err(e) => return Err(e).context("ingress: accept failed"),
                    }
                }
            }
            for conn in conns.iter_mut() {
                if stopping {
                    // Drain: ingest nothing new, answer everything owed.
                    conn.open = false;
                }
                let mut at_bound = conn.shared.inflight() >= self.max_inflight;
                if conn.open && !at_bound {
                    match conn.stream.read(&mut chunk) {
                        Ok(0) => conn.open = false,
                        Ok(n) => {
                            conn.buf.extend_from_slice(&chunk[..n]);
                            progressed = true;
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                        Err(e) if e.kind() == ErrorKind::Interrupted => {}
                        Err(_) => conn.open = false,
                    }
                }
                while conn.open && !at_bound {
                    match split_frame(&mut conn.buf) {
                        Ok(Some(payload)) => {
                            progressed = true;
                            self.handle_frame(&payload, slots, queues, &conn.shared, &mut conn.open);
                            at_bound = conn.shared.inflight() >= self.max_inflight;
                        }
                        Ok(None) => break,
                        Err(_) => {
                            self.stats.malformed.fetch_add(1, Ordering::Relaxed);
                            conn.buf.clear();
                            conn.open = false;
                        }
                    }
                }
                // Partial-frame read deadline (slow-loris guard).  Only
                // ticks while the *client* is the blocker — a buffer
                // held back by the in-flight bound is the server's slow
                // batcher, not a slow writer.
                if conn.open && !conn.buf.is_empty() && !at_bound {
                    let since = *conn.partial_since.get_or_insert_with(Instant::now);
                    if since.elapsed() > self.read_deadline {
                        self.stats.deadline_closed.fetch_add(1, Ordering::Relaxed);
                        conn.buf.clear();
                        conn.open = false;
                    }
                } else {
                    conn.partial_since = None;
                }
                // Flush queued responses (batcher workers append).
                let mut out = conn.shared.out.lock().unwrap();
                while !out.is_empty() {
                    match conn.stream.write(&out) {
                        Ok(0) => {
                            conn.dead = true;
                            break;
                        }
                        Ok(n) => {
                            out.drain(..n);
                            progressed = true;
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => {}
                        Err(_) => {
                            conn.dead = true;
                            break;
                        }
                    }
                }
                drop(out);
            }
            conns.retain(|c| {
                !c.dead
                    && (c.open
                        || c.shared.inflight() > 0
                        || !c.shared.out.lock().unwrap().is_empty())
            });
            if stopping && conns.is_empty() {
                return Ok(());
            }
            if !progressed {
                thread::sleep(Duration::from_micros(200));
            }
        }
    }

    fn handle_frame(
        &self,
        payload: &[u8],
        slots: &[Arc<ModelSlot>],
        queues: &[BatchQueue],
        shared: &Arc<ConnShared>,
        open: &mut bool,
    ) {
        let req = match decode_request(payload) {
            Ok(r) => r,
            Err(_) => {
                self.stats.malformed.fetch_add(1, Ordering::Relaxed);
                *open = false;
                return;
            }
        };
        self.stats.frames_in.fetch_add(1, Ordering::Relaxed);
        let m = req.model as usize;
        if m >= queues.len() {
            self.stats.refused.fetch_add(1, Ordering::Relaxed);
            shared.refuse(req.model, req.id);
            return;
        }
        let want = slots[m].current().entry.model.features;
        if req.features.len() != want {
            self.stats.refused.fetch_add(1, Ordering::Relaxed);
            shared.refuse(req.model, req.id);
            return;
        }
        // Accepted: from here the frame is answered exactly once — by
        // admission shed inside `push`, or by the batcher.
        shared.inflight.fetch_add(1, Ordering::AcqRel);
        queues[m].push(Frame::remote(req.id, req.model, req.features, Arc::clone(shared)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_codec_roundtrip() {
        let req = Request {
            model: 3,
            id: 0xDEAD_BEEF_0042,
            features: vec![0, 1, 2, 250, 255],
        };
        let mut wire = encode_request(&req);
        let payload = split_frame(&mut wire).unwrap().unwrap();
        assert!(wire.is_empty(), "frame fully drained");
        assert_eq!(decode_request(&payload).unwrap(), req);
    }

    #[test]
    fn response_codec_roundtrip_all_statuses() {
        for (i, status) in [
            Status::Ok,
            Status::Shed,
            Status::Late,
            Status::Refused,
            Status::Error,
        ]
        .into_iter()
        .enumerate()
        {
            assert_eq!(Status::from_code(status.code()).unwrap(), status);
            assert_eq!(status.code(), i as u8);
            let resp = Response {
                model: 7,
                id: 99 + i as u64,
                status,
                pred: if status == Status::Ok { 2 } else { -1 },
            };
            let mut wire = encode_response(&resp);
            let payload = split_frame(&mut wire).unwrap().unwrap();
            assert_eq!(decode_response(&payload).unwrap(), resp);
        }
        assert!(Status::from_code(9).is_err());
    }

    #[test]
    fn split_frame_handles_partials_and_rejects_bad_lengths() {
        let req = Request {
            model: 0,
            id: 1,
            features: vec![5; 8],
        };
        let wire = encode_request(&req);
        // Feed byte by byte: no frame until the last byte arrives.
        let mut buf = Vec::new();
        for (i, b) in wire.iter().enumerate() {
            buf.push(*b);
            let got = split_frame(&mut buf).unwrap();
            if i + 1 < wire.len() {
                assert!(got.is_none(), "premature frame at byte {i}");
            } else {
                assert_eq!(decode_request(&got.unwrap()).unwrap(), req);
            }
        }
        // Two frames back to back split cleanly.
        let mut buf: Vec<u8> = [wire.clone(), wire.clone()].concat();
        assert!(split_frame(&mut buf).unwrap().is_some());
        assert!(split_frame(&mut buf).unwrap().is_some());
        assert!(buf.is_empty());
        // Oversized and runt length prefixes are fatal.
        let mut buf = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        assert!(split_frame(&mut buf).is_err());
        let mut buf = 2u32.to_le_bytes().to_vec();
        assert!(split_frame(&mut buf).is_err());
    }

    #[test]
    fn decode_rejects_bad_magic_kind_and_shape() {
        let req = Request {
            model: 1,
            id: 2,
            features: vec![3; 4],
        };
        let wire = encode_request(&req);
        let payload = &wire[LEN_PREFIX..];
        let mut bad = payload.to_vec();
        bad[0] ^= 0xFF;
        assert!(decode_request(&bad).is_err(), "bad magic");
        let mut bad = payload.to_vec();
        bad[4] = KIND_RESPONSE;
        assert!(decode_request(&bad).is_err(), "wrong kind");
        let mut bad = payload.to_vec();
        bad.pop();
        assert!(decode_request(&bad).is_err(), "truncated features");
        assert!(decode_request(&payload[..10]).is_err(), "runt header");
        assert!(decode_response(payload).is_err(), "request is not a response");
    }

    #[test]
    fn conn_shared_respond_releases_inflight_but_refuse_does_not() {
        let shared = ConnShared::default();
        shared.inflight.fetch_add(1, Ordering::AcqRel);
        shared.refuse(0, 41);
        assert_eq!(shared.inflight(), 1, "refusal is not an in-flight answer");
        shared.respond(0, 42, Status::Ok, 1);
        assert_eq!(shared.inflight(), 0);
        let mut out = shared.out.lock().unwrap().clone();
        let first = decode_response(&split_frame(&mut out).unwrap().unwrap()).unwrap();
        assert_eq!(first.status, Status::Refused);
        assert_eq!(first.pred, -1);
        let second = decode_response(&split_frame(&mut out).unwrap().unwrap()).unwrap();
        assert_eq!(second.id, 42);
        assert_eq!(second.status, Status::Ok);
        assert_eq!(second.pred, 1);
    }
}
