//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and executes
//! them from the Rust hot path (Python is never on the request path).
//!
//! Pattern (see /opt/xla-example/load_hlo): HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.
//!
//! The `xla` crate's handles wrap raw PJRT pointers and are `!Send`, so an
//! [`Engine`] lives on one thread; the coordinator creates one engine per
//! worker when it fans out (CPU clients are cheap).  Executables are cached
//! per (dataset, batch) inside the engine.
//!
//! In offline builds the `xla` dependency is a vendored stub whose client
//! constructor fails; [`crate::runtime::Backend::resolve`] catches that
//! and falls back to the native evaluator.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::data::Split;
use crate::model::{ApproxTables, QuantModel};
use crate::runtime::Evaluator;

/// Batch sizes lowered at AOT time (see python/compile/aot.py).
pub const BATCH_LATENCY: usize = 1;
pub const BATCH_THROUGHPUT: usize = 256;

/// A PJRT CPU client plus an executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<(String, usize), Rc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached by key).
    pub fn load_hlo(
        &self,
        key: &str,
        batch: usize,
        path: &Path,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&(key.to_string(), batch)) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?,
        );
        self.cache
            .borrow_mut()
            .insert((key.to_string(), batch), exe.clone());
        Ok(exe)
    }
}

fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// A compiled hybrid-MLP evaluator bound to one model + one batch size.
///
/// Weights are converted to literals once; masks and approximation tables
/// are runtime arguments, so RFP sweeps and NSGA-II generations never
/// recompile (the whole point of the mask-based artifact design).
pub struct PjrtEvaluator {
    exe: Rc<xla::PjRtLoadedExecutable>,
    batch: usize,
    features: usize,
    hidden: usize,
    #[allow(dead_code)]
    classes: usize,
    // Cached weight literals in mlp_forward argument order.
    w1p: xla::Literal,
    w1s: xla::Literal,
    b1: xla::Literal,
    w2p: xla::Literal,
    w2s: xla::Literal,
    b2: xla::Literal,
}

impl PjrtEvaluator {
    pub fn new(
        engine: &Engine,
        hlo_path: &Path,
        model: &QuantModel,
        batch: usize,
    ) -> Result<PjrtEvaluator> {
        let exe = engine.load_hlo(&model.name, batch, hlo_path)?;
        let (f, h, c) = (model.features as i64, model.hidden as i64, model.classes as i64);
        Ok(PjrtEvaluator {
            exe,
            batch,
            features: model.features,
            hidden: model.hidden,
            classes: model.classes,
            w1p: lit_i32(&model.w1p, &[h, f])?,
            w1s: lit_i32(&model.w1s, &[h, f])?,
            b1: lit_i32(&model.b1, &[h])?,
            w2p: lit_i32(&model.w2p, &[c, h])?,
            w2s: lit_i32(&model.w2s, &[c, h])?,
            b2: lit_i32(&model.b2, &[c])?,
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Predict classes for `n` row-major samples (4-bit inputs).
    ///
    /// Inputs are chunked to the compiled batch size; the final partial
    /// chunk is zero-padded and the padding predictions discarded.
    pub fn predict(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<Vec<i32>> {
        assert_eq!(xs.len(), n * self.features);
        assert_eq!(feat_mask.len(), self.features);
        assert_eq!(approx_mask.len(), self.hidden);
        let (f, h) = (self.features as i64, self.hidden as i64);

        let fm: Vec<i32> = feat_mask.iter().map(|&v| v as i32).collect();
        let am: Vec<i32> = approx_mask.iter().map(|&v| v as i32).collect();
        let fm = lit_i32(&fm, &[f])?;
        let am = lit_i32(&am, &[h])?;
        let idx = lit_i32(&tables.idx, &[h, 2])?;
        let pos = lit_i32(&tables.pos, &[h, 2])?;
        let l1 = lit_i32(&tables.l1, &[h, 2])?;
        let sign = lit_i32(&tables.sign, &[h, 2])?;
        let base = lit_i32(&tables.base, &[h])?;

        let mut preds = Vec::with_capacity(n);
        let mut xbuf = vec![0i32; self.batch * self.features];
        let mut done = 0usize;
        while done < n {
            let take = (n - done).min(self.batch);
            for i in 0..take * self.features {
                xbuf[i] = xs[done * self.features + i] as i32;
            }
            for v in xbuf[take * self.features..].iter_mut() {
                *v = 0;
            }
            let x = lit_i32(&xbuf, &[self.batch as i64, f])?;
            let args = [
                &x, &self.w1p, &self.w1s, &self.b1, &self.w2p, &self.w2s, &self.b2, &fm, &am,
                &idx, &pos, &l1, &sign, &base,
            ];
            let out = self.exe.execute::<&xla::Literal>(&args)?[0][0]
                .to_literal_sync()?
                .to_tuple()?;
            anyhow::ensure!(out.len() == 2, "expected (pred, logits) tuple");
            let chunk = out[0].to_vec::<i32>()?;
            preds.extend_from_slice(&chunk[..take]);
            done += take;
        }
        Ok(preds)
    }

    /// Accuracy over a split under the given design decisions.
    pub fn accuracy(
        &self,
        split: &Split,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<f64> {
        let preds = self.predict(&split.xs, split.len(), feat_mask, approx_mask, tables)?;
        let correct = preds
            .iter()
            .zip(&split.ys)
            .filter(|(p, y)| **p == **y as i32)
            .count();
        Ok(correct as f64 / split.len().max(1) as f64)
    }

    /// Pre-stage a split's input chunks as device literals (§Perf).
    ///
    /// RFP sweeps and NSGA-II generations evaluate the *same* training
    /// split hundreds of times with different masks; rebuilding the
    /// `B × F` int32 input literal on every call dominated the fitness
    /// path (~1 MiB of copies per evaluation on HAR).  Preparing the
    /// chunks once and varying only the small mask/table literals cuts
    /// that cost to zero.
    pub fn prepare(&self, split: &Split) -> Result<PreparedInput> {
        let n = split.len();
        let f = self.features;
        let mut chunks = Vec::new();
        let mut xbuf = vec![0i32; self.batch * f];
        let mut done = 0usize;
        while done < n {
            let take = (n - done).min(self.batch);
            for i in 0..take * f {
                xbuf[i] = split.xs[done * f + i] as i32;
            }
            for v in xbuf[take * f..].iter_mut() {
                *v = 0;
            }
            chunks.push((lit_i32(&xbuf, &[self.batch as i64, f as i64])?, take));
            done += take;
        }
        Ok(PreparedInput {
            chunks,
            n,
            ys: split.ys.clone(),
        })
    }

    /// Predict over a prepared input (see [`PjrtEvaluator::prepare`]).
    pub fn predict_prepared(
        &self,
        prep: &PreparedInput,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<Vec<i32>> {
        let (f, h) = (self.features as i64, self.hidden as i64);
        let fm: Vec<i32> = feat_mask.iter().map(|&v| v as i32).collect();
        let am: Vec<i32> = approx_mask.iter().map(|&v| v as i32).collect();
        let fm = lit_i32(&fm, &[f])?;
        let am = lit_i32(&am, &[h])?;
        let idx = lit_i32(&tables.idx, &[h, 2])?;
        let pos = lit_i32(&tables.pos, &[h, 2])?;
        let l1 = lit_i32(&tables.l1, &[h, 2])?;
        let sign = lit_i32(&tables.sign, &[h, 2])?;
        let base = lit_i32(&tables.base, &[h])?;
        let mut preds = Vec::with_capacity(prep.n);
        for (x, take) in &prep.chunks {
            let args = [
                x, &self.w1p, &self.w1s, &self.b1, &self.w2p, &self.w2s, &self.b2, &fm, &am,
                &idx, &pos, &l1, &sign, &base,
            ];
            let out = self.exe.execute::<&xla::Literal>(&args)?[0][0]
                .to_literal_sync()?
                .to_tuple()?;
            anyhow::ensure!(out.len() == 2, "expected (pred, logits) tuple");
            let chunk = out[0].to_vec::<i32>()?;
            preds.extend_from_slice(&chunk[..*take]);
        }
        Ok(preds)
    }

    /// Accuracy over a prepared input.
    pub fn accuracy_prepared(
        &self,
        prep: &PreparedInput,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<f64> {
        let preds = self.predict_prepared(prep, feat_mask, approx_mask, tables)?;
        let correct = preds
            .iter()
            .zip(&prep.ys)
            .filter(|(p, y)| **p == **y as i32)
            .count();
        Ok(correct as f64 / prep.n.max(1) as f64)
    }
}

impl Evaluator for PjrtEvaluator {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn predict(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<Vec<i32>> {
        PjrtEvaluator::predict(self, xs, n, feat_mask, approx_mask, tables)
    }

    fn accuracy(
        &self,
        split: &Split,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<f64> {
        PjrtEvaluator::accuracy(self, split, feat_mask, approx_mask, tables)
    }
}

/// Input chunks staged as literals, plus the labels for accuracy.
pub struct PreparedInput {
    chunks: Vec<(xla::Literal, usize)>,
    n: usize,
    ys: Vec<u16>,
}

impl PreparedInput {
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
}
