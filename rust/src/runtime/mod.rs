//! Model-evaluation backends behind the unified [`Evaluator`] trait.
//!
//! Three interchangeable implementations of "predict a batch under
//! feature/approximation masks", selectable end-to-end via
//! `--backend native|pjrt|gatesim` on the CLI (or [`Backend::Auto`], the
//! default, which prefers PJRT and falls back to native):
//!
//! - [`NativeEvaluator`] — the bit-exact Rust functional model; always
//!   available, no artifacts needed.
//! - [`PjrtEvaluator`] (in [`pjrt`]) — executes the AOT-compiled
//!   JAX/Pallas artifacts through PJRT; fastest for fitness sweeps.
//! - [`GateSimEvaluator`] — generates the paper's multi-cycle sequential
//!   circuit for the requested masks and simulates the netlist with the
//!   sharded gate-level simulator; the ground truth the other two are
//!   validated against.
//!
//! All three agree bit-exactly on predictions (see
//! `tests/runtime_roundtrip.rs` and `tests/backend_equivalence.rs`).

pub mod pjrt;

use std::path::PathBuf;
use std::str::FromStr;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, ensure, Result};

use crate::circuits::{combinational, seq_multicycle, CombCircuit, SeqCircuit};
use crate::data::Split;
use crate::model::{ApproxTables, QuantModel};
use crate::netlist::NetRole;
use crate::sim::fault::{FaultList, SharedFaultList};
use crate::sim::fuse::{FusedBatch, FusedModelSpec, FusedPlan};
use crate::sim::testbench;
use crate::sim::SimPlan;
use crate::util::pool;

pub use pjrt::{Engine, PjrtEvaluator, PreparedInput, BATCH_LATENCY, BATCH_THROUGHPUT};

/// Which evaluation backend the coordinator / serve mode should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// PJRT when a client can be created, else native (the default).
    Auto,
    Native,
    Pjrt,
    GateSim,
}

impl Backend {
    pub fn label(self) -> &'static str {
        match self {
            Backend::Auto => "auto",
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
            Backend::GateSim => "gatesim",
        }
    }

    /// Resolve `Auto` to a concrete backend, probing for a PJRT client.
    ///
    /// Returns the engine (when the resolved backend is PJRT) alongside
    /// the concrete choice; callers keep the engine alive for the lifetime
    /// of any [`PjrtEvaluator`] they build from it.
    pub fn resolve(self) -> Result<(Option<Engine>, Backend)> {
        match self {
            Backend::Auto => match Engine::cpu() {
                Ok(engine) => Ok((Some(engine), Backend::Pjrt)),
                Err(err) => {
                    eprintln!("note: PJRT unavailable ({err:#}); using the native evaluator");
                    Ok((None, Backend::Native))
                }
            },
            Backend::Pjrt => Ok((Some(Engine::cpu()?), Backend::Pjrt)),
            other => Ok((None, other)),
        }
    }
}

impl FromStr for Backend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Backend> {
        Ok(match s {
            "auto" => Backend::Auto,
            "native" => Backend::Native,
            "pjrt" => Backend::Pjrt,
            "gatesim" | "gate-sim" | "sim" => Backend::GateSim,
            other => bail!("unknown backend `{other}` (want auto|native|pjrt|gatesim)"),
        })
    }
}

/// Options for [`build_evaluator`]; each backend reads the fields it
/// needs and ignores the rest.
#[derive(Clone, Debug)]
pub struct EvalOpts {
    /// HLO text artifact to compile (PJRT only; required there).
    pub hlo_path: Option<PathBuf>,
    /// AOT batch size the HLO was lowered at (PJRT only).
    pub batch: usize,
    /// Simulator shard threads (gatesim only; 0 = [`pool::default_threads`]).
    pub sim_threads: usize,
    /// Simulator super-lane width in `u64` words (gatesim only; 0 =
    /// [`crate::sim::lane_words_default`] — the `sim.lanes` /
    /// `--sim-lanes` knob).
    pub sim_lanes: usize,
}

impl Default for EvalOpts {
    fn default() -> Self {
        EvalOpts {
            hlo_path: None,
            batch: BATCH_THROUGHPUT,
            sim_threads: 0,
            sim_lanes: 0,
        }
    }
}

/// An evaluator built by [`build_evaluator`].
///
/// PJRT stays a concrete variant because its prepared-input fast path
/// (§Perf: staged device literals) is backend-specific and its handles
/// are `!Send`; everything else is a shareable trait object that worker
/// pools (the serve batcher, sim shards) can hit concurrently.
pub enum BuiltEvaluator<'m> {
    Pjrt(PjrtEvaluator),
    Shared(Box<dyn Evaluator + Send + Sync + 'm>),
}

impl<'m> BuiltEvaluator<'m> {
    pub fn as_dyn(&self) -> &(dyn Evaluator + 'm) {
        match self {
            BuiltEvaluator::Pjrt(e) => e,
            BuiltEvaluator::Shared(b) => b.as_ref(),
        }
    }

    /// Unwrap the thread-shareable box, rejecting PJRT (whose handles are
    /// bound to the constructing thread).
    pub fn into_shared(self) -> Result<Box<dyn Evaluator + Send + Sync + 'm>> {
        match self {
            BuiltEvaluator::Shared(b) => Ok(b),
            BuiltEvaluator::Pjrt(_) => {
                bail!("PJRT evaluator handles are thread-bound (!Send) and cannot be shared")
            }
        }
    }
}

/// The one place an [`Evaluator`] is constructed from a resolved
/// [`Backend`] — the coordinator pipeline and the serve-mode registry
/// both go through here instead of hand-matching backends.
///
/// `backend` must already be concrete (call [`Backend::resolve`] first);
/// `engine` is required iff the backend is PJRT and must outlive the
/// returned evaluator.
pub fn build_evaluator<'m>(
    backend: Backend,
    engine: Option<&Engine>,
    model: &'m QuantModel,
    opts: &EvalOpts,
) -> Result<BuiltEvaluator<'m>> {
    Ok(match backend {
        Backend::Pjrt => {
            let engine =
                engine.ok_or_else(|| anyhow!("pjrt backend requires an engine (resolve first)"))?;
            let hlo = opts
                .hlo_path
                .as_ref()
                .ok_or_else(|| anyhow!("pjrt backend requires an HLO artifact path"))?;
            BuiltEvaluator::Pjrt(PjrtEvaluator::new(engine, hlo, model, opts.batch)?)
        }
        Backend::Native => BuiltEvaluator::Shared(Box::new(NativeEvaluator { model })),
        Backend::GateSim => {
            let threads = if opts.sim_threads == 0 {
                pool::default_threads()
            } else {
                opts.sim_threads
            };
            BuiltEvaluator::Shared(Box::new(GateSimEvaluator::with_opts(
                model,
                threads,
                opts.sim_lanes,
            )))
        }
        Backend::Auto => bail!("resolve Backend::Auto to a concrete backend before building"),
    })
}

/// Batch prediction under feature/approximation masks — the one interface
/// RFP, NSGA-II, gate-level validation, and serve mode all consume.
pub trait Evaluator {
    /// Short backend name for logs and reports.
    fn name(&self) -> &'static str;

    /// Predict classes for `n` row-major 4-bit samples.
    fn predict(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<Vec<i32>>;

    /// [`Evaluator::predict`] into a caller-owned buffer, so hot loops
    /// (the serve batcher drains thousands of batches per second) reuse
    /// one allocation instead of taking a fresh `Vec` per batch.  The
    /// default falls back to `predict`; backends override to write in
    /// place (the native backend does).
    fn predict_into(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
        out: &mut Vec<i32>,
    ) -> Result<()> {
        let preds = self.predict(xs, n, feat_mask, approx_mask, tables)?;
        out.clear();
        out.extend_from_slice(&preds);
        Ok(())
    }

    /// Natural batch granularity of this backend: batches sized in
    /// multiples of this fill the backend's parallel width exactly.  The
    /// serve batcher aligns its drains to it so gatesim batches fill
    /// whole `W·64`-sample super-lane blocks instead of wasting
    /// partial-block lanes; scalar backends report 1 (no alignment).
    fn batch_quantum(&self) -> usize {
        1
    }

    /// Accuracy over a split (default: predict + compare labels).
    fn accuracy(
        &self,
        split: &Split,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<f64> {
        let preds = self.predict(&split.xs, split.len(), feat_mask, approx_mask, tables)?;
        let correct = preds
            .iter()
            .zip(&split.ys)
            .filter(|(p, y)| **p == **y as i32)
            .count();
        Ok(correct as f64 / split.len().max(1) as f64)
    }
}

/// Fallback evaluator with the same interface, running the bit-exact Rust
/// functional model (used where PJRT is unavailable and in cross-checks).
/// Batch paths delegate to the SoA-blocked `QuantModel::predict_rows_into`
/// kernel, so `predict_into` reuses one block of scratch across the whole
/// slice instead of allocating per sample (DESIGN.md §Perf).
pub struct NativeEvaluator<'m> {
    pub model: &'m QuantModel,
}

impl<'m> NativeEvaluator<'m> {
    pub fn predict(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Vec<i32> {
        let mut out = Vec::new();
        self.model.predict_rows_into(xs, n, feat_mask, approx_mask, tables, &mut out);
        out
    }

    pub fn accuracy(
        &self,
        split: &Split,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> f64 {
        self.model
            .accuracy(&split.xs, &split.ys, feat_mask, approx_mask, tables)
    }
}

impl<'m> Evaluator for NativeEvaluator<'m> {
    fn name(&self) -> &'static str {
        "native"
    }

    fn predict(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<Vec<i32>> {
        Ok(NativeEvaluator::predict(self, xs, n, feat_mask, approx_mask, tables))
    }

    fn predict_into(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
        out: &mut Vec<i32>,
    ) -> Result<()> {
        self.model.predict_rows_into(xs, n, feat_mask, approx_mask, tables, out);
        Ok(())
    }

    fn accuracy(
        &self,
        split: &Split,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<f64> {
        Ok(NativeEvaluator::accuracy(self, split, feat_mask, approx_mask, tables))
    }
}

/// [`NativeEvaluator`] that owns its model, so it is `'static` and can
/// back a hot-swappable server model slot whose versions outlive any
/// registry borrow.  Bit-identical to the borrowing variant.
pub struct OwnedNativeEvaluator {
    pub model: QuantModel,
}

impl Evaluator for OwnedNativeEvaluator {
    fn name(&self) -> &'static str {
        "native"
    }

    fn predict(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<Vec<i32>> {
        let mut out = Vec::new();
        self.model.predict_rows_into(xs, n, feat_mask, approx_mask, tables, &mut out);
        Ok(out)
    }

    fn predict_into(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
        out: &mut Vec<i32>,
    ) -> Result<()> {
        self.model.predict_rows_into(xs, n, feat_mask, approx_mask, tables, out);
        Ok(())
    }

    fn accuracy(
        &self,
        split: &Split,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<f64> {
        Ok(self
            .model
            .accuracy(&split.xs, &split.ys, feat_mask, approx_mask, tables))
    }
}

/// Build a thread-shareable evaluator that owns all of its state
/// (`'static`) — what the hot-reload model slots require, since a staged
/// version outlives any registry borrow.  Native clones the model;
/// gatesim already owns its clone.  PJRT (thread-bound handles) and
/// unresolved `Auto` are rejected.
pub fn owned_evaluator(
    backend: Backend,
    model: &QuantModel,
    opts: &EvalOpts,
) -> Result<Box<dyn Evaluator + Send + Sync>> {
    Ok(match backend {
        Backend::Native => Box::new(OwnedNativeEvaluator {
            model: model.clone(),
        }),
        Backend::GateSim => {
            let threads = if opts.sim_threads == 0 {
                pool::default_threads()
            } else {
                opts.sim_threads
            };
            Box::new(GateSimEvaluator::with_opts(model, threads, opts.sim_lanes))
        }
        Backend::Pjrt => bail!(
            "PJRT evaluator handles are thread-bound (!Send) and cannot back a \
             hot-swappable model slot"
        ),
        Backend::Auto => bail!("resolve Backend::Auto to a concrete backend before building"),
    })
}

/// Which circuit family [`GateSimEvaluator`] generates — the fault
/// campaign sweeps all of them over the same model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GateArch {
    /// The paper's multi-cycle sequential circuit (lowered as a hybrid
    /// whenever the approximation mask is nonzero).
    Sequential,
    /// The fully-parallel single-cycle combinational baseline.
    Combinational,
}

impl GateArch {
    pub fn label(self) -> &'static str {
        match self {
            GateArch::Sequential => "seq",
            GateArch::Combinational => "comb",
        }
    }
}

impl FromStr for GateArch {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<GateArch> {
        Ok(match s {
            "seq" | "sequential" => GateArch::Sequential,
            "comb" | "combinational" => GateArch::Combinational,
            other => bail!("unknown gate architecture `{other}` (want seq|comb)"),
        })
    }
}

/// Cache key for the generated circuit: a circuit is only valid for the
/// exact masks/tables it was generated with.
#[derive(PartialEq)]
struct GateSimKey {
    feat_mask: Vec<u8>,
    approx_mask: Vec<u8>,
    tables: ApproxTables,
}

/// The cached circuit, one variant per [`GateArch`].
#[derive(Clone)]
enum GateCircuit {
    Seq(Arc<SeqCircuit>),
    Comb(Arc<CombCircuit>),
}

/// Gate-level evaluator: generates the paper's multi-cycle sequential
/// (or hybrid, when the approximation mask is nonzero) circuit for the
/// requested masks and simulates the netlist, sharded across threads.
///
/// Exact w.r.t. the functional model by construction (the generators are
/// bit-exact — `tests/backend_equivalence.rs`), and artifact-free: it
/// needs only the [`QuantModel`], so it runs everywhere the native
/// evaluator does.  The circuit (and its levelized [`crate::sim::SimPlan`],
/// compiled to the strength-reduced micro-op stream unless
/// [`crate::sim::compile_default`] is off — `--no-compile-sim`) is cached
/// per mask/table combination and regenerated on change, so this backend
/// suits final validation and modest sweeps rather than the inner NSGA
/// fitness loop where every call changes the mask.
pub struct GateSimEvaluator {
    model: QuantModel,
    threads: usize,
    /// Super-lane width in `u64` words (0 = process default).
    lane_words: usize,
    /// Circuit family generated for each mask set.
    arch: GateArch,
    /// Printed-hardware faults injected into every simulation this
    /// evaluator runs (`None` = clean silicon^W electrolyte).
    faults: Option<SharedFaultList>,
    cached: Mutex<Option<(GateSimKey, GateCircuit)>>,
}

impl GateSimEvaluator {
    pub fn new(model: &QuantModel) -> GateSimEvaluator {
        Self::with_threads(model, pool::default_threads())
    }

    pub fn with_threads(model: &QuantModel, threads: usize) -> GateSimEvaluator {
        Self::with_opts(model, threads, 0)
    }

    /// Full control: shard threads plus the super-lane width in `u64`
    /// words (one of [`crate::sim::LANE_WORD_CHOICES`]; 0 =
    /// [`crate::sim::lane_words_default`]).
    pub fn with_opts(model: &QuantModel, threads: usize, lane_words: usize) -> GateSimEvaluator {
        GateSimEvaluator {
            model: model.clone(),
            threads: threads.max(1),
            lane_words,
            arch: GateArch::Sequential,
            faults: None,
            cached: Mutex::new(None),
        }
    }

    /// Builder: generate `arch` instead of the default sequential
    /// circuit.  The combinational baseline has no neuron-approximation
    /// lowering, so a nonzero approximation mask is rejected at predict
    /// time under [`GateArch::Combinational`].
    pub fn with_arch(mut self, arch: GateArch) -> GateSimEvaluator {
        if arch != self.arch {
            self.arch = arch;
            *self.cached.lock().unwrap() = None;
        }
        self
    }

    pub fn arch(&self) -> GateArch {
        self.arch
    }

    /// Inject (or clear) a fault list; every subsequent simulation runs
    /// under it.  The list rides to each simulator shard, which lowers it
    /// against the plan once per worker (see [`crate::sim::fault`]) — an
    /// empty list is exactly the clean path.
    pub fn set_fault_list(&mut self, faults: Option<SharedFaultList>) {
        self.faults = faults;
    }

    pub fn fault_list(&self) -> Option<&FaultList> {
        self.faults.as_deref()
    }

    /// Sample a reproducible fault list over the circuit this evaluator
    /// would simulate for the given masks: candidates are plan-
    /// materialized nets whose [`NetRole`] is in `roles` (see
    /// [`FaultList::sample`]).  Does not install the list — callers
    /// decide via [`GateSimEvaluator::set_fault_list`].
    #[allow(clippy::too_many_arguments)]
    pub fn sample_faults(
        &self,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
        roles: &[NetRole],
        n_stuck: usize,
        n_transient: usize,
        flip_rate: f64,
        seed: u64,
    ) -> Result<FaultList> {
        let circ = self.circuit(feat_mask, approx_mask, tables)?;
        Ok(match &circ {
            GateCircuit::Seq(c) => FaultList::sample(
                &c.sim_plan(),
                &c.netlist,
                roles,
                n_stuck,
                n_transient,
                flip_rate,
                seed,
            ),
            GateCircuit::Comb(c) => FaultList::sample(
                &c.sim_plan(),
                &c.netlist,
                roles,
                n_stuck,
                n_transient,
                flip_rate,
                seed,
            ),
        })
    }

    /// Resolved super-lane width (words per net) this evaluator runs at.
    /// `PRINTED_MLP_SIM_LANES` beats the configured width, exactly as it
    /// beats `--sim-lanes` on the pipeline path — one exported variable
    /// pins the width across every subcommand.
    pub fn lane_words(&self) -> usize {
        if let Some(n) = crate::sim::lane_words_env() {
            return n;
        }
        if self.lane_words == 0 {
            crate::sim::lane_words_default()
        } else {
            self.lane_words
        }
    }

    fn circuit(
        &self,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<GateCircuit> {
        let key = GateSimKey {
            feat_mask: feat_mask.to_vec(),
            approx_mask: approx_mask.to_vec(),
            tables: tables.clone(),
        };
        let mut slot = self.cached.lock().unwrap();
        if let Some((k, circ)) = slot.as_ref() {
            if *k == key {
                return Ok(circ.clone());
            }
        }
        let active: Vec<usize> = feat_mask
            .iter()
            .enumerate()
            .filter(|&(_, &m)| m == 1)
            .map(|(f, _)| f)
            .collect();
        ensure!(!active.is_empty(), "gatesim: feature mask prunes every input");
        let approx: Vec<bool> = approx_mask.iter().map(|&a| a == 1).collect();
        let circ = match self.arch {
            GateArch::Sequential => GateCircuit::Seq(Arc::new(seq_multicycle::generate_hybrid(
                &self.model,
                &active,
                &approx,
                tables,
            ))),
            GateArch::Combinational => {
                ensure!(
                    approx.iter().all(|&a| !a),
                    "gatesim: the combinational baseline has no neuron-approximation lowering"
                );
                GateCircuit::Comb(Arc::new(combinational::generate(&self.model, &active)))
            }
        };
        *slot = Some((key, circ.clone()));
        Ok(circ)
    }
}

impl Evaluator for GateSimEvaluator {
    fn name(&self) -> &'static str {
        "gatesim"
    }

    fn predict(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<Vec<i32>> {
        ensure!(
            xs.len() == n * self.model.features,
            "gatesim: expected {} input values, got {}",
            n * self.model.features,
            xs.len()
        );
        ensure!(
            feat_mask.len() == self.model.features && approx_mask.len() == self.model.hidden,
            "gatesim: mask shapes do not match the model"
        );
        let circ = self.circuit(feat_mask, approx_mask, tables)?;
        let faults = self.faults.as_deref().filter(|fl| !fl.is_empty());
        let preds = match &circ {
            GateCircuit::Seq(c) => testbench::run_sequential_plan_faulted(
                c,
                &c.sim_plan(),
                xs,
                n,
                self.model.features,
                self.threads,
                self.lane_words(),
                faults,
            ),
            GateCircuit::Comb(c) => testbench::run_combinational_plan_faulted(
                c,
                &c.sim_plan(),
                xs,
                n,
                self.model.features,
                self.threads,
                self.lane_words(),
                faults,
            ),
        };
        Ok(preds.into_iter().map(|p| p as i32).collect())
    }

    /// Whole super-lane blocks: batches in multiples of `W·64` samples
    /// leave no simulator lane idle.
    fn batch_quantum(&self) -> usize {
        crate::sim::batch::block_lanes(self.lane_words())
    }
}

/// One hosted model's contribution to a [`FusedGateSim`]: the quantized
/// model plus the masks/tables its circuit is generated under — the same
/// inputs [`Evaluator::predict`] takes per call, fixed at build
/// time here because the fused stream is compiled once for all tenants.
pub struct FusedSpec<'a> {
    pub model: &'a QuantModel,
    pub feat_mask: &'a [u8],
    pub approx_mask: &'a [u8],
    pub tables: &'a ApproxTables,
}

/// Cross-model fused gate-level evaluator (§Fusion): generates every
/// hosted model's sequential circuit, concatenates their compiled
/// micro-op streams into one level-merged [`FusedPlan`], and predicts all
/// tenants' batches in a single sharded pass — the serve batcher's fan-in
/// fast path.  Predictions are bit-identical to running each model
/// through its own [`GateSimEvaluator`].
///
/// Fault injection is not supported on the fused stream (faults name one
/// model's source nets); the campaign paths keep per-model evaluators.
pub struct FusedGateSim {
    fused: FusedPlan,
    /// Per-model feature counts, in build order (input shape checks).
    features: Vec<usize>,
    threads: usize,
    /// Super-lane width in `u64` words (0 = process default).
    lane_words: usize,
}

impl FusedGateSim {
    /// Generate and fuse every spec's sequential circuit.  Plans are
    /// compiled unconditionally — the fused stream is an optimisation of
    /// the compiled backend and has no interpreted form, so it ignores
    /// `--no-compile-sim` (per-model differential tests still exercise
    /// the interpreted oracle).
    pub fn build(specs: &[FusedSpec], threads: usize, lane_words: usize) -> Result<FusedGateSim> {
        ensure!(!specs.is_empty(), "fused gatesim: zero models");
        let mut circuits: Vec<(SeqCircuit, Arc<SimPlan>)> = Vec::with_capacity(specs.len());
        for s in specs {
            ensure!(
                s.feat_mask.len() == s.model.features && s.approx_mask.len() == s.model.hidden,
                "fused gatesim: mask shapes do not match the model"
            );
            let active: Vec<usize> = s
                .feat_mask
                .iter()
                .enumerate()
                .filter(|&(_, &m)| m == 1)
                .map(|(f, _)| f)
                .collect();
            ensure!(!active.is_empty(), "fused gatesim: feature mask prunes every input");
            let approx: Vec<bool> = s.approx_mask.iter().map(|&a| a == 1).collect();
            let circ = seq_multicycle::generate_hybrid(s.model, &active, &approx, s.tables);
            let plan = Arc::new(SimPlan::compiled(&circ.netlist));
            circuits.push((circ, plan));
        }
        let model_specs: Vec<FusedModelSpec> = circuits
            .iter()
            .zip(specs)
            .map(|((circ, plan), s)| FusedModelSpec {
                plan,
                x: testbench::input_port(&circ.netlist, "x"),
                rst: testbench::input_port(&circ.netlist, "rst")[0],
                class_out: testbench::output_port(&circ.netlist, "class_out"),
                cycles: circ.cycles,
                active: &circ.active,
                features: s.model.features,
            })
            .collect();
        let fused = FusedPlan::build(&model_specs);
        Ok(FusedGateSim {
            fused,
            features: specs.iter().map(|s| s.model.features).collect(),
            threads: threads.max(1),
            lane_words,
        })
    }

    pub fn n_models(&self) -> usize {
        self.fused.n_models()
    }

    /// Total fused micro-op count (reporting).
    pub fn n_ops(&self) -> usize {
        self.fused.n_ops()
    }

    /// Resolved super-lane width — same precedence as
    /// [`GateSimEvaluator::lane_words`] (`PRINTED_MLP_SIM_LANES` beats
    /// the configured width).
    pub fn lane_words(&self) -> usize {
        if let Some(n) = crate::sim::lane_words_env() {
            return n;
        }
        if self.lane_words == 0 {
            crate::sim::lane_words_default()
        } else {
            self.lane_words
        }
    }

    /// Whole super-lane blocks, like [`Evaluator::batch_quantum`].
    pub fn batch_quantum(&self) -> usize {
        crate::sim::batch::block_lanes(self.lane_words())
    }

    /// Predict every model's batch in one fused sharded pass.  `batches`
    /// holds one `(xs, n)` row-major 4-bit batch per model, in build
    /// order; batches may be ragged (a model whose rows run out is frozen
    /// for the padding lanes).  Returns per-model prediction vectors.
    pub fn predict_multi(&self, batches: &[(&[u8], usize)]) -> Result<Vec<Vec<i32>>> {
        ensure!(
            batches.len() == self.features.len(),
            "fused gatesim: expected {} batches, got {}",
            self.features.len(),
            batches.len()
        );
        for (i, (&(xs, n), &feats)) in batches.iter().zip(&self.features).enumerate() {
            ensure!(
                xs.len() == n * feats,
                "fused gatesim: model {i} expected {} input values, got {}",
                n * feats,
                xs.len()
            );
        }
        let fb: Vec<FusedBatch> = batches
            .iter()
            .map(|&(xs, n)| FusedBatch { xs, n })
            .collect();
        let preds = self.fused.run(&fb, self.threads, self.lane_words());
        Ok(preds
            .into_iter()
            .map(|v| v.into_iter().map(|p| p as i32).collect())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::testutil::rand_model;
    use crate::util::prng::Rng;

    #[test]
    fn backend_labels_roundtrip() {
        for b in [Backend::Auto, Backend::Native, Backend::Pjrt, Backend::GateSim] {
            assert_eq!(b.label().parse::<Backend>().unwrap(), b);
        }
        assert!("nosuch".parse::<Backend>().is_err());
    }

    #[test]
    fn auto_resolves_to_a_concrete_backend() {
        let (_engine, backend) = Backend::Auto.resolve().unwrap();
        assert!(matches!(backend, Backend::Pjrt | Backend::Native));
    }

    #[test]
    fn gatesim_matches_native_on_random_model() {
        let m = rand_model(51, 6, 3, 3);
        let native = NativeEvaluator { model: &m };
        let gate = GateSimEvaluator::with_threads(&m, 2);
        let n = 70; // forces a partial final 64-lane block
        let mut r = Rng::new(8);
        let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
        let fm = vec![1u8; m.features];
        let am = vec![0u8; m.hidden];
        let t = ApproxTables::disabled(m.hidden);
        let got = Evaluator::predict(&gate, &xs, n, &fm, &am, &t).unwrap();
        let want = NativeEvaluator::predict(&native, &xs, n, &fm, &am, &t);
        assert_eq!(got, want);
    }

    #[test]
    fn gatesim_wide_lanes_match_native_and_report_quantum() {
        let m = rand_model(55, 5, 3, 3);
        let native = NativeEvaluator { model: &m };
        let n = 70;
        let mut r = Rng::new(13);
        let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
        let fm = vec![1u8; m.features];
        let am = vec![0u8; m.hidden];
        let t = ApproxTables::disabled(m.hidden);
        let want = NativeEvaluator::predict(&native, &xs, n, &fm, &am, &t);
        for w in [1usize, 2, 4, 8] {
            let gate = GateSimEvaluator::with_opts(&m, 2, w);
            assert_eq!(gate.lane_words(), w);
            assert_eq!(Evaluator::batch_quantum(&gate), w * 64);
            let got = Evaluator::predict(&gate, &xs, n, &fm, &am, &t).unwrap();
            assert_eq!(got, want, "lane words {w}");
        }
        // Scalar backends have no alignment quantum.
        assert_eq!(Evaluator::batch_quantum(&native), 1);
        // Width 0 resolves to the process default.
        let auto = GateSimEvaluator::new(&m);
        assert!(crate::sim::LANE_WORD_CHOICES.contains(&auto.lane_words()));
    }

    #[test]
    fn predict_into_matches_predict_and_reuses_buffer() {
        let m = rand_model(53, 7, 4, 3);
        let native = NativeEvaluator { model: &m };
        let n = 9;
        let mut r = Rng::new(11);
        let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
        let fm = vec![1u8; m.features];
        let am = vec![0u8; m.hidden];
        let t = ApproxTables::disabled(m.hidden);
        let want = Evaluator::predict(&native, &xs, n, &fm, &am, &t).unwrap();
        // Pre-filled buffer: must be cleared, not appended to.
        let mut out = vec![-7i32; 3];
        native.predict_into(&xs, n, &fm, &am, &t, &mut out).unwrap();
        assert_eq!(out, want);
        // Default-impl path (gatesim) agrees too.
        let gate = GateSimEvaluator::with_threads(&m, 1);
        let mut out2 = Vec::new();
        gate.predict_into(&xs, n, &fm, &am, &t, &mut out2).unwrap();
        assert_eq!(out2, want);
    }

    #[test]
    fn build_evaluator_factory_covers_shared_backends() {
        let m = rand_model(54, 6, 3, 2);
        let native = build_evaluator(Backend::Native, None, &m, &EvalOpts::default()).unwrap();
        assert_eq!(native.as_dyn().name(), "native");
        let gate = build_evaluator(Backend::GateSim, None, &m, &EvalOpts::default()).unwrap();
        assert_eq!(gate.as_dyn().name(), "gatesim");
        // Shared variants unwrap into Send+Sync boxes.
        assert!(native.into_shared().is_ok());
        // Auto must be resolved first; PJRT needs an engine.
        assert!(build_evaluator(Backend::Auto, None, &m, &EvalOpts::default()).is_err());
        assert!(build_evaluator(Backend::Pjrt, None, &m, &EvalOpts::default()).is_err());
    }

    #[test]
    fn gatesim_comb_arch_matches_native_and_rejects_approx() {
        let m = rand_model(57, 5, 3, 3);
        let native = NativeEvaluator { model: &m };
        let gate = GateSimEvaluator::with_threads(&m, 2).with_arch(GateArch::Combinational);
        assert_eq!(gate.arch(), GateArch::Combinational);
        let n = 40;
        let mut r = Rng::new(21);
        let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
        let fm = vec![1u8; m.features];
        let am = vec![0u8; m.hidden];
        let t = ApproxTables::disabled(m.hidden);
        let got = Evaluator::predict(&gate, &xs, n, &fm, &am, &t).unwrap();
        let want = NativeEvaluator::predict(&native, &xs, n, &fm, &am, &t);
        assert_eq!(got, want);
        // No approximation lowering exists for the combinational baseline.
        let mut am_on = vec![0u8; m.hidden];
        am_on[0] = 1;
        assert!(Evaluator::predict(&gate, &xs, n, &fm, &am_on, &t).is_err());
        // Arch labels parse back.
        for a in [GateArch::Sequential, GateArch::Combinational] {
            assert_eq!(a.label().parse::<GateArch>().unwrap(), a);
        }
        assert!("nosuch".parse::<GateArch>().is_err());
    }

    #[test]
    fn gatesim_fault_list_changes_and_restores_predictions() {
        let m = rand_model(58, 6, 3, 3);
        let mut gate = GateSimEvaluator::with_threads(&m, 1);
        let n = 64;
        let mut r = Rng::new(23);
        let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
        let fm = vec![1u8; m.features];
        let am = vec![0u8; m.hidden];
        let t = ApproxTables::disabled(m.hidden);
        let clean = Evaluator::predict(&gate, &xs, n, &fm, &am, &t).unwrap();
        // A heavy transient barrage must perturb at least one prediction…
        let fl = gate
            .sample_faults(&fm, &am, &t, &crate::sim::fault::default_roles(), 0, 24, 0.5, 99)
            .unwrap();
        assert!(fl.transient_count() > 0);
        gate.set_fault_list(Some(std::sync::Arc::new(fl)));
        let faulted = Evaluator::predict(&gate, &xs, n, &fm, &am, &t).unwrap();
        assert_ne!(clean, faulted, "24 transient sites at rate 0.5 must bite");
        // …and be reproducible under the same list.
        assert_eq!(faulted, Evaluator::predict(&gate, &xs, n, &fm, &am, &t).unwrap());
        // Clearing the list restores the clean path bit-exactly.
        gate.set_fault_list(None);
        assert_eq!(clean, Evaluator::predict(&gate, &xs, n, &fm, &am, &t).unwrap());
    }

    #[test]
    fn gatesim_rejects_empty_feature_mask() {
        let m = rand_model(52, 4, 2, 2);
        let gate = GateSimEvaluator::new(&m);
        let fm = vec![0u8; m.features];
        let am = vec![0u8; m.hidden];
        let t = ApproxTables::disabled(m.hidden);
        let xs = vec![0u8; 2 * m.features];
        assert!(Evaluator::predict(&gate, &xs, 2, &fm, &am, &t).is_err());
    }

    #[test]
    fn fused_gatesim_matches_per_model_evaluators() {
        // Two models of different shapes → different cycle counts, so the
        // fused driver's freeze path is exercised, plus ragged batches.
        let m1 = rand_model(61, 6, 3, 3);
        let m2 = rand_model(62, 5, 4, 2);
        let t1 = ApproxTables::disabled(m1.hidden);
        let t2 = ApproxTables::disabled(m2.hidden);
        let fm1 = vec![1u8; m1.features];
        let mut fm2 = vec![1u8; m2.features];
        fm2[1] = 0; // pruned feature: fused active schedule must match
        let am1 = vec![0u8; m1.hidden];
        let am2 = vec![0u8; m2.hidden];
        let mut r = Rng::new(31);
        let (n1, n2) = (70usize, 40usize);
        let xs1: Vec<u8> = (0..n1 * m1.features).map(|_| r.below(16) as u8).collect();
        let xs2: Vec<u8> = (0..n2 * m2.features).map(|_| r.below(16) as u8).collect();
        let fused = FusedGateSim::build(
            &[
                FusedSpec { model: &m1, feat_mask: &fm1, approx_mask: &am1, tables: &t1 },
                FusedSpec { model: &m2, feat_mask: &fm2, approx_mask: &am2, tables: &t2 },
            ],
            2,
            2,
        )
        .unwrap();
        assert_eq!(fused.n_models(), 2);
        assert!(fused.n_ops() > 0);
        assert_eq!(fused.batch_quantum(), 2 * 64);
        let got = fused.predict_multi(&[(&xs1, n1), (&xs2, n2)]).unwrap();
        let g1 = GateSimEvaluator::with_opts(&m1, 2, 2);
        let g2 = GateSimEvaluator::with_opts(&m2, 2, 2);
        let want1 = Evaluator::predict(&g1, &xs1, n1, &fm1, &am1, &t1).unwrap();
        let want2 = Evaluator::predict(&g2, &xs2, n2, &fm2, &am2, &t2).unwrap();
        assert_eq!(got[0], want1);
        assert_eq!(got[1], want2);
        // Shape errors are rejected, not mis-sliced.
        assert!(fused.predict_multi(&[(&xs1, n1)]).is_err());
        assert!(fused.predict_multi(&[(&xs1, n1 - 1), (&xs2, n2)]).is_err());
    }
}
