//! Model-evaluation backends behind the unified [`Evaluator`] trait.
//!
//! Three interchangeable implementations of "predict a batch under
//! feature/approximation masks", selectable end-to-end via
//! `--backend native|pjrt|gatesim` on the CLI (or [`Backend::Auto`], the
//! default, which prefers PJRT and falls back to native):
//!
//! - [`NativeEvaluator`] — the bit-exact Rust functional model; always
//!   available, no artifacts needed.
//! - [`PjrtEvaluator`] (in [`pjrt`]) — executes the AOT-compiled
//!   JAX/Pallas artifacts through PJRT; fastest for fitness sweeps.
//! - [`GateSimEvaluator`] — generates the paper's multi-cycle sequential
//!   circuit for the requested masks and simulates the netlist with the
//!   sharded gate-level simulator; the ground truth the other two are
//!   validated against.
//!
//! All three agree bit-exactly on predictions (see
//! `tests/runtime_roundtrip.rs` and `tests/backend_equivalence.rs`).

pub mod pjrt;

use std::str::FromStr;
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Result};

use crate::circuits::{seq_multicycle, SeqCircuit};
use crate::data::Split;
use crate::model::{ApproxTables, QuantModel};
use crate::sim::testbench;
use crate::util::pool;

pub use pjrt::{Engine, PjrtEvaluator, PreparedInput, BATCH_LATENCY, BATCH_THROUGHPUT};

/// Which evaluation backend the coordinator / serve mode should use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// PJRT when a client can be created, else native (the default).
    Auto,
    Native,
    Pjrt,
    GateSim,
}

impl Backend {
    pub fn label(self) -> &'static str {
        match self {
            Backend::Auto => "auto",
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
            Backend::GateSim => "gatesim",
        }
    }

    /// Resolve `Auto` to a concrete backend, probing for a PJRT client.
    ///
    /// Returns the engine (when the resolved backend is PJRT) alongside
    /// the concrete choice; callers keep the engine alive for the lifetime
    /// of any [`PjrtEvaluator`] they build from it.
    pub fn resolve(self) -> Result<(Option<Engine>, Backend)> {
        match self {
            Backend::Auto => match Engine::cpu() {
                Ok(engine) => Ok((Some(engine), Backend::Pjrt)),
                Err(err) => {
                    eprintln!("note: PJRT unavailable ({err:#}); using the native evaluator");
                    Ok((None, Backend::Native))
                }
            },
            Backend::Pjrt => Ok((Some(Engine::cpu()?), Backend::Pjrt)),
            other => Ok((None, other)),
        }
    }
}

impl FromStr for Backend {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Backend> {
        Ok(match s {
            "auto" => Backend::Auto,
            "native" => Backend::Native,
            "pjrt" => Backend::Pjrt,
            "gatesim" | "gate-sim" | "sim" => Backend::GateSim,
            other => bail!("unknown backend `{other}` (want auto|native|pjrt|gatesim)"),
        })
    }
}

/// Batch prediction under feature/approximation masks — the one interface
/// RFP, NSGA-II, gate-level validation, and serve mode all consume.
pub trait Evaluator {
    /// Short backend name for logs and reports.
    fn name(&self) -> &'static str;

    /// Predict classes for `n` row-major 4-bit samples.
    fn predict(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<Vec<i32>>;

    /// Accuracy over a split (default: predict + compare labels).
    fn accuracy(
        &self,
        split: &Split,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<f64> {
        let preds = self.predict(&split.xs, split.len(), feat_mask, approx_mask, tables)?;
        let correct = preds
            .iter()
            .zip(&split.ys)
            .filter(|(p, y)| **p == **y as i32)
            .count();
        Ok(correct as f64 / split.len().max(1) as f64)
    }
}

/// Fallback evaluator with the same interface, running the bit-exact Rust
/// functional model (used where PJRT is unavailable and in cross-checks).
pub struct NativeEvaluator<'m> {
    pub model: &'m QuantModel,
}

impl<'m> NativeEvaluator<'m> {
    pub fn predict(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Vec<i32> {
        let f = self.model.features;
        let mut x = vec![0i32; f];
        (0..n)
            .map(|i| {
                for j in 0..f {
                    x[j] = xs[i * f + j] as i32;
                }
                self.model.forward(&x, feat_mask, approx_mask, tables).0 as i32
            })
            .collect()
    }

    pub fn accuracy(
        &self,
        split: &Split,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> f64 {
        self.model
            .accuracy(&split.xs, &split.ys, feat_mask, approx_mask, tables)
    }
}

impl<'m> Evaluator for NativeEvaluator<'m> {
    fn name(&self) -> &'static str {
        "native"
    }

    fn predict(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<Vec<i32>> {
        Ok(NativeEvaluator::predict(self, xs, n, feat_mask, approx_mask, tables))
    }

    fn accuracy(
        &self,
        split: &Split,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<f64> {
        Ok(NativeEvaluator::accuracy(self, split, feat_mask, approx_mask, tables))
    }
}

/// Cache key for the generated circuit: a circuit is only valid for the
/// exact masks/tables it was generated with.
#[derive(PartialEq)]
struct GateSimKey {
    feat_mask: Vec<u8>,
    approx_mask: Vec<u8>,
    tables: ApproxTables,
}

/// Gate-level evaluator: generates the paper's multi-cycle sequential
/// (or hybrid, when the approximation mask is nonzero) circuit for the
/// requested masks and simulates the netlist, sharded across threads.
///
/// Exact w.r.t. the functional model by construction (the generators are
/// bit-exact — `tests/backend_equivalence.rs`), and artifact-free: it
/// needs only the [`QuantModel`], so it runs everywhere the native
/// evaluator does.  The circuit (and its levelized [`crate::sim::SimPlan`],
/// compiled to the strength-reduced micro-op stream unless
/// [`crate::sim::compile_default`] is off — `--no-compile-sim`) is cached
/// per mask/table combination and regenerated on change, so this backend
/// suits final validation and modest sweeps rather than the inner NSGA
/// fitness loop where every call changes the mask.
pub struct GateSimEvaluator {
    model: QuantModel,
    threads: usize,
    cached: Mutex<Option<(GateSimKey, Arc<SeqCircuit>)>>,
}

impl GateSimEvaluator {
    pub fn new(model: &QuantModel) -> GateSimEvaluator {
        Self::with_threads(model, pool::default_threads())
    }

    pub fn with_threads(model: &QuantModel, threads: usize) -> GateSimEvaluator {
        GateSimEvaluator {
            model: model.clone(),
            threads: threads.max(1),
            cached: Mutex::new(None),
        }
    }

    fn circuit(
        &self,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<Arc<SeqCircuit>> {
        let key = GateSimKey {
            feat_mask: feat_mask.to_vec(),
            approx_mask: approx_mask.to_vec(),
            tables: tables.clone(),
        };
        let mut slot = self.cached.lock().unwrap();
        if let Some((k, circ)) = slot.as_ref() {
            if *k == key {
                return Ok(circ.clone());
            }
        }
        let active: Vec<usize> = feat_mask
            .iter()
            .enumerate()
            .filter(|&(_, &m)| m == 1)
            .map(|(f, _)| f)
            .collect();
        ensure!(!active.is_empty(), "gatesim: feature mask prunes every input");
        let approx: Vec<bool> = approx_mask.iter().map(|&a| a == 1).collect();
        let circ = Arc::new(seq_multicycle::generate_hybrid(
            &self.model,
            &active,
            &approx,
            tables,
        ));
        *slot = Some((key, circ.clone()));
        Ok(circ)
    }
}

impl Evaluator for GateSimEvaluator {
    fn name(&self) -> &'static str {
        "gatesim"
    }

    fn predict(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> Result<Vec<i32>> {
        ensure!(
            xs.len() == n * self.model.features,
            "gatesim: expected {} input values, got {}",
            n * self.model.features,
            xs.len()
        );
        ensure!(
            feat_mask.len() == self.model.features && approx_mask.len() == self.model.hidden,
            "gatesim: mask shapes do not match the model"
        );
        let circ = self.circuit(feat_mask, approx_mask, tables)?;
        let preds =
            testbench::run_sequential_threads(&circ, xs, n, self.model.features, self.threads);
        Ok(preds.into_iter().map(|p| p as i32).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::testutil::rand_model;
    use crate::util::prng::Rng;

    #[test]
    fn backend_labels_roundtrip() {
        for b in [Backend::Auto, Backend::Native, Backend::Pjrt, Backend::GateSim] {
            assert_eq!(b.label().parse::<Backend>().unwrap(), b);
        }
        assert!("nosuch".parse::<Backend>().is_err());
    }

    #[test]
    fn auto_resolves_to_a_concrete_backend() {
        let (_engine, backend) = Backend::Auto.resolve().unwrap();
        assert!(matches!(backend, Backend::Pjrt | Backend::Native));
    }

    #[test]
    fn gatesim_matches_native_on_random_model() {
        let m = rand_model(51, 6, 3, 3);
        let native = NativeEvaluator { model: &m };
        let gate = GateSimEvaluator::with_threads(&m, 2);
        let n = 70; // forces a partial final 64-lane block
        let mut r = Rng::new(8);
        let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
        let fm = vec![1u8; m.features];
        let am = vec![0u8; m.hidden];
        let t = ApproxTables::disabled(m.hidden);
        let got = Evaluator::predict(&gate, &xs, n, &fm, &am, &t).unwrap();
        let want = NativeEvaluator::predict(&native, &xs, n, &fm, &am, &t);
        assert_eq!(got, want);
    }

    #[test]
    fn gatesim_rejects_empty_feature_mask() {
        let m = rand_model(52, 4, 2, 2);
        let gate = GateSimEvaluator::new(&m);
        let fm = vec![0u8; m.features];
        let am = vec![0u8; m.hidden];
        let t = ApproxTables::disabled(m.hidden);
        let xs = vec![0u8; 2 * m.features];
        assert!(Evaluator::predict(&gate, &xs, 2, &fm, &am, &t).is_err());
    }
}
