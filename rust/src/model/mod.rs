//! Bit-exact functional model of the pow2-quantized hybrid MLP.
//!
//! This is the Rust mirror of `python/compile/kernels/ref.py`: identical
//! int32 semantics (barrel-shift multiply, qReLU truncate+saturate,
//! single-cycle leading-1 approximation), used to
//!
//! 1. cross-check the PJRT-executed JAX/Pallas artifacts,
//! 2. drive the gate-level circuit generators (`circuits`), and
//! 3. serve as an always-available fallback evaluator.
//!
//! See DESIGN.md §Functional semantics.

pub mod cache;
pub mod importance;
pub mod synth;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Power-of-2 quantized two-layer MLP in circuit units.
///
/// Weight matrices are stored row-major: `w1p[h * features + f]` etc.
/// Signs are in `{-1, 0, +1}`; `0` encodes a pruned (zero) weight, exactly
/// as the bespoke circuit simply omits that term from the neuron's mux.
#[derive(Clone, Debug)]
pub struct QuantModel {
    pub name: String,
    pub features: usize,
    pub classes: usize,
    pub hidden: usize,
    pub in_bits: u32,
    pub w_bits: u32,
    pub pmax: u32,
    pub trunc: u32,
    pub seq_clock_ms: f64,
    pub comb_clock_ms: f64,
    pub float_acc: f64,
    pub train_acc: f64,
    pub test_acc: f64,
    pub w1p: Vec<i32>,
    pub w1s: Vec<i32>,
    pub b1: Vec<i32>,
    pub w2p: Vec<i32>,
    pub w2s: Vec<i32>,
    pub b2: Vec<i32>,
}

/// Per-neuron single-cycle approximation tables (Fig. 5): the two
/// most-important inputs, the probed bit position, the expected leading-1
/// column the 1-bit sum is rewired to, and the weight sign.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ApproxTables {
    /// `[h][k]` flattened as `h * 2 + k`.
    pub idx: Vec<i32>,
    pub pos: Vec<i32>,
    pub l1: Vec<i32>,
    pub sign: Vec<i32>,
    /// `[h]`: hardwired expected accumulator base — bias plus the rounded
    /// expected contribution of every other active feature.  Realigns the
    /// approximated accumulator with the multi-cycle neurons (§3.1.2) at
    /// zero hardware cost (it folds into the reset constant).
    pub base: Vec<i32>,
}

impl ApproxTables {
    pub fn disabled(hidden: usize) -> Self {
        ApproxTables {
            idx: vec![0; hidden * 2],
            pos: vec![0; hidden * 2],
            l1: vec![0; hidden * 2],
            sign: vec![0; hidden * 2],
            base: vec![0; hidden],
        }
    }
}

/// Quantized ReLU: `clamp(max(acc, 0) >> trunc, 0, 15)` (§3.2.1).
#[inline]
pub fn qrelu(acc: i32, trunc: u32) -> i32 {
    (acc.max(0) >> trunc).min(15)
}

impl QuantModel {
    // -- loading -------------------------------------------------------------

    pub fn from_json(j: &Json) -> Result<Self> {
        let (w1p, h1, f1) = j.get("w1_p")?.i32_matrix().context("w1_p")?;
        let (w1s, h2, f2) = j.get("w1_s")?.i32_matrix().context("w1_s")?;
        let (w2p, c1, hh1) = j.get("w2_p")?.i32_matrix().context("w2_p")?;
        let (w2s, c2, hh2) = j.get("w2_s")?.i32_matrix().context("w2_s")?;
        let m = QuantModel {
            name: j.get("name")?.str()?.to_string(),
            features: j.get("features")?.int()? as usize,
            classes: j.get("classes")?.int()? as usize,
            hidden: j.get("hidden")?.int()? as usize,
            in_bits: j.get("in_bits")?.int()? as u32,
            w_bits: j.get("w_bits")?.int()? as u32,
            pmax: j.get("pmax")?.int()? as u32,
            trunc: j.get("trunc")?.int()? as u32,
            seq_clock_ms: j.get("seq_clock_ms")?.num()?,
            comb_clock_ms: j.get("comb_clock_ms")?.num()?,
            float_acc: j.get("float_acc")?.num()?,
            train_acc: j.get("train_acc")?.num()?,
            test_acc: j.get("test_acc")?.num()?,
            w1p,
            w1s,
            b1: j.get("b1")?.i32_vec()?,
            w2p,
            w2s,
            b2: j.get("b2")?.i32_vec()?,
        };
        if (h1, f1) != (m.hidden, m.features)
            || (h2, f2) != (m.hidden, m.features)
            || (c1, hh1) != (m.classes, m.hidden)
            || (c2, hh2) != (m.classes, m.hidden)
            || m.b1.len() != m.hidden
            || m.b2.len() != m.classes
        {
            bail!("model `{}` has inconsistent shapes", m.name);
        }
        m.validate()?;
        Ok(m)
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading model {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Sanity-check quantization invariants (signs in {-1,0,1}, powers in
    /// range). Enforced at load so every downstream consumer can trust it.
    pub fn validate(&self) -> Result<()> {
        for (s, p) in self.w1s.iter().chain(&self.w2s).zip(self.w1p.iter().chain(&self.w2p)) {
            if !(-1..=1).contains(s) {
                bail!("sign {s} out of range");
            }
            if *p < 0 || *p > self.pmax as i32 {
                bail!("power {p} out of [0, {}]", self.pmax);
            }
        }
        Ok(())
    }

    /// Number of nonzero coefficients (weights + biases), the paper's model
    /// size metric.
    pub fn coefficients(&self) -> usize {
        self.w1s.iter().chain(&self.w2s).filter(|s| **s != 0).count()
            + self.b1.len()
            + self.b2.len()
    }

    // -- forward -------------------------------------------------------------

    /// Exact hidden-layer accumulator for one sample (multi-cycle neuron).
    #[inline]
    pub fn hidden_acc_exact(&self, x: &[i32], feat_mask: &[u8], h: usize) -> i32 {
        let row = &self.w1p[h * self.features..(h + 1) * self.features];
        let sgn = &self.w1s[h * self.features..(h + 1) * self.features];
        let mut acc = self.b1[h];
        for f in 0..self.features {
            // s in {-1,0,1}: multiply keeps the loop branch-free.
            acc += (feat_mask[f] as i32) * sgn[f] * (x[f] << row[f]);
        }
        acc
    }

    /// Single-cycle (approximated) accumulator for one sample (Fig. 2c).
    #[inline]
    pub fn hidden_acc_approx(
        &self,
        x: &[i32],
        feat_mask: &[u8],
        tables: &ApproxTables,
        h: usize,
    ) -> i32 {
        let mut acc = tables.base[h];
        for k in 0..2 {
            let t = h * 2 + k;
            let idx = tables.idx[t] as usize;
            let bit = (x[idx] >> tables.pos[t]) & 1;
            acc += (feat_mask[idx] as i32) * tables.sign[t] * (bit << tables.l1[t]);
        }
        acc
    }

    /// Full hybrid forward for one sample; returns (pred, logits).
    pub fn forward(
        &self,
        x: &[i32],
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> (usize, Vec<i32>) {
        let mut hid = vec![0i32; self.hidden];
        let mut logits = vec![0i32; self.classes];
        let best = self.forward_into(x, feat_mask, approx_mask, tables, &mut hid, &mut logits);
        (best, logits)
    }

    /// [`Self::forward`] with caller-provided scratch: writes the hidden
    /// activations into `hid` and the logits into `logits` (no
    /// allocation) and returns the argmax prediction.  The batch paths
    /// ([`Self::predict_rows_into`], [`Self::accuracy`]) reuse one
    /// scratch pair across every sample.
    pub fn forward_into(
        &self,
        x: &[i32],
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
        hid: &mut [i32],
        logits: &mut [i32],
    ) -> usize {
        debug_assert_eq!(x.len(), self.features);
        debug_assert_eq!(hid.len(), self.hidden);
        debug_assert_eq!(logits.len(), self.classes);
        for h in 0..self.hidden {
            let acc = if approx_mask[h] == 1 {
                self.hidden_acc_approx(x, feat_mask, tables, h)
            } else {
                self.hidden_acc_exact(x, feat_mask, h)
            };
            hid[h] = qrelu(acc, self.trunc);
        }
        for c in 0..self.classes {
            let row = &self.w2p[c * self.hidden..(c + 1) * self.hidden];
            let sgn = &self.w2s[c * self.hidden..(c + 1) * self.hidden];
            let mut acc = self.b2[c];
            for h in 0..self.hidden {
                acc += sgn[h] * (hid[h] << row[h]);
            }
            logits[c] = acc;
        }
        // Ties break to the lowest class index, matching jnp.argmax and the
        // sequential argmax comparator (strict `>` update).
        let mut best = 0usize;
        for c in 1..self.classes {
            if logits[c] > logits[best] {
                best = c;
            }
        }
        best
    }

    /// Exact (no approximation, full feature set) convenience forward.
    pub fn forward_exact(&self, x: &[i32]) -> (usize, Vec<i32>) {
        let fm = vec![1u8; self.features];
        let am = vec![0u8; self.hidden];
        self.forward(x, &fm, &am, &ApproxTables::disabled(self.hidden))
    }

    /// Predict classes for `n` row-major 4-bit samples into `out`
    /// (cleared first) — the one u8-row → i32 decode loop shared by the
    /// native evaluator's batch paths and synthetic-split labeling.
    ///
    /// SoA-blocked: samples are processed in blocks of [`Self::BLOCK`],
    /// decoding each block's u8 rows once, walking the hidden layer
    /// neuron-major (one `w1p`/`w1s` weight-row read serves the whole
    /// block) and the output layer class-major, with all scratch
    /// allocated once per call — no per-sample `Vec`s.  Predictions are
    /// bit-identical to the per-sample [`Self::forward`] loop: the
    /// accumulation order within each neuron/class is unchanged, only
    /// the loop nest around it.
    pub fn predict_rows_into(
        &self,
        xs: &[u8],
        n: usize,
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
        out: &mut Vec<i32>,
    ) {
        let f = self.features;
        debug_assert_eq!(xs.len(), n * f);
        out.clear();
        out.reserve(n);
        let b = Self::BLOCK.min(n.max(1));
        let mut xblk = vec![0i32; b * f];
        let mut hid = vec![0i32; b * self.hidden];
        let mut logits = vec![0i32; b * self.classes];
        let mut start = 0usize;
        while start < n {
            let m = Self::BLOCK.min(n - start);
            for (xj, &v) in xblk[..m * f]
                .iter_mut()
                .zip(&xs[start * f..(start + m) * f])
            {
                *xj = v as i32;
            }
            for h in 0..self.hidden {
                if approx_mask[h] == 1 {
                    for s in 0..m {
                        let acc =
                            self.hidden_acc_approx(&xblk[s * f..(s + 1) * f], feat_mask, tables, h);
                        hid[s * self.hidden + h] = qrelu(acc, self.trunc);
                    }
                } else {
                    for s in 0..m {
                        let acc = self.hidden_acc_exact(&xblk[s * f..(s + 1) * f], feat_mask, h);
                        hid[s * self.hidden + h] = qrelu(acc, self.trunc);
                    }
                }
            }
            for c in 0..self.classes {
                let row = &self.w2p[c * self.hidden..(c + 1) * self.hidden];
                let sgn = &self.w2s[c * self.hidden..(c + 1) * self.hidden];
                for s in 0..m {
                    let hrow = &hid[s * self.hidden..(s + 1) * self.hidden];
                    let mut acc = self.b2[c];
                    for h in 0..self.hidden {
                        acc += sgn[h] * (hrow[h] << row[h]);
                    }
                    logits[s * self.classes + c] = acc;
                }
            }
            for s in 0..m {
                let l = &logits[s * self.classes..(s + 1) * self.classes];
                let mut best = 0usize;
                for c in 1..self.classes {
                    if l[c] > l[best] {
                        best = c;
                    }
                }
                out.push(best as i32);
            }
            start += m;
        }
    }

    /// Sample-block width of [`Self::predict_rows_into`] — sized so a
    /// block's decoded inputs + activations + logits stay L1-resident
    /// for every dataset shape in the suite.
    pub const BLOCK: usize = 64;

    /// Accuracy over a dataset slice (rows of `features` u8 inputs).
    /// Allocation-free per sample: one decode buffer + one
    /// [`Self::forward_into`] scratch pair reused across the slice.
    pub fn accuracy(
        &self,
        xs: &[u8],
        ys: &[u16],
        feat_mask: &[u8],
        approx_mask: &[u8],
        tables: &ApproxTables,
    ) -> f64 {
        let n = ys.len();
        assert_eq!(xs.len(), n * self.features);
        let mut correct = 0usize;
        let mut x = vec![0i32; self.features];
        let mut hid = vec![0i32; self.hidden];
        let mut logits = vec![0i32; self.classes];
        for i in 0..n {
            for (xj, &v) in x
                .iter_mut()
                .zip(&xs[i * self.features..(i + 1) * self.features])
            {
                *xj = v as i32;
            }
            let pred =
                self.forward_into(&x, feat_mask, approx_mask, tables, &mut hid, &mut logits);
            if pred == ys[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / n.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny hand-checkable model: 3 features, 2 hidden, 2 classes.
    pub(crate) fn toy() -> QuantModel {
        QuantModel {
            name: "toy".into(),
            features: 3,
            classes: 2,
            hidden: 2,
            in_bits: 4,
            w_bits: 8,
            pmax: 6,
            trunc: 1,
            seq_clock_ms: 100.0,
            comb_clock_ms: 320.0,
            float_acc: 0.0,
            train_acc: 0.0,
            test_acc: 0.0,
            // neuron0: +x0<<1 - x1; neuron1: +x2<<2
            w1p: vec![1, 0, 0, 0, 0, 2],
            w1s: vec![1, -1, 0, 0, 0, 1],
            b1: vec![3, -4],
            // class0: +h0; class1: +h1<<1
            w2p: vec![0, 0, 0, 1],
            w2s: vec![1, 0, 0, 1],
            b2: vec![0, 1],
        }
    }

    #[test]
    fn qrelu_semantics() {
        assert_eq!(qrelu(-5, 2), 0);
        assert_eq!(qrelu(0, 0), 0);
        assert_eq!(qrelu(15, 0), 15);
        assert_eq!(qrelu(16, 0), 15); // saturation
        assert_eq!(qrelu(63, 2), 15);
        assert_eq!(qrelu(64, 3), 8);
    }

    #[test]
    fn exact_forward_hand_computed() {
        let m = toy();
        let x = [2, 1, 3];
        // n0: 3 + (2<<1) - 1 = 6 -> qrelu(6,1)=3 ; n1: -4 + (3<<2) = 8 -> 4
        // c0: 3 ; c1: 1 + (4<<1) = 9 -> pred 1
        let (pred, logits) = m.forward_exact(&x);
        assert_eq!(logits, vec![3, 9]);
        assert_eq!(pred, 1);
    }

    #[test]
    fn feature_mask_zeroes_terms() {
        let m = toy();
        let x = [2, 1, 3];
        let fm = [1u8, 0, 1]; // prune x1
        let am = [0u8, 0];
        let (_, logits) = m.forward(&x, &fm, &am, &ApproxTables::disabled(2));
        // n0: 3 + 4 = 7 -> qrelu=3 ; unchanged n1 -> same as before except n0
        assert_eq!(logits[0], 3);
    }

    #[test]
    fn approx_neuron_uses_single_bits() {
        let m = toy();
        let x = [2, 1, 3];
        let fm = [1u8; 3];
        let am = [1u8, 0]; // approximate neuron 0
        let t = ApproxTables {
            idx: vec![0, 1, 0, 0],
            pos: vec![1, 0, 0, 0],
            l1: vec![2, 0, 0, 0],
            sign: vec![1, -1, 0, 0],
            base: vec![3, -4], // == biases: no expected-contribution realign
        };
        // n0 approx: 3 + bit(x0=2,pos1)=1 <<2 = +4, - bit(x1=1,pos0)=1 <<0 = -1 -> 6 -> qrelu 3
        let (_, logits) = m.forward(&x, &fm, &am, &t);
        assert_eq!(logits[0], 3);
    }

    #[test]
    fn argmax_tie_breaks_low() {
        let mut m = toy();
        m.b2 = vec![5, 5];
        m.w2s = vec![0, 0, 0, 0];
        let (pred, logits) = m.forward_exact(&[0, 0, 0]);
        assert_eq!(logits, vec![5, 5]);
        assert_eq!(pred, 0);
    }

    #[test]
    fn blocked_predict_rows_matches_per_sample_forward() {
        // The SoA-blocked batch kernel must agree with the scalar
        // forward at sizes straddling the block boundary (including a
        // partial tail block) and with a mixed approximation mask.
        let m = crate::model::synth::rand_model(19, 7, 5, 3);
        let mut r = crate::util::prng::Rng::new(6);
        let fm = vec![1u8; m.features];
        let am: Vec<u8> = (0..m.hidden).map(|h| (h % 2) as u8).collect();
        let tables = crate::model::importance::approx_tables(
            &m,
            &(0..32 * m.features).map(|i| (i % 16) as u8).collect::<Vec<_>>(),
            32,
            &fm,
        );
        for n in [0usize, 1, QuantModel::BLOCK - 1, QuantModel::BLOCK, QuantModel::BLOCK + 7] {
            let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
            let mut got = Vec::new();
            m.predict_rows_into(&xs, n, &fm, &am, &tables, &mut got);
            let mut x = vec![0i32; m.features];
            let want: Vec<i32> = (0..n)
                .map(|i| {
                    for (xj, &v) in x.iter_mut().zip(&xs[i * m.features..(i + 1) * m.features]) {
                        *xj = v as i32;
                    }
                    m.forward(&x, &fm, &am, &tables).0 as i32
                })
                .collect();
            assert_eq!(got, want, "n = {n}");
        }
    }

    #[test]
    fn coefficients_counts_nonzero() {
        let m = toy();
        // w1s nonzero: 3, w2s nonzero: 2, biases: 2+2
        assert_eq!(m.coefficients(), 3 + 2 + 4);
    }

    #[test]
    fn json_roundtrip() {
        let text = r#"{
            "name":"t","features":2,"classes":2,"hidden":1,
            "in_bits":4,"w_bits":8,"pmax":6,"trunc":0,
            "seq_clock_ms":100,"comb_clock_ms":320,
            "float_acc":0.9,"train_acc":0.8,"test_acc":0.7,
            "w1_p":[[1,2]],"w1_s":[[1,-1]],"b1":[0],
            "w2_p":[[0],[1]],"w2_s":[[1],[1]],"b2":[0,0]
        }"#;
        let m = QuantModel::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(m.features, 2);
        assert_eq!(m.w1p, vec![1, 2]);
    }

    #[test]
    fn validate_rejects_bad_power() {
        let text = r#"{
            "name":"t","features":1,"classes":1,"hidden":1,
            "in_bits":4,"w_bits":8,"pmax":6,"trunc":0,
            "seq_clock_ms":100,"comb_clock_ms":320,
            "float_acc":0,"train_acc":0,"test_acc":0,
            "w1_p":[[9]],"w1_s":[[1]],"b1":[0],
            "w2_p":[[0]],"w2_s":[[1]],"b2":[0]
        }"#;
        assert!(QuantModel::from_json(&Json::parse(text).unwrap()).is_err());
    }
}
