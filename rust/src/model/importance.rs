//! Offline statistical analysis: average expected products (Eq. 1),
//! feature relevance for RFP, and the single-cycle neuron tables (Fig. 5).

use crate::data::Split;
use crate::model::{ApproxTables, QuantModel};

/// Mean of each feature over a dataset split, in input units `[0, 15]`.
pub fn feature_means(xs: &[u8], n: usize, features: usize) -> Vec<f64> {
    let mut mu = vec![0.0f64; features];
    for i in 0..n {
        for f in 0..features {
            mu[f] += xs[i * features + f] as f64;
        }
    }
    for m in &mut mu {
        *m /= n.max(1) as f64;
    }
    mu
}

/// Average expected product of feature `f` for hidden neuron `h` (Eq. 1):
/// `avg_prod[h][f] = E[x_f] * |w_{h,f}|` with `|w| = 2^p` (0 when pruned).
pub fn avg_products(model: &QuantModel, means: &[f64]) -> Vec<f64> {
    let (h, fs) = (model.hidden, model.features);
    let mut out = vec![0.0f64; h * fs];
    for n in 0..h {
        for f in 0..fs {
            let i = n * fs + f;
            if model.w1s[i] != 0 {
                out[i] = means[f] * (1i64 << model.w1p[i]) as f64;
            }
        }
    }
    out
}

/// Per-feature relevance for RFP (Algorithm 1): the average over hidden
/// neurons of the absolute expected products.
pub fn feature_relevance(model: &QuantModel, means: &[f64]) -> Vec<f64> {
    let ap = avg_products(model, means);
    let (h, fs) = (model.hidden, model.features);
    let mut rel = vec![0.0f64; fs];
    for f in 0..fs {
        for n in 0..h {
            rel[f] += ap[n * fs + f];
        }
        rel[f] /= h as f64;
    }
    rel
}

/// Feature order by decreasing relevance (ties break on index for
/// determinism).
pub fn relevance_order(rel: &[f64]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..rel.len()).collect();
    order.sort_by(|&a, &b| rel[b].partial_cmp(&rel[a]).unwrap().then(a.cmp(&b)));
    order
}

/// Build the single-cycle neuron tables: for each hidden neuron pick the
/// two most-important *active* inputs (highest avg_prod among
/// `feat_mask==1`), record the expected leading-1 position of their
/// products and the bit of the raw input that predicts it.
///
/// The hardwired `base` constant makes the single-cycle estimator
/// *unbiased*: it is the bias plus the expected signed contribution of
/// every active feature, minus the expected value of the two bit
/// contributions (`sign * P(bit=1) * 2^l1`).  The probed bits then act as
/// zero-mean corrections around the expectation — this is the §3.1.2
/// "realignment with the multi-cycle neurons", and it costs no hardware
/// because it folds into the accumulator's reset constant.
pub fn approx_tables(
    model: &QuantModel,
    xs: &[u8],
    n_samples: usize,
    feat_mask: &[u8],
) -> ApproxTables {
    let (h, fs) = (model.hidden, model.features);
    let means = feature_means(xs, n_samples, fs);
    let ap = avg_products(model, &means);
    let in_max_bit = model.in_bits as i32 - 1;
    // Empirical probability that bit `pos` of feature `f` is set.
    let bit_prob = |f: usize, pos: i32| -> f64 {
        if n_samples == 0 {
            return 0.5;
        }
        let mut cnt = 0usize;
        for i in 0..n_samples {
            if (xs[i * fs + f] >> pos) & 1 == 1 {
                cnt += 1;
            }
        }
        cnt as f64 / n_samples as f64
    };
    let mut t = ApproxTables::disabled(h);
    for n in 0..h {
        // Top-2 active features by avg_prod.
        let mut best: [(f64, usize); 2] = [(-1.0, 0), (-1.0, 0)];
        for f in 0..fs {
            if feat_mask[f] == 0 || model.w1s[n * fs + f] == 0 {
                continue;
            }
            let v = ap[n * fs + f];
            if v > best[0].0 {
                best[1] = best[0];
                best[0] = (v, f);
            } else if v > best[1].0 {
                best[1] = (v, f);
            }
        }
        for (k, &(v, f)) in best.iter().enumerate() {
            if v <= 0.0 {
                continue; // fewer than 2 usable inputs: leave sign=0 slot
            }
            let wi = n * fs + f;
            let p = model.w1p[wi];
            // Expected leading-1 of the product E[x_f]*2^p.
            let l1 = v.max(1.0).log2().floor() as i32;
            let slot = n * 2 + k;
            t.idx[slot] = f as i32;
            t.l1[slot] = l1;
            // The probed input bit: leading-1 column minus the weight shift,
            // clamped to the 4-bit input width.
            t.pos[slot] = (l1 - p).clamp(0, in_max_bit);
            t.sign[slot] = model.w1s[wi];
        }
        // Unbiased hardwired base (see doc comment above).
        let mut base = model.b1[n] as f64;
        for f in 0..fs {
            let i = n * fs + f;
            if feat_mask[f] == 0 || model.w1s[i] == 0 {
                continue;
            }
            base += model.w1s[i] as f64 * means[f] * (1i64 << model.w1p[i]) as f64;
        }
        for k in 0..2 {
            let slot = n * 2 + k;
            if t.sign[slot] == 0 {
                continue;
            }
            let pr = bit_prob(t.idx[slot] as usize, t.pos[slot]);
            base -= t.sign[slot] as f64 * pr * (1i64 << t.l1[slot]) as f64;
        }
        t.base[n] = base.round() as i32;
    }
    t
}

/// Convenience: tables from a dataset split with a full feature mask.
pub fn approx_tables_from_split(model: &QuantModel, split: &Split) -> ApproxTables {
    approx_tables(model, &split.xs, split.len(), &vec![1u8; model.features])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::QuantModel;

    fn toy() -> QuantModel {
        QuantModel {
            name: "toy".into(),
            features: 4,
            classes: 2,
            hidden: 2,
            in_bits: 4,
            w_bits: 8,
            pmax: 6,
            trunc: 0,
            seq_clock_ms: 100.0,
            comb_clock_ms: 320.0,
            float_acc: 0.0,
            train_acc: 0.0,
            test_acc: 0.0,
            // n0 weights: [2^0, -2^3, 0, 2^1], n1: [0, 2^0, 2^2, 0]
            w1p: vec![0, 3, 0, 1, 0, 0, 2, 0],
            w1s: vec![1, -1, 0, 1, 0, 1, 1, 0],
            b1: vec![0, 0],
            w2p: vec![0, 0, 0, 0],
            w2s: vec![1, 1, 1, 1],
            b2: vec![0, 0],
        }
    }

    #[test]
    fn means_are_columnwise() {
        // 2 samples, 3 features
        let xs = [1u8, 2, 3, 3, 2, 1];
        let mu = feature_means(&xs, 2, 3);
        assert_eq!(mu, vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn avg_prod_eq1() {
        let m = toy();
        let means = vec![2.0, 4.0, 8.0, 1.0];
        let ap = avg_products(&m, &means);
        // n0: [2*1, 4*8, 0 (pruned), 1*2] = [2, 32, 0, 2]
        assert_eq!(&ap[0..4], &[2.0, 32.0, 0.0, 2.0]);
        // n1: [0, 4*1, 8*4, 0] = [0, 4, 32, 0]
        assert_eq!(&ap[4..8], &[0.0, 4.0, 32.0, 0.0]);
    }

    #[test]
    fn relevance_orders_by_mean_product() {
        let m = toy();
        let means = vec![2.0, 4.0, 8.0, 1.0];
        let rel = feature_relevance(&m, &means);
        // f1: (32+4)/2=18, f2: (0+32)/2=16, f0: 1, f3: 1
        let order = relevance_order(&rel);
        assert_eq!(order[0], 1);
        assert_eq!(order[1], 2);
    }

    #[test]
    fn tables_pick_top2_and_leading1() {
        let m = toy();
        let xs = [2u8, 4, 8, 1]; // one sample => means [2,4,8,1]
        let t = approx_tables(&m, &xs, 1, &[1, 1, 1, 1]);
        // neuron0 top2: f1 (32) then f0/f3 tie at 2.0 -> f0 first seen wins.
        assert_eq!(t.idx[0], 1);
        assert_eq!(t.l1[0], 5); // log2(32)
        assert_eq!(t.pos[0], 2); // 5 - p(=3) = 2
        assert_eq!(t.sign[0], -1);
        assert_eq!(t.idx[1], 0);
        assert_eq!(t.l1[1], 1); // log2(2)
        assert_eq!(t.pos[1], 1); // 1 - 0
    }

    #[test]
    fn masked_features_are_skipped() {
        let m = toy();
        let xs = [2u8, 4, 8, 1];
        let t = approx_tables(&m, &xs, 1, &[1, 0, 1, 1]); // prune f1
        assert_ne!(t.idx[0], 1, "pruned feature must not be selected");
    }

    #[test]
    fn neuron_with_one_input_gets_single_slot() {
        let mut m = toy();
        // n1 keeps only f2.
        m.w1s = vec![1, -1, 0, 1, 0, 0, 1, 0];
        let xs = [2u8, 4, 8, 1];
        let t = approx_tables(&m, &xs, 1, &[1, 1, 1, 1]);
        assert_eq!(t.sign[2 * 1 + 1], 0, "second slot disabled");
        assert_eq!(t.idx[2], 2);
    }
}
