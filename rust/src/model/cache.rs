//! Delta-logit fitness cache for the NSGA-II approximation search
//! (DESIGN.md §Perf).
//!
//! A genome only chooses, per hidden neuron, between two *fixed*
//! activations — exact (multi-cycle) or approximated (single-cycle) —
//! and the output layer is linear in those activations.  Over a fixed
//! fitness split the logits of any approximation mask therefore
//! decompose as
//!
//! ```text
//! logits[c][i] = base[c][i] + Σ_{h : mask[h]=1} Δ[h][c][i]
//! Δ[h][c][i]   = w2s[c][h] · ((hid_approx[i][h] − hid_exact[i][h]) << w2p[c][h])
//! ```
//!
//! where `base` is the all-exact logits.  [`FitnessCache::build`] pays
//! one O(n·hidden·features) precompute for `base` plus the per-neuron,
//! per-class delta columns (stored sample-contiguous — SoA, i32 lanes —
//! so the apply loops are straight-line adds over contiguous memory and
//! autovectorize); after that a genome evaluation costs
//! O(n·classes·|changed|): [`FitnessCache::apply`] re-applies only the
//! XOR-diff between the scratch's current mask and the requested one,
//! which between an NSGA parent and child is a handful of neurons.
//! Columns that are identically zero (pruned output weight, or an
//! approximation that never changes the activation on this split) are
//! flagged and skipped entirely.
//!
//! Bit-identity with the scalar oracle ([`QuantModel::forward`]) is
//! structural, not approximate: the decomposition telescopes exactly in
//! i32 arithmetic (`a<<p − b<<p == (a−b)<<p` at these magnitudes — the
//! qReLU range is [0, 15] and shifts are ≤ `pmax`), every intermediate
//! scratch state equals some valid mask's logits, and the argmax uses
//! the same strict-`>` lowest-index tie break.  `tests/fitness_cache.rs`
//! enforces the equivalence differentially over random models × masks ×
//! splits; `PRINTED_MLP_NO_FITNESS_CACHE=1` / `--no-fitness-cache`
//! select the scalar path at run time (see [`crate::approx`]).

use super::{qrelu, ApproxTables, QuantModel};

/// Precomputed baseline + per-neuron delta-logit columns for one
/// (model, split, feature-mask, tables) fitness context.  Read-only
/// after [`Self::build`]; workers share it and carry their own
/// [`CacheScratch`].
pub struct FitnessCache {
    n: usize,
    classes: usize,
    hidden: usize,
    /// All-exact logits, class-major: `base[c * n + i]`.
    base: Vec<i32>,
    /// Delta columns, sample-contiguous: `delta[(h * classes + c) * n + i]`.
    delta: Vec<i32>,
    /// Per-(h, c) flag: `false` when the whole column is zero, so
    /// [`Self::apply`] skips it without touching the data.
    nonzero: Vec<bool>,
    /// Split labels, for [`Self::accuracy`].
    ys: Vec<u16>,
}

/// Per-worker mutable state: the logits of the last-applied mask plus
/// that mask.  Persisting a scratch across generations is what makes
/// the parent→child incremental path pay only for changed neurons.
#[derive(Default)]
pub struct CacheScratch {
    /// Class-major logits of `mask`: `logits[c * n + i]`.  Empty until
    /// the first [`FitnessCache::apply`].
    logits: Vec<i32>,
    /// The approximation mask `logits` currently reflects.
    mask: Vec<u8>,
}

impl FitnessCache {
    /// One full pass over the split: exact and approximated activations
    /// per (sample, neuron), then the baseline logits and delta columns.
    pub fn build(
        model: &QuantModel,
        xs: &[u8],
        ys: &[u16],
        feat_mask: &[u8],
        tables: &ApproxTables,
    ) -> Self {
        let (f, hidden, classes) = (model.features, model.hidden, model.classes);
        let n = ys.len();
        assert_eq!(xs.len(), n * f, "xs rows must match ys");
        let mut base = vec![0i32; classes * n];
        let mut delta = vec![0i32; hidden * classes * n];
        let mut nonzero = vec![false; hidden * classes];
        let mut x = vec![0i32; f];
        let mut hid_exact = vec![0i32; hidden];
        let mut hid_diff = vec![0i32; hidden];
        for i in 0..n {
            for (xj, &v) in x.iter_mut().zip(&xs[i * f..(i + 1) * f]) {
                *xj = v as i32;
            }
            for h in 0..hidden {
                hid_exact[h] = qrelu(model.hidden_acc_exact(&x, feat_mask, h), model.trunc);
                let approx = qrelu(model.hidden_acc_approx(&x, feat_mask, tables, h), model.trunc);
                hid_diff[h] = approx - hid_exact[h];
            }
            for c in 0..classes {
                let row = &model.w2p[c * hidden..(c + 1) * hidden];
                let sgn = &model.w2s[c * hidden..(c + 1) * hidden];
                let mut acc = model.b2[c];
                for h in 0..hidden {
                    acc += sgn[h] * (hid_exact[h] << row[h]);
                }
                base[c * n + i] = acc;
                for h in 0..hidden {
                    let d = sgn[h] * (hid_diff[h] << row[h]);
                    if d != 0 {
                        delta[(h * classes + c) * n + i] = d;
                        nonzero[h * classes + c] = true;
                    }
                }
            }
        }
        FitnessCache {
            n,
            classes,
            hidden,
            base,
            delta,
            nonzero,
            ys: ys.to_vec(),
        }
    }

    /// Fresh worker scratch (lazily initialized from the baseline on its
    /// first [`Self::apply`]).
    pub fn new_scratch(&self) -> CacheScratch {
        CacheScratch::default()
    }

    /// Number of samples the cache covers.
    pub fn samples(&self) -> usize {
        self.n
    }

    /// Fraction of (neuron, class) delta columns that are identically
    /// zero and therefore skipped by [`Self::apply`].
    pub fn zero_column_rate(&self) -> f64 {
        let total = self.nonzero.len();
        if total == 0 {
            return 0.0;
        }
        self.nonzero.iter().filter(|&&nz| !nz).count() as f64 / total as f64
    }

    /// Bring `scratch.logits` to `mask`'s logits by adding/subtracting
    /// only the delta columns whose mask bit changed since the scratch's
    /// previous mask (first use initializes from the all-exact
    /// baseline).  Every intermediate state equals some valid mask's
    /// logits, so overflow behavior matches the scalar oracle exactly.
    pub fn apply(&self, scratch: &mut CacheScratch, mask: &[u8]) {
        assert_eq!(mask.len(), self.hidden, "mask length");
        if scratch.mask.len() != self.hidden {
            scratch.logits.clear();
            scratch.logits.extend_from_slice(&self.base);
            scratch.mask.clear();
            scratch.mask.resize(self.hidden, 0);
        }
        let n = self.n;
        for h in 0..self.hidden {
            let want = mask[h] != 0;
            if want == (scratch.mask[h] != 0) {
                continue;
            }
            scratch.mask[h] = want as u8;
            for c in 0..self.classes {
                let col = h * self.classes + c;
                if !self.nonzero[col] {
                    continue;
                }
                let src = &self.delta[col * n..(col + 1) * n];
                let dst = &mut scratch.logits[c * n..(c + 1) * n];
                if want {
                    for (l, &d) in dst.iter_mut().zip(src) {
                        *l += d;
                    }
                } else {
                    for (l, &d) in dst.iter_mut().zip(src) {
                        *l -= d;
                    }
                }
            }
        }
    }

    /// Split accuracy under `mask` — the cached twin of
    /// [`QuantModel::accuracy`], bit-identical by construction.
    pub fn accuracy(&self, scratch: &mut CacheScratch, mask: &[u8]) -> f64 {
        self.apply(scratch, mask);
        let mut correct = 0usize;
        for i in 0..self.n {
            if self.argmax(&scratch.logits, i) == self.ys[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / self.n.max(1) as f64
    }

    /// Predictions under `mask` into `out` (cleared first) — the
    /// differential hook `tests/fitness_cache.rs` compares against the
    /// scalar [`QuantModel::forward`] oracle.
    pub fn predict_into(&self, scratch: &mut CacheScratch, mask: &[u8], out: &mut Vec<i32>) {
        self.apply(scratch, mask);
        out.clear();
        out.reserve(self.n);
        for i in 0..self.n {
            out.push(self.argmax(&scratch.logits, i) as i32);
        }
    }

    /// Strided argmax over the class-major logits of sample `i`; ties
    /// break to the lowest class index (strict `>`), matching
    /// [`QuantModel::forward`].
    #[inline]
    fn argmax(&self, logits: &[i32], i: usize) -> usize {
        let mut best = 0usize;
        for c in 1..self.classes {
            if logits[c * self.n + i] > logits[best * self.n + i] {
                best = c;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth::rand_model;
    use crate::util::prng::Rng;

    fn scalar_predictions(
        m: &QuantModel,
        xs: &[u8],
        n: usize,
        fm: &[u8],
        am: &[u8],
        tables: &ApproxTables,
    ) -> Vec<i32> {
        let mut out = Vec::new();
        let mut x = vec![0i32; m.features];
        for i in 0..n {
            for (xj, &v) in x.iter_mut().zip(&xs[i * m.features..(i + 1) * m.features]) {
                *xj = v as i32;
            }
            out.push(m.forward(&x, fm, am, tables).0 as i32);
        }
        out
    }

    #[test]
    fn baseline_matches_all_exact_oracle() {
        let m = rand_model(41, 9, 6, 4);
        let mut r = Rng::new(2);
        let n = 30;
        let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
        let ys: Vec<u16> = (0..n).map(|_| r.below(m.classes as u64) as u16).collect();
        let fm = vec![1u8; m.features];
        let tables = crate::model::importance::approx_tables(&m, &xs, n, &fm);
        let cache = FitnessCache::build(&m, &xs, &ys, &fm, &tables);
        let mut scratch = cache.new_scratch();
        let zeros = vec![0u8; m.hidden];
        let mut preds = Vec::new();
        cache.predict_into(&mut scratch, &zeros, &mut preds);
        assert_eq!(preds, scalar_predictions(&m, &xs, n, &fm, &zeros, &tables));
        assert_eq!(
            cache.accuracy(&mut scratch, &zeros),
            m.accuracy(&xs, &ys, &fm, &zeros, &tables)
        );
    }

    #[test]
    fn incremental_mask_walk_matches_oracle_and_fresh_scratch() {
        let m = rand_model(42, 11, 8, 3);
        let mut r = Rng::new(3);
        let n = 40;
        let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
        let ys: Vec<u16> = (0..n).map(|_| r.below(m.classes as u64) as u16).collect();
        let fm = vec![1u8; m.features];
        let tables = crate::model::importance::approx_tables(&m, &xs, n, &fm);
        let cache = FitnessCache::build(&m, &xs, &ys, &fm, &tables);
        let mut walk = cache.new_scratch();
        for step in 0..12u64 {
            let mut mr = Rng::new(100 + step);
            let mask: Vec<u8> = (0..m.hidden).map(|_| mr.chance(0.5) as u8).collect();
            let mut got = Vec::new();
            cache.predict_into(&mut walk, &mask, &mut got);
            let want = scalar_predictions(&m, &xs, n, &fm, &mask, &tables);
            assert_eq!(got, want, "incremental walk step {step}");
            // A cold scratch must land on the same state the walk did.
            let mut fresh = cache.new_scratch();
            let mut cold = Vec::new();
            cache.predict_into(&mut fresh, &mask, &mut cold);
            assert_eq!(got, cold, "fresh vs incremental, step {step}");
        }
    }

    #[test]
    fn all_approx_and_feature_mask_paths_match() {
        let m = rand_model(43, 7, 5, 3);
        let mut r = Rng::new(4);
        let n = 24;
        let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
        let ys: Vec<u16> = (0..n).map(|_| r.below(m.classes as u64) as u16).collect();
        // Prune a couple of features: the cache must bake feat_mask in.
        let mut fm = vec![1u8; m.features];
        fm[0] = 0;
        fm[3] = 0;
        let tables = crate::model::importance::approx_tables(&m, &xs, n, &fm);
        let cache = FitnessCache::build(&m, &xs, &ys, &fm, &tables);
        let mut scratch = cache.new_scratch();
        let ones = vec![1u8; m.hidden];
        assert_eq!(
            cache.accuracy(&mut scratch, &ones),
            m.accuracy(&xs, &ys, &fm, &ones, &tables)
        );
    }

    #[test]
    fn zero_columns_are_flagged_for_pruned_output_weights() {
        let mut m = rand_model(44, 6, 4, 3);
        // Prune every output weight of neuron 1: its delta columns must
        // all be zero no matter what the activations do.
        for c in 0..m.classes {
            m.w2s[c * m.hidden + 1] = 0;
        }
        let mut r = Rng::new(5);
        let n = 16;
        let xs: Vec<u8> = (0..n * m.features).map(|_| r.below(16) as u8).collect();
        let ys: Vec<u16> = (0..n).map(|_| r.below(m.classes as u64) as u16).collect();
        let fm = vec![1u8; m.features];
        let tables = crate::model::importance::approx_tables(&m, &xs, n, &fm);
        let cache = FitnessCache::build(&m, &xs, &ys, &fm, &tables);
        assert!(cache.zero_column_rate() >= 3.0 / (4.0 * 3.0) - 1e-12);
        // Toggling the pruned neuron must not change anything.
        let mut scratch = cache.new_scratch();
        let mut mask = vec![0u8; m.hidden];
        let base_acc = cache.accuracy(&mut scratch, &mask);
        mask[1] = 1;
        assert_eq!(cache.accuracy(&mut scratch, &mask), base_acc);
        assert_eq!(base_acc, m.accuracy(&xs, &ys, &fm, &mask, &tables));
    }

    #[test]
    fn empty_split_is_harmless() {
        let m = rand_model(45, 5, 3, 2);
        let fm = vec![1u8; m.features];
        let tables = ApproxTables::disabled(m.hidden);
        let cache = FitnessCache::build(&m, &[], &[], &fm, &tables);
        assert_eq!(cache.samples(), 0);
        let mut scratch = cache.new_scratch();
        assert_eq!(cache.accuracy(&mut scratch, &vec![1u8; m.hidden]), 0.0);
    }
}
