//! Deterministic synthetic models and datasets.
//!
//! One generator shared by the integration tests/benches (via
//! `rust/tests/common`), the serve smoke path (`printed-mlp serve
//! --synthetic`), and the `serve_scaling` bench — all places that need a
//! valid [`QuantModel`] plus traffic without `make artifacts`.  Outputs
//! are fully determined by the seed, so cross-harness comparisons stay
//! bit-exact.

use crate::data::Split;
use crate::model::{ApproxTables, QuantModel};
use crate::util::prng::Rng;

/// Random valid pow2-quantized model (signs in {-1,0,1}, powers in
/// [0, pmax]); fully determined by `seed`.
pub fn rand_model(seed: u64, features: usize, hidden: usize, classes: usize) -> QuantModel {
    let mut r = Rng::new(seed);
    let pmax = 6u32;
    let mut w1p = vec![0i32; hidden * features];
    let mut w1s = vec![0i32; hidden * features];
    for i in 0..hidden * features {
        w1p[i] = r.below(pmax as u64 + 1) as i32;
        w1s[i] = [-1, 0, 1][r.usize_below(3)];
    }
    let mut w2p = vec![0i32; classes * hidden];
    let mut w2s = vec![0i32; classes * hidden];
    for i in 0..classes * hidden {
        w2p[i] = r.below(pmax as u64 + 1) as i32;
        w2s[i] = [-1, 0, 1][r.usize_below(3)];
    }
    QuantModel {
        name: format!("rand{seed}"),
        features,
        classes,
        hidden,
        in_bits: 4,
        w_bits: 8,
        pmax,
        trunc: (r.below(6) + 1) as u32,
        seq_clock_ms: 100.0,
        comb_clock_ms: 320.0,
        float_acc: 0.0,
        train_acc: 0.0,
        test_acc: 0.0,
        w1p,
        w1s,
        b1: (0..hidden).map(|_| r.i32_range(-300, 300)).collect(),
        w2p,
        w2s,
        b2: (0..classes).map(|_| r.i32_range(-300, 300)).collect(),
    }
}

/// Random 4-bit split of `n` samples, labeled with the model's own
/// full-mask predictions — so any exact evaluator scores accuracy 1.0 on
/// it, which turns serve-mode accuracy into a correctness check.
pub fn rand_split(model: &QuantModel, seed: u64, n: usize) -> Split {
    let mut r = Rng::new(seed);
    let f = model.features;
    let xs: Vec<u8> = (0..n * f).map(|_| r.below(16) as u8).collect();
    let fm = vec![1u8; f];
    let am = vec![0u8; model.hidden];
    let tables = ApproxTables::disabled(model.hidden);
    let mut preds = Vec::new();
    model.predict_rows_into(&xs, n, &fm, &am, &tables, &mut preds);
    let ys: Vec<u16> = preds.into_iter().map(|p| p as u16).collect();
    Split { xs, ys, features: f }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_valid() {
        let a = rand_model(9, 8, 5, 3);
        let b = rand_model(9, 8, 5, 3);
        assert_eq!(a.w1p, b.w1p);
        assert_eq!(a.b2, b.b2);
        a.validate().unwrap();
    }

    #[test]
    fn split_labels_are_model_predictions() {
        let m = rand_model(4, 6, 4, 3);
        let s = rand_split(&m, 77, 20);
        assert_eq!(s.len(), 20);
        let fm = vec![1u8; m.features];
        let am = vec![0u8; m.hidden];
        let t = ApproxTables::disabled(m.hidden);
        assert_eq!(m.accuracy(&s.xs, &s.ys, &fm, &am, &t), 1.0);
    }
}
