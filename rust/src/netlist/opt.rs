//! Netlist cleanup: constant folding + buffer/double-inverter collapsing
//! ([`fold_collapse`]), structural sharing ([`cse`]), inverter fusion
//! ([`fuse_inversions`]) and dead-cell elimination ([`dce`],
//! mark-and-sweep from primary outputs).  Constant folding happens eagerly
//! in the builder constructors; after bespoke hardwiring collapses most of
//! the weight muxes to constants, these passes sweep away the remainder —
//! the "synthesis" step that makes hardwired designs small, mirroring what
//! Design Compiler does to constant-driven logic.
//!
//! The same passes double as the plan-time strength reduction of the
//! compiled simulator ([`crate::sim::SimPlan::compiled`]): every cell they
//! remove is a micro-op the simulator never executes, so [`fold_collapse`]
//! also returns the net-replacement map the plan's external port map is
//! built from.

use super::{Cell, NetId, Netlist, CONST0, CONST1};

/// Statistics returned by [`dce`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DceStats {
    pub cells_before: usize,
    pub cells_after: usize,
}

/// Outcome of one [`fold_collapse`] cell visit (internal).
enum Folded {
    /// Output net is an alias of another (possibly constant) net.
    Alias(NetId),
    /// Cell survives, possibly strength-reduced, with rewired inputs.
    Keep(Cell),
}

/// Reduce an inversion of `a` driving `y`: constants fold, a double
/// inversion collapses to the original source, anything else keeps an
/// INV cell.  `inv_src[t]` is the input of the surviving INV that drives
/// net `t` (`u32::MAX` when `t` is not an INV output).
fn mk_inv(a: NetId, y: NetId, inv_src: &[NetId]) -> Folded {
    match a {
        CONST0 => Folded::Alias(CONST1),
        CONST1 => Folded::Alias(CONST0),
        _ if inv_src[a as usize] != u32::MAX => Folded::Alias(inv_src[a as usize]),
        _ => Folded::Keep(Cell::Inv { a, y }),
    }
}

/// Constant folding + buffer and double-inverter chain collapsing, in one
/// topological pass.
///
/// Rewrites every combinational cell with its inputs resolved through the
/// running replacement map, then:
/// - folds gates with constant inputs (`AND(x,1) → x`, `NOR(x,1) → 0`,
///   `XOR(x,1) → INV(x)`, mux data/select constants, …) and same-input
///   idempotence (`AND(x,x) → x`, `XOR(x,x) → 0`);
/// - elides every `BUF` (pure aliasing) and collapses `INV(INV(x)) → x`;
/// - strength-reduces to `INV` where a single inverter expresses the
///   remainder (`NAND(x,1)`, `NOR(x,x)`, `MUX(s,1,0)`, …).
///
/// DFF inputs and output ports are rewired through the final map; DFFs
/// themselves are never folded (their q nets are sequential sources).
/// Returns the replacement map (`original net → surviving net`, identity
/// where unchanged — constants are nets 0/1, so folds to constants are
/// plain aliases) so callers building external-id translations (the
/// compiled sim plan) can compose it.  Idempotent.
pub fn fold_collapse(n: &mut Netlist) -> Vec<NetId> {
    let nets = n.n_nets();
    let mut repl: Vec<NetId> = (0..nets as u32).collect();
    let mut inv_src: Vec<NetId> = vec![u32::MAX; nets];
    let order = n.topo_order();
    let mut removed = vec![false; n.cells.len()];
    for ci in order {
        let c = n.cells[ci];
        let r = |x: NetId| repl[x as usize];
        let out = match c {
            Cell::Buf { a, y: _ } => Folded::Alias(r(a)),
            Cell::Inv { a, y } => mk_inv(r(a), y, &inv_src),
            Cell::And2 { a, b, y } => {
                let (a, b) = (r(a), r(b));
                match (a, b) {
                    (CONST0, _) | (_, CONST0) => Folded::Alias(CONST0),
                    (CONST1, x) | (x, CONST1) => Folded::Alias(x),
                    _ if a == b => Folded::Alias(a),
                    _ => Folded::Keep(Cell::And2 { a, b, y }),
                }
            }
            Cell::Or2 { a, b, y } => {
                let (a, b) = (r(a), r(b));
                match (a, b) {
                    (CONST1, _) | (_, CONST1) => Folded::Alias(CONST1),
                    (CONST0, x) | (x, CONST0) => Folded::Alias(x),
                    _ if a == b => Folded::Alias(a),
                    _ => Folded::Keep(Cell::Or2 { a, b, y }),
                }
            }
            Cell::Nand2 { a, b, y } => {
                let (a, b) = (r(a), r(b));
                match (a, b) {
                    (CONST0, _) | (_, CONST0) => Folded::Alias(CONST1),
                    (CONST1, x) | (x, CONST1) => mk_inv(x, y, &inv_src),
                    _ if a == b => mk_inv(a, y, &inv_src),
                    _ => Folded::Keep(Cell::Nand2 { a, b, y }),
                }
            }
            Cell::Nor2 { a, b, y } => {
                let (a, b) = (r(a), r(b));
                match (a, b) {
                    (CONST1, _) | (_, CONST1) => Folded::Alias(CONST0),
                    (CONST0, x) | (x, CONST0) => mk_inv(x, y, &inv_src),
                    _ if a == b => mk_inv(a, y, &inv_src),
                    _ => Folded::Keep(Cell::Nor2 { a, b, y }),
                }
            }
            Cell::Xor2 { a, b, y } => {
                let (a, b) = (r(a), r(b));
                match (a, b) {
                    _ if a == b => Folded::Alias(CONST0),
                    (CONST0, x) | (x, CONST0) => Folded::Alias(x),
                    (CONST1, x) | (x, CONST1) => mk_inv(x, y, &inv_src),
                    _ => Folded::Keep(Cell::Xor2 { a, b, y }),
                }
            }
            Cell::Xnor2 { a, b, y } => {
                let (a, b) = (r(a), r(b));
                match (a, b) {
                    _ if a == b => Folded::Alias(CONST1),
                    (CONST1, x) | (x, CONST1) => Folded::Alias(x),
                    (CONST0, x) | (x, CONST0) => mk_inv(x, y, &inv_src),
                    _ => Folded::Keep(Cell::Xnor2 { a, b, y }),
                }
            }
            // y = sel ? b : a
            Cell::Mux2 { a, b, sel, y } => {
                let (a, b, sel) = (r(a), r(b), r(sel));
                match (sel, a, b) {
                    (CONST0, a, _) => Folded::Alias(a),
                    (CONST1, _, b) => Folded::Alias(b),
                    (_, a, b) if a == b => Folded::Alias(a),
                    (s, CONST0, CONST1) => Folded::Alias(s),
                    (s, CONST1, CONST0) => mk_inv(s, y, &inv_src),
                    // sel ? b : 0 == sel & b ; sel ? 1 : a == sel | a.
                    // (The inverted-select cases need a fresh INV net, so
                    // they are left as muxes with a constant data leg.)
                    (s, CONST0, b) => Folded::Keep(Cell::And2 { a: s, b, y }),
                    (s, a, CONST1) => Folded::Keep(Cell::Or2 { a: s, b: a, y }),
                    (sel, a, b) => Folded::Keep(Cell::Mux2 { a, b, sel, y }),
                }
            }
            Cell::Dff { .. } => unreachable!("DFF in comb topo order"),
        };
        match out {
            Folded::Alias(t) => {
                repl[c.output() as usize] = t;
                removed[ci] = true;
            }
            Folded::Keep(c2) => {
                if let Cell::Inv { a, y } = c2 {
                    inv_src[y as usize] = a;
                }
                n.cells[ci] = c2;
            }
        }
    }
    // Rewire the sequential cells and output ports through the final map.
    for c in n.cells.iter_mut() {
        if let Cell::Dff { d, en, rst, .. } = c {
            *d = repl[*d as usize];
            *en = repl[*en as usize];
            *rst = repl[*rst as usize];
        }
    }
    for port in n.outputs.iter_mut() {
        for b in port.bits.iter_mut() {
            *b = repl[*b as usize];
        }
    }
    let mut kept = Vec::with_capacity(n.cells.len());
    for (i, c) in n.cells.iter().enumerate() {
        if !removed[i] {
            kept.push(*c);
        }
    }
    n.cells = kept;
    repl
}

/// Fuse a lone inverter into its single-fanout producer: `INV(AND(a,b))`
/// becomes `NAND(a,b)` writing the inverter's output directly (and the
/// complementary rewrites for OR/XOR/NAND/NOR/XNOR).  Printed-EGFET NAND
/// and NOR are *cheaper* than AND/OR, so this is an area win as well as
/// one fewer simulator micro-op per fused pair.
///
/// Only fires when the producer's output has exactly one reader (the
/// inverter) and is not an output-port bit, so external observers never
/// lose a net.  Returns the number of inverters fused away.
pub fn fuse_inversions(n: &mut Netlist) -> usize {
    let nets = n.n_nets();
    let mut fanout = vec![0u32; nets];
    for c in n.cells.iter() {
        c.for_each_input(|i| fanout[i as usize] += 1);
    }
    for port in &n.outputs {
        for &b in &port.bits {
            fanout[b as usize] += 1;
        }
    }
    let mut driver = vec![u32::MAX; nets];
    for (i, c) in n.cells.iter().enumerate() {
        if !c.is_seq() {
            driver[c.output() as usize] = i as u32;
        }
    }
    let mut removed = vec![false; n.cells.len()];
    let mut fused = 0usize;
    for ci in 0..n.cells.len() {
        let Cell::Inv { a, y } = n.cells[ci] else {
            continue;
        };
        let di = driver[a as usize];
        if di == u32::MAX || fanout[a as usize] != 1 {
            continue;
        }
        let complement = match n.cells[di as usize] {
            Cell::And2 { a, b, .. } => Some(Cell::Nand2 { a, b, y }),
            Cell::Or2 { a, b, .. } => Some(Cell::Nor2 { a, b, y }),
            Cell::Xor2 { a, b, .. } => Some(Cell::Xnor2 { a, b, y }),
            Cell::Nand2 { a, b, .. } => Some(Cell::And2 { a, b, y }),
            Cell::Nor2 { a, b, .. } => Some(Cell::Or2 { a, b, y }),
            Cell::Xnor2 { a, b, .. } => Some(Cell::Xor2 { a, b, y }),
            // INV/BUF chains are fold_collapse's job; muxes and DFFs
            // have no single-cell complement in the library.
            _ => None,
        };
        if let Some(c2) = complement {
            n.cells[di as usize] = c2;
            removed[ci] = true;
            fused += 1;
        }
    }
    if fused > 0 {
        let mut kept = Vec::with_capacity(n.cells.len() - fused);
        for (i, c) in n.cells.iter().enumerate() {
            if !removed[i] {
                kept.push(*c);
            }
        }
        n.cells = kept;
    }
    fused
}

/// Remove every cell whose output transitively drives no primary output
/// and no live register. Returns the number removed.
pub fn dce(n: &mut Netlist) -> DceStats {
    let before = n.cells.len();
    let nets = n.n_nets();
    let mut driver: Vec<u32> = vec![u32::MAX; nets];
    for (i, c) in n.cells.iter().enumerate() {
        driver[c.output() as usize] = i as u32;
    }

    let mut live = vec![false; n.cells.len()];
    let mut stack: Vec<u32> = Vec::new();
    let mark_net = |net: u32, stack: &mut Vec<u32>| {
        let d = driver[net as usize];
        if d != u32::MAX {
            stack.push(d);
        }
    };
    for port in &n.outputs {
        for &b in &port.bits {
            mark_net(b, &mut stack);
        }
    }
    while let Some(ci) = stack.pop() {
        let ci = ci as usize;
        if live[ci] {
            continue;
        }
        live[ci] = true;
        n.cells[ci].for_each_input(|inp| {
            let d = driver[inp as usize];
            if d != u32::MAX && !live[d as usize] {
                stack.push(d);
            }
        });
    }

    let mut kept = Vec::with_capacity(n.cells.len());
    for (i, c) in n.cells.iter().enumerate() {
        if live[i] {
            kept.push(*c);
        }
    }
    n.cells = kept;
    DceStats {
        cells_before: before,
        cells_after: n.cells.len(),
    }
}

/// Share structurally identical combinational cells (CSE): two gates of
/// the same type with the same inputs produce the same value, so the
/// second is replaced by a rewire.  Iterates to a fixed point; DFFs are
/// never merged.  Returns the number of cells eliminated.
pub fn cse(n: &mut Netlist) -> usize {
    use std::collections::HashMap;
    let mut eliminated = 0usize;
    loop {
        let mut repl: Vec<u32> = (0..n.n_nets() as u32).collect();
        let mut seen: HashMap<(u8, u32, u32, u32), u32> = HashMap::new();
        let mut kept: Vec<Cell> = Vec::with_capacity(n.cells.len());
        let mut changed = false;
        for c in n.cells.iter() {
            let mut c = *c;
            // Rewire inputs through current replacement map.
            c = rewire(c, &repl);
            if c.is_seq() {
                kept.push(c);
                continue;
            }
            let key = cell_key(&c);
            match seen.get(&key) {
                Some(&existing) => {
                    repl[c.output() as usize] = existing;
                    eliminated += 1;
                    changed = true;
                }
                None => {
                    seen.insert(key, c.output());
                    kept.push(c);
                }
            }
        }
        // Final rewire pass over cells + ports with the full map.
        for c in kept.iter_mut() {
            *c = rewire(*c, &repl);
        }
        for port in n.outputs.iter_mut() {
            for b in port.bits.iter_mut() {
                *b = repl[*b as usize];
            }
        }
        n.cells = kept;
        if !changed {
            break;
        }
    }
    eliminated
}

fn cell_key(c: &Cell) -> (u8, u32, u32, u32) {
    // Commutative gates get sorted operands so (a,b) == (b,a).
    match *c {
        Cell::Inv { a, .. } => (0, a, 0, 0),
        Cell::Buf { a, .. } => (1, a, 0, 0),
        Cell::Nand2 { a, b, .. } => (2, a.min(b), a.max(b), 0),
        Cell::Nor2 { a, b, .. } => (3, a.min(b), a.max(b), 0),
        Cell::And2 { a, b, .. } => (4, a.min(b), a.max(b), 0),
        Cell::Or2 { a, b, .. } => (5, a.min(b), a.max(b), 0),
        Cell::Xor2 { a, b, .. } => (6, a.min(b), a.max(b), 0),
        Cell::Xnor2 { a, b, .. } => (7, a.min(b), a.max(b), 0),
        Cell::Mux2 { a, b, sel, .. } => (8, a, b, sel),
        Cell::Dff { .. } => unreachable!(),
    }
}

fn rewire(mut c: Cell, repl: &[u32]) -> Cell {
    let r = |x: u32| repl[x as usize];
    match &mut c {
        Cell::Inv { a, .. } | Cell::Buf { a, .. } => *a = r(*a),
        Cell::Nand2 { a, b, .. }
        | Cell::Nor2 { a, b, .. }
        | Cell::And2 { a, b, .. }
        | Cell::Or2 { a, b, .. }
        | Cell::Xor2 { a, b, .. }
        | Cell::Xnor2 { a, b, .. } => {
            *a = r(*a);
            *b = r(*b);
        }
        Cell::Mux2 { a, b, sel, .. } => {
            *a = r(*a);
            *b = r(*b);
            *sel = r(*sel);
        }
        Cell::Dff { d, en, rst, .. } => {
            *d = r(*d);
            *en = r(*en);
            *rst = r(*rst);
        }
    }
    c
}

/// Standard cleanup pipeline used by all circuit generators: constant
/// fold + buffer/double-inverter collapse, share structural duplicates,
/// then sweep dead logic.  ([`fuse_inversions`] is applied separately at
/// sim-plan compile time, where opcode count — not library area — is the
/// objective.)
pub fn optimize(n: &mut Netlist) -> DceStats {
    fold_collapse(n);
    cse(n);
    dce(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{Netlist, CONST1};

    #[test]
    fn dce_removes_unused() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let used = n.and2(a, b);
        let _dead = n.or2(a, b);
        n.add_output("y", vec![used]);
        let s = dce(&mut n);
        assert_eq!(s.cells_after, 1);
        assert!(matches!(n.cells[0], Cell::And2 { .. }));
    }

    #[test]
    fn dce_keeps_register_feedback() {
        let mut n = Netlist::new("t");
        let d = n.fresh();
        let q = n.dff(d, CONST1, crate::netlist::CONST0, false);
        let nq = n.inv(q);
        n.cells.push(Cell::Buf { a: nq, y: d });
        n.add_output("q", vec![q]);
        let s = dce(&mut n);
        assert_eq!(s.cells_after, 3, "dff + inv + buf all live");
    }

    #[test]
    fn cse_merges_identical_gates() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let x = n.and2(a, b);
        let y = n.and2(b, a); // commutative duplicate
        let z = n.xor2(x, y); // folds to const after merge? no: xor(x,x)=0 only after rewire
        n.add_output("z", vec![z]);
        cse(&mut n);
        let and_count = n
            .cells
            .iter()
            .filter(|c| matches!(c, Cell::And2 { .. }))
            .count();
        assert_eq!(and_count, 1);
    }

    #[test]
    fn fold_collapse_elides_buffers_and_double_inverters() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        // a -> BUF -> INV -> INV -> BUF -> y : collapses to y == a.
        let b1 = n.fresh();
        n.cells.push(Cell::Buf { a, y: b1 });
        let i1 = n.fresh();
        n.cells.push(Cell::Inv { a: b1, y: i1 });
        let i2 = n.fresh();
        n.cells.push(Cell::Inv { a: i1, y: i2 });
        let b2 = n.fresh();
        n.cells.push(Cell::Buf { a: i2, y: b2 });
        n.add_output("y", vec![b2]);
        let repl = fold_collapse(&mut n);
        assert_eq!(n.outputs[0].bits[0], a, "output rewired to the source");
        assert_eq!(repl[b2 as usize], a);
        assert_eq!(repl[i2 as usize], a);
        // The inner INV survives fold (it is merely unread now)…
        assert_eq!(n.cells.len(), 1);
        assert!(matches!(n.cells[0], Cell::Inv { .. }));
        // …and DCE sweeps it, leaving pure wiring.
        dce(&mut n);
        assert!(n.cells.is_empty());
    }

    #[test]
    fn fold_collapse_folds_constants_through_gates() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        // Pushed raw so the builder's eager folding can't intercept.
        let y1 = n.fresh();
        n.cells.push(Cell::And2 { a, b: CONST1, y: y1 }); // -> a
        let y2 = n.fresh();
        n.cells.push(Cell::Or2 { a: y1, b: CONST1, y: y2 }); // -> 1
        let y3 = n.fresh();
        n.cells.push(Cell::Xor2 { a: y2, b, y: y3 }); // XOR(1,b) -> INV(b)
        let y4 = n.fresh();
        n.cells.push(Cell::Nand2 { a: y1, b: y1, y: y4 }); // NAND(a,a) -> INV(a)
        let y5 = n.fresh();
        n.cells.push(Cell::Mux2 { a: CONST0, b, sel: y2, y: y5 }); // sel==1 -> b
        n.add_output("y3", vec![y3]);
        n.add_output("y4", vec![y4]);
        n.add_output("y5", vec![y5]);
        let repl = fold_collapse(&mut n);
        assert_eq!(repl[y1 as usize], a);
        assert_eq!(repl[y2 as usize], CONST1);
        assert_eq!(repl[y5 as usize], b);
        assert_eq!(n.cells.len(), 2, "only the two INVs remain");
        assert!(matches!(n.cells[0], Cell::Inv { a: x, .. } if x == b));
        assert!(matches!(n.cells[1], Cell::Inv { a: x, .. } if x == a));
    }

    #[test]
    fn fuse_inversions_complements_single_fanout_producers() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let x = n.and2(a, b);
        let y = n.inv(x);
        n.add_output("y", vec![y]);
        assert_eq!(fuse_inversions(&mut n), 1);
        assert_eq!(n.cells.len(), 1);
        assert!(matches!(n.cells[0], Cell::Nand2 { .. }));
        assert_eq!(n.cells[0].output(), y, "fused gate drives the INV's net");
    }

    #[test]
    fn fuse_inversions_respects_fanout_and_ports() {
        // x has two readers -> no fusion; z is an output port -> no fusion.
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let x = n.and2(a, b);
        let inv1 = n.inv(x);
        let keep = n.or2(x, a);
        let z = n.xor2(a, b);
        let inv2 = n.inv(z);
        n.add_output("inv1", vec![inv1]);
        n.add_output("keep", vec![keep]);
        n.add_output("z", vec![z]);
        n.add_output("inv2", vec![inv2]);
        assert_eq!(fuse_inversions(&mut n), 0);
        assert_eq!(n.cells.len(), 5);
    }

    #[test]
    fn fold_collapse_is_idempotent_and_rewires_dffs() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let buf = n.fresh();
        n.cells.push(Cell::Buf { a, y: buf });
        let q = n.dff(buf, CONST1, crate::netlist::CONST0, false);
        n.add_output("q", vec![q]);
        fold_collapse(&mut n);
        assert!(matches!(n.cells[0], Cell::Dff { d, .. } if d == a));
        let c1 = n.cells.clone();
        fold_collapse(&mut n);
        assert_eq!(n.cells, c1);
    }

    #[test]
    fn optimize_is_idempotent() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let x = n.and2(a, b);
        let y = n.and2(a, b);
        let z = n.or2(x, y);
        n.add_output("z", vec![z]);
        optimize(&mut n);
        let c1 = n.cells.len();
        optimize(&mut n);
        assert_eq!(n.cells.len(), c1);
    }
}
