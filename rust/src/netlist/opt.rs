//! Netlist cleanup: dead-cell elimination (mark-and-sweep from primary
//! outputs and register inputs).  Constant folding happens eagerly in the
//! builder constructors; after bespoke hardwiring collapses most of the
//! weight muxes to constants, DCE sweeps away the unreachable remainder —
//! this is the "synthesis" step that makes hardwired designs small, and it
//! mirrors what Design Compiler does to constant-driven logic.

use super::{Cell, Netlist};

/// Statistics returned by [`dce`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DceStats {
    pub cells_before: usize,
    pub cells_after: usize,
}

/// Remove every cell whose output transitively drives no primary output
/// and no live register. Returns the number removed.
pub fn dce(n: &mut Netlist) -> DceStats {
    let before = n.cells.len();
    let nets = n.n_nets();
    let mut driver: Vec<u32> = vec![u32::MAX; nets];
    for (i, c) in n.cells.iter().enumerate() {
        driver[c.output() as usize] = i as u32;
    }

    let mut live = vec![false; n.cells.len()];
    let mut stack: Vec<u32> = Vec::new();
    let mark_net = |net: u32, stack: &mut Vec<u32>| {
        let d = driver[net as usize];
        if d != u32::MAX {
            stack.push(d);
        }
    };
    for port in &n.outputs {
        for &b in &port.bits {
            mark_net(b, &mut stack);
        }
    }
    while let Some(ci) = stack.pop() {
        let ci = ci as usize;
        if live[ci] {
            continue;
        }
        live[ci] = true;
        for inp in n.cells[ci].inputs() {
            let d = driver[inp as usize];
            if d != u32::MAX && !live[d as usize] {
                stack.push(d);
            }
        }
    }

    let mut kept = Vec::with_capacity(n.cells.len());
    for (i, c) in n.cells.iter().enumerate() {
        if live[i] {
            kept.push(*c);
        }
    }
    n.cells = kept;
    DceStats {
        cells_before: before,
        cells_after: n.cells.len(),
    }
}

/// Share structurally identical combinational cells (CSE): two gates of
/// the same type with the same inputs produce the same value, so the
/// second is replaced by a rewire.  Iterates to a fixed point; DFFs are
/// never merged.  Returns the number of cells eliminated.
pub fn cse(n: &mut Netlist) -> usize {
    use std::collections::HashMap;
    let mut eliminated = 0usize;
    loop {
        let mut repl: Vec<u32> = (0..n.n_nets() as u32).collect();
        let mut seen: HashMap<(u8, u32, u32, u32), u32> = HashMap::new();
        let mut kept: Vec<Cell> = Vec::with_capacity(n.cells.len());
        let mut changed = false;
        for c in n.cells.iter() {
            let mut c = *c;
            // Rewire inputs through current replacement map.
            c = rewire(c, &repl);
            if c.is_seq() {
                kept.push(c);
                continue;
            }
            let key = cell_key(&c);
            match seen.get(&key) {
                Some(&existing) => {
                    repl[c.output() as usize] = existing;
                    eliminated += 1;
                    changed = true;
                }
                None => {
                    seen.insert(key, c.output());
                    kept.push(c);
                }
            }
        }
        // Final rewire pass over cells + ports with the full map.
        for c in kept.iter_mut() {
            *c = rewire(*c, &repl);
        }
        for port in n.outputs.iter_mut() {
            for b in port.bits.iter_mut() {
                *b = repl[*b as usize];
            }
        }
        n.cells = kept;
        if !changed {
            break;
        }
    }
    eliminated
}

fn cell_key(c: &Cell) -> (u8, u32, u32, u32) {
    // Commutative gates get sorted operands so (a,b) == (b,a).
    match *c {
        Cell::Inv { a, .. } => (0, a, 0, 0),
        Cell::Buf { a, .. } => (1, a, 0, 0),
        Cell::Nand2 { a, b, .. } => (2, a.min(b), a.max(b), 0),
        Cell::Nor2 { a, b, .. } => (3, a.min(b), a.max(b), 0),
        Cell::And2 { a, b, .. } => (4, a.min(b), a.max(b), 0),
        Cell::Or2 { a, b, .. } => (5, a.min(b), a.max(b), 0),
        Cell::Xor2 { a, b, .. } => (6, a.min(b), a.max(b), 0),
        Cell::Xnor2 { a, b, .. } => (7, a.min(b), a.max(b), 0),
        Cell::Mux2 { a, b, sel, .. } => (8, a, b, sel),
        Cell::Dff { .. } => unreachable!(),
    }
}

fn rewire(mut c: Cell, repl: &[u32]) -> Cell {
    let r = |x: u32| repl[x as usize];
    match &mut c {
        Cell::Inv { a, .. } | Cell::Buf { a, .. } => *a = r(*a),
        Cell::Nand2 { a, b, .. }
        | Cell::Nor2 { a, b, .. }
        | Cell::And2 { a, b, .. }
        | Cell::Or2 { a, b, .. }
        | Cell::Xor2 { a, b, .. }
        | Cell::Xnor2 { a, b, .. } => {
            *a = r(*a);
            *b = r(*b);
        }
        Cell::Mux2 { a, b, sel, .. } => {
            *a = r(*a);
            *b = r(*b);
            *sel = r(*sel);
        }
        Cell::Dff { d, en, rst, .. } => {
            *d = r(*d);
            *en = r(*en);
            *rst = r(*rst);
        }
    }
    c
}

/// Standard cleanup pipeline used by all circuit generators.
pub fn optimize(n: &mut Netlist) -> DceStats {
    cse(n);
    dce(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netlist::{Netlist, CONST1};

    #[test]
    fn dce_removes_unused() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let used = n.and2(a, b);
        let _dead = n.or2(a, b);
        n.add_output("y", vec![used]);
        let s = dce(&mut n);
        assert_eq!(s.cells_after, 1);
        assert!(matches!(n.cells[0], Cell::And2 { .. }));
    }

    #[test]
    fn dce_keeps_register_feedback() {
        let mut n = Netlist::new("t");
        let d = n.fresh();
        let q = n.dff(d, CONST1, crate::netlist::CONST0, false);
        let nq = n.inv(q);
        n.cells.push(Cell::Buf { a: nq, y: d });
        n.add_output("q", vec![q]);
        let s = dce(&mut n);
        assert_eq!(s.cells_after, 3, "dff + inv + buf all live");
    }

    #[test]
    fn cse_merges_identical_gates() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let x = n.and2(a, b);
        let y = n.and2(b, a); // commutative duplicate
        let z = n.xor2(x, y); // folds to const after merge? no: xor(x,x)=0 only after rewire
        n.add_output("z", vec![z]);
        cse(&mut n);
        let and_count = n
            .cells
            .iter()
            .filter(|c| matches!(c, Cell::And2 { .. }))
            .count();
        assert_eq!(and_count, 1);
    }

    #[test]
    fn optimize_is_idempotent() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let x = n.and2(a, b);
        let y = n.and2(a, b);
        let z = n.or2(x, y);
        n.add_output("z", vec![z]);
        optimize(&mut n);
        let c1 = n.cells.len();
        optimize(&mut n);
        assert_eq!(n.cells.len(), c1);
    }
}
