//! Gate-level netlist IR — the single source of truth for every circuit
//! architecture.  The Verilog emitter prints it, the synthesis-lite
//! estimator (`tech`) costs it, and the cycle-accurate simulator (`sim`)
//! executes it; all three therefore always agree on the same gates.
//!
//! Primitive cells follow the printed-EGFET library of Bleier et al. [6]:
//! INV / NAND2 / NOR2 / AND2 / OR2 / XOR2 / XNOR2 / MUX2 / DFF.  Every
//! DFF has a synchronous load-enable and a synchronous reset to a constant
//! bit (the multi-cycle neuron accumulator resets to its bias, §3.1.1).

pub mod opt;
pub mod verilog;

/// A single-bit net, identified by index. Net 0 is constant-0, net 1 is
/// constant-1.
pub type NetId = u32;

pub const CONST0: NetId = 0;
pub const CONST1: NetId = 1;

/// Combinational and sequential primitive cells.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cell {
    Inv { a: NetId, y: NetId },
    Buf { a: NetId, y: NetId },
    Nand2 { a: NetId, b: NetId, y: NetId },
    Nor2 { a: NetId, b: NetId, y: NetId },
    And2 { a: NetId, b: NetId, y: NetId },
    Or2 { a: NetId, b: NetId, y: NetId },
    Xor2 { a: NetId, b: NetId, y: NetId },
    Xnor2 { a: NetId, b: NetId, y: NetId },
    /// y = sel ? b : a
    Mux2 { a: NetId, b: NetId, sel: NetId, y: NetId },
    /// q' = rst ? rstval : (en ? d : q) — synchronous, posedge.
    Dff { d: NetId, q: NetId, en: NetId, rst: NetId, rstval: bool },
}

impl Cell {
    /// Output net of this cell.
    pub fn output(&self) -> NetId {
        match *self {
            Cell::Inv { y, .. }
            | Cell::Buf { y, .. }
            | Cell::Nand2 { y, .. }
            | Cell::Nor2 { y, .. }
            | Cell::And2 { y, .. }
            | Cell::Or2 { y, .. }
            | Cell::Xor2 { y, .. }
            | Cell::Xnor2 { y, .. }
            | Cell::Mux2 { y, .. } => y,
            Cell::Dff { q, .. } => q,
        }
    }

    /// Input nets (excluding clock, which is implicit), as a fixed array
    /// plus the live count — the non-allocating accessor the hot paths
    /// (topo sort, levelization, DCE, synthesis-lite costing) iterate with.
    #[inline]
    pub fn input_array(&self) -> ([NetId; 3], usize) {
        match *self {
            Cell::Inv { a, .. } | Cell::Buf { a, .. } => ([a, 0, 0], 1),
            Cell::Nand2 { a, b, .. }
            | Cell::Nor2 { a, b, .. }
            | Cell::And2 { a, b, .. }
            | Cell::Or2 { a, b, .. }
            | Cell::Xor2 { a, b, .. }
            | Cell::Xnor2 { a, b, .. } => ([a, b, 0], 2),
            Cell::Mux2 { a, b, sel, .. } => ([a, b, sel], 3),
            Cell::Dff { d, en, rst, .. } => ([d, en, rst], 3),
        }
    }

    /// Visit every input net without allocating (see [`Cell::input_array`]).
    #[inline]
    pub fn for_each_input<F: FnMut(NetId)>(&self, mut f: F) {
        let (ins, n) = self.input_array();
        for &i in &ins[..n] {
            f(i);
        }
    }

    /// Input nets (excluding clock, which is implicit).  Allocates a `Vec`
    /// per call — prefer [`Cell::for_each_input`] on hot paths.
    pub fn inputs(&self) -> Vec<NetId> {
        let (ins, n) = self.input_array();
        ins[..n].to_vec()
    }

    pub fn is_seq(&self) -> bool {
        matches!(self, Cell::Dff { .. })
    }

    /// Library cell name (EGFET library naming).
    pub fn type_name(&self) -> &'static str {
        match self {
            Cell::Inv { .. } => "INV",
            Cell::Buf { .. } => "BUF",
            Cell::Nand2 { .. } => "NAND2",
            Cell::Nor2 { .. } => "NOR2",
            Cell::And2 { .. } => "AND2",
            Cell::Or2 { .. } => "OR2",
            Cell::Xor2 { .. } => "XOR2",
            Cell::Xnor2 { .. } => "XNOR2",
            Cell::Mux2 { .. } => "MUX2",
            Cell::Dff { .. } => "DFF",
        }
    }
}

/// Coarse classification of a net by what drives it — the fault
/// campaign's site-selection key ([`Netlist::net_roles`], `sim::fault`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetRole {
    /// One of the constant rails ([`CONST0`] / [`CONST1`]).
    Const,
    /// Primary-input port bit.
    Input,
    /// Register (DFF) output.
    State,
    /// Combinational cell output.
    Comb,
    /// Allocated but driven by nothing (reads 0 in simulation).
    Floating,
}

impl NetRole {
    pub fn label(self) -> &'static str {
        match self {
            NetRole::Const => "const",
            NetRole::Input => "input",
            NetRole::State => "state",
            NetRole::Comb => "comb",
            NetRole::Floating => "floating",
        }
    }
}

/// A multi-bit signal, LSB first.
pub type Word = Vec<NetId>;

/// Named port: a label plus the nets it binds, LSB first.
#[derive(Clone, Debug)]
pub struct Port {
    pub name: String,
    pub bits: Word,
}

/// A flat gate-level module.
#[derive(Clone, Debug, Default)]
pub struct Netlist {
    pub name: String,
    next_net: NetId,
    pub cells: Vec<Cell>,
    pub inputs: Vec<Port>,
    pub outputs: Vec<Port>,
}

impl Netlist {
    pub fn new(name: &str) -> Netlist {
        Netlist {
            name: name.to_string(),
            next_net: 2, // 0 and 1 are the constant nets
            cells: Vec::new(),
            inputs: Vec::new(),
            outputs: Vec::new(),
        }
    }

    pub fn n_nets(&self) -> usize {
        self.next_net as usize
    }

    pub fn fresh(&mut self) -> NetId {
        let id = self.next_net;
        self.next_net += 1;
        id
    }

    pub fn fresh_word(&mut self, width: usize) -> Word {
        (0..width).map(|_| self.fresh()).collect()
    }

    pub fn add_input(&mut self, name: &str, width: usize) -> Word {
        let bits = self.fresh_word(width);
        self.inputs.push(Port {
            name: name.to_string(),
            bits: bits.clone(),
        });
        bits
    }

    pub fn add_output(&mut self, name: &str, bits: Word) {
        self.outputs.push(Port {
            name: name.to_string(),
            bits,
        });
    }

    // -- gate constructors (with local constant folding) --------------------

    pub fn inv(&mut self, a: NetId) -> NetId {
        match a {
            CONST0 => CONST1,
            CONST1 => CONST0,
            _ => {
                let y = self.fresh();
                self.cells.push(Cell::Inv { a, y });
                y
            }
        }
    }

    pub fn and2(&mut self, a: NetId, b: NetId) -> NetId {
        match (a, b) {
            (CONST0, _) | (_, CONST0) => CONST0,
            (CONST1, x) | (x, CONST1) => x,
            _ if a == b => a,
            _ => {
                let y = self.fresh();
                self.cells.push(Cell::And2 { a, b, y });
                y
            }
        }
    }

    pub fn or2(&mut self, a: NetId, b: NetId) -> NetId {
        match (a, b) {
            (CONST1, _) | (_, CONST1) => CONST1,
            (CONST0, x) | (x, CONST0) => x,
            _ if a == b => a,
            _ => {
                let y = self.fresh();
                self.cells.push(Cell::Or2 { a, b, y });
                y
            }
        }
    }

    pub fn nand2(&mut self, a: NetId, b: NetId) -> NetId {
        match (a, b) {
            (CONST0, _) | (_, CONST0) => CONST1,
            (CONST1, x) | (x, CONST1) => self.inv(x),
            _ => {
                let y = self.fresh();
                self.cells.push(Cell::Nand2 { a, b, y });
                y
            }
        }
    }

    pub fn nor2(&mut self, a: NetId, b: NetId) -> NetId {
        match (a, b) {
            (CONST1, _) | (_, CONST1) => CONST0,
            (CONST0, x) | (x, CONST0) => self.inv(x),
            _ => {
                let y = self.fresh();
                self.cells.push(Cell::Nor2 { a, b, y });
                y
            }
        }
    }

    pub fn xor2(&mut self, a: NetId, b: NetId) -> NetId {
        match (a, b) {
            (CONST0, x) | (x, CONST0) => x,
            (CONST1, x) | (x, CONST1) => self.inv(x),
            _ if a == b => CONST0,
            _ => {
                let y = self.fresh();
                self.cells.push(Cell::Xor2 { a, b, y });
                y
            }
        }
    }

    pub fn xnor2(&mut self, a: NetId, b: NetId) -> NetId {
        let x = self.xor2(a, b);
        self.inv(x)
    }

    /// y = sel ? b : a
    pub fn mux2(&mut self, sel: NetId, a: NetId, b: NetId) -> NetId {
        match (sel, a, b) {
            (CONST0, a, _) => a,
            (CONST1, _, b) => b,
            (_, a, b) if a == b => a,
            (s, CONST0, CONST1) => s,
            (s, CONST1, CONST0) => self.inv(s),
            // sel ? b : 0 == sel & b ; sel ? 1 : a == sel | a, etc.
            (s, CONST0, b) => self.and2(s, b),
            (s, a, CONST0) => {
                let ns = self.inv(s);
                self.and2(ns, a)
            }
            (s, CONST1, b) => {
                let ns = self.inv(s);
                self.or2(ns, b)
            }
            (s, a, CONST1) => self.or2(s, a),
            (sel, a, b) => {
                let y = self.fresh();
                self.cells.push(Cell::Mux2 { a, b, sel, y });
                y
            }
        }
    }

    /// Register with enable and synchronous reset-to-constant.
    pub fn dff(&mut self, d: NetId, en: NetId, rst: NetId, rstval: bool) -> NetId {
        let q = self.fresh();
        self.cells.push(Cell::Dff {
            d,
            q,
            en,
            rst,
            rstval,
        });
        q
    }

    /// Register whose `d` is connected later (for feedback paths such as
    /// accumulators and counters).  Returns `(q, cell_index)`; call
    /// [`Netlist::set_dff_d`] once the data input exists.
    pub fn dff_deferred(&mut self, en: NetId, rst: NetId, rstval: bool) -> (NetId, usize) {
        let q = self.fresh();
        self.cells.push(Cell::Dff {
            d: q, // placeholder: hold value until connected
            q,
            en,
            rst,
            rstval,
        });
        (q, self.cells.len() - 1)
    }

    pub fn set_dff_d(&mut self, cell_index: usize, d: NetId) {
        match &mut self.cells[cell_index] {
            Cell::Dff { d: slot, .. } => *slot = d,
            other => panic!("set_dff_d on non-DFF cell {other:?}"),
        }
    }

    /// Constant word of `width` bits (two's complement value).
    pub fn const_word(&self, value: i64, width: usize) -> Word {
        (0..width)
            .map(|i| if (value >> i) & 1 == 1 { CONST1 } else { CONST0 })
            .collect()
    }

    // -- stats ---------------------------------------------------------------

    /// Classify every net by its driver (indexed by [`NetId`]).  Cell
    /// outputs win over port membership, so a net that is both (never
    /// produced by the generators) reports how it is *driven*.
    pub fn net_roles(&self) -> Vec<NetRole> {
        let mut roles = vec![NetRole::Floating; self.n_nets()];
        roles[CONST0 as usize] = NetRole::Const;
        roles[CONST1 as usize] = NetRole::Const;
        for p in &self.inputs {
            for &b in &p.bits {
                roles[b as usize] = NetRole::Input;
            }
        }
        for c in &self.cells {
            roles[c.output() as usize] = if c.is_seq() {
                NetRole::State
            } else {
                NetRole::Comb
            };
        }
        roles
    }

    pub fn count_by_type(&self) -> std::collections::BTreeMap<&'static str, usize> {
        let mut m = std::collections::BTreeMap::new();
        for c in &self.cells {
            *m.entry(c.type_name()).or_insert(0) += 1;
        }
        m
    }

    pub fn n_dffs(&self) -> usize {
        self.cells.iter().filter(|c| c.is_seq()).count()
    }

    /// Topological order of combinational cell indices (Kahn).  DFF
    /// outputs and primary inputs are sources; DFFs are excluded.  Panics
    /// on combinational loops — generators must never create them.
    ///
    /// §Perf: the driver→consumer adjacency is a flat CSR (prefix-summed
    /// offsets + one edge array) built in two counting passes with
    /// [`Cell::for_each_input`], so ordering the largest (HAR-class)
    /// netlists performs O(1) allocations instead of one `Vec` per cell.
    pub fn topo_order(&self) -> Vec<usize> {
        let n = self.n_nets();
        let n_cells = self.cells.len();
        let mut driver = vec![u32::MAX; n];
        let mut n_comb = 0usize;
        for (i, c) in self.cells.iter().enumerate() {
            if !c.is_seq() {
                driver[c.output() as usize] = i as u32;
                n_comb += 1;
            }
        }
        // Pass 1: per-driver out-degree.
        let mut offsets = vec![0u32; n_cells + 1];
        for c in self.cells.iter() {
            if c.is_seq() {
                continue;
            }
            c.for_each_input(|inp| {
                let d = driver[inp as usize];
                if d != u32::MAX {
                    offsets[d as usize + 1] += 1;
                }
            });
        }
        // Prefix sum → CSR offsets.
        for i in 0..n_cells {
            offsets[i + 1] += offsets[i];
        }
        // Pass 2: fill the flat edge array (cursor restores the offsets).
        let mut edges = vec![0u32; offsets[n_cells] as usize];
        let mut cursor: Vec<u32> = offsets[..n_cells].to_vec();
        let mut indeg = vec![0u32; n_cells];
        for (i, c) in self.cells.iter().enumerate() {
            if c.is_seq() {
                continue;
            }
            c.for_each_input(|inp| {
                let d = driver[inp as usize];
                if d != u32::MAX {
                    edges[cursor[d as usize] as usize] = i as u32;
                    cursor[d as usize] += 1;
                    indeg[i] += 1;
                }
            });
        }
        let mut queue: std::collections::VecDeque<u32> = (0..n_cells)
            .filter(|&i| !self.cells[i].is_seq() && indeg[i] == 0)
            .map(|i| i as u32)
            .collect();
        let mut order = Vec::with_capacity(n_comb);
        while let Some(ci) = queue.pop_front() {
            order.push(ci as usize);
            let (lo, hi) = (offsets[ci as usize] as usize, offsets[ci as usize + 1] as usize);
            for &nxt in &edges[lo..hi] {
                indeg[nxt as usize] -= 1;
                if indeg[nxt as usize] == 0 {
                    queue.push_back(nxt);
                }
            }
        }
        assert_eq!(
            order.len(),
            n_comb,
            "combinational loop: {} of {} cells unordered",
            n_comb - order.len(),
            n_comb
        );
        order
    }

    /// Combinational depth (levels) — proxy for the critical path.
    pub fn logic_depth(&self) -> usize {
        let n = self.n_nets();
        let mut level = vec![0usize; n];
        let order = self.topo_order();
        let mut max = 0;
        for ci in order {
            let c = &self.cells[ci];
            let mut lvl = 0usize;
            c.for_each_input(|i| lvl = lvl.max(level[i as usize]));
            let lvl = lvl + 1;
            level[c.output() as usize] = lvl;
            max = max.max(lvl);
        }
        max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_folding_in_constructors() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        assert_eq!(n.and2(a, CONST0), CONST0);
        assert_eq!(n.and2(a, CONST1), a);
        assert_eq!(n.or2(a, CONST1), CONST1);
        assert_eq!(n.xor2(a, a), CONST0);
        assert_eq!(n.mux2(CONST0, a, CONST1), a);
        assert_eq!(n.cells.len(), 0, "no gates for folded ops");
    }

    #[test]
    fn mux_with_constant_data_becomes_logic() {
        let mut n = Netlist::new("t");
        let s = n.add_input("s", 1)[0];
        let b = n.add_input("b", 1)[0];
        // sel ? b : 0 -> AND
        let y = n.mux2(s, CONST0, b);
        assert!(matches!(n.cells.last(), Some(Cell::And2 { .. })));
        assert_ne!(y, CONST0);
    }

    #[test]
    fn topo_order_respects_deps() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let x = n.and2(a, b);
        let y = n.or2(x, a);
        let _z = n.xor2(y, x);
        let order = n.topo_order();
        assert_eq!(order.len(), 3);
        let pos = |ci: usize| order.iter().position(|&c| c == ci).unwrap();
        assert!(pos(0) < pos(1) && pos(1) < pos(2));
    }

    #[test]
    fn dff_breaks_cycles() {
        let mut n = Netlist::new("t");
        // q feeds an inverter that feeds d: a classic toggle — legal
        // because the DFF breaks the loop.
        let d = n.fresh();
        let q = n.dff(d, CONST1, CONST0, false);
        let nq = n.inv(q);
        // tie nq to d via a buf cell
        n.cells.push(Cell::Buf { a: nq, y: d });
        let order = n.topo_order(); // must not panic
        assert_eq!(order.len(), 2);
    }

    #[test]
    #[should_panic(expected = "combinational loop")]
    fn combinational_loop_detected() {
        let mut n = Netlist::new("t");
        let x = n.fresh();
        let y = n.fresh();
        n.cells.push(Cell::Inv { a: x, y });
        n.cells.push(Cell::Inv { a: y, y: x });
        n.topo_order();
    }

    #[test]
    fn const_word_bits() {
        let n = Netlist::new("t");
        assert_eq!(n.const_word(5, 4), vec![CONST1, CONST0, CONST1, CONST0]);
        assert_eq!(n.const_word(-1, 3), vec![CONST1, CONST1, CONST1]);
    }

    #[test]
    fn net_roles_classify_every_driver_kind() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 2);
        let x = n.and2(a[0], a[1]);
        let q = n.dff(x, CONST1, CONST0, false);
        let floating = n.fresh();
        let y = n.or2(q, floating);
        n.add_output("y", vec![y]);
        let roles = n.net_roles();
        assert_eq!(roles[CONST0 as usize], NetRole::Const);
        assert_eq!(roles[CONST1 as usize], NetRole::Const);
        assert_eq!(roles[a[0] as usize], NetRole::Input);
        assert_eq!(roles[a[1] as usize], NetRole::Input);
        assert_eq!(roles[x as usize], NetRole::Comb);
        assert_eq!(roles[q as usize], NetRole::State);
        assert_eq!(roles[floating as usize], NetRole::Floating);
        assert_eq!(roles[y as usize], NetRole::Comb);
        assert_eq!(roles.len(), n.n_nets());
    }

    #[test]
    fn logic_depth_counts_levels() {
        let mut n = Netlist::new("t");
        let a = n.add_input("a", 1)[0];
        let b = n.add_input("b", 1)[0];
        let x = n.and2(a, b);
        let y = n.and2(x, b);
        let _ = n.and2(y, a);
        assert_eq!(n.logic_depth(), 3);
    }
}
