//! Figure 8 regeneration: per-inference energy of all four architectures
//! (§4.3).  Runs the pipeline with activity profiling on, so the energy
//! column is measured — static (power × cycles × synthesis clock) plus
//! dynamic switching energy priced from per-net toggle counts — with the
//! paper's headline ratios printed alongside.

mod harness;

use printed_mlp::coordinator::{run_pipeline, PipelineConfig};
use printed_mlp::report;

fn main() {
    let Some(store) = harness::require_artifacts() else { return };
    harness::section("Figure 8 — energy per inference (measured switching activity)");
    let cfg = PipelineConfig { profile_activity: true, ..Default::default() };
    let outs = run_pipeline(&store, &cfg).expect("pipeline");
    let md = report::fig8(&outs, &store.results_dir()).expect("fig8");
    println!("{md}");

    // Also regenerate the RFP retention companion (§3.2.2).
    let md = report::rfp_summary(&outs, &store.results_dir()).expect("rfp");
    println!("{md}");
}
