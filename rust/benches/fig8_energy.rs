//! Figure 8 regeneration: per-inference energy of all four architectures
//! (power × cycles × synthesis clock, §4.3), with the paper's headline
//! ratios printed alongside.

mod harness;

use printed_mlp::report;

fn main() {
    let Some(store) = harness::require_artifacts() else { return };
    harness::section("Figure 8 — energy per inference");
    let outs = harness::pipeline_outcomes(&store);
    let md = report::fig8(&outs, &store.results_dir()).expect("fig8");
    println!("{md}");

    // Also regenerate the RFP retention companion (§3.2.2).
    let md = report::rfp_summary(&outs, &store.results_dir()).expect("rfp");
    println!("{md}");
}
