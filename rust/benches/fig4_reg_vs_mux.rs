//! Figure 4 regeneration: area of shift registers vs multiplexers as the
//! number of inputs grows (§3.1.4).
//!
//! Two series: the *generic* analytical comparison (n 4-bit values in
//! shift registers vs an n:1 mux selector — the paper's figure), and the
//! *hardwired* comparison measured on real generated circuits, where
//! constant-folding the weight mux trees delivers the ≥4× whole-circuit
//! gains the paper quotes (4.4× for Arrhythmia).

mod harness;

use printed_mlp::circuits::{seq_multicycle, seq_sota};
use printed_mlp::tech;

fn main() {
    harness::section("Figure 4 — registers vs multiplexers");

    println!("{:>8} {:>16} {:>14} {:>8}", "inputs", "shift-reg cm²", "mux cm²", "ratio");
    let mut rows = Vec::new();
    for n in [2usize, 4, 8, 16, 32, 64, 128, 256, 512, 1024] {
        let reg = tech::shift_register_area(n, 4);
        let mux = tech::mux_selector_area(n, 4);
        println!("{n:>8} {reg:>16.2} {mux:>14.2} {:>7.2}×", reg / mux);
        rows.push(format!("generic_{n},{reg:.4},{mux:.4},{:.4}", reg / mux));
    }

    let Some(store) = harness::require_artifacts() else { return };
    println!("\nhardwired-weight measurement (whole circuit, registers→muxes):");
    println!("{:>12} {:>14} {:>14} {:>8}", "dataset", "seq[16] cm²", "ours cm²", "ratio");
    for name in ["spectf", "arrhythmia", "gas"] {
        let m = store.model(name).unwrap();
        let active: Vec<usize> = (0..m.features).collect();
        let sota = tech::report(&seq_sota::generate(&m, &active).netlist);
        let ours = tech::report(&seq_multicycle::generate(&m, &active).netlist);
        println!(
            "{name:>12} {:>14.1} {:>14.1} {:>7.2}×",
            sota.area_cm2,
            ours.area_cm2,
            sota.area_cm2 / ours.area_cm2
        );
        rows.push(format!(
            "{name},{:.4},{:.4},{:.4}",
            sota.area_cm2,
            ours.area_cm2,
            sota.area_cm2 / ours.area_cm2
        ));
    }
    let dir = store.results_dir();
    std::fs::create_dir_all(&dir).ok();
    let mut csv = String::from("series,reg_or_sota_cm2,mux_or_ours_cm2,ratio\n");
    for r in &rows {
        csv.push_str(r);
        csv.push('\n');
    }
    std::fs::write(dir.join("fig4.csv"), csv).ok();

    // Perf: circuit generation speed (hot in ablation loops).
    let m = store.model("arrhythmia").unwrap();
    let active: Vec<usize> = (0..m.features).collect();
    harness::bench("generate multicycle (arrhythmia, 274F)", 10, || {
        let c = seq_multicycle::generate(&m, &active);
        std::hint::black_box(c.netlist.cells.len());
    });
}
