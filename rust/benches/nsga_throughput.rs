//! NSGA-II approximation-search throughput: genome-evals/sec at 1..N
//! fitness-batch threads (native-model fitness, memo cache off so every
//! requested genome costs a full training-set pass), plus the memo-cache
//! hit rate and its end-to-end speedup at full threads, and the
//! 3-objective (`--energy-objective`) bookkeeping cost.
//!
//! Artifact-free — the model and training split are synthetic — so this
//! bench always runs, unlike the `make artifacts`-gated harnesses.  The
//! acceptance bar mirrors the sim-sharding bench: >= 2x genome-evals/sec
//! at 4+ threads vs 1 thread on multi-core hosts, with bit-identical
//! fronts at every thread count (enforced by `tests/nsga_parallel.rs`).

mod harness;
#[path = "../tests/common/mod.rs"]
mod common;

use common::rand_model;
use printed_mlp::approx;
use printed_mlp::data::Split;
use printed_mlp::nsga::NsgaConfig;
use printed_mlp::util::pool;
use printed_mlp::util::prng::Rng;

fn main() {
    harness::section("NSGA-II search — genome-evals/sec vs fitness threads (native)");

    // HAR-class search: 48 features, 24 hidden neurons (genome bits).
    let m = rand_model(21, 48, 24, 5);
    let n = 512usize;
    let mut rng = Rng::new(9);
    let split = Split {
        xs: (0..n * m.features).map(|_| rng.below(16) as u8).collect(),
        ys: (0..n).map(|_| rng.below(m.classes as u64) as u16).collect(),
        features: m.features,
    };
    let fm = vec![1u8; m.features];
    let tables = approx::build_tables(&m, &split.xs, split.len(), &fm);

    // Cache off: genome-evals/sec measures raw fitness throughput.
    let uncached = NsgaConfig {
        pop_size: 24,
        generations: 12,
        memoize: false,
        ..Default::default()
    };
    let evals_per_run = (uncached.pop_size * (uncached.generations + 1)) as f64;
    println!(
        "search: pop {} × gen {} = {:.0} genome evals/run, {} samples/eval, {} genome bits",
        uncached.pop_size, uncached.generations, evals_per_run, n, m.hidden
    );

    let avail = pool::default_threads();
    let mut thread_counts = vec![1usize, 2, 4];
    if !thread_counts.contains(&avail) {
        thread_counts.push(avail);
    }

    let mut base_ms = 0.0f64;
    for &threads in &thread_counts {
        let r = harness::bench(
            &format!("NSGA pop24×gen12 cache off, {threads:>2} thread(s)"),
            3,
            || {
                let (front, stats) =
                    approx::explore_parallel(&m, &split, &fm, &tables, &uncached, threads);
                assert_eq!(stats.evals as f64, evals_per_run);
                std::hint::black_box(front.len());
            },
        );
        if threads == 1 {
            base_ms = r.mean_ms;
        }
        println!(
            "          {:>10.0} genome-evals/sec, speedup {:>5.2}x vs 1 thread",
            evals_per_run / (r.mean_ms / 1e3),
            base_ms / r.mean_ms.max(1e-9)
        );
    }

    // Cache on at full threads: crossover/mutation re-produce genomes
    // across generations, and each hit skips a full training-set pass.
    let cached = NsgaConfig {
        memoize: true,
        ..uncached.clone()
    };
    let r = harness::bench(
        &format!("NSGA pop24×gen12 cache on,  {avail:>2} thread(s)"),
        3,
        || {
            let (front, _stats) =
                approx::explore_parallel(&m, &split, &fm, &tables, &cached, avail);
            std::hint::black_box(front.len());
        },
    );
    let (_, stats) = approx::explore_parallel(&m, &split, &fm, &tables, &cached, avail);
    println!(
        "          memo: {} unique evals / {} requested ({:.0}% hit rate), {:>10.0} effective genome-evals/sec",
        stats.evals,
        stats.requested,
        100.0 * stats.hit_rate(),
        stats.requested as f64 / (r.mean_ms / 1e3)
    );

    // Third objective: energy (--energy-objective).  The closure here is
    // a cheap deterministic stand-in (count of exact neurons kept), so
    // the delta vs the 2-objective run isolates the 3-tuple bookkeeping
    // cost — rank/crowding over three objectives plus the memo on
    // 3-tuples — not circuit simulation.
    let energy = |mask: &[u8]| mask.iter().filter(|&&b| b == 0).count() as f64;
    let r = harness::bench(
        &format!("NSGA pop24×gen12 3-obj cache on, {avail:>2} thread(s)"),
        3,
        || {
            let (front, _stats) =
                approx::explore_parallel_energy(&m, &split, &fm, &tables, &cached, avail, &energy);
            std::hint::black_box(front.len());
        },
    );
    let (front, stats) =
        approx::explore_parallel_energy(&m, &split, &fm, &tables, &cached, avail, &energy);
    println!(
        "          3-obj: {} front points, memo {:.0}% hit rate, {:>10.0} effective genome-evals/sec \
         (serial == batched: tests/nsga_parallel.rs)",
        front.len(),
        100.0 * stats.hit_rate(),
        stats.requested as f64 / (r.mean_ms / 1e3)
    );
}
