//! NSGA-II approximation-search throughput: the delta-logit fitness
//! cache vs the scalar accuracy oracle at equal seeds and threads, plus
//! genome-evals/sec at 1..N fitness-batch threads, the memo-cache hit
//! rate, and the 3-objective (`--energy-objective`) bookkeeping cost.
//!
//! Artifact-free — the model and training split are synthetic — so this
//! bench always runs, unlike the `make artifacts`-gated harnesses.
//! Acceptance bars (ISSUE 10 / DESIGN.md §Perf):
//!   - >= 5x genome-evals/sec cached vs scalar at equal seeds and
//!     thread count (`cached_speedup` in `BENCH_nsga.json`);
//!   - bit-identical Pareto fronts on both paths at every thread count
//!     (spot-checked here; enforced by `tests/fitness_cache.rs` and
//!     `tests/nsga_parallel.rs`).
//!
//! Writes the machine-readable trajectory to
//! `artifacts/results/BENCH_nsga.json` (same shape as
//! `BENCH_sim.json`/`BENCH_serve.json`) so regressions diff across PRs.

mod harness;
#[path = "../tests/common/mod.rs"]
mod common;

use common::rand_model;
use printed_mlp::approx;
use printed_mlp::data::Split;
use printed_mlp::nsga::NsgaConfig;
use printed_mlp::util::json::{num, obj, s, Json};
use printed_mlp::util::pool;
use printed_mlp::util::prng::Rng;

fn main() {
    harness::section("NSGA-II search — cached vs scalar fitness, evals/sec vs threads");

    // HAR-class search: 48 features, 24 hidden neurons (genome bits).
    let m = rand_model(21, 48, 24, 5);
    let n = 512usize;
    let mut rng = Rng::new(9);
    let split = Split {
        xs: (0..n * m.features).map(|_| rng.below(16) as u8).collect(),
        ys: (0..n).map(|_| rng.below(m.classes as u64) as u16).collect(),
        features: m.features,
    };
    let fm = vec![1u8; m.features];
    let tables = approx::build_tables(&m, &split.xs, split.len(), &fm);

    // Memo off on both configs so every requested genome pays a full
    // fitness evaluation: the scalar/cached delta isolates the kernel,
    // not the memo table.
    let scalar = NsgaConfig {
        pop_size: 24,
        generations: 12,
        memoize: false,
        cached_fitness: false,
        ..Default::default()
    };
    let cached = NsgaConfig {
        cached_fitness: true,
        ..scalar.clone()
    };
    let evals_per_run = (scalar.pop_size * (scalar.generations + 1)) as f64;
    println!(
        "search: pop {} × gen {} = {:.0} genome evals/run, {} samples/eval, {} genome bits",
        scalar.pop_size, scalar.generations, evals_per_run, n, m.hidden
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut row = |path: &str, threads: usize, r: &harness::BenchResult, evals: f64| {
        let eps = evals / (r.mean_ms / 1e3);
        rows.push(obj(vec![
            ("path", s(path)),
            ("threads", num(threads as f64)),
            ("mean_ms", num(r.mean_ms)),
            ("p50_ms", num(r.p50_ms)),
            ("p99_ms", num(r.p99_ms)),
            ("genome_evals_per_s", num(eps)),
        ]));
        eps
    };

    // --- Head-to-head: scalar oracle vs delta-logit cache, 1 thread ----
    // Equal seeds, equal thread count; the front must not move.
    let r_scalar = harness::bench("NSGA pop24×gen12 scalar oracle, 1 thread", 3, || {
        let (front, stats) = approx::explore_parallel(&m, &split, &fm, &tables, &scalar, 1);
        assert_eq!(stats.evals as f64, evals_per_run);
        std::hint::black_box(front.len());
    });
    let scalar_eps = row("scalar", 1, &r_scalar, evals_per_run);
    println!("          {scalar_eps:>10.0} genome-evals/sec");

    let r_cached = harness::bench("NSGA pop24×gen12 delta-logit cache, 1 thread", 3, || {
        let (front, stats) = approx::explore_parallel(&m, &split, &fm, &tables, &cached, 1);
        assert_eq!(stats.evals as f64, evals_per_run);
        std::hint::black_box(front.len());
    });
    let cached_eps = row("cached", 1, &r_cached, evals_per_run);
    let cached_speedup = r_scalar.mean_ms / r_cached.mean_ms.max(1e-9);
    println!(
        "          {cached_eps:>10.0} genome-evals/sec | cached speedup {cached_speedup:5.1}x vs scalar (bar: >= 5x)"
    );

    let (front_s, _) = approx::explore_parallel(&m, &split, &fm, &tables, &scalar, 1);
    let (front_c, _) = approx::explore_parallel(&m, &split, &fm, &tables, &cached, 1);
    assert_eq!(front_s.len(), front_c.len(), "cached front size diverged");
    for (a, b) in front_s.iter().zip(&front_c) {
        assert_eq!(a.genome, b.genome, "cached front genome diverged");
        assert_eq!(a.objectives, b.objectives, "cached front objectives diverged");
    }
    println!("          fronts bit-identical (scalar == cached at equal seeds)");

    // --- Cached-path thread scaling -----------------------------------
    let avail = pool::default_threads();
    let mut thread_counts = vec![1usize, 2, 4];
    if !thread_counts.contains(&avail) {
        thread_counts.push(avail);
    }
    let mut base_ms = 0.0f64;
    for &threads in &thread_counts {
        let r = harness::bench(
            &format!("NSGA pop24×gen12 cached, {threads:>2} thread(s)"),
            3,
            || {
                let (front, _) =
                    approx::explore_parallel(&m, &split, &fm, &tables, &cached, threads);
                std::hint::black_box(front.len());
            },
        );
        if threads == 1 {
            base_ms = r.mean_ms;
        }
        let eps = row("cached", threads, &r, evals_per_run);
        println!(
            "          {:>10.0} genome-evals/sec, speedup {:>5.2}x vs 1 thread",
            eps,
            base_ms / r.mean_ms.max(1e-9)
        );
    }

    // --- Memo on top: crossover/mutation re-produce genomes across ----
    // generations, and each hit skips even the delta-adds.
    let memoized = NsgaConfig {
        memoize: true,
        ..cached.clone()
    };
    let r = harness::bench(
        &format!("NSGA pop24×gen12 cached+memo, {avail:>2} thread(s)"),
        3,
        || {
            let (front, _stats) =
                approx::explore_parallel(&m, &split, &fm, &tables, &memoized, avail);
            std::hint::black_box(front.len());
        },
    );
    let (_, stats) = approx::explore_parallel(&m, &split, &fm, &tables, &memoized, avail);
    let memo_hit_rate = stats.hit_rate();
    row("cached+memo", avail, &r, stats.requested as f64);
    println!(
        "          memo: {} unique evals / {} requested ({:.0}% hit rate), {:>10.0} effective genome-evals/sec",
        stats.evals,
        stats.requested,
        100.0 * memo_hit_rate,
        stats.requested as f64 / (r.mean_ms / 1e3)
    );

    // --- Third objective: energy (--energy-objective) ------------------
    // The closure is a cheap deterministic stand-in (count of exact
    // neurons kept), so the delta vs the 2-objective run isolates the
    // 3-tuple bookkeeping — rank/crowding over three objectives plus the
    // memo on 3-tuples — not circuit simulation.
    let energy = |mask: &[u8]| mask.iter().filter(|&&b| b == 0).count() as f64;
    let r = harness::bench(
        &format!("NSGA pop24×gen12 cached+memo 3-obj, {avail:>2} thread(s)"),
        3,
        || {
            let (front, _stats) = approx::explore_parallel_energy(
                &m, &split, &fm, &tables, &memoized, avail, &energy,
            );
            std::hint::black_box(front.len());
        },
    );
    let (front, stats) =
        approx::explore_parallel_energy(&m, &split, &fm, &tables, &memoized, avail, &energy);
    row("cached+memo+3obj", avail, &r, stats.requested as f64);
    println!(
        "          3-obj: {} front points, memo {:.0}% hit rate, {:>10.0} effective genome-evals/sec \
         (serial == batched: tests/nsga_parallel.rs)",
        front.len(),
        100.0 * stats.hit_rate(),
        stats.requested as f64 / (r.mean_ms / 1e3)
    );

    assert!(
        cached_speedup >= 5.0,
        "delta-logit cache speedup {cached_speedup:.1}x below the 5x acceptance bar"
    );
    harness::write_results_json(
        "BENCH_nsga.json",
        &obj(vec![
            ("bench", s("nsga_throughput")),
            ("samples", num(n as f64)),
            ("genome_bits", num(m.hidden as f64)),
            ("pop_size", num(scalar.pop_size as f64)),
            ("generations", num(scalar.generations as f64)),
            ("cached_speedup", num(cached_speedup)),
            ("memo_hit_rate", num(memo_hit_rate)),
            ("rows", Json::Arr(rows)),
        ]),
    );
}
