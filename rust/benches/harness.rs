//! Mini-bench harness (criterion substitute — unavailable offline; see
//! DESIGN.md §Substitutions).
//!
//! `bench(name, iters, f)` warms up, runs `f` `iters` times, and prints
//! mean / p50 / p99 wall time.  Every bench doubles as the regeneration
//! harness for its paper table/figure: it prints paper-vs-measured rows
//! and writes the CSV under `artifacts/results/`.

#![allow(dead_code)]

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Time `f` over `iters` iterations (plus one warmup) and report.
pub fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> BenchResult {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let p99 = samples[(samples.len() * 99 / 100).min(samples.len() - 1)];
    println!("  [bench] {name:<48} mean {mean:>9.3} ms  p50 {p50:>9.3} ms  p99 {p99:>9.3} ms");
    BenchResult {
        name: name.to_string(),
        mean_ms: mean,
        p50_ms: p50,
        p99_ms: p99,
    }
}

/// Section header for a table/figure bench.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Write a machine-readable bench trajectory (e.g. `BENCH_sim.json`)
/// next to the markdown/CSV results under `artifacts/results/`, so perf
/// regressions are diffable across PRs.  Failures are notes, not panics
/// — a read-only checkout must not kill the bench.
pub fn write_results_json(file: &str, json: &printed_mlp::util::json::Json) {
    let dir = printed_mlp::data::ArtifactStore::discover().results_dir();
    if let Err(e) = std::fs::create_dir_all(&dir) {
        println!("note: cannot create {}: {e}", dir.display());
        return;
    }
    let path = dir.join(file);
    match std::fs::write(&path, json.to_string()) {
        Ok(()) => println!("bench trajectory written to {}", path.display()),
        Err(e) => println!("note: cannot write {}: {e}", path.display()),
    }
}

/// PJRT-gated entry: an engine when a client comes up, else a printed
/// skip note (the vendored `xla` stub always fails — see rust/README.md).
/// Lets the non-PJRT sections of a bench still run and report.
pub fn require_pjrt() -> Option<printed_mlp::runtime::Engine> {
    match printed_mlp::runtime::Engine::cpu() {
        Ok(engine) => Some(engine),
        Err(err) => {
            println!("SKIP PJRT sections: {err:#}");
            None
        }
    }
}

/// Artifact-gated entry: skip politely when `make artifacts` hasn't run.
pub fn require_artifacts() -> Option<printed_mlp::data::ArtifactStore> {
    let store = printed_mlp::data::ArtifactStore::discover();
    if store.has("spectf") {
        Some(store)
    } else {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

/// Shared pipeline outcomes (reuses the coordinator's disk cache, so the
/// expensive NSGA stage is only paid once across all benches).
pub fn pipeline_outcomes(
    store: &printed_mlp::data::ArtifactStore,
) -> Vec<printed_mlp::coordinator::DatasetOutcome> {
    let cfg = printed_mlp::coordinator::PipelineConfig::default();
    printed_mlp::coordinator::run_pipeline(store, &cfg).expect("pipeline")
}
