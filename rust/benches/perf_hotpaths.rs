//! Whole-stack hot-path benchmarks — the §Perf measurement harness
//! (EXPERIMENTS.md §Perf records before/after for each optimization).
//!
//! Covered paths:
//!   L3a  gate-level simulator (64-lane packed; interpreted vs compiled
//!        micro-op plan, incl. the one-off plan-compile cost)
//!   L3b  PJRT batched inference (RFP/NSGA fitness engine)
//!   L3c  PJRT single-sample latency (serve mode)
//!   L3d  native functional model (fallback evaluator)
//!   L3e  RFP sweep greedy vs bisect (search-strategy ablation)
//!   L3f  netlist optimize (CSE+DCE) on the largest comb design

mod harness;

use std::sync::Arc;
use std::time::Instant;

use printed_mlp::circuits::{combinational, seq_multicycle};
use printed_mlp::model::ApproxTables;
use printed_mlp::rfp::{self, Strategy};
use printed_mlp::runtime::{NativeEvaluator, PjrtEvaluator, BATCH_THROUGHPUT};
use printed_mlp::sim::{testbench, SimPlan};

fn main() {
    let Some(store) = harness::require_artifacts() else { return };
    harness::section("Perf — hot paths");

    // L3a: simulator throughput on the largest circuit, interpreted vs
    // micro-op-compiled plan.  Pinned to one thread so the per-thread
    // hot-path metric stays comparable with the DESIGN.md §Perf records
    // taken before sharding landed; the multi-thread scaling measurement
    // lives in `sim_throughput`.
    let m = store.model("har").unwrap();
    let ds = store.dataset("har").unwrap();
    let active: Vec<usize> = (0..m.features).collect();
    let circ = seq_multicycle::generate(&m, &active);
    let split = ds.test.head(128);
    let interp = Arc::new(SimPlan::new(&circ.netlist));
    let t0 = Instant::now();
    let compiled = Arc::new(SimPlan::compiled(&circ.netlist));
    let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
    let cp = compiled.compiled_plan().unwrap();
    println!(
        "L3a plan compile: {compile_ms:.2} ms -> {} micro-ops of {} comb cells, {} dense nets",
        cp.n_ops(),
        circ.netlist.cells.len() - interp.n_dffs(),
        cp.n_dense_nets()
    );
    let gate_evals = circ.netlist.cells.len() as f64 * 582.0 * 2.0; // 2 chunks of 64 lanes
    let mut pair_ms = [0.0f64; 2];
    for (pi, &(label, plan)) in [("interp", &interp), ("compiled", &compiled)]
        .iter()
        .enumerate()
    {
        let r = harness::bench(
            &format!("L3a sim multicycle har, 128smp × 582cyc, 1thr, {label}"),
            5,
            || {
                let preds = testbench::run_sequential_plan(
                    &circ,
                    plan,
                    &split.xs,
                    split.len(),
                    m.features,
                    1,
                    1, // W=1: keep the per-thread metric comparable with §Perf records
                );
                std::hint::black_box(preds.len());
            },
        );
        pair_ms[pi] = r.mean_ms;
        println!(
            "         -> {:.1} M lane-gate-evals/s",
            gate_evals * (128.0 / 64.0) / r.mean_ms * 1e-3
        );
    }
    println!(
        "         == compiled is {:.2}x interpreted (single thread)",
        pair_ms[0] / pair_ms[1]
    );

    // L3a2: the same path at the auto-picked super-lane width (the
    // production default; the W sweep lives in `sim_throughput`).
    let lw = printed_mlp::sim::lane_words_default();
    let r = harness::bench(
        &format!("L3a2 sim multicycle har, 128smp, 1thr, compiled W={lw}"),
        5,
        || {
            let preds = testbench::run_sequential_plan(
                &circ,
                &compiled,
                &split.xs,
                split.len(),
                m.features,
                1,
                lw,
            );
            std::hint::black_box(preds.len());
        },
    );
    println!(
        "         -> {:.1} M lane-gate-evals/s | {:.2}x vs compiled W=1",
        gate_evals * (128.0 / 64.0) / r.mean_ms * 1e-3,
        pair_ms[1] / r.mean_ms
    );

    let fm = vec![1u8; m.features];
    let am = vec![0u8; m.hidden];
    let t = ApproxTables::disabled(m.hidden);
    let fit = ds.train.head(512);

    // L3b/L3c/L3e need a PJRT client; under the vendored xla stub they
    // are skipped (with a note) so the sim/native sections still report.
    if let Some(engine) = harness::require_pjrt() {
        // L3b: PJRT batched throughput.
        let eval = PjrtEvaluator::new(
            &engine,
            &store.hlo_path("har", BATCH_THROUGHPUT),
            &m,
            BATCH_THROUGHPUT,
        )
        .unwrap();
        let r = harness::bench("L3b PJRT batched 512 samples (har)", 20, || {
            std::hint::black_box(eval.accuracy(&fit, &fm, &am, &t).unwrap());
        });
        println!("         -> {:.0} samples/s", 512.0 / r.mean_ms * 1e3);

        // L3b2: the §Perf prepared-input path the coordinator uses — input
        // literals staged once, only masks/tables rebuilt per fitness call.
        let prep = eval.prepare(&fit).unwrap();
        let r = harness::bench("L3b2 PJRT prepared 512 samples (har)", 20, || {
            std::hint::black_box(eval.accuracy_prepared(&prep, &fm, &am, &t).unwrap());
        });
        println!("         -> {:.0} samples/s", 512.0 / r.mean_ms * 1e3);

        // L3c: PJRT single-sample latency.
        let eval1 = PjrtEvaluator::new(&engine, &store.hlo_path("har", 1), &m, 1).unwrap();
        let one = ds.test.head(1);
        let r = harness::bench("L3c PJRT single-sample latency (har)", 50, || {
            std::hint::black_box(
                eval1.predict(&one.xs, 1, &fm, &am, &t).unwrap()[0],
            );
        });
        println!("         -> {:.3} ms/inference", r.mean_ms);

        // L3e: RFP strategy ablation (greedy vs bisect) on a mid-size dataset.
        let mg = store.model("gas").unwrap();
        let dg = store.dataset("gas").unwrap();
        let evalg = PjrtEvaluator::new(
            &engine,
            &store.hlo_path("gas", BATCH_THROUGHPUT),
            &mg,
            BATCH_THROUGHPUT,
        )
        .unwrap();
        let fitg = dg.train.head(512);
        let amg = vec![0u8; mg.hidden];
        let tg = ApproxTables::disabled(mg.hidden);
        let thr = evalg.accuracy(&fitg, &vec![1u8; mg.features], &amg, &tg).unwrap();
        for (label, strat) in [("greedy", Strategy::Greedy), ("bisect", Strategy::Bisect)] {
            let r = harness::bench(&format!("L3e RFP {label} (gas, 128F)"), 3, || {
                let res = rfp::prune(&mg, &fitg, thr, strat, |mask| {
                    evalg.accuracy(&fitg, mask, &amg, &tg).unwrap()
                });
                std::hint::black_box(res.kept);
            });
            let _ = r;
        }
    }

    // L3d: native functional model.
    let native = NativeEvaluator { model: &m };
    let r = harness::bench("L3d native functional 512 samples (har)", 5, || {
        std::hint::black_box(native.accuracy(&fit, &fm, &am, &t));
    });
    println!("         -> {:.0} samples/s", 512.0 / r.mean_ms * 1e3);

    // L3f: netlist optimize on the largest combinational design.
    let mp = store.model("parkinsons").unwrap();
    let activep: Vec<usize> = (0..mp.features).collect();
    harness::bench("L3f comb generate+optimize (parkinsons, 753F)", 3, || {
        let c = combinational::generate(&mp, &activep);
        std::hint::black_box(c.netlist.cells.len());
    });
}
